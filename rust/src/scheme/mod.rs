//! Quantization recipe ("scheme") engine.
//!
//! A scheme maps every tensor of a model to a [`QuantFormat`] via
//! per-module rules — this is the machinery behind Table 7 of the paper,
//! including the paper's contribution **DQ3_K_M** (dynamic layer-indexed
//! bit allocation inside `ffn_down_exps`).
//!
//! Schemes are defined in `configs/schemes/*.json` (embedded at compile
//! time; the Python AOT pipeline reads the same files, making the JSON
//! the single source of truth). Three rule kinds exist:
//!
//! - `{"module": "...", "format": "q4_k"}` — fixed format.
//! - `{"module": "...", "more_bits": {"high": "q6_k", "low": "q4_k"}}` —
//!   llama.cpp's `use_more_bits(i_layer, n_layer)` mix: high precision
//!   for the first ⅛ and last ⅛ of layers plus every third layer in the
//!   middle band. This reproduces Q4_K_M's published 53.4%/46.6%
//!   `ffn_down_exps` split on the 61-layer 671B model.
//! - `{"module": "...", "dynamic": {...}}` — the DQ3_K_M rule: the first
//!   `first_moe` MoE layers get `first_format`, every `period`-th
//!   absolute layer gets `period_format`, the rest `default`. With
//!   `first_moe=2, period=5` on 58 MoE layers this yields the paper's
//!   3.4% q6_k / 20.7% q4_k / 75.9% q3_k split (Appendix A.1).
//!
//! Norms and the MoE router (`ffn_gate_inp`) always stay f32.

pub mod builtin;

use crate::model::{ModelConfig, ModuleClass, TensorInfo};
use crate::quant::QuantFormat;
use crate::util::json::{self, Value};
use anyhow::{bail, Context, Result};

/// llama.cpp's `use_more_bits`: layers that get the higher-precision
/// format in mixed `ffn_down` quantization.
pub fn use_more_bits(i_layer: usize, n_layer: usize) -> bool {
    i_layer < n_layer / 8 || i_layer >= 7 * n_layer / 8 || (i_layer - n_layer / 8) % 3 == 2
}

/// One per-module rule.
#[derive(Debug, Clone)]
pub enum RuleKind {
    Fixed(QuantFormat),
    MoreBits { high: QuantFormat, low: QuantFormat },
    Dynamic {
        first_moe: usize,
        first_format: QuantFormat,
        period: usize,
        period_format: QuantFormat,
        default: QuantFormat,
    },
}

#[derive(Debug, Clone)]
pub struct Rule {
    pub module: ModuleClass,
    pub kind: RuleKind,
}

/// A quantization scheme (recipe).
#[derive(Debug, Clone)]
pub struct Scheme {
    pub name: String,
    pub display: String,
    pub source: String,
    pub default: QuantFormat,
    pub rules: Vec<Rule>,
}

impl Scheme {
    /// Parse a scheme from its JSON definition.
    pub fn from_json(v: &Value) -> Result<Self> {
        let name = v.req("name")?.as_str()?.to_string();
        let display = v.req("display")?.as_str()?.to_string();
        let source = v.req("source")?.as_str()?.to_string();
        let default = QuantFormat::parse(v.req("default")?.as_str()?)?;
        let mut rules = Vec::new();
        for rv in v.req("rules")?.as_arr()? {
            let module_name = rv.req("module")?.as_str()?;
            let module = ModuleClass::parse(module_name)
                .with_context(|| format!("unknown module class {module_name:?}"))?;
            let kind = if let Some(f) = rv.get("format") {
                RuleKind::Fixed(QuantFormat::parse(f.as_str()?)?)
            } else if let Some(mb) = rv.get("more_bits") {
                RuleKind::MoreBits {
                    high: QuantFormat::parse(mb.req("high")?.as_str()?)?,
                    low: QuantFormat::parse(mb.req("low")?.as_str()?)?,
                }
            } else if let Some(dy) = rv.get("dynamic") {
                RuleKind::Dynamic {
                    first_moe: dy.req("first_moe")?.as_usize()?,
                    first_format: QuantFormat::parse(dy.req("first_format")?.as_str()?)?,
                    period: dy.req("period")?.as_usize()?,
                    period_format: QuantFormat::parse(dy.req("period_format")?.as_str()?)?,
                    default: QuantFormat::parse(dy.req("default")?.as_str()?)?,
                }
            } else {
                bail!("rule for {module_name} has no format/more_bits/dynamic");
            };
            rules.push(Rule { module, kind });
        }
        Ok(Scheme { name, display, source, default, rules })
    }

    pub fn parse_str(text: &str) -> Result<Self> {
        Self::from_json(&json::parse(text)?)
    }

    /// The format assigned to tensor `t` of model `cfg`.
    pub fn assign(&self, t: &TensorInfo, cfg: &ModelConfig) -> QuantFormat {
        if !t.class.quantizable() {
            return QuantFormat::F32;
        }
        let rule = self.rules.iter().find(|r| r.module == t.class);
        let fmt = match rule.map(|r| &r.kind) {
            None => self.default,
            Some(RuleKind::Fixed(f)) => *f,
            Some(RuleKind::MoreBits { high, low }) => {
                let layer = t.layer.unwrap_or(0);
                if use_more_bits(layer, cfg.n_layers) {
                    *high
                } else {
                    *low
                }
            }
            Some(RuleKind::Dynamic {
                first_moe,
                first_format,
                period,
                period_format,
                default,
            }) => {
                let layer = t.layer.unwrap_or(0);
                let moe_idx = layer.saturating_sub(cfg.first_dense);
                if moe_idx < *first_moe {
                    *first_format
                } else if *period > 0 && layer % *period == 0 {
                    *period_format
                } else {
                    *default
                }
            }
        };
        // A block format only applies if the tensor's rows are a multiple
        // of the block size; otherwise fall back to f16 (mirrors
        // llama.cpp's fallback for incompatible tensors).
        if t.row_len() % fmt.block_weights() != 0 || t.n_params() as usize % fmt.block_weights() != 0
        {
            QuantFormat::F16
        } else {
            fmt
        }
    }

    /// Precompute the per-tensor format plan for a tensor list.
    ///
    /// The container quantization pipeline consumes this instead of
    /// calling [`Scheme::assign`] inline, so rule dispatch happens once
    /// up front and the (embarrassingly parallel) per-tensor encode
    /// stage works from a plain `Vec<QuantFormat>`.
    pub fn plan(&self, tensors: &[TensorInfo], cfg: &ModelConfig) -> FormatPlan {
        FormatPlan {
            scheme: self.name.clone(),
            formats: tensors.iter().map(|t| self.assign(t, cfg)).collect(),
        }
    }

    /// Total quantized bytes for a model under this scheme.
    pub fn model_bytes(&self, cfg: &ModelConfig) -> u64 {
        let census = cfg.census();
        self.plan(&census, cfg).packed_bytes(&census)
    }

    /// Average bits per weight across the whole model (the "Avg Quants"
    /// row of Table 1).
    pub fn avg_bits(&self, cfg: &ModelConfig) -> f64 {
        let census = cfg.census();
        let total_params: u64 = census.iter().map(|t| t.n_params()).sum();
        let total_bits: f64 = census
            .iter()
            .map(|t| t.n_params() as f64 * self.assign(t, cfg).bits_per_weight())
            .sum();
        total_bits / total_params as f64
    }

    /// Per-module-class format breakdown: for each class present in the
    /// model, the parameter-weighted fraction per format (the cell
    /// contents of Table 7).
    pub fn breakdown(&self, cfg: &ModelConfig) -> Vec<(ModuleClass, Vec<(QuantFormat, f64)>)> {
        let census = cfg.census();
        let mut out = Vec::new();
        for class in ModuleClass::ALL {
            let tensors: Vec<&TensorInfo> =
                census.iter().filter(|t| t.class == class).collect();
            if tensors.is_empty() {
                continue;
            }
            let total: u64 = tensors.iter().map(|t| t.n_params()).sum();
            let mut per_fmt: Vec<(QuantFormat, u64)> = Vec::new();
            for t in &tensors {
                let f = self.assign(t, cfg);
                match per_fmt.iter_mut().find(|(pf, _)| *pf == f) {
                    Some((_, n)) => *n += t.n_params(),
                    None => per_fmt.push((f, t.n_params())),
                }
            }
            per_fmt.sort_by(|a, b| b.1.cmp(&a.1));
            out.push((
                class,
                per_fmt
                    .into_iter()
                    .map(|(f, n)| (f, n as f64 / total as f64))
                    .collect(),
            ));
        }
        out
    }
}

/// A precomputed per-tensor format assignment (one entry per tensor of
/// the list [`Scheme::plan`] was built from, in the same order).
#[derive(Debug, Clone)]
pub struct FormatPlan {
    /// Name of the scheme that produced the plan.
    pub scheme: String,
    /// Assigned format per tensor.
    pub formats: Vec<QuantFormat>,
}

impl FormatPlan {
    /// Packed bytes the planned tensors will occupy (payloads only,
    /// without container alignment padding).
    pub fn packed_bytes(&self, tensors: &[TensorInfo]) -> u64 {
        tensors
            .iter()
            .zip(&self.formats)
            .map(|(t, f)| (t.n_params() as f64 * f.bits_per_weight() / 8.0) as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn use_more_bits_matches_llama_cpp_on_61_layers() {
        // On n=61: high-precision layers are i<7, i>=53, and the middle
        // band every 3rd. ffn_down_exps lives on layers 3..61 → 27 of 58
        // MoE layers get q6_k = 46.6% (Table 7's published split).
        let n = 61;
        let moe_high = (3..61).filter(|&i| use_more_bits(i, n)).count();
        assert_eq!(moe_high, 27);
        // Dense layers 0..3 are all in the first eighth.
        assert!((0..3).all(|i| use_more_bits(i, n)));
    }

    #[test]
    fn dq3_dynamic_split_matches_paper() {
        // first_moe=2 → layers 3,4 get q6_k (2/58 = 3.4%); period=5 →
        // layers 5,10,…,60 get q4_k (12/58 = 20.7%); rest q3_k (75.9%).
        let cfg = ModelConfig::deepseek_v3_671b();
        let scheme = builtin::scheme("dq3_k_m").unwrap();
        let census = cfg.census();
        let mut q3 = 0;
        let mut q4 = 0;
        let mut q6 = 0;
        for t in census.iter().filter(|t| t.class == ModuleClass::FfnDownExps) {
            match scheme.assign(t, &cfg) {
                QuantFormat::Q3K => q3 += 1,
                QuantFormat::Q4K => q4 += 1,
                QuantFormat::Q6K => q6 += 1,
                f => panic!("unexpected format {f}"),
            }
        }
        assert_eq!((q3, q4, q6), (44, 12, 2));
    }

    #[test]
    fn norms_and_router_stay_f32() {
        let cfg = ModelConfig::deepseek_v3_671b();
        for name in ["q4_k_m", "q2_k_l", "dq3_k_m"] {
            let scheme = builtin::scheme(name).unwrap();
            for t in cfg.census() {
                if !t.class.quantizable() {
                    assert_eq!(scheme.assign(&t, &cfg), QuantFormat::F32, "{}", t.name);
                }
            }
        }
    }

    #[test]
    fn ragged_rows_fall_back_to_f16() {
        // A hypothetical tensor with a non-256-multiple row under q4_k.
        let cfg = ModelConfig::tiny_moe();
        let t = TensorInfo {
            name: "blk.0.weird.weight".into(),
            class: ModuleClass::AttnOutput,
            layer: Some(0),
            shape: vec![100, 100],
        };
        let scheme = builtin::scheme("q4_k_m").unwrap();
        assert_eq!(scheme.assign(&t, &cfg), QuantFormat::F16);
    }

    #[test]
    fn avg_bits_monotone_across_schemes() {
        let cfg = ModelConfig::deepseek_v3_671b();
        let names = ["q4_k_m", "q3_k_m", "dq3_k_m", "q2_k_l", "ud_q2_k_xl"];
        let bits: Vec<f64> = names
            .iter()
            .map(|n| builtin::scheme(n).unwrap().avg_bits(&cfg))
            .collect();
        // Table 1 ordering: 4.82 > 3.81 > 3.59 > 2.91 > 2.70.
        for w in bits.windows(2) {
            assert!(w[0] > w[1], "ordering violated: {bits:?}");
        }
    }

    #[test]
    fn table1_avg_bits_match_paper() {
        // The headline reproduction: avg bits per weight on DeepSeek-R1
        // 671B must match Table 1 to within 0.03 bits.
        let cfg = ModelConfig::deepseek_v3_671b();
        let expect = [
            ("q4_k_m", 4.82),
            ("q3_k_m", 3.81),
            ("dq3_k_m", 3.59),
            ("q2_k_l", 2.91),
            ("ud_q2_k_xl", 2.70),
        ];
        for (name, paper) in expect {
            let got = builtin::scheme(name).unwrap().avg_bits(&cfg);
            assert!(
                (got - paper).abs() < 0.03,
                "{name}: computed {got:.3} vs paper {paper}"
            );
        }
    }

    #[test]
    fn plan_matches_inline_assignment() {
        let cfg = ModelConfig::tiny_moe();
        let census = cfg.census();
        for scheme in builtin::all() {
            let plan = scheme.plan(&census, &cfg);
            assert_eq!(plan.scheme, scheme.name);
            assert_eq!(plan.formats.len(), census.len());
            for (t, &f) in census.iter().zip(&plan.formats) {
                assert_eq!(f, scheme.assign(t, &cfg), "{} / {}", scheme.name, t.name);
            }
            // Byte accounting must agree with the model-level helper.
            assert_eq!(plan.packed_bytes(&census), scheme.model_bytes(&cfg));
        }
    }

    #[test]
    fn table1_model_sizes_match_paper() {
        // Model sizes in GiB (paper's "G"): 377 / 298 / 281 / 228 / 212.
        let cfg = ModelConfig::deepseek_v3_671b();
        let expect = [
            ("q4_k_m", 377.0),
            ("q3_k_m", 298.0),
            ("dq3_k_m", 281.0),
            ("q2_k_l", 228.0),
            ("ud_q2_k_xl", 212.0),
        ];
        for (name, paper) in expect {
            let bytes = builtin::scheme(name).unwrap().model_bytes(&cfg);
            let gib = bytes as f64 / (1u64 << 30) as f64;
            assert!(
                (gib - paper).abs() < 3.0,
                "{name}: computed {gib:.1}G vs paper {paper}G"
            );
        }
    }
}
