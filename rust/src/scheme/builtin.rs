//! Built-in schemes, embedded from `configs/schemes/*.json` at compile
//! time. The Python AOT pipeline (`python/compile/aot.py`) reads the
//! same files, so recipes cannot drift between the two sides.

use super::Scheme;
use anyhow::{bail, Result};

macro_rules! embedded {
    ($($name:literal),* $(,)?) => {
        /// `(name, json_text)` pairs for every embedded scheme.
        pub const EMBEDDED: &[(&str, &str)] = &[
            $(($name, include_str!(concat!("../../../configs/schemes/", $name, ".json")))),*
        ];
    };
}

embedded!(
    "f32",
    "q8_0",
    "q4_k_m",
    "q4_k",
    "q3_k_m",
    "q3_k",
    "dq3_k_m",
    "q2_k_l",
    "ud_q2_k_xl",
);

/// Names of all built-in schemes, most precise first.
pub fn names() -> Vec<&'static str> {
    EMBEDDED.iter().map(|(n, _)| *n).collect()
}

/// Load a built-in scheme by name.
pub fn scheme(name: &str) -> Result<Scheme> {
    for (n, text) in EMBEDDED {
        if *n == name {
            return Scheme::parse_str(text);
        }
    }
    bail!(
        "unknown scheme {name:?} (available: {})",
        names().join(", ")
    )
}

/// All built-in schemes.
pub fn all() -> Vec<Scheme> {
    EMBEDDED
        .iter()
        .map(|(_, text)| Scheme::parse_str(text).expect("embedded scheme must parse"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_embedded_schemes_parse() {
        let schemes = all();
        assert_eq!(schemes.len(), EMBEDDED.len());
        for s in &schemes {
            assert!(!s.display.is_empty());
        }
    }

    #[test]
    fn names_match_json_name_field() {
        for (n, text) in EMBEDDED {
            let s = Scheme::parse_str(text).unwrap();
            assert_eq!(&s.name, n, "file name and JSON name field must agree");
        }
    }

    #[test]
    fn unknown_scheme_errors() {
        assert!(scheme("q9_z").is_err());
    }
}
