//! IEEE 754 binary16 conversion (replaces the `half` crate in this
//! offline build).
//!
//! Conversion uses round-to-nearest-even, matching hardware and the
//! `half` crate, so the stored scales are identical to what llama.cpp's
//! `GGML_FP32_TO_FP16` produces on x86.

/// Convert f32 → f16 bits (round to nearest even, IEEE semantics
/// including denormals, infinities and NaN).
pub fn f32_to_f16_bits(v: f32) -> u16 {
    let x = v.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let exp = ((x >> 23) & 0xFF) as i32;
    let man = x & 0x007F_FFFF;

    if exp == 255 {
        if man == 0 {
            return sign | 0x7C00; // infinity
        }
        // NaN: truncate the payload, force the quiet bit so it stays NaN.
        return sign | 0x7E00 | ((man >> 13) as u16 & 0x01FF);
    }

    // Re-bias: f32 exp-127 + 15.
    let unbiased = exp - 127;
    if unbiased < -24 {
        // Underflows to signed zero (too small even for denormal).
        return sign;
    }
    if unbiased < -14 {
        // Denormal half: mantissa with implicit bit, shifted.
        let shift = (-14 - unbiased) as u32; // 1..=10
        let full = man | 0x0080_0000; // implicit leading 1
        let half_man = full >> (13 + shift);
        // Round to nearest even on the dropped bits.
        let dropped = full & ((1u32 << (13 + shift)) - 1);
        let halfway = 1u32 << (12 + shift);
        let mut h = half_man as u16;
        if dropped > halfway || (dropped == halfway && (h & 1) == 1) {
            h += 1;
        }
        return sign | h;
    }
    if unbiased > 15 {
        // Overflows to infinity.
        return sign | 0x7C00;
    }
    let hexp = ((unbiased + 15) as u16) << 10;
    let mut h = sign | hexp | ((man >> 13) as u16);
    // Round to nearest even on the 13 dropped bits.
    let dropped = man & 0x1FFF;
    if dropped > 0x1000 || (dropped == 0x1000 && (h & 1) == 1) {
        h = h.wrapping_add(1); // may carry into exponent — that's correct
    }
    h
}

/// Convert f16 bits → f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // signed zero
        } else {
            // Denormal: normalize.
            let mut e = -1i32;
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                e += 1;
            }
            let m = (m & 0x03FF) << 13;
            let e = (127 - 15 - e) as u32;
            sign | (e << 23) | m
        }
    } else if exp == 31 {
        sign | 0x7F80_0000 | (man << 13) // Inf / NaN
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Round an f32 through f16 precision.
#[inline]
pub fn round_f16(v: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 0.625, 2.0, 65504.0, -65504.0] {
            assert_eq!(round_f16(v), v, "value {v}");
        }
    }

    #[test]
    fn known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF);
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(1e9), 0x7C00); // overflow → inf
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(0xFC00), f32::NEG_INFINITY);
    }

    #[test]
    fn denormals_roundtrip() {
        // Smallest positive half denormal = 2^-24.
        let tiny = f32::powi(2.0, -24);
        assert_eq!(f32_to_f16_bits(tiny), 0x0001);
        assert_eq!(f16_bits_to_f32(0x0001), tiny);
        // Below half the smallest denormal → rounds to zero.
        assert_eq!(f32_to_f16_bits(tiny / 4.0), 0x0000);
    }

    #[test]
    fn nan_stays_nan() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10;
        // nearest-even picks 1.0 (mantissa even).
        let v = 1.0 + f32::powi(2.0, -11);
        assert_eq!(round_f16(v), 1.0);
        // 1 + 3·2^-11 is halfway between 1+2^-10 and 1+2^-9 → picks even.
        let v = 1.0 + 3.0 * f32::powi(2.0, -11);
        assert_eq!(round_f16(v), 1.0 + f32::powi(2.0, -9));
    }

    #[test]
    fn exhaustive_f16_to_f32_to_f16() {
        // Every finite half value must survive a roundtrip through f32.
        for bits in 0u16..=0xFFFF {
            let exp = (bits >> 10) & 0x1F;
            if exp == 31 {
                continue; // inf/nan payloads not bit-stable by design
            }
            let f = f16_bits_to_f32(bits);
            assert_eq!(f32_to_f16_bits(f), bits, "bits {bits:#06x} f={f}");
        }
    }

    #[test]
    fn monotone_on_positives() {
        let mut prev = -1.0f32;
        for bits in 0u16..0x7C00 {
            let f = f16_bits_to_f32(bits);
            assert!(f > prev, "bits {bits:#06x}");
            prev = f;
        }
    }
}
