//! Minimal JSON parser/serializer (offline replacement for `serde_json`).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escape
//! sequences incl. `\uXXXX`, numbers, booleans, null). Object key order
//! is preserved (`Vec<(String, Value)>`) so emitted configs diff cleanly.
//! Used for `configs/schemes/*.json`, `configs/models/*.json`,
//! checkpoint manifests and benchmark reports.

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }
    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > u64::MAX as f64 {
            bail!("expected unsigned integer, got {n}");
        }
        Ok(n as u64)
    }
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }
    pub fn as_obj(&self) -> Result<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Ok(o),
            _ => bail!("expected object, got {self:?}"),
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    /// Object field lookup that errors with the key name.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing field {key:?}"))
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        bail!("trailing characters at offset {}", p.i);
    }
    Ok(v)
}

/// Parse the JSON file at `path`.
pub fn parse_file(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at offset {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at offset {}", c as char, self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(fields));
                }
                c => bail!("expected ',' or '}}' at offset {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                c => bail!("expected ',' or ']' at offset {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("invalid low surrogate at offset {}", self.i);
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(char::from_u32(c).ok_or_else(|| anyhow!("bad codepoint"))?);
                            } else if (0xDC00..0xE000).contains(&cp) {
                                bail!("unpaired low surrogate at offset {}", self.i);
                            } else {
                                out.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                            }
                        }
                        c => bail!("invalid escape {:?} at offset {}", c as char, self.i),
                    }
                }
                c if c < 0x20 => bail!("unescaped control character at offset {}", self.i - 1),
                c => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => bail!("invalid UTF-8 byte at offset {}", self.i - 1),
                    };
                    let start = self.i - 1;
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8 sequence");
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| anyhow!("invalid UTF-8 at offset {start}"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("truncated \\u escape");
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
        let v = u32::from_str_radix(s, 16).context("invalid \\u escape")?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().context("invalid number")?))
    }
}

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, 0, true, &mut s);
    s
}

/// Serialize compactly.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, 0, false, &mut s);
    s
}

fn write_value(v: &Value, indent: usize, pretty: bool, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                }
                write_value(item, indent + 1, pretty, out);
            }
            if pretty {
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
            }
            out.push(']');
        }
        Value::Obj(o) => {
            if o.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                }
                write_string(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(val, indent + 1, pretty, out);
            }
            if pretty {
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Value {
    Value::Num(n)
}
pub fn str_(s: &str) -> Value {
    Value::Str(s.to_string())
}
pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(
            v.req("a").unwrap().as_arr().unwrap()[2].req("b").unwrap(),
            &Value::Null
        );
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\Aé");
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = parse(r#"{"名": "值"}"#).unwrap();
        assert_eq!(v.req("名").unwrap().as_str().unwrap(), "值");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01a").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"name":"dq3_k_m","rules":[{"re":"ffn_down_exps","fmt":"q3_k","every":4}],"frac":0.759,"ok":true,"none":null}"#;
        let v = parse(src).unwrap();
        let compact = to_string(&v);
        assert_eq!(parse(&compact).unwrap(), v);
        let pretty = to_string_pretty(&v);
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn key_order_preserved() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn integers_serialized_without_dot() {
        assert_eq!(to_string(&Value::Num(32768.0)), "32768");
        assert_eq!(to_string(&Value::Num(0.5)), "0.5");
    }
}
