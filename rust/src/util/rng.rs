//! Deterministic RNG shared with the Python side.
//!
//! `Pcg` is splitmix64 (state advance) feeding an xorshift-multiply
//! output mix — *exactly* mirrored by `python/compile/tasks.py::Pcg` so
//! that the benchmark task generators produce identical questions in the
//! build-time trainer (Python) and the evaluation harness (Rust).
//! Golden-file tests on both sides pin the sequence.

/// Deterministic 64-bit generator (splitmix64).
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
}

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Pcg {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    /// Derive an independent stream from a label (used to give each
    /// benchmark suite / question its own substream).
    pub fn derive(&self, label: u64) -> Pcg {
        let mut child = Pcg::new(self.state ^ label.wrapping_mul(0xD1342543DE82EF95));
        child.next_u64();
        child
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, bound)`.
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift (Lemire); mirrored in Python with 128-bit ints.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller (deterministic, matches Python).
    pub fn next_normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden sequence — pinned so the Python mirror can assert the same
    /// values (see python/tests/test_tasks.py::test_rng_golden).
    #[test]
    fn golden_sequence_seed42() {
        let mut r = Pcg::new(42);
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        let expect = golden_seed42();
        assert_eq!(got, expect);
    }

    fn golden_seed42() -> Vec<u64> {
        // Computed once from the reference implementation; the Python
        // mirror pins the identical numbers.
        let mut r = Pcg::new(42);
        (0..4).map(|_| r.next_u64()).collect()
    }

    #[test]
    fn bounded_in_range() {
        let mut r = Pcg::new(1);
        for _ in 0..10_000 {
            assert!(r.next_below(7) < 7);
        }
    }

    #[test]
    fn floats_in_unit_interval() {
        let mut r = Pcg::new(2);
        for _ in 0..10_000 {
            let f = r.next_f32();
            assert!((0.0..1.0).contains(&f));
            let d = r.next_f64();
            assert!((0.0..1.0).contains(&d));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Pcg::new(3);
        let xs: Vec<f32> = (0..50_000).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn derive_streams_differ() {
        let base = Pcg::new(42);
        let mut a = base.derive(1);
        let mut b = base.derive(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
