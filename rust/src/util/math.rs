//! Deterministic f32 transcendentals for the native forward pass.
//!
//! The native serving backend's determinism contract ("two engines over
//! the same container produce bit-identical logits") extends beyond the
//! quantized matvecs to every nonlinearity on the path. IEEE add, mul,
//! div and sqrt are exactly rounded and therefore reproducible anywhere,
//! but `exp`/`sin`/`cos` come from libm and are **not** — different
//! platforms (and different languages, which matters for the bit-exact
//! Python golden mirror in `python/tools/bless_goldens.py`) round them
//! differently. This module re-implements the few transcendentals the
//! forward pass needs from exactly-rounded primitives only:
//!
//! - [`exp_f32`] — Cephes-style range reduction (`x = n·ln2 + r`, with
//!   the two-constant ln2 split) plus a degree-7 Taylor polynomial in
//!   Horner form, scaled by an exponent-bit-constructed `2^n`. Relative
//!   error ≤ ~3e-7 over the clamped domain.
//! - [`sin_small`] / [`cos_small`] — Taylor polynomials valid on
//!   `|x| ≤ 1`, used only to seed the RoPE angle recurrence (per-step
//!   rotary angles are all ≤ 1 radian; larger positions are reached by
//!   the exactly-rounded angle-addition recurrence in
//!   `runtime::forward`).
//! - [`sigmoid`] / [`silu`] and the sequential-order [`softmax_in_place`]
//!   built on top of `exp_f32`.
//!
//! Every operation here is a single-rounded f32 add/mul/div/sqrt or a
//! bit manipulation; the Python mirror replays the identical sequence in
//! `np.float32` and lands on the same bits. Do not "simplify" an
//! expression into an algebraically equal form — that changes rounding
//! and breaks the committed `forward.*.fnv64` golden checksums.

/// Inputs below this produce 0.0 (keeps the exponent construction in
/// normal range: `n ≥ -126`).
pub const EXP_LO: f32 = -87.0;
/// Inputs above this saturate (keeps `n ≤ 127`).
pub const EXP_HI: f32 = 88.0;

const LOG2E: f32 = 1.4426950408889634;
/// ln2 split: `LN2_HI` carries the high bits exactly (0.693359375 is a
/// dyadic rational), `LN2_LO` the remainder, so `x − n·LN2_HI` is exact
/// for the n range reduction produces.
const LN2_HI: f32 = 0.693359375;
const LN2_LO: f32 = -0.00021219444;

/// Taylor coefficients 1/k! for k = 0..=7, Horner-evaluated.
const EXP_P: [f32; 8] = [
    1.0,
    1.0,
    0.5,
    0.16666667,
    0.041666667,
    0.0083333333,
    0.0013888889,
    0.00019841270,
];

/// Deterministic `e^x` in pure f32 arithmetic (see module docs).
/// Clamps to `[EXP_LO, EXP_HI]`; never returns NaN for finite input.
pub fn exp_f32(x: f32) -> f32 {
    let x = x.clamp(EXP_LO, EXP_HI);
    let n = (x * LOG2E).round();
    let r = (x - n * LN2_HI) - n * LN2_LO;
    let mut p = EXP_P[7];
    for k in (0..7).rev() {
        p = p * r + EXP_P[k];
    }
    // 2^n constructed directly in the exponent field; n ∈ [-126, 127]
    // by the clamp above, so the result is a normal number.
    let scale = f32::from_bits(((n as i32 + 127) as u32) << 23);
    p * scale
}

/// Deterministic logistic function `1 / (1 + e^{−x})`.
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + exp_f32(-x))
}

/// Deterministic SiLU / swish: `x · sigmoid(x)` — the MoE FFN
/// activation (DeepSeek-V3 uses SwiGLU: `down(silu(gate(x)) · up(x))`).
pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// Taylor sine, valid (≤ ~1e-8 abs error) for `|x| ≤ 1`.
pub fn sin_small(x: f32) -> f32 {
    const S: [f32; 4] = [-0.16666667, 0.0083333333, -0.00019841270, 0.0000027557319];
    let t = x * x;
    let mut p = S[3];
    for k in (0..3).rev() {
        p = p * t + S[k];
    }
    x + (x * t) * p
}

/// Taylor cosine, valid (≤ ~1e-8 abs error) for `|x| ≤ 1`.
pub fn cos_small(x: f32) -> f32 {
    const C: [f32; 4] = [-0.5, 0.041666667, -0.0013888889, 0.000024801587];
    let t = x * x;
    let mut p = C[3];
    for k in (0..3).rev() {
        p = p * t + C[k];
    }
    1.0 + t * p
}

/// `sqrt(2)` to f64 precision (bits `0x3FF6A09E667F3BCD`) — the
/// mantissa-range pivot of [`ln_f32`]'s reduction.
const SQRT2: f64 = std::f64::consts::SQRT_2;
/// `ln(2)` to f64 precision (bits `0x3FE62E42FEFA39EF`).
const LN2_F64: f64 = std::f64::consts::LN_2;

/// Deterministic natural logarithm of a positive, finite, **normal**
/// f64, rounded to f32 — used once per model load to turn
/// [`crate::model::ModelConfig::rope_base`] into the `ln θ_base` the
/// RoPE frequency table needs (`θ_i = exp(−(2i/d)·ln base)`).
///
/// libm's `ln` is not guaranteed to round identically across platforms
/// (or across languages — the Python golden mirror must land on the
/// same bits), so this reimplements it from exactly-rounded f64
/// primitives only: split `x = m·2^e` with `m ∈ (√2/2, √2]` by bit
/// manipulation, then `ln m = 2·atanh(s)` for `s = (m−1)/(m+1)` via a
/// fixed 13-term odd series (|s| ≤ 0.172, so the truncation error is
/// ~1e-17 relative), and `ln x = e·ln2 + ln m`. Every step is a
/// single-rounded f64 add/mul/div, replayed identically by
/// `python/tools/bless_goldens.py`. The f64→f32 cast at the end absorbs
/// the few-ulp f64 error, so the result is the correctly rounded f32
/// log for every practical base (`ln_f32(10000.0)` reproduces the
/// historical `ROPE_BASE_LN` constant bit-for-bit — tested).
pub fn ln_f32(x: f64) -> f32 {
    assert!(
        x.is_finite() && x >= f64::MIN_POSITIVE,
        "ln_f32 needs a positive normal input, got {x:e}"
    );
    let bits = x.to_bits();
    let mut e = ((bits >> 52) & 0x7FF) as i64 - 1023;
    let mut m = f64::from_bits((bits & 0x000F_FFFF_FFFF_FFFF) | (1023u64 << 52));
    if m > SQRT2 {
        m *= 0.5; // exact: pure exponent decrement
        e += 1;
    }
    let s = (m - 1.0) / (m + 1.0);
    let s2 = s * s;
    // Horner over 1/(2k+1) for k = 12..=1; ln m = 2s·(1 + s²·p).
    let mut p = 0.0f64;
    let mut k = 12i64;
    while k >= 1 {
        p = p * s2 + 1.0 / (2 * k + 1) as f64;
        k -= 1;
    }
    let ln_m = 2.0 * s * (1.0 + s2 * p);
    (e as f64 * LN2_F64 + ln_m) as f32
}

/// In-place max-subtracted softmax with a **fixed sequential reduction
/// order**: the max fold, the exp+sum loop and the divide all walk the
/// slice front to back, so the result is a pure function of the input
/// bits (attention weights and router probabilities both ride this).
pub fn softmax_in_place(xs: &mut [f32]) {
    let mut m = f32::NEG_INFINITY;
    for &v in xs.iter() {
        m = m.max(v);
    }
    let mut s = 0.0f32;
    for v in xs.iter_mut() {
        *v = exp_f32(*v - m);
        s += *v;
    }
    for v in xs.iter_mut() {
        *v /= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn exp_matches_libm_within_3e7() {
        let mut rng = Pcg::new(0xE4B);
        for _ in 0..20_000 {
            let x = (rng.next_f32() - 0.5) * 60.0;
            let got = exp_f32(x) as f64;
            let want = (x as f64).exp();
            let rel = ((got - want) / want).abs();
            assert!(rel < 3e-7, "x={x}: got {got}, want {want} (rel {rel:.2e})");
        }
        assert_eq!(exp_f32(0.0), 1.0);
    }

    #[test]
    fn exp_saturates_cleanly() {
        assert!(exp_f32(1000.0).is_finite());
        assert!(exp_f32(-1000.0) > 0.0, "clamped low end stays normal");
        assert!(exp_f32(f32::NEG_INFINITY).is_finite());
        assert_eq!(exp_f32(EXP_LO).to_bits(), exp_f32(-500.0).to_bits());
    }

    #[test]
    fn sin_cos_match_libm_on_unit_interval() {
        for k in 0..=1000 {
            let x = k as f32 / 1000.0;
            assert!((sin_small(x) as f64 - (x as f64).sin()).abs() < 1e-6, "sin {x}");
            assert!((cos_small(x) as f64 - (x as f64).cos()).abs() < 1e-6, "cos {x}");
        }
    }

    #[test]
    fn silu_shape() {
        assert_eq!(silu(0.0), 0.0);
        assert!((silu(5.0) - 5.0).abs() < 0.04);
        assert!(silu(-5.0).abs() < 0.04);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn ln_reproduces_the_historical_rope_constant_and_tracks_libm() {
        // The RoPE table was seeded from the literal 9.2103404 (ln 10⁴)
        // before the base moved into ModelConfig; the tiny-moe forward
        // goldens stay byte-identical only if ln_f32 lands on the same
        // bits.
        assert_eq!(ln_f32(10000.0).to_bits(), 9.210_340_4_f32.to_bits());
        let mut rng = Pcg::new(0x106);
        for _ in 0..20_000 {
            let x = (rng.next_f64() * 20.0 - 10.0).exp2() * (1.0 + rng.next_f64());
            let got = ln_f32(x) as f64;
            let want = x.ln();
            assert!(
                (got - want).abs() <= want.abs().max(1.0) * 1e-7,
                "ln({x}): got {got}, want {want}"
            );
        }
        assert_eq!(ln_f32(1.0), 0.0);
        // Exact powers of two reduce to e·ln2 with m = 1.
        assert_eq!(ln_f32(2.0), std::f64::consts::LN_2 as f32);
        assert_eq!(ln_f32(1024.0), (10.0 * std::f64::consts::LN_2) as f32);
    }

    #[test]
    fn softmax_sums_to_one_and_is_deterministic() {
        let mut a = vec![0.5f32, -1.0, 3.25, 0.0, 2.0];
        let mut b = a.clone();
        softmax_in_place(&mut a);
        softmax_in_place(&mut b);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        let s: f32 = a.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(a[2] > a[4] && a[4] > a[0]);
        // Shift invariance up to the shared max-subtraction.
        let mut c = vec![100.5f32, 99.0, 103.25, 100.0, 102.0];
        softmax_in_place(&mut c);
        for (x, y) in a.iter().zip(&c) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
