//! Micro-benchmark harness (offline replacement for `criterion`).
//!
//! Provides warm-up, adaptive iteration-count calibration, and robust
//! statistics (median / p10 / p90 over timed batches). Bench targets in
//! `benches/` use [`Bench`] with `harness = false`, printing one line per
//! benchmark in a stable, grep-able format:
//!
//! ```text
//! bench <name> ... median 12.34 µs/iter (p10 11.9, p90 13.0, 160 iters × 32 batches) [thrpt: 1.2 GiB/s]
//! ```

use std::time::{Duration, Instant};

/// A single benchmark runner.
pub struct Bench {
    /// Target wall-clock time per measurement batch.
    pub batch_target: Duration,
    /// Number of measured batches.
    pub batches: usize,
    /// Warm-up time before calibration.
    pub warmup: Duration,
    /// Optional multiplier: bytes processed per iteration (enables
    /// throughput reporting).
    pub bytes_per_iter: Option<u64>,
    /// Optional multiplier: items processed per iteration.
    pub items_per_iter: Option<u64>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            batch_target: Duration::from_millis(20),
            batches: 32,
            warmup: Duration::from_millis(100),
            bytes_per_iter: None,
            items_per_iter: None,
        }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters_per_batch: u64,
    pub batches: usize,
}

impl BenchResult {
    pub fn median(&self) -> Duration {
        Duration::from_nanos(self.median_ns as u64)
    }
}

/// Pretty time formatting with unit auto-selection.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick preset for expensive end-to-end benches.
    pub fn quick() -> Self {
        Bench {
            batch_target: Duration::from_millis(50),
            batches: 8,
            warmup: Duration::from_millis(50),
            ..Default::default()
        }
    }

    pub fn throughput_bytes(mut self, bytes: u64) -> Self {
        self.bytes_per_iter = Some(bytes);
        self
    }

    pub fn throughput_items(mut self, items: u64) -> Self {
        self.items_per_iter = Some(items);
        self
    }

    /// Run `f` repeatedly and report statistics. The closure's return
    /// value is passed through `std::hint::black_box` to defeat DCE.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // Warm-up and single-shot estimate.
        let wstart = Instant::now();
        let mut one = Duration::ZERO;
        let mut warm_iters = 0u64;
        while wstart.elapsed() < self.warmup || warm_iters == 0 {
            let t = Instant::now();
            std::hint::black_box(f());
            one = t.elapsed();
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        // Calibrate iterations per batch from the last single-shot time.
        let per_iter_ns = one.as_nanos().max(1) as u64;
        let iters = (self.batch_target.as_nanos() as u64 / per_iter_ns).clamp(1, 10_000_000);

        let mut samples = Vec::with_capacity(self.batches);
        for _ in 0..self.batches {
            let t = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            samples.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| -> f64 {
            let idx = (p * (samples.len() - 1) as f64).round() as usize;
            samples[idx]
        };
        let res = BenchResult {
            name: name.to_string(),
            median_ns: q(0.5),
            p10_ns: q(0.1),
            p90_ns: q(0.9),
            iters_per_batch: iters,
            batches: self.batches,
        };
        let mut extra = String::new();
        if let Some(bytes) = self.bytes_per_iter {
            let gibps = bytes as f64 / res.median_ns * 1e9 / (1u64 << 30) as f64;
            extra.push_str(&format!(" [thrpt: {gibps:.2} GiB/s]"));
        }
        if let Some(items) = self.items_per_iter {
            let mips = items as f64 / res.median_ns * 1e9 / 1e6;
            extra.push_str(&format!(" [thrpt: {mips:.2} Mitem/s]"));
        }
        println!(
            "bench {:<44} median {:>10}/iter (p10 {}, p90 {}, {} iters × {} batches){}",
            res.name,
            fmt_ns(res.median_ns),
            fmt_ns(res.p10_ns),
            fmt_ns(res.p90_ns),
            res.iters_per_batch,
            res.batches,
            extra
        );
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench {
            batch_target: Duration::from_micros(200),
            batches: 4,
            warmup: Duration::from_micros(100),
            ..Default::default()
        };
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
    }

    #[test]
    fn format_units() {
        assert_eq!(fmt_ns(500.0), "500.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
