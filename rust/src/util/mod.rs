//! Small shared utilities: deterministic RNG and deterministic f32
//! transcendentals (both mirrored bit-exactly in Python), formatting
//! helpers.

pub mod bench;
pub mod f16;
pub mod json;
pub mod math;
pub mod rng;

/// Format a byte count the way the paper's tables do (GiB, labelled "G"
/// / "GB" — the paper's 377G for Q4_K_M R1 is 377 GiB).
pub fn fmt_gib(bytes: u64) -> String {
    format!("{:.0}G", bytes as f64 / (1u64 << 30) as f64)
}

/// Format GiB with one decimal.
pub fn fmt_gib1(bytes: u64) -> String {
    format!("{:.1}GiB", bytes as f64 / (1u64 << 30) as f64)
}

/// FNV-1a 64-bit fold — the checksum behind the committed golden
/// fixtures (`container.*.fnv64`, `forward.*.fnv64`), mirrored
/// byte-for-byte in `python/tools/bless_goldens.py`.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Mean and (population) standard deviation of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gib_formatting() {
        assert_eq!(fmt_gib(377 * (1u64 << 30)), "377G");
        assert_eq!(fmt_gib1(3 * (1u64 << 29)), "1.5GiB");
    }

    #[test]
    fn fnv64_known_values() {
        assert_eq!(fnv64(b""), 0xcbf29ce484222325);
        // FNV-1a("a") — published reference value.
        assert_eq!(fnv64(b"a"), 0xaf63dc4c8601ec8c);
        assert_ne!(fnv64(b"ab"), fnv64(b"ba"));
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }
}
