//! Analytic memory-usage (MU) model — the arithmetic behind Table 1 and
//! Table 6 of the paper.
//!
//! Components, for an 8-device single-machine deployment at context
//! length `n_ctx`:
//!
//! 1. **Weights** — exact: census × per-tensor quantized bytes
//!    ([`crate::scheme::Scheme::model_bytes`]), plus a runtime factor
//!    (`WEIGHT_RUNTIME_FACTOR`) for dequantization scratch and
//!    allocator alignment.
//! 2. **KV cache** — MLA compressed cache
//!    (`(kv_lora_rank + qk_rope) · n_layers · 2 bytes` per token) ×
//!    `n_ctx` × `n_seq` parallel sequences.
//! 3. **Runtime overhead** — per-device constant
//!    (`RUNTIME_OVERHEAD_PER_GPU_GIB`): CUDA/CANN context, compute-graph
//!    buffers, logits buffer, fragmentation.
//!
//! The two constants are calibrated once against the paper's published
//! Q4_K_M row (568 GB total / 71 GB per GPU at 32K ctx) and then *held
//! fixed* across all schemes and models; the remaining rows of Table 1
//! are predictions of the model, matching the paper within ±2 GB.

pub mod devices;

use crate::model::{ModelConfig, ModelKind};
use crate::quant::KvScheme;
use crate::runtime::sharded::{expert_range, row_range, MAX_SHARDS};
use crate::scheme::Scheme;
use anyhow::{bail, Result};

/// Parallel 32K-token sequences assumed by the paper's deployment.
pub const DEFAULT_N_SEQ: usize = 16;
/// Devices per machine in every configuration the paper considers.
pub const DEVICES_PER_NODE: usize = 8;
/// Weight-proportional runtime overhead (dequant scratch, alignment).
pub const WEIGHT_RUNTIME_FACTOR: f64 = 1.03;
/// Fixed per-device runtime overhead in GiB (context, graph buffers,
/// logits, fragmentation). Calibrated on the paper's Q4_K_M row.
pub const RUNTIME_OVERHEAD_PER_GPU_GIB: f64 = 18.2;

/// Memory-usage estimate for one (model, scheme, context) deployment.
#[derive(Debug, Clone)]
pub struct MemoryEstimate {
    /// Quantized checkpoint size (bytes) — the paper's "Model Size".
    pub model_bytes: u64,
    /// KV-cache bytes at the configured context.
    pub kv_bytes: u64,
    /// Total memory use across the node (bytes).
    pub total_bytes: u64,
    /// Per-device memory use (bytes).
    pub per_gpu_bytes: u64,
    /// Average bits per weight.
    pub avg_bits: f64,
    pub n_ctx: usize,
    pub n_seq: usize,
}

/// Estimate memory usage for `cfg` quantized with `scheme` at context
/// `n_ctx` with `n_seq` parallel sequences on an 8-device node.
pub fn estimate(cfg: &ModelConfig, scheme: &Scheme, n_ctx: usize, n_seq: usize) -> MemoryEstimate {
    let model_bytes = scheme.model_bytes(cfg);
    let kv_bytes = (cfg.kv_bytes_per_token() * n_ctx * n_seq) as u64;
    let overhead =
        (RUNTIME_OVERHEAD_PER_GPU_GIB * DEVICES_PER_NODE as f64 * (1u64 << 30) as f64) as u64;
    let total_bytes =
        (model_bytes as f64 * WEIGHT_RUNTIME_FACTOR) as u64 + kv_bytes + overhead;
    MemoryEstimate {
        model_bytes,
        kv_bytes,
        total_bytes,
        per_gpu_bytes: total_bytes / DEVICES_PER_NODE as u64,
        avg_bits: scheme.avg_bits(cfg),
        n_ctx,
        n_seq,
    }
}

/// Estimate with the paper's defaults (32K context, 16 sequences).
pub fn estimate_default(cfg: &ModelConfig, scheme: &Scheme) -> MemoryEstimate {
    estimate(cfg, scheme, 32_768, DEFAULT_N_SEQ)
}

/// Predict the per-shard per-tensor weight bytes the sharded engine
/// ([`crate::runtime::sharded::ShardRuntime`]) will hold resident when
/// `cfg` quantized with `scheme` is partitioned across `n_shards`
/// workers.
///
/// This is the analytic side of the planner-vs-engine contract: the
/// returned lists must match [`ShardRuntime::shard_plan`] tensor for
/// tensor and byte for byte (exact `row_bytes` arithmetic, not the
/// fractional bits-per-weight approximation used for Table 1 sizes).
/// The partition rule mirrors the loader's classification: 3-D tensors
/// split by expert range, 2-D tensors other than the embedding split by
/// output-row range, everything else stays on the driver and is omitted.
///
/// [`ShardRuntime::shard_plan`]: crate::runtime::sharded::ShardRuntime::shard_plan
pub fn shard_weights(
    cfg: &ModelConfig,
    scheme: &Scheme,
    n_shards: usize,
) -> Result<Vec<Vec<(String, u64)>>> {
    if n_shards == 0 || n_shards > MAX_SHARDS {
        bail!("shard count {n_shards} out of range 1..={MAX_SHARDS}");
    }
    let mut plan: Vec<Vec<(String, u64)>> = vec![Vec::new(); n_shards];
    for t in cfg.census() {
        let fmt = scheme.assign(&t, cfg);
        if t.shape.len() == 3 {
            // Expert-parallel: whole experts, `row_bytes(in) * out` each.
            let Ok(rb) = fmt.row_bytes(t.shape[2]) else { continue };
            let per = (rb * t.shape[1]) as u64;
            for (s, shard) in plan.iter_mut().enumerate() {
                let (e0, e1) = expert_range(t.shape[0], n_shards, s);
                shard.push((t.name.clone(), (e1 - e0) as u64 * per));
            }
        } else if t.shape.len() == 2 && t.name != "token_embd.weight" {
            // Row-parallel: contiguous output rows, one k-quant row each.
            let Ok(rb) = fmt.row_bytes(t.shape[1]) else { continue };
            for (s, shard) in plan.iter_mut().enumerate() {
                let (r0, r1) = row_range(t.shape[0], n_shards, s);
                shard.push((t.name.clone(), (r1 - r0) as u64 * rb as u64));
            }
        }
    }
    Ok(plan)
}

/// Named per-layer byte plan one cached token occupies in the native
/// engine under KV scheme `scheme` — the analytic side of the
/// planner-vs-engine contract for the **quantized KV cache** (PR 10).
///
/// The returned list must match
/// [`KvCache::measured_token_plan`] entry for entry
/// (`blk.{i}.kv_row` / `blk.{i}.kv_expanded`), exactly like
/// [`shard_weights`] matches `ShardRuntime::shard_plan`: the
/// differential suite diffs the two lists by *name* so any drift is
/// reported per tensor, not as one opaque total. `absorb_mla` mirrors
/// [`ForwardPass::set_mla_absorption`] — it decides whether the
/// expanded plane exists (and quantized KV requires it for MLA
/// models).
///
/// Note this is the **engine** cache footprint (f32 rows by default,
/// encoded codec lines under `q8_0`), not the f16 deployment analytic
/// [`ModelConfig::kv_bytes_per_token`] Table 1 is calibrated on —
/// that constant is pinned by `table1_reproduction` and unchanged.
///
/// [`KvCache::measured_token_plan`]: crate::runtime::forward::KvCache::measured_token_plan
/// [`ForwardPass::set_mla_absorption`]: crate::runtime::forward::ForwardPass::set_mla_absorption
pub fn kv_token_plan(
    cfg: &ModelConfig,
    scheme: KvScheme,
    absorb_mla: bool,
) -> Vec<(String, u64)> {
    let width = cfg.kv_cache_width();
    let xwidth = match cfg.kind {
        ModelKind::MlaMoe if absorb_mla => cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim),
        _ => 0,
    };
    let (row_b, xrow_b) = (scheme.line_bytes(width), scheme.line_bytes(xwidth));
    let mut plan = Vec::with_capacity(cfg.n_layers * 2);
    for li in 0..cfg.n_layers {
        plan.push((format!("blk.{li}.kv_row"), row_b as u64));
        if xwidth > 0 {
            plan.push((format!("blk.{li}.kv_expanded"), xrow_b as u64));
        }
    }
    plan
}

/// Total engine KV bytes per cached token under `scheme` — the sum of
/// [`kv_token_plan`]. The acceptance gate checks this equals
/// `KvCache::bytes_per_token()` exactly and that `q8_0` reports a
/// ≥ 3× reduction vs `f32` (Q8_0 packs 32 weights into 34 bytes:
/// 128/34 ≈ 3.76× on block-aligned widths).
pub fn kv_bytes_per_token(cfg: &ModelConfig, scheme: KvScheme, absorb_mla: bool) -> u64 {
    kv_token_plan(cfg, scheme, absorb_mla).iter().map(|(_, b)| b).sum()
}

impl MemoryEstimate {
    pub fn model_gib(&self) -> f64 {
        self.model_bytes as f64 / (1u64 << 30) as f64
    }
    pub fn total_gib(&self) -> f64 {
        self.total_bytes as f64 / (1u64 << 30) as f64
    }
    pub fn per_gpu_gib(&self) -> f64 {
        self.per_gpu_bytes as f64 / (1u64 << 30) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheme::builtin;

    /// The headline Table 1 reproduction: every published cell within
    /// tolerance. Model size is exact arithmetic (±3 G for rounding and
    /// small norm-tensor details); MU uses the calibrated overhead
    /// constants (±6 G).
    #[test]
    fn table1_reproduction() {
        let cfg = ModelConfig::deepseek_v3_671b();
        // (scheme, paper model size G, paper avg bits, paper MU total, paper MU/GPU)
        let rows = [
            ("q4_k_m", 377.0, 4.82, 568.0, 71.0),
            ("q3_k_m", 298.0, 3.81, 487.0, 61.0),
            ("dq3_k_m", 281.0, 3.59, 469.0, 59.0),
            ("q2_k_l", 228.0, 2.91, 415.0, 52.0),
            ("ud_q2_k_xl", 212.0, 2.70, 398.0, 50.0),
        ];
        for (name, size_g, bits, mu_total, mu_gpu) in rows {
            let est = estimate_default(&cfg, &builtin::scheme(name).unwrap());
            assert!(
                (est.model_gib() - size_g).abs() < 3.0,
                "{name} size: computed {:.1} vs paper {size_g}",
                est.model_gib()
            );
            assert!(
                (est.avg_bits - bits).abs() < 0.03,
                "{name} bits: computed {:.3} vs paper {bits}",
                est.avg_bits
            );
            assert!(
                (est.total_gib() - mu_total).abs() < 6.0,
                "{name} MU total: computed {:.1} vs paper {mu_total}",
                est.total_gib()
            );
            assert!(
                (est.per_gpu_gib() - mu_gpu).abs() < 1.5,
                "{name} MU/GPU: computed {:.1} vs paper {mu_gpu}",
                est.per_gpu_gib()
            );
        }
    }

    #[test]
    fn kv_cache_scales_linearly_with_context() {
        let cfg = ModelConfig::deepseek_v3_671b();
        let s = builtin::scheme("q4_k_m").unwrap();
        let a = estimate(&cfg, &s, 4096, 16);
        let b = estimate(&cfg, &s, 8192, 16);
        assert_eq!(b.kv_bytes, 2 * a.kv_bytes);
        assert!(b.total_bytes > a.total_bytes);
    }

    /// Whatever the shard count, the partition must cover each sliced
    /// tensor exactly once: per-tensor bytes summed over shards are
    /// invariant, and the sliced-tensor set itself never changes.
    #[test]
    fn shard_weights_partition_is_conservative() {
        let cfg = ModelConfig::tiny_moe();
        let s = builtin::scheme("dq3_k_m").unwrap();
        let one = shard_weights(&cfg, &s, 1).unwrap();
        assert_eq!(one.len(), 1);
        let totals: std::collections::HashMap<&str, u64> =
            one[0].iter().map(|(n, b)| (n.as_str(), *b)).collect();
        assert!(!totals.is_empty());
        for n in [2usize, 3, 4, 8] {
            let plan = shard_weights(&cfg, &s, n).unwrap();
            assert_eq!(plan.len(), n);
            let mut sums: std::collections::HashMap<&str, u64> = std::collections::HashMap::new();
            for shard in &plan {
                assert_eq!(shard.len(), one[0].len(), "sliced-tensor set drifted at n={n}");
                for (name, bytes) in shard {
                    *sums.entry(name.as_str()).or_default() += bytes;
                }
            }
            assert_eq!(sums, totals, "byte conservation failed at n={n}");
        }
        assert!(shard_weights(&cfg, &s, 0).is_err());
        assert!(shard_weights(&cfg, &s, 65).is_err());
    }

    /// The scheme-aware KV plan: q8_0 must report the promised ≥3×
    /// per-token saving over f32 on every built-in shape (Q8_0 packs
    /// 32×4 f32 bytes into 34), and the per-layer naming must follow
    /// the `blk.{i}.kv_row` / `blk.{i}.kv_expanded` contract the
    /// engine's `measured_token_plan` mirrors (the exact engine-vs-
    /// planner equality is asserted in `tests/quantized_kv.rs`, where
    /// a real cache exists).
    #[test]
    fn kv_token_plan_is_scheme_aware() {
        for (cfg, absorb) in [
            (ModelConfig::tiny_moe(), true),
            (ModelConfig::tiny_dense(), false),
            (ModelConfig::deepseek_v3_671b(), true),
        ] {
            let f32b = kv_bytes_per_token(&cfg, KvScheme::F32, absorb);
            let q8b = kv_bytes_per_token(&cfg, KvScheme::Q8_0, absorb);
            assert!(
                q8b * 3 <= f32b,
                "{:?}: q8_0 {q8b} B/token vs f32 {f32b} — expected ≥3× reduction",
                cfg.name
            );
            let plan = kv_token_plan(&cfg, KvScheme::Q8_0, absorb);
            assert_eq!(plan[0].0, "blk.0.kv_row");
            let expanded = plan.iter().filter(|(n, _)| n.ends_with(".kv_expanded")).count();
            match cfg.kind {
                ModelKind::MlaMoe => assert_eq!(expanded, cfg.n_layers),
                ModelKind::DenseGqa => assert_eq!(expanded, 0),
            }
            assert_eq!(
                kv_token_plan(&cfg, KvScheme::F32, absorb)
                    .iter()
                    .map(|(_, b)| b)
                    .sum::<u64>(),
                f32b
            );
        }
        // Eager (non-absorbed) MLA carries no expanded plane at all.
        let eager = kv_token_plan(&ModelConfig::tiny_moe(), KvScheme::F32, false);
        assert_eq!(eager.len(), ModelConfig::tiny_moe().n_layers);
    }

    #[test]
    fn tiny_model_fits_anywhere() {
        let cfg = ModelConfig::tiny_moe();
        let s = builtin::scheme("dq3_k_m").unwrap();
        let est = estimate(&cfg, &s, 1024, 4);
        assert!(est.model_gib() < 0.01, "tiny model should be <10 MiB");
    }
}
