//! Device database and deployment recommendation (§4.4 of the paper).
//!
//! The 8-device plan recommended here is executable, not just
//! arithmetic: [`crate::runtime::sharded`] runs the same partition as
//! real cooperating shard workers (`dsq serve --native --shards 8`),
//! and [`crate::memory::shard_weights`] predicts each shard's resident
//! weight bytes exactly — the engine's measured bytes are validated
//! against that prediction in `tests/sharded_identity.rs`.

use super::MemoryEstimate;

/// A GPU/NPU device type the paper discusses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Device {
    pub name: &'static str,
    pub vendor: &'static str,
    /// Device memory in GiB.
    pub vram_gib: u32,
}

/// Devices from §1/§4.4: "a typical single machine with 8 GPU/NPU
/// devices (like Nvidia A100/A800/H100/H800/H20 and Huawei Ascend 910B)".
pub const DEVICES: &[Device] = &[
    Device { name: "A100-80G", vendor: "nvidia", vram_gib: 80 },
    Device { name: "A800-80G", vendor: "nvidia", vram_gib: 80 },
    Device { name: "H100-80G", vendor: "nvidia", vram_gib: 80 },
    Device { name: "H800-80G", vendor: "nvidia", vram_gib: 80 },
    Device { name: "H20-96G", vendor: "nvidia", vram_gib: 96 },
    Device { name: "Ascend-910B", vendor: "huawei", vram_gib: 64 },
];

/// Safety margin: a deployment "fits" if per-device MU leaves at least
/// this many GiB free (driver/context headroom not counted in the MU
/// model's per-device constant).
pub const FIT_MARGIN_GIB: f64 = 1.0;

/// Does this memory estimate fit on a node of 8 × `device`?
pub fn fits(est: &MemoryEstimate, device: &Device) -> bool {
    est.per_gpu_gib() + FIT_MARGIN_GIB <= device.vram_gib as f64
}

pub fn by_name(name: &str) -> Option<&'static Device> {
    DEVICES.iter().find(|d| d.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::estimate_default;
    use crate::model::ModelConfig;
    use crate::scheme::builtin;

    /// §4.4's central deployment claim: Q4_K_M fits 80 GB NVIDIA nodes
    /// but exceeds the Ascend 910B (64 GB); DQ3_K_M fits both.
    #[test]
    fn paper_deployment_claims() {
        let cfg = ModelConfig::deepseek_v3_671b();
        let h100 = by_name("H100-80G").unwrap();
        let ascend = by_name("Ascend-910B").unwrap();

        let q4 = estimate_default(&cfg, &builtin::scheme("q4_k_m").unwrap());
        assert!(fits(&q4, h100), "Q4_K_M should fit H100");
        assert!(!fits(&q4, ascend), "Q4_K_M should NOT fit 910B");

        let dq3 = estimate_default(&cfg, &builtin::scheme("dq3_k_m").unwrap());
        assert!(fits(&dq3, h100), "DQ3_K_M should fit H100");
        assert!(fits(&dq3, ascend), "DQ3_K_M should fit 910B");
    }

    #[test]
    fn device_lookup() {
        assert!(by_name("h100-80g").is_some());
        assert!(by_name("tpu-v5").is_none());
    }
}
