//! # dsq — DeepSeek Quantization Analysis System
//!
//! A reproduction of *"Quantitative Analysis of Performance Drop in DeepSeek
//! Model Quantization"* (Unicom Data Intelligence, 2025).
//!
//! The crate provides, bottom-up:
//!
//! - [`quant`] — the llama.cpp k-quant codec family (`q2_k` … `q6_k`,
//!   `q8_0`) implemented from scratch with byte-layout-faithful block
//!   formats and importance-weighted scale search. Every format is a
//!   [`quant::BlockCodec`] behind the [`quant::codec`] registry; the
//!   zero-copy entry points `quantize_into` / `dequantize_into` encode
//!   into caller-provided buffers and split large tensors across
//!   threads at block granularity with byte-identical output (see the
//!   `quant::parallel` module for the threading contract, and
//!   `dsq selfcheck` for the on-host proof).
//! - [`scheme`] — the quantization *recipe* engine: per-module format
//!   rules (Table 7 of the paper) including the paper's contribution,
//!   **DQ3_K_M** dynamic 3-bit allocation. [`scheme::Scheme::plan`]
//!   precomputes the per-tensor [`scheme::FormatPlan`] the container
//!   pipeline consumes.
//! - [`model`] — architecture census for DeepSeek-V3/R1 (671B),
//!   R1-distill-Qwen-32B, and the tiny proxy models used for end-to-end
//!   accuracy evaluation.
//! - [`memory`] — the analytic memory-usage model behind Tables 1 and 6.
//! - [`container`] — the `.dsq` tensor container (mmap-able, 4 KiB
//!   aligned) used to ship both FP32 and quantized checkpoints.
//!   `quantize_container` re-quantizes a checkpoint with all tensors
//!   fanned out across cores (`quantize_container_with` pins the worker
//!   count; `threads == 1` is the streaming scratch-reusing pipeline).
//! - [`runtime`] — PJRT client wrapper that loads AOT-compiled HLO text
//!   artifacts and executes them (Python is never on the request path).
//!   `runtime::loader` prepares weight literals from `.dsq` payloads —
//!   dequantizing at load time when the manifest asks for f32 weights,
//!   fanned out across tensors and blocks with byte-identical results
//!   at any thread count; `runtime::xla` is the offline PJRT stub that
//!   keeps the crate buildable without the native backend. The offline
//!   serving path is `runtime::native` + `runtime::forward`: the full
//!   tiny-MoE transformer forward pass (RMSNorm, MLA attention with
//!   per-slot KV caches, top-k routed experts, unembed) executed
//!   directly on container-encoded weights through the fused `vec_dot`
//!   kernels, bit-identical at every thread count and pinned by the
//!   `tests/golden/forward.*.fnv64` checksums.
//! - [`coordinator`] — the serving layer: request router, continuous
//!   batcher, KV-cache sessions, sampler, metrics.
//! - [`eval`] — the benchmark harness reproducing Tables 2–5: nine proxy
//!   suites, the paper's sampling protocol, weighted aggregation.
//! - [`calib`] — calibration utilities: activation statistics (imatrix)
//!   and super-weight scanning.
//!
//! See `DESIGN.md` for the experiment index mapping every paper table to
//! a harness entry point.

pub mod cli;
pub mod container;
pub mod coordinator;
pub mod eval;
pub mod memory;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod scheme;
pub mod tables;
pub mod util;
