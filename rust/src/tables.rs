//! Regenerators for the paper's resource/recipe tables (1, 6, 7, 8).
//! The accuracy tables (2–5) are rendered by [`crate::eval::report`]
//! from live evaluation results.

use crate::eval::report::render_markdown;
use crate::eval::{suites, EvalResult};
use crate::memory::{self, devices};
use crate::model::{ModelConfig, ModuleClass};
use crate::scheme::builtin;
use crate::util::fmt_gib;
use anyhow::Result;

/// Scheme columns of Table 1 / Table 7, in the paper's order.
pub const TABLE1_SCHEMES: [&str; 5] = ["q4_k_m", "q3_k_m", "dq3_k_m", "q2_k_l", "ud_q2_k_xl"];

/// Paper values for Table 1 (DeepSeek-R1 671B), for side-by-side
/// comparison: (size G, avg bits, MU total GB, MU per GPU GB).
pub const TABLE1_PAPER: [(f64, f64, f64, f64); 5] = [
    (377.0, 4.82, 568.0, 71.0),
    (298.0, 3.81, 487.0, 61.0),
    (281.0, 3.59, 469.0, 59.0),
    (228.0, 2.91, 415.0, 52.0),
    (212.0, 2.70, 398.0, 50.0),
];

/// Table 1: resource consumption of DeepSeek-R1 671B under each scheme.
pub fn table1(with_paper: bool) -> Result<String> {
    let cfg = ModelConfig::by_name("deepseek-r1-671b")?;
    let mut header = vec!["Metric".to_string()];
    for name in TABLE1_SCHEMES {
        header.push(crate::eval::report::display_scheme(name));
    }
    let mut size = vec!["Model Size".to_string()];
    let mut bits = vec!["Avg Quants".to_string()];
    let mut mu_t = vec!["MU (total)".to_string()];
    let mut mu_g = vec!["MU (per GPU)".to_string()];
    for (i, name) in TABLE1_SCHEMES.iter().enumerate() {
        let est = memory::estimate_default(&cfg, &builtin::scheme(name)?);
        let paper = TABLE1_PAPER[i];
        let p = |computed: String, paper_v: f64, unit: &str| {
            if with_paper {
                format!("{computed} (paper {paper_v}{unit})")
            } else {
                computed
            }
        };
        size.push(p(fmt_gib(est.model_bytes), paper.0, "G"));
        bits.push(p(format!("{:.2}", est.avg_bits), paper.1, ""));
        mu_t.push(p(format!("{:.0}GB", est.total_gib()), paper.2, "GB"));
        mu_g.push(p(format!("{:.0}GB", est.per_gpu_gib()), paper.3, "GB"));
    }
    let rows = vec![size, bits, mu_t, mu_g];
    Ok(format!(
        "## Table 1: resource consumption, DeepSeek-R1 671B @ 32K ctx\n\n{}",
        render_markdown(&header, &rows)
    ))
}

/// Table 6: accuracy (from cached eval results, when available) vs
/// memory usage.
pub fn table6(results: &[EvalResult]) -> Result<String> {
    let cfg = ModelConfig::by_name("deepseek-r1-671b")?;
    let mut header = vec!["Metric".to_string()];
    for name in TABLE1_SCHEMES {
        header.push(crate::eval::report::display_scheme(name));
    }
    let lookup = |model: &str, scheme: &str| -> String {
        results
            .iter()
            .find(|r| r.model == model && r.scheme == scheme)
            .map(|r| format!("{:.2}", r.weighted_average()))
            .unwrap_or_else(|| "-".to_string())
    };
    let mut v3 = vec!["Avg. Score (V3 proxy)".to_string()];
    let mut r1 = vec!["Avg. Score (R1 proxy)".to_string()];
    let mut mu_t = vec!["MU (total)".to_string()];
    let mut mu_g = vec!["MU (per GPU)".to_string()];
    let mut fit_h100 = vec!["Fits 8×H100-80G".to_string()];
    let mut fit_910b = vec!["Fits 8×Ascend-910B".to_string()];
    for name in TABLE1_SCHEMES {
        v3.push(lookup("tiny-moe-v3", name));
        r1.push(lookup("tiny-moe-r1", name));
        let est = memory::estimate_default(&cfg, &builtin::scheme(name)?);
        mu_t.push(format!("{:.0}GB", est.total_gib()));
        mu_g.push(format!("{:.0}GB", est.per_gpu_gib()));
        let h100 = devices::by_name("H100-80G").unwrap();
        let asc = devices::by_name("Ascend-910B").unwrap();
        fit_h100.push(if devices::fits(&est, h100) { "yes" } else { "NO" }.to_string());
        fit_910b.push(if devices::fits(&est, asc) { "yes" } else { "NO" }.to_string());
    }
    let rows = vec![v3, r1, mu_t, mu_g, fit_h100, fit_910b];
    Ok(format!(
        "## Table 6: accuracy vs memory trade-off (671B memory model; proxy accuracy)\n\n{}",
        render_markdown(&header, &rows)
    ))
}

/// Table 7: per-module quantization recipes, with parameter-weighted
/// percentages for mixed modules (computed on the 671B census).
pub fn table7() -> Result<String> {
    let cfg = ModelConfig::by_name("deepseek-r1-671b")?;
    let schemes: Vec<_> = TABLE1_SCHEMES
        .iter()
        .map(|n| builtin::scheme(n))
        .collect::<Result<_>>()?;
    let mut header = vec!["Weight-Matrix".to_string()];
    for s in &schemes {
        header.push(s.display.clone());
    }
    // Table 7's row order.
    let row_classes = [
        ModuleClass::Output,
        ModuleClass::TokenEmbd,
        ModuleClass::AttnKvAMqa,
        ModuleClass::AttnKvB,
        ModuleClass::AttnOutput,
        ModuleClass::AttnQA,
        ModuleClass::AttnQB,
        ModuleClass::FfnDown,
        ModuleClass::FfnGate,
        ModuleClass::FfnUp,
        ModuleClass::FfnDownExps,
        ModuleClass::FfnDownShexp,
        ModuleClass::FfnGateExps,
        ModuleClass::FfnGateShexp,
        ModuleClass::FfnUpExps,
        ModuleClass::FfnUpShexp,
    ];
    let mut rows = Vec::new();
    for class in row_classes {
        let mut row = vec![class.name().to_string()];
        for s in &schemes {
            let breakdown = s.breakdown(&cfg);
            let cell = breakdown
                .iter()
                .find(|(c, _)| *c == class)
                .map(|(_, fmts)| {
                    if fmts.len() == 1 {
                        fmts[0].0.name().to_string()
                    } else {
                        fmts.iter()
                            .map(|(f, frac)| format!("{}({:.1}%)", f.name(), frac * 100.0))
                            .collect::<Vec<_>>()
                            .join(" ")
                    }
                })
                .unwrap_or_else(|| "-".to_string());
            row.push(cell);
        }
        rows.push(row);
    }
    Ok(format!(
        "## Table 7: per-module quantization recipes (671B census)\n\n{}",
        render_markdown(&header, &rows)
    ))
}

/// Table 8: benchmark statistics and weights.
pub fn table8(full_size: bool) -> String {
    let header = vec![
        "Benchmark".to_string(),
        "Question Count (paper)".to_string(),
        "Question Count (run)".to_string(),
        "Samples (paper)".to_string(),
        "Weight".to_string(),
        "Proxy family".to_string(),
    ];
    let rows: Vec<Vec<String>> = suites::SUITES
        .iter()
        .map(|s| {
            vec![
                s.name.to_string(),
                s.paper_count.to_string(),
                s.count(full_size).to_string(),
                s.samples.to_string(),
                format!("{}", s.weight),
                format!("{:?}", s.family),
            ]
        })
        .collect();
    format!(
        "## Table 8: benchmark statistics\n\n{}",
        render_markdown(&header, &rows)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_and_contains_values() {
        let t = table1(true).unwrap();
        assert!(t.contains("Model Size"));
        assert!(t.contains("DQ3_K_M (ours)"));
        assert!(t.contains("377")); // computed ≈ paper 377G appears in cell
    }

    #[test]
    fn table7_shows_dynamic_split() {
        let t = table7().unwrap();
        assert!(t.contains("ffn_down_exps"));
        // DQ3's published split: 75.9 / 20.7 / 3.4.
        assert!(t.contains("q3_k(75.9%)"), "{t}");
        assert!(t.contains("q4_k(20.7%)"), "{t}");
        assert!(t.contains("q6_k(3.4%)"), "{t}");
    }

    #[test]
    fn table8_counts() {
        let t = table8(false);
        assert!(t.contains("14042"));
        assert!(t.contains("AIME 2024"));
    }

    #[test]
    fn table6_renders_without_results() {
        let t = table6(&[]).unwrap();
        assert!(t.contains("Fits 8×Ascend-910B"));
        assert!(t.contains("NO")); // Q4_K_M does not fit the 910B
    }
}
