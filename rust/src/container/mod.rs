//! `.dsq` — the checkpoint container format.
//!
//! A single-file tensor container, GGUF-like in spirit but with a JSON
//! header (readable by both the Rust runtime and the Python build
//! pipeline without extra dependencies):
//!
//! ```text
//! [0..4)    magic "DSQ1"
//! [4..8)    u32 LE header length H
//! [8..8+H)  header JSON (UTF-8)
//! ...       zero padding to DATA_ALIGN (4096)
//! [D..)     tensor payloads, each aligned to TENSOR_ALIGN (64)
//! ```
//!
//! Header schema:
//! ```json
//! {
//!   "version": 1,
//!   "model": { ...ModelConfig... },
//!   "scheme": "dq3_k_m",
//!   "meta": {"seed": 42, "train_steps": 600},
//!   "tensors": [
//!     {"name": "blk.0.attn_q_a.weight", "class": "attn_q_a",
//!      "layer": 0, "shape": [256, 256], "format": "q4_k",
//!      "offset": 0, "nbytes": 36864}
//!   ]
//! }
//! ```
//! `offset` is relative to the start of the data section. Written by
//! `dsq quantize` (Rust) and `python/compile/train.py` (f32 checkpoints);
//! both sides are covered by cross-format tests.
//!
//! The [`gguf`] submodule converts between this container and llama.cpp
//! GGUF v3 checkpoints (`dsq import|export`). Tensor names need no
//! mapping: the census already uses GGUF spelling, so the gguf↔census
//! name map is the identity (enforced as exact set equality on import).

pub mod gguf;

use crate::model::{ModelConfig, ModuleClass, TensorInfo};
use crate::quant::QuantFormat;
use crate::util::json::{self, Value};
use anyhow::{anyhow, bail, Context, Result};
use std::io::Write;
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"DSQ1";
pub const DATA_ALIGN: usize = 4096;
pub const TENSOR_ALIGN: usize = 64;

/// Metadata for one stored tensor.
#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub name: String,
    pub class: ModuleClass,
    pub layer: Option<usize>,
    pub shape: Vec<usize>,
    pub format: QuantFormat,
    /// Offset into the data section.
    pub offset: usize,
    pub nbytes: usize,
}

impl TensorEntry {
    pub fn n_elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// An open container (fully resident; checkpoints here are small).
pub struct Container {
    pub model: ModelConfig,
    pub scheme_name: String,
    pub meta: Value,
    pub tensors: Vec<TensorEntry>,
    data: Vec<u8>,
}

impl Container {
    /// Read and validate a `.dsq` file.
    pub fn open(path: &Path) -> Result<Self> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(bytes).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_bytes(bytes: Vec<u8>) -> Result<Self> {
        if bytes.len() < 8 || &bytes[0..4] != MAGIC {
            bail!("not a DSQ1 container");
        }
        let hlen = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        if 8 + hlen > bytes.len() {
            bail!("truncated header");
        }
        let header: Value = json::parse(std::str::from_utf8(&bytes[8..8 + hlen])?)?;
        let version = header.req("version")?.as_u64()?;
        if version != 1 {
            bail!("unsupported container version {version}");
        }
        let model = ModelConfig::from_json(header.req("model")?)?;
        let scheme_name = header.req("scheme")?.as_str()?.to_string();
        let meta = header.get("meta").cloned().unwrap_or(Value::Obj(vec![]));
        let data_start = (8 + hlen).div_ceil(DATA_ALIGN) * DATA_ALIGN;
        let mut tensors = Vec::new();
        for tv in header.req("tensors")?.as_arr()? {
            let name = tv.req("name")?.as_str()?.to_string();
            let class_name = tv.req("class")?.as_str()?;
            let class = ModuleClass::parse(class_name)
                .ok_or_else(|| anyhow!("unknown module class {class_name:?}"))?;
            let layer = match tv.get("layer") {
                Some(Value::Null) | None => None,
                Some(v) => Some(v.as_usize()?),
            };
            let shape: Vec<usize> = tv
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?;
            let format = QuantFormat::parse(tv.req("format")?.as_str()?)?;
            let offset = tv.req("offset")?.as_usize()?;
            let nbytes = tv.req("nbytes")?.as_usize()?;
            // Validate byte count against shape × format.
            let expect = format.row_bytes(shape.iter().product())?;
            if expect != nbytes {
                bail!("tensor {name}: nbytes {nbytes} != expected {expect}");
            }
            if data_start + offset + nbytes > bytes.len() {
                bail!("tensor {name}: payload out of bounds");
            }
            tensors.push(TensorEntry { name, class, layer, shape, format, offset, nbytes });
        }
        let data = bytes[data_start..].to_vec();
        Ok(Container { model, scheme_name, meta, tensors, data })
    }

    /// Raw payload bytes of a tensor entry.
    pub fn bytes(&self, t: &TensorEntry) -> &[u8] {
        &self.data[t.offset..t.offset + t.nbytes]
    }

    /// Find a tensor by name.
    pub fn tensor(&self, name: &str) -> Result<&TensorEntry> {
        self.tensors
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow!("tensor {name:?} not in container"))
    }

    /// Dequantize a tensor to f32.
    pub fn dequantize(&self, t: &TensorEntry) -> Result<Vec<f32>> {
        crate::quant::dequantize(t.format, self.bytes(t), t.n_elems())
    }

    /// Dequantize a tensor into a reusable scratch buffer (resized to
    /// exactly `t.n_elems()`), auto-parallelizing over blocks for large
    /// tensors. The zero-allocation path of the error sweep; the
    /// container quantization pipeline pins its own thread counts
    /// instead of using this.
    pub fn dequantize_into(&self, t: &TensorEntry, out: &mut Vec<f32>) -> Result<()> {
        out.resize(t.n_elems(), 0.0);
        crate::quant::dequantize_into(t.format, self.bytes(t), out)
    }

    /// [`TensorInfo`] view of an entry (what the scheme engine consumes).
    pub fn tensor_info(&self, t: &TensorEntry) -> TensorInfo {
        TensorInfo {
            name: t.name.clone(),
            class: t.class,
            layer: t.layer,
            shape: t.shape.clone(),
        }
    }

    /// Total data-section bytes.
    pub fn data_bytes(&self) -> usize {
        self.data.len()
    }
}

/// Streaming writer.
pub struct Writer {
    model: ModelConfig,
    scheme_name: String,
    meta: Value,
    tensors: Vec<TensorEntry>,
    data: Vec<u8>,
}

impl Writer {
    pub fn new(model: ModelConfig, scheme_name: &str) -> Self {
        Writer {
            model,
            scheme_name: scheme_name.to_string(),
            meta: Value::Obj(vec![]),
            tensors: Vec::new(),
            data: Vec::new(),
        }
    }

    pub fn set_meta(&mut self, meta: Value) {
        self.meta = meta;
    }

    /// Append a tensor payload (already packed in `format`).
    pub fn add_tensor(
        &mut self,
        name: &str,
        class: ModuleClass,
        layer: Option<usize>,
        shape: &[usize],
        format: QuantFormat,
        payload: &[u8],
    ) -> Result<()> {
        let expect = format.row_bytes(shape.iter().product())?;
        if payload.len() != expect {
            bail!("tensor {name}: payload {} != expected {expect}", payload.len());
        }
        if self.tensors.iter().any(|t| t.name == name) {
            bail!("duplicate tensor {name}");
        }
        // Align each payload.
        let aligned = self.data.len().div_ceil(TENSOR_ALIGN) * TENSOR_ALIGN;
        self.data.resize(aligned, 0);
        self.tensors.push(TensorEntry {
            name: name.to_string(),
            class,
            layer,
            shape: shape.to_vec(),
            format,
            offset: aligned,
            nbytes: payload.len(),
        });
        self.data.extend_from_slice(payload);
        Ok(())
    }

    fn header_json(&self) -> Value {
        let tensors: Vec<Value> = self
            .tensors
            .iter()
            .map(|t| {
                json::obj(vec![
                    ("name", json::str_(&t.name)),
                    ("class", json::str_(t.class.name())),
                    (
                        "layer",
                        t.layer.map_or(Value::Null, |l| json::num(l as f64)),
                    ),
                    (
                        "shape",
                        json::arr(t.shape.iter().map(|&d| json::num(d as f64)).collect()),
                    ),
                    ("format", json::str_(t.format.name())),
                    ("offset", json::num(t.offset as f64)),
                    ("nbytes", json::num(t.nbytes as f64)),
                ])
            })
            .collect();
        json::obj(vec![
            ("version", json::num(1.0)),
            ("model", self.model.to_json()),
            ("scheme", json::str_(&self.scheme_name)),
            ("meta", self.meta.clone()),
            ("tensors", json::arr(tensors)),
        ])
    }

    /// Serialize the container to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let header = json::to_string(&self.header_json());
        let hlen = header.len();
        let data_start = (8 + hlen).div_ceil(DATA_ALIGN) * DATA_ALIGN;
        let mut out = Vec::with_capacity(data_start + self.data.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(hlen as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.resize(data_start, 0);
        out.extend_from_slice(&self.data);
        out
    }

    /// Write to a file (atomic: write to `.tmp`, then rename).
    pub fn write(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("dsq.tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&self.to_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }
}

/// Build a deterministic random-weight f32 container for `cfg` — the
/// shared fixture behind `dsq selfcheck`, `benches/codec.rs`, and the
/// parallel-vs-serial property tests (same seed → same bytes).
pub fn synthetic_f32_container(cfg: &ModelConfig, seed: u64) -> Result<Container> {
    let mut w = Writer::new(cfg.clone(), "f32");
    let mut rng = crate::util::rng::Pcg::new(seed);
    for t in cfg.census() {
        let n: usize = t.shape.iter().product();
        let vals: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.05).collect();
        let payload = crate::quant::quantize(QuantFormat::F32, &vals, None)?;
        w.add_tensor(&t.name, t.class, t.layer, &t.shape, QuantFormat::F32, &payload)?;
    }
    Container::from_bytes(w.to_bytes())
}

/// Quantize an f32 container under `scheme`, returning the new container
/// bytes. `importance` optionally maps tensor name → per-element
/// importance (from calibration).
///
/// Tensors are quantized in parallel across all cores; the result is
/// byte-identical to the serial pipeline (each tensor's payload is a
/// pure function of its values, its planned format and its importance,
/// and payloads are assembled in source order either way).
pub fn quantize_container(
    src: &Container,
    scheme: &crate::scheme::Scheme,
    importance: Option<&std::collections::HashMap<String, Vec<f32>>>,
) -> Result<Writer> {
    quantize_container_with(src, scheme, importance, crate::quant::parallel::max_threads())
}

/// [`quantize_container`] with an explicit worker count. `threads == 1`
/// runs the streaming serial pipeline (one reused dequantize scratch and
/// one reused payload buffer, no per-tensor allocation); `threads > 1`
/// fans tensors out over scoped worker threads pulling from a shared
/// work queue, each with its own scratch.
pub fn quantize_container_with(
    src: &Container,
    scheme: &crate::scheme::Scheme,
    importance: Option<&std::collections::HashMap<String, Vec<f32>>>,
    threads: usize,
) -> Result<Writer> {
    for t in &src.tensors {
        if t.format != QuantFormat::F32 {
            bail!("quantize_container expects an f32 source, found {} in {}", t.format, t.name);
        }
    }
    // Precompute the whole format plan up front (rule dispatch is not
    // part of the parallel stage).
    let infos: Vec<TensorInfo> = src.tensors.iter().map(|t| src.tensor_info(t)).collect();
    let plan = scheme.plan(&infos, &src.model);

    let mut w = Writer::new(src.model.clone(), &scheme.name);
    w.set_meta(src.meta.clone());
    let n = src.tensors.len();
    let threads = threads.clamp(1, n.max(1));

    if threads == 1 {
        // Streaming pipeline: dequantize → quantize → append, with both
        // scratch buffers reused across tensors. Inner codec calls pin
        // 1 thread so this baseline is genuinely serial.
        let mut values: Vec<f32> = Vec::new();
        let mut payload: Vec<u8> = Vec::new();
        for (t, &fmt) in src.tensors.iter().zip(&plan.formats) {
            values.resize(t.n_elems(), 0.0);
            crate::quant::dequantize_into_with(t.format, src.bytes(t), &mut values, 1)?;
            let imp = importance.and_then(|m| m.get(&t.name)).map(|v| v.as_slice());
            payload.resize(fmt.row_bytes(values.len())?, 0);
            crate::quant::quantize_into_with(fmt, &values, imp, &mut payload, 1)
                .with_context(|| format!("quantizing tensor {}", t.name))?;
            w.add_tensor(&t.name, t.class, t.layer, &t.shape, fmt, &payload)?;
        }
        return Ok(w);
    }

    // Parallel stage: tensor-level work queue over scoped threads (the
    // shared `quant::parallel::run_queue` helper, also used by the
    // serving weight loader), with a per-worker dequantize scratch.
    let results = crate::quant::parallel::run_queue(
        n,
        threads,
        Vec::new,
        |values: &mut Vec<f32>, i: usize| -> Result<Vec<u8>> {
            let t = &src.tensors[i];
            let fmt = plan.formats[i];
            // Serial inner decode/encode: parallelism lives at the
            // tensor level here — nesting the block splitter would
            // oversubscribe the machine.
            values.resize(t.n_elems(), 0.0);
            crate::quant::dequantize_into_with(t.format, src.bytes(t), values, 1)?;
            let imp = importance.and_then(|m| m.get(&t.name)).map(|v| v.as_slice());
            let mut payload = vec![0u8; fmt.row_bytes(values.len())?];
            crate::quant::quantize_into_with(fmt, values, imp, &mut payload, 1)?;
            Ok(payload)
        },
    );

    // Assemble in source order → identical offsets/bytes to serial.
    for ((t, &fmt), r) in src.tensors.iter().zip(&plan.formats).zip(results) {
        let payload = r.with_context(|| format!("quantizing tensor {}", t.name))?;
        w.add_tensor(&t.name, t.class, t.layer, &t.shape, fmt, &payload)?;
    }
    Ok(w)
}

/// Load a calibration importance-matrix container and validate it
/// against the model container it will steer: an imatrix is itself a
/// `.dsq` file whose tensors hold per-element importance under the
/// **same names** as the model's tensors. Every imatrix tensor must
/// name a tensor of `src` and carry exactly as many elements (one
/// importance weight per model weight) — a mismatched width would
/// silently mis-weight the scale search, so both drifts are rejected
/// here with the offending tensor named, before any quantization work
/// starts (`dsq quantize --imatrix F`).
pub fn load_imatrix(
    path: &Path,
    src: &Container,
) -> Result<std::collections::HashMap<String, Vec<f32>>> {
    let c = Container::open(path)?;
    let mut map = std::collections::HashMap::with_capacity(c.tensors.len());
    for t in &c.tensors {
        let model_t = src.tensor(&t.name).map_err(|_| {
            anyhow!(
                "imatrix {}: tensor {} does not exist in the model checkpoint",
                path.display(),
                t.name
            )
        })?;
        if t.n_elems() != model_t.n_elems() {
            bail!(
                "imatrix {}: tensor {} has {} importance values but the model tensor \
                 has {} weights",
                path.display(),
                t.name,
                t.n_elems(),
                model_t.n_elems()
            );
        }
        map.insert(t.name.clone(), c.dequantize(t)?);
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheme::builtin;
    use crate::util::rng::Pcg;

    fn tiny_f32_container() -> Writer {
        let cfg = ModelConfig::tiny_moe();
        let mut w = Writer::new(cfg.clone(), "f32");
        let mut rng = Pcg::new(7);
        for t in cfg.census() {
            let n: usize = t.shape.iter().product();
            let vals: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.05).collect();
            let payload = crate::quant::quantize(QuantFormat::F32, &vals, None).unwrap();
            w.add_tensor(&t.name, t.class, t.layer, &t.shape, QuantFormat::F32, &payload)
                .unwrap();
        }
        w
    }

    #[test]
    fn roundtrip_f32_container() {
        let w = tiny_f32_container();
        let bytes = w.to_bytes();
        let c = Container::from_bytes(bytes).unwrap();
        assert_eq!(c.model.name, "tiny-moe");
        assert_eq!(c.scheme_name, "f32");
        assert_eq!(c.tensors.len(), ModelConfig::tiny_moe().census().len());
        let t = c.tensor("blk.1.ffn_down_exps.weight").unwrap();
        let vals = c.dequantize(t).unwrap();
        assert_eq!(vals.len(), t.n_elems());
    }

    #[test]
    fn quantize_container_respects_scheme() {
        let src = Container::from_bytes(tiny_f32_container().to_bytes()).unwrap();
        let scheme = builtin::scheme("dq3_k_m").unwrap();
        let q = quantize_container(&src, &scheme, None).unwrap();
        let qc = Container::from_bytes(q.to_bytes()).unwrap();
        assert_eq!(qc.scheme_name, "dq3_k_m");
        // Dynamic rule: first two MoE layers' down_exps are q6_k.
        let cfg = ModelConfig::tiny_moe();
        for t in &qc.tensors {
            if t.class == ModuleClass::FfnDownExps {
                let expect = match t.layer.unwrap() {
                    1 | 2 => QuantFormat::Q6K,
                    5 => QuantFormat::Q4K, // layer 5 % period(5) == 0
                    _ => QuantFormat::Q3K,
                };
                assert_eq!(t.format, expect, "layer {:?}", t.layer);
            }
            if !t.class.quantizable() {
                assert_eq!(t.format, QuantFormat::F32, "{}", t.name);
            }
        }
        // Quantized container must be much smaller than f32.
        assert!(qc.data_bytes() * 4 < src.data_bytes() * 2, "compression missing");
        let _ = cfg;
    }

    #[test]
    fn parallel_quantization_bitwise_identical() {
        // Full-scheme sweep lives in tests/quant_properties.rs; this is
        // the in-module smoke check on the paper's headline scheme.
        let src = Container::from_bytes(tiny_f32_container().to_bytes()).unwrap();
        let scheme = builtin::scheme("dq3_k_m").unwrap();
        let serial = quantize_container_with(&src, &scheme, None, 1).unwrap().to_bytes();
        let par = quantize_container_with(&src, &scheme, None, 8).unwrap().to_bytes();
        assert_eq!(serial, par);
    }

    #[test]
    fn dequantize_into_matches_allocating_path() {
        let c = Container::from_bytes(tiny_f32_container().to_bytes()).unwrap();
        let t = c.tensor("blk.1.ffn_down_exps.weight").unwrap();
        let mut scratch = vec![0f32; 3]; // wrong size on purpose
        c.dequantize_into(t, &mut scratch).unwrap();
        assert_eq!(scratch, c.dequantize(t).unwrap());
    }

    #[test]
    fn corrupted_files_rejected() {
        let mut bytes = tiny_f32_container().to_bytes();
        // Bad magic.
        let mut b2 = bytes.clone();
        b2[0] = b'X';
        assert!(Container::from_bytes(b2).is_err());
        // Truncated payload.
        bytes.truncate(bytes.len() - 100);
        assert!(Container::from_bytes(bytes).is_err());
    }

    #[test]
    fn duplicate_tensor_rejected() {
        let cfg = ModelConfig::tiny_dense();
        let mut w = Writer::new(cfg, "f32");
        let vals = vec![0f32; 256];
        let payload = crate::quant::quantize(QuantFormat::F32, &vals, None).unwrap();
        w.add_tensor("a", ModuleClass::Norm, None, &[256], QuantFormat::F32, &payload)
            .unwrap();
        assert!(w
            .add_tensor("a", ModuleClass::Norm, None, &[256], QuantFormat::F32, &payload)
            .is_err());
    }

    #[test]
    fn payload_size_validated() {
        let cfg = ModelConfig::tiny_dense();
        let mut w = Writer::new(cfg, "f32");
        assert!(w
            .add_tensor("a", ModuleClass::Norm, None, &[256], QuantFormat::F32, &[0u8; 4])
            .is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("dsq-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.dsq");
        tiny_f32_container().write(&path).unwrap();
        let c = Container::open(&path).unwrap();
        assert_eq!(c.model.name, "tiny-moe");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
