//! GGUF v3 interop — import llama.cpp checkpoints into DSQ1, export back.
//!
//! The paper's Table-5 distill rows are measured on *released* quantized
//! checkpoints (`DeepSeek-R1-Distill-Qwen-*-Q4_K_M.gguf`), which ship in
//! llama.cpp's GGUF container. This module reads GGUF v3 — header,
//! metadata KV tree, tensor-info table, alignment-padded payloads —
//! converts the payloads into our block layouts, and assembles a normal
//! [`super::Container`] the native engine can serve. The inverse
//! direction (`dsq export`) writes a `.dsq` container back out as GGUF
//! with bit-exactly inverted payload transcoding, so
//! `import → export` is payload-byte-identical.
//!
//! ## gguf ↔ census name map
//!
//! Our [`crate::model::census`] deliberately uses llama.cpp's tensor
//! names (`token_embd.weight`, `blk.N.attn_q.weight`,
//! `blk.N.ffn_down.weight`, `output_norm.weight`, …), so the name map is
//! the **identity**: an imported file must contain *exactly* the census
//! name set for its reconstructed [`ModelConfig`] — a missing or
//! unexpected name is a named error, never a silent skip. Shapes are
//! cross-checked too: GGUF stores dimensions innermost-first
//! (`ne[0]` = row length), the reverse of our outermost-first census
//! shapes, so `token_embd.weight` is `ne = [hidden, vocab]` in GGUF and
//! `[vocab, hidden]` here.
//!
//! ## Block transcoding
//!
//! Our K-quant *semantics* match llama.cpp bit-for-bit (same scales,
//! same code values), but the in-block bit placement differs: we store
//! code `i` at a dense position (`q4_k` nibble `i&1` of byte `i/2`)
//! while llama.cpp interleaves codes across 32-byte planes for SIMD.
//! Every format pair is therefore a pure bijective bit permutation —
//! `from_llama`/`to_llama` move bits, never re-quantize — which makes
//! imported blocks dequantize **bit-identically** through our decoders
//! and the export exactly invertible. `f32`/`f16`/`q8_0` layouts match
//! llama.cpp byte-for-byte and pass through untouched.
//!
//! ## Scheme + model reconstruction
//!
//! GGUF has no scheme object, so the scheme is *inferred*: the imported
//! per-tensor formats are compared against every builtin scheme's
//! [`crate::scheme::FormatPlan`] for the reconstructed model; an exact
//! match adopts that scheme name (the committed fixture infers
//! `q4_k_m`), otherwise the container is labelled `"imported"`. The
//! [`ModelConfig`] comes from `dsq.model_config` metadata when present
//! (written by `dsq export`, exact round-trip), else is rebuilt from
//! `qwen2.*` keys for `general.architecture = "qwen2"` (the R1-distill
//! family); other architectures are a named error.
//!
//! Conversion fans tensors out over the shared work queue
//! ([`crate::quant::parallel::run_queue`]) and assembles in census
//! order, so the resulting container bytes are identical for any thread
//! count. Like the rest of the toolchain, checkpoints are fully
//! resident (they are small by design); the reader is bounds-checked
//! everywhere and total on adversarial bytes — every failure is a named
//! error, never a panic.

use crate::model::{ModelConfig, ModelKind};
use crate::quant::{QuantFormat, QK_K};
use crate::util::json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::Write as _;
use std::path::Path;

use super::{Container, Writer};

pub const GGUF_MAGIC: &[u8; 4] = b"GGUF";
pub const GGUF_VERSION: u32 = 3;
/// Default payload alignment (`general.alignment`).
pub const GGUF_ALIGN: usize = 32;
/// Sanity cap on header-declared counts/lengths, so adversarial files
/// cannot request absurd allocations before any bounds check fires.
const MAX_COUNT: u64 = 1 << 20;

// ---------------------------------------------------------------------------
// Metadata values
// ---------------------------------------------------------------------------

/// A GGUF metadata value (type ids 0–12 of the v3 spec).
#[derive(Debug, Clone, PartialEq)]
pub enum GgufValue {
    U8(u8),
    I8(i8),
    U16(u16),
    I16(i16),
    U32(u32),
    I32(i32),
    F32(f32),
    Bool(bool),
    Str(String),
    /// Element type id + elements (nested arrays are rejected).
    Arr(u32, Vec<GgufValue>),
    U64(u64),
    I64(i64),
    F64(f64),
}

impl GgufValue {
    fn type_id(&self) -> u32 {
        match self {
            GgufValue::U8(_) => 0,
            GgufValue::I8(_) => 1,
            GgufValue::U16(_) => 2,
            GgufValue::I16(_) => 3,
            GgufValue::U32(_) => 4,
            GgufValue::I32(_) => 5,
            GgufValue::F32(_) => 6,
            GgufValue::Bool(_) => 7,
            GgufValue::Str(_) => 8,
            GgufValue::Arr(..) => 9,
            GgufValue::U64(_) => 10,
            GgufValue::I64(_) => 11,
            GgufValue::F64(_) => 12,
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v: u64 = match *self {
            GgufValue::U8(v) => v as u64,
            GgufValue::U16(v) => v as u64,
            GgufValue::U32(v) => v as u64,
            GgufValue::U64(v) => v,
            GgufValue::I8(v) if v >= 0 => v as u64,
            GgufValue::I16(v) if v >= 0 => v as u64,
            GgufValue::I32(v) if v >= 0 => v as u64,
            GgufValue::I64(v) if v >= 0 => v as u64,
            _ => bail!("expected unsigned integer metadata, got {self:?}"),
        };
        Ok(v as usize)
    }

    pub fn as_f64(&self) -> Result<f64> {
        Ok(match *self {
            GgufValue::F32(v) => v as f64,
            GgufValue::F64(v) => v,
            _ => self.as_usize()? as f64,
        })
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            GgufValue::Str(s) => Ok(s),
            _ => bail!("expected string metadata, got {self:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// ggml type ids ↔ QuantFormat
// ---------------------------------------------------------------------------

/// (ggml type id, our format) for every type we can transcode.
const GGML_TYPES: [(u32, QuantFormat); 8] = [
    (0, QuantFormat::F32),
    (1, QuantFormat::F16),
    (8, QuantFormat::Q8_0),
    (10, QuantFormat::Q2K),
    (11, QuantFormat::Q3K),
    (12, QuantFormat::Q4K),
    (13, QuantFormat::Q5K),
    (14, QuantFormat::Q6K),
];

pub fn format_from_ggml_type(id: u32) -> Result<QuantFormat> {
    GGML_TYPES
        .iter()
        .find(|(g, _)| *g == id)
        .map(|(_, f)| *f)
        .ok_or_else(|| {
            anyhow!(
                "unsupported ggml tensor type {id} (supported: f32=0, f16=1, q8_0=8, \
                 q2_K=10, q3_K=11, q4_K=12, q5_K=13, q6_K=14)"
            )
        })
}

pub fn ggml_type_from_format(f: QuantFormat) -> u32 {
    GGML_TYPES.iter().find(|(_, q)| *q == f).map(|(g, _)| *g).unwrap()
}

// ---------------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------------

/// One entry of the tensor-info table.
#[derive(Debug, Clone)]
pub struct GgufTensor {
    pub name: String,
    /// Outermost-first (census convention; reverse of the stored dims).
    pub shape: Vec<usize>,
    pub format: QuantFormat,
    /// Offset into the data section (multiple of the file alignment).
    pub offset: usize,
    pub nbytes: usize,
}

/// A parsed GGUF file (metadata + tensor table + resident data section).
pub struct Gguf {
    pub kv: Vec<(String, GgufValue)>,
    pub tensors: Vec<GgufTensor>,
    pub alignment: usize,
    data: Vec<u8>,
}

/// Bounds-checked little-endian cursor; every read names what it was
/// reading so truncation errors point at the offending field.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.b.len() - self.pos < n {
            bail!(
                "truncated GGUF: {what} needs {n} bytes, {} left at offset {}",
                self.b.len() - self.pos,
                self.pos
            );
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn string(&mut self, what: &str) -> Result<String> {
        let len = self.u64(what)?;
        if len > MAX_COUNT {
            bail!("implausible GGUF string length {len} for {what}");
        }
        let bytes = self.take(len as usize, what)?;
        Ok(std::str::from_utf8(bytes)
            .with_context(|| format!("{what}: invalid UTF-8"))?
            .to_string())
    }

    fn value(&mut self, type_id: u32, what: &str, in_array: bool) -> Result<GgufValue> {
        Ok(match type_id {
            0 => GgufValue::U8(self.take(1, what)?[0]),
            1 => GgufValue::I8(self.take(1, what)?[0] as i8),
            2 => GgufValue::U16(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap())),
            3 => GgufValue::I16(i16::from_le_bytes(self.take(2, what)?.try_into().unwrap())),
            4 => GgufValue::U32(self.u32(what)?),
            5 => GgufValue::I32(self.u32(what)? as i32),
            6 => GgufValue::F32(f32::from_bits(self.u32(what)?)),
            7 => match self.take(1, what)?[0] {
                0 => GgufValue::Bool(false),
                1 => GgufValue::Bool(true),
                other => bail!("{what}: invalid bool byte {other}"),
            },
            8 => GgufValue::Str(self.string(what)?),
            9 => {
                if in_array {
                    bail!("{what}: nested GGUF arrays are not supported");
                }
                let elem = self.u32(what)?;
                let count = self.u64(what)?;
                // Every element costs at least one byte, so the count can
                // never exceed the bytes left in the file.
                if count > MAX_COUNT || count as usize > self.b.len() - self.pos {
                    bail!("{what}: implausible array length {count}");
                }
                let mut items = Vec::with_capacity(count as usize);
                for _ in 0..count {
                    items.push(self.value(elem, what, true)?);
                }
                GgufValue::Arr(elem, items)
            }
            10 => GgufValue::U64(self.u64(what)?),
            11 => GgufValue::I64(self.u64(what)? as i64),
            12 => GgufValue::F64(f64::from_bits(self.u64(what)?)),
            other => bail!("{what}: unknown GGUF metadata type {other}"),
        })
    }
}

impl Gguf {
    pub fn open(path: &Path) -> Result<Self> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut cur = Cur { b: bytes, pos: 0 };
        let magic = cur.take(4, "magic")?;
        if magic != GGUF_MAGIC {
            bail!("not a GGUF file (magic {magic:02x?})");
        }
        let version = cur.u32("version")?;
        if version != GGUF_VERSION {
            bail!("unsupported GGUF version {version} (only v{GGUF_VERSION})");
        }
        let n_tensors = cur.u64("tensor count")?;
        let n_kv = cur.u64("metadata kv count")?;
        if n_tensors > MAX_COUNT || n_kv > MAX_COUNT {
            bail!("implausible GGUF counts: {n_tensors} tensors, {n_kv} metadata keys");
        }

        let mut kv = Vec::with_capacity(n_kv as usize);
        for _ in 0..n_kv {
            let key = cur.string("metadata key")?;
            let type_id = cur.u32(&format!("metadata type of {key:?}"))?;
            let val = cur.value(type_id, &format!("metadata value of {key:?}"), false)?;
            if kv.iter().any(|(k, _)| *k == key) {
                bail!("duplicate metadata key {key:?}");
            }
            kv.push((key, val));
        }

        let alignment = match kv.iter().find(|(k, _)| k == "general.alignment") {
            None => GGUF_ALIGN,
            Some((_, v)) => {
                let a = v.as_usize().context("general.alignment")?;
                if a == 0 || !a.is_power_of_two() {
                    bail!("general.alignment {a} is not a power of two");
                }
                a
            }
        };

        let mut tensors = Vec::with_capacity(n_tensors as usize);
        for _ in 0..n_tensors {
            let name = cur.string("tensor name")?;
            let what = format!("tensor {name:?}");
            let n_dims = cur.u32(&what)?;
            if n_dims == 0 || n_dims > 4 {
                bail!("{what}: n_dims {n_dims} outside 1..=4");
            }
            let mut dims = Vec::with_capacity(n_dims as usize);
            for _ in 0..n_dims {
                let d = cur.u64(&what)?;
                if d == 0 || d > MAX_COUNT {
                    bail!("{what}: implausible dimension {d}");
                }
                dims.push(d as usize);
            }
            let ggml_type = cur.u32(&what)?;
            let offset = cur.u64(&what)?;
            let format = format_from_ggml_type(ggml_type).context(what.clone())?;
            let n_elems = dims
                .iter()
                .try_fold(1usize, |a, &d| a.checked_mul(d))
                .ok_or_else(|| anyhow!("{what}: element count overflows"))?;
            if dims[0] % format.block_weights() != 0 {
                bail!(
                    "{what}: row length {} not a multiple of the {} block size {}",
                    dims[0],
                    format.name(),
                    format.block_weights()
                );
            }
            let nbytes = format.row_bytes(n_elems).context(what.clone())?;
            if offset % alignment as u64 != 0 {
                bail!("{what}: offset {offset} not aligned to {alignment}");
            }
            if tensors.iter().any(|t: &GgufTensor| t.name == name) {
                bail!("duplicate tensor {name:?}");
            }
            // Census convention is outermost-first; GGUF stores ne[0]
            // (the row length) first.
            let shape: Vec<usize> = dims.iter().rev().copied().collect();
            tensors.push(GgufTensor { name, shape, format, offset: offset as usize, nbytes });
        }

        // Data section starts at the next alignment boundary after the
        // tensor-info table.
        let data_start = cur.pos.div_ceil(alignment) * alignment;
        let data = if data_start <= bytes.len() { bytes[data_start..].to_vec() } else { Vec::new() };

        // Payload bounds + pairwise overlap (offsets are file-author
        // controlled; overlapping spans would alias payload bytes).
        let mut spans: Vec<(usize, usize, &str)> =
            tensors.iter().map(|t| (t.offset, t.offset + t.nbytes, t.name.as_str())).collect();
        spans.sort();
        for (i, &(start, end, name)) in spans.iter().enumerate() {
            if end > data.len() {
                bail!(
                    "tensor {name:?}: payload [{start}, {end}) out of bounds \
                     (data section is {} bytes)",
                    data.len()
                );
            }
            if i + 1 < spans.len() && end > spans[i + 1].0 {
                bail!("tensors {name:?} and {:?} have overlapping payloads", spans[i + 1].2);
            }
        }

        Ok(Gguf { kv, tensors, alignment, data })
    }

    pub fn kv(&self, key: &str) -> Option<&GgufValue> {
        self.kv.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    fn kv_req(&self, key: &str) -> Result<&GgufValue> {
        self.kv(key).ok_or_else(|| anyhow!("missing GGUF metadata key {key:?}"))
    }

    pub fn payload(&self, t: &GgufTensor) -> &[u8] {
        &self.data[t.offset..t.offset + t.nbytes]
    }
}

// ---------------------------------------------------------------------------
// Block transcoding (llama.cpp bit placement ↔ ours)
// ---------------------------------------------------------------------------
//
// Conventions used below, all derived from llama.cpp's
// `dequantize_row_*` loops: a super-block holds QK_K = 256 codes indexed
// by their weight position i. llama.cpp addresses them as
//   q2/q3/q6: i = 128·g + 32·j + l   (g half, j 2-bit plane, l lane)
//   q4/q5:    i =  64·g + r          (g nibble-pair group, r lane)
// while we store code i densely (nibble i&1 of byte i/2, 2-bit i&3 of
// byte i/4, …). Scale *semantics* are identical on both sides; only
// q4/q5's 6-bit scale/min packing needs repacking (llama splits the top
// two bits across the first 8 bytes, we split the top four into the
// last 4 bytes).

/// Unpack llama.cpp's 12-byte `q4_K`/`q5_K` scale block into 8 six-bit
/// scales + 8 six-bit mins (`get_scale_min_k4`).
fn scale_min_from_llama(b: &[u8]) -> ([u8; 8], [u8; 8]) {
    let (mut sc, mut mn) = ([0u8; 8], [0u8; 8]);
    for j in 0..8 {
        if j < 4 {
            sc[j] = b[j] & 63;
            mn[j] = b[j + 4] & 63;
        } else {
            sc[j] = (b[j + 4] & 0x0F) | ((b[j - 4] >> 6) << 4);
            mn[j] = (b[j + 4] >> 4) | ((b[j] >> 6) << 4);
        }
    }
    (sc, mn)
}

/// Inverse of [`scale_min_from_llama`].
fn scale_min_to_llama(sc: &[u8; 8], mn: &[u8; 8], out: &mut [u8]) {
    for j in 0..4 {
        out[j] = (sc[j] & 63) | ((sc[j + 4] >> 4) << 6);
        out[j + 4] = (mn[j] & 63) | ((mn[j + 4] >> 4) << 6);
        out[j + 8] = (sc[j + 4] & 0x0F) | ((mn[j + 4] & 0x0F) << 4);
    }
}

/// `q2_K` (84 B): identical field order (scales[16], qs[64], d, dmin)
/// and identical scale bytes; only the 2-bit code plane permutes.
fn q2k_from_llama(s: &[u8], d: &mut [u8]) {
    d[..16].copy_from_slice(&s[..16]);
    d[80..84].copy_from_slice(&s[80..84]);
    for i in 0..QK_K {
        let (g, j, l) = (i >> 7, (i >> 5) & 3, i & 31);
        let code = (s[16 + 32 * g + l] >> (2 * j)) & 3;
        d[16 + (i >> 2)] |= code << (2 * (i & 3));
    }
}

fn q2k_to_llama(s: &[u8], d: &mut [u8]) {
    d[..16].copy_from_slice(&s[..16]);
    d[80..84].copy_from_slice(&s[80..84]);
    for i in 0..QK_K {
        let code = (s[16 + (i >> 2)] >> (2 * (i & 3))) & 3;
        let (g, j, l) = (i >> 7, (i >> 5) & 3, i & 31);
        d[16 + 32 * g + l] |= code << (2 * j);
    }
}

/// `q3_K` (110 B): llama.cpp orders hmask[32], qs[64], scales[12], d;
/// we order scales, hmask, qs, d. The 12 scale bytes are byte-identical
/// (same 6-bit packing) and the high-bit *sense* matches (set bit ⇒
/// +4 before the −4 recentering on both sides) — only positions move.
fn q3k_from_llama(s: &[u8], d: &mut [u8]) {
    d[..12].copy_from_slice(&s[96..108]);
    d[108..110].copy_from_slice(&s[108..110]);
    for i in 0..QK_K {
        let hbit = (s[i & 31] >> (i >> 5)) & 1;
        d[12 + (i >> 3)] |= hbit << (i & 7);
        let (g, j, l) = (i >> 7, (i >> 5) & 3, i & 31);
        let code = (s[32 + 32 * g + l] >> (2 * j)) & 3;
        d[44 + (i >> 2)] |= code << (2 * (i & 3));
    }
}

fn q3k_to_llama(s: &[u8], d: &mut [u8]) {
    d[96..108].copy_from_slice(&s[..12]);
    d[108..110].copy_from_slice(&s[108..110]);
    for i in 0..QK_K {
        let hbit = (s[12 + (i >> 3)] >> (i & 7)) & 1;
        d[i & 31] |= hbit << (i >> 5);
        let code = (s[44 + (i >> 2)] >> (2 * (i & 3))) & 3;
        let (g, j, l) = (i >> 7, (i >> 5) & 3, i & 31);
        d[32 + 32 * g + l] |= code << (2 * j);
    }
}

/// `q4_K` (144 B): same field order (d, dmin, scales[12], qs[128]);
/// scales repack, nibbles permute.
fn q4k_from_llama(s: &[u8], d: &mut [u8]) {
    d[..4].copy_from_slice(&s[..4]);
    let (sc, mn) = scale_min_from_llama(&s[4..16]);
    crate::quant::q4k::pack_scale_min_6(&sc, &mn, &mut d[4..16]);
    for i in 0..QK_K {
        let (g, r) = (i >> 6, i & 63);
        let b = s[16 + 32 * g + (r & 31)];
        let nib = if r < 32 { b & 0x0F } else { b >> 4 };
        d[16 + (i >> 1)] |= nib << (4 * (i & 1));
    }
}

fn q4k_to_llama(s: &[u8], d: &mut [u8]) {
    d[..4].copy_from_slice(&s[..4]);
    let (mut sc, mut mn) = ([0u8; 8], [0u8; 8]);
    for j in 0..8 {
        let (a, b) = crate::quant::q4k::unpack_scale_min_6(&s[4..16], j);
        sc[j] = a;
        mn[j] = b;
    }
    scale_min_to_llama(&sc, &mn, &mut d[4..16]);
    for i in 0..QK_K {
        let nib = (s[16 + (i >> 1)] >> (4 * (i & 1))) & 0x0F;
        let (g, r) = (i >> 6, i & 63);
        d[16 + 32 * g + (r & 31)] |= if r < 32 { nib } else { nib << 4 };
    }
}

/// `q5_K` (176 B): `q4_K` plus a 32-byte high-bit plane at [16, 48).
fn q5k_from_llama(s: &[u8], d: &mut [u8]) {
    d[..4].copy_from_slice(&s[..4]);
    let (sc, mn) = scale_min_from_llama(&s[4..16]);
    crate::quant::q4k::pack_scale_min_6(&sc, &mn, &mut d[4..16]);
    for i in 0..QK_K {
        let (g, r) = (i >> 6, i & 63);
        let hbit = (s[16 + (r & 31)] >> (2 * g + (r >> 5))) & 1;
        d[16 + (i >> 3)] |= hbit << (i & 7);
        let b = s[48 + 32 * g + (r & 31)];
        let nib = if r < 32 { b & 0x0F } else { b >> 4 };
        d[48 + (i >> 1)] |= nib << (4 * (i & 1));
    }
}

fn q5k_to_llama(s: &[u8], d: &mut [u8]) {
    d[..4].copy_from_slice(&s[..4]);
    let (mut sc, mut mn) = ([0u8; 8], [0u8; 8]);
    for j in 0..8 {
        let (a, b) = crate::quant::q4k::unpack_scale_min_6(&s[4..16], j);
        sc[j] = a;
        mn[j] = b;
    }
    scale_min_to_llama(&sc, &mn, &mut d[4..16]);
    for i in 0..QK_K {
        let (g, r) = (i >> 6, i & 63);
        let hbit = (s[16 + (i >> 3)] >> (i & 7)) & 1;
        d[16 + (r & 31)] |= hbit << (2 * g + (r >> 5));
        let nib = (s[48 + (i >> 1)] >> (4 * (i & 1))) & 0x0F;
        d[48 + 32 * g + (r & 31)] |= if r < 32 { nib } else { nib << 4 };
    }
}

/// `q6_K` (210 B): same field order (ql[128], qh[64], sc[16] i8, d);
/// the 16 int8 scales pass through (same per-16 indexing both sides).
fn q6k_from_llama(s: &[u8], d: &mut [u8]) {
    d[192..210].copy_from_slice(&s[192..210]);
    for i in 0..QK_K {
        let (g, j, l) = (i >> 7, (i >> 5) & 3, i & 31);
        let lo = (s[64 * g + 32 * (j & 1) + l] >> (4 * (j >> 1))) & 0x0F;
        let hi = (s[128 + 32 * g + l] >> (2 * j)) & 3;
        d[i >> 1] |= lo << (4 * (i & 1));
        d[128 + (i >> 2)] |= hi << (2 * (i & 3));
    }
}

fn q6k_to_llama(s: &[u8], d: &mut [u8]) {
    d[192..210].copy_from_slice(&s[192..210]);
    for i in 0..QK_K {
        let lo = (s[i >> 1] >> (4 * (i & 1))) & 0x0F;
        let hi = (s[128 + (i >> 2)] >> (2 * (i & 3))) & 3;
        let (g, j, l) = (i >> 7, (i >> 5) & 3, i & 31);
        d[64 * g + 32 * (j & 1) + l] |= lo << (4 * (j >> 1));
        d[128 + 32 * g + l] |= hi << (2 * j);
    }
}

/// Transcode a whole payload between llama.cpp and native bit placement.
/// `f32`/`f16`/`q8_0` are byte-identical and copy through.
fn transcode_payload(fmt: QuantFormat, src: &[u8], to_llama: bool) -> Vec<u8> {
    let per_block: Option<fn(&[u8], &mut [u8])> = match (fmt, to_llama) {
        (QuantFormat::Q2K, false) => Some(q2k_from_llama),
        (QuantFormat::Q2K, true) => Some(q2k_to_llama),
        (QuantFormat::Q3K, false) => Some(q3k_from_llama),
        (QuantFormat::Q3K, true) => Some(q3k_to_llama),
        (QuantFormat::Q4K, false) => Some(q4k_from_llama),
        (QuantFormat::Q4K, true) => Some(q4k_to_llama),
        (QuantFormat::Q5K, false) => Some(q5k_from_llama),
        (QuantFormat::Q5K, true) => Some(q5k_to_llama),
        (QuantFormat::Q6K, false) => Some(q6k_from_llama),
        (QuantFormat::Q6K, true) => Some(q6k_to_llama),
        _ => None,
    };
    match per_block {
        None => src.to_vec(),
        Some(f) => {
            let bb = fmt.block_bytes();
            let mut out = vec![0u8; src.len()];
            for (s, d) in src.chunks_exact(bb).zip(out.chunks_exact_mut(bb)) {
                f(s, d);
            }
            out
        }
    }
}

// ---------------------------------------------------------------------------
// Import
// ---------------------------------------------------------------------------

/// Rebuild the [`ModelConfig`] from GGUF metadata: exact round-trip via
/// `dsq.model_config` when present, else the `qwen2.*` key family.
fn model_config_from_metadata(g: &Gguf) -> Result<ModelConfig> {
    if let Some(v) = g.kv("dsq.model_config") {
        let parsed = json::parse(v.as_str().context("dsq.model_config")?)
            .context("dsq.model_config is not valid JSON")?;
        return ModelConfig::from_json(&parsed).context("dsq.model_config");
    }
    let arch = g.kv_req("general.architecture")?.as_str()?;
    if arch != "qwen2" {
        bail!(
            "unsupported GGUF architecture {arch:?}: only \"qwen2\" (the R1-distill \
             family) can be reconstructed without dsq.model_config metadata"
        );
    }
    let u = |key: &str| -> Result<usize> { g.kv_req(key)?.as_usize().context(key.to_string()) };
    let hidden_size = u("qwen2.embedding_length")?;
    let n_layers = u("qwen2.block_count")?;
    let n_heads = u("qwen2.attention.head_count")?;
    let n_kv_heads = u("qwen2.attention.head_count_kv")?;
    let intermediate_size = u("qwen2.feed_forward_length")?;
    let head_dim = match g.kv("qwen2.attention.key_length") {
        Some(v) => v.as_usize().context("qwen2.attention.key_length")?,
        None if n_heads > 0 && hidden_size % n_heads == 0 => hidden_size / n_heads,
        None => bail!("cannot derive head_dim: hidden {hidden_size} % heads {n_heads} != 0"),
    };
    let rope_base = match g.kv("qwen2.rope.freq_base") {
        Some(v) => v.as_f64().context("qwen2.rope.freq_base")?,
        None => crate::model::config::DEFAULT_ROPE_BASE,
    };
    // The vocab size is not a metadata key; it is the outermost
    // embedding dimension.
    let embd = g
        .tensors
        .iter()
        .find(|t| t.name == "token_embd.weight")
        .ok_or_else(|| anyhow!("missing tensor \"token_embd.weight\" (needed for vocab size)"))?;
    if embd.shape.len() != 2 {
        bail!("token_embd.weight must be 2-D, got {:?}", embd.shape);
    }
    let name = match g.kv("general.name") {
        Some(v) => v.as_str().context("general.name")?.to_string(),
        None => "imported".to_string(),
    };
    let cfg = ModelConfig {
        name,
        kind: ModelKind::DenseGqa,
        vocab_size: embd.shape[0],
        hidden_size,
        n_layers,
        first_dense: n_layers,
        n_heads,
        n_kv_heads,
        head_dim,
        rope_base,
        q_lora_rank: 0,
        kv_lora_rank: 0,
        qk_nope_head_dim: 0,
        qk_rope_head_dim: 0,
        v_head_dim: 0,
        intermediate_size,
        moe_intermediate_size: 0,
        n_routed_experts: 0,
        n_shared_experts: 0,
        n_active_experts: 0,
    };
    // Round-trip through JSON so an imported config can never be more
    // permissive than one read back from a written container.
    ModelConfig::from_json(&cfg.to_json())
}

/// Name of the builtin scheme whose format plan exactly matches the
/// imported per-tensor formats, else `"imported"`.
fn infer_scheme_name(
    census: &[crate::model::TensorInfo],
    cfg: &ModelConfig,
    formats: &[QuantFormat],
) -> String {
    for scheme in crate::scheme::builtin::all() {
        if scheme.plan(census, cfg).formats == formats {
            return scheme.name;
        }
    }
    "imported".to_string()
}

/// Convert a parsed GGUF into a DSQ1 [`Writer`]. Tensor payloads are
/// transcoded in parallel over `threads` workers and assembled in
/// census order, so the output bytes are thread-count independent.
pub fn import_gguf(g: &Gguf, threads: usize) -> Result<Writer> {
    let cfg = model_config_from_metadata(g)?;
    let census = cfg.census();

    // Identity name map, enforced both ways (see module docs).
    let mut order = Vec::with_capacity(census.len());
    for info in &census {
        let t = g
            .tensors
            .iter()
            .find(|t| t.name == info.name)
            .ok_or_else(|| {
                anyhow!("missing tensor {:?} required by the {} census", info.name, cfg.name)
            })?;
        if t.shape != info.shape {
            bail!(
                "tensor {:?}: GGUF shape {:?} (outermost-first) does not match the \
                 census shape {:?}",
                info.name,
                t.shape,
                info.shape
            );
        }
        order.push(t);
    }
    for t in &g.tensors {
        if !census.iter().any(|info| info.name == t.name) {
            bail!("unexpected tensor {:?} not in the {} census", t.name, cfg.name);
        }
    }

    let formats: Vec<QuantFormat> = order.iter().map(|t| t.format).collect();
    let scheme_name = infer_scheme_name(&census, &cfg, &formats);

    let n = order.len();
    let payloads = crate::quant::parallel::run_queue(
        n,
        threads.clamp(1, n.max(1)),
        || (),
        |_, i| transcode_payload(order[i].format, g.payload(order[i]), false),
    );

    let mut w = Writer::new(cfg, &scheme_name);
    for (info, (t, payload)) in census.iter().zip(order.iter().zip(&payloads)) {
        w.add_tensor(&info.name, info.class, info.layer, &info.shape, t.format, payload)?;
    }
    Ok(w)
}

/// Read a GGUF file and convert it into an open DSQ1 [`Container`].
pub fn import(path: &Path, threads: usize) -> Result<Container> {
    let g = Gguf::open(path)?;
    Container::from_bytes(import_gguf(&g, threads)?.to_bytes())
}

/// Open a checkpoint for serving: sniffs the 4-byte magic and accepts
/// either a native `.dsq` container or a GGUF file (imported on the
/// fly), so `--ckpt model.gguf` works everywhere `--ckpt model.dsq`
/// does.
pub fn open_checkpoint(path: &Path, threads: usize) -> Result<Container> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() >= 4 && &bytes[..4] == GGUF_MAGIC {
        let g = Gguf::from_bytes(&bytes)
            .with_context(|| format!("parsing {}", path.display()))?;
        Container::from_bytes(import_gguf(&g, threads)?.to_bytes())
    } else {
        Container::from_bytes(bytes).with_context(|| format!("parsing {}", path.display()))
    }
}

// ---------------------------------------------------------------------------
// Export
// ---------------------------------------------------------------------------

fn push_string(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u64).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn push_value(out: &mut Vec<u8>, v: &GgufValue) {
    match v {
        GgufValue::U8(x) => out.push(*x),
        GgufValue::I8(x) => out.push(*x as u8),
        GgufValue::U16(x) => out.extend_from_slice(&x.to_le_bytes()),
        GgufValue::I16(x) => out.extend_from_slice(&x.to_le_bytes()),
        GgufValue::U32(x) => out.extend_from_slice(&x.to_le_bytes()),
        GgufValue::I32(x) => out.extend_from_slice(&x.to_le_bytes()),
        GgufValue::F32(x) => out.extend_from_slice(&x.to_bits().to_le_bytes()),
        GgufValue::Bool(x) => out.push(*x as u8),
        GgufValue::Str(s) => push_string(out, s),
        GgufValue::Arr(elem, items) => {
            out.extend_from_slice(&elem.to_le_bytes());
            out.extend_from_slice(&(items.len() as u64).to_le_bytes());
            for item in items {
                push_value(out, item);
            }
        }
        GgufValue::U64(x) => out.extend_from_slice(&x.to_le_bytes()),
        GgufValue::I64(x) => out.extend_from_slice(&x.to_le_bytes()),
        GgufValue::F64(x) => out.extend_from_slice(&x.to_bits().to_le_bytes()),
    }
}

/// Metadata written by `dsq export`: `dsq.model_config` carries the
/// exact config JSON (lossless re-import), and dense models also get
/// the standard `qwen2.*` keys so third-party GGUF tooling recognizes
/// the file.
fn export_metadata(c: &Container) -> Vec<(String, GgufValue)> {
    let cfg = &c.model;
    let arch = match cfg.kind {
        ModelKind::DenseGqa => "qwen2",
        ModelKind::MlaMoe => "deepseek2",
    };
    let mut kv = vec![
        ("general.architecture".to_string(), GgufValue::Str(arch.to_string())),
        ("general.name".to_string(), GgufValue::Str(cfg.name.clone())),
        ("general.alignment".to_string(), GgufValue::U32(GGUF_ALIGN as u32)),
        ("dsq.model_config".to_string(), GgufValue::Str(json::to_string(&cfg.to_json()))),
        ("dsq.scheme".to_string(), GgufValue::Str(c.scheme_name.clone())),
    ];
    if cfg.kind == ModelKind::DenseGqa {
        for (key, val) in [
            ("qwen2.block_count", cfg.n_layers),
            ("qwen2.embedding_length", cfg.hidden_size),
            ("qwen2.feed_forward_length", cfg.intermediate_size),
            ("qwen2.attention.head_count", cfg.n_heads),
            ("qwen2.attention.head_count_kv", cfg.n_kv_heads),
            ("qwen2.attention.key_length", cfg.head_dim),
        ] {
            kv.push((key.to_string(), GgufValue::U32(val as u32)));
        }
        kv.push(("qwen2.rope.freq_base".to_string(), GgufValue::F32(cfg.rope_base as f32)));
    }
    kv
}

/// Serialize a DSQ1 container as a GGUF v3 file (payloads transcoded to
/// llama.cpp bit placement — the exact inverse of [`import_gguf`], so
/// an imported file exports back with byte-identical payloads).
pub fn export_bytes(c: &Container) -> Result<Vec<u8>> {
    let kv = export_metadata(c);
    let mut out = Vec::new();
    out.extend_from_slice(GGUF_MAGIC);
    out.extend_from_slice(&GGUF_VERSION.to_le_bytes());
    out.extend_from_slice(&(c.tensors.len() as u64).to_le_bytes());
    out.extend_from_slice(&(kv.len() as u64).to_le_bytes());
    for (key, val) in &kv {
        push_string(&mut out, key);
        out.extend_from_slice(&val.type_id().to_le_bytes());
        push_value(&mut out, val);
    }

    // Tensor-info table: offsets assigned in container order, each
    // padded to the GGUF alignment.
    let mut offset = 0usize;
    let mut offsets = Vec::with_capacity(c.tensors.len());
    for t in &c.tensors {
        offset = offset.div_ceil(GGUF_ALIGN) * GGUF_ALIGN;
        offsets.push(offset);
        offset += t.nbytes;
    }
    for (t, &off) in c.tensors.iter().zip(&offsets) {
        push_string(&mut out, &t.name);
        out.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in t.shape.iter().rev() {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        out.extend_from_slice(&ggml_type_from_format(t.format).to_le_bytes());
        out.extend_from_slice(&(off as u64).to_le_bytes());
    }

    let data_start = out.len().div_ceil(GGUF_ALIGN) * GGUF_ALIGN;
    out.resize(data_start, 0);
    for (t, &off) in c.tensors.iter().zip(&offsets) {
        out.resize(data_start + off, 0);
        out.extend_from_slice(&transcode_payload(t.format, c.bytes(t), true));
    }
    Ok(out)
}

/// Write a container to `path` as GGUF (atomic: `.tmp` then rename).
pub fn export(c: &Container, path: &Path) -> Result<()> {
    let bytes = export_bytes(c)?;
    let tmp = path.with_extension("gguf.tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    /// Random well-formed native block for `fmt` (valid arbitrary bits:
    /// every bit pattern is a legal K-quant block).
    fn random_block(fmt: QuantFormat, rng: &mut Pcg) -> Vec<u8> {
        (0..fmt.block_bytes()).map(|_| (rng.next_u64() & 0xFF) as u8).collect()
    }

    #[test]
    fn transcode_roundtrips_every_format() {
        let mut rng = Pcg::new(0xD5A1);
        for fmt in [
            QuantFormat::Q2K,
            QuantFormat::Q3K,
            QuantFormat::Q4K,
            QuantFormat::Q5K,
            QuantFormat::Q6K,
            QuantFormat::Q8_0,
            QuantFormat::F16,
            QuantFormat::F32,
        ] {
            for _ in 0..16 {
                let native = random_block(fmt, &mut rng);
                let llama = transcode_payload(fmt, &native, true);
                let back = transcode_payload(fmt, &llama, false);
                assert_eq!(native, back, "{fmt}: native→llama→native not identity");
                let native2 = transcode_payload(fmt, &llama, false);
                let llama2 = transcode_payload(fmt, &native2, true);
                assert_eq!(llama, llama2, "{fmt}: llama→native→llama not identity");
            }
        }
    }

    #[test]
    fn transcoded_quantized_row_dequantizes_identically() {
        // Quantize with our encoder, move the bits to llama placement
        // and back: the dequantized values must be bit-identical, which
        // pins the permutations to real (not just random) blocks.
        let mut rng = Pcg::new(0x5EED);
        let vals: Vec<f32> = (0..QK_K * 2).map(|_| rng.next_normal()).collect();
        for fmt in [
            QuantFormat::Q2K,
            QuantFormat::Q3K,
            QuantFormat::Q4K,
            QuantFormat::Q5K,
            QuantFormat::Q6K,
        ] {
            let packed = crate::quant::quantize(fmt, &vals, None).unwrap();
            let roundtrip =
                transcode_payload(fmt, &transcode_payload(fmt, &packed, true), false);
            assert_eq!(packed, roundtrip, "{fmt}");
            let a = crate::quant::dequantize(fmt, &packed, vals.len()).unwrap();
            let b = crate::quant::dequantize(fmt, &roundtrip, vals.len()).unwrap();
            assert_eq!(a, b, "{fmt}");
        }
    }

    #[test]
    fn metadata_value_roundtrip() {
        let kvs: Vec<(String, GgufValue)> = vec![
            ("a.u8".into(), GgufValue::U8(7)),
            ("a.i32".into(), GgufValue::I32(-5)),
            ("a.f32".into(), GgufValue::F32(1.5)),
            ("a.bool".into(), GgufValue::Bool(true)),
            ("a.str".into(), GgufValue::Str("hello".into())),
            (
                "a.arr".into(),
                GgufValue::Arr(4, vec![GgufValue::U32(1), GgufValue::U32(2)]),
            ),
            ("a.u64".into(), GgufValue::U64(1 << 40)),
            ("a.f64".into(), GgufValue::F64(-0.25)),
        ];
        let mut out = Vec::new();
        out.extend_from_slice(GGUF_MAGIC);
        out.extend_from_slice(&GGUF_VERSION.to_le_bytes());
        out.extend_from_slice(&0u64.to_le_bytes());
        out.extend_from_slice(&(kvs.len() as u64).to_le_bytes());
        for (k, v) in &kvs {
            push_string(&mut out, k);
            out.extend_from_slice(&v.type_id().to_le_bytes());
            push_value(&mut out, v);
        }
        let g = Gguf::from_bytes(&out).unwrap();
        assert_eq!(g.kv, kvs);
        assert_eq!(g.alignment, GGUF_ALIGN);
        assert!(g.tensors.is_empty());
    }

    #[test]
    fn scale_min_repack_is_bijective() {
        let mut rng = Pcg::new(3);
        for _ in 0..64 {
            let mut llama = [0u8; 12];
            // Start from a canonical llama packing of random 6-bit values
            // (raw random 12 bytes are not all reachable packings).
            let mut sc = [0u8; 8];
            let mut mn = [0u8; 8];
            for j in 0..8 {
                sc[j] = (rng.next_u64() & 63) as u8;
                mn[j] = (rng.next_u64() & 63) as u8;
            }
            scale_min_to_llama(&sc, &mn, &mut llama);
            let (sc2, mn2) = scale_min_from_llama(&llama);
            assert_eq!(sc, sc2);
            assert_eq!(mn, mn2);
        }
    }
}
