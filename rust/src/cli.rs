//! Minimal CLI argument parsing (offline replacement for `clap`).
//!
//! Grammar: `dsq <command> [positional...] [--flag value | --switch]`.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

/// Flags that take no value.
const SWITCHES: &[&str] = &[
    "help",
    "full-size",
    "verbose",
    "no-imatrix",
    "json",
    "paper",
    "native",
    "wave",
];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut it = argv.iter().peekable();
        let command = it
            .next()
            .cloned()
            .ok_or_else(|| anyhow!("missing command; try `dsq help`"))?;
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut switches = Vec::new();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if SWITCHES.contains(&name) {
                    switches.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    flags.insert(name.to_string(), v.clone());
                }
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { command, positional, flags, switches })
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("invalid value for --{name}: {e}")),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// Parse the shared `--threads N` flag: `0` or absent means "auto"
    /// (the caller passes its auto value, typically all cores).
    pub fn threads_flag(&self, auto: usize) -> Result<usize> {
        match self.flag_parse("threads", 0usize)? {
            0 => Ok(auto),
            t => Ok(t),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.flag(name)
            .ok_or_else(|| anyhow!("missing required flag --{name}"))
    }

    pub fn positional_at(&self, i: usize) -> Result<&str> {
        self.positional
            .get(i)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing positional argument {i}"))
    }

    pub fn reject_unknown(&self, known_flags: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known_flags.contains(&k.as_str()) {
                bail!("unknown flag --{k} for command {}", self.command);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_flags_positionals() {
        let a = Args::parse(&argv("quantize in.dsq --scheme dq3_k_m --output out.dsq")).unwrap();
        assert_eq!(a.command, "quantize");
        assert_eq!(a.positional, vec!["in.dsq"]);
        assert_eq!(a.flag("scheme"), Some("dq3_k_m"));
        assert_eq!(a.flag("output"), Some("out.dsq"));
    }

    #[test]
    fn switches_take_no_value() {
        let a = Args::parse(&argv("table 1 --paper --model 671b")).unwrap();
        assert!(a.switch("paper"));
        assert_eq!(a.flag("model"), Some("671b"));
        assert_eq!(a.positional, vec!["1"]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv("x --scheme")).is_err());
        assert!(Args::parse(&argv("")).is_err());
    }

    #[test]
    fn flag_parse_types() {
        let a = Args::parse(&argv("memory --ctx 4096")).unwrap();
        assert_eq!(a.flag_parse("ctx", 0usize).unwrap(), 4096);
        assert_eq!(a.flag_parse("nope", 7usize).unwrap(), 7);
    }

    #[test]
    fn threads_flag_zero_and_absent_mean_auto() {
        let a = Args::parse(&argv("serve --threads 3")).unwrap();
        assert_eq!(a.threads_flag(16).unwrap(), 3);
        let a = Args::parse(&argv("serve --threads 0")).unwrap();
        assert_eq!(a.threads_flag(16).unwrap(), 16);
        let a = Args::parse(&argv("serve")).unwrap();
        assert_eq!(a.threads_flag(16).unwrap(), 16);
        let a = Args::parse(&argv("serve --threads nope")).unwrap();
        assert!(a.threads_flag(16).is_err());
    }
}
