//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them.
//!
//! Python is never on this path — the artifacts under `artifacts/hlo/`
//! are compiled once at build time (`make artifacts`); this module
//! loads the HLO **text** (`HloModuleProto::from_text_file`; serialized
//! protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1), compiles
//! it on the PJRT CPU client, marshals `.dsq` container payloads into
//! input literals in the manifest-declared order, and runs
//! prefill/decode steps.
//!
//! Weight marshalling goes through [`loader`]: payloads whose container
//! format matches the manifest pass through zero-copy, while manifests
//! that declare `f32` weights over a quantized checkpoint are decoded
//! at load time — fanned out across tensors *and* across blocks inside
//! a tensor (`Engine::load_with` pins the thread budget; `dsq serve
//! --threads N` / `dsq eval --threads N` plumb it from the CLI). The
//! decode result is byte-identical at every thread count (see
//! `tests/loader_roundtrip.rs` and `dsq selfcheck`).
//!
//! This tree builds against the offline [`xla`] stub (the native
//! `xla_extension` backend is not vendorable here): literals and the
//! whole loader path are real, while `compile`/`execute` report the
//! missing backend gracefully.
//!
//! Since PR 3 the engine also has a **native CPU backend** ([`native`],
//! `Engine::load_native`, `dsq serve|eval --native`); since PR 4 that
//! backend executes a **complete transformer forward pass**
//! ([`forward`]) directly on the container's quantized payloads through
//! the fused `quant::kernels` vec_dot path, and since PR 5 it serves
//! **both architecture families** the paper evaluates: the
//! DeepSeek-shaped MLA+MoE step (tiny-moe, Tables 2–4) and the
//! Qwen2.5-shaped dense-GQA step of the distill models (tiny-dense /
//! distill-qwen-32b, Table 5) — so the coordinator can execute
//! prefill/decode waves offline, no HLO artifacts, no PJRT, with
//! logits bit-identical at every thread count. Since PR 6 native
//! prefill runs each slot's whole prompt as one quantized-GEMM panel
//! ([`forward::ForwardPass::forward_tokens`]), decoding each weight
//! tile once per prompt instead of once per token. Per-wave mutable state
//! (PJRT cache literals or native per-slot KV caches plus the wave's
//! reused forward scratch) is threaded through [`StepOutput::state`]
//! as a backend-tagged [`StepState`], keeping the engine itself
//! immutable between steps.

pub mod forward;
pub mod loader;
pub mod manifest;
pub mod native;
pub mod paged;
pub mod sharded;
pub mod xla;

use crate::container::Container;
use anyhow::{anyhow, bail, Result};
use manifest::{Dtype, Manifest, Role};
use std::path::Path;

/// A (model, scheme) serving engine behind one of two backends:
/// compiled PJRT prefill/decode executables with weight literals from
/// the checkpoint ([`Engine::load`]), or the native CPU backend that
/// executes the full tiny-MoE forward pass directly on the quantized
/// container through the fused `vec_dot` kernels
/// ([`Engine::load_native`] — no HLO artifacts or PJRT backend needed).
pub struct Engine {
    backend: Backend,
    pub model_name: String,
    pub scheme_name: String,
}

enum Backend {
    Pjrt {
        /// Keeps the PJRT client alive for the executables' lifetime.
        _client: std::sync::Arc<xla::PjRtClient>,
        prefill: Phase,
        decode: Phase,
    },
    Native(native::NativeEngine),
}

/// One compiled phase and its manifest.
pub struct Phase {
    pub manifest: Manifest,
    pub exe: xla::PjRtLoadedExecutable,
    /// Weight literals in manifest input order.
    weights: Vec<xla::Literal>,
}

fn elem_ty(d: Dtype) -> xla::ElementType {
    match d {
        Dtype::F32 => xla::ElementType::F32,
        Dtype::U8 => xla::ElementType::U8,
        Dtype::I32 => xla::ElementType::S32,
    }
}

/// Build a literal from raw bytes + manifest spec.
fn literal(dtype: Dtype, shape: &[usize], bytes: &[u8]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(elem_ty(dtype), shape, bytes)
        .map_err(|e| anyhow!("literal creation failed: {e:?}"))
}

fn i32_literal(shape: &[usize], vals: &[i32]) -> Result<xla::Literal> {
    let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    literal(Dtype::I32, shape, &bytes)
}

fn f32_zeros(shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    literal(Dtype::F32, shape, &vec![0u8; n * 4])
}

impl Phase {
    fn load(
        client: &xla::PjRtClient,
        hlo_path: &Path,
        manifest_path: &Path,
        ckpt: &Container,
        threads: usize,
    ) -> Result<Phase> {
        let manifest = Manifest::load(manifest_path)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("loading {}: {e:?}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", hlo_path.display()))?;

        // Validate + decode + marshal the weight payloads (fanned out
        // across tensors and blocks), then build literals in manifest
        // order.
        let payloads = loader::prepare_weights(&manifest, ckpt, threads)?;
        let mut weights = Vec::with_capacity(payloads.len());
        for (spec, payload) in manifest
            .inputs
            .iter()
            .filter(|s| s.role == Role::Weight)
            .zip(payloads.iter())
        {
            weights.push(literal(spec.dtype, &spec.shape, payload.as_slice())?);
        }
        Ok(Phase { manifest, exe, weights })
    }

    /// Execute with the given leading (non-weight) inputs.
    fn run(&self, lead: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let n_lead = self
            .manifest
            .inputs
            .iter()
            .filter(|i| i.role != Role::Weight)
            .count();
        if lead.len() != n_lead {
            bail!(
                "phase {}: expected {n_lead} leading inputs, got {}",
                self.manifest.phase,
                lead.len()
            );
        }
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(lead.len() + self.weights.len());
        inputs.extend(lead.iter());
        inputs.extend(self.weights.iter());
        let result = self
            .exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute failed: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("readback failed: {e:?}"))?;
        out.to_tuple().map_err(|e| anyhow!("untuple failed: {e:?}"))
    }
}

/// Backend-tagged per-wave state threaded from one step into the next:
/// PJRT cache literals for compiled graphs, per-slot KV caches for the
/// native forward pass. The coordinator treats it as opaque.
pub enum StepState {
    Pjrt(Vec<xla::Literal>),
    Native(native::BatchKv),
}

/// Result of a prefill/decode step.
pub struct StepOutput {
    /// Row-major [batch, vocab].
    pub logits: Vec<f32>,
    /// Wave state to thread into the next decode step.
    pub state: StepState,
}

impl Engine {
    /// Load a serving engine with the default weight-loader thread
    /// budget (all cores).
    ///
    /// `hlo_dir` holds `{model}_{scheme}_{phase}.hlo.txt` + manifests
    /// (from `make artifacts`); `ckpt_path` is the quantized container
    /// produced by `dsq quantize` (or the f32 training checkpoint).
    pub fn load(hlo_dir: &Path, ckpt_path: &Path) -> Result<Engine> {
        Self::load_with(hlo_dir, ckpt_path, crate::quant::parallel::max_threads())
    }

    /// [`Engine::load`] with an explicit weight-loader thread count
    /// (`1` forces the serial decode path; the loaded weights are
    /// byte-identical either way).
    pub fn load_with(hlo_dir: &Path, ckpt_path: &Path, threads: usize) -> Result<Engine> {
        let ckpt = Container::open(ckpt_path)?;
        let model_name = ckpt.model.name.clone();
        let scheme_name = ckpt.scheme_name.clone();
        let client = std::sync::Arc::new(
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?,
        );
        let stem = |phase: &str| format!("{model_name}_{scheme_name}_{phase}");
        let prefill = Phase::load(
            &client,
            &hlo_dir.join(format!("{}.hlo.txt", stem("prefill"))),
            &hlo_dir.join(format!("{}.manifest.json", stem("prefill"))),
            &ckpt,
            threads,
        )?;
        let decode = Phase::load(
            &client,
            &hlo_dir.join(format!("{}.hlo.txt", stem("decode"))),
            &hlo_dir.join(format!("{}.manifest.json", stem("decode"))),
            &ckpt,
            threads,
        )?;
        Ok(Engine {
            backend: Backend::Pjrt { _client: client, prefill, decode },
            model_name,
            scheme_name,
        })
    }

    /// Load the native CPU backend from a checkpoint alone — no HLO
    /// artifacts, no PJRT. Steps execute the full tiny-MoE forward pass
    /// on the container's quantized payloads through the fused
    /// `vec_dot` kernels (see [`native`] / [`forward`]); `threads`
    /// bounds the per-matvec row fan-out.
    pub fn load_native(ckpt_path: &Path, threads: usize) -> Result<Engine> {
        Self::native_from_container(Container::open(ckpt_path)?, threads)
    }

    /// [`Engine::load_native`] over an already-open container (taken
    /// over whole — the backend serves from its payloads in place).
    pub fn native_from_container(ckpt: Container, threads: usize) -> Result<Engine> {
        Self::from_native(native::NativeEngine::from_container(ckpt, threads)?)
    }

    /// [`Engine::native_from_container`] partitioned across `shards`
    /// shard worker threads ([`sharded`]; `0` = unsharded local
    /// execution). Logits are bit-identical at every shard count — the
    /// flag trades memory-per-shard and exchange overhead, never
    /// output bits (`dsq serve|eval --native --shards N`).
    pub fn native_from_container_sharded(
        ckpt: Container,
        threads: usize,
        shards: usize,
    ) -> Result<Engine> {
        Self::from_native(native::NativeEngine::with_limits_sharded(
            ckpt,
            threads,
            native::NATIVE_BATCH,
            native::NATIVE_PROMPT_LEN,
            native::NATIVE_MAX_CTX,
            shards,
        )?)
    }

    /// Wrap an already-built native backend (tests and benches use this
    /// with [`native::NativeEngine::with_limits`] to pin small serving
    /// shapes).
    pub fn from_native(native: native::NativeEngine) -> Result<Engine> {
        let model_name = native.forward().config().name.clone();
        let scheme_name = native.forward().scheme_name().to_string();
        Ok(Engine { backend: Backend::Native(native), model_name, scheme_name })
    }

    /// The native backend, when this engine carries one — the
    /// continuous-batching scheduler drives it directly (per-step
    /// admission needs the forward pass, not the wave-shaped step API).
    /// PJRT engines return `None` and keep serving fixed waves.
    pub fn native(&self) -> Option<&native::NativeEngine> {
        match &self.backend {
            Backend::Native(m) => Some(m),
            Backend::Pjrt { .. } => None,
        }
    }

    /// Mutable access to the native backend — `--kv-scheme` configures
    /// the KV-cache storage scheme through this before any cache or
    /// scratch exists ([`native::NativeEngine::set_kv_scheme`]).
    pub fn native_mut(&mut self) -> Option<&mut native::NativeEngine> {
        match &mut self.backend {
            Backend::Native(m) => Some(m),
            Backend::Pjrt { .. } => None,
        }
    }

    pub fn batch(&self) -> usize {
        match &self.backend {
            Backend::Pjrt { prefill, .. } => prefill.manifest.batch,
            Backend::Native(m) => m.batch(),
        }
    }

    pub fn prompt_len(&self) -> usize {
        match &self.backend {
            Backend::Pjrt { prefill, .. } => prefill.manifest.prompt_len,
            Backend::Native(m) => m.prompt_len(),
        }
    }

    pub fn max_ctx(&self) -> usize {
        match &self.backend {
            Backend::Pjrt { prefill, .. } => prefill.manifest.max_ctx,
            Backend::Native(m) => m.max_ctx(),
        }
    }

    pub fn vocab(&self) -> usize {
        match &self.backend {
            Backend::Pjrt { prefill, .. } => prefill.manifest.vocab,
            Backend::Native(m) => m.vocab(),
        }
    }

    /// Run prefill over a padded prompt batch.
    ///
    /// `tokens`: row-major [batch, prompt_len]; `lengths`: [batch] with
    /// values in 1..=prompt_len. A non-positive `lengths[i]` marks an
    /// unused slot: the native backend skips its forward pass entirely
    /// (zero logits row, empty cache); the PJRT backend clamps the
    /// value to 1 so the compiled graph sees its historical input
    /// shape. The native backend runs each used slot's actual prompt
    /// as one GEMM panel pass and fills fresh per-slot KV caches
    /// (returned in [`StepOutput::state`]).
    pub fn run_prefill(&self, tokens: &[i32], lengths: &[i32]) -> Result<StepOutput> {
        let (b, t) = (self.batch(), self.prompt_len());
        if tokens.len() != b * t || lengths.len() != b {
            bail!("prefill input shape mismatch");
        }
        match &self.backend {
            Backend::Pjrt { prefill, .. } => {
                let clamped: Vec<i32> = lengths.iter().map(|&l| l.max(1)).collect();
                let lead = vec![i32_literal(&[b, t], tokens)?, i32_literal(&[b], &clamped)?];
                let mut out = prefill.run(&lead)?;
                let logits = out.remove(0);
                Ok(StepOutput {
                    logits: logits.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
                    state: StepState::Pjrt(out),
                })
            }
            Backend::Native(m) => {
                let (logits, kv) = m.prefill(tokens, lengths)?;
                Ok(StepOutput { logits, state: StepState::Native(kv) })
            }
        }
    }

    /// Run one decode step: `token`/`pos` are [batch]; `state` from the
    /// previous step. A negative `pos[i]` marks an inactive slot
    /// (finished or unused): the native backend skips it entirely
    /// (zero logits row, cache untouched); the PJRT backend clamps the
    /// value to 0 so the compiled graph sees its historical input shape.
    pub fn run_decode(&self, token: &[i32], pos: &[i32], state: StepState) -> Result<StepOutput> {
        let b = self.batch();
        if token.len() != b || pos.len() != b {
            bail!("decode input shape mismatch");
        }
        match (&self.backend, state) {
            (Backend::Pjrt { decode, .. }, StepState::Pjrt(cache)) => {
                let clamped: Vec<i32> = pos.iter().map(|&p| p.max(0)).collect();
                let mut lead = vec![i32_literal(&[b], token)?, i32_literal(&[b], &clamped)?];
                lead.extend(cache);
                let mut out = decode.run(&lead)?;
                let logits = out.remove(0);
                Ok(StepOutput {
                    logits: logits.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
                    state: StepState::Pjrt(out),
                })
            }
            (Backend::Native(m), StepState::Native(mut kv)) => {
                let logits = m.decode(token, pos, &mut kv)?;
                Ok(StepOutput { logits, state: StepState::Native(kv) })
            }
            _ => bail!("step state does not match the engine backend"),
        }
    }

    /// A fresh wave state of the right backend shape (useful for tests).
    pub fn initial_state(&self) -> Result<StepState> {
        match &self.backend {
            Backend::Pjrt { decode, .. } => Ok(StepState::Pjrt(
                decode
                    .manifest
                    .inputs
                    .iter()
                    .filter(|i| matches!(i.role, Role::CacheKv | Role::CacheK | Role::CacheV))
                    .map(|i| f32_zeros(&i.shape))
                    .collect::<Result<Vec<_>>>()?,
            )),
            Backend::Native(m) => Ok(StepState::Native(m.new_batch_kv())),
        }
    }
}
