//! The native transformer forward pass, executed **directly on
//! container-encoded weights** — for both architecture families the
//! paper evaluates.
//!
//! This is the computation `dsq serve|eval --native` runs: a complete
//! decoder step — RMSNorm, attention, FFN, and the final unembedding —
//! where **every matrix–vector product goes through the fused
//! [`crate::quant::vec_dot_rows_with`] kernels on the container's
//! packed payloads**. No weight matrix is ever materialized as a
//! resident f32 table; only the per-layer norm vectors (f32 in every
//! scheme, a few KiB total) are decoded at load time. Two
//! [`crate::model::ModelKind`]s are served:
//!
//! - [`ModelKind::MlaMoe`] — the DeepSeek-V3-shaped step: MLA attention
//!   with a compressed-latent KV cache, top-k routed + shared expert
//!   FFNs (Tables 2–4 shapes, `tiny-moe`).
//! - [`ModelKind::DenseGqa`] — the Qwen2.5-shaped step of the
//!   R1-distill models: grouped-query attention with a conventional
//!   per-head K/V cache and dense SwiGLU FFNs (Table 5 shapes,
//!   `tiny-dense` / `distill-qwen-32b`).
//!
//! ## Layer map
//!
//! Weights are resolved from the container by the GGUF-style names the
//! [`crate::model::ModelConfig::census`] declares, and every shape is
//! validated against the config before serving:
//!
//! ```text
//! ── shared ─────────────────────────────────────────────────────────
//! token_embd.weight                  [vocab, hidden]     one row decoded per token
//! blk.{i}.attn_norm.weight           [hidden]            f32, decoded at load
//! blk.{i}.ffn_norm.weight            [hidden]
//! output_norm.weight                 [hidden]
//! output.weight                      [vocab, hidden]     fused matvec per step
//! ── MlaMoe attention ───────────────────────────────────────────────
//! blk.{i}.attn_q_a.weight            [q_rank, hidden]    fused matvec
//! blk.{i}.attn_q_a_norm.weight       [q_rank]            f32, decoded at load
//! blk.{i}.attn_q_b.weight            [heads·(nope+rope), q_rank]
//! blk.{i}.attn_kv_a_mqa.weight       [kv_rank+rope, hidden]
//! blk.{i}.attn_kv_a_norm.weight      [kv_rank]
//! blk.{i}.attn_kv_b.weight           [heads·(nope+v), kv_rank]
//! blk.{i}.attn_output.weight         [hidden, heads·v]
//! ── MlaMoe FFN ─────────────────────────────────────────────────────
//! dense layers (i < first_dense):    ffn_gate / ffn_up / ffn_down
//! MoE layers:                        ffn_gate_inp (f32 router) +
//!                                    ffn_{gate,up,down}_exps [n_exp, ..] +
//!                                    ffn_{gate,up,down}_shexp
//! ── DenseGqa ───────────────────────────────────────────────────────
//! blk.{i}.attn_q.weight              [heads·head_dim, hidden]
//! blk.{i}.attn_k.weight              [kv_heads·head_dim, hidden]
//! blk.{i}.attn_v.weight              [kv_heads·head_dim, hidden]
//! blk.{i}.attn_output.weight         [hidden, heads·head_dim]
//! every layer:                       ffn_gate / ffn_up / ffn_down
//! ```
//!
//! ## KV caches
//!
//! Per slot and layer the [`KvCache`] row stores exactly the state
//! [`crate::model::ModelConfig::kv_cache_width`] declares (the
//! footprint `kv_bytes_per_token` accounts for both kinds):
//!
//! - **MLA**: the RMS-normed compressed latent plus the shared
//!   post-RoPE rope key (`kv_lora_rank + qk_rope_head_dim` floats);
//!   per-head keys/values are re-expanded from the latents through the
//!   encoded `attn_kv_b` matvec each step.
//! - **GQA**: the conventional per-head state — post-RoPE keys followed
//!   by values (`2 · n_kv_heads · head_dim` floats); query heads share
//!   each KV head in groups of `n_heads / n_kv_heads`.
//!
//! The cache is hard-bounded: a token forwarded at `position ≥ max_ctx`
//! is an error, raised *before* any state changes. The backing buffer
//! is allocated **lazily on the first forwarded token**, so the unused
//! batch slots a wave skips (length 0 at prefill, `pos < 0` at decode)
//! never pay `n_layers × max_ctx × width` floats of idle memory.
//!
//! ## RoPE
//!
//! Rotary frequencies are `θ_i = rope_base^(−2i/d)` with the base taken
//! from [`ModelConfig::rope_base`] (10000 for the DeepSeek shapes,
//! 1000000 for the Qwen-style distill shapes — a hard-coded base would
//! silently compute every dense-model frequency wrong). The table is
//! built from [`crate::util::math::ln_f32`] / [`math::exp_f32`] and the
//! exactly-rounded angle-addition recurrence — no libm, so it is
//! reproducible bit-for-bit anywhere, including the Python mirror.
//!
//! ## Scratch reuse
//!
//! All per-token intermediates live in a caller-owned [`Scratch`]
//! (created once per slot/wave via [`ForwardPass::new_scratch`]), so
//! [`ForwardPass::forward_token`] performs **zero heap allocations per
//! decoded token** — both architectures share the same allocation-free
//! decode loop (asserted by a counting-allocator test in
//! `tests/native_forward.rs` and reported by `benches/codec.rs`).
//!
//! ## Determinism contract
//!
//! Identical to the PR-3 `vec_dot` contract, extended end to end: every
//! dot product — quantized matvecs, attention scores, the RMSNorm sum
//! of squares — reduces in the canonical 8-lane order
//! ([`crate::quant::kernels::dot_lanes`]); every nonlinearity uses the
//! deterministic [`crate::util::math`] kernels; softmaxes, weighted-sum
//! folds and expert combines walk fixed sequential orders. Consequently
//! the logits are **bit-identical** across matvec thread counts and
//! across the `DSQ_SCALAR_DECODE` dispatch arms, and are mirrored
//! bit-exactly by `python/tools/bless_goldens.py` (the committed
//! `rust/tests/golden/forward.*.fnv64` and
//! `forward.tiny_dense.*.fnv64` checksums pin both sides).

use crate::container::{Container, TensorEntry};
use crate::model::{ModelConfig, ModelKind};
use crate::quant::{self, kernels, QuantFormat};
use crate::util::math;
use anyhow::{bail, Context, Result};

/// RMSNorm epsilon (matches the proxy training configuration).
pub const RMS_EPS: f32 = 1e-6;

/// The [`ModelKind`]s this backend serves, spelled out for rejection
/// messages.
pub const SUPPORTED_KINDS: &str =
    "MlaMoe (MLA attention + MoE FFNs), DenseGqa (grouped-query attention + dense FFNs)";

/// How the per-matvec dot products are executed.
#[derive(Debug, Clone, Copy)]
pub enum MatvecMode {
    /// Row-parallel fused matvec over up to N threads, runtime-selected
    /// dispatch arm (the serving default; bit-identical for every N).
    Threads(usize),
    /// Serial matvec with the dispatch arm pinned (`true` = lane
    /// kernels, `false` = scalar reference) — the seam `dsq selfcheck`
    /// and the arm-identity tests use.
    Pinned(bool),
}

/// Per-slot KV cache: `[n_layers][max_ctx][width]` f32, filled front to
/// back; `len` positions are valid in every layer. The row width is
/// [`ModelConfig::kv_cache_width`] (compressed latent + rope key for
/// MLA, per-head K then V for GQA).
///
/// The backing buffer is **lazily allocated** on the first forwarded
/// token: a cache created for a batch slot that never sees a token
/// (skipped at prefill, inactive at decode) costs a few machine words,
/// not `n_layers × max_ctx × width` floats.
pub struct KvCache {
    data: Vec<f32>,
    len: usize,
    width: usize,
    max_ctx: usize,
    n_layers: usize,
}

impl KvCache {
    fn new(n_layers: usize, width: usize, max_ctx: usize) -> Self {
        KvCache { data: Vec::new(), len: 0, width, max_ctx, n_layers }
    }

    /// Tokens cached so far (== the next token's position).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn max_ctx(&self) -> usize {
        self.max_ctx
    }

    /// Whether the backing buffer has been allocated yet (it is, lazily,
    /// by the first forwarded token — the skipped-slot regression tests
    /// assert it stays `false` for slots a wave never touches).
    pub fn is_allocated(&self) -> bool {
        !self.data.is_empty()
    }

    /// Allocate the backing buffer on first use.
    fn ensure_allocated(&mut self) {
        if self.data.is_empty() {
            self.data = vec![0.0; self.n_layers * self.max_ctx * self.width];
        }
    }

    fn row(&self, layer: usize, pos: usize) -> &[f32] {
        let at = (layer * self.max_ctx + pos) * self.width;
        &self.data[at..at + self.width]
    }

    fn row_mut(&mut self, layer: usize, pos: usize) -> &mut [f32] {
        let at = (layer * self.max_ctx + pos) * self.width;
        &mut self.data[at..at + self.width]
    }
}

/// One layer's resolved weights: encoded entries for everything the
/// fused matvec consumes, decoded f32 vectors for the (tiny) norms.
struct LayerWeights {
    attn_norm: Vec<f32>,
    attn: LayerAttn,
    attn_output: TensorEntry,
    ffn_norm: Vec<f32>,
    ffn: LayerFfn,
}

/// The attention projections, by architecture family.
enum LayerAttn {
    /// Multi-head latent attention (DeepSeek-V3 style).
    Mla {
        q_a: TensorEntry,
        q_a_norm: Vec<f32>,
        q_b: TensorEntry,
        kv_a: TensorEntry,
        kv_a_norm: Vec<f32>,
        kv_b: TensorEntry,
    },
    /// Grouped-query attention (Qwen2.5 style, the distill shapes).
    Gqa {
        q: TensorEntry,
        k: TensorEntry,
        v: TensorEntry,
    },
}

enum LayerFfn {
    Dense {
        gate: TensorEntry,
        up: TensorEntry,
        down: TensorEntry,
    },
    Moe {
        router: TensorEntry,
        gate_exps: TensorEntry,
        up_exps: TensorEntry,
        down_exps: TensorEntry,
        gate_shexp: TensorEntry,
        up_shexp: TensorEntry,
        down_shexp: TensorEntry,
    },
}

/// Precomputed rotary table: `cos/sin(pos · θ_i)` for every position
/// below `max_ctx` and every frequency `θ_i = base^(−2i/d)`.
///
/// Built from [`math::ln_f32`] (the base), [`math::exp_f32`]
/// (frequencies), [`math::sin_small`] / [`math::cos_small`] (the
/// ≤ 1-radian per-step angles) and the exactly-rounded angle-addition
/// recurrence — no libm, so the table is reproducible bit-for-bit
/// anywhere (including the Python mirror).
struct RopeTable {
    half: usize,
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl RopeTable {
    /// `base_ln` is `ln(rope_base)` as computed by [`math::ln_f32`].
    fn new(dim: usize, max_ctx: usize, base_ln: f32) -> Self {
        let half = dim / 2;
        let mut cos = vec![0.0f32; max_ctx * half];
        let mut sin = vec![0.0f32; max_ctx * half];
        for i in 0..half {
            let a = (2 * i) as f32 / dim as f32;
            let theta = math::exp_f32(-(a * base_ln));
            let (c1, s1) = (math::cos_small(theta), math::sin_small(theta));
            let (mut c, mut s) = (1.0f32, 0.0f32);
            for p in 0..max_ctx {
                cos[p * half + i] = c;
                sin[p * half + i] = s;
                let (cn, sn) = (c * c1 - s * s1, s * c1 + c * s1);
                c = cn;
                s = sn;
            }
        }
        RopeTable { half, cos, sin }
    }

    /// Rotate consecutive pairs `(x[2i], x[2i+1])` by `pos · θ_i`.
    fn apply(&self, x: &mut [f32], pos: usize) {
        debug_assert_eq!(x.len(), 2 * self.half);
        for i in 0..self.half {
            let c = self.cos[pos * self.half + i];
            let s = self.sin[pos * self.half + i];
            let (a, b) = (x[2 * i], x[2 * i + 1]);
            x[2 * i] = a * c - b * s;
            x[2 * i + 1] = a * s + b * c;
        }
    }
}

/// RMSNorm with the canonical lane-ordered sum of squares:
/// `out[i] = (x[i] · rsqrt(mean(x²) + ε)) · w[i]`.
pub fn rms_norm(x: &[f32], w: &[f32], out: &mut [f32]) {
    debug_assert!(x.len() == w.len() && x.len() == out.len());
    let ss = kernels::dot_lanes(x, x);
    let scale = 1.0 / (ss / x.len() as f32 + RMS_EPS).sqrt();
    for ((o, &xv), &wv) in out.iter_mut().zip(x).zip(w) {
        *o = (xv * scale) * wv;
    }
}

/// Reusable per-slot scratch for [`ForwardPass::forward_token`]: every
/// per-token intermediate, allocated once (sized to the model and
/// `max_ctx`) and fully overwritten each use, so the decode loop itself
/// never touches the heap. Create with [`ForwardPass::new_scratch`].
pub struct Scratch {
    /// Residual stream.
    h: Vec<f32>,
    /// Normed input to attention/FFN (and the final output norm).
    xn: Vec<f32>,
    /// Attention/FFN output before the residual add.
    delta: Vec<f32>,
    attn: AttnScratch,
    ffn: FfnScratch,
}

struct AttnScratch {
    /// Per-head query projections (`heads·(nope+rope)` for MLA,
    /// `heads·head_dim` for GQA), rotated in place.
    q: Vec<f32>,
    /// MLA: pre-norm query latent.
    q_a: Vec<f32>,
    /// MLA: RMS-normed query latent.
    q_an: Vec<f32>,
    /// MLA: joint (latent, rope-key) projection before the cache write.
    kv_a: Vec<f32>,
    /// MLA: per-position re-expanded `k_nope|v` rows, `max_ctx · kvb_w`.
    kvb: Vec<f32>,
    /// Per-head attention outputs before `attn_output`.
    heads_out: Vec<f32>,
    /// Attention scores over the cached context, `max_ctx`.
    scores: Vec<f32>,
}

struct FfnScratch {
    /// SwiGLU gate projection (becomes `silu(g)·u` in place).
    g: Vec<f32>,
    /// SwiGLU up projection.
    u: Vec<f32>,
    /// MoE: one routed expert's output before the weighted combine.
    y: Vec<f32>,
    /// MoE: router probabilities.
    probs: Vec<f32>,
    /// MoE: expert ordering for the top-k selection.
    idx: Vec<usize>,
}

/// The forward-pass model over an opened (quantized or f32) container.
pub struct ForwardPass {
    cfg: ModelConfig,
    ckpt: Container,
    token_embd: TensorEntry,
    embd_row_bytes: usize,
    layers: Vec<LayerWeights>,
    output_norm: Vec<f32>,
    output: TensorEntry,
    rope: RopeTable,
    max_ctx: usize,
    mode: MatvecMode,
}

/// Kind-specific config dims the forward pass depends on must be usable
/// before any tensor is resolved; the rejection names the model and
/// lists what this backend *can* serve.
fn validate_kind(cfg: &ModelConfig) -> Result<()> {
    let reject = |why: &str| -> Result<()> {
        bail!(
            "native forward pass cannot serve container model {:?} (kind {:?}): {why}; \
             supported kinds: {SUPPORTED_KINDS}",
            cfg.name,
            cfg.kind
        )
    };
    if !cfg.rope_base.is_finite() || cfg.rope_base <= 1.0 {
        return reject(&format!("rope_base {} is not a finite base > 1", cfg.rope_base));
    }
    match cfg.kind {
        ModelKind::MlaMoe => {
            if cfg.q_lora_rank == 0 || cfg.kv_lora_rank == 0 {
                return reject("MLA needs q_lora_rank and kv_lora_rank > 0");
            }
            if cfg.qk_rope_head_dim == 0 || cfg.qk_rope_head_dim % 2 != 0 {
                return reject("MLA needs a positive, even qk_rope_head_dim for RoPE pairs");
            }
        }
        ModelKind::DenseGqa => {
            if cfg.head_dim == 0 || cfg.head_dim % 2 != 0 {
                return reject("GQA needs a positive, even head_dim for RoPE pairs");
            }
            if cfg.n_kv_heads == 0 || cfg.n_heads % cfg.n_kv_heads != 0 {
                return reject("GQA needs n_heads divisible by a positive n_kv_heads");
            }
        }
    }
    Ok(())
}

impl ForwardPass {
    /// Resolve and validate the full layer map from `ckpt` (taken over
    /// whole; payloads are served in place). `threads` bounds the
    /// row-parallel matvec fan-out; `max_ctx` bounds every
    /// [`KvCache`] this model creates.
    pub fn new(ckpt: Container, threads: usize, max_ctx: usize) -> Result<Self> {
        let cfg = ckpt.model.clone();
        validate_kind(&cfg)?;
        if max_ctx == 0 {
            bail!("native forward pass needs max_ctx ≥ 1");
        }
        let entry = |name: &str, shape: &[usize]| -> Result<TensorEntry> {
            let t = ckpt.tensor(name).context("native forward layer map")?;
            if t.shape != shape {
                bail!(
                    "model {:?} ({:?}): tensor {name}: shape {:?} does not match the \
                     config's expected {:?}",
                    cfg.name,
                    cfg.kind,
                    t.shape,
                    shape
                );
            }
            // Fused matvecs consume whole rows of blocks.
            t.format
                .row_bytes(*shape.last().unwrap())
                .with_context(|| format!("tensor {name}: rows not block-aligned"))?;
            Ok(t.clone())
        };
        let norm = |name: &str, len: usize| -> Result<Vec<f32>> {
            let t = entry(name, &[len])?;
            ckpt.dequantize(&t)
        };

        let h = cfg.hidden_size;
        let token_embd = entry("token_embd.weight", &[cfg.vocab_size, h])?;
        let embd_row_bytes = token_embd.format.row_bytes(h)?;
        let output = entry("output.weight", &[cfg.vocab_size, h])?;
        let output_norm = norm("output_norm.weight", h)?;

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let blk = |stem: &str| format!("blk.{i}.{stem}.weight");
            let (attn, attn_output) = match cfg.kind {
                ModelKind::MlaMoe => {
                    let qk_head = cfg.qk_head_dim();
                    let attn = LayerAttn::Mla {
                        q_a: entry(&blk("attn_q_a"), &[cfg.q_lora_rank, h])?,
                        q_a_norm: norm(&blk("attn_q_a_norm"), cfg.q_lora_rank)?,
                        q_b: entry(&blk("attn_q_b"), &[cfg.n_heads * qk_head, cfg.q_lora_rank])?,
                        kv_a: entry(&blk("attn_kv_a_mqa"), &[cfg.kv_cache_width(), h])?,
                        kv_a_norm: norm(&blk("attn_kv_a_norm"), cfg.kv_lora_rank)?,
                        kv_b: entry(
                            &blk("attn_kv_b"),
                            &[
                                cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim),
                                cfg.kv_lora_rank,
                            ],
                        )?,
                    };
                    let out = entry(&blk("attn_output"), &[h, cfg.n_heads * cfg.v_head_dim])?;
                    (attn, out)
                }
                ModelKind::DenseGqa => {
                    let kd = cfg.n_kv_heads * cfg.head_dim;
                    let attn = LayerAttn::Gqa {
                        q: entry(&blk("attn_q"), &[cfg.n_heads * cfg.head_dim, h])?,
                        k: entry(&blk("attn_k"), &[kd, h])?,
                        v: entry(&blk("attn_v"), &[kd, h])?,
                    };
                    let out = entry(&blk("attn_output"), &[h, cfg.n_heads * cfg.head_dim])?;
                    (attn, out)
                }
            };
            let ffn = if cfg.is_moe_layer(i) {
                let mi = cfg.moe_intermediate_size;
                let sh = cfg.n_shared_experts * mi;
                LayerFfn::Moe {
                    router: entry(&blk("ffn_gate_inp"), &[cfg.n_routed_experts, h])?,
                    gate_exps: entry(&blk("ffn_gate_exps"), &[cfg.n_routed_experts, mi, h])?,
                    up_exps: entry(&blk("ffn_up_exps"), &[cfg.n_routed_experts, mi, h])?,
                    down_exps: entry(&blk("ffn_down_exps"), &[cfg.n_routed_experts, h, mi])?,
                    gate_shexp: entry(&blk("ffn_gate_shexp"), &[sh, h])?,
                    up_shexp: entry(&blk("ffn_up_shexp"), &[sh, h])?,
                    down_shexp: entry(&blk("ffn_down_shexp"), &[h, sh])?,
                }
            } else {
                LayerFfn::Dense {
                    gate: entry(&blk("ffn_gate"), &[cfg.intermediate_size, h])?,
                    up: entry(&blk("ffn_up"), &[cfg.intermediate_size, h])?,
                    down: entry(&blk("ffn_down"), &[h, cfg.intermediate_size])?,
                }
            };
            layers.push(LayerWeights {
                attn_norm: norm(&blk("attn_norm"), h)?,
                attn,
                attn_output,
                ffn_norm: norm(&blk("ffn_norm"), h)?,
                ffn,
            });
        }
        let rope_dim = match cfg.kind {
            ModelKind::MlaMoe => cfg.qk_rope_head_dim,
            ModelKind::DenseGqa => cfg.head_dim,
        };
        let rope = RopeTable::new(rope_dim, max_ctx, math::ln_f32(cfg.rope_base));
        Ok(ForwardPass {
            cfg,
            ckpt,
            token_embd,
            embd_row_bytes,
            layers,
            output_norm,
            output,
            rope,
            max_ctx,
            mode: MatvecMode::Threads(threads.max(1)),
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Scheme name of the underlying container.
    pub fn scheme_name(&self) -> &str {
        &self.ckpt.scheme_name
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab_size
    }

    pub fn max_ctx(&self) -> usize {
        self.max_ctx
    }

    /// The stored format of the unembedding matrix (what the per-step
    /// vocab-wide fused matvec runs on).
    pub fn output_format(&self) -> QuantFormat {
        self.output.format
    }

    /// Override the matvec execution mode (thread count or pinned
    /// dispatch arm). Logits are bit-identical under every mode — that
    /// is the point of the seam (`dsq selfcheck`, arm-identity tests).
    pub fn set_mode(&mut self, mode: MatvecMode) {
        self.mode = mode;
    }

    /// A fresh, empty per-slot cache bounded by this model's `max_ctx`.
    /// The backing buffer is allocated lazily on the first forwarded
    /// token, so idle batch slots stay (almost) free.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.cfg.n_layers, self.cfg.kv_cache_width(), self.max_ctx)
    }

    /// A scratch sized for this model and context bound. One per slot
    /// (or per serving thread) is enough; [`ForwardPass::forward_token`]
    /// fully overwrites every buffer it reads.
    pub fn new_scratch(&self) -> Scratch {
        let cfg = &self.cfg;
        let (q_len, heads_len, q_rank, kv_a_len, kvb_len) = match cfg.kind {
            ModelKind::MlaMoe => (
                cfg.n_heads * cfg.qk_head_dim(),
                cfg.n_heads * cfg.v_head_dim,
                cfg.q_lora_rank,
                cfg.kv_cache_width(),
                self.max_ctx * cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim),
            ),
            ModelKind::DenseGqa => {
                let hd = cfg.n_heads * cfg.head_dim;
                (hd, hd, 0, 0, 0)
            }
        };
        let inter_max = cfg
            .intermediate_size
            .max(cfg.moe_intermediate_size)
            .max(cfg.n_shared_experts * cfg.moe_intermediate_size);
        Scratch {
            h: vec![0.0; cfg.hidden_size],
            xn: vec![0.0; cfg.hidden_size],
            delta: vec![0.0; cfg.hidden_size],
            attn: AttnScratch {
                q: vec![0.0; q_len],
                q_a: vec![0.0; q_rank],
                q_an: vec![0.0; q_rank],
                kv_a: vec![0.0; kv_a_len],
                kvb: vec![0.0; kvb_len],
                heads_out: vec![0.0; heads_len],
                scores: vec![0.0; self.max_ctx],
            },
            ffn: FfnScratch {
                g: vec![0.0; inter_max],
                u: vec![0.0; inter_max],
                y: vec![0.0; cfg.hidden_size],
                probs: vec![0.0; cfg.n_routed_experts],
                idx: Vec::with_capacity(cfg.n_routed_experts),
            },
        }
    }

    /// Quantized matvec `out[r] = row_r · x` on encoded bytes, under
    /// the active [`MatvecMode`].
    fn matvec_bytes(
        &self,
        fmt: QuantFormat,
        bytes: &[u8],
        x: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        match self.mode {
            MatvecMode::Threads(n) => quant::vec_dot_rows_with(fmt, bytes, x, out, n),
            MatvecMode::Pinned(fast) => {
                let rb = fmt.row_bytes(x.len())?;
                if bytes.len() != rb * out.len() {
                    bail!("pinned matvec: {} bytes != {} rows × {rb}", bytes.len(), out.len());
                }
                for (o, row) in out.iter_mut().zip(bytes.chunks_exact(rb)) {
                    *o = kernels::vec_dot_pinned(fmt, row, x, fast);
                }
                Ok(())
            }
        }
    }

    fn matvec(&self, t: &TensorEntry, x: &[f32], out: &mut [f32]) -> Result<()> {
        self.matvec_bytes(t.format, self.ckpt.bytes(t), x, out)
    }

    /// The encoded rows of expert `e` inside a `[n_exp, out, in]`
    /// expert-stacked tensor.
    fn expert_bytes(&self, t: &TensorEntry, e: usize) -> Result<&[u8]> {
        let per = t.format.row_bytes(t.shape[2])? * t.shape[1];
        Ok(&self.ckpt.bytes(t)[e * per..(e + 1) * per])
    }

    /// Decode one embedding row (`token_embd.weight[t]`) into `h`.
    /// Out-of-range ids wrap into the vocabulary (padding slots send
    /// `PAD`, and sampled ids are always in range).
    fn embed(&self, tok: i32, h: &mut [f32]) -> Result<()> {
        let t = tok.rem_euclid(self.cfg.vocab_size as i32) as usize;
        let bytes = self.ckpt.bytes(&self.token_embd);
        let row = &bytes[t * self.embd_row_bytes..(t + 1) * self.embd_row_bytes];
        quant::dequantize_into(self.token_embd.format, row, h)
    }

    /// `down(silu(gate(x)) · up(x))` with all three projections fused
    /// on encoded rows; `g_buf`/`u_buf` are the scratch projections.
    #[allow(clippy::too_many_arguments)]
    fn mlp(
        &self,
        gate: (QuantFormat, &[u8]),
        up: (QuantFormat, &[u8]),
        down: (QuantFormat, &[u8]),
        inter: usize,
        x: &[f32],
        out: &mut [f32],
        g_buf: &mut [f32],
        u_buf: &mut [f32],
    ) -> Result<()> {
        let g = &mut g_buf[..inter];
        let u = &mut u_buf[..inter];
        self.matvec_bytes(gate.0, gate.1, x, g)?;
        self.matvec_bytes(up.0, up.1, x, u)?;
        for (gv, &uv) in g.iter_mut().zip(&*u) {
            *gv = math::silu(*gv) * uv;
        }
        self.matvec_bytes(down.0, down.1, g, out)
    }

    /// Attention for one layer at `pos` (appends this token's K/V state
    /// to the cache row first), dispatched by architecture family.
    #[allow(clippy::too_many_arguments)]
    fn attention(
        &self,
        li: usize,
        lw: &LayerWeights,
        xn: &[f32],
        cache: &mut KvCache,
        pos: usize,
        out: &mut [f32],
        s: &mut AttnScratch,
    ) -> Result<()> {
        match &lw.attn {
            LayerAttn::Mla { q_a, q_a_norm, q_b, kv_a, kv_a_norm, kv_b } => self.attention_mla(
                li,
                (q_a, q_a_norm.as_slice(), q_b, kv_a, kv_a_norm.as_slice(), kv_b),
                &lw.attn_output,
                xn,
                cache,
                pos,
                out,
                s,
            ),
            LayerAttn::Gqa { q, k, v } => {
                self.attention_gqa(li, (q, k, v), &lw.attn_output, xn, cache, pos, out, s)
            }
        }
    }

    /// MLA attention: compressed-latent cache, per-step re-expansion of
    /// the per-head keys/values through the encoded `kv_b` matvec.
    #[allow(clippy::too_many_arguments, clippy::type_complexity)]
    fn attention_mla(
        &self,
        li: usize,
        (q_a_w, q_a_norm, q_b_w, kv_a_w, kv_a_norm, kv_b_w): (
            &TensorEntry,
            &[f32],
            &TensorEntry,
            &TensorEntry,
            &[f32],
            &TensorEntry,
        ),
        attn_output: &TensorEntry,
        xn: &[f32],
        cache: &mut KvCache,
        pos: usize,
        out: &mut [f32],
        s: &mut AttnScratch,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let (nope, vh) = (cfg.qk_nope_head_dim, cfg.v_head_dim);
        let qk_head = nope + cfg.qk_rope_head_dim;
        let kv_rank = cfg.kv_lora_rank;

        // Query path: hidden → q_lora_rank → heads·(nope+rope).
        let q_a = &mut s.q_a[..cfg.q_lora_rank];
        self.matvec(q_a_w, xn, q_a)?;
        let q_an = &mut s.q_an[..cfg.q_lora_rank];
        rms_norm(q_a, q_a_norm, q_an);
        let q = &mut s.q[..cfg.n_heads * qk_head];
        self.matvec(q_b_w, q_an, q)?;

        // KV path: hidden → (latent, rope key); the cache row stores the
        // RMS-normed latent and the post-RoPE shared key.
        let kv_a = &mut s.kv_a[..cfg.kv_cache_width()];
        self.matvec(kv_a_w, xn, kv_a)?;
        {
            let row = cache.row_mut(li, pos);
            rms_norm(&kv_a[..kv_rank], kv_a_norm, &mut row[..kv_rank]);
            row[kv_rank..].copy_from_slice(&kv_a[kv_rank..]);
            self.rope.apply(&mut row[kv_rank..], pos);
        }

        // Re-expand per-head k_nope/v for every cached position from the
        // compressed latents (the encoded kv_b matvec).
        let ctx = pos + 1;
        let kvb_w = cfg.n_heads * (nope + vh);
        let kvb = &mut s.kvb[..ctx * kvb_w];
        for p in 0..ctx {
            let latent = &cache.row(li, p)[..kv_rank];
            // Split borrow: `kvb` rows are disjoint per position.
            let dst = &mut kvb[p * kvb_w..(p + 1) * kvb_w];
            self.matvec(kv_b_w, latent, dst)?;
        }

        let inv_scale = 1.0 / (qk_head as f32).sqrt();
        let heads_out = &mut s.heads_out[..cfg.n_heads * vh];
        heads_out.fill(0.0);
        let scores = &mut s.scores[..ctx];
        for hd in 0..cfg.n_heads {
            let qh = &mut q[hd * qk_head..(hd + 1) * qk_head];
            self.rope.apply(&mut qh[nope..], pos);
            for (p, sc) in scores.iter_mut().enumerate() {
                let k_nope = &kvb[p * kvb_w + hd * (nope + vh)..][..nope];
                let k_rope = &cache.row(li, p)[kv_rank..];
                let sv = kernels::dot_lanes(&qh[..nope], k_nope)
                    + kernels::dot_lanes(&qh[nope..], k_rope);
                *sc = sv * inv_scale;
            }
            math::softmax_in_place(scores);
            let oh = &mut heads_out[hd * vh..(hd + 1) * vh];
            for (p, &w) in scores.iter().enumerate() {
                let v = &kvb[p * kvb_w + hd * (nope + vh) + nope..][..vh];
                for (o, &vv) in oh.iter_mut().zip(v) {
                    *o += w * vv;
                }
            }
        }
        self.matvec(attn_output, heads_out, out)
    }

    /// Grouped-query attention: conventional per-head K/V cache, query
    /// heads share each KV head in groups of `n_heads / n_kv_heads`.
    /// K and V project **straight into the cache row** (no staging
    /// buffer); RoPE rotates the full head dimension, Qwen2.5 style.
    #[allow(clippy::too_many_arguments)]
    fn attention_gqa(
        &self,
        li: usize,
        (q_w, k_w, v_w): (&TensorEntry, &TensorEntry, &TensorEntry),
        attn_output: &TensorEntry,
        xn: &[f32],
        cache: &mut KvCache,
        pos: usize,
        out: &mut [f32],
        s: &mut AttnScratch,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let hd = cfg.head_dim;
        let kd = cfg.n_kv_heads * hd;
        let group = cfg.n_heads / cfg.n_kv_heads;

        let q = &mut s.q[..cfg.n_heads * hd];
        self.matvec(q_w, xn, q)?;
        {
            let row = cache.row_mut(li, pos);
            let (krow, vrow) = row.split_at_mut(kd);
            self.matvec(k_w, xn, krow)?;
            self.matvec(v_w, xn, vrow)?;
            for kh in 0..cfg.n_kv_heads {
                self.rope.apply(&mut krow[kh * hd..(kh + 1) * hd], pos);
            }
        }

        let ctx = pos + 1;
        let inv_scale = 1.0 / (hd as f32).sqrt();
        let heads_out = &mut s.heads_out[..cfg.n_heads * hd];
        heads_out.fill(0.0);
        let scores = &mut s.scores[..ctx];
        for h in 0..cfg.n_heads {
            let qh = &mut q[h * hd..(h + 1) * hd];
            self.rope.apply(qh, pos);
            let kh = h / group;
            for (p, sc) in scores.iter_mut().enumerate() {
                let k = &cache.row(li, p)[kh * hd..(kh + 1) * hd];
                *sc = kernels::dot_lanes(qh, k) * inv_scale;
            }
            math::softmax_in_place(scores);
            let oh = &mut heads_out[h * hd..(h + 1) * hd];
            for (p, &w) in scores.iter().enumerate() {
                let v = &cache.row(li, p)[kd + kh * hd..][..hd];
                for (o, &vv) in oh.iter_mut().zip(v) {
                    *o += w * vv;
                }
            }
        }
        self.matvec(attn_output, heads_out, out)
    }

    /// FFN for one layer: dense SwiGLU, or router → top-k routed
    /// experts + shared expert. The combine order is fixed (shared
    /// expert first, then selected experts in ascending index), so the
    /// output is a pure function of the inputs.
    fn ffn(
        &self,
        lw: &LayerWeights,
        xn: &[f32],
        out: &mut [f32],
        s: &mut FfnScratch,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let fb = |t: &TensorEntry| (t.format, self.ckpt.bytes(t));
        match &lw.ffn {
            LayerFfn::Dense { gate, up, down } => self.mlp(
                fb(gate),
                fb(up),
                fb(down),
                cfg.intermediate_size,
                xn,
                out,
                &mut s.g,
                &mut s.u,
            ),
            LayerFfn::Moe {
                router,
                gate_exps,
                up_exps,
                down_exps,
                gate_shexp,
                up_shexp,
                down_shexp,
            } => {
                let ne = cfg.n_routed_experts;
                let probs = &mut s.probs[..ne];
                self.matvec(router, xn, probs)?;
                math::softmax_in_place(probs);
                // Top-k selection: highest probability first, ties to
                // the lower expert index; combined in ascending index.
                // (Keys are distinct — probability ties break on the
                // unique index — so the unstable sort is deterministic.)
                s.idx.clear();
                s.idx.extend(0..ne);
                s.idx.sort_unstable_by(|&a, &b| {
                    probs[b].partial_cmp(&probs[a]).expect("softmax is NaN-free").then(a.cmp(&b))
                });
                s.idx.truncate(cfg.n_active_experts);
                s.idx.sort_unstable();
                let mut z = 0f32;
                for &e in &s.idx {
                    z += probs[e];
                }
                // Shared expert contributes with weight 1.
                let sh_inter = cfg.n_shared_experts * cfg.moe_intermediate_size;
                self.mlp(
                    fb(gate_shexp),
                    fb(up_shexp),
                    fb(down_shexp),
                    sh_inter,
                    xn,
                    out,
                    &mut s.g,
                    &mut s.u,
                )?;
                let y = &mut s.y[..cfg.hidden_size];
                for &e in &s.idx {
                    let w = probs[e] / z;
                    self.mlp(
                        (gate_exps.format, self.expert_bytes(gate_exps, e)?),
                        (up_exps.format, self.expert_bytes(up_exps, e)?),
                        (down_exps.format, self.expert_bytes(down_exps, e)?),
                        cfg.moe_intermediate_size,
                        xn,
                        y,
                        &mut s.g,
                        &mut s.u,
                    )?;
                    for (o, &yv) in out.iter_mut().zip(&*y) {
                        *o += w * yv;
                    }
                }
                Ok(())
            }
        }
    }

    /// Run one token through the full stack at the cache's next
    /// position. When `logits` is given it receives the vocab-wide
    /// unembedding of the final hidden state (`logits.len() == vocab`);
    /// prefill steps that only need to advance the cache pass `None`
    /// and skip the vocab matvec.
    ///
    /// All intermediates live in the caller's `scratch`
    /// ([`ForwardPass::new_scratch`]); after the cache's first token has
    /// forced its lazy allocation, this function performs **no heap
    /// allocation** on the success path.
    pub fn forward_token(
        &self,
        tok: i32,
        cache: &mut KvCache,
        scratch: &mut Scratch,
        logits: Option<&mut [f32]>,
    ) -> Result<()> {
        let pos = cache.len;
        if pos >= cache.max_ctx {
            bail!(
                "KV cache full: token at position {pos} exceeds the engine's configured \
                 max context {}",
                cache.max_ctx
            );
        }
        if let Some(out) = &logits {
            if out.len() != self.cfg.vocab_size {
                bail!("logits buffer {} != vocab {}", out.len(), self.cfg.vocab_size);
            }
        }
        cache.ensure_allocated();
        let Scratch { h, xn, delta, attn, ffn } = scratch;
        self.embed(tok, h)?;
        for (li, lw) in self.layers.iter().enumerate() {
            rms_norm(h, &lw.attn_norm, xn);
            self.attention(li, lw, xn, cache, pos, delta, attn)?;
            for (hv, &dv) in h.iter_mut().zip(&*delta) {
                *hv += dv;
            }
            rms_norm(h, &lw.ffn_norm, xn);
            self.ffn(lw, xn, delta, ffn)?;
            for (hv, &dv) in h.iter_mut().zip(&*delta) {
                *hv += dv;
            }
        }
        cache.len = pos + 1;
        if let Some(out) = logits {
            rms_norm(h, &self.output_norm, xn);
            self.matvec(&self.output, xn, out)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{quantize_container_with, synthetic_f32_container};
    use crate::scheme::builtin;

    fn tiny_forward(scheme: &str, threads: usize, max_ctx: usize) -> ForwardPass {
        // One shared quantized container (q4_k_m is the only scheme
        // these in-module tests use; the cross-scheme coverage lives in
        // tests/native_forward.rs).
        static Q4: std::sync::OnceLock<Vec<u8>> = std::sync::OnceLock::new();
        assert_eq!(scheme, "q4_k_m");
        let bytes = Q4.get_or_init(|| {
            let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 0xF052).unwrap();
            quantize_container_with(&src, &builtin::scheme(scheme).unwrap(), None, 1)
                .unwrap()
                .to_bytes()
        });
        ForwardPass::new(Container::from_bytes(bytes.clone()).unwrap(), threads, max_ctx).unwrap()
    }

    #[test]
    fn cache_overflow_is_a_clean_error_before_any_state_change() {
        let fwd = tiny_forward("q4_k_m", 1, 2);
        let mut cache = fwd.new_cache();
        let mut scratch = fwd.new_scratch();
        fwd.forward_token(1, &mut cache, &mut scratch, None).unwrap();
        fwd.forward_token(2, &mut cache, &mut scratch, None).unwrap();
        assert_eq!(cache.len(), 2);
        let err = fwd.forward_token(3, &mut cache, &mut scratch, None).unwrap_err();
        assert!(err.to_string().contains("max context"), "{err}");
        assert_eq!(cache.len(), 2, "failed append must not consume a slot");
    }

    #[test]
    fn dense_gqa_containers_are_served_not_rejected() {
        // Before PR 5 every non-MLA container was bailed on; the dense
        // tiny proxy now resolves a full GQA layer map.
        let src = synthetic_f32_container(&ModelConfig::tiny_dense(), 7).unwrap();
        let fwd = ForwardPass::new(src, 1, 8).unwrap();
        let mut cache = fwd.new_cache();
        let mut scratch = fwd.new_scratch();
        let mut logits = vec![0f32; fwd.vocab()];
        fwd.forward_token(3, &mut cache, &mut scratch, Some(&mut logits)).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(logits.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn unusable_kind_dims_are_rejected_listing_supported_kinds() {
        // A DenseGqa config whose GQA dims are unusable is the
        // genuinely-unsupported case left after PR 5.
        let mut src = synthetic_f32_container(&ModelConfig::tiny_dense(), 7).unwrap();
        src.model.head_dim = 0;
        let err = ForwardPass::new(src, 1, 8).unwrap_err().to_string();
        assert!(err.contains("tiny-dense"), "{err}");
        assert!(err.contains("DenseGqa"), "{err}");
        assert!(err.contains(SUPPORTED_KINDS), "must list supported kinds: {err}");
    }

    #[test]
    fn shape_mismatch_names_the_model_and_kind() {
        // Doctor the config so a resolved tensor no longer matches the
        // expectation derived from it; the error must say which model
        // (and kind) was being validated.
        let mut src = synthetic_f32_container(&ModelConfig::tiny_dense(), 7).unwrap();
        src.model.intermediate_size = 768;
        let err = ForwardPass::new(src, 1, 8).unwrap_err().to_string();
        assert!(err.contains("tiny-dense"), "{err}");
        assert!(err.contains("DenseGqa"), "{err}");
        assert!(err.contains("ffn_gate"), "{err}");
    }

    #[test]
    fn logits_buffer_must_match_vocab() {
        let fwd = tiny_forward("q4_k_m", 1, 4);
        let mut cache = fwd.new_cache();
        let mut scratch = fwd.new_scratch();
        let mut short = vec![0f32; 3];
        assert!(fwd.forward_token(1, &mut cache, &mut scratch, Some(&mut short)).is_err());
    }

    #[test]
    fn kv_cache_allocates_lazily_on_first_token() {
        let fwd = tiny_forward("q4_k_m", 1, 4);
        let mut cache = fwd.new_cache();
        assert!(!cache.is_allocated(), "fresh caches must not allocate");
        let mut scratch = fwd.new_scratch();
        fwd.forward_token(1, &mut cache, &mut scratch, None).unwrap();
        assert!(cache.is_allocated());
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn rope_table_rows_are_unit_rotations() {
        let t = RopeTable::new(32, 24, math::ln_f32(10000.0));
        for p in 0..24 {
            for i in 0..16 {
                let (c, s) = (t.cos[p * 16 + i], t.sin[p * 16 + i]);
                let n = (c as f64).hypot(s as f64);
                assert!((n - 1.0).abs() < 1e-4, "pos {p} freq {i}: |({c},{s})| = {n}");
            }
        }
        // Position 0 is the identity rotation for every frequency.
        assert!(t.cos[..16].iter().all(|&c| c == 1.0));
        assert!(t.sin[..16].iter().all(|&s| s == 0.0));
    }

    #[test]
    fn rope_base_changes_the_frequencies() {
        // The satellite bug this PR fixes: a Qwen-style θ=1000000 model
        // computed with the old hard-coded ln(10000) would get these
        // exact tables instead of its own.
        let a = RopeTable::new(64, 8, math::ln_f32(10000.0));
        let b = RopeTable::new(64, 8, math::ln_f32(1_000_000.0));
        assert_ne!(
            a.cos[32..64],
            b.cos[32..64],
            "different bases must rotate differently from position 1 on"
        );
    }
}
