//! The native transformer forward pass, executed **directly on
//! container-encoded weights** — for both architecture families the
//! paper evaluates.
//!
//! This is the computation `dsq serve|eval --native` runs: a complete
//! decoder step — RMSNorm, attention, FFN, and the final unembedding —
//! where **every matrix product goes through the fused
//! [`crate::quant::vec_dot_rows_with`] /
//! [`crate::quant::vec_dot_rows_mat_with`] kernels on the container's
//! packed payloads** (single-column matvecs at decode, decode-once
//! GEMM panels at prefill). No weight matrix is ever materialized as a
//! resident f32 table; only the per-layer norm vectors (f32 in every
//! scheme, a few KiB total) are decoded at load time. Two
//! [`crate::model::ModelKind`]s are served:
//!
//! - [`ModelKind::MlaMoe`] — the DeepSeek-V3-shaped step: MLA attention
//!   with a compressed-latent KV cache, top-k routed + shared expert
//!   FFNs (Tables 2–4 shapes, `tiny-moe`).
//! - [`ModelKind::DenseGqa`] — the Qwen2.5-shaped step of the
//!   R1-distill models: grouped-query attention with a conventional
//!   per-head K/V cache and dense SwiGLU FFNs (Table 5 shapes,
//!   `tiny-dense` / `distill-qwen-32b`).
//!
//! ## Layer map
//!
//! Weights are resolved from the container by the GGUF-style names the
//! [`crate::model::ModelConfig::census`] declares, and every shape is
//! validated against the config before serving:
//!
//! ```text
//! ── shared ─────────────────────────────────────────────────────────
//! token_embd.weight                  [vocab, hidden]     one row decoded per token
//! blk.{i}.attn_norm.weight           [hidden]            f32, decoded at load
//! blk.{i}.ffn_norm.weight            [hidden]
//! output_norm.weight                 [hidden]
//! output.weight                      [vocab, hidden]     fused matvec per step
//! ── MlaMoe attention ───────────────────────────────────────────────
//! blk.{i}.attn_q_a.weight            [q_rank, hidden]    fused matvec
//! blk.{i}.attn_q_a_norm.weight       [q_rank]            f32, decoded at load
//! blk.{i}.attn_q_b.weight            [heads·(nope+rope), q_rank]
//! blk.{i}.attn_kv_a_mqa.weight       [kv_rank+rope, hidden]
//! blk.{i}.attn_kv_a_norm.weight      [kv_rank]
//! blk.{i}.attn_kv_b.weight           [heads·(nope+v), kv_rank]
//! blk.{i}.attn_output.weight         [hidden, heads·v]
//! ── MlaMoe FFN ─────────────────────────────────────────────────────
//! dense layers (i < first_dense):    ffn_gate / ffn_up / ffn_down
//! MoE layers:                        ffn_gate_inp (f32 router) +
//!                                    ffn_{gate,up,down}_exps [n_exp, ..] +
//!                                    ffn_{gate,up,down}_shexp
//! ── DenseGqa ───────────────────────────────────────────────────────
//! blk.{i}.attn_q.weight              [heads·head_dim, hidden]
//! blk.{i}.attn_k.weight              [kv_heads·head_dim, hidden]
//! blk.{i}.attn_v.weight              [kv_heads·head_dim, hidden]
//! blk.{i}.attn_output.weight         [hidden, heads·head_dim]
//! every layer:                       ffn_gate / ffn_up / ffn_down
//! ```
//!
//! ## KV caches
//!
//! Per slot and layer the [`KvCache`] row stores exactly the state
//! [`crate::model::ModelConfig::kv_cache_width`] declares (the
//! footprint `kv_bytes_per_token` accounts for both kinds):
//!
//! - **MLA**: the RMS-normed compressed latent plus the shared
//!   post-RoPE rope key (`kv_lora_rank + qk_rope_head_dim` floats).
//! - **GQA**: the conventional per-head state — post-RoPE keys followed
//!   by values (`2 · n_kv_heads · head_dim` floats); query heads share
//!   each KV head in groups of `n_heads / n_kv_heads`.
//!
//! The cache is hard-bounded: a token forwarded at `position ≥ max_ctx`
//! is an error, raised *before* any state changes. The backing buffer
//! is allocated **lazily on the first forwarded token**, so the unused
//! batch slots a wave skips (length 0 at prefill, `pos < 0` at decode)
//! never pay `n_layers × max_ctx × width` floats of idle memory.
//!
//! ## Quantized KV (PR 10)
//!
//! [`ForwardPass::set_kv_scheme`] swaps the f32 planes for per-row
//! codec blocks ([`crate::quant::KvScheme`], `q8_0` first). The
//! determinism argument is **quantize-on-write, fused-read**:
//!
//! - *Write once*: each appended position's row is built **exactly** in
//!   a preallocated f32 staging line (projections, RMSNorm, RoPE — the
//!   same arithmetic as the f32 path, on the same inputs), then encoded
//!   once into whole codec blocks via
//!   [`crate::quant::encode_kv_line`]. The absorbed-MLA expansion runs
//!   on the *staged exact latent* before encoding, so quantization
//!   error enters each plane exactly once, never compounds, and the
//!   encoded bytes are a pure function of the activations — identical
//!   whichever path (token loop, panel prefill, batched decode) appends
//!   the row, and wherever the row lives (dense or paged).
//! - *Fused read*: attention scores run [`kernels::vec_dot_arm`]
//!   directly on each row's encoded blocks (head segments sit on the
//!   block grid — validated at scheme-set time), which is bit-identical
//!   to decode-then-[`kernels::dot_lanes`] on every dispatch arm by the
//!   PR-3 `vec_dot` contract; value rows decode into preallocated
//!   scratch and fold in the same sequential order as f32.
//!
//! Hence quantized-KV logits inherit the full identity matrix below —
//! threads × shards × arms × backings × batched-vs-solo — and `f32`
//! (the default) remains byte-identical to every pre-PR-10 golden.
//!
//! ## Absorbed MLA (PR 6)
//!
//! With absorption enabled (the default), the cache additionally keeps
//! an **expanded-row plane**: the per-head `k_nope|v` rows that the
//! encoded `attn_kv_b` matvec produces from each position's latent,
//! computed **once when that position is appended** and read back by
//! every later attention step. Decode therefore runs one `kv_b` matvec
//! per step instead of re-expanding all `context` cached positions —
//! the O(ctx) per-step re-expansion loop is gone. The trade is cache
//! memory (`n_layers · max_ctx · n_heads · (nope + v)` extra floats);
//! [`ForwardPass::set_mla_absorption`]`(false)` restores the
//! memory-lean latent-only cache with eager re-expansion (the seam the
//! equivalence tests pin against the goldens).
//!
//! Why not fold `kv_b` into the query/output projections algebraically
//! (the textbook "absorbed MLA")? That rewrite reassociates the float
//! sums — `(q·Wᵀ)·c` vs `q·(Wᵀ·c)` — and therefore cannot preserve
//! the bit-exact determinism contract the golden fixtures pin. Caching
//! the expansion instead runs the *same* matvec on the *same* inputs,
//! just once per position instead of once per position per step, so
//! the logits stay bit-identical while the per-step cost drops the
//! same O(ctx) factor.
//!
//! ## Panel prefill (PR 6)
//!
//! [`ForwardPass::forward_tokens`] runs a whole prompt in one pass:
//! every projection and FFN matvec is batched across the prompt's
//! token dimension through the decode-once GEMM kernels
//! ([`crate::quant::vec_dot_rows_mat_with`] /
//! [`kernels::vec_dot_mat_arm`]), so each quantized weight tile is
//! decoded **once per prompt** instead of once per token. RMSNorm,
//! RoPE, attention scores/softmax and expert routing stay
//! per-position; MoE layers gather the tokens routed to each expert
//! and run one expert GEMM over them (ascending expert order, which is
//! exactly each token's own combine order). Layer `l` processes every
//! token before layer `l + 1`, but attention for token `j` only reads
//! cache rows already written from bit-identical activations, so the
//! cache and logits match the token loop bit-for-bit (asserted by
//! `tests/native_forward.rs` and `dsq selfcheck`).
//!
//! ## RoPE
//!
//! Rotary frequencies are `θ_i = rope_base^(−2i/d)` with the base taken
//! from [`ModelConfig::rope_base`] (10000 for the DeepSeek shapes,
//! 1000000 for the Qwen-style distill shapes — a hard-coded base would
//! silently compute every dense-model frequency wrong). The table is
//! built from [`crate::util::math::ln_f32`] / [`math::exp_f32`] and the
//! exactly-rounded angle-addition recurrence — no libm, so it is
//! reproducible bit-for-bit anywhere, including the Python mirror.
//!
//! Pairing is **half-split** (NeoX style): frequency `i` rotates
//! `(x[i], x[i+half])`, matching `python/compile/model.py::rope` and
//! the convention HF/llama.cpp Qwen checkpoints (the R1 distills) are
//! trained with. An earlier revision rotated interleaved pairs
//! `(x[2i], x[2i+1])` — self-consistent on synthetic weights but wrong
//! for every externally-trained checkpoint, which is why GGUF interop
//! (`container::gguf`) was gated on this reconciliation and the four
//! forward goldens were re-blessed through the mirror.
//!
//! ## Scratch reuse
//!
//! All per-token and per-panel intermediates live in a caller-owned
//! [`Scratch`] (created once per slot/wave via
//! [`ForwardPass::new_scratch`]), so [`ForwardPass::forward_token`]
//! performs **zero heap allocations per decoded token** and
//! [`ForwardPass::forward_tokens`] none per prefilled prompt — both
//! architectures share the same allocation-free loops (asserted by a
//! counting-allocator test in `tests/native_forward.rs` and reported
//! by `benches/codec.rs`).
//!
//! ## Determinism contract
//!
//! Identical to the PR-3 `vec_dot` contract, extended end to end: every
//! dot product — quantized matvecs, the prefill GEMM panels, attention
//! scores, the RMSNorm sum of squares — reduces in the canonical
//! 8-lane order ([`crate::quant::kernels::dot_lanes`]); every
//! nonlinearity uses the deterministic [`crate::util::math`] kernels;
//! softmaxes, weighted-sum folds and expert combines walk fixed
//! sequential orders. Consequently the logits are **bit-identical**
//! across matvec thread counts, across panel vs token-loop prefill,
//! across absorbed vs eager MLA, and across every `DSQ_FORCE_ARM`
//! dispatch arm (scalar, lanes, AVX2/NEON simd — see the arm matrix in
//! [`crate::quant`]), and are mirrored bit-exactly by
//! `python/tools/bless_goldens.py` (the committed
//! `rust/tests/golden/forward.*.fnv64` and
//! `forward.tiny_dense.*.fnv64` checksums pin both sides).

use crate::container::{Container, TensorEntry};
use crate::model::{ModelConfig, ModelKind};
use crate::quant::{self, kernels, KvScheme, QuantFormat};
use crate::runtime::paged::{KvBlock, KvBlockPool};
use crate::runtime::sharded::ShardRuntime;
use crate::util::math;
use anyhow::{bail, Context, Result};

/// RMSNorm epsilon (matches the proxy training configuration).
pub const RMS_EPS: f32 = 1e-6;

/// The [`ModelKind`]s this backend serves, spelled out for rejection
/// messages.
pub const SUPPORTED_KINDS: &str =
    "MlaMoe (MLA attention + MoE FFNs), DenseGqa (grouped-query attention + dense FFNs)";

/// How the per-matvec dot products are executed.
#[derive(Debug, Clone, Copy)]
pub enum MatvecMode {
    /// Row-parallel fused matvec over up to N threads, runtime-selected
    /// dispatch arm (the serving default; bit-identical for every N).
    Threads(usize),
    /// Serial matvec with the dispatch arm pinned
    /// ([`kernels::DispatchArm`]: scalar reference, lane kernels, or
    /// the AVX2/NEON intrinsics) — the seam `dsq selfcheck` and the
    /// arm-identity tests use.
    Pinned(kernels::DispatchArm),
}

/// How a [`KvCache`]'s rows are stored.
enum KvBacking {
    /// One contiguous `[n_layers][max_ctx][width]` buffer (plus the
    /// expanded plane), lazily allocated on the first forwarded token —
    /// the wave-serving layout.
    Dense {
        data: Vec<f32>,
        /// Absorbed-MLA expanded-row plane: per position the per-head
        /// `k_nope|v` rows the `kv_b` matvec produces from the latent,
        /// written once at append time. Empty when `xwidth == 0`
        /// (GQA, or MLA with absorption disabled).
        xdata: Vec<f32>,
        /// Encoded main plane `[n_layers][max_ctx][row_enc]` bytes —
        /// quantized [`KvScheme`]s only (empty under f32, and vice
        /// versa: exactly one plane pair is ever allocated).
        qdata: Vec<u8>,
        /// Encoded expanded plane `[n_layers][max_ctx][xrow_enc]`.
        xqdata: Vec<u8>,
    },
    /// Fixed-size blocks drawn from a shared [`KvBlockPool`] — the
    /// continuous-batching layout. The `Vec` *is* the block table:
    /// position `p` lives in `blocks[p / block_tokens]` at in-block
    /// offset `p % block_tokens` (each block holds `block_tokens`
    /// positions of **all** layers). Grown explicitly with
    /// [`KvCache::grow_to`] before forwarding.
    Paged {
        blocks: Vec<KvBlock>,
        block_tokens: usize,
    },
}

/// Per-slot KV cache: logically `[n_layers][max_ctx][width]` f32,
/// filled front to back; `len` positions are valid in every layer. The
/// row width is [`ModelConfig::kv_cache_width`] (compressed latent +
/// rope key for MLA, per-head K then V for GQA).
///
/// Two backings share every forward path bit-for-bit (the row values
/// never depend on where a row lives):
///
/// - **Dense** ([`ForwardPass::new_cache`]): one buffer, lazily
///   allocated on the first forwarded token, so a batch slot that never
///   sees a token costs a few machine words, not
///   `n_layers × max_ctx × width` floats.
/// - **Paged** ([`ForwardPass::new_paged_cache`]): fixed-size blocks
///   from a shared [`KvBlockPool`], grown per admission/step with
///   [`KvCache::grow_to`] and recycled with [`KvCache::release`] — the
///   continuous-batching scheduler's layout (see
///   [`crate::runtime::paged`]).
pub struct KvCache {
    backing: KvBacking,
    len: usize,
    width: usize,
    xwidth: usize,
    max_ctx: usize,
    n_layers: usize,
    /// How rows are stored: f32 planes (default) or per-row codec
    /// blocks quantized on append ([`ForwardPass::set_kv_scheme`]).
    scheme: KvScheme,
    /// Encoded bytes per main row (`scheme.line_bytes(width)`; the f32
    /// path never touches it).
    row_enc: usize,
    /// Encoded bytes per expanded row (`scheme.line_bytes(xwidth)`).
    xrow_enc: usize,
}

impl KvCache {
    fn new(n_layers: usize, width: usize, xwidth: usize, max_ctx: usize, scheme: KvScheme) -> Self {
        KvCache {
            backing: KvBacking::Dense {
                data: Vec::new(),
                xdata: Vec::new(),
                qdata: Vec::new(),
                xqdata: Vec::new(),
            },
            len: 0,
            width,
            xwidth,
            max_ctx,
            n_layers,
            scheme,
            row_enc: scheme.line_bytes(width),
            xrow_enc: scheme.line_bytes(xwidth),
        }
    }

    fn new_paged(
        n_layers: usize,
        width: usize,
        xwidth: usize,
        max_ctx: usize,
        scheme: KvScheme,
        block_tokens: usize,
    ) -> Self {
        KvCache {
            backing: KvBacking::Paged {
                blocks: Vec::with_capacity(max_ctx.div_ceil(block_tokens)),
                block_tokens,
            },
            len: 0,
            width,
            xwidth,
            max_ctx,
            n_layers,
            scheme,
            row_enc: scheme.line_bytes(width),
            xrow_enc: scheme.line_bytes(xwidth),
        }
    }

    /// The KV encoding this cache stores rows under.
    pub fn scheme(&self) -> KvScheme {
        self.scheme
    }

    /// Bytes one cached position occupies across all layers under the
    /// active scheme — measured from the same arithmetic the backing
    /// allocation uses, so the planner test can diff it against
    /// [`crate::memory`]'s analytic plan name by name.
    pub fn bytes_per_token(&self) -> usize {
        match self.scheme {
            KvScheme::F32 => self.n_layers * 4 * (self.width + self.xwidth),
            _ => self.n_layers * (self.row_enc + self.xrow_enc),
        }
    }

    /// Named per-layer byte plan one token actually occupies in this
    /// cache — the engine-measured side of the planner-vs-engine gate
    /// (`blk.{i}.kv_row` / `blk.{i}.kv_expanded`, matching
    /// [`crate::memory::kv_token_plan`]).
    pub fn measured_token_plan(&self) -> Vec<(String, u64)> {
        let (row_b, xrow_b) = match self.scheme {
            KvScheme::F32 => (4 * self.width, 4 * self.xwidth),
            _ => (self.row_enc, self.xrow_enc),
        };
        let mut plan = Vec::with_capacity(self.n_layers * 2);
        for li in 0..self.n_layers {
            plan.push((format!("blk.{li}.kv_row"), row_b as u64));
            if self.xwidth > 0 {
                plan.push((format!("blk.{li}.kv_expanded"), xrow_b as u64));
            }
        }
        plan
    }

    /// Payload bytes the backing currently holds resident (dense: the
    /// lazily-allocated planes; paged: the blocks in the table) — the
    /// context-length sweep in `benches/serving.rs` reports this.
    pub fn resident_bytes(&self) -> usize {
        match &self.backing {
            KvBacking::Dense { data, xdata, qdata, xqdata } => {
                4 * (data.len() + xdata.len()) + qdata.len() + xqdata.len()
            }
            KvBacking::Paged { blocks, .. } => blocks
                .iter()
                .map(|b| 4 * (b.data.len() + b.xdata.len()) + b.qdata.len() + b.xqdata.len())
                .sum(),
        }
    }

    /// Tokens cached so far (== the next token's position).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn max_ctx(&self) -> usize {
        self.max_ctx
    }

    /// Whether any backing memory is held yet (dense: the lazy buffer
    /// was allocated by the first forwarded token; paged: at least one
    /// block was taken — the skipped-slot regression tests assert it
    /// stays `false` for slots a wave never touches).
    pub fn is_allocated(&self) -> bool {
        match &self.backing {
            KvBacking::Dense { data, qdata, .. } => !data.is_empty() || !qdata.is_empty(),
            KvBacking::Paged { blocks, .. } => !blocks.is_empty(),
        }
    }

    /// Token positions this cache can currently hold without growing:
    /// `max_ctx` for dense (the lazy buffer covers everything),
    /// the block table's coverage for paged.
    pub fn capacity(&self) -> usize {
        match &self.backing {
            KvBacking::Dense { .. } => self.max_ctx,
            KvBacking::Paged { blocks, block_tokens } => {
                (blocks.len() * block_tokens).min(self.max_ctx)
            }
        }
    }

    /// Make room for a cache of `tokens` positions: dense caches
    /// lazily allocate their full buffer; paged caches must already
    /// have been grown ([`KvCache::grow_to`]) — forwarding never
    /// touches the pool, so an under-grown paged cache is a scheduler
    /// bug reported before any state changes.
    fn prepare_append(&mut self, tokens: usize) -> Result<()> {
        match &mut self.backing {
            KvBacking::Dense { data, xdata, qdata, xqdata } => {
                match self.scheme {
                    KvScheme::F32 => {
                        if data.is_empty() {
                            *data = vec![0.0; self.n_layers * self.max_ctx * self.width];
                        }
                        if self.xwidth > 0 && xdata.is_empty() {
                            *xdata = vec![0.0; self.n_layers * self.max_ctx * self.xwidth];
                        }
                    }
                    _ => {
                        if qdata.is_empty() {
                            *qdata = vec![0; self.n_layers * self.max_ctx * self.row_enc];
                        }
                        if self.xrow_enc > 0 && xqdata.is_empty() {
                            *xqdata = vec![0; self.n_layers * self.max_ctx * self.xrow_enc];
                        }
                    }
                }
                Ok(())
            }
            KvBacking::Paged { blocks, block_tokens } => {
                let cap = blocks.len() * *block_tokens;
                if cap < tokens {
                    bail!(
                        "paged KV cache holds {} blocks × {block_tokens} tokens = {cap} \
                         positions but {tokens} are needed: grow it from the block pool \
                         (KvCache::grow_to) before forwarding",
                        blocks.len()
                    );
                }
                Ok(())
            }
        }
    }

    /// Grow a paged cache's block table to cover `tokens` positions,
    /// taking blocks from `pool` (each take must be covered by the
    /// admission-time reservation — see [`crate::runtime::paged`]).
    /// No-op when the table already covers `tokens`.
    pub fn grow_to(&mut self, tokens: usize, pool: &mut KvBlockPool) -> Result<()> {
        if tokens > self.max_ctx {
            bail!(
                "paged KV cache cannot grow to {tokens} tokens: the engine's configured \
                 max context is {}",
                self.max_ctx
            );
        }
        if !pool.matches(self.n_layers, self.width, self.xwidth, self.scheme) {
            bail!(
                "paged KV cache shape ({} layers × width {} / xwidth {}, kv scheme {}) \
                 does not match the block pool it is growing from — was MLA absorption \
                 or the KV scheme toggled after the pool was created?",
                self.n_layers,
                self.width,
                self.xwidth,
                self.scheme
            );
        }
        match &mut self.backing {
            KvBacking::Dense { .. } => bail!("grow_to: dense KV caches do not use a block pool"),
            KvBacking::Paged { blocks, block_tokens } => {
                let need = tokens.div_ceil(*block_tokens);
                while blocks.len() < need {
                    blocks.push(pool.take()?);
                }
                Ok(())
            }
        }
    }

    /// Return every block to `pool` and reset the cache for reuse by
    /// the next admitted request (paged caches only; returns the number
    /// of blocks released). Block contents are left stale — safe, see
    /// [`crate::runtime::paged`].
    pub fn release(&mut self, pool: &mut KvBlockPool) -> usize {
        self.len = 0;
        match &mut self.backing {
            KvBacking::Dense { .. } => 0,
            KvBacking::Paged { blocks, .. } => {
                let n = blocks.len();
                for b in blocks.drain(..) {
                    pool.put(b);
                }
                n
            }
        }
    }

    /// The base addresses of this cache's blocks (empty for dense) —
    /// the aliasing property test's seam: across all live caches every
    /// address must be distinct.
    pub fn block_addrs(&self) -> Vec<usize> {
        match &self.backing {
            KvBacking::Dense { .. } => Vec::new(),
            KvBacking::Paged { blocks, .. } => match self.scheme {
                KvScheme::F32 => blocks.iter().map(|b| b.data.as_ptr() as usize).collect(),
                _ => blocks.iter().map(|b| b.qdata.as_ptr() as usize).collect(),
            },
        }
    }

    fn row(&self, layer: usize, pos: usize) -> &[f32] {
        match &self.backing {
            KvBacking::Dense { data, .. } => {
                let at = (layer * self.max_ctx + pos) * self.width;
                &data[at..at + self.width]
            }
            KvBacking::Paged { blocks, block_tokens } => {
                let b = &blocks[pos / block_tokens];
                let at = (layer * block_tokens + pos % block_tokens) * self.width;
                &b.data[at..at + self.width]
            }
        }
    }

    fn row_mut(&mut self, layer: usize, pos: usize) -> &mut [f32] {
        match &mut self.backing {
            KvBacking::Dense { data, .. } => {
                let at = (layer * self.max_ctx + pos) * self.width;
                &mut data[at..at + self.width]
            }
            KvBacking::Paged { blocks, block_tokens } => {
                let bt = *block_tokens;
                let b = &mut blocks[pos / bt];
                let at = (layer * bt + pos % bt) * self.width;
                &mut b.data[at..at + self.width]
            }
        }
    }

    fn xrow(&self, layer: usize, pos: usize) -> &[f32] {
        match &self.backing {
            KvBacking::Dense { xdata, .. } => {
                let at = (layer * self.max_ctx + pos) * self.xwidth;
                &xdata[at..at + self.xwidth]
            }
            KvBacking::Paged { blocks, block_tokens } => {
                let b = &blocks[pos / block_tokens];
                let at = (layer * block_tokens + pos % block_tokens) * self.xwidth;
                &b.xdata[at..at + self.xwidth]
            }
        }
    }

    /// One position's latent row (read) together with its expanded row
    /// (write) — the borrow split the append-time expansion needs.
    fn row_and_xrow_mut(&mut self, layer: usize, pos: usize) -> (&[f32], &mut [f32]) {
        match &mut self.backing {
            KvBacking::Dense { data, xdata, .. } => {
                let at = (layer * self.max_ctx + pos) * self.width;
                let xat = (layer * self.max_ctx + pos) * self.xwidth;
                (&data[at..at + self.width], &mut xdata[xat..xat + self.xwidth])
            }
            KvBacking::Paged { blocks, block_tokens } => {
                let bt = *block_tokens;
                let b = &mut blocks[pos / bt];
                let at = (layer * bt + pos % bt) * self.width;
                let xat = (layer * bt + pos % bt) * self.xwidth;
                (&b.data[at..at + self.width], &mut b.xdata[xat..xat + self.xwidth])
            }
        }
    }

    /// One position's encoded main row (quantized schemes only).
    fn row_enc(&self, layer: usize, pos: usize) -> &[u8] {
        match &self.backing {
            KvBacking::Dense { qdata, .. } => {
                let at = (layer * self.max_ctx + pos) * self.row_enc;
                &qdata[at..at + self.row_enc]
            }
            KvBacking::Paged { blocks, block_tokens } => {
                let b = &blocks[pos / block_tokens];
                let at = (layer * block_tokens + pos % block_tokens) * self.row_enc;
                &b.qdata[at..at + self.row_enc]
            }
        }
    }

    /// One position's encoded expanded row (quantized absorbed MLA).
    fn xrow_enc(&self, layer: usize, pos: usize) -> &[u8] {
        match &self.backing {
            KvBacking::Dense { xqdata, .. } => {
                let at = (layer * self.max_ctx + pos) * self.xrow_enc;
                &xqdata[at..at + self.xrow_enc]
            }
            KvBacking::Paged { blocks, block_tokens } => {
                let b = &blocks[pos / block_tokens];
                let at = (layer * block_tokens + pos % block_tokens) * self.xrow_enc;
                &b.xqdata[at..at + self.xrow_enc]
            }
        }
    }

    /// Quantize-on-append: encode the staged f32 row (already padded to
    /// the scheme's block grid) into position `pos`'s main-plane codec
    /// blocks. Write-once, like the absorbed-MLA expanded plane.
    fn write_row_enc(&mut self, layer: usize, pos: usize, staged: &[f32]) -> Result<()> {
        let scheme = self.scheme;
        let re = self.row_enc;
        let dst = match &mut self.backing {
            KvBacking::Dense { qdata, .. } => {
                let at = (layer * self.max_ctx + pos) * re;
                &mut qdata[at..at + re]
            }
            KvBacking::Paged { blocks, block_tokens } => {
                let bt = *block_tokens;
                let b = &mut blocks[pos / bt];
                let at = (layer * bt + pos % bt) * re;
                &mut b.qdata[at..at + re]
            }
        };
        quant::encode_kv_line(scheme, staged, dst)
    }

    /// [`KvCache::write_row_enc`] for the expanded plane.
    fn write_xrow_enc(&mut self, layer: usize, pos: usize, staged: &[f32]) -> Result<()> {
        let scheme = self.scheme;
        let re = self.xrow_enc;
        let dst = match &mut self.backing {
            KvBacking::Dense { xqdata, .. } => {
                let at = (layer * self.max_ctx + pos) * re;
                &mut xqdata[at..at + re]
            }
            KvBacking::Paged { blocks, block_tokens } => {
                let bt = *block_tokens;
                let b = &mut blocks[pos / bt];
                let at = (layer * bt + pos % bt) * re;
                &mut b.xqdata[at..at + re]
            }
        };
        quant::encode_kv_line(scheme, staged, dst)
    }

    /// Attention-score dot of `q` against elements
    /// `[off, off + q.len())` of position `pos`'s main row. f32 reads
    /// the plane directly through [`kernels::dot_lanes`]; quantized
    /// schemes run the fused [`kernels::vec_dot_arm`] over the row's
    /// codec blocks — same canonical 8-lane reduction order, so scores
    /// are bit-identical across threads, shards and dispatch arms.
    /// `off` and `q.len()` must sit on the scheme's block grid
    /// (validated once by [`ForwardPass::set_kv_scheme`]).
    fn score_dot(&self, layer: usize, pos: usize, off: usize, q: &[f32], arm: kernels::DispatchArm) -> f32 {
        match self.scheme {
            KvScheme::F32 => kernels::dot_lanes(q, &self.row(layer, pos)[off..off + q.len()]),
            s => {
                let fmt = s.format();
                let (bw, bb) = (fmt.block_weights(), fmt.block_bytes());
                let seg = &self.row_enc(layer, pos)[off / bw * bb..(off + q.len()) / bw * bb];
                kernels::vec_dot_arm(fmt, seg, q, arm)
            }
        }
    }

    /// [`KvCache::score_dot`] against the absorbed-MLA expanded row.
    fn score_dot_x(
        &self,
        layer: usize,
        pos: usize,
        off: usize,
        q: &[f32],
        arm: kernels::DispatchArm,
    ) -> f32 {
        match self.scheme {
            KvScheme::F32 => kernels::dot_lanes(q, &self.xrow(layer, pos)[off..off + q.len()]),
            s => {
                let fmt = s.format();
                let (bw, bb) = (fmt.block_weights(), fmt.block_bytes());
                let seg = &self.xrow_enc(layer, pos)[off / bw * bb..(off + q.len()) / bw * bb];
                kernels::vec_dot_arm(fmt, seg, q, arm)
            }
        }
    }

    /// The value segment `[off, off + len)` of position `pos`'s main
    /// row as f32: a direct plane slice under f32, a block decode into
    /// the caller's preallocated `dec` scratch under a quantized scheme
    /// (zero heap allocations either way). The weighted-sum fold over
    /// the returned slice is unchanged, so the reduction order is too.
    fn values<'a>(
        &'a self,
        layer: usize,
        pos: usize,
        off: usize,
        len: usize,
        dec: &'a mut [f32],
        arm: kernels::DispatchArm,
    ) -> &'a [f32] {
        match self.scheme {
            KvScheme::F32 => &self.row(layer, pos)[off..off + len],
            s => {
                let fmt = s.format();
                let (bw, bb) = (fmt.block_weights(), fmt.block_bytes());
                let seg = &self.row_enc(layer, pos)[off / bw * bb..(off + len) / bw * bb];
                kernels::decode_blocks_arm(fmt, seg, &mut dec[..len], arm);
                &dec[..len]
            }
        }
    }

    /// [`KvCache::values`] against the absorbed-MLA expanded row.
    fn values_x<'a>(
        &'a self,
        layer: usize,
        pos: usize,
        off: usize,
        len: usize,
        dec: &'a mut [f32],
        arm: kernels::DispatchArm,
    ) -> &'a [f32] {
        match self.scheme {
            KvScheme::F32 => &self.xrow(layer, pos)[off..off + len],
            s => {
                let fmt = s.format();
                let (bw, bb) = (fmt.block_weights(), fmt.block_bytes());
                let seg = &self.xrow_enc(layer, pos)[off / bw * bb..(off + len) / bw * bb];
                kernels::decode_blocks_arm(fmt, seg, &mut dec[..len], arm);
                &dec[..len]
            }
        }
    }

    /// The raw dense cache plane (`[n_layers][max_ctx][width]`,
    /// zero-filled past `len`) — the bit-identity tests compare prefill
    /// paths on this directly. Dense-only seam: paged caches return an
    /// empty slice (compare via [`KvCache::copy_rows`] instead).
    pub fn raw_rows(&self) -> &[f32] {
        match &self.backing {
            KvBacking::Dense { data, .. } => data,
            KvBacking::Paged { .. } => &[],
        }
    }

    /// The raw dense absorbed-MLA expanded plane (empty unless
    /// absorption is active) — same inspection seam as
    /// [`KvCache::raw_rows`].
    pub fn raw_expanded(&self) -> &[f32] {
        match &self.backing {
            KvBacking::Dense { xdata, .. } => xdata,
            KvBacking::Paged { .. } => &[],
        }
    }

    /// Materialize the logical `[n_layers][max_ctx][width]` plane for
    /// either backing: positions `< len` copied row by row, everything
    /// past `len` zero. Only `< len` rows are meaningful to compare —
    /// recycled paged blocks carry stale values past `len` where a
    /// dense buffer holds zeros, so this is the cross-backing
    /// reconstruction seam the property tests use.
    pub fn copy_rows(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.n_layers * self.max_ctx * self.width];
        let mut dec = vec![0.0f32; self.scheme.line_weights(self.width)];
        for layer in 0..self.n_layers {
            for pos in 0..self.len {
                let at = (layer * self.max_ctx + pos) * self.width;
                match self.scheme {
                    KvScheme::F32 => out[at..at + self.width].copy_from_slice(self.row(layer, pos)),
                    s => {
                        kernels::decode_blocks_arm(
                            s.format(),
                            self.row_enc(layer, pos),
                            &mut dec,
                            kernels::active_arm(),
                        );
                        out[at..at + self.width].copy_from_slice(&dec[..self.width]);
                    }
                }
            }
        }
        out
    }

    /// [`KvCache::copy_rows`] for the absorbed-MLA expanded plane
    /// (empty when absorption is off / GQA).
    pub fn copy_expanded(&self) -> Vec<f32> {
        if self.xwidth == 0 {
            return Vec::new();
        }
        let mut out = vec![0.0; self.n_layers * self.max_ctx * self.xwidth];
        let mut dec = vec![0.0f32; self.scheme.line_weights(self.xwidth)];
        for layer in 0..self.n_layers {
            for pos in 0..self.len {
                let at = (layer * self.max_ctx + pos) * self.xwidth;
                match self.scheme {
                    KvScheme::F32 => {
                        out[at..at + self.xwidth].copy_from_slice(self.xrow(layer, pos))
                    }
                    s => {
                        kernels::decode_blocks_arm(
                            s.format(),
                            self.xrow_enc(layer, pos),
                            &mut dec,
                            kernels::active_arm(),
                        );
                        out[at..at + self.xwidth].copy_from_slice(&dec[..self.xwidth]);
                    }
                }
            }
        }
        out
    }

    /// Materialize the logical encoded plane
    /// `[n_layers][max_ctx][row_enc]` bytes, zero past `len` — the
    /// byte-level cross-backing reconstruction seam for quantized
    /// schemes (dense ≡ paged must hold on the *encoded* blocks, not
    /// just their decode). Empty under f32.
    pub fn copy_rows_enc(&self) -> Vec<u8> {
        if self.scheme == KvScheme::F32 {
            return Vec::new();
        }
        let mut out = vec![0u8; self.n_layers * self.max_ctx * self.row_enc];
        for layer in 0..self.n_layers {
            for pos in 0..self.len {
                let at = (layer * self.max_ctx + pos) * self.row_enc;
                out[at..at + self.row_enc].copy_from_slice(self.row_enc(layer, pos));
            }
        }
        out
    }

    /// [`KvCache::copy_rows_enc`] for the encoded expanded plane.
    pub fn copy_expanded_enc(&self) -> Vec<u8> {
        if self.scheme == KvScheme::F32 || self.xwidth == 0 {
            return Vec::new();
        }
        let mut out = vec![0u8; self.n_layers * self.max_ctx * self.xrow_enc];
        for layer in 0..self.n_layers {
            for pos in 0..self.len {
                let at = (layer * self.max_ctx + pos) * self.xrow_enc;
                out[at..at + self.xrow_enc].copy_from_slice(self.xrow_enc(layer, pos));
            }
        }
        out
    }
}

/// One layer's resolved weights: encoded entries for everything the
/// fused matvec consumes, decoded f32 vectors for the (tiny) norms.
struct LayerWeights {
    attn_norm: Vec<f32>,
    attn: LayerAttn,
    attn_output: TensorEntry,
    ffn_norm: Vec<f32>,
    ffn: LayerFfn,
}

/// The attention projections, by architecture family.
enum LayerAttn {
    /// Multi-head latent attention (DeepSeek-V3 style).
    Mla {
        q_a: TensorEntry,
        q_a_norm: Vec<f32>,
        q_b: TensorEntry,
        kv_a: TensorEntry,
        kv_a_norm: Vec<f32>,
        kv_b: TensorEntry,
    },
    /// Grouped-query attention (Qwen2.5 style, the distill shapes).
    Gqa {
        q: TensorEntry,
        k: TensorEntry,
        v: TensorEntry,
    },
}

enum LayerFfn {
    Dense {
        gate: TensorEntry,
        up: TensorEntry,
        down: TensorEntry,
    },
    Moe {
        router: TensorEntry,
        gate_exps: TensorEntry,
        up_exps: TensorEntry,
        down_exps: TensorEntry,
        gate_shexp: TensorEntry,
        up_shexp: TensorEntry,
        down_shexp: TensorEntry,
    },
}

/// Precomputed rotary table: `cos/sin(pos · θ_i)` for every position
/// below `max_ctx` and every frequency `θ_i = base^(−2i/d)`.
///
/// Built from [`math::ln_f32`] (the base), [`math::exp_f32`]
/// (frequencies), [`math::sin_small`] / [`math::cos_small`] (the
/// ≤ 1-radian per-step angles) and the exactly-rounded angle-addition
/// recurrence — no libm, so the table is reproducible bit-for-bit
/// anywhere (including the Python mirror).
struct RopeTable {
    half: usize,
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl RopeTable {
    /// `base_ln` is `ln(rope_base)` as computed by [`math::ln_f32`].
    fn new(dim: usize, max_ctx: usize, base_ln: f32) -> Self {
        let half = dim / 2;
        let mut cos = vec![0.0f32; max_ctx * half];
        let mut sin = vec![0.0f32; max_ctx * half];
        for i in 0..half {
            let a = (2 * i) as f32 / dim as f32;
            let theta = math::exp_f32(-(a * base_ln));
            let (c1, s1) = (math::cos_small(theta), math::sin_small(theta));
            let (mut c, mut s) = (1.0f32, 0.0f32);
            for p in 0..max_ctx {
                cos[p * half + i] = c;
                sin[p * half + i] = s;
                let (cn, sn) = (c * c1 - s * s1, s * c1 + c * s1);
                c = cn;
                s = sn;
            }
        }
        RopeTable { half, cos, sin }
    }

    /// Rotate half-split pairs `(x[i], x[i+half])` by `pos · θ_i`.
    ///
    /// This is the HF/llama.cpp "NeoX" pairing that Qwen (and hence the
    /// DeepSeek-R1 distills) are trained with, and what
    /// `python/compile/model.py::rope` computes: the first half of the
    /// rotated span carries `x1·cos − x2·sin`, the second `x1·sin +
    /// x2·cos`. Earlier revisions rotated interleaved GPT-NeoX-*source*
    /// pairs `(x[2i], x[2i+1])`, which is self-consistent on synthetic
    /// weights but serves externally-trained checkpoints with garbage
    /// attention; the forward goldens were re-blessed when the pairing
    /// was reconciled (see `rust/tests/golden/README.md`).
    fn apply(&self, x: &mut [f32], pos: usize) {
        debug_assert_eq!(x.len(), 2 * self.half);
        for i in 0..self.half {
            let c = self.cos[pos * self.half + i];
            let s = self.sin[pos * self.half + i];
            let (a, b) = (x[i], x[i + self.half]);
            x[i] = a * c - b * s;
            x[i + self.half] = a * s + b * c;
        }
    }
}

/// RMSNorm with the canonical lane-ordered sum of squares:
/// `out[i] = (x[i] · rsqrt(mean(x²) + ε)) · w[i]`.
pub fn rms_norm(x: &[f32], w: &[f32], out: &mut [f32]) {
    debug_assert!(x.len() == w.len() && x.len() == out.len());
    let ss = kernels::dot_lanes(x, x);
    let scale = 1.0 / (ss / x.len() as f32 + RMS_EPS).sqrt();
    for ((o, &xv), &wv) in out.iter_mut().zip(x).zip(w) {
        *o = (xv * scale) * wv;
    }
}

/// Quantized matvec `out[r] = row_r · x` on encoded bytes under an
/// explicit [`MatvecMode`] — the kernel both the driver
/// ([`ForwardPass`]) and the shard workers
/// ([`crate::runtime::sharded`]) run, so a shard computing rows
/// `r0..r1` from its sliced bytes executes exactly the unsharded
/// arithmetic for those rows.
pub(crate) fn matvec_bytes_mode(
    mode: MatvecMode,
    fmt: QuantFormat,
    bytes: &[u8],
    x: &[f32],
    out: &mut [f32],
) -> Result<()> {
    match mode {
        MatvecMode::Threads(n) => quant::vec_dot_rows_with(fmt, bytes, x, out, n),
        MatvecMode::Pinned(arm) => {
            let rb = fmt.row_bytes(x.len())?;
            if bytes.len() != rb * out.len() {
                bail!("pinned matvec: {} bytes != {} rows × {rb}", bytes.len(), out.len());
            }
            for (o, row) in out.iter_mut().zip(bytes.chunks_exact(rb)) {
                *o = kernels::vec_dot_arm(fmt, row, x, arm);
            }
            Ok(())
        }
    }
}

/// GEMM staging under an explicit [`MatvecMode`]: fill the row-major
/// `[rows][t]` plane `m` with `m[r*t + c] = row_r · col_c` over the
/// token-major activation panel `xs` (`rows = m.len() / t`). This is
/// the pre-transpose half of [`ForwardPass::matvec_mat`], factored out
/// so each shard worker can fill its own disjoint row range of the
/// shared staging plane.
pub(crate) fn stage_rows_mode(
    mode: MatvecMode,
    fmt: QuantFormat,
    bytes: &[u8],
    xs: &[f32],
    n: usize,
    t: usize,
    m: &mut [f32],
) -> Result<()> {
    match mode {
        MatvecMode::Threads(threads) => {
            quant::vec_dot_rows_mat_with(fmt, bytes, xs, n, t, m, threads)
        }
        MatvecMode::Pinned(arm) => {
            debug_assert_eq!(m.len() % t, 0);
            let rows = m.len() / t;
            let rb = fmt.row_bytes(n)?;
            if bytes.len() != rb * rows {
                bail!("pinned GEMM: {} bytes != {rows} rows × {rb}", bytes.len());
            }
            if rb == 0 {
                m.fill(0.0);
            } else {
                for (row, o) in bytes.chunks_exact(rb).zip(m.chunks_exact_mut(t)) {
                    kernels::vec_dot_mat_arm(fmt, row, xs, n, o, arm);
                }
            }
            Ok(())
        }
    }
}

/// Transpose the row-major `[rows][t]` staging plane into a token-major
/// `[t][rows]` panel — a pure permutation of finished f32 values, so
/// every element stays bit-identical to the single-column matvec.
pub(crate) fn transpose_into(m: &[f32], out: &mut [f32], rows: usize, t: usize) {
    for r in 0..rows {
        for c in 0..t {
            out[c * rows + r] = m[r * t + c];
        }
    }
}

/// [`stage_rows_mode`] + [`transpose_into`]: the complete unsharded
/// GEMM (`out[c*rows + r] = row_r · col_c`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn matvec_mat_bytes_mode(
    mode: MatvecMode,
    fmt: QuantFormat,
    bytes: &[u8],
    xs: &[f32],
    n: usize,
    t: usize,
    mat: &mut [f32],
    out: &mut [f32],
) -> Result<()> {
    debug_assert_eq!(out.len() % t, 0);
    let rows = out.len() / t;
    let m = &mut mat[..rows * t];
    stage_rows_mode(mode, fmt, bytes, xs, n, t, m)?;
    transpose_into(m, out, rows, t);
    Ok(())
}

/// `down(silu(gate(x)) · up(x))` with all three projections fused on
/// encoded rows under an explicit [`MatvecMode`] — the routed-expert
/// MLP body, shared by the unsharded driver and the shard workers (an
/// expert's whole MLP runs on its owner shard, so the arithmetic is
/// identical wherever it executes).
#[allow(clippy::too_many_arguments)]
pub(crate) fn mlp_bytes_mode(
    mode: MatvecMode,
    gate: (QuantFormat, &[u8]),
    up: (QuantFormat, &[u8]),
    down: (QuantFormat, &[u8]),
    inter: usize,
    x: &[f32],
    out: &mut [f32],
    g_buf: &mut [f32],
    u_buf: &mut [f32],
) -> Result<()> {
    let g = &mut g_buf[..inter];
    let u = &mut u_buf[..inter];
    matvec_bytes_mode(mode, gate.0, gate.1, x, g)?;
    matvec_bytes_mode(mode, up.0, up.1, x, u)?;
    for (gv, &uv) in g.iter_mut().zip(&*u) {
        *gv = math::silu(*gv) * uv;
    }
    matvec_bytes_mode(mode, down.0, down.1, g, out)
}

/// Panel analogue of [`mlp_bytes_mode`]: the SwiGLU MLP over a
/// `t`-column token-major panel, all three projections through the
/// decode-once GEMM kernels — bit-identical per column to the
/// single-token path.
#[allow(clippy::too_many_arguments)]
pub(crate) fn mlp_mat_bytes_mode(
    mode: MatvecMode,
    gate: (QuantFormat, &[u8]),
    up: (QuantFormat, &[u8]),
    down: (QuantFormat, &[u8]),
    inter: usize,
    xs: &[f32],
    n: usize,
    t: usize,
    mat: &mut [f32],
    g_buf: &mut [f32],
    u_buf: &mut [f32],
    out: &mut [f32],
) -> Result<()> {
    let g = &mut g_buf[..t * inter];
    let u = &mut u_buf[..t * inter];
    matvec_mat_bytes_mode(mode, gate.0, gate.1, xs, n, t, mat, g)?;
    matvec_mat_bytes_mode(mode, up.0, up.1, xs, n, t, mat, u)?;
    for (gv, &uv) in g.iter_mut().zip(&*u) {
        *gv = math::silu(*gv) * uv;
    }
    matvec_mat_bytes_mode(mode, down.0, down.1, g, inter, t, mat, out)
}

/// Reusable per-slot scratch for [`ForwardPass::forward_token`]: every
/// per-token intermediate, allocated once (sized to the model and
/// `max_ctx`) and fully overwritten each use, so the decode loop itself
/// never touches the heap. Create with [`ForwardPass::new_scratch`].
pub struct Scratch {
    /// Residual stream.
    h: Vec<f32>,
    /// Normed input to attention/FFN (and the final output norm).
    xn: Vec<f32>,
    /// Attention/FFN output before the residual add.
    delta: Vec<f32>,
    attn: AttnScratch,
    ffn: FfnScratch,
    panel: PanelScratch,
}

struct AttnScratch {
    /// Per-head query projections (`heads·(nope+rope)` for MLA,
    /// `heads·head_dim` for GQA), rotated in place.
    q: Vec<f32>,
    /// MLA: pre-norm query latent.
    q_a: Vec<f32>,
    /// MLA: RMS-normed query latent.
    q_an: Vec<f32>,
    /// MLA: joint (latent, rope-key) projection before the cache write.
    kv_a: Vec<f32>,
    /// MLA: per-position re-expanded `k_nope|v` rows, `max_ctx · kvb_w`.
    kvb: Vec<f32>,
    /// Per-head attention outputs before `attn_output`.
    heads_out: Vec<f32>,
    /// Attention scores over the cached context, `max_ctx`.
    scores: Vec<f32>,
    /// Quantized KV: staging line for the exact f32 row (norm/RoPE/
    /// projections land here, then one `encode_kv_line` writes the
    /// cache blocks), padded to the scheme's block grid with a
    /// zero tail. Empty under f32 KV.
    kv_stage: Vec<f32>,
    /// Quantized absorbed MLA: staging line for the expanded row.
    xkv_stage: Vec<f32>,
    /// Quantized KV: per-segment value decode scratch for the
    /// weighted-sum fold. Empty under f32 KV.
    vdec: Vec<f32>,
}

struct FfnScratch {
    /// SwiGLU gate projection (becomes `silu(g)·u` in place).
    g: Vec<f32>,
    /// SwiGLU up projection.
    u: Vec<f32>,
    /// MoE: one routed expert's output before the weighted combine.
    y: Vec<f32>,
    /// Sharded MoE: all selected experts' outputs, `[n_active][hidden]`
    /// — the owners fill their disjoint rows concurrently, then the
    /// driver combines in ascending expert order.
    ye: Vec<f32>,
    /// MoE: router probabilities.
    probs: Vec<f32>,
    /// MoE: expert ordering for the top-k selection.
    idx: Vec<usize>,
}

/// Panel (multi-token) intermediates for
/// [`ForwardPass::forward_tokens`] and
/// [`ForwardPass::forward_step_batch`]: token-major `[T][dim]` panels
/// sized for `T = cap` (`max_ctx`, or the batch width if larger — see
/// [`ForwardPass::new_scratch_cols`]), plus the row-major GEMM staging
/// buffer. Allocated once with the rest of the scratch, so panel
/// prefill and batched decode touch the heap zero times per call.
struct PanelScratch {
    /// Panel column capacity (`T ≤ cap` for every panel call).
    cap: usize,
    /// Batched decode: column → batch-slot map (the live slots, in
    /// ascending slot order).
    cols: Vec<usize>,
    /// Batched decode: per-column logits staging (`[cap][vocab]`)
    /// before the scatter back to slot-major rows.
    lp: Vec<f32>,
    /// Residual stream panel.
    h: Vec<f32>,
    /// Normed panel (attention/FFN input).
    xn: Vec<f32>,
    /// Attention/FFN output panel before the residual add.
    delta: Vec<f32>,
    /// Query projection panel.
    q: Vec<f32>,
    /// MLA: pre-norm query latent panel.
    q_a: Vec<f32>,
    /// MLA: RMS-normed query latent panel.
    q_an: Vec<f32>,
    /// MLA: joint (latent, rope key) panel; GQA: K projection panel.
    kv: Vec<f32>,
    /// GQA: V projection panel (MLA leaves it empty).
    v: Vec<f32>,
    /// Per-head attention output panel (input to `attn_output`).
    heads_out: Vec<f32>,
    /// Attention scores over the cached context, `max_ctx`.
    scores: Vec<f32>,
    /// SwiGLU gate panel (becomes `silu(g)·u` in place).
    g: Vec<f32>,
    /// SwiGLU up panel.
    u: Vec<f32>,
    /// MoE: router probability panel.
    probs: Vec<f32>,
    /// MoE: per-token selected experts, `[T][n_active]` flat.
    sel: Vec<usize>,
    /// MoE: per-token top-k probability normalizers.
    z: Vec<f32>,
    /// MoE: indices of the tokens routed to the current expert.
    gather: Vec<usize>,
    /// MoE: gathered activation columns for one expert's GEMM.
    xg: Vec<f32>,
    /// MoE: one expert's outputs over the gathered tokens.
    y: Vec<f32>,
    /// Sharded MoE: gathered activations for **all** experts' token
    /// groups at once, `[cap·n_active][hidden]` — empty unless the
    /// scratch was created on a sharded pass (see
    /// [`ForwardPass::set_sharding`]).
    xge: Vec<f32>,
    /// Sharded MoE: every expert's outputs over its gathered tokens,
    /// same plane layout as `xge` (empty unsharded).
    ye: Vec<f32>,
    /// Sharded MoE: `(expert, plane row offset, token count)` per
    /// non-empty expert, ascending expert order.
    exp_jobs: Vec<(usize, usize, usize)>,
    /// Sharded MoE: concatenated gathered token indices (rows of `xge`
    /// back to panel columns), aligned with `exp_jobs` offsets.
    gat: Vec<usize>,
    /// Row-major `[rows][T]` GEMM staging, transposed into the panels.
    mat: Vec<f32>,
    /// Quantized KV: staging line for the exact f32 row before the
    /// one-shot `encode_kv_line` cache write (see [`AttnScratch`]).
    kv_stage: Vec<f32>,
    /// Quantized absorbed MLA: staging line for the expanded row.
    xkv_stage: Vec<f32>,
    /// Quantized KV: per-segment value decode scratch.
    vdec: Vec<f32>,
}

/// The forward-pass model over an opened (quantized or f32) container.
pub struct ForwardPass {
    cfg: ModelConfig,
    ckpt: Container,
    token_embd: TensorEntry,
    embd_row_bytes: usize,
    layers: Vec<LayerWeights>,
    output_norm: Vec<f32>,
    output: TensorEntry,
    rope: RopeTable,
    max_ctx: usize,
    mode: MatvecMode,
    absorb_mla: bool,
    /// KV cache encoding ([`ForwardPass::set_kv_scheme`]); f32 default.
    kv_scheme: KvScheme,
    /// Sharded execution runtime (expert-parallel MoE + row-split
    /// tensor-parallel matmuls); `None` runs everything locally.
    shards: Option<ShardRuntime>,
}

/// Kind-specific config dims the forward pass depends on must be usable
/// before any tensor is resolved; the rejection names the model and
/// lists what this backend *can* serve.
fn validate_kind(cfg: &ModelConfig) -> Result<()> {
    let reject = |why: &str| -> Result<()> {
        bail!(
            "native forward pass cannot serve container model {:?} (kind {:?}): {why}; \
             supported kinds: {SUPPORTED_KINDS}",
            cfg.name,
            cfg.kind
        )
    };
    if !cfg.rope_base.is_finite() || cfg.rope_base <= 1.0 {
        return reject(&format!("rope_base {} is not a finite base > 1", cfg.rope_base));
    }
    match cfg.kind {
        ModelKind::MlaMoe => {
            if cfg.q_lora_rank == 0 || cfg.kv_lora_rank == 0 {
                return reject("MLA needs q_lora_rank and kv_lora_rank > 0");
            }
            if cfg.qk_rope_head_dim == 0 || cfg.qk_rope_head_dim % 2 != 0 {
                return reject("MLA needs a positive, even qk_rope_head_dim for RoPE pairs");
            }
        }
        ModelKind::DenseGqa => {
            if cfg.head_dim == 0 || cfg.head_dim % 2 != 0 {
                return reject("GQA needs a positive, even head_dim for RoPE pairs");
            }
            if cfg.n_kv_heads == 0 || cfg.n_heads % cfg.n_kv_heads != 0 {
                return reject("GQA needs n_heads divisible by a positive n_kv_heads");
            }
        }
    }
    Ok(())
}

impl ForwardPass {
    /// Resolve and validate the full layer map from `ckpt` (taken over
    /// whole; payloads are served in place). `threads` bounds the
    /// row-parallel matvec fan-out; `max_ctx` bounds every
    /// [`KvCache`] this model creates.
    pub fn new(ckpt: Container, threads: usize, max_ctx: usize) -> Result<Self> {
        let cfg = ckpt.model.clone();
        validate_kind(&cfg)?;
        if max_ctx == 0 {
            bail!("native forward pass needs max_ctx ≥ 1");
        }
        let entry = |name: &str, shape: &[usize]| -> Result<TensorEntry> {
            let t = ckpt.tensor(name).context("native forward layer map")?;
            if t.shape != shape {
                bail!(
                    "model {:?} ({:?}): tensor {name}: shape {:?} does not match the \
                     config's expected {:?}",
                    cfg.name,
                    cfg.kind,
                    t.shape,
                    shape
                );
            }
            // Fused matvecs consume whole rows of blocks.
            t.format
                .row_bytes(*shape.last().unwrap())
                .with_context(|| format!("tensor {name}: rows not block-aligned"))?;
            Ok(t.clone())
        };
        let norm = |name: &str, len: usize| -> Result<Vec<f32>> {
            let t = entry(name, &[len])?;
            ckpt.dequantize(&t)
        };

        let h = cfg.hidden_size;
        let token_embd = entry("token_embd.weight", &[cfg.vocab_size, h])?;
        let embd_row_bytes = token_embd.format.row_bytes(h)?;
        let output = entry("output.weight", &[cfg.vocab_size, h])?;
        let output_norm = norm("output_norm.weight", h)?;

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let blk = |stem: &str| format!("blk.{i}.{stem}.weight");
            let (attn, attn_output) = match cfg.kind {
                ModelKind::MlaMoe => {
                    let qk_head = cfg.qk_head_dim();
                    let attn = LayerAttn::Mla {
                        q_a: entry(&blk("attn_q_a"), &[cfg.q_lora_rank, h])?,
                        q_a_norm: norm(&blk("attn_q_a_norm"), cfg.q_lora_rank)?,
                        q_b: entry(&blk("attn_q_b"), &[cfg.n_heads * qk_head, cfg.q_lora_rank])?,
                        kv_a: entry(&blk("attn_kv_a_mqa"), &[cfg.kv_cache_width(), h])?,
                        kv_a_norm: norm(&blk("attn_kv_a_norm"), cfg.kv_lora_rank)?,
                        kv_b: entry(
                            &blk("attn_kv_b"),
                            &[
                                cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim),
                                cfg.kv_lora_rank,
                            ],
                        )?,
                    };
                    let out = entry(&blk("attn_output"), &[h, cfg.n_heads * cfg.v_head_dim])?;
                    (attn, out)
                }
                ModelKind::DenseGqa => {
                    let kd = cfg.n_kv_heads * cfg.head_dim;
                    let attn = LayerAttn::Gqa {
                        q: entry(&blk("attn_q"), &[cfg.n_heads * cfg.head_dim, h])?,
                        k: entry(&blk("attn_k"), &[kd, h])?,
                        v: entry(&blk("attn_v"), &[kd, h])?,
                    };
                    let out = entry(&blk("attn_output"), &[h, cfg.n_heads * cfg.head_dim])?;
                    (attn, out)
                }
            };
            let ffn = if cfg.is_moe_layer(i) {
                let mi = cfg.moe_intermediate_size;
                let sh = cfg.n_shared_experts * mi;
                LayerFfn::Moe {
                    router: entry(&blk("ffn_gate_inp"), &[cfg.n_routed_experts, h])?,
                    gate_exps: entry(&blk("ffn_gate_exps"), &[cfg.n_routed_experts, mi, h])?,
                    up_exps: entry(&blk("ffn_up_exps"), &[cfg.n_routed_experts, mi, h])?,
                    down_exps: entry(&blk("ffn_down_exps"), &[cfg.n_routed_experts, h, mi])?,
                    gate_shexp: entry(&blk("ffn_gate_shexp"), &[sh, h])?,
                    up_shexp: entry(&blk("ffn_up_shexp"), &[sh, h])?,
                    down_shexp: entry(&blk("ffn_down_shexp"), &[h, sh])?,
                }
            } else {
                LayerFfn::Dense {
                    gate: entry(&blk("ffn_gate"), &[cfg.intermediate_size, h])?,
                    up: entry(&blk("ffn_up"), &[cfg.intermediate_size, h])?,
                    down: entry(&blk("ffn_down"), &[h, cfg.intermediate_size])?,
                }
            };
            layers.push(LayerWeights {
                attn_norm: norm(&blk("attn_norm"), h)?,
                attn,
                attn_output,
                ffn_norm: norm(&blk("ffn_norm"), h)?,
                ffn,
            });
        }
        let rope_dim = match cfg.kind {
            ModelKind::MlaMoe => cfg.qk_rope_head_dim,
            ModelKind::DenseGqa => cfg.head_dim,
        };
        let rope = RopeTable::new(rope_dim, max_ctx, math::ln_f32(cfg.rope_base));
        Ok(ForwardPass {
            cfg,
            ckpt,
            token_embd,
            embd_row_bytes,
            layers,
            output_norm,
            output,
            rope,
            max_ctx,
            mode: MatvecMode::Threads(threads.max(1)),
            absorb_mla: true,
            kv_scheme: KvScheme::F32,
            shards: None,
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Scheme name of the underlying container.
    pub fn scheme_name(&self) -> &str {
        &self.ckpt.scheme_name
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab_size
    }

    pub fn max_ctx(&self) -> usize {
        self.max_ctx
    }

    /// The stored format of the unembedding matrix (what the per-step
    /// vocab-wide fused matvec runs on).
    pub fn output_format(&self) -> QuantFormat {
        self.output.format
    }

    /// Override the matvec execution mode (thread count or pinned
    /// dispatch arm). Logits are bit-identical under every mode — that
    /// is the point of the seam (`dsq selfcheck`, arm-identity tests).
    pub fn set_mode(&mut self, mode: MatvecMode) {
        self.mode = mode;
    }

    /// Enable/disable MLA `kv_b` absorption (default: enabled).
    /// Absorbed caches keep the per-head expanded `k_nope|v` rows,
    /// written once at append time, dropping the O(context) per-step
    /// re-expansion; disabling restores the memory-lean latent-only
    /// cache with eager re-expansion — the seam the equivalence tests
    /// use. Call **before** creating caches: the flag decides the
    /// layout [`ForwardPass::new_cache`] builds. No-op for GQA models.
    pub fn set_mla_absorption(&mut self, absorb: bool) {
        self.absorb_mla = absorb;
    }

    /// Select the KV cache encoding (default [`KvScheme::F32`], which
    /// keeps every existing golden byte-identical). Quantized schemes
    /// store each appended row as codec blocks (quantize-on-write) and
    /// read attention scores through the fused [`kernels::vec_dot_arm`]
    /// — bit-identical across threads, shards, dispatch arms and
    /// batched-vs-solo decode, because encode and fused dot are
    /// themselves arm-stable and the reduction order is unchanged.
    ///
    /// Call **before** creating caches, pools or scratches (the scheme
    /// decides their layout; [`KvCache::grow_to`] rejects mismatched
    /// pools). Errors when the model's attention segment widths do not
    /// sit on the scheme's block grid — the fused score/value reads
    /// slice whole codec blocks per head segment — or when MLA
    /// absorption is disabled (the eager path re-expands from f32
    /// latents the quantized cache does not store).
    pub fn set_kv_scheme(&mut self, scheme: KvScheme) -> Result<()> {
        if scheme != KvScheme::F32 {
            let cfg = &self.cfg;
            let bw = scheme.format().block_weights();
            let check = |name: &str, dim: usize| -> Result<()> {
                if dim % bw != 0 {
                    bail!(
                        "kv scheme {scheme}: model {:?} has {name} = {dim}, not a multiple \
                         of the codec's {bw}-weight block — attention reads whole codec \
                         blocks per head segment, so this model cannot use {scheme} KV",
                        cfg.name
                    );
                }
                Ok(())
            };
            match cfg.kind {
                ModelKind::MlaMoe => {
                    if !self.absorb_mla {
                        bail!(
                            "kv scheme {scheme} needs absorbed MLA: the eager path \
                             re-expands every position from f32 latents the quantized \
                             cache does not keep (enable absorption or use f32 KV)"
                        );
                    }
                    check("kv_lora_rank", cfg.kv_lora_rank)?;
                    check("qk_rope_head_dim", cfg.qk_rope_head_dim)?;
                    check("qk_nope_head_dim", cfg.qk_nope_head_dim)?;
                    check("v_head_dim", cfg.v_head_dim)?;
                }
                ModelKind::DenseGqa => check("head_dim", cfg.head_dim)?,
            }
        }
        self.kv_scheme = scheme;
        Ok(())
    }

    /// The active KV cache encoding.
    pub fn kv_scheme(&self) -> KvScheme {
        self.kv_scheme
    }

    /// The dispatch arm quantized-KV reads run under: the pinned arm in
    /// [`MatvecMode::Pinned`], else the runtime-selected one — every
    /// arm produces identical bits, so this only matters for the
    /// arm-identity seam.
    fn kv_arm(&self) -> kernels::DispatchArm {
        match self.mode {
            MatvecMode::Pinned(arm) => arm,
            MatvecMode::Threads(_) => kernels::active_arm(),
        }
    }

    /// Partition this pass across `n` shard worker threads
    /// (expert-parallel MoE FFNs, output-row tensor-parallel matmuls —
    /// see [`crate::runtime::sharded`]); `n == 0` restores local
    /// execution. Logits are **bit-identical** for every shard count —
    /// the sharded-identity suite and `dsq selfcheck` pin it.
    ///
    /// Call **before** creating scratches: sharded MoE panels need the
    /// gather/output planes [`ForwardPass::new_scratch_cols`] only
    /// allocates when sharding is active.
    pub fn set_sharding(&mut self, n: usize) -> Result<()> {
        self.shards = match n {
            0 => None,
            n => Some(ShardRuntime::new(&self.ckpt, n)?),
        };
        Ok(())
    }

    /// Active shard count (0 when running locally).
    pub fn shard_count(&self) -> usize {
        self.shards.as_ref().map_or(0, |s| s.n_shards())
    }

    /// The shard runtime, when sharding is active — the seam the
    /// planner-validation tests and serving metrics read (per-shard
    /// resident bytes, exchange counters).
    pub fn shards(&self) -> Option<&ShardRuntime> {
        self.shards.as_ref()
    }

    /// Expanded-plane row width of the caches this pass creates (zero
    /// unless absorbed MLA is active).
    fn cache_xwidth(&self) -> usize {
        match self.cfg.kind {
            ModelKind::MlaMoe if self.absorb_mla => {
                self.cfg.n_heads * (self.cfg.qk_nope_head_dim + self.cfg.v_head_dim)
            }
            _ => 0,
        }
    }

    /// A fresh, empty per-slot cache bounded by this model's `max_ctx`.
    /// The backing buffer is allocated lazily on the first forwarded
    /// token, so idle batch slots stay (almost) free.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(
            self.cfg.n_layers,
            self.cfg.kv_cache_width(),
            self.cache_xwidth(),
            self.max_ctx,
            self.kv_scheme,
        )
    }

    /// A KV block pool sized for this model's cache shape: `capacity`
    /// blocks of `block_tokens` positions each (all layers, both
    /// planes). Create the pool *after* any
    /// [`ForwardPass::set_mla_absorption`] call — the flag decides the
    /// expanded-plane width the blocks carry, and
    /// [`KvCache::grow_to`] rejects mismatched pools.
    pub fn new_block_pool(&self, capacity: usize, block_tokens: usize) -> Result<KvBlockPool> {
        KvBlockPool::new(
            self.cfg.n_layers,
            self.cfg.kv_cache_width(),
            self.cache_xwidth(),
            self.kv_scheme,
            block_tokens,
            capacity,
        )
    }

    /// A fresh, empty **paged** per-slot cache allocating from `pool`
    /// (same logical layout and bits as [`ForwardPass::new_cache`];
    /// grow it with [`KvCache::grow_to`] before forwarding, recycle
    /// with [`KvCache::release`]).
    pub fn new_paged_cache(&self, pool: &KvBlockPool) -> Result<KvCache> {
        let (w, xw) = (self.cfg.kv_cache_width(), self.cache_xwidth());
        if !pool.matches(self.cfg.n_layers, w, xw, self.kv_scheme) {
            bail!(
                "paged cache shape ({} layers × width {w} / xwidth {xw}, kv scheme {}) \
                 does not match the block pool — was MLA absorption or the KV scheme \
                 toggled after the pool was created?",
                self.cfg.n_layers,
                self.kv_scheme
            );
        }
        Ok(KvCache::new_paged(
            self.cfg.n_layers,
            w,
            xw,
            self.max_ctx,
            self.kv_scheme,
            pool.block_tokens(),
        ))
    }

    /// A scratch sized for this model and context bound. One per slot
    /// (or per serving thread) is enough; [`ForwardPass::forward_token`]
    /// fully overwrites every buffer it reads.
    pub fn new_scratch(&self) -> Scratch {
        self.new_scratch_cols(0)
    }

    /// A scratch whose panels additionally fit `cols` batched-decode
    /// columns ([`ForwardPass::forward_step_batch`] needs one column
    /// per live slot, and a serving batch may exceed `max_ctx`). The
    /// panels are sized for `max(max_ctx, cols)` columns, so the same
    /// scratch still serves every prefill/token path.
    pub fn new_scratch_cols(&self, cols: usize) -> Scratch {
        let cfg = &self.cfg;
        let (q_len, heads_len, q_rank, kv_a_len, kvb_len) = match cfg.kind {
            ModelKind::MlaMoe => (
                cfg.n_heads * cfg.qk_head_dim(),
                cfg.n_heads * cfg.v_head_dim,
                cfg.q_lora_rank,
                cfg.kv_cache_width(),
                self.max_ctx * cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim),
            ),
            ModelKind::DenseGqa => {
                let hd = cfg.n_heads * cfg.head_dim;
                (hd, hd, 0, 0, 0)
            }
        };
        let inter_max = cfg
            .intermediate_size
            .max(cfg.moe_intermediate_size)
            .max(cfg.n_shared_experts * cfg.moe_intermediate_size);
        let mc = self.max_ctx.max(cols);
        let hs = cfg.hidden_size;
        // GQA projects V through its own panel; MLA leaves it empty.
        let vp_len = match cfg.kind {
            ModelKind::MlaMoe => 0,
            ModelKind::DenseGqa => cfg.n_kv_heads * cfg.head_dim,
        };
        // Widest batched-GEMM output this model produces (the `mat`
        // staging buffer holds one `[rows][T]` product at a time;
        // vocab_size covers the batched-decode unembedding panel).
        let max_rows = hs
            .max(q_len)
            .max(q_rank)
            .max(cfg.kv_cache_width())
            .max(inter_max)
            .max(cfg.n_routed_experts)
            .max(cfg.vocab_size);
        // The all-experts gather/output planes only exist on a sharded
        // pass (worst case every token's row appears in n_active expert
        // groups) — the unsharded zero-alloc decode path must not pay
        // for them.
        let exp_planes = if self.shards.is_some() { mc * cfg.n_active_experts * hs } else { 0 };
        // Quantized-KV staging/decode lines (padded to the scheme's
        // block grid, zero tails); absent under f32 so the default
        // scratch layout is unchanged.
        let (stage_len, xstage_len, vdec_len) = match self.kv_scheme {
            KvScheme::F32 => (0, 0, 0),
            s => (
                s.line_weights(cfg.kv_cache_width()),
                s.line_weights(self.cache_xwidth()),
                s.line_weights(cfg.kv_cache_width().max(self.cache_xwidth())),
            ),
        };
        Scratch {
            h: vec![0.0; hs],
            xn: vec![0.0; hs],
            delta: vec![0.0; hs],
            attn: AttnScratch {
                q: vec![0.0; q_len],
                q_a: vec![0.0; q_rank],
                q_an: vec![0.0; q_rank],
                kv_a: vec![0.0; kv_a_len],
                kvb: vec![0.0; kvb_len],
                heads_out: vec![0.0; heads_len],
                scores: vec![0.0; mc],
                kv_stage: vec![0.0; stage_len],
                xkv_stage: vec![0.0; xstage_len],
                vdec: vec![0.0; vdec_len],
            },
            ffn: FfnScratch {
                g: vec![0.0; inter_max],
                u: vec![0.0; inter_max],
                y: vec![0.0; hs],
                ye: vec![0.0; cfg.n_active_experts * hs],
                probs: vec![0.0; cfg.n_routed_experts],
                idx: Vec::with_capacity(cfg.n_routed_experts),
            },
            panel: PanelScratch {
                cap: mc,
                cols: Vec::with_capacity(mc),
                lp: vec![0.0; mc * cfg.vocab_size],
                h: vec![0.0; mc * hs],
                xn: vec![0.0; mc * hs],
                delta: vec![0.0; mc * hs],
                q: vec![0.0; mc * q_len],
                q_a: vec![0.0; mc * q_rank],
                q_an: vec![0.0; mc * q_rank],
                kv: vec![0.0; mc * cfg.kv_cache_width()],
                v: vec![0.0; mc * vp_len],
                heads_out: vec![0.0; mc * heads_len],
                scores: vec![0.0; mc],
                g: vec![0.0; mc * inter_max],
                u: vec![0.0; mc * inter_max],
                probs: vec![0.0; mc * cfg.n_routed_experts],
                sel: Vec::with_capacity(mc * cfg.n_active_experts),
                z: vec![0.0; mc],
                gather: Vec::with_capacity(mc),
                xg: vec![0.0; mc * hs],
                y: vec![0.0; mc * hs],
                xge: vec![0.0; exp_planes],
                ye: vec![0.0; exp_planes],
                exp_jobs: Vec::with_capacity(cfg.n_routed_experts),
                gat: Vec::with_capacity(mc * cfg.n_active_experts.max(1)),
                mat: vec![0.0; mc * max_rows],
                kv_stage: vec![0.0; stage_len],
                xkv_stage: vec![0.0; xstage_len],
                vdec: vec![0.0; vdec_len],
            },
        }
    }

    /// Quantized matvec `out[r] = row_r · x` on a resolved tensor.
    /// Sharded: one row-split job per shard (each computes its own
    /// disjoint row range of `out`, so no cross-shard sum ever forms),
    /// one barrier. Local: [`matvec_bytes_mode`] under the active mode.
    fn matvec(&self, t: &TensorEntry, x: &[f32], out: &mut [f32]) -> Result<()> {
        if let Some(sh) = &self.shards {
            return sh.matvec(t, x, out, self.mode);
        }
        matvec_bytes_mode(self.mode, t.format, self.ckpt.bytes(t), x, out)
    }

    /// Quantized GEMM over a token-major activation panel (`xs[c*n..]`
    /// is column `c`): the kernels fill the row-major `[rows][T]`
    /// staging buffer `mat` (the layout the row-parallel / row-sharded
    /// split needs), which is then transposed into the token-major
    /// `out` panel (`out[c*rows + r] = row_r · col_c`). The transpose
    /// is a pure permutation of finished f32 values, so every element
    /// is bit-identical to the single-column matvec — sharded (each
    /// shard stages its own row range, one barrier) or local alike.
    fn matvec_mat(
        &self,
        e: &TensorEntry,
        xs: &[f32],
        n: usize,
        t: usize,
        mat: &mut [f32],
        out: &mut [f32],
    ) -> Result<()> {
        if let Some(sh) = &self.shards {
            debug_assert_eq!(out.len() % t, 0);
            let rows = out.len() / t;
            let m = &mut mat[..rows * t];
            sh.matvec_mat(e, xs, n, t, m, self.mode)?;
            transpose_into(m, out, rows, t);
            return Ok(());
        }
        matvec_mat_bytes_mode(self.mode, e.format, self.ckpt.bytes(e), xs, n, t, mat, out)
    }

    /// The encoded rows of expert `e` inside a `[n_exp, out, in]`
    /// expert-stacked tensor.
    fn expert_bytes(&self, t: &TensorEntry, e: usize) -> Result<&[u8]> {
        let per = t.format.row_bytes(t.shape[2])? * t.shape[1];
        Ok(&self.ckpt.bytes(t)[e * per..(e + 1) * per])
    }

    /// Decode one embedding row (`token_embd.weight[t]`) into `h`.
    /// Out-of-range ids wrap into the vocabulary (padding slots send
    /// `PAD`, and sampled ids are always in range).
    fn embed(&self, tok: i32, h: &mut [f32]) -> Result<()> {
        let t = tok.rem_euclid(self.cfg.vocab_size as i32) as usize;
        let bytes = self.ckpt.bytes(&self.token_embd);
        let row = &bytes[t * self.embd_row_bytes..(t + 1) * self.embd_row_bytes];
        quant::dequantize_into(self.token_embd.format, row, h)
    }

    /// `down(silu(gate(x)) · up(x))` on resolved tensors, every
    /// projection through the sharding-aware [`ForwardPass::matvec`]
    /// (the SiLU gating runs on the driver either way);
    /// `g_buf`/`u_buf` are the scratch projections.
    #[allow(clippy::too_many_arguments)]
    fn mlp(
        &self,
        gate: &TensorEntry,
        up: &TensorEntry,
        down: &TensorEntry,
        inter: usize,
        x: &[f32],
        out: &mut [f32],
        g_buf: &mut [f32],
        u_buf: &mut [f32],
    ) -> Result<()> {
        let g = &mut g_buf[..inter];
        let u = &mut u_buf[..inter];
        self.matvec(gate, x, g)?;
        self.matvec(up, x, u)?;
        for (gv, &uv) in g.iter_mut().zip(&*u) {
            *gv = math::silu(*gv) * uv;
        }
        self.matvec(down, g, out)
    }

    /// Panel SwiGLU: [`ForwardPass::mlp`] over a `t`-column token-major
    /// panel, all three projections through the (sharding-aware)
    /// decode-once GEMM kernels — bit-identical per column to the
    /// single-token path.
    #[allow(clippy::too_many_arguments)]
    fn mlp_mat(
        &self,
        gate: &TensorEntry,
        up: &TensorEntry,
        down: &TensorEntry,
        inter: usize,
        xs: &[f32],
        n: usize,
        t: usize,
        mat: &mut [f32],
        g_buf: &mut [f32],
        u_buf: &mut [f32],
        out: &mut [f32],
    ) -> Result<()> {
        let g = &mut g_buf[..t * inter];
        let u = &mut u_buf[..t * inter];
        self.matvec_mat(gate, xs, n, t, mat, g)?;
        self.matvec_mat(up, xs, n, t, mat, u)?;
        for (gv, &uv) in g.iter_mut().zip(&*u) {
            *gv = math::silu(*gv) * uv;
        }
        self.matvec_mat(down, g, inter, t, mat, out)
    }

    /// Attention for one layer at `pos` (appends this token's K/V state
    /// to the cache row first), dispatched by architecture family.
    #[allow(clippy::too_many_arguments)]
    fn attention(
        &self,
        li: usize,
        lw: &LayerWeights,
        xn: &[f32],
        cache: &mut KvCache,
        pos: usize,
        out: &mut [f32],
        s: &mut AttnScratch,
    ) -> Result<()> {
        match &lw.attn {
            LayerAttn::Mla { q_a, q_a_norm, q_b, kv_a, kv_a_norm, kv_b } => self.attention_mla(
                li,
                (q_a, q_a_norm.as_slice(), q_b, kv_a, kv_a_norm.as_slice(), kv_b),
                &lw.attn_output,
                xn,
                cache,
                pos,
                out,
                s,
            ),
            LayerAttn::Gqa { q, k, v } => {
                self.attention_gqa(li, (q, k, v), &lw.attn_output, xn, cache, pos, out, s)
            }
        }
    }

    /// MLA attention over the compressed-latent cache. Absorbed mode
    /// (default) expands the new position's per-head keys/values once
    /// into the cache's expanded plane; eager mode re-expands every
    /// cached position per step through the same encoded `kv_b`
    /// matvec (bit-identical either way — see the module docs).
    #[allow(clippy::too_many_arguments, clippy::type_complexity)]
    fn attention_mla(
        &self,
        li: usize,
        (q_a_w, q_a_norm, q_b_w, kv_a_w, kv_a_norm, kv_b_w): (
            &TensorEntry,
            &[f32],
            &TensorEntry,
            &TensorEntry,
            &[f32],
            &TensorEntry,
        ),
        attn_output: &TensorEntry,
        xn: &[f32],
        cache: &mut KvCache,
        pos: usize,
        out: &mut [f32],
        s: &mut AttnScratch,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let (nope, vh) = (cfg.qk_nope_head_dim, cfg.v_head_dim);
        let qk_head = nope + cfg.qk_rope_head_dim;
        let kv_rank = cfg.kv_lora_rank;

        // Query path: hidden → q_lora_rank → heads·(nope+rope).
        let q_a = &mut s.q_a[..cfg.q_lora_rank];
        self.matvec(q_a_w, xn, q_a)?;
        let q_an = &mut s.q_an[..cfg.q_lora_rank];
        rms_norm(q_a, q_a_norm, q_an);
        let q = &mut s.q[..cfg.n_heads * qk_head];
        self.matvec(q_b_w, q_an, q)?;

        // KV path: hidden → (latent, rope key); the cache row stores the
        // RMS-normed latent and the post-RoPE shared key.
        let kv_a = &mut s.kv_a[..cfg.kv_cache_width()];
        self.matvec(kv_a_w, xn, kv_a)?;
        let ctx = pos + 1;
        let kvb_w = cfg.n_heads * (nope + vh);
        match cache.scheme() {
            KvScheme::F32 => {
                {
                    let row = cache.row_mut(li, pos);
                    rms_norm(&kv_a[..kv_rank], kv_a_norm, &mut row[..kv_rank]);
                    row[kv_rank..].copy_from_slice(&kv_a[kv_rank..]);
                    self.rope.apply(&mut row[kv_rank..], pos);
                }
                if self.absorb_mla {
                    // Absorbed: expand only the just-appended position
                    // into the cache's expanded-row plane — the same
                    // encoded kv_b matvec the eager path runs, so the
                    // bits are identical; older positions were expanded
                    // when *they* were appended.
                    let (row, xrow) = cache.row_and_xrow_mut(li, pos);
                    self.matvec(kv_b_w, &row[..kv_rank], xrow)?;
                } else {
                    // Eager reference: re-expand per-head k_nope/v for
                    // every cached position from the compressed latents.
                    let kvb = &mut s.kvb[..ctx * kvb_w];
                    for p in 0..ctx {
                        let latent = &cache.row(li, p)[..kv_rank];
                        // Split borrow: `kvb` rows are disjoint per position.
                        let dst = &mut kvb[p * kvb_w..(p + 1) * kvb_w];
                        self.matvec(kv_b_w, latent, dst)?;
                    }
                }
            }
            _ => {
                if !self.absorb_mla {
                    bail!(
                        "quantized KV requires absorbed MLA \
                         (ForwardPass::set_kv_scheme enforces this before caches exist)"
                    );
                }
                // Quantize-on-append: build the exact f32 row — and its
                // absorbed expansion, from the exact (pre-quantization)
                // latent — in the staging lines, then encode each once
                // into the cache's codec blocks.
                let w = cfg.kv_cache_width();
                let stage = &mut s.kv_stage;
                rms_norm(&kv_a[..kv_rank], kv_a_norm, &mut stage[..kv_rank]);
                stage[kv_rank..w].copy_from_slice(&kv_a[kv_rank..]);
                self.rope.apply(&mut stage[kv_rank..w], pos);
                let xstage = &mut s.xkv_stage;
                self.matvec(kv_b_w, &stage[..kv_rank], &mut xstage[..kvb_w])?;
                cache.write_row_enc(li, pos, stage)?;
                cache.write_xrow_enc(li, pos, xstage)?;
            }
        }

        let inv_scale = 1.0 / (qk_head as f32).sqrt();
        let heads_out = &mut s.heads_out[..cfg.n_heads * vh];
        heads_out.fill(0.0);
        let scores = &mut s.scores[..ctx];
        let cache = &*cache;
        if self.absorb_mla {
            // Scheme-generic absorbed path: under f32 the score/value
            // helpers read the planes with the exact historical
            // dot_lanes calls; under a quantized scheme they run the
            // fused vec_dot / block decode on the encoded rows — same
            // canonical reduction order either way.
            let arm = self.kv_arm();
            let vdec = &mut s.vdec;
            for hd in 0..cfg.n_heads {
                let qh = &mut q[hd * qk_head..(hd + 1) * qk_head];
                self.rope.apply(&mut qh[nope..], pos);
                for (p, sc) in scores.iter_mut().enumerate() {
                    let sv = cache.score_dot_x(li, p, hd * (nope + vh), &qh[..nope], arm)
                        + cache.score_dot(li, p, kv_rank, &qh[nope..], arm);
                    *sc = sv * inv_scale;
                }
                math::softmax_in_place(scores);
                let oh = &mut heads_out[hd * vh..(hd + 1) * vh];
                for (p, &w) in scores.iter().enumerate() {
                    let v =
                        cache.values_x(li, p, hd * (nope + vh) + nope, vh, &mut vdec[..], arm);
                    for (o, &vv) in oh.iter_mut().zip(v) {
                        *o += w * vv;
                    }
                }
            }
        } else {
            // Eager (f32-only): per-step re-expanded rows from scratch.
            let kvb = &s.kvb[..];
            let expanded = |p: usize| -> &[f32] { &kvb[p * kvb_w..(p + 1) * kvb_w] };
            for hd in 0..cfg.n_heads {
                let qh = &mut q[hd * qk_head..(hd + 1) * qk_head];
                self.rope.apply(&mut qh[nope..], pos);
                for (p, sc) in scores.iter_mut().enumerate() {
                    let k_nope = &expanded(p)[hd * (nope + vh)..][..nope];
                    let k_rope = &cache.row(li, p)[kv_rank..];
                    let sv = kernels::dot_lanes(&qh[..nope], k_nope)
                        + kernels::dot_lanes(&qh[nope..], k_rope);
                    *sc = sv * inv_scale;
                }
                math::softmax_in_place(scores);
                let oh = &mut heads_out[hd * vh..(hd + 1) * vh];
                for (p, &w) in scores.iter().enumerate() {
                    let v = &expanded(p)[hd * (nope + vh) + nope..][..vh];
                    for (o, &vv) in oh.iter_mut().zip(v) {
                        *o += w * vv;
                    }
                }
            }
        }
        self.matvec(attn_output, heads_out, out)
    }

    /// Grouped-query attention: conventional per-head K/V cache, query
    /// heads share each KV head in groups of `n_heads / n_kv_heads`.
    /// K and V project **straight into the cache row** (no staging
    /// buffer); RoPE rotates the full head dimension, Qwen2.5 style.
    #[allow(clippy::too_many_arguments)]
    fn attention_gqa(
        &self,
        li: usize,
        (q_w, k_w, v_w): (&TensorEntry, &TensorEntry, &TensorEntry),
        attn_output: &TensorEntry,
        xn: &[f32],
        cache: &mut KvCache,
        pos: usize,
        out: &mut [f32],
        s: &mut AttnScratch,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let hd = cfg.head_dim;
        let kd = cfg.n_kv_heads * hd;
        let group = cfg.n_heads / cfg.n_kv_heads;

        let q = &mut s.q[..cfg.n_heads * hd];
        self.matvec(q_w, xn, q)?;
        match cache.scheme() {
            KvScheme::F32 => {
                let row = cache.row_mut(li, pos);
                let (krow, vrow) = row.split_at_mut(kd);
                self.matvec(k_w, xn, krow)?;
                self.matvec(v_w, xn, vrow)?;
                for kh in 0..cfg.n_kv_heads {
                    self.rope.apply(&mut krow[kh * hd..(kh + 1) * hd], pos);
                }
            }
            _ => {
                // Quantize-on-append: project and rotate into the exact
                // f32 staging line, then encode the row's codec blocks
                // once (write-once, preallocated scratch — zero heap
                // allocations per token).
                let stage = &mut s.kv_stage;
                let (krow, vrow) = stage[..2 * kd].split_at_mut(kd);
                self.matvec(k_w, xn, krow)?;
                self.matvec(v_w, xn, vrow)?;
                for kh in 0..cfg.n_kv_heads {
                    self.rope.apply(&mut krow[kh * hd..(kh + 1) * hd], pos);
                }
                cache.write_row_enc(li, pos, stage)?;
            }
        }

        let ctx = pos + 1;
        let inv_scale = 1.0 / (hd as f32).sqrt();
        let heads_out = &mut s.heads_out[..cfg.n_heads * hd];
        heads_out.fill(0.0);
        let scores = &mut s.scores[..ctx];
        let cache = &*cache;
        let arm = self.kv_arm();
        let vdec = &mut s.vdec;
        for h in 0..cfg.n_heads {
            let qh = &mut q[h * hd..(h + 1) * hd];
            self.rope.apply(qh, pos);
            let kh = h / group;
            for (p, sc) in scores.iter_mut().enumerate() {
                *sc = cache.score_dot(li, p, kh * hd, qh, arm) * inv_scale;
            }
            math::softmax_in_place(scores);
            let oh = &mut heads_out[h * hd..(h + 1) * hd];
            for (p, &w) in scores.iter().enumerate() {
                let v = cache.values(li, p, kd + kh * hd, hd, &mut vdec[..], arm);
                for (o, &vv) in oh.iter_mut().zip(v) {
                    *o += w * vv;
                }
            }
        }
        self.matvec(attn_output, heads_out, out)
    }

    /// Panel attention for one layer over the tokens at positions
    /// `base..base + t` (projections batched through the GEMM kernels;
    /// cache writes, RoPE, scores and value sums per position),
    /// dispatched by architecture family. Reads `p.xn`, writes
    /// `p.delta`.
    fn attention_panel(
        &self,
        li: usize,
        lw: &LayerWeights,
        t: usize,
        base: usize,
        cache: &mut KvCache,
        p: &mut PanelScratch,
    ) -> Result<()> {
        match &lw.attn {
            LayerAttn::Mla { q_a, q_a_norm, q_b, kv_a, kv_a_norm, kv_b } => self
                .attention_mla_panel(
                    li,
                    (q_a, q_a_norm.as_slice(), q_b, kv_a, kv_a_norm.as_slice(), kv_b),
                    &lw.attn_output,
                    t,
                    base,
                    cache,
                    p,
                ),
            LayerAttn::Gqa { q, k, v } => {
                self.attention_gqa_panel(li, (q, k, v), &lw.attn_output, t, base, cache, p)
            }
        }
    }

    /// Panel MLA attention (absorbed caches only — the eager mode
    /// falls back to the token loop in
    /// [`ForwardPass::forward_tokens`]). Per token the score/value
    /// loops are exactly [`ForwardPass::attention_mla`]'s; the
    /// projections are its matvecs as GEMM columns.
    #[allow(clippy::too_many_arguments, clippy::type_complexity)]
    fn attention_mla_panel(
        &self,
        li: usize,
        (q_a_w, q_a_norm, q_b_w, kv_a_w, kv_a_norm, kv_b_w): (
            &TensorEntry,
            &[f32],
            &TensorEntry,
            &TensorEntry,
            &[f32],
            &TensorEntry,
        ),
        attn_output: &TensorEntry,
        t: usize,
        base: usize,
        cache: &mut KvCache,
        p: &mut PanelScratch,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let hs = cfg.hidden_size;
        let (nope, vh) = (cfg.qk_nope_head_dim, cfg.v_head_dim);
        let qk_head = nope + cfg.qk_rope_head_dim;
        let (q_rank, kv_rank) = (cfg.q_lora_rank, cfg.kv_lora_rank);
        let kv_w = cfg.kv_cache_width();
        let q_len = cfg.n_heads * qk_head;
        let ho_w = cfg.n_heads * vh;

        // Query path, batched: hidden → q_rank → heads·(nope+rope).
        let xs = &p.xn[..t * hs];
        self.matvec_mat(q_a_w, xs, hs, t, &mut p.mat, &mut p.q_a[..t * q_rank])?;
        for j in 0..t {
            let (a, b) = (j * q_rank, (j + 1) * q_rank);
            rms_norm(&p.q_a[a..b], q_a_norm, &mut p.q_an[a..b]);
        }
        let q_an = &p.q_an[..t * q_rank];
        self.matvec_mat(q_b_w, q_an, q_rank, t, &mut p.mat, &mut p.q[..t * q_len])?;

        // KV path, batched; per position: the cache-row write (normed
        // latent + post-RoPE shared key) and the absorbed expansion.
        self.matvec_mat(kv_a_w, xs, hs, t, &mut p.mat, &mut p.kv[..t * kv_w])?;
        let xw = cfg.n_heads * (nope + vh);
        for j in 0..t {
            let pos = base + j;
            let kv_a = &p.kv[j * kv_w..(j + 1) * kv_w];
            match cache.scheme() {
                KvScheme::F32 => {
                    {
                        let row = cache.row_mut(li, pos);
                        rms_norm(&kv_a[..kv_rank], kv_a_norm, &mut row[..kv_rank]);
                        row[kv_rank..].copy_from_slice(&kv_a[kv_rank..]);
                        self.rope.apply(&mut row[kv_rank..], pos);
                    }
                    let (row, xrow) = cache.row_and_xrow_mut(li, pos);
                    self.matvec(kv_b_w, &row[..kv_rank], xrow)?;
                }
                _ => {
                    // Same quantize-on-append staging as the token loop:
                    // exact f32 row + expansion from the exact latent,
                    // one codec-block encode per plane.
                    let stage = &mut p.kv_stage;
                    rms_norm(&kv_a[..kv_rank], kv_a_norm, &mut stage[..kv_rank]);
                    stage[kv_rank..kv_w].copy_from_slice(&kv_a[kv_rank..]);
                    self.rope.apply(&mut stage[kv_rank..kv_w], pos);
                    let xstage = &mut p.xkv_stage;
                    self.matvec(kv_b_w, &stage[..kv_rank], &mut xstage[..xw])?;
                    cache.write_row_enc(li, pos, stage)?;
                    cache.write_xrow_enc(li, pos, xstage)?;
                }
            }
        }

        let inv_scale = 1.0 / (qk_head as f32).sqrt();
        let arm = self.kv_arm();
        p.heads_out[..t * ho_w].fill(0.0);
        for j in 0..t {
            let pos = base + j;
            let scores = &mut p.scores[..pos + 1];
            let q = &mut p.q[j * q_len..(j + 1) * q_len];
            let heads_out = &mut p.heads_out[j * ho_w..(j + 1) * ho_w];
            for hd in 0..cfg.n_heads {
                let qh = &mut q[hd * qk_head..(hd + 1) * qk_head];
                self.rope.apply(&mut qh[nope..], pos);
                for (pp, sc) in scores.iter_mut().enumerate() {
                    let sv = cache.score_dot_x(li, pp, hd * (nope + vh), &qh[..nope], arm)
                        + cache.score_dot(li, pp, kv_rank, &qh[nope..], arm);
                    *sc = sv * inv_scale;
                }
                math::softmax_in_place(scores);
                let oh = &mut heads_out[hd * vh..(hd + 1) * vh];
                for (pp, &w) in scores.iter().enumerate() {
                    let v = cache.values_x(
                        li,
                        pp,
                        hd * (nope + vh) + nope,
                        vh,
                        &mut p.vdec[..],
                        arm,
                    );
                    for (o, &vv) in oh.iter_mut().zip(v) {
                        *o += w * vv;
                    }
                }
            }
        }
        let ho = &p.heads_out[..t * ho_w];
        self.matvec_mat(attn_output, ho, ho_w, t, &mut p.mat, &mut p.delta[..t * hs])
    }

    /// Panel GQA attention: per token the score/value loops are
    /// exactly [`ForwardPass::attention_gqa`]'s; the Q/K/V and output
    /// projections run as GEMM panels, K/V copied into the cache rows
    /// before RoPE.
    #[allow(clippy::too_many_arguments)]
    fn attention_gqa_panel(
        &self,
        li: usize,
        (q_w, k_w, v_w): (&TensorEntry, &TensorEntry, &TensorEntry),
        attn_output: &TensorEntry,
        t: usize,
        base: usize,
        cache: &mut KvCache,
        p: &mut PanelScratch,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let hs = cfg.hidden_size;
        let hd = cfg.head_dim;
        let kd = cfg.n_kv_heads * hd;
        let group = cfg.n_heads / cfg.n_kv_heads;
        let q_len = cfg.n_heads * hd;

        let xs = &p.xn[..t * hs];
        self.matvec_mat(q_w, xs, hs, t, &mut p.mat, &mut p.q[..t * q_len])?;
        self.matvec_mat(k_w, xs, hs, t, &mut p.mat, &mut p.kv[..t * kd])?;
        self.matvec_mat(v_w, xs, hs, t, &mut p.mat, &mut p.v[..t * kd])?;
        for j in 0..t {
            let pos = base + j;
            match cache.scheme() {
                KvScheme::F32 => {
                    let row = cache.row_mut(li, pos);
                    let (krow, vrow) = row.split_at_mut(kd);
                    krow.copy_from_slice(&p.kv[j * kd..(j + 1) * kd]);
                    vrow.copy_from_slice(&p.v[j * kd..(j + 1) * kd]);
                    for kh in 0..cfg.n_kv_heads {
                        self.rope.apply(&mut krow[kh * hd..(kh + 1) * hd], pos);
                    }
                }
                _ => {
                    // Quantize-on-append via the exact f32 staging line
                    // (same rotation, then one codec-block encode).
                    let stage = &mut p.kv_stage;
                    let (krow, vrow) = stage[..2 * kd].split_at_mut(kd);
                    krow.copy_from_slice(&p.kv[j * kd..(j + 1) * kd]);
                    vrow.copy_from_slice(&p.v[j * kd..(j + 1) * kd]);
                    for kh in 0..cfg.n_kv_heads {
                        self.rope.apply(&mut krow[kh * hd..(kh + 1) * hd], pos);
                    }
                    cache.write_row_enc(li, pos, stage)?;
                }
            }
        }

        let inv_scale = 1.0 / (hd as f32).sqrt();
        let arm = self.kv_arm();
        p.heads_out[..t * q_len].fill(0.0);
        for j in 0..t {
            let pos = base + j;
            let scores = &mut p.scores[..pos + 1];
            let q = &mut p.q[j * q_len..(j + 1) * q_len];
            let heads_out = &mut p.heads_out[j * q_len..(j + 1) * q_len];
            for h in 0..cfg.n_heads {
                let qh = &mut q[h * hd..(h + 1) * hd];
                self.rope.apply(qh, pos);
                let kh = h / group;
                for (pp, sc) in scores.iter_mut().enumerate() {
                    *sc = cache.score_dot(li, pp, kh * hd, qh, arm) * inv_scale;
                }
                math::softmax_in_place(scores);
                let oh = &mut heads_out[h * hd..(h + 1) * hd];
                for (pp, &w) in scores.iter().enumerate() {
                    let v = cache.values(li, pp, kd + kh * hd, hd, &mut p.vdec[..], arm);
                    for (o, &vv) in oh.iter_mut().zip(v) {
                        *o += w * vv;
                    }
                }
            }
        }
        let ho = &p.heads_out[..t * q_len];
        self.matvec_mat(attn_output, ho, q_len, t, &mut p.mat, &mut p.delta[..t * hs])
    }

    /// FFN for one layer: dense SwiGLU, or router → top-k routed
    /// experts + shared expert. The combine order is fixed (shared
    /// expert first, then selected experts in ascending index), so the
    /// output is a pure function of the inputs.
    fn ffn(
        &self,
        lw: &LayerWeights,
        xn: &[f32],
        out: &mut [f32],
        s: &mut FfnScratch,
    ) -> Result<()> {
        let cfg = &self.cfg;
        match &lw.ffn {
            LayerFfn::Dense { gate, up, down } => {
                self.mlp(gate, up, down, cfg.intermediate_size, xn, out, &mut s.g, &mut s.u)
            }
            LayerFfn::Moe {
                router,
                gate_exps,
                up_exps,
                down_exps,
                gate_shexp,
                up_shexp,
                down_shexp,
            } => {
                let ne = cfg.n_routed_experts;
                let probs = &mut s.probs[..ne];
                self.matvec(router, xn, probs)?;
                math::softmax_in_place(probs);
                // Top-k selection: highest probability first, ties to
                // the lower expert index; combined in ascending index.
                // (Keys are distinct — probability ties break on the
                // unique index — so the unstable sort is deterministic.)
                s.idx.clear();
                s.idx.extend(0..ne);
                s.idx.sort_unstable_by(|&a, &b| {
                    probs[b].partial_cmp(&probs[a]).expect("softmax is NaN-free").then(a.cmp(&b))
                });
                s.idx.truncate(cfg.n_active_experts);
                s.idx.sort_unstable();
                let mut z = 0f32;
                for &e in &s.idx {
                    z += probs[e];
                }
                // Shared expert contributes with weight 1.
                let sh_inter = cfg.n_shared_experts * cfg.moe_intermediate_size;
                self.mlp(gate_shexp, up_shexp, down_shexp, sh_inter, xn, out, &mut s.g, &mut s.u)?;
                if let Some(sh) = &self.shards {
                    // Expert-parallel: every selected expert's MLP runs
                    // whole on its owner shard (concurrently, one
                    // barrier); the driver then combines in ascending
                    // expert order — exactly the local loop's order.
                    let hs = cfg.hidden_size;
                    let ye = &mut s.ye[..s.idx.len() * hs];
                    sh.moe_token(
                        gate_exps,
                        up_exps,
                        down_exps,
                        &s.idx,
                        xn,
                        ye,
                        cfg.moe_intermediate_size,
                        hs,
                        self.mode,
                    )?;
                    for (k, &e) in s.idx.iter().enumerate() {
                        let w = probs[e] / z;
                        let y = &ye[k * hs..(k + 1) * hs];
                        for (o, &yv) in out.iter_mut().zip(y) {
                            *o += w * yv;
                        }
                    }
                    return Ok(());
                }
                let y = &mut s.y[..cfg.hidden_size];
                for &e in &s.idx {
                    let w = probs[e] / z;
                    mlp_bytes_mode(
                        self.mode,
                        (gate_exps.format, self.expert_bytes(gate_exps, e)?),
                        (up_exps.format, self.expert_bytes(up_exps, e)?),
                        (down_exps.format, self.expert_bytes(down_exps, e)?),
                        cfg.moe_intermediate_size,
                        xn,
                        y,
                        &mut s.g,
                        &mut s.u,
                    )?;
                    for (o, &yv) in out.iter_mut().zip(&*y) {
                        *o += w * yv;
                    }
                }
                Ok(())
            }
        }
    }

    /// Panel FFN over `t` tokens: dense SwiGLU batched across the
    /// panel; MoE routes per token, then batches each routed expert
    /// over the tokens that selected it (gather → expert GEMM →
    /// weighted scatter, experts ascending — exactly each token's own
    /// combine order). Reads `p.xn`, writes `p.delta`; `s` lends the
    /// top-k index scratch.
    fn ffn_panel(
        &self,
        lw: &LayerWeights,
        t: usize,
        s: &mut FfnScratch,
        p: &mut PanelScratch,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let hs = cfg.hidden_size;
        match &lw.ffn {
            LayerFfn::Dense { gate, up, down } => self.mlp_mat(
                gate,
                up,
                down,
                cfg.intermediate_size,
                &p.xn[..t * hs],
                hs,
                t,
                &mut p.mat,
                &mut p.g,
                &mut p.u,
                &mut p.delta[..t * hs],
            ),
            LayerFfn::Moe {
                router,
                gate_exps,
                up_exps,
                down_exps,
                gate_shexp,
                up_shexp,
                down_shexp,
            } => {
                let ne = cfg.n_routed_experts;
                let na = cfg.n_active_experts;
                let xs = &p.xn[..t * hs];
                self.matvec_mat(router, xs, hs, t, &mut p.mat, &mut p.probs[..t * ne])?;
                p.sel.clear();
                for j in 0..t {
                    let probs = &mut p.probs[j * ne..(j + 1) * ne];
                    math::softmax_in_place(probs);
                    // Same top-k rule as the per-token path: highest
                    // probability first, ties to the lower index.
                    s.idx.clear();
                    s.idx.extend(0..ne);
                    s.idx.sort_unstable_by(|&a, &b| {
                        probs[b]
                            .partial_cmp(&probs[a])
                            .expect("softmax is NaN-free")
                            .then(a.cmp(&b))
                    });
                    s.idx.truncate(na);
                    s.idx.sort_unstable();
                    let mut z = 0f32;
                    for &e in &s.idx {
                        z += probs[e];
                    }
                    p.z[j] = z;
                    p.sel.extend_from_slice(&s.idx);
                }
                // Shared expert (weight 1) over the whole panel.
                let sh_inter = cfg.n_shared_experts * cfg.moe_intermediate_size;
                self.mlp_mat(
                    gate_shexp,
                    up_shexp,
                    down_shexp,
                    sh_inter,
                    xs,
                    hs,
                    t,
                    &mut p.mat,
                    &mut p.g,
                    &mut p.u,
                    &mut p.delta[..t * hs],
                )?;
                if let Some(sh) = &self.shards {
                    // Expert-parallel panel: gather every expert's
                    // token group up front, dispatch all groups to
                    // their owner shards at once (one barrier), then
                    // scatter in ascending expert order — the same
                    // combine order as the local loop below.
                    p.exp_jobs.clear();
                    p.gat.clear();
                    let mut cursor = 0usize;
                    for e in 0..ne {
                        let start = p.gat.len();
                        for j in 0..t {
                            if p.sel[j * na..(j + 1) * na].contains(&e) {
                                p.gat.push(j);
                            }
                        }
                        let gt = p.gat.len() - start;
                        if gt == 0 {
                            continue;
                        }
                        if (cursor + gt) * hs > p.xge.len() {
                            bail!(
                                "sharded MoE panel: the scratch's gather plane is too small \
                                 — create scratches after ForwardPass::set_sharding"
                            );
                        }
                        for gi in 0..gt {
                            let j = p.gat[start + gi];
                            let (a, b) = ((cursor + gi) * hs, (cursor + gi + 1) * hs);
                            p.xge[a..b].copy_from_slice(&p.xn[j * hs..(j + 1) * hs]);
                        }
                        p.exp_jobs.push((e, cursor, gt));
                        cursor += gt;
                    }
                    sh.moe_panel(
                        gate_exps,
                        up_exps,
                        down_exps,
                        &p.exp_jobs,
                        &p.xge[..cursor * hs],
                        &mut p.ye[..cursor * hs],
                        cfg.moe_intermediate_size,
                        hs,
                        hs,
                        self.mode,
                    )?;
                    for &(e, off, gt) in &p.exp_jobs {
                        for gi in 0..gt {
                            let j = p.gat[off + gi];
                            let w = p.probs[j * ne + e] / p.z[j];
                            let y = &p.ye[(off + gi) * hs..(off + gi + 1) * hs];
                            let out = &mut p.delta[j * hs..(j + 1) * hs];
                            for (o, &yv) in out.iter_mut().zip(y) {
                                *o += w * yv;
                            }
                        }
                    }
                    return Ok(());
                }
                // Routed experts, ascending: gather the tokens that
                // selected each expert, run one panel mlp, scatter the
                // weighted outputs back.
                for e in 0..ne {
                    p.gather.clear();
                    for j in 0..t {
                        if p.sel[j * na..(j + 1) * na].contains(&e) {
                            p.gather.push(j);
                        }
                    }
                    if p.gather.is_empty() {
                        continue;
                    }
                    let gt = p.gather.len();
                    for (gi, &j) in p.gather.iter().enumerate() {
                        let (a, b) = (gi * hs, (gi + 1) * hs);
                        p.xg[a..b].copy_from_slice(&p.xn[j * hs..(j + 1) * hs]);
                    }
                    mlp_mat_bytes_mode(
                        self.mode,
                        (gate_exps.format, self.expert_bytes(gate_exps, e)?),
                        (up_exps.format, self.expert_bytes(up_exps, e)?),
                        (down_exps.format, self.expert_bytes(down_exps, e)?),
                        cfg.moe_intermediate_size,
                        &p.xg[..gt * hs],
                        hs,
                        gt,
                        &mut p.mat,
                        &mut p.g,
                        &mut p.u,
                        &mut p.y[..gt * hs],
                    )?;
                    for (gi, &j) in p.gather.iter().enumerate() {
                        let w = p.probs[j * ne + e] / p.z[j];
                        let y = &p.y[gi * hs..(gi + 1) * hs];
                        let out = &mut p.delta[j * hs..(j + 1) * hs];
                        for (o, &yv) in out.iter_mut().zip(y) {
                            *o += w * yv;
                        }
                    }
                }
                Ok(())
            }
        }
    }

    /// Run a whole prompt through the stack in one panel pass: every
    /// projection and FFN matvec is batched across the token dimension
    /// through the decode-once GEMM kernels, while RMSNorm, RoPE,
    /// attention and routing stay per-position. The KV cache is filled
    /// for all `toks.len()` positions; `logits`, when given, receives
    /// the unembedding of the **last** token.
    ///
    /// Bit-identity: layer `l` processes every token before layer
    /// `l + 1`, but each per-token value is produced by exactly the
    /// per-token code (or a GEMM column bit-identical to it by the
    /// `vec_dot_mat` contract), and attention for token `j` only reads
    /// cache rows already written from those same values — so cache
    /// and logits match looping [`ForwardPass::forward_token`]
    /// bit-for-bit. Eager-MLA mode (`set_mla_absorption(false)`) falls
    /// back to that loop outright — it exists as the equivalence seam,
    /// not as a serving path.
    pub fn forward_tokens(
        &self,
        toks: &[i32],
        cache: &mut KvCache,
        scratch: &mut Scratch,
        logits: Option<&mut [f32]>,
    ) -> Result<()> {
        let t = toks.len();
        let base = cache.len;
        if t == 0 {
            if logits.is_some() {
                bail!("forward_tokens: logits requested for an empty token run");
            }
            return Ok(());
        }
        if base + t > cache.max_ctx {
            bail!(
                "KV cache full: {t} tokens at position {base} exceed the engine's \
                 configured max context {}",
                cache.max_ctx
            );
        }
        if let Some(out) = &logits {
            if out.len() != self.cfg.vocab_size {
                bail!("logits buffer {} != vocab {}", out.len(), self.cfg.vocab_size);
            }
        }
        let eager_mla = matches!(self.cfg.kind, ModelKind::MlaMoe) && !self.absorb_mla;
        if t == 1 || eager_mla {
            let mut logits = logits;
            for (j, &tok) in toks.iter().enumerate() {
                let want = if j + 1 == t { logits.take() } else { None };
                self.forward_token(tok, cache, scratch, want)?;
            }
            return Ok(());
        }
        cache.prepare_append(base + t)?;
        let hs = self.cfg.hidden_size;
        let Scratch { xn, ffn, panel: p, .. } = scratch;
        for (j, &tok) in toks.iter().enumerate() {
            self.embed(tok, &mut p.h[j * hs..(j + 1) * hs])?;
        }
        for (li, lw) in self.layers.iter().enumerate() {
            for j in 0..t {
                let (a, b) = (j * hs, (j + 1) * hs);
                rms_norm(&p.h[a..b], &lw.attn_norm, &mut p.xn[a..b]);
            }
            self.attention_panel(li, lw, t, base, cache, p)?;
            for (hv, &dv) in p.h[..t * hs].iter_mut().zip(&p.delta[..t * hs]) {
                *hv += dv;
            }
            for j in 0..t {
                let (a, b) = (j * hs, (j + 1) * hs);
                rms_norm(&p.h[a..b], &lw.ffn_norm, &mut p.xn[a..b]);
            }
            self.ffn_panel(lw, t, ffn, p)?;
            for (hv, &dv) in p.h[..t * hs].iter_mut().zip(&p.delta[..t * hs]) {
                *hv += dv;
            }
        }
        cache.len = base + t;
        if let Some(out) = logits {
            rms_norm(&p.h[(t - 1) * hs..t * hs], &self.output_norm, xn);
            self.matvec(&self.output, xn, out)?;
        }
        Ok(())
    }

    /// Run one token through the full stack at the cache's next
    /// position. When `logits` is given it receives the vocab-wide
    /// unembedding of the final hidden state (`logits.len() == vocab`);
    /// prefill steps that only need to advance the cache pass `None`
    /// and skip the vocab matvec.
    ///
    /// All intermediates live in the caller's `scratch`
    /// ([`ForwardPass::new_scratch`]); after the cache's first token has
    /// forced its lazy allocation, this function performs **no heap
    /// allocation** on the success path.
    pub fn forward_token(
        &self,
        tok: i32,
        cache: &mut KvCache,
        scratch: &mut Scratch,
        logits: Option<&mut [f32]>,
    ) -> Result<()> {
        let pos = cache.len;
        if pos >= cache.max_ctx {
            bail!(
                "KV cache full: token at position {pos} exceeds the engine's configured \
                 max context {}",
                cache.max_ctx
            );
        }
        if let Some(out) = &logits {
            if out.len() != self.cfg.vocab_size {
                bail!("logits buffer {} != vocab {}", out.len(), self.cfg.vocab_size);
            }
        }
        cache.prepare_append(pos + 1)?;
        let Scratch { h, xn, delta, attn, ffn, .. } = scratch;
        self.embed(tok, h)?;
        for (li, lw) in self.layers.iter().enumerate() {
            rms_norm(h, &lw.attn_norm, xn);
            self.attention(li, lw, xn, cache, pos, delta, attn)?;
            for (hv, &dv) in h.iter_mut().zip(&*delta) {
                *hv += dv;
            }
            rms_norm(h, &lw.ffn_norm, xn);
            self.ffn(lw, xn, delta, ffn)?;
            for (hv, &dv) in h.iter_mut().zip(&*delta) {
                *hv += dv;
            }
        }
        cache.len = pos + 1;
        if let Some(out) = logits {
            rms_norm(h, &self.output_norm, xn);
            self.matvec(&self.output, xn, out)?;
        }
        Ok(())
    }

    /// One decode step for a whole batch of independent slots as a
    /// single GEMM panel — the continuous-batching hot path. Each live
    /// slot `i` (`live[i]`) forwards `toks[i]` at its own cache's next
    /// position; its logits land in `logits[i*vocab..]` (dead slots'
    /// rows are zeroed).
    ///
    /// Every projection, FFN and the unembedding batch the live slots'
    /// activations through the decode-once `vec_dot_mat` kernels (one
    /// quantized-tile decode per step instead of one per slot), while
    /// the per-slot cache writes, RoPE, attention scores/softmax and
    /// value sums run per column against that column's own cache —
    /// exactly [`ForwardPass::forward_token`]'s loops. By the GEMM
    /// contract every column's bits equal the single-column matvec, so
    /// **each slot's logits are bit-identical to running it alone**,
    /// regardless of which other slots share the step (the
    /// `tests/continuous_batching.rs` differential gate). With one live
    /// slot (or eager MLA) this *is* the per-token path.
    ///
    /// All live slots are validated up front (context bound, paged
    /// capacity), so an error leaves every cache unchanged.
    pub fn forward_step_batch(
        &self,
        toks: &[i32],
        live: &[bool],
        caches: &mut [KvCache],
        scratch: &mut Scratch,
        logits: &mut [f32],
    ) -> Result<()> {
        let n_slots = caches.len();
        if toks.len() != n_slots || live.len() != n_slots {
            bail!(
                "forward_step_batch: {} tokens / {} live flags for {n_slots} caches",
                toks.len(),
                live.len()
            );
        }
        let v = self.cfg.vocab_size;
        if logits.len() != n_slots * v {
            bail!(
                "forward_step_batch: logits buffer {} != {n_slots} slots × vocab {v}",
                logits.len()
            );
        }
        for (i, cache) in caches.iter_mut().enumerate() {
            if !live[i] {
                continue;
            }
            let pos = cache.len;
            if pos >= cache.max_ctx {
                bail!(
                    "KV cache full: slot {i} token at position {pos} exceeds the \
                     engine's configured max context {}",
                    cache.max_ctx
                );
            }
            cache.prepare_append(pos + 1)?;
        }
        logits.fill(0.0);
        let t = live.iter().filter(|&&l| l).count();
        if t == 0 {
            return Ok(());
        }
        let eager_mla = matches!(self.cfg.kind, ModelKind::MlaMoe) && !self.absorb_mla;
        if t == 1 || eager_mla {
            for i in 0..n_slots {
                if !live[i] {
                    continue;
                }
                let row = &mut logits[i * v..(i + 1) * v];
                self.forward_token(toks[i], &mut caches[i], scratch, Some(row))?;
            }
            return Ok(());
        }
        let hs = self.cfg.hidden_size;
        let Scratch { ffn, panel: p, .. } = scratch;
        if t > p.cap {
            bail!(
                "forward_step_batch: {t} live slots exceed the scratch's {}-column \
                 panel capacity (create it with ForwardPass::new_scratch_cols)",
                p.cap
            );
        }
        p.cols.clear();
        for (i, &l) in live.iter().enumerate() {
            if l {
                p.cols.push(i);
            }
        }
        for (c, &slot) in p.cols.iter().enumerate() {
            self.embed(toks[slot], &mut p.h[c * hs..(c + 1) * hs])?;
        }
        for (li, lw) in self.layers.iter().enumerate() {
            for c in 0..t {
                let (a, b) = (c * hs, (c + 1) * hs);
                rms_norm(&p.h[a..b], &lw.attn_norm, &mut p.xn[a..b]);
            }
            self.attention_step(li, lw, t, caches, p)?;
            for (hv, &dv) in p.h[..t * hs].iter_mut().zip(&p.delta[..t * hs]) {
                *hv += dv;
            }
            for c in 0..t {
                let (a, b) = (c * hs, (c + 1) * hs);
                rms_norm(&p.h[a..b], &lw.ffn_norm, &mut p.xn[a..b]);
            }
            self.ffn_panel(lw, t, ffn, p)?;
            for (hv, &dv) in p.h[..t * hs].iter_mut().zip(&p.delta[..t * hs]) {
                *hv += dv;
            }
        }
        for &slot in &p.cols {
            caches[slot].len += 1;
        }
        // Batched unembedding, then a pure scatter of finished f32
        // values back to slot-major logits rows (bit-preserving).
        for c in 0..t {
            let (a, b) = (c * hs, (c + 1) * hs);
            rms_norm(&p.h[a..b], &self.output_norm, &mut p.xn[a..b]);
        }
        self.matvec_mat(&self.output, &p.xn[..t * hs], hs, t, &mut p.mat, &mut p.lp[..t * v])?;
        for (c, &slot) in p.cols.iter().enumerate() {
            logits[slot * v..(slot + 1) * v].copy_from_slice(&p.lp[c * v..(c + 1) * v]);
        }
        Ok(())
    }

    /// Batched-decode attention for one layer: each column `c` attends
    /// over its own slot's cache (`caches[p.cols[c]]`) at that cache's
    /// next position, dispatched by architecture family. Reads `p.xn`,
    /// writes `p.delta`.
    fn attention_step(
        &self,
        li: usize,
        lw: &LayerWeights,
        t: usize,
        caches: &mut [KvCache],
        p: &mut PanelScratch,
    ) -> Result<()> {
        match &lw.attn {
            LayerAttn::Mla { q_a, q_a_norm, q_b, kv_a, kv_a_norm, kv_b } => self
                .attention_mla_step(
                    li,
                    (q_a, q_a_norm.as_slice(), q_b, kv_a, kv_a_norm.as_slice(), kv_b),
                    &lw.attn_output,
                    t,
                    caches,
                    p,
                ),
            LayerAttn::Gqa { q, k, v } => {
                self.attention_gqa_step(li, (q, k, v), &lw.attn_output, t, caches, p)
            }
        }
    }

    /// Batched-decode MLA attention (absorbed caches only — eager mode
    /// falls back to the token loop in
    /// [`ForwardPass::forward_step_batch`]). Identical to
    /// [`ForwardPass::attention_mla_panel`] except each column targets
    /// its own cache at its own position instead of consecutive
    /// positions of one cache; per column the cache write, RoPE and
    /// score/value loops are exactly [`ForwardPass::attention_mla`]'s.
    #[allow(clippy::too_many_arguments, clippy::type_complexity)]
    fn attention_mla_step(
        &self,
        li: usize,
        (q_a_w, q_a_norm, q_b_w, kv_a_w, kv_a_norm, kv_b_w): (
            &TensorEntry,
            &[f32],
            &TensorEntry,
            &TensorEntry,
            &[f32],
            &TensorEntry,
        ),
        attn_output: &TensorEntry,
        t: usize,
        caches: &mut [KvCache],
        p: &mut PanelScratch,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let hs = cfg.hidden_size;
        let (nope, vh) = (cfg.qk_nope_head_dim, cfg.v_head_dim);
        let qk_head = nope + cfg.qk_rope_head_dim;
        let (q_rank, kv_rank) = (cfg.q_lora_rank, cfg.kv_lora_rank);
        let kv_w = cfg.kv_cache_width();
        let q_len = cfg.n_heads * qk_head;
        let ho_w = cfg.n_heads * vh;

        let xs = &p.xn[..t * hs];
        self.matvec_mat(q_a_w, xs, hs, t, &mut p.mat, &mut p.q_a[..t * q_rank])?;
        for c in 0..t {
            let (a, b) = (c * q_rank, (c + 1) * q_rank);
            rms_norm(&p.q_a[a..b], q_a_norm, &mut p.q_an[a..b]);
        }
        let q_an = &p.q_an[..t * q_rank];
        self.matvec_mat(q_b_w, q_an, q_rank, t, &mut p.mat, &mut p.q[..t * q_len])?;

        self.matvec_mat(kv_a_w, xs, hs, t, &mut p.mat, &mut p.kv[..t * kv_w])?;
        let xw = cfg.n_heads * (nope + vh);
        for c in 0..t {
            let cache = &mut caches[p.cols[c]];
            let pos = cache.len;
            let kv_a = &p.kv[c * kv_w..(c + 1) * kv_w];
            match cache.scheme() {
                KvScheme::F32 => {
                    {
                        let row = cache.row_mut(li, pos);
                        rms_norm(&kv_a[..kv_rank], kv_a_norm, &mut row[..kv_rank]);
                        row[kv_rank..].copy_from_slice(&kv_a[kv_rank..]);
                        self.rope.apply(&mut row[kv_rank..], pos);
                    }
                    let (row, xrow) = cache.row_and_xrow_mut(li, pos);
                    self.matvec(kv_b_w, &row[..kv_rank], xrow)?;
                }
                _ => {
                    // Quantize-on-append via the staging lines — see
                    // attention_mla; per column the bits are identical
                    // to the solo decode path.
                    let stage = &mut p.kv_stage;
                    rms_norm(&kv_a[..kv_rank], kv_a_norm, &mut stage[..kv_rank]);
                    stage[kv_rank..kv_w].copy_from_slice(&kv_a[kv_rank..]);
                    self.rope.apply(&mut stage[kv_rank..kv_w], pos);
                    let xstage = &mut p.xkv_stage;
                    self.matvec(kv_b_w, &stage[..kv_rank], &mut xstage[..xw])?;
                    cache.write_row_enc(li, pos, stage)?;
                    cache.write_xrow_enc(li, pos, xstage)?;
                }
            }
        }

        let inv_scale = 1.0 / (qk_head as f32).sqrt();
        let arm = self.kv_arm();
        p.heads_out[..t * ho_w].fill(0.0);
        for c in 0..t {
            let cache = &caches[p.cols[c]];
            let pos = cache.len;
            let scores = &mut p.scores[..pos + 1];
            let q = &mut p.q[c * q_len..(c + 1) * q_len];
            let heads_out = &mut p.heads_out[c * ho_w..(c + 1) * ho_w];
            for hd in 0..cfg.n_heads {
                let qh = &mut q[hd * qk_head..(hd + 1) * qk_head];
                self.rope.apply(&mut qh[nope..], pos);
                for (pp, sc) in scores.iter_mut().enumerate() {
                    let sv = cache.score_dot_x(li, pp, hd * (nope + vh), &qh[..nope], arm)
                        + cache.score_dot(li, pp, kv_rank, &qh[nope..], arm);
                    *sc = sv * inv_scale;
                }
                math::softmax_in_place(scores);
                let oh = &mut heads_out[hd * vh..(hd + 1) * vh];
                for (pp, &w) in scores.iter().enumerate() {
                    let v = cache.values_x(
                        li,
                        pp,
                        hd * (nope + vh) + nope,
                        vh,
                        &mut p.vdec[..],
                        arm,
                    );
                    for (o, &vv) in oh.iter_mut().zip(v) {
                        *o += w * vv;
                    }
                }
            }
        }
        let ho = &p.heads_out[..t * ho_w];
        self.matvec_mat(attn_output, ho, ho_w, t, &mut p.mat, &mut p.delta[..t * hs])
    }

    /// Batched-decode GQA attention: the per-column analogue of
    /// [`ForwardPass::attention_gqa_panel`] over each column's own
    /// cache/position ([`ForwardPass::attention_gqa`]'s loops exactly).
    #[allow(clippy::too_many_arguments)]
    fn attention_gqa_step(
        &self,
        li: usize,
        (q_w, k_w, v_w): (&TensorEntry, &TensorEntry, &TensorEntry),
        attn_output: &TensorEntry,
        t: usize,
        caches: &mut [KvCache],
        p: &mut PanelScratch,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let hs = cfg.hidden_size;
        let hd = cfg.head_dim;
        let kd = cfg.n_kv_heads * hd;
        let group = cfg.n_heads / cfg.n_kv_heads;
        let q_len = cfg.n_heads * hd;

        let xs = &p.xn[..t * hs];
        self.matvec_mat(q_w, xs, hs, t, &mut p.mat, &mut p.q[..t * q_len])?;
        self.matvec_mat(k_w, xs, hs, t, &mut p.mat, &mut p.kv[..t * kd])?;
        self.matvec_mat(v_w, xs, hs, t, &mut p.mat, &mut p.v[..t * kd])?;
        for c in 0..t {
            let cache = &mut caches[p.cols[c]];
            let pos = cache.len;
            match cache.scheme() {
                KvScheme::F32 => {
                    let row = cache.row_mut(li, pos);
                    let (krow, vrow) = row.split_at_mut(kd);
                    krow.copy_from_slice(&p.kv[c * kd..(c + 1) * kd]);
                    vrow.copy_from_slice(&p.v[c * kd..(c + 1) * kd]);
                    for kh in 0..cfg.n_kv_heads {
                        self.rope.apply(&mut krow[kh * hd..(kh + 1) * hd], pos);
                    }
                }
                _ => {
                    // Quantize-on-append via the exact f32 staging line
                    // — per column identical to the solo decode path.
                    let stage = &mut p.kv_stage;
                    let (krow, vrow) = stage[..2 * kd].split_at_mut(kd);
                    krow.copy_from_slice(&p.kv[c * kd..(c + 1) * kd]);
                    vrow.copy_from_slice(&p.v[c * kd..(c + 1) * kd]);
                    for kh in 0..cfg.n_kv_heads {
                        self.rope.apply(&mut krow[kh * hd..(kh + 1) * hd], pos);
                    }
                    cache.write_row_enc(li, pos, stage)?;
                }
            }
        }

        let inv_scale = 1.0 / (hd as f32).sqrt();
        let arm = self.kv_arm();
        p.heads_out[..t * q_len].fill(0.0);
        for c in 0..t {
            let cache = &caches[p.cols[c]];
            let pos = cache.len;
            let scores = &mut p.scores[..pos + 1];
            let q = &mut p.q[c * q_len..(c + 1) * q_len];
            let heads_out = &mut p.heads_out[c * q_len..(c + 1) * q_len];
            for h in 0..cfg.n_heads {
                let qh = &mut q[h * hd..(h + 1) * hd];
                self.rope.apply(qh, pos);
                let kh = h / group;
                for (pp, sc) in scores.iter_mut().enumerate() {
                    *sc = cache.score_dot(li, pp, kh * hd, qh, arm) * inv_scale;
                }
                math::softmax_in_place(scores);
                let oh = &mut heads_out[h * hd..(h + 1) * hd];
                for (pp, &w) in scores.iter().enumerate() {
                    let v = cache.values(li, pp, kd + kh * hd, hd, &mut p.vdec[..], arm);
                    for (o, &vv) in oh.iter_mut().zip(v) {
                        *o += w * vv;
                    }
                }
            }
        }
        let ho = &p.heads_out[..t * q_len];
        self.matvec_mat(attn_output, ho, q_len, t, &mut p.mat, &mut p.delta[..t * hs])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{quantize_container_with, synthetic_f32_container};
    use crate::scheme::builtin;

    fn tiny_forward(scheme: &str, threads: usize, max_ctx: usize) -> ForwardPass {
        // One shared quantized container (q4_k_m is the only scheme
        // these in-module tests use; the cross-scheme coverage lives in
        // tests/native_forward.rs).
        static Q4: std::sync::OnceLock<Vec<u8>> = std::sync::OnceLock::new();
        assert_eq!(scheme, "q4_k_m");
        let bytes = Q4.get_or_init(|| {
            let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 0xF052).unwrap();
            quantize_container_with(&src, &builtin::scheme(scheme).unwrap(), None, 1)
                .unwrap()
                .to_bytes()
        });
        ForwardPass::new(Container::from_bytes(bytes.clone()).unwrap(), threads, max_ctx).unwrap()
    }

    #[test]
    fn cache_overflow_is_a_clean_error_before_any_state_change() {
        let fwd = tiny_forward("q4_k_m", 1, 2);
        let mut cache = fwd.new_cache();
        let mut scratch = fwd.new_scratch();
        fwd.forward_token(1, &mut cache, &mut scratch, None).unwrap();
        fwd.forward_token(2, &mut cache, &mut scratch, None).unwrap();
        assert_eq!(cache.len(), 2);
        let err = fwd.forward_token(3, &mut cache, &mut scratch, None).unwrap_err();
        assert!(err.to_string().contains("max context"), "{err}");
        assert_eq!(cache.len(), 2, "failed append must not consume a slot");
    }

    #[test]
    fn dense_gqa_containers_are_served_not_rejected() {
        // Before PR 5 every non-MLA container was bailed on; the dense
        // tiny proxy now resolves a full GQA layer map.
        let src = synthetic_f32_container(&ModelConfig::tiny_dense(), 7).unwrap();
        let fwd = ForwardPass::new(src, 1, 8).unwrap();
        let mut cache = fwd.new_cache();
        let mut scratch = fwd.new_scratch();
        let mut logits = vec![0f32; fwd.vocab()];
        fwd.forward_token(3, &mut cache, &mut scratch, Some(&mut logits)).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(logits.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn unusable_kind_dims_are_rejected_listing_supported_kinds() {
        // A DenseGqa config whose GQA dims are unusable is the
        // genuinely-unsupported case left after PR 5.
        let mut src = synthetic_f32_container(&ModelConfig::tiny_dense(), 7).unwrap();
        src.model.head_dim = 0;
        let err = ForwardPass::new(src, 1, 8).unwrap_err().to_string();
        assert!(err.contains("tiny-dense"), "{err}");
        assert!(err.contains("DenseGqa"), "{err}");
        assert!(err.contains(SUPPORTED_KINDS), "must list supported kinds: {err}");
    }

    #[test]
    fn shape_mismatch_names_the_model_and_kind() {
        // Doctor the config so a resolved tensor no longer matches the
        // expectation derived from it; the error must say which model
        // (and kind) was being validated.
        let mut src = synthetic_f32_container(&ModelConfig::tiny_dense(), 7).unwrap();
        src.model.intermediate_size = 768;
        let err = ForwardPass::new(src, 1, 8).unwrap_err().to_string();
        assert!(err.contains("tiny-dense"), "{err}");
        assert!(err.contains("DenseGqa"), "{err}");
        assert!(err.contains("ffn_gate"), "{err}");
    }

    #[test]
    fn logits_buffer_must_match_vocab() {
        let fwd = tiny_forward("q4_k_m", 1, 4);
        let mut cache = fwd.new_cache();
        let mut scratch = fwd.new_scratch();
        let mut short = vec![0f32; 3];
        assert!(fwd.forward_token(1, &mut cache, &mut scratch, Some(&mut short)).is_err());
    }

    #[test]
    fn kv_cache_allocates_lazily_on_first_token() {
        let fwd = tiny_forward("q4_k_m", 1, 4);
        let mut cache = fwd.new_cache();
        assert!(!cache.is_allocated(), "fresh caches must not allocate");
        let mut scratch = fwd.new_scratch();
        fwd.forward_token(1, &mut cache, &mut scratch, None).unwrap();
        assert!(cache.is_allocated());
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn rope_table_rows_are_unit_rotations() {
        let t = RopeTable::new(32, 24, math::ln_f32(10000.0));
        for p in 0..24 {
            for i in 0..16 {
                let (c, s) = (t.cos[p * 16 + i], t.sin[p * 16 + i]);
                let n = (c as f64).hypot(s as f64);
                assert!((n - 1.0).abs() < 1e-4, "pos {p} freq {i}: |({c},{s})| = {n}");
            }
        }
        // Position 0 is the identity rotation for every frequency.
        assert!(t.cos[..16].iter().all(|&c| c == 1.0));
        assert!(t.sin[..16].iter().all(|&s| s == 0.0));
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn panel_prefill_matches_token_loop_dense() {
        let src = synthetic_f32_container(&ModelConfig::tiny_dense(), 7).unwrap();
        let fwd = ForwardPass::new(src, 2, 8).unwrap();
        let toks = [1i32, 5, 260, 3, 17];

        let mut c_loop = fwd.new_cache();
        let mut s_loop = fwd.new_scratch();
        let mut l_loop = vec![0f32; fwd.vocab()];
        for (j, &tok) in toks.iter().enumerate() {
            let want = if j + 1 == toks.len() { Some(&mut l_loop[..]) } else { None };
            fwd.forward_token(tok, &mut c_loop, &mut s_loop, want).unwrap();
        }

        let mut c_panel = fwd.new_cache();
        let mut s_panel = fwd.new_scratch();
        let mut l_panel = vec![0f32; fwd.vocab()];
        fwd.forward_tokens(&toks, &mut c_panel, &mut s_panel, Some(&mut l_panel)).unwrap();

        assert_eq!(c_panel.len(), toks.len());
        assert_eq!(bits(&l_panel), bits(&l_loop), "panel logits must match the token loop");
        assert_eq!(
            bits(c_panel.raw_rows()),
            bits(c_loop.raw_rows()),
            "panel KV rows must match the token loop"
        );
    }

    #[test]
    fn panel_prefill_matches_token_loop_mla() {
        let fwd = tiny_forward("q4_k_m", 1, 8);
        let toks = [1i32, 17, 300, 42, 511];

        let mut c_loop = fwd.new_cache();
        let mut s_loop = fwd.new_scratch();
        let mut l_loop = vec![0f32; fwd.vocab()];
        for (j, &tok) in toks.iter().enumerate() {
            let want = if j + 1 == toks.len() { Some(&mut l_loop[..]) } else { None };
            fwd.forward_token(tok, &mut c_loop, &mut s_loop, want).unwrap();
        }

        let mut c_panel = fwd.new_cache();
        let mut s_panel = fwd.new_scratch();
        let mut l_panel = vec![0f32; fwd.vocab()];
        fwd.forward_tokens(&toks, &mut c_panel, &mut s_panel, Some(&mut l_panel)).unwrap();

        assert_eq!(bits(&l_panel), bits(&l_loop), "panel logits must match the token loop");
        assert_eq!(bits(c_panel.raw_rows()), bits(c_loop.raw_rows()));
        assert_eq!(
            bits(c_panel.raw_expanded()),
            bits(c_loop.raw_expanded()),
            "panel-written expanded rows must match the per-token writes"
        );
    }

    #[test]
    fn absorbed_mla_decode_matches_unabsorbed() {
        let fwd_a = tiny_forward("q4_k_m", 1, 8);
        let mut fwd_e = tiny_forward("q4_k_m", 1, 8);
        fwd_e.set_mla_absorption(false);

        let mut ca = fwd_a.new_cache();
        let mut ce = fwd_e.new_cache();
        let mut sa = fwd_a.new_scratch();
        let mut se = fwd_e.new_scratch();
        let mut la = vec![0f32; fwd_a.vocab()];
        let mut le = vec![0f32; fwd_e.vocab()];
        for (step, &tok) in [1i32, 17, 300, 42, 511, 7].iter().enumerate() {
            fwd_a.forward_token(tok, &mut ca, &mut sa, Some(&mut la)).unwrap();
            fwd_e.forward_token(tok, &mut ce, &mut se, Some(&mut le)).unwrap();
            assert_eq!(bits(&la), bits(&le), "step {step}: absorbed logits diverged");
        }
        assert_eq!(bits(ca.raw_rows()), bits(ce.raw_rows()));
        assert!(ce.raw_expanded().is_empty(), "eager caches must not carry the expanded plane");
    }

    #[test]
    fn rope_base_changes_the_frequencies() {
        // The satellite bug this PR fixes: a Qwen-style θ=1000000 model
        // computed with the old hard-coded ln(10000) would get these
        // exact tables instead of its own.
        let a = RopeTable::new(64, 8, math::ln_f32(10000.0));
        let b = RopeTable::new(64, 8, math::ln_f32(1_000_000.0));
        assert_ne!(
            a.cos[32..64],
            b.cos[32..64],
            "different bases must rotate differently from position 1 on"
        );
    }
}
