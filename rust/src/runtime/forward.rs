//! The tiny-MoE transformer forward pass, executed **directly on
//! container-encoded weights**.
//!
//! This is the computation `dsq serve|eval --native` runs: a complete
//! DeepSeek-V3-shaped decoder step — RMSNorm, MLA attention with a
//! compressed-latent KV cache, top-k routed + shared expert FFNs, and
//! the final unembedding — where **every matrix–vector product goes
//! through the fused [`crate::quant::vec_dot_rows_with`] kernels on the
//! container's packed payloads**. No weight matrix is ever materialized
//! as a resident f32 table; only the per-layer norm vectors (f32 in
//! every scheme, a few KiB total) are decoded at load time.
//!
//! ## Layer map
//!
//! Weights are resolved from the container by the GGUF-style names the
//! [`crate::model::ModelConfig::census`] declares, and every shape is
//! validated against the config before serving:
//!
//! ```text
//! token_embd.weight                  [vocab, hidden]     one row decoded per token
//! blk.{i}.attn_norm.weight           [hidden]            f32, decoded at load
//! blk.{i}.attn_q_a.weight            [q_rank, hidden]    fused matvec
//! blk.{i}.attn_q_a_norm.weight       [q_rank]            f32, decoded at load
//! blk.{i}.attn_q_b.weight            [heads·(nope+rope), q_rank]
//! blk.{i}.attn_kv_a_mqa.weight       [kv_rank+rope, hidden]
//! blk.{i}.attn_kv_a_norm.weight      [kv_rank]
//! blk.{i}.attn_kv_b.weight           [heads·(nope+v), kv_rank]
//! blk.{i}.attn_output.weight         [hidden, heads·v]
//! blk.{i}.ffn_norm.weight            [hidden]
//! dense layers (i < first_dense):    ffn_gate / ffn_up / ffn_down
//! MoE layers:                        ffn_gate_inp (f32 router) +
//!                                    ffn_{gate,up,down}_exps [n_exp, ..] +
//!                                    ffn_{gate,up,down}_shexp
//! output_norm.weight                 [hidden]
//! output.weight                      [vocab, hidden]     fused matvec per step
//! ```
//!
//! ## MLA attention
//!
//! The cache stores, per layer and position, the **compressed** state
//! MLA is designed around: the RMS-normed KV latent (`kv_lora_rank`
//! floats) plus the shared post-RoPE rope key (`qk_rope_head_dim`
//! floats) — `kv_lora_rank + qk_rope_head_dim` floats per layer-token,
//! exactly the footprint [`crate::model::ModelConfig::kv_bytes_per_token`]
//! accounts. At each step the per-head no-position keys and values are
//! re-expanded from the cached latents through the (encoded)
//! `attn_kv_b` matvec. The cache is hard-bounded: a token forwarded at
//! `position ≥ max_ctx` is an error, raised *before* any state changes.
//!
//! ## Determinism contract
//!
//! Identical to the PR-3 `vec_dot` contract, extended end to end: every
//! dot product — quantized matvecs, attention scores, the RMSNorm sum
//! of squares — reduces in the canonical 8-lane order
//! ([`crate::quant::kernels::dot_lanes`]); every nonlinearity uses the
//! deterministic [`crate::util::math`] kernels; softmaxes, weighted-sum
//! folds and expert combines walk fixed sequential orders. Consequently
//! the logits are **bit-identical** across matvec thread counts and
//! across the `DSQ_SCALAR_DECODE` dispatch arms, and are mirrored
//! bit-exactly by `python/tools/bless_goldens.py` (the committed
//! `rust/tests/golden/forward.*.fnv64` checksums pin both sides).

use crate::container::{Container, TensorEntry};
use crate::model::{ModelConfig, ModelKind};
use crate::quant::{self, kernels, QuantFormat};
use crate::util::math;
use anyhow::{bail, Context, Result};

/// RMSNorm epsilon (matches the proxy training configuration).
pub const RMS_EPS: f32 = 1e-6;
/// RoPE frequency base (`θ_i = BASE^(−2i/d)`).
pub const ROPE_BASE_LN: f32 = 9.2103404; // ln(10000)

/// How the per-matvec dot products are executed.
#[derive(Debug, Clone, Copy)]
pub enum MatvecMode {
    /// Row-parallel fused matvec over up to N threads, runtime-selected
    /// dispatch arm (the serving default; bit-identical for every N).
    Threads(usize),
    /// Serial matvec with the dispatch arm pinned (`true` = lane
    /// kernels, `false` = scalar reference) — the seam `dsq selfcheck`
    /// and the arm-identity tests use.
    Pinned(bool),
}

/// Per-slot KV cache: `[n_layers][max_ctx][kv_rank + rope]` f32, filled
/// front to back; `len` positions are valid in every layer.
pub struct KvCache {
    data: Vec<f32>,
    len: usize,
    width: usize,
    max_ctx: usize,
}

impl KvCache {
    fn new(n_layers: usize, width: usize, max_ctx: usize) -> Self {
        KvCache { data: vec![0.0; n_layers * max_ctx * width], len: 0, width, max_ctx }
    }

    /// Tokens cached so far (== the next token's position).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn max_ctx(&self) -> usize {
        self.max_ctx
    }

    fn row(&self, layer: usize, pos: usize) -> &[f32] {
        let at = (layer * self.max_ctx + pos) * self.width;
        &self.data[at..at + self.width]
    }

    fn row_mut(&mut self, layer: usize, pos: usize) -> &mut [f32] {
        let at = (layer * self.max_ctx + pos) * self.width;
        &mut self.data[at..at + self.width]
    }
}

/// One layer's resolved weights: encoded entries for everything the
/// fused matvec consumes, decoded f32 vectors for the (tiny) norms.
struct LayerWeights {
    attn_norm: Vec<f32>,
    q_a: TensorEntry,
    q_a_norm: Vec<f32>,
    q_b: TensorEntry,
    kv_a: TensorEntry,
    kv_a_norm: Vec<f32>,
    kv_b: TensorEntry,
    attn_output: TensorEntry,
    ffn_norm: Vec<f32>,
    ffn: LayerFfn,
}

enum LayerFfn {
    Dense {
        gate: TensorEntry,
        up: TensorEntry,
        down: TensorEntry,
    },
    Moe {
        router: TensorEntry,
        gate_exps: TensorEntry,
        up_exps: TensorEntry,
        down_exps: TensorEntry,
        gate_shexp: TensorEntry,
        up_shexp: TensorEntry,
        down_shexp: TensorEntry,
    },
}

/// Precomputed rotary table: `cos/sin(pos · θ_i)` for every position
/// below `max_ctx` and every frequency `θ_i = BASE^(−2i/d)`.
///
/// Built from [`math::exp_f32`] (frequencies), [`math::sin_small`] /
/// [`math::cos_small`] (the ≤ 1-radian per-step angles) and the
/// exactly-rounded angle-addition recurrence — no libm, so the table is
/// reproducible bit-for-bit anywhere (including the Python mirror).
struct RopeTable {
    half: usize,
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl RopeTable {
    fn new(dim: usize, max_ctx: usize) -> Self {
        let half = dim / 2;
        let mut cos = vec![0.0f32; max_ctx * half];
        let mut sin = vec![0.0f32; max_ctx * half];
        for i in 0..half {
            let a = (2 * i) as f32 / dim as f32;
            let theta = math::exp_f32(-(a * ROPE_BASE_LN));
            let (c1, s1) = (math::cos_small(theta), math::sin_small(theta));
            let (mut c, mut s) = (1.0f32, 0.0f32);
            for p in 0..max_ctx {
                cos[p * half + i] = c;
                sin[p * half + i] = s;
                let (cn, sn) = (c * c1 - s * s1, s * c1 + c * s1);
                c = cn;
                s = sn;
            }
        }
        RopeTable { half, cos, sin }
    }

    /// Rotate consecutive pairs `(x[2i], x[2i+1])` by `pos · θ_i`.
    fn apply(&self, x: &mut [f32], pos: usize) {
        debug_assert_eq!(x.len(), 2 * self.half);
        for i in 0..self.half {
            let c = self.cos[pos * self.half + i];
            let s = self.sin[pos * self.half + i];
            let (a, b) = (x[2 * i], x[2 * i + 1]);
            x[2 * i] = a * c - b * s;
            x[2 * i + 1] = a * s + b * c;
        }
    }
}

/// RMSNorm with the canonical lane-ordered sum of squares:
/// `out[i] = (x[i] · rsqrt(mean(x²) + ε)) · w[i]`.
pub fn rms_norm(x: &[f32], w: &[f32], out: &mut [f32]) {
    debug_assert!(x.len() == w.len() && x.len() == out.len());
    let ss = kernels::dot_lanes(x, x);
    let scale = 1.0 / (ss / x.len() as f32 + RMS_EPS).sqrt();
    for ((o, &xv), &wv) in out.iter_mut().zip(x).zip(w) {
        *o = (xv * scale) * wv;
    }
}

/// The forward-pass model over an opened (quantized or f32) container.
pub struct ForwardPass {
    cfg: ModelConfig,
    ckpt: Container,
    token_embd: TensorEntry,
    embd_row_bytes: usize,
    layers: Vec<LayerWeights>,
    output_norm: Vec<f32>,
    output: TensorEntry,
    rope: RopeTable,
    max_ctx: usize,
    mode: MatvecMode,
}

impl ForwardPass {
    /// Resolve and validate the full layer map from `ckpt` (taken over
    /// whole; payloads are served in place). `threads` bounds the
    /// row-parallel matvec fan-out; `max_ctx` bounds every
    /// [`KvCache`] this model creates.
    pub fn new(ckpt: Container, threads: usize, max_ctx: usize) -> Result<Self> {
        let cfg = ckpt.model.clone();
        if cfg.kind != ModelKind::MlaMoe {
            bail!(
                "native forward pass supports MLA+MoE models; container model {:?} is {:?}",
                cfg.name,
                cfg.kind
            );
        }
        if max_ctx == 0 {
            bail!("native forward pass needs max_ctx ≥ 1");
        }
        let entry = |name: &str, shape: &[usize]| -> Result<TensorEntry> {
            let t = ckpt.tensor(name).context("native forward layer map")?;
            if t.shape != shape {
                bail!("tensor {name}: shape {:?} does not match config {:?}", t.shape, shape);
            }
            // Fused matvecs consume whole rows of blocks.
            t.format
                .row_bytes(*shape.last().unwrap())
                .with_context(|| format!("tensor {name}: rows not block-aligned"))?;
            Ok(t.clone())
        };
        let norm = |name: &str, len: usize| -> Result<Vec<f32>> {
            let t = entry(name, &[len])?;
            ckpt.dequantize(&t)
        };

        let (h, qk_head) = (cfg.hidden_size, cfg.qk_head_dim());
        let token_embd = entry("token_embd.weight", &[cfg.vocab_size, h])?;
        let embd_row_bytes = token_embd.format.row_bytes(h)?;
        let output = entry("output.weight", &[cfg.vocab_size, h])?;
        let output_norm = norm("output_norm.weight", h)?;

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let blk = |stem: &str| format!("blk.{i}.{stem}.weight");
            let ffn = if cfg.is_moe_layer(i) {
                let mi = cfg.moe_intermediate_size;
                let sh = cfg.n_shared_experts * mi;
                LayerFfn::Moe {
                    router: entry(&blk("ffn_gate_inp"), &[cfg.n_routed_experts, h])?,
                    gate_exps: entry(&blk("ffn_gate_exps"), &[cfg.n_routed_experts, mi, h])?,
                    up_exps: entry(&blk("ffn_up_exps"), &[cfg.n_routed_experts, mi, h])?,
                    down_exps: entry(&blk("ffn_down_exps"), &[cfg.n_routed_experts, h, mi])?,
                    gate_shexp: entry(&blk("ffn_gate_shexp"), &[sh, h])?,
                    up_shexp: entry(&blk("ffn_up_shexp"), &[sh, h])?,
                    down_shexp: entry(&blk("ffn_down_shexp"), &[h, sh])?,
                }
            } else {
                LayerFfn::Dense {
                    gate: entry(&blk("ffn_gate"), &[cfg.intermediate_size, h])?,
                    up: entry(&blk("ffn_up"), &[cfg.intermediate_size, h])?,
                    down: entry(&blk("ffn_down"), &[h, cfg.intermediate_size])?,
                }
            };
            layers.push(LayerWeights {
                attn_norm: norm(&blk("attn_norm"), h)?,
                q_a: entry(&blk("attn_q_a"), &[cfg.q_lora_rank, h])?,
                q_a_norm: norm(&blk("attn_q_a_norm"), cfg.q_lora_rank)?,
                q_b: entry(&blk("attn_q_b"), &[cfg.n_heads * qk_head, cfg.q_lora_rank])?,
                kv_a: entry(&blk("attn_kv_a_mqa"), &[cfg.kv_cache_width(), h])?,
                kv_a_norm: norm(&blk("attn_kv_a_norm"), cfg.kv_lora_rank)?,
                kv_b: entry(
                    &blk("attn_kv_b"),
                    &[cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim), cfg.kv_lora_rank],
                )?,
                attn_output: entry(&blk("attn_output"), &[h, cfg.n_heads * cfg.v_head_dim])?,
                ffn_norm: norm(&blk("ffn_norm"), h)?,
                ffn,
            });
        }
        let rope = RopeTable::new(cfg.qk_rope_head_dim, max_ctx);
        Ok(ForwardPass {
            cfg,
            ckpt,
            token_embd,
            embd_row_bytes,
            layers,
            output_norm,
            output,
            rope,
            max_ctx,
            mode: MatvecMode::Threads(threads.max(1)),
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Scheme name of the underlying container.
    pub fn scheme_name(&self) -> &str {
        &self.ckpt.scheme_name
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab_size
    }

    pub fn max_ctx(&self) -> usize {
        self.max_ctx
    }

    /// The stored format of the unembedding matrix (what the per-step
    /// vocab-wide fused matvec runs on).
    pub fn output_format(&self) -> QuantFormat {
        self.output.format
    }

    /// Override the matvec execution mode (thread count or pinned
    /// dispatch arm). Logits are bit-identical under every mode — that
    /// is the point of the seam (`dsq selfcheck`, arm-identity tests).
    pub fn set_mode(&mut self, mode: MatvecMode) {
        self.mode = mode;
    }

    /// A fresh, empty per-slot cache bounded by this model's `max_ctx`.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.cfg.n_layers, self.cfg.kv_cache_width(), self.max_ctx)
    }

    /// Quantized matvec `out[r] = row_r · x` on encoded bytes, under
    /// the active [`MatvecMode`].
    fn matvec_bytes(
        &self,
        fmt: QuantFormat,
        bytes: &[u8],
        x: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        match self.mode {
            MatvecMode::Threads(n) => quant::vec_dot_rows_with(fmt, bytes, x, out, n),
            MatvecMode::Pinned(fast) => {
                let rb = fmt.row_bytes(x.len())?;
                if bytes.len() != rb * out.len() {
                    bail!("pinned matvec: {} bytes != {} rows × {rb}", bytes.len(), out.len());
                }
                for (o, row) in out.iter_mut().zip(bytes.chunks_exact(rb)) {
                    *o = kernels::vec_dot_pinned(fmt, row, x, fast);
                }
                Ok(())
            }
        }
    }

    fn matvec(&self, t: &TensorEntry, x: &[f32], out: &mut [f32]) -> Result<()> {
        self.matvec_bytes(t.format, self.ckpt.bytes(t), x, out)
    }

    /// The encoded rows of expert `e` inside a `[n_exp, out, in]`
    /// expert-stacked tensor.
    fn expert_bytes(&self, t: &TensorEntry, e: usize) -> Result<&[u8]> {
        let per = t.format.row_bytes(t.shape[2])? * t.shape[1];
        Ok(&self.ckpt.bytes(t)[e * per..(e + 1) * per])
    }

    /// Decode one embedding row (`token_embd.weight[t]`) into `h`.
    /// Out-of-range ids wrap into the vocabulary (padding slots send
    /// `PAD`, and sampled ids are always in range).
    fn embed(&self, tok: i32, h: &mut [f32]) -> Result<()> {
        let t = tok.rem_euclid(self.cfg.vocab_size as i32) as usize;
        let bytes = self.ckpt.bytes(&self.token_embd);
        let row = &bytes[t * self.embd_row_bytes..(t + 1) * self.embd_row_bytes];
        quant::dequantize_into(self.token_embd.format, row, h)
    }

    /// `down(silu(gate(x)) · up(x))` with all three projections fused
    /// on encoded rows.
    fn mlp(
        &self,
        gate: (QuantFormat, &[u8]),
        up: (QuantFormat, &[u8]),
        down: (QuantFormat, &[u8]),
        inter: usize,
        x: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        let mut g = vec![0f32; inter];
        let mut u = vec![0f32; inter];
        self.matvec_bytes(gate.0, gate.1, x, &mut g)?;
        self.matvec_bytes(up.0, up.1, x, &mut u)?;
        for (gv, &uv) in g.iter_mut().zip(&u) {
            *gv = math::silu(*gv) * uv;
        }
        self.matvec_bytes(down.0, down.1, &g, out)
    }

    /// MLA attention for one layer at `pos` (appends this token's
    /// latent + rope key to the cache row first).
    fn attention(
        &self,
        li: usize,
        lw: &LayerWeights,
        xn: &[f32],
        cache: &mut KvCache,
        pos: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let cfg = &self.cfg;
        let (nope, rope_d, vh) = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim);
        let qk_head = nope + rope_d;
        let kv_rank = cfg.kv_lora_rank;

        // Query path: hidden → q_lora_rank → heads·(nope+rope).
        let mut q_a = vec![0f32; cfg.q_lora_rank];
        self.matvec(&lw.q_a, xn, &mut q_a)?;
        let mut q_an = vec![0f32; cfg.q_lora_rank];
        rms_norm(&q_a, &lw.q_a_norm, &mut q_an);
        let mut q = vec![0f32; cfg.n_heads * qk_head];
        self.matvec(&lw.q_b, &q_an, &mut q)?;

        // KV path: hidden → (latent, rope key); the cache row stores the
        // RMS-normed latent and the post-RoPE shared key.
        let mut kv_a = vec![0f32; cfg.kv_cache_width()];
        self.matvec(&lw.kv_a, xn, &mut kv_a)?;
        {
            let row = cache.row_mut(li, pos);
            rms_norm(&kv_a[..kv_rank], &lw.kv_a_norm, &mut row[..kv_rank]);
            row[kv_rank..].copy_from_slice(&kv_a[kv_rank..]);
            self.rope.apply(&mut row[kv_rank..], pos);
        }

        // Re-expand per-head k_nope/v for every cached position from the
        // compressed latents (the encoded kv_b matvec).
        let ctx = pos + 1;
        let kvb_w = cfg.n_heads * (nope + vh);
        let mut kvb = vec![0f32; ctx * kvb_w];
        for p in 0..ctx {
            let latent = &cache.row(li, p)[..kv_rank];
            // Split borrow: `kvb` rows are disjoint per position.
            let dst = &mut kvb[p * kvb_w..(p + 1) * kvb_w];
            self.matvec(&lw.kv_b, latent, dst)?;
        }

        let inv_scale = 1.0 / (qk_head as f32).sqrt();
        let mut heads_out = vec![0f32; cfg.n_heads * vh];
        let mut scores = vec![0f32; ctx];
        for hd in 0..cfg.n_heads {
            let qh = &mut q[hd * qk_head..(hd + 1) * qk_head];
            self.rope.apply(&mut qh[nope..], pos);
            for (p, sc) in scores.iter_mut().enumerate() {
                let k_nope = &kvb[p * kvb_w + hd * (nope + vh)..][..nope];
                let k_rope = &cache.row(li, p)[kv_rank..];
                let s = kernels::dot_lanes(&qh[..nope], k_nope)
                    + kernels::dot_lanes(&qh[nope..], k_rope);
                *sc = s * inv_scale;
            }
            math::softmax_in_place(&mut scores);
            let oh = &mut heads_out[hd * vh..(hd + 1) * vh];
            for (p, &w) in scores.iter().enumerate() {
                let v = &kvb[p * kvb_w + hd * (nope + vh) + nope..][..vh];
                for (o, &vv) in oh.iter_mut().zip(v) {
                    *o += w * vv;
                }
            }
        }
        self.matvec(&lw.attn_output, &heads_out, out)
    }

    /// FFN for one layer: dense SwiGLU, or router → top-k routed
    /// experts + shared expert. The combine order is fixed (shared
    /// expert first, then selected experts in ascending index), so the
    /// output is a pure function of the inputs.
    fn ffn(&self, lw: &LayerWeights, xn: &[f32], out: &mut [f32]) -> Result<()> {
        let cfg = &self.cfg;
        let fb = |t: &TensorEntry| (t.format, self.ckpt.bytes(t));
        match &lw.ffn {
            LayerFfn::Dense { gate, up, down } => {
                self.mlp(fb(gate), fb(up), fb(down), cfg.intermediate_size, xn, out)
            }
            LayerFfn::Moe {
                router,
                gate_exps,
                up_exps,
                down_exps,
                gate_shexp,
                up_shexp,
                down_shexp,
            } => {
                let ne = cfg.n_routed_experts;
                let mut probs = vec![0f32; ne];
                self.matvec(router, xn, &mut probs)?;
                math::softmax_in_place(&mut probs);
                // Top-k selection: highest probability first, ties to
                // the lower expert index; combined in ascending index.
                let mut idx: Vec<usize> = (0..ne).collect();
                idx.sort_by(|&a, &b| {
                    probs[b].partial_cmp(&probs[a]).expect("softmax is NaN-free").then(a.cmp(&b))
                });
                idx.truncate(cfg.n_active_experts);
                idx.sort_unstable();
                let mut z = 0f32;
                for &e in &idx {
                    z += probs[e];
                }
                // Shared expert contributes with weight 1.
                let sh_inter = cfg.n_shared_experts * cfg.moe_intermediate_size;
                self.mlp(fb(gate_shexp), fb(up_shexp), fb(down_shexp), sh_inter, xn, out)?;
                let mut y = vec![0f32; cfg.hidden_size];
                for &e in &idx {
                    let w = probs[e] / z;
                    self.mlp(
                        (gate_exps.format, self.expert_bytes(gate_exps, e)?),
                        (up_exps.format, self.expert_bytes(up_exps, e)?),
                        (down_exps.format, self.expert_bytes(down_exps, e)?),
                        cfg.moe_intermediate_size,
                        xn,
                        &mut y,
                    )?;
                    for (o, &yv) in out.iter_mut().zip(&y) {
                        *o += w * yv;
                    }
                }
                Ok(())
            }
        }
    }

    /// Run one token through the full stack at the cache's next
    /// position. When `logits` is given it receives the vocab-wide
    /// unembedding of the final hidden state (`logits.len() == vocab`);
    /// prefill steps that only need to advance the cache pass `None`
    /// and skip the vocab matvec.
    pub fn forward_token(
        &self,
        tok: i32,
        cache: &mut KvCache,
        logits: Option<&mut [f32]>,
    ) -> Result<()> {
        let pos = cache.len;
        if pos >= cache.max_ctx {
            bail!(
                "KV cache full: token at position {pos} exceeds the engine's configured \
                 max context {}",
                cache.max_ctx
            );
        }
        if let Some(out) = &logits {
            if out.len() != self.cfg.vocab_size {
                bail!("logits buffer {} != vocab {}", out.len(), self.cfg.vocab_size);
            }
        }
        let h_dim = self.cfg.hidden_size;
        let mut h = vec![0f32; h_dim];
        self.embed(tok, &mut h)?;
        let mut xn = vec![0f32; h_dim];
        let mut delta = vec![0f32; h_dim];
        for (li, lw) in self.layers.iter().enumerate() {
            rms_norm(&h, &lw.attn_norm, &mut xn);
            self.attention(li, lw, &xn, cache, pos, &mut delta)?;
            for (hv, &dv) in h.iter_mut().zip(&delta) {
                *hv += dv;
            }
            rms_norm(&h, &lw.ffn_norm, &mut xn);
            self.ffn(lw, &xn, &mut delta)?;
            for (hv, &dv) in h.iter_mut().zip(&delta) {
                *hv += dv;
            }
        }
        cache.len = pos + 1;
        if let Some(out) = logits {
            rms_norm(&h, &self.output_norm, &mut xn);
            self.matvec(&self.output, &xn, out)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{quantize_container_with, synthetic_f32_container};
    use crate::scheme::builtin;

    fn tiny_forward(scheme: &str, threads: usize, max_ctx: usize) -> ForwardPass {
        // One shared quantized container (q4_k_m is the only scheme
        // these in-module tests use; the cross-scheme coverage lives in
        // tests/native_forward.rs).
        static Q4: std::sync::OnceLock<Vec<u8>> = std::sync::OnceLock::new();
        assert_eq!(scheme, "q4_k_m");
        let bytes = Q4.get_or_init(|| {
            let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 0xF052).unwrap();
            quantize_container_with(&src, &builtin::scheme(scheme).unwrap(), None, 1)
                .unwrap()
                .to_bytes()
        });
        ForwardPass::new(Container::from_bytes(bytes.clone()).unwrap(), threads, max_ctx).unwrap()
    }

    #[test]
    fn cache_overflow_is_a_clean_error_before_any_state_change() {
        let fwd = tiny_forward("q4_k_m", 1, 2);
        let mut cache = fwd.new_cache();
        fwd.forward_token(1, &mut cache, None).unwrap();
        fwd.forward_token(2, &mut cache, None).unwrap();
        assert_eq!(cache.len(), 2);
        let err = fwd.forward_token(3, &mut cache, None).unwrap_err();
        assert!(err.to_string().contains("max context"), "{err}");
        assert_eq!(cache.len(), 2, "failed append must not consume a slot");
    }

    #[test]
    fn dense_gqa_containers_are_rejected_with_a_clear_error() {
        let src = synthetic_f32_container(&ModelConfig::tiny_dense(), 7).unwrap();
        let err = ForwardPass::new(src, 1, 8).unwrap_err();
        assert!(err.to_string().contains("MLA+MoE"), "{err}");
    }

    #[test]
    fn logits_buffer_must_match_vocab() {
        let fwd = tiny_forward("q4_k_m", 1, 4);
        let mut cache = fwd.new_cache();
        let mut short = vec![0f32; 3];
        assert!(fwd.forward_token(1, &mut cache, Some(&mut short)).is_err());
    }

    #[test]
    fn rope_table_rows_are_unit_rotations() {
        let t = RopeTable::new(32, 24);
        for p in 0..24 {
            for i in 0..16 {
                let (c, s) = (t.cos[p * 16 + i], t.sin[p * 16 + i]);
                let n = (c as f64).hypot(s as f64);
                assert!((n - 1.0).abs() < 1e-4, "pos {p} freq {i}: |({c},{s})| = {n}");
            }
        }
        // Position 0 is the identity rotation for every frequency.
        assert!(t.cos[..16].iter().all(|&c| c == 1.0));
        assert!(t.sin[..16].iter().all(|&s| s == 0.0));
    }
}
