//! Paged KV allocation for the continuous-batching scheduler: a pool
//! of fixed-size KV **blocks** that per-slot [`super::forward::KvCache`]s
//! draw from and return to, decoupling how many requests can be live at
//! once from `max_ctx` — a slot only ever holds the blocks its actual
//! context length needs, not a dense `n_layers × max_ctx × width`
//! buffer.
//!
//! ## Block layout
//!
//! One [`KvBlock`] stores `block_tokens` consecutive positions of **one
//! slot's** cache across *all* layers. Under the default
//! [`KvScheme::F32`], `data` is `[n_layers][block_tokens][width]` f32
//! and (for absorbed MLA) `xdata` is `[n_layers][block_tokens][xwidth]`.
//! Under a quantized scheme the planes are **encoded bytes** instead:
//! `qdata` is `[n_layers][block_tokens][row_bytes]` where `row_bytes =
//! scheme.line_bytes(width)` — each per-token row padded up to the
//! scheme's 32-element block grid and stored as whole codec blocks
//! (f16 scale + packed ints), and `xqdata` likewise for the expanded
//! plane. All sizing and reservation arithmetic is expressed in
//! **blocks of bytes** derived from the scheme ([`KvBlockPool::block_bytes`]),
//! never by assuming the f32 plane width. A paged cache's block table is
//! simply its `Vec<KvBlock>` — position `p` lives in block
//! `p / block_tokens` at in-block offset `p % block_tokens`. Blocks are
//! *moved* between the pool's free list and exactly one cache, so two
//! slots can never alias the same block by construction (the
//! pointer-uniqueness property tests re-verify this from outside).
//! Because every position owns a whole number of codec blocks (the
//! padded row), a `block_tokens` that is *not* a multiple of the
//! codec's 32-weight grid cannot make two positions share a codec
//! block: padding is per-row, write-once, and zero-filled — it can
//! neither alias a neighbour nor leak stale state (swept by the
//! property test in `tests/continuous_batching.rs`).
//!
//! ## Block size
//!
//! `block_tokens` trades internal fragmentation (a slot wastes at most
//! `block_tokens − 1` trailing token slots per plane) against
//! block-table overhead and pool churn (smaller blocks mean more
//! `take`/`put` traffic and more table entries per slot). The serving
//! default is 4 — with the native engine's `max_ctx = 24` that is 6
//! blocks per full-length slot, and a short 3-token request holds 1
//! block instead of a full dense buffer.
//!
//! ## Reservation discipline (why admission can never deadlock)
//!
//! The pool tracks two counters: `outstanding` (blocks currently held
//! by caches) and `reserved` (blocks promised to admitted requests).
//! The scheduler reserves a request's **worst-case** block count
//! (`ceil(min(prompt + max_new, max_ctx) / block_tokens)`) *before*
//! admitting it — [`KvBlockPool::try_reserve`] fails when the pool
//! cannot promise that many, and the request simply waits in the queue.
//! [`KvBlockPool::take`] refuses to hand out a block beyond the
//! reserved count, so the invariant `outstanding ≤ reserved ≤ capacity`
//! holds at every step and an admitted request's mid-generation
//! `grow_to` can never starve: its blocks were promised at admission.
//! Requests whose worst case exceeds the *total* capacity are rejected
//! at submit time with a clear error — they could never be scheduled.
//!
//! ## Recycling
//!
//! When a request finishes (or is cancelled) its cache releases every
//! block back to the free list and the reservation is dropped. Freed
//! blocks keep their (stale) contents; that is safe because attention
//! at position `p` only reads rows `0..=p`, each written earlier by the
//! *current* request before being read — and under a quantized scheme
//! each row's codec blocks (including the zero padding tail) are
//! rewritten whole at append time, so stale encoded bytes are never
//! decoded. The free list is pre-reserved to `capacity`, so
//! steady-state recycling performs zero heap allocations — after warmup
//! every admission is served from the free list
//! ([`KvBlockPool::created`] stops growing, asserted by the
//! counting-allocator test in `tests/continuous_batching.rs`).

use crate::quant::KvScheme;
use anyhow::{bail, Result};

/// One fixed-size page of KV state: `block_tokens` positions across all
/// layers of a single slot's cache. Created by [`KvBlockPool::take`],
/// returned by [`KvBlockPool::put`]; owned by at most one cache at a
/// time. Exactly one plane pair is allocated, per the pool's scheme.
pub struct KvBlock {
    /// `[n_layers][block_tokens][width]` main KV plane
    /// ([`KvScheme::F32`] only; empty under a quantized scheme).
    pub(crate) data: Vec<f32>,
    /// `[n_layers][block_tokens][xwidth]` absorbed-MLA expanded plane
    /// (empty when `xwidth == 0` or the scheme is quantized).
    pub(crate) xdata: Vec<f32>,
    /// `[n_layers][block_tokens][scheme.line_bytes(width)]` encoded main
    /// plane (quantized schemes only; empty under f32).
    pub(crate) qdata: Vec<u8>,
    /// `[n_layers][block_tokens][scheme.line_bytes(xwidth)]` encoded
    /// expanded plane (quantized schemes with `xwidth > 0` only).
    pub(crate) xqdata: Vec<u8>,
}

/// The fixed-capacity block pool a [`ContinuousScheduler`]'s paged
/// caches allocate from.
///
/// [`ContinuousScheduler`]: crate::coordinator::scheduler::ContinuousScheduler
pub struct KvBlockPool {
    n_layers: usize,
    width: usize,
    xwidth: usize,
    scheme: KvScheme,
    block_tokens: usize,
    capacity: usize,
    /// Recycled blocks, pre-reserved to `capacity` so `put` never
    /// reallocates.
    free: Vec<KvBlock>,
    outstanding: usize,
    reserved: usize,
    created: usize,
    peak_outstanding: usize,
}

impl KvBlockPool {
    pub(crate) fn new(
        n_layers: usize,
        width: usize,
        xwidth: usize,
        scheme: KvScheme,
        block_tokens: usize,
        capacity: usize,
    ) -> Result<Self> {
        if block_tokens == 0 {
            bail!("KV block pool needs block_tokens ≥ 1");
        }
        if capacity == 0 {
            bail!("KV block pool needs capacity ≥ 1 block");
        }
        Ok(KvBlockPool {
            n_layers,
            width,
            xwidth,
            scheme,
            block_tokens,
            capacity,
            free: Vec::with_capacity(capacity),
            outstanding: 0,
            reserved: 0,
            created: 0,
            peak_outstanding: 0,
        })
    }

    /// Whether this pool's block layout matches a cache/model shape and
    /// KV scheme (a cache must never draw blocks whose planes were
    /// sized for a different encoding).
    pub(crate) fn matches(
        &self,
        n_layers: usize,
        width: usize,
        xwidth: usize,
        scheme: KvScheme,
    ) -> bool {
        self.n_layers == n_layers
            && self.width == width
            && self.xwidth == xwidth
            && self.scheme == scheme
    }

    /// Tokens per block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// The KV encoding this pool's block planes are sized for.
    pub fn scheme(&self) -> KvScheme {
        self.scheme
    }

    /// Bytes one cached position occupies across all layers under this
    /// pool's scheme — encoded row + expanded plane, including the
    /// padding that rounds each row up to the codec's block grid.
    pub fn bytes_per_token(&self) -> usize {
        self.n_layers * (self.scheme.line_bytes(self.width) + self.scheme.line_bytes(self.xwidth))
    }

    /// Total payload bytes one block allocates (reservation arithmetic
    /// is expressed in these blocks-of-bytes, not f32 plane widths).
    pub fn block_bytes(&self) -> usize {
        self.block_tokens * self.bytes_per_token()
    }

    /// Total blocks this pool may ever hand out at once.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Blocks currently held by caches.
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// Blocks currently promised to admitted requests.
    pub fn reserved(&self) -> usize {
        self.reserved
    }

    /// Recycled blocks waiting on the free list.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks ever heap-allocated (monotone; stops growing once the
    /// free list covers the working set — the zero-alloc gate).
    pub fn created(&self) -> usize {
        self.created
    }

    /// High-water mark of `outstanding` — bounded by the sum of
    /// concurrent reservations, hence by `capacity` (the analytic bound
    /// the property tests check).
    pub fn peak_outstanding(&self) -> usize {
        self.peak_outstanding
    }

    /// Promise `n` blocks to a request about to be admitted. Returns
    /// `false` (promising nothing) when the pool cannot cover the
    /// request's worst case on top of existing promises — the caller
    /// leaves the request queued.
    pub fn try_reserve(&mut self, n: usize) -> bool {
        if self.reserved + n > self.capacity {
            return false;
        }
        self.reserved += n;
        true
    }

    /// Drop `n` promised blocks (request finished or cancelled).
    pub fn unreserve(&mut self, n: usize) {
        debug_assert!(n <= self.reserved, "unreserve {n} > reserved {}", self.reserved);
        self.reserved = self.reserved.saturating_sub(n);
    }

    /// Hand out one block, recycled from the free list when possible.
    /// Every take must be covered by a prior [`KvBlockPool::try_reserve`]
    /// — taking beyond the reserved count is a scheduler bug, reported
    /// as an error rather than silently overcommitting.
    pub(crate) fn take(&mut self) -> Result<KvBlock> {
        if self.outstanding >= self.reserved {
            bail!(
                "KV block pool: take without a covering reservation \
                 ({} outstanding, {} reserved, {} capacity) — admission must \
                 reserve a request's worst-case blocks before it grows a cache",
                self.outstanding,
                self.reserved,
                self.capacity
            );
        }
        self.outstanding += 1;
        self.peak_outstanding = self.peak_outstanding.max(self.outstanding);
        if let Some(b) = self.free.pop() {
            return Ok(b);
        }
        self.created += 1;
        let slots = self.n_layers * self.block_tokens;
        Ok(match self.scheme {
            KvScheme::F32 => KvBlock {
                data: vec![0.0; slots * self.width],
                xdata: vec![0.0; slots * self.xwidth],
                qdata: Vec::new(),
                xqdata: Vec::new(),
            },
            s => KvBlock {
                data: Vec::new(),
                xdata: Vec::new(),
                qdata: vec![0; slots * s.line_bytes(self.width)],
                xqdata: vec![0; slots * s.line_bytes(self.xwidth)],
            },
        })
    }

    /// Return a block to the free list (contents left stale — see the
    /// module docs for why that is safe).
    pub(crate) fn put(&mut self, b: KvBlock) {
        let slots = self.n_layers * self.block_tokens;
        match self.scheme {
            KvScheme::F32 => debug_assert_eq!(b.data.len(), slots * self.width),
            s => debug_assert_eq!(b.qdata.len(), slots * s.line_bytes(self.width)),
        }
        debug_assert!(self.outstanding > 0, "put with nothing outstanding");
        self.outstanding -= 1;
        self.free.push(b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(capacity: usize) -> KvBlockPool {
        KvBlockPool::new(2, 8, 0, KvScheme::F32, 4, capacity).unwrap()
    }

    #[test]
    fn take_requires_a_reservation() {
        let mut p = pool(4);
        let err = p.take().unwrap_err().to_string();
        assert!(err.contains("reservation"), "{err}");
        assert!(p.try_reserve(2));
        let a = p.take().unwrap();
        let b = p.take().unwrap();
        assert!(p.take().is_err(), "third take exceeds the 2-block reservation");
        assert_eq!(p.outstanding(), 2);
        p.put(a);
        p.put(b);
        p.unreserve(2);
        assert_eq!((p.outstanding(), p.reserved(), p.free_blocks()), (0, 0, 2));
    }

    #[test]
    fn reservations_are_capacity_bounded() {
        let mut p = pool(3);
        assert!(p.try_reserve(2));
        assert!(!p.try_reserve(2), "2+2 > 3 must fail without promising anything");
        assert_eq!(p.reserved(), 2);
        assert!(p.try_reserve(1));
        assert!(!p.try_reserve(1));
    }

    #[test]
    fn recycling_serves_from_the_free_list() {
        let mut p = pool(2);
        assert!(p.try_reserve(1));
        let a = p.take().unwrap();
        p.put(a);
        p.unreserve(1);
        assert_eq!(p.created(), 1);
        assert!(p.try_reserve(1));
        let _b = p.take().unwrap();
        assert_eq!(p.created(), 1, "recycled take must not heap-allocate a new block");
        assert_eq!(p.peak_outstanding(), 1);
    }

    #[test]
    fn quantized_blocks_are_sized_in_encoded_bytes() {
        // width 8 pads to one 32-weight Q8_0 block (34 B) per row; the
        // f32 plane would have been 8 · 4 = 32 B — the accounting must
        // come from the codec grid, not the plane width.
        let mut p = KvBlockPool::new(2, 8, 8, KvScheme::Q8_0, 3, 4).unwrap();
        assert_eq!(p.scheme(), KvScheme::Q8_0);
        assert_eq!(p.bytes_per_token(), 2 * (34 + 34));
        assert_eq!(p.block_bytes(), 3 * 2 * 68);
        assert!(p.try_reserve(1));
        let b = p.take().unwrap();
        assert!(b.data.is_empty() && b.xdata.is_empty(), "no f32 planes under q8_0");
        assert_eq!(b.qdata.len(), 2 * 3 * 34);
        assert_eq!(b.xqdata.len(), 2 * 3 * 34);
        assert!(b.qdata.iter().all(|&x| x == 0), "fresh blocks are zeroed");
        p.put(b);
        p.unreserve(1);
        // An f32 pool of the same shape reports the un-padded footprint.
        let f = pool(4);
        assert_eq!(f.bytes_per_token(), 2 * 8 * 4);
        assert!(!f.matches(2, 8, 0, KvScheme::Q8_0), "scheme is part of the layout");
        assert!(f.matches(2, 8, 0, KvScheme::F32));
    }

    #[test]
    fn degenerate_pools_are_rejected() {
        assert!(KvBlockPool::new(1, 4, 0, KvScheme::F32, 0, 4).is_err());
        assert!(KvBlockPool::new(1, 4, 0, KvScheme::F32, 4, 0).is_err());
    }
}
