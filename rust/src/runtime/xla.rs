//! Offline stand-in for the `xla` (PJRT) bindings.
//!
//! The serving runtime was written against the `xla_extension` Rust
//! bindings, which need a multi-gigabyte native XLA build that is not
//! vendorable in this repository. This module keeps the exact API
//! surface [`super`] uses — literals, client, compiled executable — so
//! the whole crate (weight loader, codecs, coordinator logic, CLI)
//! builds and tests offline:
//!
//! - **Literals are fully functional**: shape/dtype-checked byte
//!   buffers with typed readback. The weight-loader path (decode +
//!   marshal) is real and covered by tests.
//! - **Compilation is unavailable**: [`PjRtClient::compile`] returns a
//!   clear error, so `Engine::load` fails gracefully on hosts without
//!   the native backend instead of at link time. Swapping the real
//!   bindings back in is a one-line change (replace this module with
//!   the `xla` crate dependency); nothing else in `runtime` needs to
//!   move.
//! - **Execution has a CPU fallback**: hosts without PJRT can still
//!   serve through [`super::native`] (`Engine::load_native`), which
//!   runs decode steps directly on quantized container payloads via
//!   the fused `quant::kernels` matvec — the compile error below
//!   points there.

use std::fmt;
use std::path::Path;

/// Error type mirroring the bindings' debug-printable error.
pub struct XlaError {
    pub msg: String,
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for XlaError {}

fn err<T>(msg: impl Into<String>) -> Result<T, XlaError> {
    Err(XlaError { msg: msg.into() })
}

/// Element types the manifests use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    U8,
    S32,
}

impl ElementType {
    pub fn size(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::U8 => 1,
        }
    }
}

/// Sealed-ish helper for typed literal readback.
pub trait NativeType: Sized {
    const TY: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes(bytes.try_into().expect("4-byte chunk"))
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes(bytes.try_into().expect("4-byte chunk"))
    }
}

impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
    fn from_le(bytes: &[u8]) -> Self {
        bytes[0]
    }
}

/// A host tensor: dtype + dims + little-endian bytes. Tuple literals
/// (the executable's multi-output form) carry elements instead.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Build a literal from raw bytes, validating the byte count
    /// against `dims` × element size.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal, XlaError> {
        let expect: usize = dims.iter().product::<usize>() * ty.size();
        if data.len() != expect {
            return err(format!(
                "literal shape {dims:?} ({ty:?}) needs {expect} bytes, got {}",
                data.len()
            ));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec(), tuple: None })
    }

    /// Wrap several literals as one tuple literal.
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { ty: ElementType::U8, dims: vec![], bytes: vec![], tuple: Some(elements) }
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn raw_bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Typed readback (row-major).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        if self.tuple.is_some() {
            return err("to_vec on a tuple literal");
        }
        if self.ty != T::TY {
            return err(format!("literal is {:?}, asked for {:?}", self.ty, T::TY));
        }
        Ok(self
            .bytes
            .chunks_exact(self.ty.size())
            .map(T::from_le)
            .collect())
    }

    /// Unpack a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        match self.tuple {
            Some(elements) => Ok(elements),
            None => err("to_tuple on a non-tuple literal"),
        }
    }
}

/// Parsed HLO module (text form). The stub only retains the text; the
/// real bindings parse it into a proto.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        match std::fs::read_to_string(Path::new(path)) {
            Ok(text) => Ok(HloModuleProto { text }),
            Err(e) => err(format!("reading HLO text {path}: {e}")),
        }
    }
}

/// A computation handle (opaque in the stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    pub hlo_text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { hlo_text: proto.text.clone() }
    }
}

/// PJRT client handle. Construction succeeds (it is cheap and lets
/// callers get as far as manifest + weight validation); compilation is
/// where the stub reports the missing backend.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

pub const BACKEND_UNAVAILABLE: &str =
    "PJRT backend unavailable: this build uses the offline xla stub \
     (rust/src/runtime/xla.rs); install the xla_extension bindings and swap \
     the stub for the real crate to execute HLO artifacts, or serve with \
     the native CPU matvec backend (`dsq serve --native`)";

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        err(BACKEND_UNAVAILABLE)
    }
}

/// A compiled executable. Unreachable through the stub client (compile
/// always errors), but the type keeps `Phase`/`Engine` well-formed.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        err(BACKEND_UNAVAILABLE)
    }
}

/// A device buffer (host-resident in the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Ok(self.lit.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.5f32, -2.0, 0.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.dims(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_validates_byte_count() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[4], &[0u8; 15])
                .is_err()
        );
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::U8, &[2, 3], &[0u8; 6])
                .is_ok()
        );
    }

    #[test]
    fn tuple_literals_unpack() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::U8, &[1], &[7]).unwrap();
        let t = Literal::tuple(vec![a.clone()]);
        let elems = t.to_tuple().unwrap();
        assert_eq!(elems.len(), 1);
        assert_eq!(elems[0].raw_bytes(), a.raw_bytes());
        assert!(a.to_tuple().is_err());
    }

    #[test]
    fn stub_client_reports_missing_backend() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation { hlo_text: String::new() };
        let e = client.compile(&comp).err().unwrap();
        assert!(format!("{e:?}").contains("offline xla stub"));
    }
}
