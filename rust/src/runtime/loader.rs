//! Weight-payload preparation for the serving engine — the
//! checkpoint→literal decode path of `Phase::load`, kept free of any
//! PJRT types so it is testable without compiled artifacts.
//!
//! For every manifest weight input the loader either
//!
//! - **passes the container payload through** when the container's
//!   stored format already matches what the manifest declares (packed
//!   quantized bytes the HLO graph dequantizes in-kernel, or raw f32),
//!   or
//! - **decodes to f32 at load time** when the manifest wants `f32`
//!   weights (`dtype: f32`, no/`"f32"` format field) but the container
//!   stores a quantized payload.
//!
//! Decoding fans out over the same scoped-thread work-queue pattern as
//! `container::quantize_container`: workers claim tensors from an
//! atomic cursor, keep per-worker scratch, and results are assembled in
//! manifest order, so the output is byte-identical at any thread count.
//! Inside each worker the per-block decode runs through the
//! lane-chunked batch kernels in `quant::kernels` (scalar reference
//! under `DSQ_SCALAR_DECODE=1` — bit-identical either way), so
//! load-time dequantization rides the same fast read path as the fused
//! serving matvec.
//! The thread budget is split by [`crate::quant::parallel::fan_out`] —
//! many tensors get one worker each, while a single giant tensor is
//! split at *block* granularity through
//! [`crate::quant::dequantize_into_with`], so the 671B-census case
//! (few, huge expert matrices) also scales.

use crate::container::Container;
use crate::quant::{self, parallel, QuantFormat};
use crate::runtime::manifest::{Dtype, IoSpec, Manifest, Role};
use anyhow::{anyhow, bail, Context, Result};

/// One prepared weight payload, in manifest weight-input order.
pub enum WeightBytes<'a> {
    /// Container payload used as-is (format matches the manifest).
    Raw(&'a [u8]),
    /// Payload decoded to little-endian f32 at load time.
    Decoded(Vec<u8>),
}

impl WeightBytes<'_> {
    pub fn as_slice(&self) -> &[u8] {
        match self {
            WeightBytes::Raw(b) => b,
            WeightBytes::Decoded(v) => v,
        }
    }
}

/// The format a weight spec declares; absent means raw `"f32"`.
fn spec_format(spec: &IoSpec) -> &str {
    spec.format.as_deref().unwrap_or("f32")
}

/// The container/manifest format-mismatch error. One message for every
/// arm — the manifest default is reported as `f32`, never `"?"`.
pub fn format_mismatch(name: &str, container_fmt: &str, manifest_fmt: &str) -> String {
    format!(
        "tensor {name}: container format {container_fmt} != manifest {manifest_fmt}; \
         re-run `dsq quantize` with the matching scheme"
    )
}

struct Job<'a> {
    name: &'a str,
    bytes: &'a [u8],
    /// `Some((format, n_elems))` when the payload must be decoded to
    /// f32; `None` for raw passthrough.
    decode: Option<(QuantFormat, usize)>,
}

fn decode_one(job: &Job<'_>, inner_threads: usize, scratch: &mut Vec<f32>) -> Result<Vec<u8>> {
    let (fmt, n) = job.decode.expect("decode_one called on a raw job");
    scratch.resize(n, 0.0);
    quant::dequantize_into_with(fmt, job.bytes, scratch, inner_threads)
        .with_context(|| format!("decoding tensor {}", job.name))?;
    let mut out = vec![0u8; n * 4];
    for (dst, v) in out.chunks_exact_mut(4).zip(scratch.iter()) {
        dst.copy_from_slice(&v.to_le_bytes());
    }
    Ok(out)
}

/// Validate the manifest's weight inputs against `ckpt` and produce
/// their payload bytes in manifest order, decoding quantized tensors to
/// f32 where the manifest asks for it. `threads` bounds the total
/// worker budget (tensor-level × block-level); the result is
/// byte-identical for every thread count.
pub fn prepare_weights<'a>(
    manifest: &Manifest,
    ckpt: &'a Container,
    threads: usize,
) -> Result<Vec<WeightBytes<'a>>> {
    // Validation pass (serial, cheap): classify every weight input.
    let mut jobs: Vec<Job<'a>> = Vec::new();
    for spec in manifest.inputs.iter().filter(|s| s.role == Role::Weight) {
        let spec_name = spec
            .name
            .as_deref()
            .ok_or_else(|| anyhow!("weight input without a name in {} manifest", manifest.phase))?;
        let entry = ckpt
            .tensor(spec_name)
            .with_context(|| format!("checkpoint {}", ckpt.scheme_name))?;
        // Borrow the name from the container entry so the job outlives
        // the manifest borrow.
        let name: &'a str = entry.name.as_str();
        let want = spec_format(spec);
        let bytes = ckpt.bytes(entry);
        if entry.format.name() == want {
            let expect: usize = spec.shape.iter().product::<usize>() * spec.dtype.size();
            if bytes.len() != expect {
                bail!(
                    "tensor {name}: payload {} bytes != manifest expectation {expect}",
                    bytes.len()
                );
            }
            jobs.push(Job { name, bytes, decode: None });
        } else if want == "f32" && spec.dtype == Dtype::F32 {
            // Manifest wants dequantized weights; decode at load time.
            let n: usize = spec.shape.iter().product();
            if n != entry.n_elems() {
                bail!(
                    "tensor {name}: manifest shape {:?} ({n} elems) != container element count {}",
                    spec.shape,
                    entry.n_elems()
                );
            }
            jobs.push(Job { name, bytes, decode: Some((entry.format, n)) });
        } else {
            bail!(format_mismatch(name, entry.format.name(), want));
        }
    }

    // Decode fan-out: tensor-level work queue (shared with the
    // container pipeline — `parallel::run_queue`), block-level split
    // inside each worker when the budget allows.
    let decode_idx: Vec<usize> = jobs
        .iter()
        .enumerate()
        .filter(|(_, j)| j.decode.is_some())
        .map(|(i, _)| i)
        .collect();
    let mut decoded: Vec<Option<Vec<u8>>> = (0..jobs.len()).map(|_| None).collect();
    if !decode_idx.is_empty() {
        let (workers, inner) = parallel::fan_out(threads, decode_idx.len());
        let results = parallel::run_queue(
            decode_idx.len(),
            workers,
            Vec::new,
            |scratch: &mut Vec<f32>, k: usize| decode_one(&jobs[decode_idx[k]], inner, scratch),
        );
        // Assemble in manifest order — identical bytes at any count.
        for (k, r) in results.into_iter().enumerate() {
            decoded[decode_idx[k]] = Some(r?);
        }
    }

    Ok(jobs
        .iter()
        .zip(decoded.iter_mut())
        .map(|(job, d)| match d.take() {
            Some(v) => WeightBytes::Decoded(v),
            None => WeightBytes::Raw(job.bytes),
        })
        .collect())
}

/// A synthetic manifest declaring every tensor of `ckpt` as an f32
/// weight input — the decode-direction fixture used by `dsq selfcheck`
/// and the loader property tests (no compiled artifacts needed).
pub fn f32_weight_manifest(ckpt: &Container) -> Manifest {
    let inputs = ckpt
        .tensors
        .iter()
        .map(|t| IoSpec {
            role: Role::Weight,
            name: Some(t.name.clone()),
            format: None,
            shape: t.shape.clone(),
            dtype: Dtype::F32,
        })
        .collect();
    Manifest {
        model_name: ckpt.model.name.clone(),
        scheme: ckpt.scheme_name.clone(),
        phase: "selfcheck".to_string(),
        batch: 1,
        prompt_len: 1,
        max_ctx: 1,
        vocab: 1,
        inputs,
        outputs: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{quantize_container_with, synthetic_f32_container};
    use crate::model::ModelConfig;
    use crate::scheme::builtin;

    fn quantized_tiny_moe() -> Container {
        let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 0x10AD).unwrap();
        let scheme = builtin::scheme("dq3_k_m").unwrap();
        Container::from_bytes(
            quantize_container_with(&src, &scheme, None, 1)
                .unwrap()
                .to_bytes(),
        )
        .unwrap()
    }

    #[test]
    fn decode_direction_matches_container_dequantize() {
        let q = quantized_tiny_moe();
        let manifest = f32_weight_manifest(&q);
        let payloads = prepare_weights(&manifest, &q, 1).unwrap();
        assert_eq!(payloads.len(), q.tensors.len());
        for (t, p) in q.tensors.iter().zip(&payloads) {
            let want = q.dequantize(t).unwrap();
            let got: Vec<f32> = p
                .as_slice()
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            assert_eq!(got, want, "tensor {}", t.name);
            // f32 tensors pass through without copying.
            if t.format == QuantFormat::F32 {
                assert!(matches!(p, WeightBytes::Raw(_)), "tensor {}", t.name);
            } else {
                assert!(matches!(p, WeightBytes::Decoded(_)), "tensor {}", t.name);
            }
        }
    }

    #[test]
    fn mismatch_message_consistent_for_default_and_explicit_formats() {
        let q = quantized_tiny_moe();
        // Find a quantized tensor and ask for it with a wrong dtype so
        // the default-format arm trips.
        let t = q
            .tensors
            .iter()
            .find(|t| t.format != QuantFormat::F32)
            .unwrap();
        let mut manifest = f32_weight_manifest(&q);
        manifest.inputs.retain(|s| s.name.as_deref() == Some(t.name.as_str()));
        manifest.inputs[0].dtype = Dtype::U8;
        let e = prepare_weights(&manifest, &q, 1).err().unwrap();
        let msg = format!("{e:#}");
        assert!(
            msg.contains("!= manifest f32"),
            "default format must be reported as f32, got: {msg}"
        );
        assert!(!msg.contains("manifest ?"), "got: {msg}");

        // Explicit wrong format reports that format.
        let other = if t.format.name() == "q6_k" { "q4_k" } else { "q6_k" };
        manifest.inputs[0].dtype = Dtype::U8;
        manifest.inputs[0].format = Some(other.to_string());
        let e = prepare_weights(&manifest, &q, 1).err().unwrap();
        assert!(format!("{e:#}").contains(&format!("!= manifest {other}")));
    }

    #[test]
    fn missing_tensor_and_bad_shape_rejected() {
        let q = quantized_tiny_moe();
        let mut manifest = f32_weight_manifest(&q);
        manifest.inputs[0].name = Some("no.such.tensor".to_string());
        assert!(prepare_weights(&manifest, &q, 1).is_err());

        let mut manifest = f32_weight_manifest(&q);
        manifest.inputs[0].shape = vec![1, 7]; // wrong element count
        assert!(prepare_weights(&manifest, &q, 2).is_err());
    }
}
