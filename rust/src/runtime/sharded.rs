//! Sharded execution of the native forward pass: the Table-2 8-device
//! deployment plan run as N cooperating worker threads, each holding
//! **only its own encoded weight slices** (the memory partition
//! `memory::shard_weights` predicts and `memory/devices.rs` plans on
//! paper).
//!
//! ## Partition map
//!
//! Built once from the container by [`ShardRuntime::new`], mirroring
//! exactly what [`crate::memory::shard_weights`] computes analytically:
//!
//! - **3-D expert-stacked tensors** (`ffn_{gate,up,down}_exps`,
//!   `[n_exp, out, in]`) are **expert-parallel**: shard `s` owns the
//!   contiguous expert range [`expert_range`]`(n_exp, n, s)` and copies
//!   only those experts' encoded bytes.
//! - **2-D matmul weights** (all attention projections, dense/shared
//!   FFNs, the router, the unembedding) are **output-row
//!   tensor-parallel**: shard `s` owns row range [`row_range`]
//!   `(rows, n, s)` — k-quant rows are whole blocks, so a row range is
//!   a contiguous slice of the encoded payload.
//! - Everything else (`token_embd.weight`, the f32 norm vectors, any
//!   1-D tensor) stays on the **driver**, which also keeps the opened
//!   container as the host checkpoint image.
//!
//! ## Execution model
//!
//! The driver thread runs all sequential glue — embedding, RMSNorm,
//! RoPE, attention scores/softmax, routing, SiLU, residual adds and the
//! MoE weighted combine — through literally the same code as the
//! unsharded engine. Only the fused matmuls fan out:
//!
//! - a **row-split matvec/GEMM** sends one job to every shard; shard
//!   `s` computes output rows `r0..r1` into its disjoint range of the
//!   shared output plane (a preallocated scratch buffer);
//! - a **routed-expert MLP** is sent to the one shard owning that
//!   expert, which runs the whole gate/up/SiLU/down pipeline locally
//!   and writes the expert's output rows into its disjoint slice of
//!   the expert-output plane.
//!
//! Each dispatch ends in an explicit **barrier** (the driver blocks
//! until every job acknowledges) — the all-gather exchange step: after
//! it, the output plane is fully materialized and the driver's
//! sequential glue proceeds. [`ShardRuntime::exchanges`] /
//! [`ShardRuntime::exchange_wait_ns`] count these barriers and the time
//! the driver spent inside them (the exchange overhead
//! `benches/sharded.rs` reports).
//!
//! ## Why logits are bit-identical to the unsharded engine
//!
//! No floating-point sum is ever split across shards:
//!
//! - Row-split keeps every output element's **complete** canonical
//!   8-lane dot product on exactly one shard — per-row dots are
//!   independent of surrounding rows, so computing rows `r0..r1` from
//!   the sliced bytes is the same arithmetic as the unsharded kernel's
//!   rows `r0..r1`. No cross-shard reduction exists, hence no
//!   reassociation.
//! - Expert-parallel MoE computes each routed expert's MLP whole on
//!   its owner; the **driver** then folds the weighted outputs in
//!   ascending global expert order — the PR 6 combine contract,
//!   independent of which shard produced which output.
//! - All remaining arithmetic runs on the driver, unchanged.
//!
//! Hence logits are bit-identical for every shard count (the
//! `tests/sharded_identity.rs` differential suite and `dsq selfcheck`
//! pin shards {1, 2, 4, 8} against the unsharded engine across both
//! model kinds, both headline schemes and every dispatch arm).

use crate::container::{Container, TensorEntry};
use crate::quant::QuantFormat;
use crate::runtime::forward::{self, MatvecMode};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Most shards a runtime will spin up — far beyond the 8-device node
/// the paper deploys; a guard against nonsense CLI input.
pub const MAX_SHARDS: usize = 64;

/// How long the driver waits on a shard barrier before declaring the
/// worker wedged (internal-bug guard; normal exchanges are µs–ms).
const BARRIER_TIMEOUT: Duration = Duration::from_secs(120);

/// Output rows owned by shard `s` of `n`: the contiguous range
/// `[rows·s/n, rows·(s+1)/n)` — a balanced partition (sizes differ by
/// at most one row) that is exhaustive and disjoint for any `rows`.
pub fn row_range(rows: usize, n_shards: usize, s: usize) -> (usize, usize) {
    (rows * s / n_shards, rows * (s + 1) / n_shards)
}

/// Experts owned by shard `s` of `n` — same balanced contiguous
/// partition as [`row_range`] (256 experts over 8 shards is 32 per
/// shard, the Table-2 per-device expert count).
pub fn expert_range(n_experts: usize, n_shards: usize, s: usize) -> (usize, usize) {
    row_range(n_experts, n_shards, s)
}

/// The shard whose [`expert_range`] contains expert `e`.
pub fn expert_owner(n_experts: usize, n_shards: usize, e: usize) -> usize {
    debug_assert!(e < n_experts);
    (0..n_shards)
        .find(|&s| {
            let (a, b) = expert_range(n_experts, n_shards, s);
            e >= a && e < b
        })
        .expect("expert ranges are exhaustive")
}

/// A read-only f32 plane handed to a worker by raw pointer. Safe by
/// protocol: the driver keeps the backing buffer borrowed (and does not
/// mutate it) until the dispatch barrier completes.
struct SendPtr(*const f32, usize);
unsafe impl Send for SendPtr {}

/// A writable f32 plane handed to a worker by raw pointer. Safe by
/// protocol: every concurrently dispatched job writes a disjoint
/// sub-range (row ranges / expert plane segments are disjoint by
/// construction) and the driver blocks on the barrier before reading
/// or releasing the buffer.
struct SendPtrMut(*mut f32, usize);
unsafe impl Send for SendPtrMut {}

impl SendPtr {
    fn new(x: &[f32]) -> Self {
        SendPtr(x.as_ptr(), x.len())
    }
    /// Reconstruct the slice inside the worker.
    unsafe fn get(&self) -> &[f32] {
        std::slice::from_raw_parts(self.0, self.1)
    }
}

impl SendPtrMut {
    fn new(x: &mut [f32]) -> Self {
        SendPtrMut(x.as_mut_ptr(), x.len())
    }
    /// Reconstruct a sub-slice `[at, at + len)` inside the worker.
    unsafe fn get(&self, at: usize, len: usize) -> &mut [f32] {
        debug_assert!(at + len <= self.1);
        std::slice::from_raw_parts_mut(self.0.add(at), len)
    }
}

/// One shard's local copy of one tensor's slice.
enum WorkerSlice {
    /// Output-row range `r0..r1` of a 2-D weight (encoded bytes of
    /// exactly those rows).
    Rows { fmt: QuantFormat, bytes: Vec<u8>, r0: usize, r1: usize },
    /// Expert range `e0..e1` of a 3-D expert stack (`per` encoded bytes
    /// per expert).
    Experts { fmt: QuantFormat, bytes: Vec<u8>, e0: usize, per: usize },
}

/// Driver-side view of how each container tensor was partitioned.
#[derive(Debug, Clone, Copy)]
enum SliceMeta {
    Rows { rows: usize },
    Experts { n_exp: usize },
    /// Driver-held (embedding, norms, 1-D): never dispatched.
    Driver,
}

enum Job {
    /// Row-split matvec: every shard computes its own `r0..r1` rows of
    /// `out`.
    Matvec { tid: usize, x: SendPtr, out: SendPtrMut, mode: MatvecMode },
    /// Row-split GEMM staging: every shard fills rows `r0..r1` of the
    /// row-major `[rows][t]` staging plane (the driver transposes).
    MatStage { tid: usize, xs: SendPtr, n: usize, t: usize, mat: SendPtrMut, mode: MatvecMode },
    /// One routed expert's full gate/up/SiLU/down MLP on its owner.
    ExpertMlp {
        gid: usize,
        uid: usize,
        did: usize,
        e: usize,
        x: SendPtr,
        y: SendPtrMut,
        inter: usize,
        mode: MatvecMode,
    },
    /// Panel variant: the expert's MLP over `t` gathered tokens
    /// (`xs` token-major `[t][n]`, output `[t][hs]` at `y_at`).
    ExpertMlpPanel {
        gid: usize,
        uid: usize,
        did: usize,
        e: usize,
        xs: SendPtr,
        n: usize,
        t: usize,
        y: SendPtrMut,
        y_at: usize,
        inter: usize,
        mode: MatvecMode,
    },
    Stop,
}

/// The job/ack channels, behind one lock: a dispatch (send every job,
/// then drain exactly that many acks) must be exclusive — there is a
/// single ack stream — and the `Mutex` also keeps [`ShardRuntime`]
/// `Sync` (mpsc endpoints are not).
struct Channels {
    txs: Vec<Sender<Job>>,
    done_rx: Receiver<Result<(), String>>,
}

/// N persistent shard worker threads plus the partition bookkeeping —
/// created by [`ShardRuntime::new`], owned by
/// [`crate::runtime::forward::ForwardPass`] (see
/// `ForwardPass::set_sharding`).
pub struct ShardRuntime {
    n: usize,
    chan: Mutex<Channels>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Container tensor index by payload offset (unique per tensor).
    lookup: HashMap<usize, usize>,
    metas: Vec<SliceMeta>,
    /// Per-shard (tensor name, resident slice bytes) — the loader-side
    /// truth the planner test diffs against `memory::shard_weights`.
    plan: Vec<Vec<(String, u64)>>,
    resident: Vec<u64>,
    exchanges: AtomicU64,
    exchange_wait_ns: AtomicU64,
}

fn classify(t: &TensorEntry) -> Option<SliceMeta> {
    if t.shape.len() == 3 {
        t.format.row_bytes(t.shape[2]).ok()?;
        Some(SliceMeta::Experts { n_exp: t.shape[0] })
    } else if t.shape.len() == 2 && t.name != "token_embd.weight" {
        t.format.row_bytes(t.shape[1]).ok()?;
        Some(SliceMeta::Rows { rows: t.shape[0] })
    } else {
        None
    }
}

impl ShardRuntime {
    /// Partition `ckpt` across `n` shard workers. Each worker gets real
    /// owned copies of its weight slices (so per-shard resident bytes
    /// are genuinely allocated and measurable); the driver keeps the
    /// container itself as the host image for the embedding and norms.
    pub fn new(ckpt: &Container, n: usize) -> Result<Self> {
        if n == 0 || n > MAX_SHARDS {
            bail!("shard count {n} out of range 1..={MAX_SHARDS}");
        }
        let nt = ckpt.tensors.len();
        let mut lookup = HashMap::with_capacity(nt);
        let mut metas = Vec::with_capacity(nt);
        let mut tables: Vec<Vec<Option<WorkerSlice>>> =
            (0..n).map(|_| Vec::with_capacity(nt)).collect();
        let mut plan: Vec<Vec<(String, u64)>> = vec![Vec::new(); n];
        let mut resident = vec![0u64; n];
        for (tid, t) in ckpt.tensors.iter().enumerate() {
            if lookup.insert(t.offset, tid).is_some() {
                bail!("container tensors alias payload offset {}", t.offset);
            }
            let data = ckpt.bytes(t);
            let meta = classify(t);
            metas.push(meta.unwrap_or(SliceMeta::Driver));
            match meta {
                Some(SliceMeta::Experts { n_exp }) => {
                    let per = t.format.row_bytes(t.shape[2])? * t.shape[1];
                    for (s, table) in tables.iter_mut().enumerate() {
                        let (e0, e1) = expert_range(n_exp, n, s);
                        let bytes = data[e0 * per..e1 * per].to_vec();
                        resident[s] += bytes.len() as u64;
                        plan[s].push((t.name.clone(), bytes.len() as u64));
                        table.push(Some(WorkerSlice::Experts { fmt: t.format, bytes, e0, per }));
                    }
                }
                Some(SliceMeta::Rows { rows }) => {
                    let rb = t.format.row_bytes(t.shape[1])?;
                    for (s, table) in tables.iter_mut().enumerate() {
                        let (r0, r1) = row_range(rows, n, s);
                        let bytes = data[r0 * rb..r1 * rb].to_vec();
                        resident[s] += bytes.len() as u64;
                        plan[s].push((t.name.clone(), bytes.len() as u64));
                        table.push(Some(WorkerSlice::Rows { fmt: t.format, bytes, r0, r1 }));
                    }
                }
                _ => {
                    for table in tables.iter_mut() {
                        table.push(None);
                    }
                }
            }
        }
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        let mut txs = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n);
        for (s, table) in tables.into_iter().enumerate() {
            let (tx, rx) = std::sync::mpsc::channel::<Job>();
            let done = done_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dsq-shard-{s}"))
                    .spawn(move || worker_loop(table, rx, done))?,
            );
            txs.push(tx);
        }
        Ok(ShardRuntime {
            n,
            chan: Mutex::new(Channels { txs, done_rx }),
            workers,
            lookup,
            metas,
            plan,
            resident,
            exchanges: AtomicU64::new(0),
            exchange_wait_ns: AtomicU64::new(0),
        })
    }

    pub fn n_shards(&self) -> usize {
        self.n
    }

    /// Encoded weight bytes resident on each shard (the measured side
    /// of the planner-vs-engine validation).
    pub fn resident_bytes(&self) -> &[u64] {
        &self.resident
    }

    /// Per-shard per-tensor resident bytes, in container tensor order.
    pub fn shard_plan(&self) -> &[Vec<(String, u64)>] {
        &self.plan
    }

    /// Barrier/all-gather exchange steps completed so far.
    pub fn exchanges(&self) -> u64 {
        self.exchanges.load(Ordering::Relaxed)
    }

    /// Total driver time spent inside exchange barriers (dispatch to
    /// last ack), in nanoseconds.
    pub fn exchange_wait_ns(&self) -> u64 {
        self.exchange_wait_ns.load(Ordering::Relaxed)
    }

    fn tensor_id(&self, t: &TensorEntry) -> Result<usize> {
        self.lookup
            .get(&t.offset)
            .copied()
            .ok_or_else(|| anyhow!("tensor {} is not part of the sharded container", t.name))
    }

    /// Per-worker matvec threading: divide the driver's thread budget
    /// across the shards (bit-identity holds at any thread count, so
    /// this is purely an oversubscription guard).
    fn worker_mode(&self, mode: MatvecMode) -> MatvecMode {
        match mode {
            MatvecMode::Threads(t) => MatvecMode::Threads((t / self.n).max(1)),
            pinned => pinned,
        }
    }

    /// Drain exactly `k` acks, surfacing the first worker error after
    /// all jobs of the dispatch have quiesced (so no straggler is still
    /// writing when the caller regains the buffers).
    fn wait(&self, chan: &Channels, k: usize) -> Result<()> {
        let mut first_err: Option<String> = None;
        for _ in 0..k {
            match chan.done_rx.recv_timeout(BARRIER_TIMEOUT) {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(e) => {
                    first_err.get_or_insert(format!("shard barrier broke: {e}"));
                    break;
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => bail!("shard worker failed: {e}"),
        }
    }

    fn barrier_done(&self, t0: Instant) {
        self.exchanges.fetch_add(1, Ordering::Relaxed);
        self.exchange_wait_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Row-split sharded matvec: `out[r] = row_r · x` with shard `s`
    /// computing its own disjoint row range. One exchange barrier.
    pub(crate) fn matvec(
        &self,
        t: &TensorEntry,
        x: &[f32],
        out: &mut [f32],
        mode: MatvecMode,
    ) -> Result<()> {
        let tid = self.tensor_id(t)?;
        match self.metas[tid] {
            SliceMeta::Rows { rows } if rows == out.len() => {}
            SliceMeta::Rows { rows } => {
                bail!("sharded matvec on {}: {} outputs != {rows} rows", t.name, out.len())
            }
            _ => bail!("sharded matvec on {}: tensor is not row-partitioned", t.name),
        }
        let mode = self.worker_mode(mode);
        let t0 = Instant::now();
        let chan = self.chan.lock().map_err(|_| anyhow!("shard channel poisoned"))?;
        for tx in &chan.txs {
            tx.send(Job::Matvec {
                tid,
                x: SendPtr::new(x),
                out: SendPtrMut::new(out),
                mode,
            })
            .map_err(|_| anyhow!("shard worker hung up"))?;
        }
        let r = self.wait(&chan, self.n);
        self.barrier_done(t0);
        r
    }

    /// Row-split sharded GEMM staging: fills the row-major `[rows][t]`
    /// plane `mat` (the caller transposes into its token-major panel,
    /// exactly as the unsharded path does). One exchange barrier.
    pub(crate) fn matvec_mat(
        &self,
        e: &TensorEntry,
        xs: &[f32],
        n: usize,
        t: usize,
        mat: &mut [f32],
        mode: MatvecMode,
    ) -> Result<()> {
        let tid = self.tensor_id(e)?;
        match self.metas[tid] {
            SliceMeta::Rows { rows } if rows * t == mat.len() => {}
            SliceMeta::Rows { rows } => bail!(
                "sharded GEMM on {}: staging plane {} != {rows} rows × {t} cols",
                e.name,
                mat.len()
            ),
            _ => bail!("sharded GEMM on {}: tensor is not row-partitioned", e.name),
        }
        let mode = self.worker_mode(mode);
        let t0 = Instant::now();
        let chan = self.chan.lock().map_err(|_| anyhow!("shard channel poisoned"))?;
        for tx in &chan.txs {
            tx.send(Job::MatStage {
                tid,
                xs: SendPtr::new(xs),
                n,
                t,
                mat: SendPtrMut::new(mat),
                mode,
            })
            .map_err(|_| anyhow!("shard worker hung up"))?;
        }
        let r = self.wait(&chan, self.n);
        self.barrier_done(t0);
        r
    }

    /// Expert-parallel routed MoE for one token: each selected expert's
    /// MLP runs whole on its owner shard, writing `ye[k*hs..]` for the
    /// k-th selected expert (ascending order — the driver's combine
    /// order). One exchange barrier over all selected experts.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn moe_token(
        &self,
        gate: &TensorEntry,
        up: &TensorEntry,
        down: &TensorEntry,
        idx: &[usize],
        x: &[f32],
        ye: &mut [f32],
        inter: usize,
        hs: usize,
        mode: MatvecMode,
    ) -> Result<()> {
        if idx.is_empty() {
            return Ok(());
        }
        let (gid, uid, did) = (self.tensor_id(gate)?, self.tensor_id(up)?, self.tensor_id(down)?);
        let n_exp = match self.metas[gid] {
            SliceMeta::Experts { n_exp } => n_exp,
            _ => bail!("sharded MoE on {}: tensor is not expert-partitioned", gate.name),
        };
        if ye.len() < idx.len() * hs {
            bail!("sharded MoE: expert-output plane {} < {} experts × {hs}", ye.len(), idx.len());
        }
        let mode = self.worker_mode(mode);
        let t0 = Instant::now();
        let chan = self.chan.lock().map_err(|_| anyhow!("shard channel poisoned"))?;
        let out = SendPtrMut::new(ye);
        for (k, &e) in idx.iter().enumerate() {
            let owner = expert_owner(n_exp, self.n, e);
            chan.txs[owner]
                .send(Job::ExpertMlp {
                    gid,
                    uid,
                    did,
                    e,
                    x: SendPtr::new(x),
                    y: SendPtrMut(unsafe { out.0.add(k * hs) }, hs),
                    inter,
                    mode,
                })
                .map_err(|_| anyhow!("shard worker hung up"))?;
        }
        let r = self.wait(&chan, idx.len());
        self.barrier_done(t0);
        r
    }

    /// Expert-parallel routed MoE for a token panel: `jobs` lists
    /// `(expert, plane offset, token count)` with gathered activations
    /// in `xs` (`[Σ gt][n]`) and outputs into `ye` (`[Σ gt][hs]`) at
    /// the same offsets. One exchange barrier over all expert jobs.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn moe_panel(
        &self,
        gate: &TensorEntry,
        up: &TensorEntry,
        down: &TensorEntry,
        jobs: &[(usize, usize, usize)],
        xs: &[f32],
        ye: &mut [f32],
        inter: usize,
        n: usize,
        hs: usize,
        mode: MatvecMode,
    ) -> Result<()> {
        if jobs.is_empty() {
            return Ok(());
        }
        let (gid, uid, did) = (self.tensor_id(gate)?, self.tensor_id(up)?, self.tensor_id(down)?);
        let n_exp = match self.metas[gid] {
            SliceMeta::Experts { n_exp } => n_exp,
            _ => bail!("sharded MoE on {}: tensor is not expert-partitioned", gate.name),
        };
        let mode = self.worker_mode(mode);
        let t0 = Instant::now();
        let chan = self.chan.lock().map_err(|_| anyhow!("shard channel poisoned"))?;
        let out = SendPtrMut::new(ye);
        for &(e, off, gt) in jobs {
            let owner = expert_owner(n_exp, self.n, e);
            chan.txs[owner]
                .send(Job::ExpertMlpPanel {
                    gid,
                    uid,
                    did,
                    e,
                    xs: SendPtr(unsafe { xs.as_ptr().add(off * n) }, gt * n),
                    n,
                    t: gt,
                    y: SendPtrMut(out.0, out.1),
                    y_at: off * hs,
                    inter,
                    mode,
                })
                .map_err(|_| anyhow!("shard worker hung up"))?;
        }
        let r = self.wait(&chan, jobs.len());
        self.barrier_done(t0);
        r
    }
}

impl Drop for ShardRuntime {
    fn drop(&mut self) {
        if let Ok(chan) = self.chan.lock() {
            for tx in &chan.txs {
                let _ = tx.send(Job::Stop);
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Per-worker reusable scratch (gate/up projections and GEMM staging
/// for the expert MLPs — row-split jobs need none).
#[derive(Default)]
struct WorkerScratch {
    g: Vec<f32>,
    u: Vec<f32>,
    mat: Vec<f32>,
}

fn worker_loop(
    slices: Vec<Option<WorkerSlice>>,
    rx: Receiver<Job>,
    done: Sender<Result<(), String>>,
) {
    let mut scratch = WorkerScratch::default();
    while let Ok(job) = rx.recv() {
        if matches!(job, Job::Stop) {
            break;
        }
        let r = run_job(&slices, &mut scratch, job);
        if done.send(r.map_err(|e| format!("{e:#}"))).is_err() {
            break;
        }
    }
}

/// The encoded bytes of expert `e` on this shard, or an error if the
/// expert landed here by a dispatch bug.
fn expert_bytes(
    slices: &[Option<WorkerSlice>],
    tid: usize,
    e: usize,
) -> Result<(QuantFormat, &[u8])> {
    match slices.get(tid).and_then(|s| s.as_ref()) {
        Some(WorkerSlice::Experts { fmt, bytes, e0, per }) => {
            let local = e
                .checked_sub(*e0)
                .ok_or_else(|| anyhow!("expert {e} dispatched below this shard's range"))?;
            let at = local * per;
            if at + per > bytes.len() {
                bail!("expert {e} dispatched beyond this shard's range");
            }
            Ok((*fmt, &bytes[at..at + per]))
        }
        _ => bail!("tensor {tid} is not expert-partitioned on this shard"),
    }
}

fn run_job(slices: &[Option<WorkerSlice>], s: &mut WorkerScratch, job: Job) -> Result<()> {
    match job {
        Job::Stop => Ok(()),
        Job::Matvec { tid, x, out, mode } => {
            let Some(WorkerSlice::Rows { fmt, bytes, r0, r1 }) =
                slices.get(tid).and_then(|s| s.as_ref())
            else {
                bail!("tensor {tid} is not row-partitioned on this shard");
            };
            if r0 == r1 {
                return Ok(());
            }
            let x = unsafe { x.get() };
            let out = unsafe { out.get(*r0, r1 - r0) };
            forward::matvec_bytes_mode(mode, *fmt, bytes, x, out)
        }
        Job::MatStage { tid, xs, n, t, mat, mode } => {
            let Some(WorkerSlice::Rows { fmt, bytes, r0, r1 }) =
                slices.get(tid).and_then(|s| s.as_ref())
            else {
                bail!("tensor {tid} is not row-partitioned on this shard");
            };
            if r0 == r1 {
                return Ok(());
            }
            let xs = unsafe { xs.get() };
            let m = unsafe { mat.get(r0 * t, (r1 - r0) * t) };
            forward::stage_rows_mode(mode, *fmt, bytes, xs, n, t, m)
        }
        Job::ExpertMlp { gid, uid, did, e, x, y, inter, mode } => {
            let gate = expert_bytes(slices, gid, e)?;
            let up = expert_bytes(slices, uid, e)?;
            let down = expert_bytes(slices, did, e)?;
            let x = unsafe { x.get() };
            let y = unsafe { y.get(0, y.1) };
            s.g.resize(inter, 0.0);
            s.u.resize(inter, 0.0);
            forward::mlp_bytes_mode(mode, gate, up, down, inter, x, y, &mut s.g, &mut s.u)
        }
        Job::ExpertMlpPanel { gid, uid, did, e, xs, n, t, y, y_at, inter, mode } => {
            let gate = expert_bytes(slices, gid, e)?;
            let up = expert_bytes(slices, uid, e)?;
            let down = expert_bytes(slices, did, e)?;
            let xs = unsafe { xs.get() };
            let out_rows = {
                // Output width per token comes from the down slice: its
                // rows-per-expert is the hidden size.
                match slices.get(did).and_then(|s| s.as_ref()) {
                    Some(WorkerSlice::Experts { fmt, per, .. }) => {
                        let rb = fmt.row_bytes(inter)?;
                        if rb == 0 {
                            bail!("expert down-projection has zero-byte rows");
                        }
                        per / rb
                    }
                    _ => bail!("tensor {did} is not expert-partitioned on this shard"),
                }
            };
            let y = unsafe { y.get(y_at, t * out_rows) };
            s.g.resize(t * inter, 0.0);
            s.u.resize(t * inter, 0.0);
            s.mat.resize(t * inter.max(out_rows), 0.0);
            forward::mlp_mat_bytes_mode(
                mode, gate, up, down, inter, xs, n, t, &mut s.mat, &mut s.g, &mut s.u, y,
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_partition_exhaustively() {
        for rows in [0usize, 1, 7, 8, 64, 129, 256] {
            for n in [1usize, 2, 3, 4, 8] {
                let mut covered = 0;
                for s in 0..n {
                    let (a, b) = row_range(rows, n, s);
                    assert!(a <= b && b <= rows);
                    assert_eq!(a, covered, "rows={rows} n={n} s={s}: ranges must be contiguous");
                    covered = b;
                }
                assert_eq!(covered, rows, "rows={rows} n={n}: ranges must cover everything");
            }
        }
    }

    #[test]
    fn ranges_are_balanced() {
        // Sizes differ by at most one — the Table-2 case is exact.
        for s in 0..8 {
            let (a, b) = expert_range(256, 8, s);
            assert_eq!(b - a, 32, "256 experts over 8 shards is 32 per device");
        }
        let sizes: Vec<usize> = (0..4)
            .map(|s| {
                let (a, b) = row_range(7, 4, s);
                b - a
            })
            .collect();
        assert_eq!(sizes.iter().sum::<usize>(), 7);
        assert!(sizes.iter().all(|&z| z == 1 || z == 2));
    }

    #[test]
    fn expert_owner_inverts_expert_range() {
        for n in [1usize, 2, 4, 8] {
            for e in 0..64 {
                let s = expert_owner(64, n, e);
                let (a, b) = expert_range(64, n, s);
                assert!(e >= a && e < b, "expert {e} not inside its owner {s}'s range");
            }
        }
    }
}
