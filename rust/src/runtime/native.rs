//! Native CPU serving backend — the offline engine that lets
//! [`super::Engine`] and the coordinator execute prefill and decode
//! steps on **quantized** weights without the PJRT backend or AOT HLO
//! artifacts.
//!
//! Since PR 4 this is no longer an embed→unembed stub: each slot runs
//! a **complete transformer forward pass** in [`super::forward`] —
//! RMSNorm, attention over a per-slot KV cache bounded by
//! [`NATIVE_MAX_CTX`], the FFN stack, and the final unembedding — with
//! every matvec fused on the container's encoded payloads
//! ([`crate::quant::vec_dot_rows_with`]; no resident f32 weight
//! tables). Since PR 5 **both architecture families** are served: the
//! tiny-MoE step (MLA attention + top-k routed experts, Tables 2–4)
//! and the dense-GQA step of the distill shapes (grouped-query
//! attention + dense SwiGLU, Table 5 — `tiny-dense` /
//! `distill-qwen-32b`). Since PR 6 prefill runs each slot's whole
//! prompt as **one panel pass** ([`ForwardPass::forward_tokens`]):
//! every projection/FFN matvec is a decode-once GEMM over the prompt's
//! token dimension, so each quantized weight tile is decoded once per
//! prompt instead of once per token. Decode advances one token per
//! live slot, and slots marked inactive (`pos < 0`) are skipped
//! entirely. Unused slots never even allocate their KV backing buffer
//! ([`KvCache`] allocates lazily on the first forwarded token), and
//! all per-token and per-panel intermediates live in one reused
//! [`Scratch`] per wave, so both loops are allocation-free.
//!
//! Determinism: the PR-3 contract extends through the whole pass — the
//! same 8-lane reduction order at every thread count, on every
//! `DSQ_FORCE_ARM` dispatch arm, and in panel prefill exactly as in
//! the per-token loop, so two native engines over the same container
//! produce bit-identical logits (asserted by `tests/native_engine.rs`
//! / `tests/native_forward.rs`, pinned by the committed
//! `rust/tests/golden/forward.*.fnv64` checksums, and proven on the
//! deployment host by `dsq selfcheck`).

use super::forward::{ForwardPass, KvCache, MatvecMode, Scratch};
use super::paged::KvBlockPool;
use crate::container::Container;
use crate::quant::{KvScheme, QuantFormat};
use anyhow::{bail, Result};

/// Batch slots the native backend serves per wave (mirrors the tiny
/// AOT manifests so coordinator behaviour matches the PJRT path).
pub const NATIVE_BATCH: usize = 16;
/// Compiled prompt length of the native backend.
pub const NATIVE_PROMPT_LEN: usize = 16;
/// Context bound: prompt plus an 8-token generation budget. Every
/// per-slot KV cache is hard-bounded by this; `Coordinator::submit`
/// rejects prompts that could not generate within it.
pub const NATIVE_MAX_CTX: usize = 24;

/// Per-wave mutable state: one [`KvCache`] per batch slot plus the
/// wave's shared forward-pass [`Scratch`] (slots step sequentially, so
/// one scratch serves them all and every per-token intermediate is
/// reused instead of reallocated). Threaded through
/// [`super::StepOutput`] exactly like the PJRT cache literals, so the
/// engine itself stays immutable between steps.
pub struct BatchKv {
    slots: Vec<KvCache>,
    scratch: Scratch,
}

impl BatchKv {
    /// Tokens cached in slot `i` (the next decode position).
    pub fn slot_len(&self, i: usize) -> usize {
        self.slots[i].len()
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Whether slot `i` has allocated its KV backing buffer (false for
    /// slots a wave never forwarded a token through — the lazy-alloc
    /// regression tests assert skipped slots stay unallocated).
    pub fn slot_allocated(&self, i: usize) -> bool {
        self.slots[i].is_allocated()
    }
}

/// The native backend: the forward-pass model over the opened container
/// plus the serving shape (batch/prompt/context bounds).
pub struct NativeEngine {
    fwd: ForwardPass,
    batch: usize,
    prompt_len: usize,
    max_ctx: usize,
}

impl NativeEngine {
    /// Build the backend from an opened container (taken over whole —
    /// the weight payloads are served in place) with the default
    /// serving shape. `threads` bounds the per-matvec row fan-out;
    /// logits are bit-identical for every count.
    pub fn from_container(ckpt: Container, threads: usize) -> Result<Self> {
        Self::with_limits(ckpt, threads, NATIVE_BATCH, NATIVE_PROMPT_LEN, NATIVE_MAX_CTX)
    }

    /// [`NativeEngine::from_container`] with an explicit serving shape —
    /// used by tests and benches to exercise the context-bound
    /// validation paths with small limits.
    pub fn with_limits(
        ckpt: Container,
        threads: usize,
        batch: usize,
        prompt_len: usize,
        max_ctx: usize,
    ) -> Result<Self> {
        Self::with_limits_sharded(ckpt, threads, batch, prompt_len, max_ctx, 0)
    }

    /// [`NativeEngine::with_limits`] partitioned across `shards` shard
    /// worker threads (0 = local execution) — the Table-2 deployment
    /// plan as cooperating shards, logits bit-identical to unsharded at
    /// every shard count (see [`crate::runtime::sharded`]). Sharding is
    /// set **before** any scratch exists, so every wave/scheduler
    /// scratch built from this engine carries the sharded MoE planes.
    pub fn with_limits_sharded(
        ckpt: Container,
        threads: usize,
        batch: usize,
        prompt_len: usize,
        max_ctx: usize,
        shards: usize,
    ) -> Result<Self> {
        if batch == 0 || prompt_len == 0 {
            bail!("native backend needs batch ≥ 1 and prompt_len ≥ 1");
        }
        let mut fwd = ForwardPass::new(ckpt, threads, max_ctx)?;
        fwd.set_sharding(shards)?;
        Ok(NativeEngine { fwd, batch, prompt_len, max_ctx })
    }

    /// Active shard count (0 when running locally).
    pub fn shard_count(&self) -> usize {
        self.fwd.shard_count()
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    pub fn max_ctx(&self) -> usize {
        self.max_ctx
    }

    pub fn vocab(&self) -> usize {
        self.fwd.vocab()
    }

    pub fn hidden(&self) -> usize {
        self.fwd.config().hidden_size
    }

    /// The stored format of the unembedding matrix (what the per-step
    /// vocab-wide fused matvec runs on).
    pub fn output_format(&self) -> QuantFormat {
        self.fwd.output_format()
    }

    /// Direct access to the forward-pass model (tests, selfcheck, the
    /// continuous-batching scheduler).
    pub fn forward(&self) -> &ForwardPass {
        &self.fwd
    }

    /// Override the matvec execution mode (thread count or pinned
    /// dispatch arm) — the arm-identity seam the continuous-batching
    /// determinism tests drive. Logits are bit-identical under every
    /// mode.
    pub fn set_mode(&mut self, mode: MatvecMode) {
        self.fwd.set_mode(mode);
    }

    /// Select the KV-cache storage scheme (see
    /// [`ForwardPass::set_kv_scheme`]). Call **before** any cache,
    /// block pool, or scratch is created from this engine — the scheme
    /// decides block byte sizes and staging-scratch layouts, and
    /// [`KvCache::grow_to`] rejects pools built under a different one.
    /// Logits under `q8_0` stay bit-identical across threads, dispatch
    /// arms, shards, and dense/paged backings (only f32 matches the
    /// pre-quantized-KV goldens byte-for-byte).
    pub fn set_kv_scheme(&mut self, scheme: KvScheme) -> Result<()> {
        self.fwd.set_kv_scheme(scheme)
    }

    /// Active KV-cache storage scheme (f32 unless overridden).
    pub fn kv_scheme(&self) -> KvScheme {
        self.fwd.kv_scheme()
    }

    /// Encoded KV bytes one cached token occupies across all layers
    /// and planes under the active scheme — the engine-measured side
    /// of the planner's [`crate::memory::kv_token_plan`].
    pub fn kv_bytes_per_token(&self) -> usize {
        self.fwd.new_cache().bytes_per_token()
    }

    /// A KV block pool sized for this engine's cache shape (see
    /// [`ForwardPass::new_block_pool`]).
    pub fn new_block_pool(&self, capacity: usize, block_tokens: usize) -> Result<KvBlockPool> {
        self.fwd.new_block_pool(capacity, block_tokens)
    }

    /// Fresh per-slot caches (and the wave's reused scratch) for one
    /// wave. Nothing is heap-allocated per slot beyond the cache
    /// handles themselves: KV buffers appear lazily on first use. The
    /// scratch panels are sized for `max(max_ctx, batch)` columns so
    /// batched decode can feed every live slot through one GEMM panel.
    pub fn new_batch_kv(&self) -> BatchKv {
        BatchKv {
            slots: (0..self.batch).map(|_| self.fwd.new_cache()).collect(),
            scratch: self.fwd.new_scratch_cols(self.batch),
        }
    }

    /// Prefill: run each slot's actual prompt (`lengths[i]` tokens of
    /// row `i`, clamped to `1..=prompt_len`) through the forward pass
    /// as one [`ForwardPass::forward_tokens`] panel, returning the
    /// last-token logits per slot (row-major `[batch, vocab]`) and the
    /// filled per-slot caches.
    ///
    /// `lengths[i] <= 0` marks an **unused** slot: it is skipped
    /// entirely (zero logits row, empty cache) instead of burning a
    /// full attention+MoE pass on padding — the prefill counterpart of
    /// decode's `pos < 0` contract.
    pub fn prefill(&self, tokens: &[i32], lengths: &[i32]) -> Result<(Vec<f32>, BatchKv)> {
        let (b, t, v) = (self.batch, self.prompt_len, self.vocab());
        if tokens.len() != b * t || lengths.len() != b {
            bail!("prefill input shape mismatch");
        }
        let mut kv = self.new_batch_kv();
        let mut logits = vec![0f32; b * v];
        for (slot, cache) in kv.slots.iter_mut().enumerate() {
            if lengths[slot] <= 0 {
                continue;
            }
            let l = (lengths[slot] as usize).min(t);
            let prompt = &tokens[slot * t..slot * t + l];
            let row = &mut logits[slot * v..(slot + 1) * v];
            self.fwd.forward_tokens(prompt, cache, &mut kv.scratch, Some(row))?;
        }
        Ok((logits, kv))
    }

    /// One decode step: advance every **active** slot by one token
    /// (`pos[i] < 0` marks an inactive slot — finished or unused — whose
    /// logits row is zeroed and whose cache is left untouched). Returns
    /// row-major `[batch, vocab]` logits.
    ///
    /// Since PR 7 the live slots run as **one GEMM panel** per step
    /// ([`ForwardPass::forward_step_batch`]): each quantized weight
    /// tile is decoded once per step instead of once per live slot,
    /// with every slot's logits bit-identical to stepping it alone.
    pub fn decode(&self, token: &[i32], pos: &[i32], kv: &mut BatchKv) -> Result<Vec<f32>> {
        let (b, v) = (self.batch, self.vocab());
        if token.len() != b || pos.len() != b || kv.slots.len() != b {
            bail!("decode input shape mismatch");
        }
        let mut logits = vec![0f32; b * v];
        let live: Vec<bool> = pos.iter().map(|&p| p >= 0).collect();
        self.fwd.forward_step_batch(token, &live, &mut kv.slots, &mut kv.scratch, &mut logits)?;
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{quantize_container_with, synthetic_f32_container};
    use crate::model::ModelConfig;
    use crate::scheme::builtin;

    fn native(scheme: &str, threads: usize) -> NativeEngine {
        // Quantize once per scheme — serial container quantization is
        // the slow part of these tests in debug builds.
        static DQ3: std::sync::OnceLock<Vec<u8>> = std::sync::OnceLock::new();
        static Q4: std::sync::OnceLock<Vec<u8>> = std::sync::OnceLock::new();
        let cell = match scheme {
            "dq3_k_m" => &DQ3,
            "q4_k_m" => &Q4,
            other => panic!("unexpected scheme {other}"),
        };
        let bytes = cell.get_or_init(|| {
            let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 0xA17E).unwrap();
            quantize_container_with(&src, &builtin::scheme(scheme).unwrap(), None, 1)
                .unwrap()
                .to_bytes()
        });
        let q = Container::from_bytes(bytes.clone()).unwrap();
        NativeEngine::with_limits(q, threads, 3, 4, 8).unwrap()
    }

    #[test]
    fn prefill_fills_only_the_actual_prompt_per_slot() {
        let m = native("dq3_k_m", 1);
        let tokens = vec![5i32; 3 * 4];
        let (logits, kv) = m.prefill(&tokens, &[2, 4, 0]).unwrap();
        assert_eq!(logits.len(), 3 * m.vocab());
        // Length 0 marks an unused slot: no forward pass, empty cache,
        // zeroed logits row — and no KV backing allocation at all.
        assert_eq!([kv.slot_len(0), kv.slot_len(1), kv.slot_len(2)], [2, 4, 0]);
        assert!(kv.slot_allocated(0) && kv.slot_allocated(1));
        assert!(!kv.slot_allocated(2), "skipped slot must not allocate its KV buffer");
        let v = m.vocab();
        assert!(logits[..2 * v].iter().all(|x| x.is_finite()));
        assert!(logits[2 * v..].iter().all(|&x| x == 0.0), "unused slot row must be zero");
        assert!(logits[..v].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn decode_skips_inactive_slots() {
        let m = native("q4_k_m", 2);
        let tokens = vec![7i32; 3 * 4];
        let (_, mut kv) = m.prefill(&tokens, &[1, 1, 1]).unwrap();
        let logits = m.decode(&[3, 0, 9], &[1, -1, 1], &mut kv).unwrap();
        let v = m.vocab();
        assert!(logits[v..2 * v].iter().all(|&x| x == 0.0), "inactive slot must zero");
        assert!(logits[..v].iter().any(|&x| x != 0.0));
        assert_eq!(kv.slot_len(0), 2);
        assert_eq!(kv.slot_len(1), 1, "inactive slot cache untouched");
        assert_eq!(kv.slot_len(2), 2);
    }

    #[test]
    fn decode_beyond_max_ctx_errors_cleanly() {
        let m = native("q4_k_m", 1);
        let tokens = vec![1i32; 3 * 4];
        let (_, mut kv) = m.prefill(&tokens, &[4, 1, 1]).unwrap();
        // Slot 0 has 4 cached tokens; max_ctx is 8 → 4 more fit.
        for step in 0..4 {
            m.decode(&[2, 2, 2], &[4 + step, -1, -1], &mut kv).unwrap();
        }
        let err = m.decode(&[2, 2, 2], &[8, -1, -1], &mut kv).unwrap_err();
        assert!(err.to_string().contains("max context"), "{err}");
    }

    #[test]
    fn quantized_weights_stay_encoded() {
        let m = native("dq3_k_m", 1);
        assert_ne!(m.output_format(), QuantFormat::F32, "scheme should quantize output");
    }

    #[test]
    fn dense_gqa_engine_serves_prefill_and_decode() {
        // Table-5 coverage: the tiny-dense proxy rides the same serving
        // plumbing as tiny-moe (fuller numeric coverage lives in
        // tests/native_forward.rs; the wave test in native_engine.rs).
        let src = synthetic_f32_container(&ModelConfig::tiny_dense(), 0xA17E).unwrap();
        let q = quantize_container_with(&src, &builtin::scheme("q4_k_m").unwrap(), None, 1)
            .unwrap()
            .to_bytes();
        let m = NativeEngine::with_limits(Container::from_bytes(q).unwrap(), 1, 2, 4, 8).unwrap();
        let (logits, mut kv) = m.prefill(&[1, 2, 3, 4, 9, 8, 7, 6], &[3, 0]).unwrap();
        let v = m.vocab();
        assert!(logits[..v].iter().any(|&x| x != 0.0));
        assert!(!kv.slot_allocated(1), "unused dense slot stays unallocated");
        let step = m.decode(&[5, 0], &[3, -1], &mut kv).unwrap();
        assert!(step[..v].iter().all(|x| x.is_finite()));
        assert_eq!(kv.slot_len(0), 4);
    }
}
