//! Native CPU matvec serving backend — the offline fallback that lets
//! [`super::Engine`] and the coordinator actually execute prefill and
//! decode steps on **quantized** weights without the PJRT backend or
//! AOT HLO artifacts.
//!
//! This is *not* the trained proxy model (that computation lives in the
//! compiled HLO graphs). It is the smallest honest serving computation
//! over real checkpoint tensors: an embed → unembed step,
//!
//! ```text
//! h         = token_embd.weight[last_token]   (one row, decoded per step)
//! logits[v] = vec_dot(output.weight row v, h) (fused, on encoded blocks)
//! ```
//!
//! **Both** matrices stay in their **container-encoded form** (`q6_k`,
//! `q4_k`, … per the scheme): the embedding side decodes exactly one
//! block-aligned row per unique step token through the batch decode
//! kernels (a resident f32 table would cost vocab×hidden×4 bytes —
//! ~3.7 GB at 671B scale, in a repo whose point is *not* paying that),
//! and every step's logits are computed with the fused
//! [`crate::quant::vec_dot_rows_with`] kernels — so `dsq serve
//! --native` / `dsq eval --native` drive the exact read-side hot path
//! the decode kernels exist for, end to end through the coordinator.
//! Determinism: the row decode and the row-parallel matvec are
//! bit-identical at every thread count, so two native engines over the
//! same container always produce the same logits (asserted by
//! `tests/native_engine.rs`).

use crate::container::{Container, TensorEntry};
use crate::quant::{self, QuantFormat};
use anyhow::{bail, Context, Result};

/// Batch slots the native backend serves per wave (mirrors the tiny
/// AOT manifests so coordinator behaviour matches the PJRT path).
pub const NATIVE_BATCH: usize = 16;
/// Compiled prompt length of the native backend.
pub const NATIVE_PROMPT_LEN: usize = 16;
/// Context bound: prompt plus an 8-token generation budget.
pub const NATIVE_MAX_CTX: usize = 24;

/// The native backend's state: the opened container (payloads stay
/// exactly as encoded, never copied) plus the two weight entries the
/// embed → unembed step reads.
pub struct NativeMatvec {
    vocab: usize,
    hidden: usize,
    ckpt: Container,
    /// `token_embd.weight`; one block-aligned row is decoded per
    /// unique step token.
    embd: TensorEntry,
    /// Encoded bytes per embedding row (`format.row_bytes(hidden)`).
    embd_row_bytes: usize,
    /// `output.weight`, consumed in place by the fused matvec.
    out: TensorEntry,
    /// Worker budget for the per-step row-parallel matvec.
    threads: usize,
}

impl NativeMatvec {
    /// Build the backend from an opened container (taken over whole —
    /// the weight payloads are sliced in place, not copied). `threads`
    /// bounds the per-step matvec fan-out; results are bit-identical
    /// for every count.
    pub fn from_container(ckpt: Container, threads: usize) -> Result<Self> {
        let embd = ckpt.tensor("token_embd.weight").context("native backend")?.clone();
        let out = ckpt.tensor("output.weight").context("native backend")?.clone();
        if embd.shape.len() != 2 || out.shape.len() != 2 {
            bail!("native backend expects 2-D embedding/output tensors");
        }
        let (vocab, hidden) = (embd.shape[0], embd.shape[1]);
        if vocab == 0 || hidden == 0 {
            bail!("native backend: token_embd has a zero dimension ([{vocab}, {hidden}])");
        }
        if out.shape != [vocab, hidden] {
            bail!(
                "output.weight shape {:?} != token_embd shape [{vocab}, {hidden}]",
                out.shape
            );
        }
        // Rows must be whole runs of blocks for per-row decode (every
        // quantizable census tensor guarantees this; f32/f16 trivially).
        let embd_row_bytes = embd
            .format
            .row_bytes(hidden)
            .context("native backend: token_embd rows not block-aligned")?;
        Ok(NativeMatvec { vocab, hidden, ckpt, embd, embd_row_bytes, out, threads: threads.max(1) })
    }

    /// Decode one embedding row (`token_embd.weight[t]`) into `h`.
    fn embed_row(&self, t: usize, h: &mut [f32]) -> Result<()> {
        let bytes = self.ckpt.bytes(&self.embd);
        let row = &bytes[t * self.embd_row_bytes..(t + 1) * self.embd_row_bytes];
        quant::dequantize_into(self.embd.format, row, h)
    }

    pub fn batch(&self) -> usize {
        NATIVE_BATCH
    }

    pub fn prompt_len(&self) -> usize {
        NATIVE_PROMPT_LEN
    }

    pub fn max_ctx(&self) -> usize {
        NATIVE_MAX_CTX
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }

    /// The stored format of the unembedding matrix (what the fused
    /// matvec actually runs on).
    pub fn output_format(&self) -> QuantFormat {
        self.out.format
    }

    /// One step: for every slot, unembed the embedding of its token.
    /// Returns row-major `[tokens.len(), vocab]` logits. Out-of-range
    /// token ids wrap into the vocabulary (padding slots send `PAD`).
    ///
    /// The vocab-wide fused matvec runs once per *unique* token in the
    /// step — during a wave tail most slots are finished and all send
    /// `PAD`, so their identical logits row is computed once and copied
    /// into the remaining slots instead of redone per slot.
    pub fn step_logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let mut logits = vec![0f32; tokens.len() * self.vocab];
        let mut h = vec![0f32; self.hidden];
        let mut first_slot: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::with_capacity(tokens.len());
        for (slot, &tok) in tokens.iter().enumerate() {
            let t = tok.rem_euclid(self.vocab as i32) as usize;
            if let Some(&src) = first_slot.get(&t) {
                let (head, tail) = logits.split_at_mut(slot * self.vocab);
                tail[..self.vocab]
                    .copy_from_slice(&head[src * self.vocab..(src + 1) * self.vocab]);
                continue;
            }
            first_slot.insert(t, slot);
            self.embed_row(t, &mut h)?;
            let row = &mut logits[slot * self.vocab..(slot + 1) * self.vocab];
            quant::vec_dot_rows_with(
                self.out.format,
                self.ckpt.bytes(&self.out),
                &h,
                row,
                self.threads,
            )?;
        }
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{quantize_container_with, synthetic_f32_container};
    use crate::model::ModelConfig;
    use crate::quant::kernels;
    use crate::scheme::builtin;

    fn native(scheme: &str, threads: usize) -> NativeMatvec {
        let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 0xA17E).unwrap();
        let q = Container::from_bytes(
            quantize_container_with(&src, &builtin::scheme(scheme).unwrap(), None, 1)
                .unwrap()
                .to_bytes(),
        )
        .unwrap();
        NativeMatvec::from_container(q, threads).unwrap()
    }

    #[test]
    fn logits_match_decode_then_dot_reference() {
        let m = native("dq3_k_m", 1);
        let logits = m.step_logits(&[7, 0, 511]).unwrap();
        assert_eq!(logits.len(), 3 * m.vocab());
        // Reference: decode the whole output matrix, then the canonical
        // lane dot per row — must match the fused path bit-for-bit.
        let n = m.vocab * m.hidden;
        let mut w = vec![0f32; n];
        quant::dequantize_into_with(m.out.format, m.ckpt.bytes(&m.out), &mut w, 1).unwrap();
        let mut h = vec![0f32; m.hidden];
        for (s, &tok) in [7i32, 0, 511].iter().enumerate() {
            let t = tok.rem_euclid(m.vocab as i32) as usize;
            m.embed_row(t, &mut h).unwrap();
            for v in 0..m.vocab {
                let want = kernels::dot_lanes(&w[v * m.hidden..(v + 1) * m.hidden], &h);
                let got = logits[s * m.vocab + v];
                assert_eq!(got.to_bits(), want.to_bits(), "slot {s} vocab row {v}");
            }
        }
    }

    #[test]
    fn thread_counts_bit_identical() {
        let a = native("q4_k_m", 1);
        let b = native("q4_k_m", 8);
        let toks: Vec<i32> = (0..16).collect();
        let la = a.step_logits(&toks).unwrap();
        let lb = b.step_logits(&toks).unwrap();
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&la), bits(&lb));
    }

    #[test]
    fn duplicate_tokens_share_one_matvec_row() {
        // Wave tails send PAD from every finished slot; the deduped
        // step must return exactly the rows the per-slot loop would.
        let m = native("q4_k_m", 2);
        let toks = [5i32, 0, 5, 0, 0, 9];
        let logits = m.step_logits(&toks).unwrap();
        for (s, &tok) in toks.iter().enumerate() {
            let solo = m.step_logits(&[tok]).unwrap();
            let row = &logits[s * m.vocab..(s + 1) * m.vocab];
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(row), bits(&solo), "slot {s}");
        }
    }

    #[test]
    fn quantized_output_matrix_stays_encoded() {
        let m = native("dq3_k_m", 1);
        assert_ne!(m.output_format(), QuantFormat::F32, "scheme should quantize output");
        let logits = m.step_logits(&[3]).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
    }
}
