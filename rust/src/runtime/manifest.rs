//! Artifact manifests: the contract between `python/compile/aot.py`
//! (which emits them next to each HLO file) and the runtime (which
//! marshals buffers in exactly this order).

use crate::util::json::{self, Value};
use anyhow::{bail, Result};
use std::path::Path;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    U8,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Dtype::F32,
            "u8" => Dtype::U8,
            "i32" => Dtype::I32,
            other => bail!("unknown dtype {other:?}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::U8 => 1,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Tokens,
    Lengths,
    Token,
    Pos,
    CacheKv,
    CacheK,
    CacheV,
    Weight,
    Logits,
}

impl Role {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "tokens" => Role::Tokens,
            "lengths" => Role::Lengths,
            "token" => Role::Token,
            "pos" => Role::Pos,
            "cache_kv" => Role::CacheKv,
            "cache_k" => Role::CacheK,
            "cache_v" => Role::CacheV,
            "weight" => Role::Weight,
            "logits" => Role::Logits,
            other => bail!("unknown role {other:?}"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub role: Role,
    /// Tensor name (weights only).
    pub name: Option<String>,
    /// Quant format name (weights only). Absent means `"f32"`. A weight
    /// spec with `dtype: f32` and format `"f32"` over a *quantized*
    /// container tensor asks the loader to dequantize that payload at
    /// load time (see `runtime::loader`); any other disagreement
    /// between container and manifest formats is an error.
    pub format: Option<String>,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model_name: String,
    pub scheme: String,
    pub phase: String,
    pub batch: usize,
    pub prompt_len: usize,
    pub max_ctx: usize,
    pub vocab: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

fn io_spec(v: &Value) -> Result<IoSpec> {
    Ok(IoSpec {
        role: Role::parse(v.req("role")?.as_str()?)?,
        name: match v.get("name") {
            Some(Value::Str(s)) => Some(s.clone()),
            _ => None,
        },
        format: match v.get("format") {
            Some(Value::Str(s)) => Some(s.clone()),
            _ => None,
        },
        shape: v
            .req("buf_shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<_>>()?,
        dtype: Dtype::parse(v.req("dtype")?.as_str()?)?,
    })
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = json::parse(text)?;
        let inputs = v
            .req("inputs")?
            .as_arr()?
            .iter()
            .map(io_spec)
            .collect::<Result<Vec<_>>>()?;
        let outputs = v
            .req("outputs")?
            .as_arr()?
            .iter()
            .map(io_spec)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            model_name: v.req("model")?.req("name")?.as_str()?.to_string(),
            scheme: v.req("scheme")?.as_str()?.to_string(),
            phase: v.req("phase")?.as_str()?.to_string(),
            batch: v.req("batch")?.as_usize()?,
            prompt_len: v.req("prompt_len")?.as_usize()?,
            max_ctx: v.req("max_ctx")?.as_usize()?,
            vocab: v.req("vocab")?.as_usize()?,
            inputs,
            outputs,
        })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Names of all weight inputs, in order.
    pub fn weight_names(&self) -> Vec<&str> {
        self.inputs
            .iter()
            .filter(|i| i.role == Role::Weight)
            .filter_map(|i| i.name.as_deref())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"name": "tiny-moe"},
      "scheme": "dq3_k_m", "phase": "prefill",
      "batch": 16, "prompt_len": 16, "max_ctx": 24, "vocab": 512,
      "inputs": [
        {"role": "tokens", "buf_shape": [16, 16], "dtype": "i32"},
        {"role": "lengths", "buf_shape": [16], "dtype": "i32"},
        {"role": "weight", "name": "token_embd.weight", "format": "q4_k",
         "buf_shape": [512, 144], "dtype": "u8"}
      ],
      "outputs": [
        {"role": "logits", "buf_shape": [16, 512], "dtype": "f32"},
        {"role": "cache_kv", "buf_shape": [6, 16, 24, 288], "dtype": "f32"}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model_name, "tiny-moe");
        assert_eq!(m.batch, 16);
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.inputs[2].role, Role::Weight);
        assert_eq!(m.inputs[2].format.as_deref(), Some("q4_k"));
        assert_eq!(m.weight_names(), vec!["token_embd.weight"]);
        assert_eq!(m.outputs[1].shape, vec![6, 16, 24, 288]);
    }

    #[test]
    fn rejects_bad_role() {
        let bad = SAMPLE.replace("\"tokens\"", "\"bogus\"");
        assert!(Manifest::parse(&bad).is_err());
    }
}
