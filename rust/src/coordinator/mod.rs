//! L3 serving coordinator.
//!
//! The deployment layer the paper's §4.4 recommendations are about:
//! a request queue, a batch scheduler that packs pending generation
//! requests into the engine's compiled batch slots, per-request
//! sampling state, early-exit on EOS, and serving metrics
//! (latency / throughput) — all in Rust over the PJRT runtime;
//! Python is never on this path.
//!
//! Scheduling model — two, by backend:
//!
//! * **Native backend: continuous batching** (since PR 7).
//!   [`run_to_completion`](Coordinator::run_to_completion) hands the
//!   queue to [`scheduler::ContinuousScheduler`], which admits requests
//!   into free batch slots at *any* decode step, recycles a slot the
//!   moment its request finishes, and pages each slot's KV state out of
//!   a shared fixed-size block pool (`runtime::paged`) instead of a
//!   dense `max_ctx` buffer. Each decode step drives all live slots
//!   through one `vec_dot_mat` GEMM panel; per-slot token streams stay
//!   bit-identical to solo runs (see the scheduler docs for why).
//! * **PJRT backend: batch-synchronous waves with early termination.**
//!   The compiled executables fix batch B; [`run_wave`](Coordinator::run_wave)
//!   drains up to B requests, prefills them together, then decodes
//!   until every sequence has emitted EOS or hit its budget. Slot-level
//!   continuous batching would require per-slot KV-cache splicing
//!   across PJRT literals; see DESIGN.md §Perf for the trade-off.
//!   `run_wave` remains available for the native backend too (the
//!   `dsq serve --wave` escape hatch and differential tests).
//!
//! The coordinator is backend-agnostic: it drives the same wave loop
//! whether the engine holds compiled PJRT executables or the native
//! CPU backend (`Engine::load_native`), which executes the full
//! forward pass — MLA attention + routed experts for the MoE shapes,
//! grouped-query attention + dense FFNs for the distill (Table 5)
//! shapes — directly on quantized container payloads through the fused
//! `quant::kernels` vec_dot path; `tests/native_engine.rs` runs full
//! waves over DQ3_K_M weights of both model kinds that way, with no
//! HLO artifacts. Per-wave state (PJRT cache literals or native
//! per-slot KV caches plus one reused forward scratch) is threaded
//! through `StepOutput::state`; finished and unused slots are marked
//! inactive with a negative position so the native backend skips their
//! forward passes entirely — such slots never even allocate their KV
//! backing buffers.
//!
//! Admission control happens at `submit` time: a prompt that does not
//! fit the engine's compiled prompt length, or that could not generate
//! a single token within the engine's max context (`NATIVE_MAX_CTX`
//! for the native backend), is rejected with a clear error instead of
//! failing mid-wave with a KV-cache overflow.

pub mod metrics;
pub mod sampler;
pub mod scheduler;

use crate::eval::tasks::{EOS, PAD};
use crate::runtime::Engine;
use crate::util::rng::Pcg;
use anyhow::{bail, Result};
use metrics::Metrics;
use sampler::SamplingParams;
use std::collections::VecDeque;
use std::time::Instant;

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Prompt token ids (must fit the engine's compiled prompt length).
    pub prompt: Vec<i32>,
    pub params: SamplingParams,
    /// Sampling seed (paper protocol: one seed per (question, sample)).
    pub seed: u64,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Generated tokens (EOS included when emitted within budget).
    pub tokens: Vec<i32>,
    /// Wall-clock latency of the whole wave this request rode in.
    pub latency_ms: f64,
    /// Tokens decoded in this request.
    pub n_generated: usize,
}

/// The coordinator: queue + scheduler + metrics around an [`Engine`].
pub struct Coordinator {
    engine: Engine,
    queue: VecDeque<Request>,
    pub metrics: Metrics,
}

impl Coordinator {
    pub fn new(engine: Engine) -> Self {
        Coordinator { engine, queue: VecDeque::new(), metrics: Metrics::default() }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Enqueue a request. Rejected here — not mid-wave — when the
    /// prompt exceeds the engine's compiled prompt length or leaves no
    /// room to generate within its max context (the per-slot KV caches
    /// of the native backend are hard-bounded by `NATIVE_MAX_CTX`; a
    /// prompt at or past that bound would only surface as a KV-cache
    /// overflow in the middle of a batch wave).
    pub fn submit(&mut self, req: Request) -> Result<()> {
        if req.prompt.is_empty() || req.prompt.len() > self.engine.prompt_len() {
            bail!(
                "prompt length {} out of range 1..={}",
                req.prompt.len(),
                self.engine.prompt_len()
            );
        }
        let max_ctx = self.engine.max_ctx();
        if req.prompt.len() >= max_ctx {
            bail!(
                "prompt length {} leaves no room to generate within the engine's \
                 max context {max_ctx}: a wave would overflow the per-slot KV cache; \
                 submit at most {} prompt tokens",
                req.prompt.len(),
                max_ctx.saturating_sub(1)
            );
        }
        self.queue.push_back(req);
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Drain the queue, returning responses in completion order. On
    /// the native backend this runs the continuous-batching scheduler
    /// (per-step admission, paged KV); the PJRT backend keeps the wave
    /// loop. Either way each request's token stream is the same — the
    /// differential suite in `tests/continuous_batching.rs` holds the
    /// two paths bit-identical.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        if self.engine.native().is_some() {
            return self.run_continuous();
        }
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            out.extend(self.run_wave()?);
        }
        Ok(out)
    }

    /// Drain the queue through a [`scheduler::ContinuousScheduler`]
    /// with default sizing (dense-equivalent block pool, unbounded
    /// queue), folding its metrics into the coordinator's.
    fn run_continuous(&mut self) -> Result<Vec<Response>> {
        let engine = self.engine.native().expect("caller checked the backend");
        let mut sched =
            scheduler::ContinuousScheduler::new(engine, scheduler::ServeConfig::default())?;
        for req in self.queue.drain(..) {
            match sched.submit(req)? {
                scheduler::SubmitOutcome::Queued => {}
                scheduler::SubmitOutcome::Backpressure(req) => {
                    bail!("unbounded scheduler queue backpressured request {}", req.id)
                }
            }
        }
        let responses = sched.run_to_completion()?;
        self.metrics.merge(sched.into_metrics());
        Ok(responses)
    }

    /// Run one batch wave (up to `engine.batch()` requests).
    pub fn run_wave(&mut self) -> Result<Vec<Response>> {
        let b = self.engine.batch();
        let t = self.engine.prompt_len();
        let max_ctx = self.engine.max_ctx();
        let vocab = self.engine.vocab();
        let n = self.queue.len().min(b);
        if n == 0 {
            return Ok(Vec::new());
        }
        let wave: Vec<Request> = self.queue.drain(..n).collect();
        let start = Instant::now();

        // Pack prompts into the fixed batch. Unused slots get length 0
        // — the native backend skips their prefill forward passes
        // entirely (the PJRT backend clamps to the compiled shape).
        let mut tokens = vec![PAD; b * t];
        let mut lengths = vec![0i32; b];
        for (i, req) in wave.iter().enumerate() {
            tokens[i * t..i * t + req.prompt.len()].copy_from_slice(&req.prompt);
            lengths[i] = req.prompt.len() as i32;
        }
        let prompt_tokens: usize = lengths[..n].iter().map(|&l| l as usize).sum();
        let prefill_start = Instant::now();
        let mut step = self.engine.run_prefill(&tokens, &lengths)?;
        self.metrics.record_prefill(prefill_start.elapsed(), n, prompt_tokens);

        let mut rngs: Vec<Pcg> = wave.iter().map(|r| Pcg::new(r.seed)).collect();
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); n];
        let mut done = vec![false; n];
        let mut pos: Vec<i32> = lengths.clone();
        // Every slot's KV cache holds at most its prompt plus `budget`
        // generated tokens; capping at `max_ctx` minus the wave's
        // longest prompt keeps every slot inside the per-slot bound
        // without under-budgeting short-prompt waves on engines whose
        // compiled prompt length exceeds the context bound. (`submit`
        // already rejected prompts with no generation room at all.)
        let max_prompt = lengths[..n].iter().copied().max().unwrap_or(1).max(1) as usize;
        let budget = wave
            .iter()
            .map(|r| r.params.max_new_tokens)
            .max()
            .unwrap_or(0)
            .min(max_ctx.saturating_sub(max_prompt).max(1));

        for step_i in 0..budget {
            // Sample the next token for every live slot.
            let mut next = vec![PAD; b];
            for i in 0..n {
                if done[i] {
                    continue;
                }
                let row = &step.logits[i * vocab..(i + 1) * vocab];
                let tok = sampler::sample(row, &wave[i].params, &mut rngs[i]);
                generated[i].push(tok);
                if tok == EOS || generated[i].len() >= wave[i].params.max_new_tokens {
                    done[i] = true;
                }
                next[i] = tok;
            }
            // No decode after the final sample: its logits would never
            // be consumed, and since PR 4 a decode step is a full
            // batch-wide attention+MoE pass, not a cheap matvec.
            if step_i + 1 == budget || done[..n].iter().all(|&d| d) {
                break;
            }
            // Finished and unused slots are marked inactive (pos −1):
            // the native backend skips their forward passes entirely
            // instead of burning a full attention+MoE step on PAD.
            let step_pos: Vec<i32> = (0..b)
                .map(|i| if i < n && !done[i] { pos[i] } else { -1 })
                .collect();
            // Only slots still generating consume this decode step —
            // charging all n wave slots would inflate the reported
            // per-slot decode throughput once early slots hit EOS.
            let live = done[..n].iter().filter(|&&d| !d).count();
            let decode_start = Instant::now();
            step = self.engine.run_decode(&next, &step_pos, step.state)?;
            self.metrics.record_decode(decode_start.elapsed(), live);
            for p in pos.iter_mut() {
                *p += 1;
            }
        }

        let latency_ms = start.elapsed().as_secs_f64() * 1e3;
        let responses: Vec<Response> = wave
            .iter()
            .zip(generated)
            .map(|(req, tokens)| {
                let n_generated = tokens.len();
                Response { id: req.id, tokens, latency_ms, n_generated }
            })
            .collect();
        self.metrics.record_wave(start.elapsed(), &responses);
        Ok(responses)
    }
}
