//! Continuous-batching scheduler over the native engine.
//!
//! Replaces the batch-synchronous wave loop for the native backend:
//! requests join the running batch at **any** decode step (admission),
//! finished slots are recycled immediately, and every slot's KV state
//! lives in fixed-size blocks drawn from a shared
//! [`KvBlockPool`](crate::runtime::paged::KvBlockPool) instead of a
//! dense per-slot `max_ctx` buffer — so a short request holds one
//! block, not a full context's worth.
//!
//! ## Scheduling loop
//!
//! One [`ContinuousScheduler::step`] is: *admit* (pop queued requests
//! into free batch slots while both a slot and a worst-case block
//! reservation are available, prefilling each on its own paged cache),
//! then *decode* (advance every live slot one token as a single
//! [`forward_step_batch`](crate::runtime::forward::ForwardPass::forward_step_batch)
//! GEMM panel). Admission is strictly FIFO — a request that cannot
//! reserve its blocks waits rather than being overtaken, so no request
//! starves.
//!
//! ## Why per-slot streams are bit-identical to solo runs
//!
//! Three facts compose, each tested on its own layer:
//! 1. prefill is `forward_tokens` on the request's **own** cache —
//!    other slots are not involved at all;
//! 2. a batched decode step computes each column's projections with the
//!    panel GEMM, whose per-column accumulation order is defined to be
//!    exactly the single-column `vec_dot`'s (the PR 6 contract), and
//!    runs cache writes / RoPE / attention per column against that
//!    column's own cache — so each slot's logits carry the same bits
//!    as a solo `forward_token`, regardless of batch composition;
//! 3. sampling consumes a per-request `Pcg` stream advanced once per
//!    emitted token, and each request's token budget
//!    (`min(max_new, max_ctx − prompt_len)`) equals its solo-wave
//!    budget.
//! Hence admission order, batch packing and thread count cannot change
//! any request's tokens — `tests/continuous_batching.rs` sweeps all
//! three.
//!
//! ## Deadlock freedom & backpressure
//!
//! Admission reserves a request's **worst-case** block count up front;
//! the pool's `take` refuses to exceed reservations, so a live
//! request's mid-generation growth can never fail (its blocks were
//! promised at admission). Requests whose worst case exceeds the whole
//! pool are rejected at `submit` with a clear error; a bounded queue
//! hands the request back as [`SubmitOutcome::Backpressure`] instead of
//! stalling.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::sampler::{self, SamplingParams};
use crate::coordinator::{Request, Response};
use crate::eval::tasks::{EOS, PAD};
use crate::runtime::forward::{KvCache, Scratch};
use crate::runtime::native::NativeEngine;
use crate::runtime::paged::KvBlockPool;
use crate::util::rng::Pcg;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// Default tokens per KV block — fragmentation is at most 3 trailing
/// token slots per plane, while a full `NATIVE_MAX_CTX = 24` slot is a
/// 6-entry block table (see `runtime::paged` for the trade-off).
pub const DEFAULT_BLOCK_TOKENS: usize = 4;

/// Queue/pool sizing for a [`ContinuousScheduler`]. Zero means "pick
/// the default": enough blocks for every batch slot at full context, a
/// 4-token block, an unbounded queue.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeConfig {
    /// Total KV blocks in the pool (0 = `batch × ceil(max_ctx / block_tokens)`,
    /// i.e. paged layout with dense capacity).
    pub kv_blocks: usize,
    /// Tokens per KV block (0 = [`DEFAULT_BLOCK_TOKENS`]).
    pub block_tokens: usize,
    /// Queue depth before `submit` backpressures (0 = unbounded).
    pub max_pending: usize,
}

/// What happened to a structurally valid `submit`.
#[derive(Debug)]
pub enum SubmitOutcome {
    /// Queued for admission.
    Queued,
    /// The queue is at `max_pending`; the request is handed back to the
    /// caller, who should drive [`ContinuousScheduler::step`] (draining
    /// the queue) and retry.
    Backpressure(Request),
}

/// Per-slot state of a request that has been admitted into the batch.
/// Value-like on purpose (no heap fields): it is moved in and out of
/// the slot table without allocating.
struct ActiveSlot {
    id: u64,
    params: SamplingParams,
    rng: Pcg,
    /// Submit time — request latency spans queue wait + generation.
    submitted: Instant,
    /// Token budget: `min(max_new_tokens, max_ctx − prompt_len)`, the
    /// same cap a solo wave would apply.
    budget: usize,
    /// Blocks reserved in the pool at admission (released at finish).
    reserved: usize,
    /// The token to feed the next decode step (last sampled).
    next_tok: i32,
}

/// The continuous-batching scheduler: an admission queue, a fixed set
/// of batch slots with paged KV caches, and a metrics sink, all driven
/// against a borrowed [`NativeEngine`].
pub struct ContinuousScheduler<'e> {
    engine: &'e NativeEngine,
    pool: KvBlockPool,
    /// One persistent paged cache per batch slot, reused (release →
    /// grow) across the requests that pass through the slot.
    caches: Vec<KvCache>,
    slots: Vec<Option<ActiveSlot>>,
    queue: VecDeque<(Request, Instant)>,
    max_pending: usize,
    scratch: Scratch,
    /// `[batch][vocab]` logits staging for both admission prefill and
    /// batched decode.
    logits: Vec<f32>,
    /// Per-slot next-token inputs for the decode panel (PAD when dead).
    toks: Vec<i32>,
    live: Vec<bool>,
    /// Per-slot generated tokens, capacity pre-reserved to `max_ctx` so
    /// steady-state pushes never reallocate.
    gen: Vec<Vec<i32>>,
    /// Reusable sampling scratch (`sampler::sample_into`).
    samp: Vec<(usize, f32)>,
    responses: Vec<Response>,
    pub metrics: Metrics,
}

impl<'e> ContinuousScheduler<'e> {
    pub fn new(engine: &'e NativeEngine, cfg: ServeConfig) -> Result<Self> {
        let batch = engine.batch();
        let max_ctx = engine.max_ctx();
        let vocab = engine.vocab();
        let bt = if cfg.block_tokens == 0 { DEFAULT_BLOCK_TOKENS } else { cfg.block_tokens };
        let capacity =
            if cfg.kv_blocks == 0 { batch * max_ctx.div_ceil(bt) } else { cfg.kv_blocks };
        // The pool inherits the engine's KV scheme (set via
        // `NativeEngine::set_kv_scheme` before the scheduler exists),
        // so block byte sizes and admission budgets automatically
        // reflect the encoded per-token footprint.
        let pool = engine.new_block_pool(capacity, bt)?;
        let caches = (0..batch)
            .map(|_| engine.forward().new_paged_cache(&pool))
            .collect::<Result<Vec<_>>>()?;
        let mut metrics = Metrics::default();
        metrics.record_kv_config(engine.kv_scheme().name(), pool.bytes_per_token());
        Ok(ContinuousScheduler {
            engine,
            pool,
            caches,
            slots: (0..batch).map(|_| None).collect(),
            queue: VecDeque::new(),
            max_pending: cfg.max_pending,
            scratch: engine.forward().new_scratch_cols(batch),
            logits: vec![0.0; batch * vocab],
            toks: vec![PAD; batch],
            live: vec![false; batch],
            gen: (0..batch).map(|_| Vec::with_capacity(max_ctx)).collect(),
            samp: Vec::with_capacity(vocab),
            responses: Vec::new(),
            metrics,
        })
    }

    /// Validate and enqueue a request. Structural errors (prompt shape,
    /// a worst case no pool state could ever serve) are `Err` — they
    /// would stall forever if queued. A full queue is not an error: the
    /// request comes back as [`SubmitOutcome::Backpressure`].
    pub fn submit(&mut self, req: Request) -> Result<SubmitOutcome> {
        let plen = req.prompt.len();
        if plen == 0 || plen > self.engine.prompt_len() {
            self.metrics.rejected += 1;
            bail!("prompt length {plen} out of range 1..={}", self.engine.prompt_len());
        }
        let max_ctx = self.engine.max_ctx();
        if plen >= max_ctx {
            self.metrics.rejected += 1;
            bail!(
                "prompt length {plen} leaves no room to generate within the engine's \
                 max context {max_ctx}: submit at most {} prompt tokens",
                max_ctx.saturating_sub(1)
            );
        }
        let need = self.worst_case_blocks(plen, req.params.max_new_tokens);
        if need > self.pool.capacity() {
            self.metrics.rejected += 1;
            bail!(
                "request needs up to {need} KV blocks ({} tokens at {} per block) but \
                 the pool only holds {} — it could never be admitted; raise --kv-blocks \
                 or shorten the request",
                (plen + req.params.max_new_tokens).min(max_ctx),
                self.pool.block_tokens(),
                self.pool.capacity()
            );
        }
        if self.max_pending > 0 && self.queue.len() >= self.max_pending {
            return Ok(SubmitOutcome::Backpressure(req));
        }
        self.queue.push_back((req, Instant::now()));
        Ok(SubmitOutcome::Queued)
    }

    /// Cancel a request by id, wherever it is: still queued (dropped)
    /// or mid-generation (its slot is torn down and every KV block goes
    /// straight back to the pool). Returns whether anything matched.
    /// No response is emitted for a cancelled request.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(qi) = self.queue.iter().position(|(r, _)| r.id == id) {
            self.queue.remove(qi);
            self.metrics.cancelled += 1;
            return true;
        }
        let Some(i) = self
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|slot| slot.id == id))
        else {
            return false;
        };
        let slot = self.slots[i].take().expect("matched above");
        self.caches[i].release(&mut self.pool);
        self.pool.unreserve(slot.reserved);
        self.gen[i].clear();
        self.metrics.cancelled += 1;
        true
    }

    /// Admit queued requests into free batch slots (FIFO) while the
    /// pool can reserve each one's worst-case blocks. Each admission
    /// prefills the prompt on the slot's own paged cache and samples
    /// the first token from the prefill logits — exactly a solo run's
    /// step 0. Returns how many requests were admitted. After warmup
    /// (pool free list populated, buffers grown) admission performs no
    /// heap allocation beyond pool bookkeeping.
    pub fn admit(&mut self) -> Result<usize> {
        let v = self.engine.vocab();
        let max_ctx = self.engine.max_ctx();
        let mut admitted = 0;
        loop {
            let Some((front, _)) = self.queue.front() else { break };
            let Some(i) = self.slots.iter().position(|s| s.is_none()) else { break };
            let plen = front.prompt.len();
            let need = self.worst_case_blocks(plen, front.params.max_new_tokens);
            if !self.pool.try_reserve(need) {
                // FIFO: wait for blocks rather than overtake the front.
                break;
            }
            let (req, submitted) = self.queue.pop_front().expect("front checked above");
            debug_assert_eq!(self.caches[i].len(), 0, "free slot with a non-empty cache");
            self.caches[i].grow_to(plen, &mut self.pool)?;
            let row = &mut self.logits[i * v..(i + 1) * v];
            let t0 = Instant::now();
            self.engine.forward().forward_tokens(
                &req.prompt,
                &mut self.caches[i],
                &mut self.scratch,
                Some(row),
            )?;
            self.metrics.record_prefill_step(t0.elapsed(), plen);
            self.metrics.admitted += 1;
            admitted += 1;
            let mut slot = ActiveSlot {
                id: req.id,
                params: req.params,
                rng: Pcg::new(req.seed),
                submitted,
                budget: req.params.max_new_tokens.min(max_ctx - plen),
                reserved: need,
                next_tok: PAD,
            };
            if slot.budget == 0 {
                // Zero-budget request: prefill only, no sample (the rng
                // stays untouched, as in a solo wave of budget 0).
                self.finish_slot(i, slot);
                continue;
            }
            let row = &self.logits[i * v..(i + 1) * v];
            let tok = sampler::sample_into(row, &slot.params, &mut slot.rng, &mut self.samp);
            self.gen[i].push(tok);
            if tok == EOS || self.gen[i].len() >= slot.budget {
                self.finish_slot(i, slot);
            } else {
                slot.next_tok = tok;
                self.slots[i] = Some(slot);
            }
        }
        Ok(admitted)
    }

    /// Advance every live slot one token as a single batched GEMM
    /// panel. Returns the number of live slots stepped (0 = idle).
    /// Steady-state (no slot crossing a block boundary, none
    /// finishing), this performs **zero** heap allocations — the
    /// counting-allocator test pins that down.
    pub fn decode_step(&mut self) -> Result<usize> {
        let v = self.engine.vocab();
        let mut n_live = 0;
        for i in 0..self.slots.len() {
            match &self.slots[i] {
                Some(slot) => {
                    self.live[i] = true;
                    self.toks[i] = slot.next_tok;
                    n_live += 1;
                }
                None => {
                    self.live[i] = false;
                    self.toks[i] = PAD;
                }
            }
        }
        if n_live == 0 {
            return Ok(0);
        }
        for i in 0..self.caches.len() {
            if self.live[i] {
                // Covered by the admission-time reservation, so this
                // can only draw from promised blocks — never starve.
                let len = self.caches[i].len();
                self.caches[i].grow_to(len + 1, &mut self.pool)?;
            }
        }
        let t0 = Instant::now();
        self.engine.forward().forward_step_batch(
            &self.toks,
            &self.live,
            &mut self.caches,
            &mut self.scratch,
            &mut self.logits,
        )?;
        self.metrics.record_decode_step(t0.elapsed(), n_live);
        for i in 0..self.slots.len() {
            if !self.live[i] {
                continue;
            }
            let mut slot = self.slots[i].take().expect("live slot");
            let row = &self.logits[i * v..(i + 1) * v];
            let tok = sampler::sample_into(row, &slot.params, &mut slot.rng, &mut self.samp);
            self.gen[i].push(tok);
            if tok == EOS || self.gen[i].len() >= slot.budget {
                self.finish_slot(i, slot);
            } else {
                slot.next_tok = tok;
                self.slots[i] = Some(slot);
            }
        }
        Ok(n_live)
    }

    /// One scheduler tick: admissions, then a batched decode step.
    /// Returns whether any work happened.
    pub fn step(&mut self) -> Result<bool> {
        let admitted = self.admit()?;
        let stepped = self.decode_step()?;
        Ok(admitted > 0 || stepped > 0)
    }

    /// Drive [`ContinuousScheduler::step`] until the queue and the
    /// batch are both empty, then hand back the accumulated responses
    /// (completion order).
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        loop {
            let progressed = self.step()?;
            if !progressed {
                if self.queue.is_empty() && self.live() == 0 {
                    break;
                }
                // Unreachable by construction (submit rejects requests
                // that can never reserve; an empty batch has the whole
                // pool free) — guarded so a scheduler bug surfaces as
                // an error, not an infinite loop.
                bail!(
                    "continuous scheduler stalled with {} queued and {} live requests",
                    self.queue.len(),
                    self.live()
                );
            }
        }
        Ok(std::mem::take(&mut self.responses))
    }

    /// Responses completed so far (drains the internal buffer).
    pub fn take_responses(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.responses)
    }

    /// Requests waiting for admission.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently generating in the batch.
    pub fn live(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// The KV block pool (tests assert its leak/peak invariants).
    pub fn pool(&self) -> &KvBlockPool {
        &self.pool
    }

    /// Consume the scheduler, handing its metrics to the caller (the
    /// coordinator merges them into its long-lived sink).
    pub fn into_metrics(self) -> Metrics {
        self.metrics
    }

    /// Worst-case block demand of a request: its prompt plus full token
    /// budget, clamped to the context bound — the amount reserved at
    /// admission and validated against pool capacity at submit.
    fn worst_case_blocks(&self, plen: usize, max_new: usize) -> usize {
        let tokens = (plen + max_new).min(self.engine.max_ctx());
        tokens.div_ceil(self.pool.block_tokens())
    }

    /// Retire a slot: every KV block back to the pool, reservation
    /// dropped, response recorded. The generation buffer is cloned (its
    /// pre-reserved capacity stays with the slot) and cleared.
    fn finish_slot(&mut self, i: usize, slot: ActiveSlot) {
        self.caches[i].release(&mut self.pool);
        self.pool.unreserve(slot.reserved);
        let tokens = self.gen[i].clone();
        self.gen[i].clear();
        let latency_ms = slot.submitted.elapsed().as_secs_f64() * 1e3;
        let n_generated = tokens.len();
        self.metrics.record_request(latency_ms, n_generated);
        self.responses.push(Response { id: slot.id, tokens, latency_ms, n_generated });
    }
}
