//! Serving metrics: latency distributions and throughput counters.

use std::time::Duration;

#[derive(Debug, Default)]
pub struct Metrics {
    pub waves: u64,
    pub requests: u64,
    pub generated_tokens: u64,
    pub prefill_calls: u64,
    pub decode_calls: u64,
    /// Sequences prefetched across all prefill calls.
    pub prefill_slots: u64,
    /// Prompt tokens pushed through prefill across all calls (the
    /// denominator panel-prefill throughput is measured in).
    pub prefill_tokens: u64,
    /// Live slot-steps across all decode calls (a decode step that only
    /// three of sixteen batch slots still need counts as 3, not 16).
    pub decode_slot_steps: u64,
    /// Requests admitted into the continuous batch.
    pub admitted: u64,
    /// Requests that ran to completion under continuous batching.
    pub completed: u64,
    /// Requests cancelled (queued or mid-generation).
    pub cancelled: u64,
    /// Requests rejected at submit time (invalid, or can never fit the
    /// KV block pool).
    pub rejected: u64,
    /// Total decode-step time under continuous batching, accumulated as
    /// integer nanoseconds so the steady-state decode loop records
    /// without pushing to a `Vec` (the zero-alloc gate).
    pub decode_step_ns: u128,
    /// Total admission-prefill time under continuous batching —
    /// counter-only for the same reason: admission must allocate
    /// nothing but KV blocks from the pool.
    pub prefill_step_ns: u128,
    /// Active KV-cache storage scheme of the engine this run was
    /// served on (`""` until recorded; the engine default is `f32`,
    /// `q8_0` stores encoded codec lines — see `--kv-scheme`).
    pub kv_scheme: &'static str,
    /// Engine-measured KV bytes per cached token under that scheme
    /// (all layers, both planes — `KvCache::bytes_per_token`).
    pub kv_bytes_per_token: u64,
    /// Shard workers the native forward pass was partitioned across
    /// (0 = unsharded local execution).
    pub shards: u64,
    /// Barrier/all-gather exchange steps the sharded pass completed
    /// (copied from `runtime::sharded::ShardRuntime` at run end).
    pub exchanges: u64,
    /// Total driver time spent inside exchange barriers, nanoseconds.
    pub exchange_wait_ns: u64,
    prefill_ms: Vec<f64>,
    decode_ms: Vec<f64>,
    wave_ms: Vec<f64>,
    /// Per-request end-to-end latencies (submit → completion) under
    /// continuous batching; pushed at request *finish*, never per step.
    request_latency_ms: Vec<f64>,
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = (p * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx]
}

#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub median: f64,
    pub p90: f64,
    pub mean: f64,
}

fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary { median: 0.0, p90: 0.0, mean: 0.0 };
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        median: pct(&s, 0.5),
        p90: pct(&s, 0.9),
        mean: xs.iter().sum::<f64>() / xs.len() as f64,
    }
}

impl Metrics {
    /// Record a prefill call covering `n` live sequences totalling
    /// `tokens` prompt tokens.
    pub fn record_prefill(&mut self, d: Duration, n: usize, tokens: usize) {
        self.prefill_calls += 1;
        self.prefill_slots += n as u64;
        self.prefill_tokens += tokens as u64;
        self.prefill_ms.push(d.as_secs_f64() * 1e3);
    }

    /// Record one continuous-batching admission prefill of `tokens`
    /// prompt tokens for a single slot. Counter-only — see
    /// [`Metrics::record_decode_step`].
    pub fn record_prefill_step(&mut self, d: Duration, tokens: usize) {
        self.prefill_calls += 1;
        self.prefill_slots += 1;
        self.prefill_tokens += tokens as u64;
        self.prefill_step_ns += d.as_nanos();
    }

    /// Prompt tokens per second of prefill time — the throughput the
    /// panel-prefill GEMM path is measured in.
    pub fn prefill_tokens_per_sec(&self) -> f64 {
        let total_s: f64 =
            self.prefill_ms.iter().sum::<f64>() / 1e3 + self.prefill_step_ns as f64 / 1e9;
        if total_s == 0.0 {
            0.0
        } else {
            self.prefill_tokens as f64 / total_s
        }
    }

    /// Record a decode step that `n` slots were still live for.
    pub fn record_decode(&mut self, d: Duration, n: usize) {
        self.decode_calls += 1;
        self.decode_slot_steps += n as u64;
        self.decode_ms.push(d.as_secs_f64() * 1e3);
    }

    /// Record one continuous-batching decode step that `live` slots
    /// rode in. Counter-only on purpose: unlike [`Metrics::record_decode`]
    /// it pushes nothing to a `Vec`, so the steady-state decode loop
    /// stays heap-allocation-free (asserted by the counting-allocator
    /// test in `tests/continuous_batching.rs`).
    pub fn record_decode_step(&mut self, d: Duration, live: usize) {
        self.decode_calls += 1;
        self.decode_slot_steps += live as u64;
        self.decode_step_ns += d.as_nanos();
    }

    /// Record the KV-cache configuration of the engine a run is served
    /// on, so reports identify what was measured. Set once at
    /// scheduler/serve construction — never in the decode loop (the
    /// `&'static str` keeps this allocation-free regardless).
    pub fn record_kv_config(&mut self, scheme: &'static str, bytes_per_token: usize) {
        self.kv_scheme = scheme;
        self.kv_bytes_per_token = bytes_per_token as u64;
    }

    /// Record a request completed under continuous batching.
    pub fn record_request(&mut self, latency_ms: f64, n_tokens: usize) {
        self.completed += 1;
        self.requests += 1;
        self.generated_tokens += n_tokens as u64;
        self.request_latency_ms.push(latency_ms);
    }

    /// (p50, p99) of per-request submit→completion latency in ms.
    pub fn latency_percentiles(&self) -> (f64, f64) {
        if self.request_latency_ms.is_empty() {
            return (0.0, 0.0);
        }
        let mut s = self.request_latency_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (pct(&s, 0.5), pct(&s, 0.99))
    }

    /// Fold another `Metrics` into this one (the coordinator merges a
    /// finished continuous-scheduler run into its long-lived metrics).
    pub fn merge(&mut self, other: Metrics) {
        self.waves += other.waves;
        self.requests += other.requests;
        self.generated_tokens += other.generated_tokens;
        self.prefill_calls += other.prefill_calls;
        self.decode_calls += other.decode_calls;
        self.prefill_slots += other.prefill_slots;
        self.prefill_tokens += other.prefill_tokens;
        self.decode_slot_steps += other.decode_slot_steps;
        self.admitted += other.admitted;
        self.completed += other.completed;
        self.cancelled += other.cancelled;
        self.rejected += other.rejected;
        self.decode_step_ns += other.decode_step_ns;
        self.prefill_step_ns += other.prefill_step_ns;
        if self.kv_scheme.is_empty() {
            self.kv_scheme = other.kv_scheme;
            self.kv_bytes_per_token = other.kv_bytes_per_token;
        }
        self.shards = self.shards.max(other.shards);
        self.exchanges += other.exchanges;
        self.exchange_wait_ns += other.exchange_wait_ns;
        self.prefill_ms.extend(other.prefill_ms);
        self.decode_ms.extend(other.decode_ms);
        self.wave_ms.extend(other.wave_ms);
        self.request_latency_ms.extend(other.request_latency_ms);
    }

    /// Live slot-steps per second of decode time — the honest per-slot
    /// decode throughput (excludes finished slots riding in the batch).
    /// Covers both the wave path's per-call samples and the continuous
    /// path's counter-only nanosecond total.
    pub fn decode_slot_steps_per_sec(&self) -> f64 {
        let total_s: f64 =
            self.decode_ms.iter().sum::<f64>() / 1e3 + self.decode_step_ns as f64 / 1e9;
        if total_s == 0.0 {
            0.0
        } else {
            self.decode_slot_steps as f64 / total_s
        }
    }

    pub fn record_wave(&mut self, d: Duration, responses: &[super::Response]) {
        self.waves += 1;
        self.requests += responses.len() as u64;
        self.generated_tokens += responses.iter().map(|r| r.n_generated as u64).sum::<u64>();
        self.wave_ms.push(d.as_secs_f64() * 1e3);
    }

    pub fn prefill_summary(&self) -> Summary {
        summarize(&self.prefill_ms)
    }

    pub fn decode_summary(&self) -> Summary {
        summarize(&self.decode_ms)
    }

    pub fn wave_summary(&self) -> Summary {
        summarize(&self.wave_ms)
    }

    /// Generated tokens per second of total wave time.
    pub fn tokens_per_sec(&self) -> f64 {
        let total_s: f64 = self.wave_ms.iter().sum::<f64>() / 1e3;
        if total_s == 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / total_s
        }
    }

    /// Requests per second of total wave time.
    pub fn requests_per_sec(&self) -> f64 {
        let total_s: f64 = self.wave_ms.iter().sum::<f64>() / 1e3;
        if total_s == 0.0 {
            0.0
        } else {
            self.requests as f64 / total_s
        }
    }

    pub fn report(&self) -> String {
        let p = self.prefill_summary();
        let d = self.decode_summary();
        let w = self.wave_summary();
        let continuous = if self.completed > 0 {
            let (p50, p99) = self.latency_percentiles();
            format!(
                "\ncontinuous: {} admitted, {} completed, {} cancelled, {} rejected, \
                 latency p50 {:.1} ms, p99 {:.1} ms",
                self.admitted, self.completed, self.cancelled, self.rejected, p50, p99
            )
        } else {
            String::new()
        };
        let sharded = if self.shards > 0 {
            format!(
                "\nsharded: {} shards, {} exchange barriers, {:.1} ms total exchange wait",
                self.shards,
                self.exchanges,
                self.exchange_wait_ns as f64 / 1e6
            )
        } else {
            String::new()
        };
        let kv = if self.kv_scheme.is_empty() {
            String::new()
        } else {
            format!(
                "\nkv: scheme {}, {} B/token measured",
                self.kv_scheme, self.kv_bytes_per_token
            )
        };
        format!(
            "waves {} | requests {} | gen tokens {}\n\
             prefill: {} calls ({} seqs, {} prompt tokens), median {:.1} ms, p90 {:.1} ms\n\
             decode:  {} calls ({} live slot-steps), median {:.1} ms, p90 {:.1} ms\n\
             wave:    median {:.1} ms, p90 {:.1} ms\n\
             throughput: {:.1} tok/s, {:.2} req/s, {:.1} live slot-steps/s, \
             {:.1} prefill tok/s{continuous}{sharded}{kv}",
            self.waves,
            self.requests,
            self.generated_tokens,
            self.prefill_calls,
            self.prefill_slots,
            self.prefill_tokens,
            p.median,
            p.p90,
            self.decode_calls,
            self.decode_slot_steps,
            d.median,
            d.p90,
            w.median,
            w.p90,
            self.tokens_per_sec(),
            self.requests_per_sec(),
            self.decode_slot_steps_per_sec(),
            self.prefill_tokens_per_sec()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summaries_from_samples() {
        let mut m = Metrics::default();
        for i in 1..=10 {
            m.record_decode(Duration::from_millis(i), 4);
        }
        let s = m.decode_summary();
        assert_eq!(m.decode_calls, 10);
        assert_eq!(m.decode_slot_steps, 40, "4 live slots × 10 steps");
        assert!(m.decode_slot_steps_per_sec() > 0.0);
        assert!((s.mean - 5.5).abs() < 1e-9);
        assert!(s.median >= 5.0 && s.median <= 6.0);
        assert!(s.p90 >= 9.0);
    }

    #[test]
    fn prefill_token_totals_and_throughput() {
        let mut m = Metrics::default();
        m.record_prefill(Duration::from_millis(10), 3, 24);
        m.record_prefill(Duration::from_millis(10), 1, 8);
        assert_eq!(m.prefill_calls, 2);
        assert_eq!(m.prefill_slots, 4);
        assert_eq!(m.prefill_tokens, 32, "prompt-token totals accumulate across calls");
        // 32 tokens over 20 ms of prefill time → 1600 tok/s.
        assert!((m.prefill_tokens_per_sec() - 1600.0).abs() < 1.0);
        let report = m.report();
        assert!(report.contains("32 prompt tokens"), "{report}");
        assert!(report.contains("prefill tok/s"), "{report}");
    }

    #[test]
    fn continuous_counters_and_percentiles() {
        let mut m = Metrics::default();
        m.admitted = 3;
        m.record_decode_step(Duration::from_millis(2), 3);
        m.record_decode_step(Duration::from_millis(2), 2);
        for lat in [10.0, 20.0, 30.0] {
            m.record_request(lat, 4);
        }
        assert_eq!(m.decode_calls, 2);
        assert_eq!(m.decode_slot_steps, 5);
        assert_eq!(m.completed, 3);
        assert_eq!(m.generated_tokens, 12);
        // 5 slot-steps over 4 ms of counter-only decode time.
        assert!((m.decode_slot_steps_per_sec() - 1250.0).abs() < 1.0);
        let (p50, p99) = m.latency_percentiles();
        assert_eq!(p50, 20.0);
        assert_eq!(p99, 30.0);
        let report = m.report();
        assert!(report.contains("continuous:"), "{report}");
        assert!(report.contains("3 completed"), "{report}");

        let mut base = Metrics::default();
        base.record_decode(Duration::from_millis(1), 1);
        base.merge(m);
        assert_eq!(base.decode_calls, 3);
        assert_eq!(base.completed, 3);
        assert_eq!(base.latency_percentiles().0, 20.0);
    }

    #[test]
    fn kv_config_line_and_merge_precedence() {
        let mut m = Metrics::default();
        assert!(!m.report().contains("kv:"), "no kv line before recording");
        m.record_kv_config("q8_0", 714);
        let report = m.report();
        assert!(report.contains("kv: scheme q8_0, 714 B/token"), "{report}");

        let mut base = Metrics::default();
        base.merge(m);
        assert_eq!(base.kv_scheme, "q8_0");
        assert_eq!(base.kv_bytes_per_token, 714);
        // An already-recorded scheme is not overwritten by a later merge.
        let mut other = Metrics::default();
        other.record_kv_config("f32", 2688);
        base.merge(other);
        assert_eq!(base.kv_scheme, "q8_0");
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.tokens_per_sec(), 0.0);
        assert_eq!(m.prefill_tokens_per_sec(), 0.0);
        assert_eq!(m.wave_summary().median, 0.0);
    }
}
