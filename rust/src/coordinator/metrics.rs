//! Serving metrics: latency distributions and throughput counters.

use std::time::Duration;

#[derive(Debug, Default)]
pub struct Metrics {
    pub waves: u64,
    pub requests: u64,
    pub generated_tokens: u64,
    pub prefill_calls: u64,
    pub decode_calls: u64,
    /// Sequences prefetched across all prefill calls.
    pub prefill_slots: u64,
    /// Prompt tokens pushed through prefill across all calls (the
    /// denominator panel-prefill throughput is measured in).
    pub prefill_tokens: u64,
    /// Live slot-steps across all decode calls (a decode step that only
    /// three of sixteen batch slots still need counts as 3, not 16).
    pub decode_slot_steps: u64,
    prefill_ms: Vec<f64>,
    decode_ms: Vec<f64>,
    wave_ms: Vec<f64>,
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = (p * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx]
}

#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub median: f64,
    pub p90: f64,
    pub mean: f64,
}

fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary { median: 0.0, p90: 0.0, mean: 0.0 };
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        median: pct(&s, 0.5),
        p90: pct(&s, 0.9),
        mean: xs.iter().sum::<f64>() / xs.len() as f64,
    }
}

impl Metrics {
    /// Record a prefill call covering `n` live sequences totalling
    /// `tokens` prompt tokens.
    pub fn record_prefill(&mut self, d: Duration, n: usize, tokens: usize) {
        self.prefill_calls += 1;
        self.prefill_slots += n as u64;
        self.prefill_tokens += tokens as u64;
        self.prefill_ms.push(d.as_secs_f64() * 1e3);
    }

    /// Prompt tokens per second of prefill time — the throughput the
    /// panel-prefill GEMM path is measured in.
    pub fn prefill_tokens_per_sec(&self) -> f64 {
        let total_s: f64 = self.prefill_ms.iter().sum::<f64>() / 1e3;
        if total_s == 0.0 {
            0.0
        } else {
            self.prefill_tokens as f64 / total_s
        }
    }

    /// Record a decode step that `n` slots were still live for.
    pub fn record_decode(&mut self, d: Duration, n: usize) {
        self.decode_calls += 1;
        self.decode_slot_steps += n as u64;
        self.decode_ms.push(d.as_secs_f64() * 1e3);
    }

    /// Live slot-steps per second of decode time — the honest per-slot
    /// decode throughput (excludes finished slots riding in the batch).
    pub fn decode_slot_steps_per_sec(&self) -> f64 {
        let total_s: f64 = self.decode_ms.iter().sum::<f64>() / 1e3;
        if total_s == 0.0 {
            0.0
        } else {
            self.decode_slot_steps as f64 / total_s
        }
    }

    pub fn record_wave(&mut self, d: Duration, responses: &[super::Response]) {
        self.waves += 1;
        self.requests += responses.len() as u64;
        self.generated_tokens += responses.iter().map(|r| r.n_generated as u64).sum::<u64>();
        self.wave_ms.push(d.as_secs_f64() * 1e3);
    }

    pub fn prefill_summary(&self) -> Summary {
        summarize(&self.prefill_ms)
    }

    pub fn decode_summary(&self) -> Summary {
        summarize(&self.decode_ms)
    }

    pub fn wave_summary(&self) -> Summary {
        summarize(&self.wave_ms)
    }

    /// Generated tokens per second of total wave time.
    pub fn tokens_per_sec(&self) -> f64 {
        let total_s: f64 = self.wave_ms.iter().sum::<f64>() / 1e3;
        if total_s == 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / total_s
        }
    }

    /// Requests per second of total wave time.
    pub fn requests_per_sec(&self) -> f64 {
        let total_s: f64 = self.wave_ms.iter().sum::<f64>() / 1e3;
        if total_s == 0.0 {
            0.0
        } else {
            self.requests as f64 / total_s
        }
    }

    pub fn report(&self) -> String {
        let p = self.prefill_summary();
        let d = self.decode_summary();
        let w = self.wave_summary();
        format!(
            "waves {} | requests {} | gen tokens {}\n\
             prefill: {} calls ({} seqs, {} prompt tokens), median {:.1} ms, p90 {:.1} ms\n\
             decode:  {} calls ({} live slot-steps), median {:.1} ms, p90 {:.1} ms\n\
             wave:    median {:.1} ms, p90 {:.1} ms\n\
             throughput: {:.1} tok/s, {:.2} req/s, {:.1} live slot-steps/s, \
             {:.1} prefill tok/s",
            self.waves,
            self.requests,
            self.generated_tokens,
            self.prefill_calls,
            self.prefill_slots,
            self.prefill_tokens,
            p.median,
            p.p90,
            self.decode_calls,
            self.decode_slot_steps,
            d.median,
            d.p90,
            w.median,
            w.p90,
            self.tokens_per_sec(),
            self.requests_per_sec(),
            self.decode_slot_steps_per_sec(),
            self.prefill_tokens_per_sec()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summaries_from_samples() {
        let mut m = Metrics::default();
        for i in 1..=10 {
            m.record_decode(Duration::from_millis(i), 4);
        }
        let s = m.decode_summary();
        assert_eq!(m.decode_calls, 10);
        assert_eq!(m.decode_slot_steps, 40, "4 live slots × 10 steps");
        assert!(m.decode_slot_steps_per_sec() > 0.0);
        assert!((s.mean - 5.5).abs() < 1e-9);
        assert!(s.median >= 5.0 && s.median <= 6.0);
        assert!(s.p90 >= 9.0);
    }

    #[test]
    fn prefill_token_totals_and_throughput() {
        let mut m = Metrics::default();
        m.record_prefill(Duration::from_millis(10), 3, 24);
        m.record_prefill(Duration::from_millis(10), 1, 8);
        assert_eq!(m.prefill_calls, 2);
        assert_eq!(m.prefill_slots, 4);
        assert_eq!(m.prefill_tokens, 32, "prompt-token totals accumulate across calls");
        // 32 tokens over 20 ms of prefill time → 1600 tok/s.
        assert!((m.prefill_tokens_per_sec() - 1600.0).abs() < 1.0);
        let report = m.report();
        assert!(report.contains("32 prompt tokens"), "{report}");
        assert!(report.contains("prefill tok/s"), "{report}");
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.tokens_per_sec(), 0.0);
        assert_eq!(m.prefill_tokens_per_sec(), 0.0);
        assert_eq!(m.wave_summary().median, 0.0);
    }
}
