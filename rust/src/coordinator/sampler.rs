//! Token sampling — temperature + nucleus (top-p), matching the paper's
//! decoding configuration (§4.2: temperature 0.6, top-p 0.95).

use crate::util::rng::Pcg;

/// Sampling configuration.
#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    pub temperature: f32,
    pub top_p: f32,
    /// Maximum tokens to generate (answers are short in the proxy
    /// suites; the paper's 32,768-token budget is a no-op here).
    pub max_new_tokens: usize,
}

impl SamplingParams {
    /// The paper's configuration (§4.2).
    pub fn paper() -> Self {
        SamplingParams { temperature: 0.6, top_p: 0.95, max_new_tokens: 8 }
    }

    /// Greedy decoding.
    pub fn greedy() -> Self {
        SamplingParams { temperature: 0.0, top_p: 1.0, max_new_tokens: 8 }
    }
}

/// Sample one token from a logits row.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Pcg) -> i32 {
    let mut buf = Vec::new();
    sample_into(logits, params, rng, &mut buf)
}

/// [`sample`] with a caller-owned scratch buffer: once `buf` has grown
/// to the vocab size it is only cleared and refilled, so the continuous
/// scheduler's steady-state decode loop samples without touching the
/// heap. Bit-identical to [`sample`] — the unstable sort's explicit
/// ascending-index tie-break reproduces exactly the order a stable
/// descending-probability sort leaves equal entries in.
pub fn sample_into(
    logits: &[f32],
    params: &SamplingParams,
    rng: &mut Pcg,
    buf: &mut Vec<(usize, f32)>,
) -> i32 {
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    // Softmax with temperature (stable: subtract max).
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let inv_t = 1.0 / params.temperature;
    buf.clear();
    buf.extend(logits.iter().enumerate().map(|(i, &l)| (i, ((l - max) * inv_t).exp())));
    let probs = buf;
    let z: f32 = probs.iter().map(|(_, p)| p).sum();
    for p in probs.iter_mut() {
        p.1 /= z;
    }
    // Nucleus truncation.
    probs.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    let mut cum = 0.0;
    let mut cut = probs.len();
    for (i, (_, p)) in probs.iter().enumerate() {
        cum += p;
        if cum >= params.top_p {
            cut = i + 1;
            break;
        }
    }
    probs.truncate(cut);
    let z: f32 = probs.iter().map(|(_, p)| p).sum();
    let mut r = rng.next_f32() * z;
    for (i, p) in probs.iter() {
        r -= p;
        if r <= 0.0 {
            return *i as i32;
        }
    }
    probs.last().map(|(i, _)| *i as i32).unwrap_or(0)
}

pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let mut rng = Pcg::new(1);
        assert_eq!(sample(&logits, &SamplingParams::greedy(), &mut rng), 1);
    }

    #[test]
    fn top_p_excludes_tail() {
        // One dominant token (p > 0.95): nucleus keeps only it.
        let mut logits = vec![0.0f32; 8];
        logits[3] = 20.0;
        let params = SamplingParams { temperature: 1.0, top_p: 0.95, max_new_tokens: 4 };
        let mut rng = Pcg::new(2);
        for _ in 0..100 {
            assert_eq!(sample(&logits, &params, &mut rng), 3);
        }
    }

    #[test]
    fn temperature_spreads_samples() {
        let logits = vec![1.0f32, 1.0, 1.0, 1.0];
        let params = SamplingParams { temperature: 1.0, top_p: 1.0, max_new_tokens: 4 };
        let mut rng = Pcg::new(3);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[sample(&logits, &params, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "uniform logits must hit all tokens");
    }

    #[test]
    fn deterministic_given_seed() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let params = SamplingParams::paper();
        let a: Vec<i32> = {
            let mut rng = Pcg::new(7);
            (0..20).map(|_| sample(&logits, &params, &mut rng)).collect()
        };
        let b: Vec<i32> = {
            let mut rng = Pcg::new(7);
            (0..20).map(|_| sample(&logits, &params, &mut rng)).collect()
        };
        assert_eq!(a, b);
    }

    /// The paper protocol (temperature 0.6 / top-p 0.95) must be a pure
    /// function of (logits, seed): distinct seeds explore, equal seeds
    /// replay — the property the eval harness's per-(question, sample)
    /// seeding relies on.
    #[test]
    fn paper_protocol_seed_sensitive_but_reproducible() {
        let params = SamplingParams::paper();
        assert_eq!(params.temperature, 0.6);
        assert_eq!(params.top_p, 0.95);
        // Flat-ish logits so the nucleus keeps several candidates.
        let logits: Vec<f32> = (0..64).map(|i| (i as f32 * 0.11).cos() * 0.5).collect();
        let draw = |seed: u64| -> Vec<i32> {
            let mut rng = Pcg::new(seed);
            (0..64).map(|_| sample(&logits, &params, &mut rng)).collect()
        };
        let mut distinct = 0;
        for seed in 0..8u64 {
            assert_eq!(draw(seed), draw(seed), "seed {seed} must replay exactly");
            if draw(seed) != draw(seed + 1) {
                distinct += 1;
            }
        }
        assert!(distinct >= 6, "only {distinct}/8 adjacent seed pairs differed");
    }

    /// `sample_into` must be bit-identical to `sample` — including on
    /// logits rows full of exact ties, where the unstable sort's
    /// index tie-break has to reproduce the stable sort's order.
    #[test]
    fn sample_into_matches_sample_bit_for_bit() {
        let params = SamplingParams { temperature: 0.8, top_p: 0.9, max_new_tokens: 4 };
        // Many repeated values → equal probabilities → tie-break matters.
        let logits: Vec<f32> = (0..48).map(|i| ((i % 5) as f32) * 0.25).collect();
        let mut buf = Vec::new();
        for seed in 0..32u64 {
            let mut a = Pcg::new(seed);
            let mut b = Pcg::new(seed);
            for _ in 0..16 {
                assert_eq!(
                    sample(&logits, &params, &mut a),
                    sample_into(&logits, &params, &mut b, &mut buf),
                    "seed {seed}"
                );
            }
        }
    }

    /// Cloning the RNG mid-stream must replay the suffix — the
    /// coordinator assumes per-slot sampling state is value-like.
    #[test]
    fn sampling_stream_resumable_from_cloned_rng() {
        let params = SamplingParams::paper();
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.23).sin()).collect();
        let mut rng = Pcg::new(0x5eed);
        for _ in 0..10 {
            sample(&logits, &params, &mut rng);
        }
        let mut fork = rng.clone();
        let tail: Vec<i32> = (0..16).map(|_| sample(&logits, &params, &mut rng)).collect();
        let replay: Vec<i32> = (0..16).map(|_| sample(&logits, &params, &mut fork)).collect();
        assert_eq!(tail, replay);
    }
}
