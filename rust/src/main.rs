//! `dsq` — the leader binary: quantization, serving, evaluation, and
//! table regeneration for the DeepSeek-quantization reproduction.
//!
//! ```text
//! dsq table 1|6|7|8 [--paper]            regenerate resource tables
//! dsq table 2|3|4|5 [--hlo D --ckpt-dir D]  accuracy tables (needs artifacts)
//! dsq quantize IN.dsq --scheme S --output OUT.dsq [--imatrix F] [--threads N]
//! dsq import IN.gguf --output OUT.dsq [--threads N]   llama.cpp → DSQ1
//! dsq export IN.dsq --output OUT.gguf                  DSQ1 → llama.cpp
//! dsq eval --hlo D --ckpt F [--suite N] [--full-size] [--out R.json] [--native]
//! dsq eval --native [--model M] [--scheme S]   (synthetic container, no artifacts)
//! dsq serve --hlo D --ckpt F --requests N [--native]   (serving smoke/throughput)
//! dsq serve --native [--model M] [--scheme S] [--kv-scheme f32|q8_0] [--requests N]
//!           [--kv-blocks N] [--block-tokens N] [--max-pending N] [--wave]
//!   Native serving runs the continuous-batching scheduler (per-step
//!   admission, paged KV from a block pool, submit-time backpressure);
//!   --wave forces the legacy batch-synchronous wave loop instead.
//!   --kv-scheme q8_0 stores KV rows as quantized codec blocks (~3.8×
//!   smaller); eval/selfcheck accept it too.
//! dsq memory --model M --scheme S [--ctx N] [--seqs N]
//! dsq recommend --model M               §4.4 device recommendations
//! dsq sweep-error --input CKPT.dsq      bpw ↔ reconstruction error (E10)
//! dsq selfcheck [--threads N]           parallel codec byte-identity check
//! dsq testvec --out DIR                 cross-language codec vectors
//! dsq inspect FILE.dsq
//! dsq schemes                           list built-in schemes
//! ```

use anyhow::{anyhow, bail, Context, Result};
use dsq::cli::Args;
use dsq::container::{
    quantize_container, quantize_container_with, synthetic_f32_container, Container,
};
use dsq::coordinator::{sampler::SamplingParams, scheduler, Coordinator, Request};
use dsq::eval::{self, report, suites};
use dsq::memory::{self, devices};
use dsq::model::ModelConfig;
use dsq::quant::{self, KvScheme, QuantFormat};
use dsq::runtime::Engine;
use dsq::scheme::builtin;
use dsq::util::json;
use dsq::util::rng::Pcg;
use std::path::{Path, PathBuf};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "help" || argv[0] == "--help" {
        print!("{}", HELP);
        return;
    }
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
dsq — DeepSeek quantization analysis (paper reproduction)

Commands:
  table <1-8>        regenerate a paper table (2-5 need artifacts)
  quantize IN.dsq --scheme S --output OUT.dsq [--threads N]
  import IN.gguf --output OUT.dsq [--threads N]   convert a llama.cpp checkpoint
  export IN.dsq --output OUT.gguf                 convert back to GGUF v3
  eval --hlo DIR --ckpt FILE [--out results.json] [--full-size] [--threads N] [--native]
                     (--native --ckpt accepts .dsq or .gguf, sniffed by magic)
  eval --native [--model M] [--scheme S]    (synthetic container — works for tiny-dense too)
  serve --hlo DIR --ckpt FILE [--requests N] [--threads N] [--native]
  serve --native [--model M] [--scheme S] [--requests N]   (synthetic container)
        [--kv-blocks N]    KV block pool size (0 = dense-equivalent capacity)
        [--block-tokens N] tokens per paged-KV block (0 = default 4)
        [--max-pending N]  queue depth before submit backpressures (default 2×batch)
        [--wave]           legacy batch-synchronous waves instead of
                           continuous batching (always used for PJRT)
        [--shards N]       partition the native forward pass across N shard
                           workers (expert-parallel MoE + row-parallel matmuls;
                           logits bit-identical to unsharded; also for eval)
        [--kv-scheme S]    KV-cache storage scheme: f32 (default) or q8_0
                           (rows quantized to codec blocks on append, read
                           through the fused vec_dot kernels; ~3.8× less KV
                           memory; also for eval and selfcheck)
  longgen [--model M] [--schemes a,b] [--kv-schemes f32,q8_0]
          [--ctx-lens 16,32,48] [--prompts N] [--out FILE.json]
                     long-generation sweep: greedy-decode synthetic prompts out
                     to each context length and report token agreement + an NLL
                     perplexity proxy vs the f32-KV baseline, per weight scheme
                     × KV scheme × context length
  memory --model M --scheme S [--ctx N] [--seqs N]
  recommend [--model M]
  sweep-error --input CKPT.dsq
  selfcheck [--threads N]
  testvec --out DIR
  fidelity --tag r1 [--schemes a,b,c]
  inspect FILE.dsq
  schemes
";

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "table" => cmd_table(args),
        "quantize" => cmd_quantize(args),
        "import" => cmd_import(args),
        "export" => cmd_export(args),
        "eval" => cmd_eval(args),
        "longgen" => cmd_longgen(args),
        "serve" => cmd_serve(args),
        "memory" => cmd_memory(args),
        "recommend" => cmd_recommend(args),
        "sweep-error" => cmd_sweep_error(args),
        "selfcheck" => cmd_selfcheck(args),
        "testvec" => cmd_testvec(args),
        "fidelity" => cmd_fidelity(args),
        "inspect" => cmd_inspect(args),
        "schemes" => cmd_schemes(),
        other => bail!("unknown command {other:?}; try `dsq help`"),
    }
}

fn cmd_schemes() -> Result<()> {
    let cfg = ModelConfig::by_name("deepseek-r1-671b")?;
    println!("{:<12} {:>9} {:>9}  source", "scheme", "avg bits", "size");
    for s in builtin::all() {
        println!(
            "{:<12} {:>9.3} {:>9}  {}",
            s.name,
            s.avg_bits(&cfg),
            dsq::util::fmt_gib(s.model_bytes(&cfg)),
            s.source
        );
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let which: u32 = args.positional_at(0)?.parse().context("table number")?;
    match which {
        1 => println!("{}", dsq::tables::table1(args.switch("paper"))?),
        6 => {
            let results = load_cached_results(args)?;
            println!("{}", dsq::tables::table6(&results)?);
        }
        7 => println!("{}", dsq::tables::table7()?),
        8 => println!("{}", dsq::tables::table8(args.switch("full-size"))),
        2..=5 => cmd_accuracy_table(args, which)?,
        other => bail!("unknown table {other}"),
    }
    Ok(())
}

/// Scheme columns per accuracy table (first = reference).
fn table_columns(which: u32) -> (&'static str, &'static str, Vec<&'static str>) {
    match which {
        2 => ("r1", "Table 2: DeepSeek-R1 proxy (tiny-moe)",
              vec!["f32", "q4_k_m", "q3_k_m", "ud_q2_k_xl", "dq3_k_m"]),
        3 => ("v3", "Table 3: DeepSeek-V3 proxy (tiny-moe)",
              vec!["f32", "q4_k_m", "q3_k_m", "q2_k_l", "dq3_k_m"]),
        4 => ("v3_0324", "Table 4: DeepSeek-V3-0324 proxy (tiny-moe)",
              vec!["f32", "q4_k_m", "q3_k_m", "q2_k_l", "dq3_k_m", "q4_k", "q3_k"]),
        5 => ("distill", "Table 5: R1-distill proxy (tiny-dense)",
              vec!["f32", "q8_0", "q4_k_m", "q3_k_m"]),
        _ => unreachable!(),
    }
}

fn results_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.flag_or("results", "artifacts/results"))
}

fn cmd_accuracy_table(args: &Args, which: u32) -> Result<()> {
    let (ckpt_tag, title, schemes) = table_columns(which);
    let hlo = PathBuf::from(args.flag_or("hlo", "artifacts/hlo"));
    let ckpt_dir = PathBuf::from(args.flag_or("ckpt-dir", "artifacts/ckpt"));
    let rdir = results_dir(args);
    std::fs::create_dir_all(&rdir)?;
    let protocol = protocol_from_args(args);
    let model = if which == 5 { "tiny-dense" } else { "tiny-moe" };

    let mut columns = Vec::new();
    for scheme in &schemes {
        let model_tag = format!("{model}-{ckpt_tag}");
        let cache = rdir.join(format!("{model_tag}_{scheme}.json"));
        let result = if cache.exists() {
            eval::EvalResult::from_json(&json::parse_file(&cache)?)?
        } else {
            let ckpt = checkpoint_for(&ckpt_dir, ckpt_tag, scheme)?;
            let engine = Engine::load(&hlo, &ckpt)?;
            let mut coord = Coordinator::new(engine);
            let mut r = eval::run_all(&mut coord, &protocol)?;
            r.model = model_tag.clone();
            std::fs::write(&cache, json::to_string_pretty(&r.to_json()))?;
            eprintln!("[eval] cached → {}", cache.display());
            r
        };
        columns.push(result);
    }
    println!("{}", report::render(title, &columns));
    Ok(())
}

/// Resolve (and lazily create) the quantized checkpoint for a scheme.
fn checkpoint_for(ckpt_dir: &Path, tag: &str, scheme_name: &str) -> Result<PathBuf> {
    let f32_path = ckpt_dir.join(format!("{tag}.f32.dsq"));
    if scheme_name == "f32" {
        if !f32_path.exists() {
            bail!(
                "{} missing — run `make artifacts` (python training) first",
                f32_path.display()
            );
        }
        return Ok(f32_path);
    }
    let qpath = ckpt_dir.join(format!("{tag}.{scheme_name}.dsq"));
    if !qpath.exists() {
        let src = Container::open(&f32_path)?;
        let scheme = builtin::scheme(scheme_name)?;
        eprintln!("[quantize] {} → {}", f32_path.display(), qpath.display());
        quantize_container(&src, &scheme, None)?.write(&qpath)?;
    }
    Ok(qpath)
}

fn load_cached_results(args: &Args) -> Result<Vec<eval::EvalResult>> {
    let rdir = results_dir(args);
    let mut out = Vec::new();
    if let Ok(entries) = std::fs::read_dir(&rdir) {
        for e in entries.flatten() {
            if e.path().extension().is_some_and(|x| x == "json") {
                if let Ok(v) = json::parse_file(&e.path()) {
                    if let Ok(r) = eval::EvalResult::from_json(&v) {
                        out.push(r);
                    }
                }
            }
        }
    }
    Ok(out)
}

fn protocol_from_args(args: &Args) -> eval::Protocol {
    let mut p = if args.switch("full-size") {
        eval::Protocol::paper()
    } else {
        eval::Protocol::default()
    };
    if let Some(d) = args.flag("sample-divisor") {
        p.sample_divisor = d.parse().unwrap_or(p.sample_divisor).max(1);
    }
    p
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.positional_at(0).or_else(|_| args.require("input"))?);
    let scheme = builtin::scheme(args.require("scheme")?)?;
    let output = PathBuf::from(args.require("output")?);
    let threads = args.threads_flag(quant::parallel::max_threads())?;
    let src = Container::open(&input)?;
    // imatrix container: a .dsq file whose tensors hold per-element
    // importance (f32), same names/widths as the model — validated
    // against `src` before any quantization work starts.
    let imatrix = match args.flag("imatrix") {
        Some(p) => Some(dsq::container::load_imatrix(Path::new(p), &src)?),
        None => None,
    };
    let t0 = std::time::Instant::now();
    let w = quantize_container_with(&src, &scheme, imatrix.as_ref(), threads)?;
    w.write(&output)?;
    let elapsed = t0.elapsed().as_secs_f64();
    let out = Container::open(&output)?;
    println!(
        "quantized {} ({} tensors) with {} on {threads} threads in {elapsed:.2}s \
         ({:.1} MiB/s in): {} → {} bytes ({:.2}×)",
        input.display(),
        out.tensors.len(),
        scheme.name,
        src.data_bytes() as f64 / (1 << 20) as f64 / elapsed.max(1e-9),
        src.data_bytes(),
        out.data_bytes(),
        src.data_bytes() as f64 / out.data_bytes() as f64
    );
    Ok(())
}

fn cmd_import(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.positional_at(0).or_else(|_| args.require("input"))?);
    let output = PathBuf::from(args.require("output")?);
    let threads = args.threads_flag(quant::parallel::max_threads())?;
    let t0 = std::time::Instant::now();
    let g = dsq::container::gguf::Gguf::open(&input)?;
    let w = dsq::container::gguf::import_gguf(&g, threads)?;
    w.write(&output)?;
    let elapsed = t0.elapsed().as_secs_f64();
    let out = Container::open(&output)?;
    println!(
        "imported {} → {} ({} tensors, model {}, scheme {}) on {threads} threads \
         in {elapsed:.2}s ({:.1} MiB/s)",
        input.display(),
        output.display(),
        out.tensors.len(),
        out.model.name,
        out.scheme_name,
        out.data_bytes() as f64 / (1 << 20) as f64 / elapsed.max(1e-9),
    );
    Ok(())
}

fn cmd_export(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.positional_at(0).or_else(|_| args.require("input"))?);
    let output = PathBuf::from(args.require("output")?);
    let t0 = std::time::Instant::now();
    let c = Container::open(&input)?;
    dsq::container::gguf::export(&c, &output)?;
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "exported {} → {} ({} tensors, model {}, scheme {}) in {elapsed:.2}s",
        input.display(),
        output.display(),
        c.tensors.len(),
        c.model.name,
        c.scheme_name,
    );
    Ok(())
}

/// Resolve the serving engine for `eval`/`serve`: `--ckpt FILE` serves
/// a checkpoint from disk (native or PJRT per `--native`); `--native`
/// **without** `--ckpt` synthesizes a deterministic quantized container
/// in memory from `--model M` (default tiny-moe) and `--scheme S`
/// (default dq3_k_m), so both model kinds — tiny-moe and the Table-5
/// tiny-dense proxy — can be served end to end with zero artifacts:
/// `dsq eval --native --model tiny-dense`. `--shards N` partitions the
/// native forward pass across N shard workers (`runtime::sharded`) —
/// logits stay bit-identical to the unsharded engine at every count.
/// `--kv-scheme S` (`f32` default, `q8_0`) selects the KV-cache
/// storage scheme on the native backend: rows are encoded into codec
/// blocks on append and attention reads them through the fused
/// `vec_dot` kernels, so logits stay bit-identical across threads,
/// arms, shards, and dense/paged backings (but differ numerically from
/// f32-KV by the bounded quantization error).
fn load_engine_from_args(args: &Args, hlo: &Path, threads: usize) -> Result<Engine> {
    let shards: usize = args.flag_parse("shards", 0usize)?;
    if shards > 0 && !args.switch("native") {
        bail!("--shards requires the native backend (pass --native)");
    }
    let kv_scheme = KvScheme::parse(&args.flag_or("kv-scheme", "f32"))?;
    if kv_scheme != KvScheme::F32 && !args.switch("native") {
        bail!("--kv-scheme {kv_scheme} requires the native backend (pass --native)");
    }
    let mut engine = load_engine_backend(args, hlo, threads, shards)?;
    if kv_scheme != KvScheme::F32 {
        engine
            .native_mut()
            .expect("--native checked above")
            .set_kv_scheme(kv_scheme)?;
        eprintln!(
            "[native] KV cache scheme {kv_scheme}: {} B/token (vs {} B/token at f32)",
            engine.native().expect("native").kv_bytes_per_token(),
            {
                let fwd = engine.native().expect("native").forward();
                let cfg = fwd.config();
                memory::kv_bytes_per_token(cfg, KvScheme::F32, true)
            }
        );
    }
    Ok(engine)
}

fn load_engine_backend(args: &Args, hlo: &Path, threads: usize, shards: usize) -> Result<Engine> {
    match (args.flag("ckpt"), args.switch("native")) {
        // The native path sniffs the checkpoint magic, so `--ckpt` takes
        // either a .dsq container or a llama.cpp .gguf file directly.
        (Some(ckpt), true) => Engine::native_from_container_sharded(
            dsq::container::gguf::open_checkpoint(Path::new(ckpt), threads)?,
            threads,
            shards,
        ),
        (Some(ckpt), false) => Engine::load_with(hlo, Path::new(ckpt), threads),
        (None, true) => {
            let model = ModelConfig::by_name(&args.flag_or("model", "tiny-moe"))?;
            let scheme_name = args.flag_or("scheme", "dq3_k_m");
            let src = synthetic_f32_container(&model, 0x601D)?;
            let ckpt = if scheme_name == "f32" {
                src
            } else {
                let scheme = builtin::scheme(&scheme_name)?;
                Container::from_bytes(
                    quantize_container_with(&src, &scheme, None, threads)?.to_bytes(),
                )?
            };
            eprintln!(
                "[native] no --ckpt given: serving a synthetic {} container quantized \
                 with {scheme_name}",
                model.name
            );
            Engine::native_from_container_sharded(ckpt, threads, shards)
        }
        (None, false) => bail!(
            "missing required flag --ckpt (or pass --native with --model M to serve a \
             synthetic container)"
        ),
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let hlo = PathBuf::from(args.flag_or("hlo", "artifacts/hlo"));
    let threads = args.threads_flag(quant::parallel::max_threads())?;
    let engine = load_engine_from_args(args, &hlo, threads)?;
    let mut coord = Coordinator::new(engine);
    let protocol = protocol_from_args(args);
    let result = match args.flag("suite") {
        Some(name) => {
            let suite = suites::by_name(name).ok_or_else(|| anyhow!("unknown suite {name}"))?;
            let r = eval::run_suite(&mut coord, suite, &protocol, None)?;
            eval::EvalResult {
                model: coord.engine().model_name.clone(),
                scheme: coord.engine().scheme_name.clone(),
                suites: vec![r],
            }
        }
        None => eval::run_all(&mut coord, &protocol)?,
    };
    println!("{}", report::render("Evaluation", &[result.clone()]));
    println!("--- serving metrics ---\n{}", coord.metrics.report());
    if let Some(out) = args.flag("out") {
        std::fs::write(out, json::to_string_pretty(&result.to_json()))?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

/// `dsq longgen` — the long-generation KV-scheme sweep
/// (`eval::longgen`): accuracy-proxy (token agreement vs the f32-KV
/// baseline) and NLL perplexity proxy × weight scheme × KV scheme ×
/// context length, on a synthetic container. Deterministic (greedy
/// decode), so the report is byte-reproducible and CI-diffable.
fn cmd_longgen(args: &Args) -> Result<()> {
    let parse_list = |flag: &str, default: &str| -> Vec<String> {
        args.flag_or(flag, default)
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    };
    let kv_schemes = parse_list("kv-schemes", "f32,q8_0")
        .iter()
        .map(|s| KvScheme::parse(s))
        .collect::<Result<Vec<_>>>()?;
    let ctx_lens = parse_list("ctx-lens", "16,32,48")
        .iter()
        .map(|s| s.parse::<usize>().map_err(|e| anyhow!("invalid --ctx-lens entry {s:?}: {e}")))
        .collect::<Result<Vec<_>>>()?;
    let cfg = eval::longgen::LongGenConfig {
        model: args.flag_or("model", "tiny-moe"),
        weight_schemes: parse_list("schemes", "q4_k_m,dq3_k_m"),
        kv_schemes,
        ctx_lens,
        n_prompts: args.flag_parse("prompts", 3usize)?,
        threads: args.threads_flag(quant::parallel::max_threads())?,
    };
    let cells = eval::longgen::run_sweep(&cfg)?;
    print!("{}", eval::longgen::render(&cfg.model, &cells));
    if let Some(out) = args.flag("out") {
        std::fs::write(out, json::to_string_pretty(&eval::longgen::to_json(&cfg.model, &cells)))?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let hlo = PathBuf::from(args.flag_or("hlo", "artifacts/hlo"));
    let n: usize = args.flag_parse("requests", 64usize)?;
    let threads = args.threads_flag(quant::parallel::max_threads())?;
    let engine = load_engine_from_args(args, &hlo, threads)?;
    // Mixed request stream drawn from the benchmark distribution.
    let make_req = |id: u64| {
        let suite = &suites::SUITES[(id % suites::SUITES.len() as u64) as usize];
        let q = eval::tasks::eval_question(suite, id);
        Request {
            id,
            prompt: q.prompt,
            params: SamplingParams::paper(),
            seed: id.wrapping_mul(7919),
        }
    };
    // PJRT has no paged-KV path; `--wave` forces the legacy scheduler
    // on the native backend too (the differential baseline).
    if args.switch("wave") || engine.native().is_none() {
        let mut coord = Coordinator::new(engine);
        for id in 0..n as u64 {
            coord.submit(make_req(id))?;
        }
        let t0 = std::time::Instant::now();
        let mut responses = Vec::new();
        while coord.pending() > 0 {
            responses.extend(coord.run_wave()?);
        }
        let wall = t0.elapsed().as_secs_f64();
        if let Some(sh) = coord.engine().native().and_then(|m| m.forward().shards()) {
            coord.metrics.shards = sh.n_shards() as u64;
            coord.metrics.exchanges = sh.exchanges();
            coord.metrics.exchange_wait_ns = sh.exchange_wait_ns();
        }
        println!("{}", coord.metrics.report());
        println!(
            "served {} requests in {wall:.2}s wall ({:.2} req/s end-to-end)",
            responses.len(),
            responses.len() as f64 / wall
        );
        return Ok(());
    }
    let native = engine.native().expect("checked above");
    let cfg = scheduler::ServeConfig {
        kv_blocks: args.flag_parse("kv-blocks", 0usize)?,
        block_tokens: args.flag_parse("block-tokens", 0usize)?,
        max_pending: args.flag_parse("max-pending", 2 * native.batch())?,
    };
    let mut sched = scheduler::ContinuousScheduler::new(native, cfg)?;
    let t0 = std::time::Instant::now();
    let mut responses = Vec::new();
    for id in 0..n as u64 {
        let mut req = make_req(id);
        // Submit-time backpressure: when the queue is at --max-pending
        // the scheduler hands the request back; drain a step (admitting
        // and decoding) and retry instead of growing the queue without
        // bound.
        loop {
            match sched.submit(req)? {
                scheduler::SubmitOutcome::Queued => break,
                scheduler::SubmitOutcome::Backpressure(r) => {
                    req = r;
                    sched.step()?;
                    responses.extend(sched.take_responses());
                }
            }
        }
    }
    responses.extend(sched.run_to_completion()?);
    let wall = t0.elapsed().as_secs_f64();
    let mut metrics = sched.into_metrics();
    if let Some(sh) = native.forward().shards() {
        metrics.shards = sh.n_shards() as u64;
        metrics.exchanges = sh.exchanges();
        metrics.exchange_wait_ns = sh.exchange_wait_ns();
    }
    println!("{}", metrics.report());
    let (p50, p99) = metrics.latency_percentiles();
    let goodput = metrics.generated_tokens as f64 / wall;
    println!(
        "served {} requests in {wall:.2}s wall ({:.2} req/s end-to-end)\n\
         continuous batching: latency p50 {p50:.1} ms, p99 {p99:.1} ms, \
         goodput {goodput:.1} tok/s",
        responses.len(),
        responses.len() as f64 / wall
    );
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let cfg = ModelConfig::by_name(&args.flag_or("model", "deepseek-r1-671b"))?;
    let scheme = builtin::scheme(&args.flag_or("scheme", "dq3_k_m"))?;
    let ctx: usize = args.flag_parse("ctx", 32_768usize)?;
    let seqs: usize = args.flag_parse("seqs", memory::DEFAULT_N_SEQ)?;
    let est = memory::estimate(&cfg, &scheme, ctx, seqs);
    println!(
        "model {} × scheme {} @ ctx {} × {} seqs\n\
         weights: {} ({:.2} bits/weight)\n\
         kv cache: {}\n\
         total: {:.0}GB | per GPU (×8): {:.0}GB",
        cfg.name,
        scheme.name,
        ctx,
        seqs,
        dsq::util::fmt_gib1(est.model_bytes),
        est.avg_bits,
        dsq::util::fmt_gib1(est.kv_bytes),
        est.total_gib(),
        est.per_gpu_gib()
    );
    for d in devices::DEVICES {
        let fits = devices::fits(&est, d);
        println!(
            "  8×{:<12} ({} GiB): {}",
            d.name,
            d.vram_gib,
            if fits { "fits" } else { "does NOT fit" }
        );
    }
    Ok(())
}

fn cmd_recommend(args: &Args) -> Result<()> {
    let cfg = ModelConfig::by_name(&args.flag_or("model", "deepseek-r1-671b"))?;
    println!("§4.4 deployment recommendations for {} @ 32K ctx:\n", cfg.name);
    for d in devices::DEVICES {
        // Highest-precision scheme that fits this device.
        let mut best: Option<(String, f64)> = None;
        for s in builtin::all() {
            if s.name == "f32" {
                continue;
            }
            let est = memory::estimate_default(&cfg, &s);
            if devices::fits(&est, d) {
                match &best {
                    Some((_, bits)) if *bits >= est.avg_bits => {}
                    _ => best = Some((s.name.clone(), est.avg_bits)),
                }
            }
        }
        match best {
            Some((name, bits)) => println!(
                "  8×{:<12}: {} ({bits:.2} bits/weight)",
                d.name,
                report::display_scheme(&name)
            ),
            None => println!("  8×{:<12}: no quantization fits", d.name),
        }
    }
    Ok(())
}

fn cmd_sweep_error(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.require("input")?);
    let src = Container::open(&input)?;
    println!(
        "# bpw ↔ relative RMSE on real checkpoint tensors ({})",
        src.model.name
    );
    println!("{:<8} {:>7} {:>12} {:>12}", "format", "bpw", "rel RMSE", "max |err|");
    // Scratch reused across every (format, tensor) pair — the sweep
    // allocates nothing inside the loop.
    let mut vals: Vec<f32> = Vec::new();
    let mut packed: Vec<u8> = Vec::new();
    let mut rt: Vec<f32> = Vec::new();
    for fmt in [
        QuantFormat::Q8_0,
        QuantFormat::Q6K,
        QuantFormat::Q5K,
        QuantFormat::Q4K,
        QuantFormat::Q3K,
        QuantFormat::Q2K,
    ] {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        let mut max_err = 0.0f64;
        for t in &src.tensors {
            if !t.class.quantizable() || t.n_elems() % fmt.block_weights() != 0 {
                continue;
            }
            src.dequantize_into(t, &mut vals)?;
            rt.resize(vals.len(), 0.0);
            quant::roundtrip_into(fmt, &vals, None, &mut packed, &mut rt)?;
            for (a, b) in vals.iter().zip(&rt) {
                let d = (*a - *b) as f64;
                num += d * d;
                den += (*a as f64) * (*a as f64);
                max_err = max_err.max(d.abs());
            }
        }
        println!(
            "{:<8} {:>7.4} {:>12.6} {:>12.6}",
            fmt.name(),
            fmt.bits_per_weight(),
            (num / den.max(1e-30)).sqrt(),
            max_err
        );
    }
    Ok(())
}

/// `dsq selfcheck` — prove the parallel codec paths on *this* host.
///
/// For every format: quantize the same data serially and with N worker
/// threads and require byte-identical packings (then the same for
/// decode). For every builtin scheme: quantize a deterministic tiny-moe
/// checkpoint through the serial and the tensor-parallel container
/// pipelines and require byte-identical containers. Then the
/// serving weight loader's decode direction: preparing f32 weight
/// payloads from a quantized checkpoint must be byte-identical at every
/// thread count. Then the vec_dot identity: for every format the
/// fused `vec_dot(q, x)` must equal the same-reduction-order lane dot
/// over `decode_blocks(q)` bit-for-bit, on *every* dispatch arm
/// available on this host (scalar reference, lane kernels, AVX2/NEON
/// intrinsics). Then the GEMM identity: `vec_dot_mat` over a T-column
/// panel must equal T independent `vec_dot` calls bit-for-bit, per
/// arm and at 1 vs N row-parallel threads. Finally the native forward
/// pass: the full MLA+MoE and dense-GQA steps over encoded DQ3_K_M /
/// Q4_K_M containers must yield bit-identical logits across matvec
/// thread counts, across every pinned dispatch arm, and across
/// panel-GEMM vs per-token prefill. Exits non-zero on any mismatch.
fn cmd_selfcheck(args: &Args) -> Result<()> {
    let threads = args.threads_flag(quant::parallel::max_threads())?;
    println!("# codec selfcheck: serial vs {threads} threads\n");
    let mut failures = 0usize;

    for fmt in QuantFormat::ALL {
        for nblocks in [1usize, 3, 17] {
            let n = fmt.block_weights() * nblocks;
            let mut rng = Pcg::new(0xC0DEC ^ ((n as u64) << 8) ^ fmt.block_bytes() as u64);
            let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let imp: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
            let nbytes = fmt.row_bytes(n)?;
            let mut serial = vec![0u8; nbytes];
            let mut par = vec![0u8; nbytes];
            quant::quantize_into_with(fmt, &data, Some(&imp), &mut serial, 1)?;
            quant::quantize_into_with(fmt, &data, Some(&imp), &mut par, threads)?;
            let mut dec_serial = vec![0f32; n];
            let mut dec_par = vec![0f32; n];
            quant::dequantize_into_with(fmt, &serial, &mut dec_serial, 1)?;
            quant::dequantize_into_with(fmt, &par, &mut dec_par, threads)?;
            let ok = serial == par && dec_serial == dec_par;
            if !ok {
                failures += 1;
            }
            println!(
                "  {:<6} {:>4} blocks ({:>8} weights): {}",
                fmt.name(),
                nblocks,
                n,
                if ok { "identical" } else { "MISMATCH" }
            );
        }
    }

    // Container level: serial vs tensor-parallel pipeline per scheme.
    let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 0x5E1F)?;
    println!();
    for scheme in builtin::all() {
        let serial = quantize_container_with(&src, &scheme, None, 1)?.to_bytes();
        let par = quantize_container_with(&src, &scheme, None, threads)?.to_bytes();
        let ok = serial == par;
        if !ok {
            failures += 1;
        }
        println!(
            "  container/{:<12} ({} tensors, {} bytes): {}",
            scheme.name,
            src.tensors.len(),
            serial.len(),
            if ok { "identical" } else { "MISMATCH" }
        );
    }

    // Decode direction: the serving weight loader over a quantized
    // checkpoint (tensor-level fan-out + block-level splits inside a
    // tensor) must reproduce the serial decode byte-for-byte.
    println!();
    for scheme_name in ["dq3_k_m", "q4_k_m"] {
        let scheme = builtin::scheme(scheme_name)?;
        let q = Container::from_bytes(
            quantize_container_with(&src, &scheme, None, 1)?.to_bytes(),
        )?;
        let manifest = dsq::runtime::loader::f32_weight_manifest(&q);
        let serial = dsq::runtime::loader::prepare_weights(&manifest, &q, 1)?;
        let par = dsq::runtime::loader::prepare_weights(&manifest, &q, threads)?;
        let ok = serial.len() == par.len()
            && serial
                .iter()
                .zip(&par)
                .all(|(a, b)| a.as_slice() == b.as_slice());
        if !ok {
            failures += 1;
        }
        println!(
            "  loader-decode/{:<12} ({} tensors → f32 literals): {}",
            scheme_name,
            q.tensors.len(),
            if ok { "identical" } else { "MISMATCH" }
        );
    }

    // vec_dot identity: the fused kernels must reproduce the canonical
    // decode-then-lane-dot reduction exactly, on every available
    // dispatch arm (scalar reference, lane kernels, AVX2/NEON
    // intrinsics) and through the row-parallel matvec entry point.
    println!();
    let arms: Vec<quant::kernels::DispatchArm> = quant::kernels::DispatchArm::ALL
        .into_iter()
        .filter(|a| a.available())
        .collect();
    for fmt in QuantFormat::ALL {
        let rows = 4usize;
        let n = fmt.block_weights().max(64);
        let mut rng = Pcg::new(0xD07 ^ ((n as u64) << 4) ^ fmt.block_bytes() as u64);
        let data: Vec<f32> = (0..rows * n).map(|_| rng.next_normal()).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let packed = quant::quantize(fmt, &data, None)?;
        let rb = fmt.row_bytes(n)?;
        let mut ok = true;
        let mut decoded = vec![0f32; n];
        for &arm in &arms {
            for row in packed.chunks_exact(rb) {
                quant::kernels::decode_blocks_arm(fmt, row, &mut decoded, arm);
                let want = quant::kernels::dot_lanes(&decoded, &x);
                let got = quant::kernels::vec_dot_arm(fmt, row, &x, arm);
                ok &= got.to_bits() == want.to_bits();
            }
        }
        // Row-parallel matvec at 1 vs N threads, through the public
        // dispatch-selected entry point.
        let mut serial = vec![0f32; rows];
        let mut par = vec![0f32; rows];
        quant::vec_dot_rows_with(fmt, &packed, &x, &mut serial, 1)?;
        quant::vec_dot_rows_with(fmt, &packed, &x, &mut par, threads)?;
        ok &= serial
            .iter()
            .zip(&par)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        if !ok {
            failures += 1;
        }
        println!(
            "  vec_dot/{:<6} ({rows} rows × {n} weights, {} arms): {}",
            fmt.name(),
            arms.len(),
            if ok { "identical" } else { "MISMATCH" }
        );
    }

    // GEMM identity: the decode-once vec_dot_mat panel kernels must
    // reproduce T independent single-column dots bit-for-bit — per
    // format, on every available arm, and through the row-parallel
    // GEMM entry point at 1 vs N threads.
    println!();
    for fmt in QuantFormat::ALL {
        let (rows, t) = (4usize, 5usize);
        let n = fmt.block_weights().max(64);
        let mut rng = Pcg::new(0x6E33 ^ ((n as u64) << 4) ^ fmt.block_bytes() as u64);
        let data: Vec<f32> = (0..rows * n).map(|_| rng.next_normal()).collect();
        let xs: Vec<f32> = (0..t * n).map(|_| rng.next_normal()).collect();
        let packed = quant::quantize(fmt, &data, None)?;
        let rb = fmt.row_bytes(n)?;
        let mut ok = true;
        let mut out = vec![0f32; t];
        for &arm in &arms {
            for row in packed.chunks_exact(rb) {
                quant::kernels::vec_dot_mat_arm(fmt, row, &xs, n, &mut out, arm);
                for (c, &got) in out.iter().enumerate() {
                    let want = quant::kernels::vec_dot_arm(fmt, row, &xs[c * n..(c + 1) * n], arm);
                    ok &= got.to_bits() == want.to_bits();
                }
            }
        }
        // Row-parallel GEMM at 1 vs N threads vs the per-column matvec.
        let mut serial = vec![0f32; rows * t];
        let mut par = vec![0f32; rows * t];
        quant::vec_dot_rows_mat_with(fmt, &packed, &xs, n, t, &mut serial, 1)?;
        quant::vec_dot_rows_mat_with(fmt, &packed, &xs, n, t, &mut par, threads)?;
        ok &= serial.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits());
        let mut col = vec![0f32; rows];
        for c in 0..t {
            quant::vec_dot_rows_with(fmt, &packed, &xs[c * n..(c + 1) * n], &mut col, 1)?;
            for (r, &want) in col.iter().enumerate() {
                ok &= serial[r * t + c].to_bits() == want.to_bits();
            }
        }
        if !ok {
            failures += 1;
        }
        println!(
            "  vec_dot_mat/{:<6} ({rows} rows × {t} cols × {n} weights, {} arms): {}",
            fmt.name(),
            arms.len(),
            if ok { "identical" } else { "MISMATCH" }
        );
    }

    // Forward-pass identity: the full native forward — the MLA+MoE
    // step on tiny-moe AND the dense-GQA step on tiny-dense — must
    // produce bit-identical logits across matvec thread counts, across
    // every available pinned dispatch arm, and across panel-GEMM vs
    // per-token prefill.
    println!();
    {
        use dsq::runtime::forward::{ForwardPass, MatvecMode};
        let toks = [1i32, 17, 300, 42, 511];
        let dense_src = synthetic_f32_container(&ModelConfig::tiny_dense(), 0x5E1F)?;
        for (model_src, model_name) in [(&src, "tiny-moe"), (&dense_src, "tiny-dense")] {
            for scheme_name in ["dq3_k_m", "q4_k_m"] {
                let scheme = builtin::scheme(scheme_name)?;
                let qbytes = quantize_container_with(model_src, &scheme, None, threads)?
                    .to_bytes();
                let run = |mode: MatvecMode| -> Result<Vec<u32>> {
                    let q = Container::from_bytes(qbytes.clone())?;
                    let mut fwd =
                        ForwardPass::new(q, 1, dsq::runtime::native::NATIVE_MAX_CTX)?;
                    fwd.set_mode(mode);
                    let mut cache = fwd.new_cache();
                    let mut scratch = fwd.new_scratch();
                    let mut logits = vec![0f32; fwd.vocab()];
                    let mut bits = Vec::new();
                    for &t in &toks {
                        fwd.forward_token(t, &mut cache, &mut scratch, Some(&mut logits))?;
                        bits.extend(logits.iter().map(|v| v.to_bits()));
                    }
                    Ok(bits)
                };
                let serial = run(MatvecMode::Threads(1))?;
                let par = run(MatvecMode::Threads(threads))?;
                let mut ok = serial == par;
                for &arm in &arms {
                    ok &= run(MatvecMode::Pinned(arm))? == serial;
                }
                // Panel prefill: the whole prompt in one GEMM pass must
                // leave the same last-step logits and KV planes as the
                // per-token loop above.
                {
                    let q = Container::from_bytes(qbytes.clone())?;
                    let fwd = ForwardPass::new(q, 1, dsq::runtime::native::NATIVE_MAX_CTX)?;
                    let mut cache = fwd.new_cache();
                    let mut scratch = fwd.new_scratch();
                    let mut logits = vec![0f32; fwd.vocab()];
                    fwd.forward_tokens(&toks, &mut cache, &mut scratch, Some(&mut logits))?;
                    let last = &serial[serial.len() - fwd.vocab()..];
                    ok &= logits.iter().map(|v| v.to_bits()).eq(last.iter().copied());
                }
                if !ok {
                    failures += 1;
                }
                println!(
                    "  forward/{model_name}/{:<8} ({} steps × {} logits, 1 vs {threads} \
                     threads + {} arms + panel prefill): {}",
                    scheme_name,
                    toks.len(),
                    serial.len() / toks.len(),
                    arms.len(),
                    if ok { "identical" } else { "MISMATCH" }
                );
            }
        }
    }

    // Sharded identity: partitioning the same forward pass across
    // shard workers (expert-parallel MoE + row-parallel matmuls, see
    // runtime::sharded) must leave the logits bit-identical to the
    // unsharded engine — per scheme, per model kind, at shards
    // {1, 2, 4}.
    println!();
    {
        use dsq::runtime::forward::ForwardPass;
        let toks = [1i32, 17, 300, 42, 511];
        let dense_src = synthetic_f32_container(&ModelConfig::tiny_dense(), 0x5E1F)?;
        for (model_src, model_name) in [(&src, "tiny-moe"), (&dense_src, "tiny-dense")] {
            for scheme_name in ["dq3_k_m", "q4_k_m"] {
                let scheme = builtin::scheme(scheme_name)?;
                let qbytes = quantize_container_with(model_src, &scheme, None, threads)?
                    .to_bytes();
                let run = |shards: usize| -> Result<Vec<u32>> {
                    let q = Container::from_bytes(qbytes.clone())?;
                    let mut fwd =
                        ForwardPass::new(q, threads, dsq::runtime::native::NATIVE_MAX_CTX)?;
                    fwd.set_sharding(shards)?;
                    let mut cache = fwd.new_cache();
                    let mut scratch = fwd.new_scratch();
                    let mut logits = vec![0f32; fwd.vocab()];
                    let mut bits = Vec::new();
                    for &t in &toks {
                        fwd.forward_token(t, &mut cache, &mut scratch, Some(&mut logits))?;
                        bits.extend(logits.iter().map(|v| v.to_bits()));
                    }
                    Ok(bits)
                };
                let unsharded = run(0)?;
                let mut ok = true;
                for n in [1usize, 2, 4] {
                    ok &= run(n)? == unsharded;
                }
                if !ok {
                    failures += 1;
                }
                println!(
                    "  sharded/{model_name}/{:<8} (shards 1, 2, 4 vs unsharded, {} steps \
                     × {} logits): {}",
                    scheme_name,
                    toks.len(),
                    unsharded.len() / toks.len(),
                    if ok { "identical" } else { "MISMATCH" }
                );
            }
        }
    }

    // Quantized-KV identity: with the KV cache stored as q8_0 codec
    // blocks (`--kv-scheme q8_0`) the same bit-identity matrix must
    // hold — quantize-on-write/fused-read may not depend on thread
    // count or dispatch arm. Logits legitimately differ from the f32-KV
    // runs above by bounded quantization error, but never between two
    // q8_0 runs.
    println!();
    {
        use dsq::runtime::forward::{ForwardPass, MatvecMode};
        let toks = [1i32, 17, 300, 42, 511];
        let dense_src = synthetic_f32_container(&ModelConfig::tiny_dense(), 0x5E1F)?;
        for (model_src, model_name) in [(&src, "tiny-moe"), (&dense_src, "tiny-dense")] {
            for scheme_name in ["dq3_k_m", "q4_k_m"] {
                let scheme = builtin::scheme(scheme_name)?;
                let qbytes = quantize_container_with(model_src, &scheme, None, threads)?
                    .to_bytes();
                let run = |mode: MatvecMode| -> Result<Vec<u32>> {
                    let q = Container::from_bytes(qbytes.clone())?;
                    let mut fwd =
                        ForwardPass::new(q, 1, dsq::runtime::native::NATIVE_MAX_CTX)?;
                    fwd.set_kv_scheme(KvScheme::Q8_0)?;
                    fwd.set_mode(mode);
                    let mut cache = fwd.new_cache();
                    let mut scratch = fwd.new_scratch();
                    let mut logits = vec![0f32; fwd.vocab()];
                    let mut bits = Vec::new();
                    for &t in &toks {
                        fwd.forward_token(t, &mut cache, &mut scratch, Some(&mut logits))?;
                        bits.extend(logits.iter().map(|v| v.to_bits()));
                    }
                    Ok(bits)
                };
                let serial = run(MatvecMode::Threads(1))?;
                let mut ok = run(MatvecMode::Threads(threads))? == serial;
                for &arm in &arms {
                    ok &= run(MatvecMode::Pinned(arm))? == serial;
                }
                if !ok {
                    failures += 1;
                }
                println!(
                    "  kv-q8_0/{model_name}/{:<8} ({} steps × {} logits, 1 vs {threads} \
                     threads + {} arms): {}",
                    scheme_name,
                    toks.len(),
                    serial.len() / toks.len(),
                    arms.len(),
                    if ok { "identical" } else { "MISMATCH" }
                );
            }
        }
    }

    if failures > 0 {
        bail!("selfcheck FAILED: {failures} mismatching case(s)");
    }
    println!(
        "\nselfcheck passed: parallel encode, loader decode, fused vec_dot, the \
         vec_dot_mat GEMM panels, the native forward pass (f32 and q8_0 KV \
         caches) and the sharded expert/tensor-parallel pass are bit-identical \
         to their serial/scalar/unsharded references on every available \
         dispatch arm"
    );
    Ok(())
}

fn cmd_testvec(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.flag_or("out", "artifacts/testvectors"));
    std::fs::create_dir_all(&out)?;
    let mut index = Vec::new();
    for fmt in [
        QuantFormat::Q8_0,
        QuantFormat::Q6K,
        QuantFormat::Q5K,
        QuantFormat::Q4K,
        QuantFormat::Q3K,
        QuantFormat::Q2K,
        QuantFormat::F16,
    ] {
        let n = fmt.block_weights().max(256) * 4;
        let mut rng = Pcg::new(0xFEED ^ fmt.block_bytes() as u64);
        let src: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.1).collect();
        let packed = quant::quantize(fmt, &src, None)?;
        let deq = quant::dequantize(fmt, &packed, n)?;
        let base = fmt.name();
        std::fs::write(
            out.join(format!("{base}.src.f32")),
            src.iter().flat_map(|v| v.to_le_bytes()).collect::<Vec<u8>>(),
        )?;
        std::fs::write(out.join(format!("{base}.packed.bin")), &packed)?;
        std::fs::write(
            out.join(format!("{base}.deq.f32")),
            deq.iter().flat_map(|v| v.to_le_bytes()).collect::<Vec<u8>>(),
        )?;
        index.push(json::obj(vec![
            ("format", json::str_(base)),
            ("n", json::num(n as f64)),
            ("packed_bytes", json::num(packed.len() as f64)),
        ]));
    }
    std::fs::write(
        out.join("index.json"),
        json::to_string_pretty(&json::Value::Arr(index)),
    )?;
    println!("wrote test vectors to {}", out.display());
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = PathBuf::from(args.positional_at(0)?);
    let c = Container::open(&path)?;
    println!(
        "{}: model={} scheme={} tensors={} data={:.2} MiB",
        path.display(),
        c.model.name,
        c.scheme_name,
        c.tensors.len(),
        c.data_bytes() as f64 / (1 << 20) as f64
    );
    println!("meta: {}", json::to_string(&c.meta));
    if args.switch("verbose") {
        for t in &c.tensors {
            println!(
                "  {:<36} {:<6} {:?} ({} bytes)",
                t.name,
                t.format.name(),
                t.shape,
                t.nbytes
            );
        }
    }
    Ok(())
}

/// `dsq fidelity` — logit-level quantization fidelity (experiment E11).
///
/// Runs identical prompts through the FP32 engine and each quantized
/// engine, reporting cosine similarity of last-token logits, top-1
/// agreement, and the log-prob gap on the reference's top token. This
/// measures quantization damage *independently of task mastery* — the
/// monotone bitwidth↔fidelity curve is the distilled form of the
/// paper's Tables 2–4.
fn cmd_fidelity(args: &Args) -> Result<()> {
    let hlo = PathBuf::from(args.flag_or("hlo", "artifacts/hlo"));
    let ckpt_dir = PathBuf::from(args.flag_or("ckpt-dir", "artifacts/ckpt"));
    let tag = args.flag_or("tag", "r1");
    let n_prompts: usize = args.flag_parse("prompts", 96usize)?;
    let schemes: Vec<String> = args
        .flag_or("schemes", "q8_0,q4_k_m,q3_k_m,dq3_k_m,ud_q2_k_xl,q2_k_l")
        .split(',')
        .map(|s| s.to_string())
        .collect();

    let reference = Engine::load(&hlo, &checkpoint_for(&ckpt_dir, &tag, "f32")?)?;
    let b = reference.batch();
    let t = reference.prompt_len();
    let v = reference.vocab();

    // A fixed prompt set across all benchmark suites.
    let mut prompts: Vec<Vec<i32>> = Vec::new();
    for i in 0..n_prompts as u64 {
        let suite = &suites::SUITES[(i % 9) as usize];
        prompts.push(eval::tasks::eval_question(suite, i).prompt);
    }
    let mut ref_logits: Vec<Vec<f32>> = Vec::new();
    for chunk in prompts.chunks(b) {
        let mut tokens = vec![0i32; b * t];
        let mut lengths = vec![1i32; b];
        for (i, p) in chunk.iter().enumerate() {
            tokens[i * t..i * t + p.len()].copy_from_slice(p);
            lengths[i] = p.len() as i32;
        }
        let out = reference.run_prefill(&tokens, &lengths)?;
        for i in 0..chunk.len() {
            ref_logits.push(out.logits[i * v..(i + 1) * v].to_vec());
        }
    }
    drop(reference);

    println!(
        "# logit fidelity vs FP32 ({} prompts, checkpoint {tag})\n",
        prompts.len()
    );
    println!(
        "{:<12} {:>8} {:>12} {:>12} {:>16}",
        "scheme", "bpw", "cosine", "top1-agree", "dlogprob(top1)"
    );
    for scheme_name in &schemes {
        let engine = Engine::load(&hlo, &checkpoint_for(&ckpt_dir, &tag, scheme_name)?)?;
        let mut cos_sum = 0.0;
        let mut agree = 0usize;
        let mut dlp_sum = 0.0;
        let mut idx = 0usize;
        for chunk in prompts.chunks(b) {
            let mut tokens = vec![0i32; b * t];
            let mut lengths = vec![1i32; b];
            for (i, p) in chunk.iter().enumerate() {
                tokens[i * t..i * t + p.len()].copy_from_slice(p);
                lengths[i] = p.len() as i32;
            }
            let out = engine.run_prefill(&tokens, &lengths)?;
            for i in 0..chunk.len() {
                let ql = &out.logits[i * v..(i + 1) * v];
                let rl = &ref_logits[idx];
                cos_sum += dsq::quant::error::cosine(rl, ql);
                let top_ref = dsq::coordinator::sampler::argmax(rl);
                let top_q = dsq::coordinator::sampler::argmax(ql);
                if top_ref == top_q {
                    agree += 1;
                }
                let lse = |l: &[f32]| {
                    let m = l.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    m + l.iter().map(|x| (x - m).exp()).sum::<f32>().ln()
                };
                let dlp = (ql[top_ref as usize] - lse(ql)) - (rl[top_ref as usize] - lse(rl));
                dlp_sum += dlp as f64;
                idx += 1;
            }
        }
        let n = prompts.len() as f64;
        let bpw = builtin::scheme(scheme_name)?
            .avg_bits(&ModelConfig::by_name("deepseek-r1-671b")?);
        println!(
            "{:<12} {:>8.2} {:>12.5} {:>11.1}% {:>16.4}",
            scheme_name,
            bpw,
            cos_sum / n,
            agree as f64 / n * 100.0,
            dlp_sum / n
        );
    }
    Ok(())
}
