//! Architecture configurations: the real 671B DeepSeek-V3/R1, the 32B
//! dense distill, and the tiny proxy models trained at build time.

use anyhow::{bail, Result};

/// Whether a model uses MLA+MoE (DeepSeek-V3 style) or dense GQA
/// (Qwen2.5 style, for the distill variant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// Multi-head Latent Attention + Mixture-of-Experts (DeepSeek-V3/R1).
    MlaMoe,
    /// Dense transformer with grouped-query attention (distill-Qwen).
    DenseGqa,
}

/// RoPE frequency base assumed when a config (or a container header
/// written before the base became configurable) does not declare one:
/// the classic `θ = 10000` of the original RoPE paper and DeepSeek-V3.
/// [`ModelConfig::to_json`] omits `rope_base` at this value so legacy
/// container bytes (and their golden checksums) stay byte-identical.
pub const DEFAULT_ROPE_BASE: f64 = 10000.0;

/// Full architecture description.
///
/// For [`ModelKind::DenseGqa`], the MLA/MoE fields are ignored
/// (`n_routed_experts == 0` etc.).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub kind: ModelKind,
    pub vocab_size: usize,
    pub hidden_size: usize,
    pub n_layers: usize,
    /// Number of leading dense (non-MoE) layers (`first_k_dense_replace`).
    pub first_dense: usize,
    pub n_heads: usize,
    /// KV heads for GQA (dense models); ignored for MLA.
    pub n_kv_heads: usize,
    /// Per-head dim for dense models.
    pub head_dim: usize,
    /// RoPE frequency base `θ` (`θ_i = rope_base^(−2i/d)`). DeepSeek-V3
    /// style models use 10000; Qwen2.5-style dense models (the distill
    /// shapes) use 1000000 — serving one with the other's base computes
    /// every rotary frequency wrong, which is why this lives in the
    /// config instead of a hard-coded constant.
    pub rope_base: f64,
    // --- MLA ---
    pub q_lora_rank: usize,
    pub kv_lora_rank: usize,
    pub qk_nope_head_dim: usize,
    pub qk_rope_head_dim: usize,
    pub v_head_dim: usize,
    // --- FFN ---
    /// Dense-layer FFN intermediate size.
    pub intermediate_size: usize,
    /// Per-expert FFN intermediate size (MoE layers).
    pub moe_intermediate_size: usize,
    pub n_routed_experts: usize,
    pub n_shared_experts: usize,
    pub n_active_experts: usize,
}

impl ModelConfig {
    /// DeepSeek-V3 / DeepSeek-R1 (671B). Both share the architecture
    /// (R1 is an RL-finetuned V3); dims are from the V3 technical report
    /// `config.json`.
    pub fn deepseek_v3_671b() -> Self {
        ModelConfig {
            name: "deepseek-v3-671b".into(),
            kind: ModelKind::MlaMoe,
            vocab_size: 129_280,
            hidden_size: 7168,
            n_layers: 61,
            first_dense: 3,
            n_heads: 128,
            n_kv_heads: 128,
            head_dim: 0,
            rope_base: DEFAULT_ROPE_BASE,
            q_lora_rank: 1536,
            kv_lora_rank: 512,
            qk_nope_head_dim: 128,
            qk_rope_head_dim: 64,
            v_head_dim: 128,
            intermediate_size: 18_432,
            moe_intermediate_size: 2048,
            n_routed_experts: 256,
            n_shared_experts: 1,
            n_active_experts: 8,
        }
    }

    /// DeepSeek-R1-distill-Qwen-32B (Qwen2.5-32B dense architecture).
    pub fn distill_qwen_32b() -> Self {
        ModelConfig {
            name: "distill-qwen-32b".into(),
            kind: ModelKind::DenseGqa,
            vocab_size: 152_064,
            hidden_size: 5120,
            n_layers: 64,
            first_dense: 64,
            n_heads: 40,
            n_kv_heads: 8,
            head_dim: 128,
            rope_base: 1_000_000.0,
            q_lora_rank: 0,
            kv_lora_rank: 0,
            qk_nope_head_dim: 0,
            qk_rope_head_dim: 0,
            v_head_dim: 0,
            intermediate_size: 27_648,
            moe_intermediate_size: 0,
            n_routed_experts: 0,
            n_shared_experts: 0,
            n_active_experts: 0,
        }
    }

    /// Tiny MLA+MoE proxy (~7M params) used for the end-to-end accuracy
    /// experiments (Tables 2–4 shape reproduction). All quantizable
    /// in-features are multiples of 256 so k-quant super-blocks never
    /// straddle a matrix row.
    pub fn tiny_moe() -> Self {
        ModelConfig {
            name: "tiny-moe".into(),
            kind: ModelKind::MlaMoe,
            vocab_size: 512,
            hidden_size: 256,
            n_layers: 6,
            first_dense: 1,
            n_heads: 4,
            n_kv_heads: 4,
            head_dim: 0,
            rope_base: DEFAULT_ROPE_BASE,
            q_lora_rank: 256,
            kv_lora_rank: 256,
            qk_nope_head_dim: 32,
            qk_rope_head_dim: 32,
            v_head_dim: 64,
            intermediate_size: 512,
            moe_intermediate_size: 256,
            n_routed_experts: 8,
            n_shared_experts: 1,
            n_active_experts: 2,
        }
    }

    /// Tiny dense proxy (~3M params) standing in for the distilled
    /// 32B model (Table 5 shape reproduction).
    pub fn tiny_dense() -> Self {
        ModelConfig {
            name: "tiny-dense".into(),
            kind: ModelKind::DenseGqa,
            vocab_size: 512,
            hidden_size: 256,
            n_layers: 3,
            first_dense: 3,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 64,
            rope_base: 1_000_000.0,
            q_lora_rank: 0,
            kv_lora_rank: 0,
            qk_nope_head_dim: 0,
            qk_rope_head_dim: 0,
            v_head_dim: 0,
            intermediate_size: 512,
            moe_intermediate_size: 0,
            n_routed_experts: 0,
            n_shared_experts: 0,
            n_active_experts: 0,
        }
    }

    /// Scaled-down 671B deployment proxy: the layer plan of
    /// [`ModelConfig::deepseek_v3_671b`] (leading dense layers, then
    /// MoE with a shared expert and 8 active routed experts) at
    /// synthesizable dims, with **64 routed experts** so the Table-2
    /// 8-device deployment shape — a contiguous expert range per shard,
    /// 8 experts per shard at `--shards 8`, mirroring 256/32 per device
    /// on the real model — runs end to end through `runtime::sharded`.
    /// All quantizable in-features are multiples of 256 (the k-quant
    /// super-block rule the tiny proxies follow).
    pub fn deepseek_v3_671b_sim() -> Self {
        ModelConfig {
            name: "deepseek-v3-671b-sim".into(),
            kind: ModelKind::MlaMoe,
            vocab_size: 1024,
            hidden_size: 256,
            n_layers: 4,
            first_dense: 1,
            n_heads: 8,
            n_kv_heads: 8,
            head_dim: 0,
            rope_base: DEFAULT_ROPE_BASE,
            q_lora_rank: 256,
            kv_lora_rank: 256,
            qk_nope_head_dim: 64,
            qk_rope_head_dim: 32,
            v_head_dim: 64,
            intermediate_size: 512,
            moe_intermediate_size: 256,
            n_routed_experts: 64,
            n_shared_experts: 1,
            n_active_experts: 8,
        }
    }

    /// Look up a named config.
    pub fn by_name(name: &str) -> Result<Self> {
        Ok(match name {
            "deepseek-v3-671b" | "deepseek-r1-671b" | "671b" => {
                let mut c = Self::deepseek_v3_671b();
                if name == "deepseek-r1-671b" {
                    c.name = "deepseek-r1-671b".into();
                }
                c
            }
            "deepseek-v3-671b-sim" | "671b-sim" => Self::deepseek_v3_671b_sim(),
            "distill-qwen-32b" | "32b" => Self::distill_qwen_32b(),
            "tiny-moe" => Self::tiny_moe(),
            "tiny-dense" => Self::tiny_dense(),
            other => bail!("unknown model config {other:?}"),
        })
    }

    /// Number of MoE layers.
    pub fn n_moe_layers(&self) -> usize {
        match self.kind {
            ModelKind::MlaMoe => self.n_layers - self.first_dense,
            ModelKind::DenseGqa => 0,
        }
    }

    /// Is layer `i` a MoE layer?
    pub fn is_moe_layer(&self, i: usize) -> bool {
        self.kind == ModelKind::MlaMoe && i >= self.first_dense
    }

    /// Per-head query dimension for MLA models (`nope + rope` parts).
    pub fn qk_head_dim(&self) -> usize {
        self.qk_nope_head_dim + self.qk_rope_head_dim
    }

    /// Floats cached per (layer, token) by the native runtime — the
    /// width of every `runtime::forward::KvCache` row:
    ///
    /// - MLA: the compressed KV latent plus the shared post-RoPE rope
    ///   key (also the out-dimension of `attn_kv_a_mqa`);
    /// - dense GQA: the conventional per-head state, post-RoPE keys
    ///   followed by values (`2 · n_kv_heads · head_dim`).
    pub fn kv_cache_width(&self) -> usize {
        match self.kind {
            ModelKind::MlaMoe => self.kv_lora_rank + self.qk_rope_head_dim,
            ModelKind::DenseGqa => 2 * self.n_kv_heads * self.head_dim,
        }
    }

    /// MLA KV-cache bytes per token (compressed latent + rope key),
    /// stored in f16: `(kv_lora_rank + qk_rope_head_dim) · n_layers · 2`.
    /// Dense GQA caches full K/V heads instead.
    pub fn kv_bytes_per_token(&self) -> usize {
        match self.kind {
            ModelKind::MlaMoe => (self.kv_lora_rank + self.qk_rope_head_dim) * self.n_layers * 2,
            ModelKind::DenseGqa => 2 * self.n_kv_heads * self.head_dim * self.n_layers * 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v3_config_dims_match_tech_report() {
        let c = ModelConfig::deepseek_v3_671b();
        assert_eq!(c.n_layers, 61);
        assert_eq!(c.hidden_size, 7168);
        assert_eq!(c.n_routed_experts, 256);
        assert_eq!(c.n_moe_layers(), 58);
        assert!(!c.is_moe_layer(2));
        assert!(c.is_moe_layer(3));
        // MLA cache: (512 + 64) · 61 · 2 bytes ≈ 70.3 KB/token.
        assert_eq!(c.kv_bytes_per_token(), (512 + 64) * 61 * 2);
        assert_eq!(c.qk_head_dim(), 128 + 64);
        assert_eq!(c.kv_cache_width(), 512 + 64);
        // The runtime cache width is exactly what kv_bytes_per_token
        // accounts (f16 storage in the analytic model).
        assert_eq!(c.kv_bytes_per_token(), c.kv_cache_width() * c.n_layers * 2);
    }

    #[test]
    fn dense_kv_cache_is_full_per_head_state() {
        // GQA caches post-RoPE K plus V per kv head — the footprint
        // kv_bytes_per_token has always accounted for DenseGqa.
        let c = ModelConfig::tiny_dense();
        assert_eq!(c.kv_cache_width(), 2 * 2 * 64);
        assert_eq!(c.kv_bytes_per_token(), c.kv_cache_width() * c.n_layers * 2);
        let d = ModelConfig::distill_qwen_32b();
        assert_eq!(d.kv_cache_width(), 2 * 8 * 128);
        assert_eq!(d.kv_bytes_per_token(), d.kv_cache_width() * d.n_layers * 2);
    }

    #[test]
    fn rope_base_matches_the_architecture_family() {
        // DeepSeek-V3 keeps the classic θ=10000; the Qwen2.5-style
        // distill shapes use θ=1000000 (Qwen2.5 config.json rope_theta).
        assert_eq!(ModelConfig::deepseek_v3_671b().rope_base, DEFAULT_ROPE_BASE);
        assert_eq!(ModelConfig::tiny_moe().rope_base, DEFAULT_ROPE_BASE);
        assert_eq!(ModelConfig::distill_qwen_32b().rope_base, 1_000_000.0);
        assert_eq!(ModelConfig::tiny_dense().rope_base, 1_000_000.0);
    }

    #[test]
    fn lookup_by_name() {
        assert!(ModelConfig::by_name("deepseek-r1-671b").is_ok());
        assert!(ModelConfig::by_name("tiny-moe").is_ok());
        assert!(ModelConfig::by_name("nope").is_err());
    }

    #[test]
    fn tiny_rows_are_superblock_aligned() {
        // Quantization requirement: every quantizable in-feature dim is a
        // multiple of 256 (checked properly in census tests).
        for c in [ModelConfig::tiny_moe(), ModelConfig::deepseek_v3_671b_sim()] {
            assert_eq!(c.hidden_size % 256, 0, "{}", c.name);
            assert_eq!(c.q_lora_rank % 256, 0, "{}", c.name);
            assert_eq!(c.kv_lora_rank % 256, 0, "{}", c.name);
            assert_eq!(c.moe_intermediate_size % 256, 0, "{}", c.name);
            assert_eq!(c.intermediate_size % 256, 0, "{}", c.name);
            assert_eq!(c.n_heads * c.v_head_dim % 256, 0, "{}", c.name);
        }
    }

    #[test]
    fn sim_671b_mirrors_the_table2_deployment_shape() {
        let c = ModelConfig::by_name("671b-sim").unwrap();
        assert_eq!(c.name, "deepseek-v3-671b-sim");
        assert_eq!(c.kind, ModelKind::MlaMoe);
        // The deployment-defining ratio: a contiguous expert range per
        // shard, 8 experts per shard at 8 shards (Table 2 deploys
        // 256 experts as 32 per device on the real model).
        assert_eq!(c.n_routed_experts % 8, 0);
        assert_eq!(c.n_routed_experts / 8, 8);
        assert_eq!(c.n_active_experts, 8, "V3's top-k is preserved");
        assert_eq!(c.n_shared_experts, 1);
        assert!(c.first_dense >= 1, "leading dense layer(s) like the real plan");
    }
}

// --- JSON (de)serialization for container headers and configs/models ---

use crate::util::json::{self, Value};

impl ModelConfig {
    /// Serialize to the JSON object stored in `.dsq` headers and
    /// `configs/models/*.json`.
    ///
    /// `rope_base` is omitted at [`DEFAULT_ROPE_BASE`] so containers of
    /// θ=10000 models keep the exact header bytes they had before the
    /// base became configurable (the committed `container.*.fnv64`
    /// golden checksums pin those bytes); [`ModelConfig::from_json`]
    /// defaults a missing field to the same value.
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("name", json::str_(&self.name)),
            (
                "kind",
                json::str_(match self.kind {
                    ModelKind::MlaMoe => "mla_moe",
                    ModelKind::DenseGqa => "dense_gqa",
                }),
            ),
            ("vocab_size", json::num(self.vocab_size as f64)),
            ("hidden_size", json::num(self.hidden_size as f64)),
            ("n_layers", json::num(self.n_layers as f64)),
            ("first_dense", json::num(self.first_dense as f64)),
            ("n_heads", json::num(self.n_heads as f64)),
            ("n_kv_heads", json::num(self.n_kv_heads as f64)),
            ("head_dim", json::num(self.head_dim as f64)),
            ("q_lora_rank", json::num(self.q_lora_rank as f64)),
            ("kv_lora_rank", json::num(self.kv_lora_rank as f64)),
            ("qk_nope_head_dim", json::num(self.qk_nope_head_dim as f64)),
            ("qk_rope_head_dim", json::num(self.qk_rope_head_dim as f64)),
            ("v_head_dim", json::num(self.v_head_dim as f64)),
            ("intermediate_size", json::num(self.intermediate_size as f64)),
            ("moe_intermediate_size", json::num(self.moe_intermediate_size as f64)),
            ("n_routed_experts", json::num(self.n_routed_experts as f64)),
            ("n_shared_experts", json::num(self.n_shared_experts as f64)),
            ("n_active_experts", json::num(self.n_active_experts as f64)),
        ];
        if self.rope_base != DEFAULT_ROPE_BASE {
            fields.push(("rope_base", json::num(self.rope_base)));
        }
        json::obj(fields)
    }

    /// Inverse of [`ModelConfig::to_json`].
    pub fn from_json(v: &Value) -> Result<Self> {
        let kind = match v.req("kind")?.as_str()? {
            "mla_moe" => ModelKind::MlaMoe,
            "dense_gqa" => ModelKind::DenseGqa,
            other => bail!("unknown model kind {other:?}"),
        };
        let u = |k: &str| -> Result<usize> { v.req(k)?.as_usize() };
        let rope_base = match v.get("rope_base") {
            None => DEFAULT_ROPE_BASE,
            Some(b) => {
                let b = b.as_f64()?;
                if !b.is_finite() || b <= 1.0 {
                    bail!("rope_base must be a finite number > 1, got {b}");
                }
                b
            }
        };
        Ok(ModelConfig {
            name: v.req("name")?.as_str()?.to_string(),
            kind,
            rope_base,
            vocab_size: u("vocab_size")?,
            hidden_size: u("hidden_size")?,
            n_layers: u("n_layers")?,
            first_dense: u("first_dense")?,
            n_heads: u("n_heads")?,
            n_kv_heads: u("n_kv_heads")?,
            head_dim: u("head_dim")?,
            q_lora_rank: u("q_lora_rank")?,
            kv_lora_rank: u("kv_lora_rank")?,
            qk_nope_head_dim: u("qk_nope_head_dim")?,
            qk_rope_head_dim: u("qk_rope_head_dim")?,
            v_head_dim: u("v_head_dim")?,
            intermediate_size: u("intermediate_size")?,
            moe_intermediate_size: u("moe_intermediate_size")?,
            n_routed_experts: u("n_routed_experts")?,
            n_shared_experts: u("n_shared_experts")?,
            n_active_experts: u("n_active_experts")?,
        })
    }
}

#[cfg(test)]
mod json_tests {
    use super::*;

    /// The checked-in configs/models/*.json (read by the Python build
    /// pipeline) must stay identical to the built-in configs.
    #[test]
    fn config_files_match_builtin() {
        for (name, text) in [
            ("tiny-moe", include_str!("../../../configs/models/tiny-moe.json")),
            ("tiny-dense", include_str!("../../../configs/models/tiny-dense.json")),
        ] {
            let v = json::parse(text).unwrap();
            let parsed = ModelConfig::from_json(&v).unwrap();
            let builtin = ModelConfig::by_name(name).unwrap();
            assert_eq!(format!("{parsed:?}"), format!("{builtin:?}"), "{name}");
        }
    }

    #[test]
    fn config_json_roundtrip() {
        for cfg in [
            ModelConfig::deepseek_v3_671b(),
            ModelConfig::deepseek_v3_671b_sim(),
            ModelConfig::distill_qwen_32b(),
            ModelConfig::tiny_moe(),
            ModelConfig::tiny_dense(),
        ] {
            let v = cfg.to_json();
            let back = ModelConfig::from_json(&v).unwrap();
            assert_eq!(format!("{cfg:?}"), format!("{back:?}"));
        }
    }

    #[test]
    fn rope_base_json_is_defaulted_omitted_and_validated() {
        // Default-θ configs omit the field (legacy container headers
        // stay byte-identical) and re-parse to the default.
        let v = ModelConfig::tiny_moe().to_json();
        assert!(v.get("rope_base").is_none(), "θ=10000 must serialize implicitly");
        assert_eq!(ModelConfig::from_json(&v).unwrap().rope_base, DEFAULT_ROPE_BASE);
        // Non-default bases round-trip explicitly.
        let v = ModelConfig::tiny_dense().to_json();
        assert_eq!(v.req("rope_base").unwrap().as_f64().unwrap(), 1_000_000.0);
        // Degenerate bases are rejected at parse time.
        let mut cfg = ModelConfig::tiny_dense();
        cfg.rope_base = 0.5;
        assert!(ModelConfig::from_json(&cfg.to_json()).is_err());
    }
}
