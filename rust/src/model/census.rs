//! Tensor census: enumerate every weight tensor of a [`ModelConfig`]
//! with its GGUF-style module class, layer index and shape.

use super::config::{ModelConfig, ModelKind};

/// GGUF-style module classes (the rows of Table 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModuleClass {
    TokenEmbd,
    Output,
    Norm,
    AttnQA,
    AttnQB,
    AttnKvAMqa,
    AttnKvB,
    AttnOutput,
    // Dense (GQA) attention:
    AttnQ,
    AttnK,
    AttnV,
    // Dense FFN:
    FfnGate,
    FfnUp,
    FfnDown,
    // MoE:
    FfnGateInp,
    FfnGateExps,
    FfnUpExps,
    FfnDownExps,
    FfnGateShexp,
    FfnUpShexp,
    FfnDownShexp,
}

impl ModuleClass {
    /// GGUF tensor-name stem, as used in Table 7 and the scheme JSON.
    pub fn name(self) -> &'static str {
        use ModuleClass::*;
        match self {
            TokenEmbd => "token_embd",
            Output => "output",
            Norm => "norm",
            AttnQA => "attn_q_a",
            AttnQB => "attn_q_b",
            AttnKvAMqa => "attn_kv_a_mqa",
            AttnKvB => "attn_kv_b",
            AttnOutput => "attn_output",
            AttnQ => "attn_q",
            AttnK => "attn_k",
            AttnV => "attn_v",
            FfnGate => "ffn_gate",
            FfnUp => "ffn_up",
            FfnDown => "ffn_down",
            FfnGateInp => "ffn_gate_inp",
            FfnGateExps => "ffn_gate_exps",
            FfnUpExps => "ffn_up_exps",
            FfnDownExps => "ffn_down_exps",
            FfnGateShexp => "ffn_gate_shexp",
            FfnUpShexp => "ffn_up_shexp",
            FfnDownShexp => "ffn_down_shexp",
        }
    }

    /// All classes, in Table 7 row order where applicable.
    pub const ALL: [ModuleClass; 21] = [
        ModuleClass::Output,
        ModuleClass::TokenEmbd,
        ModuleClass::AttnKvAMqa,
        ModuleClass::AttnKvB,
        ModuleClass::AttnOutput,
        ModuleClass::AttnQA,
        ModuleClass::AttnQB,
        ModuleClass::FfnDown,
        ModuleClass::FfnGate,
        ModuleClass::FfnUp,
        ModuleClass::FfnDownExps,
        ModuleClass::FfnDownShexp,
        ModuleClass::FfnGateExps,
        ModuleClass::FfnGateShexp,
        ModuleClass::FfnUpExps,
        ModuleClass::FfnUpShexp,
        ModuleClass::AttnQ,
        ModuleClass::AttnK,
        ModuleClass::AttnV,
        ModuleClass::FfnGateInp,
        ModuleClass::Norm,
    ];

    pub fn parse(name: &str) -> Option<Self> {
        ModuleClass::ALL.into_iter().find(|c| c.name() == name)
    }

    /// Norms, biases and the MoE router stay in f32 under every scheme
    /// (llama.cpp keeps these high-precision too — they are tiny).
    pub fn quantizable(self) -> bool {
        !matches!(self, ModuleClass::Norm | ModuleClass::FfnGateInp)
    }
}

/// One weight tensor in the census.
#[derive(Debug, Clone)]
pub struct TensorInfo {
    /// Full GGUF-style name, e.g. `blk.7.ffn_down_exps.weight`.
    pub name: String,
    pub class: ModuleClass,
    /// Layer index; `None` for global tensors (embeddings, output).
    pub layer: Option<usize>,
    /// Storage shape, outermost first (e.g. `[n_experts, out, in]`).
    pub shape: Vec<usize>,
}

impl TensorInfo {
    pub fn n_params(&self) -> u64 {
        self.shape.iter().map(|&d| d as u64).product()
    }

    /// The contiguous row length that k-quant blocks run along (the
    /// innermost dimension).
    pub fn row_len(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }
}

impl ModelConfig {
    /// Enumerate every weight tensor.
    pub fn census(&self) -> Vec<TensorInfo> {
        let mut out = Vec::new();
        let t = |name: String, class: ModuleClass, layer: Option<usize>, shape: Vec<usize>| {
            TensorInfo { name, class, layer, shape }
        };
        out.push(t(
            "token_embd.weight".into(),
            ModuleClass::TokenEmbd,
            None,
            vec![self.vocab_size, self.hidden_size],
        ));
        for i in 0..self.n_layers {
            let blk = |stem: &str| format!("blk.{i}.{stem}.weight");
            out.push(t(blk("attn_norm"), ModuleClass::Norm, Some(i), vec![self.hidden_size]));
            match self.kind {
                ModelKind::MlaMoe => {
                    let qk_head = self.qk_nope_head_dim + self.qk_rope_head_dim;
                    out.push(t(
                        blk("attn_q_a"),
                        ModuleClass::AttnQA,
                        Some(i),
                        vec![self.q_lora_rank, self.hidden_size],
                    ));
                    out.push(t(
                        blk("attn_q_a_norm"),
                        ModuleClass::Norm,
                        Some(i),
                        vec![self.q_lora_rank],
                    ));
                    out.push(t(
                        blk("attn_q_b"),
                        ModuleClass::AttnQB,
                        Some(i),
                        vec![self.n_heads * qk_head, self.q_lora_rank],
                    ));
                    out.push(t(
                        blk("attn_kv_a_mqa"),
                        ModuleClass::AttnKvAMqa,
                        Some(i),
                        vec![self.kv_lora_rank + self.qk_rope_head_dim, self.hidden_size],
                    ));
                    out.push(t(
                        blk("attn_kv_a_norm"),
                        ModuleClass::Norm,
                        Some(i),
                        vec![self.kv_lora_rank],
                    ));
                    out.push(t(
                        blk("attn_kv_b"),
                        ModuleClass::AttnKvB,
                        Some(i),
                        vec![
                            self.n_heads * (self.qk_nope_head_dim + self.v_head_dim),
                            self.kv_lora_rank,
                        ],
                    ));
                    out.push(t(
                        blk("attn_output"),
                        ModuleClass::AttnOutput,
                        Some(i),
                        vec![self.hidden_size, self.n_heads * self.v_head_dim],
                    ));
                }
                ModelKind::DenseGqa => {
                    out.push(t(
                        blk("attn_q"),
                        ModuleClass::AttnQ,
                        Some(i),
                        vec![self.n_heads * self.head_dim, self.hidden_size],
                    ));
                    out.push(t(
                        blk("attn_k"),
                        ModuleClass::AttnK,
                        Some(i),
                        vec![self.n_kv_heads * self.head_dim, self.hidden_size],
                    ));
                    out.push(t(
                        blk("attn_v"),
                        ModuleClass::AttnV,
                        Some(i),
                        vec![self.n_kv_heads * self.head_dim, self.hidden_size],
                    ));
                    out.push(t(
                        blk("attn_output"),
                        ModuleClass::AttnOutput,
                        Some(i),
                        vec![self.hidden_size, self.n_heads * self.head_dim],
                    ));
                }
            }
            out.push(t(blk("ffn_norm"), ModuleClass::Norm, Some(i), vec![self.hidden_size]));
            if self.is_moe_layer(i) {
                out.push(t(
                    blk("ffn_gate_inp"),
                    ModuleClass::FfnGateInp,
                    Some(i),
                    vec![self.n_routed_experts, self.hidden_size],
                ));
                out.push(t(
                    blk("ffn_gate_exps"),
                    ModuleClass::FfnGateExps,
                    Some(i),
                    vec![self.n_routed_experts, self.moe_intermediate_size, self.hidden_size],
                ));
                out.push(t(
                    blk("ffn_up_exps"),
                    ModuleClass::FfnUpExps,
                    Some(i),
                    vec![self.n_routed_experts, self.moe_intermediate_size, self.hidden_size],
                ));
                out.push(t(
                    blk("ffn_down_exps"),
                    ModuleClass::FfnDownExps,
                    Some(i),
                    vec![self.n_routed_experts, self.hidden_size, self.moe_intermediate_size],
                ));
                let sh_inter = self.n_shared_experts * self.moe_intermediate_size;
                out.push(t(
                    blk("ffn_gate_shexp"),
                    ModuleClass::FfnGateShexp,
                    Some(i),
                    vec![sh_inter, self.hidden_size],
                ));
                out.push(t(
                    blk("ffn_up_shexp"),
                    ModuleClass::FfnUpShexp,
                    Some(i),
                    vec![sh_inter, self.hidden_size],
                ));
                out.push(t(
                    blk("ffn_down_shexp"),
                    ModuleClass::FfnDownShexp,
                    Some(i),
                    vec![self.hidden_size, sh_inter],
                ));
            } else {
                out.push(t(
                    blk("ffn_gate"),
                    ModuleClass::FfnGate,
                    Some(i),
                    vec![self.intermediate_size, self.hidden_size],
                ));
                out.push(t(
                    blk("ffn_up"),
                    ModuleClass::FfnUp,
                    Some(i),
                    vec![self.intermediate_size, self.hidden_size],
                ));
                out.push(t(
                    blk("ffn_down"),
                    ModuleClass::FfnDown,
                    Some(i),
                    vec![self.hidden_size, self.intermediate_size],
                ));
            }
        }
        out.push(t(
            "output_norm.weight".into(),
            ModuleClass::Norm,
            None,
            vec![self.hidden_size],
        ));
        out.push(t(
            "output.weight".into(),
            ModuleClass::Output,
            None,
            vec![self.vocab_size, self.hidden_size],
        ));
        out
    }

    /// Total parameter count.
    pub fn total_params(&self) -> u64 {
        self.census().iter().map(|t| t.n_params()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v3_census_totals_671b() {
        let c = ModelConfig::deepseek_v3_671b();
        let total = c.total_params();
        // The census must land on the published 671B figure (±1%).
        let b = 1_000_000_000f64;
        let t = total as f64 / b;
        assert!((665.0..678.0).contains(&t), "total params {t:.1}B");
    }

    #[test]
    fn distill_census_totals_32b() {
        let c = ModelConfig::distill_qwen_32b();
        let t = c.total_params() as f64 / 1e9;
        assert!((31.0..34.0).contains(&t), "total params {t:.1}B");
    }

    #[test]
    fn moe_layer_structure() {
        let c = ModelConfig::deepseek_v3_671b();
        let census = c.census();
        let down_exps: Vec<_> = census
            .iter()
            .filter(|t| t.class == ModuleClass::FfnDownExps)
            .collect();
        assert_eq!(down_exps.len(), 58);
        assert_eq!(down_exps[0].layer, Some(3));
        assert_eq!(down_exps[0].shape, vec![256, 7168, 2048]);
        let dense_down: Vec<_> = census
            .iter()
            .filter(|t| t.class == ModuleClass::FfnDown)
            .collect();
        assert_eq!(dense_down.len(), 3);
    }

    #[test]
    fn tiny_quantizable_rows_superblock_aligned() {
        for cfg in [ModelConfig::tiny_moe(), ModelConfig::tiny_dense()] {
            for t in cfg.census() {
                if t.class.quantizable() {
                    assert_eq!(
                        t.row_len() % 256,
                        0,
                        "{}: row len {} not 256-aligned",
                        t.name,
                        t.row_len()
                    );
                }
            }
        }
    }

    #[test]
    fn names_are_unique() {
        let c = ModelConfig::deepseek_v3_671b();
        let census = c.census();
        let mut names: Vec<&str> = census.iter().map(|t| t.name.as_str()).collect();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        assert_eq!(before, names.len());
    }

    #[test]
    fn class_name_roundtrip() {
        for c in ModuleClass::ALL {
            assert_eq!(ModuleClass::parse(c.name()), Some(c));
        }
    }
}
