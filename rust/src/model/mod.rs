//! Model architecture census.
//!
//! Describes the *shapes* of every weight tensor in a DeepSeek-V3-style
//! MLA+MoE transformer (and the dense Qwen-style distill variant), so
//! that the scheme engine and memory model can compute exact per-module
//! parameter counts, quantized sizes, and average bit-widths — the
//! arithmetic behind Tables 1, 6 and 7 of the paper.
//!
//! Module naming follows GGUF (`ffn_down_exps`, `attn_kv_a_mqa`, …),
//! matching Table 7 of the paper.

pub mod census;
pub mod config;

pub use census::{ModuleClass, TensorInfo};
pub use config::{ModelConfig, ModelKind};
