//! Table rendering for the accuracy tables (Tables 2–5).

use super::EvalResult;

/// Render an accuracy table: one row per suite (mean with ±std), plus
/// Average / Weighted avg. / Accuracy drop rows — the exact row
/// structure of Tables 2–5. The first column is the reference
/// (accuracy drop is relative to it).
pub fn render(title: &str, columns: &[EvalResult]) -> String {
    assert!(!columns.is_empty());
    let reference = &columns[0];
    let mut out = String::new();
    out.push_str(&format!("## {title}\n\n"));

    let mut header = vec!["Benchmark".to_string()];
    header.extend(columns.iter().map(|c| display_scheme(&c.scheme)));
    let mut rows: Vec<Vec<String>> = Vec::new();

    for (i, suite) in reference.suites.iter().enumerate() {
        let mut row = vec![suite.suite.to_string()];
        for c in columns {
            let s = &c.suites[i];
            match s.std() {
                Some(sd) => row.push(format!("{:.2} (±{:.2})", s.mean(), sd)),
                None => row.push(format!("{:.2}", s.mean())),
            }
        }
        rows.push(row);
    }
    let mut avg = vec!["Average".to_string()];
    let mut wavg = vec!["Weighted avg.".to_string()];
    let mut drop = vec!["Accuracy drop".to_string()];
    for (i, c) in columns.iter().enumerate() {
        avg.push(format!("{:.2}", c.average()));
        wavg.push(format!("{:.2}", c.weighted_average()));
        if i == 0 {
            drop.push("-".to_string());
        } else {
            let d = c.accuracy_drop_vs(reference);
            drop.push(if d == 0.0 { "0".to_string() } else { format!("{d:.2}%") });
        }
    }
    rows.push(avg);
    rows.push(wavg);
    rows.push(drop);

    out.push_str(&render_markdown(&header, &rows));
    out
}

/// Human display name for a scheme column.
pub fn display_scheme(name: &str) -> String {
    match name {
        "f32" => "FP32 (reference)".to_string(),
        "q8_0" => "Q8_0 (llama.cpp)".to_string(),
        "q4_k_m" => "Q4_K_M (llama.cpp)".to_string(),
        "q3_k_m" => "Q3_K_M (llama.cpp)".to_string(),
        "dq3_k_m" => "DQ3_K_M (ours)".to_string(),
        "q2_k_l" => "Q2_K_L (llama.cpp)".to_string(),
        "ud_q2_k_xl" => "UD-Q2_K_XL (Unsloth)".to_string(),
        "q4_k" => "Q4_K".to_string(),
        "q3_k" => "Q3_K".to_string(),
        other => other.to_string(),
    }
}

/// Simple aligned markdown table.
pub fn render_markdown(header: &[String], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut width = vec![0usize; ncol];
    for (i, h) in header.iter().enumerate() {
        width[i] = h.chars().count();
    }
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            width[i] = width[i].max(cell.chars().count());
        }
    }
    let fmt_row = |cells: &[String]| -> String {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!(" {:w$} |", c, w = width[i]));
        }
        s.push('\n');
        s
    };
    let mut out = fmt_row(header);
    out.push('|');
    for w in &width {
        out.push_str(&format!("{:-<w$}|", "", w = w + 2));
    }
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{suites, SuiteResult};

    fn fake(scheme: &str, base: f64) -> EvalResult {
        EvalResult {
            model: "tiny-moe".into(),
            scheme: scheme.into(),
            suites: suites::SUITES
                .iter()
                .enumerate()
                .map(|(i, s)| SuiteResult {
                    suite: s.name,
                    weight: s.weight,
                    sample_scores: if s.samples > 1 {
                        vec![base + i as f64, base + i as f64 + 1.0]
                    } else {
                        vec![base + i as f64]
                    },
                    n_questions: 8,
                })
                .collect(),
        }
    }

    #[test]
    fn renders_all_rows() {
        let cols = vec![fake("f32", 80.0), fake("dq3_k_m", 79.0)];
        let t = render("Table 2: DeepSeek-R1 proxy", &cols);
        assert!(t.contains("AIME 2024"));
        assert!(t.contains("Weighted avg."));
        assert!(t.contains("Accuracy drop"));
        assert!(t.contains("DQ3_K_M (ours)"));
        assert!(t.contains("(±")); // std for multi-sample rows
        // 9 suites + 3 aggregate rows + header + separator = 14 lines + title.
        assert_eq!(t.lines().filter(|l| l.starts_with('|')).count(), 14);
    }

    #[test]
    fn markdown_alignment() {
        let t = render_markdown(
            &["A".into(), "B".into()],
            &[vec!["x".into(), "yyyy".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines[0].len(), lines[2].len());
    }
}
