//! Benchmark evaluation harness — reproduces Tables 2–5.
//!
//! Implements the paper's protocol (§4.2): temperature 0.6 / top-p 0.95
//! decoding; 8 independent samples per AIME question, 4 for the other
//! small suites, a single pass for the large knowledge suites; mean ±
//! population-std across sample passes; plain and Table-8-weighted
//! averages; relative accuracy drop vs the reference column.

pub mod longgen;
pub mod report;
pub mod suites;
pub mod tasks;

use crate::coordinator::{sampler::SamplingParams, Coordinator, Request};
use crate::util::json::{self, Value};
use anyhow::Result;
use suites::{Suite, TaskFamily};

/// Evaluation protocol options.
#[derive(Debug, Clone, Copy)]
pub struct Protocol {
    /// Use the paper's full question counts (default: CPU-scaled).
    pub full_size: bool,
    /// Divide per-question sample counts by this factor (≥1).
    pub sample_divisor: usize,
    pub temperature: f32,
    pub top_p: f32,
}

impl Default for Protocol {
    fn default() -> Self {
        // Default: scaled counts, halved samples (AIME 8→4, small 4→2)
        // to keep a full table run tractable on one CPU core.
        Protocol { full_size: false, sample_divisor: 2, temperature: 0.6, top_p: 0.95 }
    }
}

impl Protocol {
    pub fn paper() -> Self {
        Protocol { full_size: true, sample_divisor: 1, temperature: 0.6, top_p: 0.95 }
    }

    pub fn samples_for(&self, suite: &Suite) -> usize {
        (suite.samples / self.sample_divisor).max(1)
    }
}

/// Result of one suite evaluation.
#[derive(Debug, Clone)]
pub struct SuiteResult {
    pub suite: &'static str,
    pub weight: f64,
    /// Suite-level score (0–100) per sample pass.
    pub sample_scores: Vec<f64>,
    pub n_questions: usize,
}

impl SuiteResult {
    pub fn mean(&self) -> f64 {
        let (m, _) = crate::util::mean_std(&self.sample_scores);
        m
    }

    /// Population std across sample passes (None for single-pass suites).
    pub fn std(&self) -> Option<f64> {
        if self.sample_scores.len() < 2 {
            return None;
        }
        let (_, s) = crate::util::mean_std(&self.sample_scores);
        Some(s)
    }
}

/// Full evaluation of one (checkpoint, scheme) column.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub model: String,
    pub scheme: String,
    pub suites: Vec<SuiteResult>,
}

impl EvalResult {
    /// Plain average over suites (the paper's "Average" row).
    pub fn average(&self) -> f64 {
        let scores: Vec<f64> = self.suites.iter().map(|s| s.mean()).collect();
        let (m, _) = crate::util::mean_std(&scores);
        m
    }

    /// Table-8-weighted average (the paper's "Weighted avg." row).
    pub fn weighted_average(&self) -> f64 {
        let num: f64 = self.suites.iter().map(|s| s.weight * s.mean()).sum();
        let den: f64 = self.suites.iter().map(|s| s.weight).sum();
        num / den
    }

    /// Relative accuracy drop vs a reference (the paper clamps gains
    /// to 0, reporting "0" when a quantized model beats the reference).
    pub fn accuracy_drop_vs(&self, reference: &EvalResult) -> f64 {
        let r = reference.weighted_average();
        let d = (r - self.weighted_average()) / r * 100.0;
        d.max(0.0)
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("model", json::str_(&self.model)),
            ("scheme", json::str_(&self.scheme)),
            (
                "suites",
                json::arr(
                    self.suites
                        .iter()
                        .map(|s| {
                            json::obj(vec![
                                ("suite", json::str_(s.suite)),
                                ("weight", json::num(s.weight)),
                                ("n_questions", json::num(s.n_questions as f64)),
                                (
                                    "sample_scores",
                                    json::arr(
                                        s.sample_scores.iter().map(|&x| json::num(x)).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<EvalResult> {
        let mut suites_out = Vec::new();
        for sv in v.req("suites")?.as_arr()? {
            let name = sv.req("suite")?.as_str()?;
            let suite = suites::by_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown suite {name:?}"))?;
            suites_out.push(SuiteResult {
                suite: suite.name,
                weight: sv.req("weight")?.as_f64()?,
                n_questions: sv.req("n_questions")?.as_usize()?,
                sample_scores: sv
                    .req("sample_scores")?
                    .as_arr()?
                    .iter()
                    .map(|x| x.as_f64())
                    .collect::<Result<_>>()?,
            });
        }
        Ok(EvalResult {
            model: v.req("model")?.as_str()?.to_string(),
            scheme: v.req("scheme")?.as_str()?.to_string(),
            suites: suites_out,
        })
    }
}

/// Score a generation against the expected answer.
///
/// MBPP ("prefix-lenient"): the expected content tokens must be a prefix
/// of the generation — trailing rambling is forgiven. Everything else
/// (including MBPP+, the "stricter tests" variant) requires exact match
/// including the terminating EOS.
pub fn score(family: TaskFamily, strict: bool, expected: &[i32], generated: &[i32]) -> bool {
    let _ = family;
    if strict {
        generated == expected
    } else {
        let content = &expected[..expected.len() - 1]; // strip EOS
        generated.len() >= content.len() && &generated[..content.len()] == content
    }
}

/// Evaluate one suite through the coordinator.
pub fn run_suite(
    coord: &mut Coordinator,
    suite: &'static Suite,
    protocol: &Protocol,
    strict_override: Option<bool>,
) -> Result<SuiteResult> {
    let n = suite.count(protocol.full_size);
    let samples = protocol.samples_for(suite);
    // MBPP is the only prefix-lenient suite (MBPP+ re-scores strictly).
    let strict = strict_override.unwrap_or(suite.name != "MBPP");

    let mut sample_scores = Vec::with_capacity(samples);
    for s in 0..samples {
        let mut correct = 0usize;
        let mut questions = Vec::with_capacity(n);
        for qid in 0..n {
            let q = tasks::eval_question(suite, qid as u64);
            coord.submit(Request {
                id: qid as u64,
                prompt: q.prompt.clone(),
                params: SamplingParams {
                    temperature: protocol.temperature,
                    top_p: protocol.top_p,
                    max_new_tokens: tasks::MAX_ANSWER,
                },
                seed: 0x5eed ^ (suite.stream_id())
                    ^ ((qid as u64) << 20)
                    ^ ((s as u64) << 50),
            })?;
            questions.push(q);
        }
        let responses = coord.run_to_completion()?;
        for resp in responses {
            let q = &questions[resp.id as usize];
            if score(suite.family, strict, &q.answer, &resp.tokens) {
                correct += 1;
            }
        }
        sample_scores.push(correct as f64 / n as f64 * 100.0);
    }
    Ok(SuiteResult {
        suite: suite.name,
        weight: suite.weight,
        sample_scores,
        n_questions: n,
    })
}

/// Evaluate all nine suites for one engine.
pub fn run_all(coord: &mut Coordinator, protocol: &Protocol) -> Result<EvalResult> {
    let mut out = Vec::new();
    for suite in suites::SUITES {
        let t0 = std::time::Instant::now();
        let r = run_suite(coord, suite, protocol, None)?;
        eprintln!(
            "[eval] {} {}: {} = {:.2} (±{:.2}) [{} questions × {} samples, {:.1}s]",
            coord.engine().model_name,
            coord.engine().scheme_name,
            suite.name,
            r.mean(),
            r.std().unwrap_or(0.0),
            r.n_questions,
            r.sample_scores.len(),
            t0.elapsed().as_secs_f64()
        );
        out.push(r);
    }
    Ok(EvalResult {
        model: coord.engine().model_name.clone(),
        scheme: coord.engine().scheme_name.clone(),
        suites: out,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoring_rules() {
        use TaskFamily::*;
        // Exact.
        assert!(score(Arith, true, &[7, 8, 4], &[7, 8, 4]));
        assert!(!score(Arith, true, &[7, 8, 4], &[7, 8]));
        assert!(!score(Arith, true, &[7, 8, 4], &[7, 8, 4, 9]));
        // Prefix-lenient (MBPP): rambling after the answer is fine.
        assert!(score(Transform, false, &[7, 8, 4], &[7, 8, 4]));
        assert!(score(Transform, false, &[7, 8, 4], &[7, 8, 9, 9]));
        assert!(!score(Transform, false, &[7, 8, 4], &[7, 9, 4]));
    }

    #[test]
    fn protocol_sample_scaling() {
        let p = Protocol::default();
        assert_eq!(p.samples_for(suites::by_name("AIME 2024").unwrap()), 4);
        assert_eq!(p.samples_for(suites::by_name("MATH 500").unwrap()), 2);
        assert_eq!(p.samples_for(suites::by_name("MMLU").unwrap()), 1);
        let full = Protocol::paper();
        assert_eq!(full.samples_for(suites::by_name("AIME 2024").unwrap()), 8);
    }

    #[test]
    fn samples_for_divisor_larger_than_samples_clamps_to_one() {
        // A divisor bigger than any suite's sample count must still run
        // one pass per suite, never zero.
        let p = Protocol { sample_divisor: 1000, ..Protocol::default() };
        for suite in suites::SUITES {
            assert_eq!(p.samples_for(suite), 1, "suite {}", suite.name);
        }
        // Exactly-equal divisor also lands on one pass.
        let aime = suites::by_name("AIME 2024").unwrap();
        let p = Protocol { sample_divisor: aime.samples, ..p };
        assert_eq!(p.samples_for(aime), 1);
    }

    #[test]
    fn suite_result_mean_std_edge_cases() {
        let mk = |scores: Vec<f64>| SuiteResult {
            suite: suites::SUITES[0].name,
            weight: 1.0,
            sample_scores: scores,
            n_questions: 4,
        };
        // Single-pass suites report no spread.
        let single = mk(vec![70.0]);
        assert_eq!(single.mean(), 70.0);
        assert_eq!(single.std(), None);
        // Degenerate empty score list: mean 0, no spread (not NaN).
        let empty = mk(vec![]);
        assert_eq!(empty.mean(), 0.0);
        assert_eq!(empty.std(), None);
        // Two passes: population std.
        let two = mk(vec![40.0, 60.0]);
        assert_eq!(two.mean(), 50.0);
        assert!((two.std().unwrap() - 10.0).abs() < 1e-12);
        // Constant passes: zero std, Some(_) not None.
        let flat = mk(vec![55.0, 55.0, 55.0]);
        assert_eq!(flat.std(), Some(0.0));
    }

    #[test]
    fn eval_result_aggregation() {
        let mk = |name: &str, scores: Vec<f64>| SuiteResult {
            suite: suites::by_name(name).unwrap().name,
            weight: suites::by_name(name).unwrap().weight,
            sample_scores: scores,
            n_questions: 10,
        };
        let r = EvalResult {
            model: "m".into(),
            scheme: "s".into(),
            suites: vec![mk("AIME 2024", vec![50.0, 60.0]), mk("MMLU", vec![80.0])],
        };
        assert!((r.average() - 67.5).abs() < 1e-9);
        // Weighted: (0.2·55 + 1.0·80) / 1.2 = 75.833…
        assert!((r.weighted_average() - 75.8333333).abs() < 1e-5);
        let json = r.to_json();
        let back = EvalResult::from_json(&json).unwrap();
        assert!((back.weighted_average() - r.weighted_average()).abs() < 1e-12);
    }

    #[test]
    fn accuracy_drop_clamped_at_zero() {
        let mk = |score: f64| EvalResult {
            model: "m".into(),
            scheme: "s".into(),
            suites: vec![SuiteResult {
                suite: suites::SUITES[0].name,
                weight: 1.0,
                sample_scores: vec![score],
                n_questions: 1,
            }],
        };
        let reference = mk(80.0);
        assert!((mk(76.0).accuracy_drop_vs(&reference) - 5.0).abs() < 1e-9);
        assert_eq!(mk(85.0).accuracy_drop_vs(&reference), 0.0);
    }
}
