//! Benchmark suite registry — the paper's nine benchmarks with their
//! question counts, sampling protocol and weighted-average weights
//! (Table 8 / §4.2), mapped to our synthetic proxy generators.

/// Task family a suite draws from (determines generator + scorer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskFamily {
    /// Multi-step modular-arithmetic chains (AIME proxy).
    ArithChain,
    /// Two-step modular arithmetic (MATH proxy).
    Arith,
    /// 4-way multiple choice over a memorized relation KB.
    Knowledge,
    /// Sequence-transformation output prediction (MBPP proxy).
    Transform,
    /// Composed two-op transformations (LiveCodeBench proxy).
    TransformHard,
}

/// One benchmark suite.
#[derive(Debug, Clone)]
pub struct Suite {
    /// Paper benchmark this proxies.
    pub name: &'static str,
    pub family: TaskFamily,
    /// Question count in the paper (Table 8).
    pub paper_count: usize,
    /// Question count we run by default (scaled for CPU; `--full-size`
    /// restores `paper_count`).
    pub default_count: usize,
    /// Independent samples per question (§4.2: 8 for AIME, 4 for small
    /// suites, 1 for the large knowledge suites).
    pub samples: usize,
    /// Weight in the paper's weighted average (Table 8).
    pub weight: f64,
    /// Knowledge-domain id (disjoint relation spaces for MMLU/CMMLU/
    /// C-Eval/GPQA); 0 for non-knowledge suites.
    pub domain: u32,
}

/// The nine suites, in the paper's table row order.
pub const SUITES: &[Suite] = &[
    Suite { name: "AIME 2024", family: TaskFamily::ArithChain, paper_count: 30, default_count: 30, samples: 8, weight: 0.2, domain: 0 },
    Suite { name: "MATH 500", family: TaskFamily::Arith, paper_count: 500, default_count: 64, samples: 4, weight: 0.5, domain: 0 },
    Suite { name: "GPQA", family: TaskFamily::Knowledge, paper_count: 198, default_count: 64, samples: 4, weight: 0.5, domain: 1 },
    Suite { name: "MBPP", family: TaskFamily::Transform, paper_count: 378, default_count: 64, samples: 4, weight: 0.5, domain: 0 },
    Suite { name: "MBPP+", family: TaskFamily::Transform, paper_count: 378, default_count: 64, samples: 4, weight: 0.5, domain: 0 },
    Suite { name: "LiveCodeBench", family: TaskFamily::TransformHard, paper_count: 272, default_count: 64, samples: 4, weight: 0.5, domain: 0 },
    Suite { name: "MMLU", family: TaskFamily::Knowledge, paper_count: 14042, default_count: 160, samples: 1, weight: 1.0, domain: 2 },
    Suite { name: "CMMLU", family: TaskFamily::Knowledge, paper_count: 11582, default_count: 160, samples: 1, weight: 1.0, domain: 3 },
    Suite { name: "C-Eval", family: TaskFamily::Knowledge, paper_count: 12342, default_count: 160, samples: 1, weight: 1.0, domain: 4 },
];

/// MBPP and MBPP+ share questions (MBPP+ re-scores with stricter
/// checking); this index pairs them.
pub const MBPP_PLUS_INDEX: usize = 4;

pub fn by_name(name: &str) -> Option<&'static Suite> {
    SUITES.iter().find(|s| s.name.eq_ignore_ascii_case(name))
}

impl Suite {
    /// Question count under a given protocol scale.
    pub fn count(&self, full_size: bool) -> usize {
        if full_size {
            self.paper_count
        } else {
            self.default_count
        }
    }

    /// Stable substream id for the task generator (shared with Python).
    pub fn stream_id(&self) -> u64 {
        // FNV-1a over the name — mirrored in python/compile/tasks.py.
        let mut h: u64 = 0xcbf29ce484222325;
        for b in self.name.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_counts_and_weights_match_table8() {
        let expect: &[(&str, usize, f64)] = &[
            ("AIME 2024", 30, 0.2),
            ("MATH 500", 500, 0.5),
            ("GPQA", 198, 0.5),
            ("MBPP", 378, 0.5),
            ("MBPP+", 378, 0.5),
            ("LiveCodeBench", 272, 0.5),
            ("MMLU", 14042, 1.0),
            ("CMMLU", 11582, 1.0),
            ("C-Eval", 12342, 1.0),
        ];
        assert_eq!(SUITES.len(), expect.len());
        for (s, (name, count, weight)) in SUITES.iter().zip(expect) {
            assert_eq!(&s.name, name);
            assert_eq!(&s.paper_count, count);
            assert_eq!(&s.weight, weight);
        }
    }

    #[test]
    fn sampling_protocol_matches_section_4_2() {
        assert_eq!(by_name("AIME 2024").unwrap().samples, 8);
        assert_eq!(by_name("MATH 500").unwrap().samples, 4);
        assert_eq!(by_name("MMLU").unwrap().samples, 1);
    }

    #[test]
    fn knowledge_domains_disjoint() {
        let domains: Vec<u32> = SUITES
            .iter()
            .filter(|s| s.family == TaskFamily::Knowledge)
            .map(|s| s.domain)
            .collect();
        let mut d = domains.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), domains.len(), "domains must be disjoint");
    }

    #[test]
    fn stream_ids_stable_and_distinct() {
        let ids: Vec<u64> = SUITES.iter().map(|s| s.stream_id()).collect();
        let mut d = ids.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), ids.len());
        // Golden value pinned for the Python mirror.
        assert_eq!(by_name("MATH 500").unwrap().stream_id(), fnv("MATH 500"));
    }

    fn fnv(s: &str) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in s.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}
