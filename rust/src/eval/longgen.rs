//! Long-generation KV-scheme sweeps — the eval surface of the
//! quantized KV cache (ROADMAP item 5, PR 10).
//!
//! The paper's Table 1 shows weights stop dominating memory once
//! generations get long: at 32K context the KV cache is the marginal
//! byte. Related work (the Qwen3 and reasoning-model quantization
//! studies in PAPERS.md) finds that quantization failures surface
//! precisely on long chain-of-thought generations — short-prompt
//! accuracy hides drift that accumulates over hundreds of decoded
//! tokens. This module builds the corresponding measurement at proxy
//! scale: synthetic prompts decoded greedily out to a configurable
//! context length, swept over **weight scheme × KV scheme × context
//! length**, reporting
//!
//! - **token agreement** — the fraction of greedily decoded tokens
//!   matching the f32-KV baseline *with the same weight scheme*, so the
//!   column isolates KV-quantization damage from weight-quantization
//!   damage; and
//! - **an NLL perplexity proxy** — the mean negative log-likelihood the
//!   swept configuration assigns to the baseline's generated tail under
//!   teacher forcing (`exp` of it is a perplexity over the baseline
//!   trajectory). Unlike agreement this is smooth: it moves even when
//!   every argmax survives the perturbation.
//!
//! Greedy decoding keeps every cell deterministic (bit-stable across
//! threads/arms by the PR-3..PR-10 identity chain), so sweep output is
//! reproducible byte-for-byte and CI-diffable.

use crate::container::{quantize_container_with, synthetic_f32_container, Container};
use crate::coordinator::sampler::argmax;
use crate::eval::{suites, tasks};
use crate::model::ModelConfig;
use crate::quant::KvScheme;
use crate::runtime::forward::ForwardPass;
use crate::scheme::builtin;
use crate::util::json::{self, Value};
use anyhow::{bail, Result};

/// One sweep configuration (the CLI fills this from `dsq longgen`).
#[derive(Debug, Clone)]
pub struct LongGenConfig {
    /// Model to synthesize (`tiny-moe` / `tiny-dense`).
    pub model: String,
    /// Weight quantization schemes (container-level, e.g. `q4_k_m`).
    pub weight_schemes: Vec<String>,
    /// KV cache storage schemes to compare (baseline `F32` is always
    /// run — it anchors the agreement/NLL reference per weight scheme).
    pub kv_schemes: Vec<KvScheme>,
    /// Total context lengths (prompt + generation) to sweep.
    pub ctx_lens: Vec<usize>,
    /// Synthetic prompts averaged per cell.
    pub n_prompts: usize,
    /// Threads for container quantization (forward runs single-thread;
    /// logits are bit-identical at any count).
    pub threads: usize,
}

impl Default for LongGenConfig {
    fn default() -> Self {
        LongGenConfig {
            model: "tiny-moe".into(),
            weight_schemes: vec!["q4_k_m".into(), "dq3_k_m".into()],
            kv_schemes: vec![KvScheme::F32, KvScheme::Q8_0],
            ctx_lens: vec![16, 32, 48],
            n_prompts: 3,
            threads: 1,
        }
    }
}

/// One measured cell of the sweep.
#[derive(Debug, Clone)]
pub struct LongGenCell {
    pub weight_scheme: String,
    pub kv_scheme: KvScheme,
    pub ctx_len: usize,
    /// Greedy tokens generated per prompt (ctx − prompt length, summed).
    pub n_generated: usize,
    /// % of generated tokens agreeing with the f32-KV baseline at the
    /// same weight scheme (100.0 for the baseline itself).
    pub agreement_pct: f64,
    /// Mean NLL of the baseline's generated tail under this
    /// configuration (teacher-forced); `exp` = perplexity proxy.
    pub nll: f64,
    /// Engine-measured KV bytes per cached token under this scheme.
    pub kv_bytes_per_token: usize,
}

/// Greedy-decode from `prompt` until `total` tokens are cached,
/// returning the generated tail (panel prefill + token loop — the same
/// code paths serving uses).
fn greedy_tail(fwd: &ForwardPass, prompt: &[i32], total: usize) -> Result<Vec<i32>> {
    let mut cache = fwd.new_cache();
    let mut scratch = fwd.new_scratch();
    let mut logits = vec![0f32; fwd.vocab()];
    fwd.forward_tokens(prompt, &mut cache, &mut scratch, Some(&mut logits))?;
    let gen_len = total - prompt.len();
    let mut out = Vec::with_capacity(gen_len);
    for i in 0..gen_len {
        let tok = argmax(&logits);
        out.push(tok);
        if i + 1 < gen_len {
            fwd.forward_token(tok, &mut cache, &mut scratch, Some(&mut logits))?;
        }
    }
    Ok(out)
}

/// Numerically stable `log∑exp` over a logits row (f64 accumulation so
/// the proxy is insensitive to vocab ordering).
fn log_sum_exp(logits: &[f32]) -> f64 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    m + logits.iter().map(|&x| (x as f64 - m).exp()).sum::<f64>().ln()
}

/// Teacher-force `stream` through `fwd`, summing the NLL of each token
/// from `score_from` onward (position `i ≥ score_from` is scored by the
/// logits after forwarding `stream[i−1]`). Returns (total NLL, count).
fn forced_nll(fwd: &ForwardPass, stream: &[i32], score_from: usize) -> Result<(f64, usize)> {
    let mut cache = fwd.new_cache();
    let mut scratch = fwd.new_scratch();
    let mut logits = vec![0f32; fwd.vocab()];
    let mut nll = 0.0;
    let mut n = 0;
    for (i, &t) in stream.iter().enumerate() {
        fwd.forward_token(t, &mut cache, &mut scratch, Some(&mut logits))?;
        if i + 1 < stream.len() && i + 1 >= score_from {
            let next = stream[i + 1] as usize;
            nll += log_sum_exp(&logits) - logits[next] as f64;
            n += 1;
        }
    }
    Ok((nll, n))
}

/// Deterministic prompt mix: one question from each benchmark suite in
/// round-robin, truncated to leave room to generate.
fn sweep_prompts(n: usize, max_prompt: usize) -> Vec<Vec<i32>> {
    (0..n as u64)
        .map(|i| {
            let suite = &suites::SUITES[(i % suites::SUITES.len() as u64) as usize];
            let mut p = tasks::eval_question(suite, i).prompt;
            p.truncate(max_prompt);
            p
        })
        .collect()
}

/// Run the full sweep: for every (weight scheme, context length) an
/// f32-KV baseline trajectory is generated first, then every requested
/// KV scheme is measured against it.
pub fn run_sweep(cfg: &LongGenConfig) -> Result<Vec<LongGenCell>> {
    let model = ModelConfig::by_name(&cfg.model)?;
    let min_ctx = *cfg.ctx_lens.iter().min().unwrap_or(&0);
    if min_ctx < 2 {
        bail!("context lengths must be ≥ 2 (got {:?})", cfg.ctx_lens);
    }
    let src = synthetic_f32_container(&model, 0x601D)?;
    let mut cells = Vec::new();
    for ws in &cfg.weight_schemes {
        let qbytes = if ws == "f32" {
            src.to_bytes()
        } else {
            quantize_container_with(&src, &builtin::scheme(ws)?, None, cfg.threads)?.to_bytes()
        };
        let build = |kv: KvScheme, max_ctx: usize| -> Result<ForwardPass> {
            let mut fwd = ForwardPass::new(Container::from_bytes(qbytes.clone())?, 1, max_ctx)?;
            fwd.set_kv_scheme(kv)?;
            Ok(fwd)
        };
        for &ctx in &cfg.ctx_lens {
            // Prompts leave at least half the context to generate into.
            let prompts = sweep_prompts(cfg.n_prompts, ctx / 2);
            let baseline = build(KvScheme::F32, ctx)?;
            let refs: Vec<Vec<i32>> = prompts
                .iter()
                .map(|p| {
                    let tail = greedy_tail(&baseline, p, ctx)?;
                    let mut s = p.clone();
                    s.extend_from_slice(&tail);
                    Ok(s)
                })
                .collect::<Result<_>>()?;
            for &kv in &cfg.kv_schemes {
                let fwd = build(kv, ctx)?;
                let mut agree = 0usize;
                let mut total = 0usize;
                let mut nll_sum = 0.0;
                let mut nll_n = 0usize;
                for (p, r) in prompts.iter().zip(&refs) {
                    let tail = greedy_tail(&fwd, p, ctx)?;
                    let ref_tail = &r[p.len()..];
                    agree += tail.iter().zip(ref_tail).filter(|(a, b)| a == b).count();
                    total += tail.len();
                    let (s, n) = forced_nll(&fwd, r, p.len())?;
                    nll_sum += s;
                    nll_n += n;
                }
                cells.push(LongGenCell {
                    weight_scheme: ws.clone(),
                    kv_scheme: kv,
                    ctx_len: ctx,
                    n_generated: total,
                    agreement_pct: agree as f64 / total.max(1) as f64 * 100.0,
                    nll: nll_sum / nll_n.max(1) as f64,
                    kv_bytes_per_token: fwd.new_cache().bytes_per_token(),
                });
            }
        }
    }
    Ok(cells)
}

/// Render the sweep as a `dsq table`-style text report.
pub fn render(model: &str, cells: &[LongGenCell]) -> String {
    let mut out = format!(
        "# long-generation KV sweep: {model} (greedy decode, agreement/NLL vs f32-KV \
         baseline at the same weight scheme)\n\
         {:<6} {:<10} {:<6} {:>6} {:>8} {:>9} {:>10} {:>9}\n",
        "ctx", "weights", "kv", "gen", "agree%", "nll", "ppl-proxy", "kv B/tok"
    );
    for c in cells {
        out.push_str(&format!(
            "{:<6} {:<10} {:<6} {:>6} {:>8.1} {:>9.4} {:>10.3} {:>9}\n",
            c.ctx_len,
            c.weight_scheme,
            c.kv_scheme.name(),
            c.n_generated,
            c.agreement_pct,
            c.nll,
            c.nll.exp(),
            c.kv_bytes_per_token
        ));
    }
    out
}

/// JSON form (one object per cell) for `--out` / CI artifacts.
pub fn to_json(model: &str, cells: &[LongGenCell]) -> Value {
    json::obj(vec![
        ("bench", json::str_("longgen_kv_sweep")),
        ("model", json::str_(model)),
        (
            "cells",
            json::arr(
                cells
                    .iter()
                    .map(|c| {
                        json::obj(vec![
                            ("weight_scheme", json::str_(&c.weight_scheme)),
                            ("kv_scheme", json::str_(c.kv_scheme.name())),
                            ("ctx_len", json::num(c.ctx_len as f64)),
                            ("n_generated", json::num(c.n_generated as f64)),
                            ("agreement_pct", json::num(c.agreement_pct)),
                            ("nll", json::num(c.nll)),
                            ("kv_bytes_per_token", json::num(c.kv_bytes_per_token as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal sweep must anchor its own baseline: the f32-KV cell
    /// agrees 100% with itself, q8_0 stays within a loose agreement
    /// band, and the reported per-token footprint shrinks ≥3×.
    #[test]
    fn tiny_sweep_baseline_and_q8() {
        let cfg = LongGenConfig {
            model: "tiny-moe".into(),
            weight_schemes: vec!["q4_k_m".into()],
            kv_schemes: vec![KvScheme::F32, KvScheme::Q8_0],
            ctx_lens: vec![12],
            n_prompts: 2,
            threads: 1,
        };
        let cells = run_sweep(&cfg).unwrap();
        assert_eq!(cells.len(), 2);
        let f = cells.iter().find(|c| c.kv_scheme == KvScheme::F32).unwrap();
        let q = cells.iter().find(|c| c.kv_scheme == KvScheme::Q8_0).unwrap();
        assert_eq!(f.agreement_pct, 100.0, "baseline must agree with itself");
        assert!(f.nll.is_finite() && q.nll.is_finite());
        assert!(q.agreement_pct >= 0.0 && q.agreement_pct <= 100.0);
        assert!(q.kv_bytes_per_token * 3 <= f.kv_bytes_per_token, "≥3× KV saving");
        assert!(f.n_generated > 0 && q.n_generated == f.n_generated);
        let text = render("tiny-moe", &cells);
        assert!(text.contains("q8_0"), "{text}");
        // Determinism: the whole sweep reruns bit-identically.
        let again = run_sweep(&cfg).unwrap();
        assert_eq!(again.len(), cells.len());
        for (a, b) in cells.iter().zip(&again) {
            assert_eq!(a.agreement_pct.to_bits(), b.agreement_pct.to_bits());
            assert_eq!(a.nll.to_bits(), b.nll.to_bits());
        }
    }

    #[test]
    fn rejects_degenerate_context() {
        let cfg = LongGenConfig { ctx_lens: vec![1], ..LongGenConfig::default() };
        assert!(run_sweep(&cfg).is_err());
    }
}
