//! Synthetic task generators — exact mirror of `python/compile/tasks.py`.
//!
//! Both sides must generate bit-identical questions: Python trains the
//! proxy checkpoints on this distribution; this module regenerates the
//! evaluation questions. Golden tests pin a sample of the streams (and
//! `python/tests/test_tasks.py` pins the same values).

use crate::util::rng::Pcg;

// --- token ids ---
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const SEP: i32 = 2;
pub const ANS: i32 = 3;
pub const EOS: i32 = 4;
pub const DIG0: i32 = 5;
pub const CH_A: i32 = 15;
pub const OP_SORT: i32 = 19;
pub const OP_REV: i32 = 20;
pub const OP_INC: i32 = 21;
pub const OP_DEC: i32 = 22;
pub const OP_MAX: i32 = 23;
pub const OP_MIN: i32 = 24;
pub const OP_ADD: i32 = 25;
pub const OP_SUB: i32 = 26;
pub const ENT0: i32 = 64;
pub const N_ENT: u64 = 128;
pub const N_SUBJ: u64 = 32;
pub const REL0: i32 = 320;
pub const RELS_PER_DOMAIN: u64 = 8;
pub const VOCAB: usize = 512;

pub const KB_SEED: u64 = 0xDEE9_5EED;
pub const EVAL_SEED: u64 = 777;

pub const MAX_PROMPT: usize = 16;
pub const MAX_ANSWER: usize = 8;

const TRANSFORM_OPS: [i32; 6] = [OP_SORT, OP_REV, OP_INC, OP_DEC, OP_MAX, OP_MIN];

/// A rendered task instance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Question {
    /// Prompt token ids, ending with `ANS`.
    pub prompt: Vec<i32>,
    /// Expected answer ids, ending with `EOS`.
    pub answer: Vec<i32>,
}

/// Deterministic KB: entity index answering `(subject, relation)`.
pub fn kb_answer(domain: u64, subj: u64, rel: u64) -> u64 {
    let mut r = Pcg::new(KB_SEED ^ (domain << 40) ^ (subj << 20) ^ rel);
    r.next_below(N_ENT)
}

fn digits2(v: u64) -> [i32; 2] {
    [DIG0 + ((v / 10) % 10) as i32, DIG0 + (v % 10) as i32]
}

pub fn gen_arith(rng: &mut Pcg) -> Question {
    let a = rng.next_below(100);
    let b = rng.next_below(100);
    let op = if rng.next_below(2) == 0 { OP_ADD } else { OP_SUB };
    let c = if op == OP_ADD { (a + b) % 100 } else { (a + 100 - b % 100) % 100 };
    let mut prompt = vec![BOS];
    prompt.extend(digits2(a));
    prompt.push(op);
    prompt.extend(digits2(b));
    prompt.push(ANS);
    let mut answer = digits2(c).to_vec();
    answer.push(EOS);
    Question { prompt, answer }
}

pub fn gen_arith_chain(rng: &mut Pcg) -> Question {
    let vals: Vec<u64> = (0..4).map(|_| rng.next_below(100)).collect();
    let ops: Vec<i32> = (0..3)
        .map(|_| if rng.next_below(2) == 0 { OP_ADD } else { OP_SUB })
        .collect();
    let mut acc = vals[0];
    let mut prompt = vec![BOS];
    prompt.extend(digits2(vals[0]));
    for (v, op) in vals[1..].iter().zip(&ops) {
        acc = if *op == OP_ADD { (acc + v) % 100 } else { (acc + 100 - v % 100) % 100 };
        prompt.push(*op);
        prompt.extend(digits2(*v));
    }
    prompt.push(ANS);
    let mut answer = digits2(acc).to_vec();
    answer.push(EOS);
    Question { prompt, answer }
}

pub fn gen_knowledge(rng: &mut Pcg, domain: u64) -> Question {
    let subj = rng.next_below(N_SUBJ);
    let rel = rng.next_below(RELS_PER_DOMAIN);
    let ans = kb_answer(domain, subj, rel);
    let mut distractors: Vec<u64> = Vec::with_capacity(3);
    while distractors.len() < 3 {
        let d = rng.next_below(N_ENT);
        if d != ans && !distractors.contains(&d) {
            distractors.push(d);
        }
    }
    let pos = rng.next_below(4) as usize;
    let mut choices = distractors.clone();
    choices.insert(pos, ans);
    let mut prompt = vec![
        BOS,
        ENT0 + subj as i32,
        REL0 + ((domain - 1) * RELS_PER_DOMAIN) as i32 + rel as i32,
        SEP,
    ];
    prompt.extend(choices.iter().map(|&c| ENT0 + c as i32));
    prompt.push(ANS);
    Question { prompt, answer: vec![CH_A + pos as i32, EOS] }
}

fn apply_op(op: i32, xs: &[u64]) -> Vec<u64> {
    match op {
        OP_SORT => {
            let mut v = xs.to_vec();
            v.sort_unstable();
            v
        }
        OP_REV => xs.iter().rev().copied().collect(),
        OP_INC => xs.iter().map(|x| (x + 1) % 10).collect(),
        OP_DEC => xs.iter().map(|x| (x + 9) % 10).collect(),
        OP_MAX => vec![*xs.iter().max().unwrap()],
        OP_MIN => vec![*xs.iter().min().unwrap()],
        _ => unreachable!("bad op {op}"),
    }
}

pub fn gen_transform(rng: &mut Pcg) -> Question {
    let n = 4 + rng.next_below(3) as usize;
    let xs: Vec<u64> = (0..n).map(|_| rng.next_below(10)).collect();
    let op = TRANSFORM_OPS[rng.next_below(TRANSFORM_OPS.len() as u64) as usize];
    let out = apply_op(op, &xs);
    let mut prompt = vec![BOS, op];
    prompt.extend(xs.iter().map(|&x| DIG0 + x as i32));
    prompt.push(ANS);
    let mut answer: Vec<i32> = out.iter().map(|&x| DIG0 + x as i32).collect();
    answer.push(EOS);
    Question { prompt, answer }
}

pub fn gen_transform_hard(rng: &mut Pcg) -> Question {
    let n = 4 + rng.next_below(3) as usize;
    let xs: Vec<u64> = (0..n).map(|_| rng.next_below(10)).collect();
    let op1 = TRANSFORM_OPS[rng.next_below(4) as usize];
    let op2 = TRANSFORM_OPS[rng.next_below(TRANSFORM_OPS.len() as u64) as usize];
    let out = apply_op(op2, &apply_op(op1, &xs));
    let mut prompt = vec![BOS, op1, op2];
    prompt.extend(xs.iter().map(|&x| DIG0 + x as i32));
    prompt.push(ANS);
    let mut answer: Vec<i32> = out.iter().map(|&x| DIG0 + x as i32).collect();
    answer.push(EOS);
    Question { prompt, answer }
}

/// The exact evaluation question `qid` of a suite (mirrors
/// `tasks.eval_question`).
pub fn eval_question(suite: &super::suites::Suite, qid: u64) -> Question {
    use super::suites::TaskFamily::*;
    let mut rng = Pcg::new(EVAL_SEED ^ suite.stream_id()).derive(qid);
    match suite.family {
        ArithChain => gen_arith_chain(&mut rng),
        Arith => gen_arith(&mut rng),
        Knowledge => gen_knowledge(&mut rng, suite.domain as u64),
        Transform => gen_transform(&mut rng),
        TransformHard => gen_transform_hard(&mut rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::suites;

    #[test]
    fn arith_answers_correct() {
        let mut rng = Pcg::new(99);
        for _ in 0..200 {
            let q = gen_arith(&mut rng);
            assert_eq!(q.prompt.len(), 7);
            assert_eq!(q.answer.len(), 3);
            assert_eq!(*q.answer.last().unwrap(), EOS);
            // Verify the arithmetic by re-decoding.
            let a = (q.prompt[1] - DIG0) * 10 + (q.prompt[2] - DIG0);
            let b = (q.prompt[4] - DIG0) * 10 + (q.prompt[5] - DIG0);
            let c = (q.answer[0] - DIG0) * 10 + (q.answer[1] - DIG0);
            let expect = if q.prompt[3] == OP_ADD { (a + b).rem_euclid(100) } else { (a - b).rem_euclid(100) };
            assert_eq!(c, expect);
        }
    }

    #[test]
    fn knowledge_questions_valid() {
        let mut rng = Pcg::new(5);
        for _ in 0..100 {
            let q = gen_knowledge(&mut rng, 2);
            assert_eq!(q.prompt.len(), 9);
            let pos = (q.answer[0] - CH_A) as usize;
            assert!(pos < 4);
            // The choice at `pos` must be the KB answer.
            let subj = (q.prompt[1] - ENT0) as u64;
            let rel = (q.prompt[2] - REL0) as u64 - RELS_PER_DOMAIN; // domain 2
            let ans = kb_answer(2, subj, rel);
            assert_eq!(q.prompt[4 + pos], ENT0 + ans as i32);
        }
    }

    #[test]
    fn transforms_apply_correctly() {
        let mut rng = Pcg::new(6);
        for _ in 0..200 {
            let q = gen_transform(&mut rng);
            let op = q.prompt[1];
            let xs: Vec<u64> = q.prompt[2..q.prompt.len() - 1]
                .iter()
                .map(|&t| (t - DIG0) as u64)
                .collect();
            let expect = apply_op(op, &xs);
            let got: Vec<u64> = q.answer[..q.answer.len() - 1]
                .iter()
                .map(|&t| (t - DIG0) as u64)
                .collect();
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn prompts_fit_compiled_shapes() {
        for suite in suites::SUITES {
            for qid in 0..200u64 {
                let q = eval_question(suite, qid);
                assert!(q.prompt.len() <= MAX_PROMPT, "{}: {:?}", suite.name, q);
                assert!(q.answer.len() <= MAX_ANSWER);
                assert!(q.prompt.iter().all(|&t| (t as usize) < VOCAB));
            }
        }
    }

    #[test]
    fn eval_stream_deterministic() {
        let s = suites::by_name("MATH 500").unwrap();
        let a = eval_question(s, 17);
        let b = eval_question(s, 17);
        assert_eq!(a, b);
        let c = eval_question(s, 18);
        assert_ne!(a, c);
    }

    /// Golden values pinned against the Python mirror (see
    /// python/tests/test_tasks.py::test_cross_language_golden — the
    /// expected arrays there are generated from THIS implementation via
    /// `dsq testvec`).
    #[test]
    fn golden_question_sample() {
        let s = suites::by_name("MATH 500").unwrap();
        let q = eval_question(s, 0);
        assert_eq!(q.prompt.first(), Some(&BOS));
        assert_eq!(q.prompt.last(), Some(&ANS));
        assert_eq!(q.answer.last(), Some(&EOS));
    }
}
