//! `Q3_K` — 3-bit k-quant, super-block of 256, 110 bytes (3.4375 bpw).
//!
//! 16 sub-blocks of 16 weights. Symmetric:
//! `x_i = d · (sc[j] − 32) · (c_i − 4)` with codes `c_i ∈ [0, 7]` and
//! 6-bit stored sub-block scales `sc[j] ∈ [0, 63]` (offset-32 signed).
//!
//! Layout per super-block (flat element order, sub-block `j = i / 16`):
//! ```text
//! [0..12)    packed 6-bit scales (see [`pack_scales_6x16`])
//! [12..44)   hmask[32]  high bit of c_i: bit (i&7) of hmask[i>>3]
//! [44..108)  qs[64]     low 2 bits of c_i: bits 2·(i&3) of qs[i>>2]
//! [108..110) f16 d
//! ```
//!
//! ### 6-bit scale packing (16 values → 12 bytes)
//!
//! - byte `j` (j<8): low nibble = `sc[j] & 0xF`, high nibble = `sc[8+j] & 0xF`
//! - byte `8+k` (k<4): two high bits of `sc[4k .. 4k+4)` at bit `2·t`
//!
//! i.e. `sc[j] = ((j<8 ? b[j]&0xF : b[j-8]>>4)) | ((b[8 + j%4] >> (2·(j/4))) & 3) << 4`.
//!
//! (Note: high-bit byte index is `j % 4`, shift group is `j / 4`, which
//! keeps the unpack a pure gather in the JAX mirror.)
//!
//! Decode arms: scalar (this module) and lane-chunked; inside the
//! `simd` dispatch arm the lane decoder is reused with the intrinsic
//! accumulator (see the arm matrix in [`super`]).

use super::scalar::{get_f16, make_qx_quants, nearest_int, put_f16};
use super::QK_K;

pub const BLOCK_BYTES: usize = 110;
const SUB: usize = 16;
const NSUB: usize = QK_K / SUB;

/// Pack 16 six-bit values into 12 bytes.
pub fn pack_scales_6x16(sc: &[u8; NSUB], out: &mut [u8]) {
    debug_assert!(out.len() >= 12);
    for j in 0..8 {
        out[j] = (sc[j] & 0x0F) | ((sc[8 + j] & 0x0F) << 4);
    }
    for k in 0..4 {
        let mut b = 0u8;
        for t in 0..4 {
            b |= ((sc[4 * t + k] >> 4) & 0x03) << (2 * t);
        }
        out[8 + k] = b;
    }
}

/// Inverse of [`pack_scales_6x16`] for sub-block `j`.
pub fn unpack_scales_6x16(b: &[u8], j: usize) -> u8 {
    let lo = if j < 8 { b[j] & 0x0F } else { b[j - 8] >> 4 };
    let hi = (b[8 + (j % 4)] >> (2 * (j / 4))) & 0x03;
    lo | (hi << 4)
}

pub fn quantize(src: &[f32], importance: Option<&[f32]>, out: &mut [u8]) {
    debug_assert_eq!(src.len() % QK_K, 0);
    for (bi, (xb, ob)) in src
        .chunks_exact(QK_K)
        .zip(out.chunks_exact_mut(BLOCK_BYTES))
        .enumerate()
    {
        let wb = importance.map(|w| &w[bi * QK_K..(bi + 1) * QK_K]);
        let mut scales = [0f32; NSUB];
        let mut codes = [0u8; QK_K];
        let mut max_abs_scale = 0f32;
        for j in 0..NSUB {
            let xs = &xb[j * SUB..(j + 1) * SUB];
            let ws = wb.map(|w| &w[j * SUB..(j + 1) * SUB]);
            scales[j] = make_qx_quants(xs, 4, ws, &mut codes[j * SUB..(j + 1) * SUB]);
            max_abs_scale = max_abs_scale.max(scales[j].abs());
        }
        if max_abs_scale < 1e-30 {
            ob.fill(0);
            // All-zero block: sc=32 (0 after offset) reconstructs zeros,
            // but sc bytes of 0 give sc-32=-32 times c-4 — ensure codes
            // decode to 4 (0) by writing the midpoint code plane.
            let mut sc = [32u8; NSUB];
            sc.iter_mut().for_each(|s| *s = 32);
            pack_scales_6x16(&sc, &mut ob[0..12]);
            pack_codes(&[4u8; QK_K], ob);
            continue;
        }
        let d = max_abs_scale / 31.0;
        put_f16(ob, 108, d);
        let d = get_f16(ob, 108);
        let invd = if d > 0.0 { 1.0 / d } else { 0.0 };
        let mut sc6 = [0u8; NSUB];
        for j in 0..NSUB {
            let isc = nearest_int(scales[j] * invd).clamp(-32, 31);
            sc6[j] = (isc + 32) as u8;
            let sd = d * isc as f32;
            let inv = if sd != 0.0 { 1.0 / sd } else { 0.0 };
            for k in 0..SUB {
                let i = j * SUB + k;
                codes[i] = if sd != 0.0 {
                    (nearest_int(xb[i] * inv).clamp(-4, 3) + 4) as u8
                } else {
                    4
                };
            }
        }
        pack_scales_6x16(&sc6, &mut ob[0..12]);
        pack_codes(&codes, ob);
    }
}

fn pack_codes(codes: &[u8; QK_K], ob: &mut [u8]) {
    ob[12..108].fill(0);
    for (i, &c) in codes.iter().enumerate() {
        let lo = c & 0x03;
        let hi = (c >> 2) & 0x01;
        ob[44 + (i >> 2)] |= lo << (2 * (i & 3));
        ob[12 + (i >> 3)] |= hi << (i & 7);
    }
}

pub fn dequantize(bytes: &[u8], out: &mut [f32]) {
    for (ob, xb) in bytes.chunks_exact(BLOCK_BYTES).zip(out.chunks_exact_mut(QK_K)) {
        let d = get_f16(ob, 108);
        for i in 0..QK_K {
            let j = i / SUB;
            let sc = unpack_scales_6x16(&ob[0..12], j) as i32 - 32;
            let lo = (ob[44 + (i >> 2)] >> (2 * (i & 3))) & 0x03;
            let hi = (ob[12 + (i >> 3)] >> (i & 7)) & 0x01;
            let c = (lo | (hi << 2)) as i32;
            xb[i] = d * sc as f32 * (c - 4) as f32;
        }
    }
}

crate::quant::impl_block_codec!(crate::quant::QuantFormat::Q3K);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::error::rel_rmse;
    use crate::quant::{roundtrip, QuantFormat};
    use crate::util::rng::Pcg;

    #[test]
    fn scales_packing_roundtrips() {
        let mut rng = Pcg::new(29);
        for _ in 0..100 {
            let mut sc = [0u8; NSUB];
            for s in sc.iter_mut() {
                *s = (rng.next_u64() % 64) as u8;
            }
            let mut buf = [0u8; 12];
            pack_scales_6x16(&sc, &mut buf);
            for j in 0..NSUB {
                assert_eq!(unpack_scales_6x16(&buf, j), sc[j], "sub-block {j}");
            }
        }
    }

    #[test]
    fn q3k_accuracy_on_gaussian() {
        let mut rng = Pcg::new(31);
        let src: Vec<f32> = (0..QK_K * 4).map(|_| rng.next_normal()).collect();
        let rt = roundtrip(QuantFormat::Q3K, &src, None).unwrap();
        let err = rel_rmse(&src, &rt);
        assert!(err < 0.17, "q3_k rel rmse too high: {err}");
    }

    #[test]
    fn q3k_zero_block() {
        let src = vec![0f32; QK_K];
        let rt = roundtrip(QuantFormat::Q3K, &src, None).unwrap();
        assert_eq!(rt, src);
    }

    #[test]
    fn q3k_decode_kernel_and_vec_dot_bit_identical() {
        crate::quant::kernels::assert_decode_and_vec_dot_identity(
            crate::quant::QuantFormat::Q3K,
            0x3D,
        );
    }

    #[test]
    fn monotone_error_q3_worse_than_q4() {
        let mut rng = Pcg::new(37);
        let src: Vec<f32> = (0..QK_K * 8).map(|_| rng.next_normal()).collect();
        let e3 = rel_rmse(&src, &roundtrip(QuantFormat::Q3K, &src, None).unwrap());
        let e4 = rel_rmse(&src, &roundtrip(QuantFormat::Q4K, &src, None).unwrap());
        assert!(e3 > e4, "q3_k ({e3}) should be worse than q4_k ({e4})");
    }
}
