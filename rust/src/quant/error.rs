//! Reconstruction-error metrics used by the codec error sweep (E10) and
//! by the calibration/sensitivity analysis.

/// Mean squared error.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Root-mean-square error relative to the RMS of the reference signal.
/// This is the scale-free quantity the bpw↔error curve (E10) plots.
pub fn rel_rmse(reference: &[f32], approx: &[f32]) -> f64 {
    let ms_ref = reference.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
        / reference.len().max(1) as f64;
    if ms_ref == 0.0 {
        return if mse(reference, approx) == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (mse(reference, approx) / ms_ref).sqrt()
}

/// Maximum absolute error.
pub fn max_abs_err(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| ((*x - *y) as f64).abs())
        .fold(0.0, f64::max)
}

/// Cosine similarity (used for logit-level comparisons).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
    let na: f64 = a.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        return if na == nb { 1.0 } else { 0.0 };
    }
    dot / (na * nb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_zero_for_identical() {
        let a = [1.0f32, 2.0, 3.0];
        assert_eq!(mse(&a, &a), 0.0);
        assert_eq!(rel_rmse(&a, &a), 0.0);
        assert_eq!(max_abs_err(&a, &a), 0.0);
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rel_rmse_scale_free() {
        let a = [1.0f32, -1.0, 1.0, -1.0];
        let b = [1.1f32, -1.1, 1.1, -1.1];
        let a10: Vec<f32> = a.iter().map(|x| x * 10.0).collect();
        let b10: Vec<f32> = b.iter().map(|x| x * 10.0).collect();
        assert!((rel_rmse(&a, &b) - rel_rmse(&a10, &b10)).abs() < 1e-6);
    }

    #[test]
    fn cosine_orthogonal() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!(cosine(&a, &b).abs() < 1e-12);
    }
}
