//! `F32` / `F16` pass-through codecs (block size 1).
//!
//! These exist so the raw formats ride the same [`BlockCodec`] registry,
//! buffer contract, and block-parallel splitting as the k-quants —
//! `quantize_into(F32, …)` on a large tensor is a parallel `memcpy`-like
//! transpose into little-endian bytes, and `F16` is a parallel
//! round-to-half.

use super::{BlockCodec, QuantFormat};
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};

/// Identity codec: 4 little-endian bytes per weight.
pub struct F32Codec;

impl BlockCodec for F32Codec {
    fn format(&self) -> QuantFormat {
        QuantFormat::F32
    }

    fn encode_block(&self, src: &[f32], _importance: Option<&[f32]>, out: &mut [u8]) {
        out.copy_from_slice(&src[0].to_le_bytes());
    }

    fn decode_block(&self, bytes: &[u8], out: &mut [f32]) {
        out[0] = f32::from_le_bytes(bytes.try_into().unwrap());
    }

    fn encode_blocks(&self, src: &[f32], _importance: Option<&[f32]>, out: &mut [u8]) {
        for (o, v) in out.chunks_exact_mut(4).zip(src) {
            o.copy_from_slice(&v.to_le_bytes());
        }
    }

    fn decode_blocks(&self, bytes: &[u8], out: &mut [f32]) {
        for (o, b) in out.iter_mut().zip(bytes.chunks_exact(4)) {
            *o = f32::from_le_bytes(b.try_into().unwrap());
        }
    }

    fn vec_dot(&self, bytes: &[u8], x: &[f32]) -> f32 {
        super::kernels::vec_dot_f32(bytes, x)
    }
}

/// IEEE half-precision codec: 2 little-endian bytes per weight.
pub struct F16Codec;

impl BlockCodec for F16Codec {
    fn format(&self) -> QuantFormat {
        QuantFormat::F16
    }

    fn encode_block(&self, src: &[f32], _importance: Option<&[f32]>, out: &mut [u8]) {
        out.copy_from_slice(&f32_to_f16_bits(src[0]).to_le_bytes());
    }

    fn decode_block(&self, bytes: &[u8], out: &mut [f32]) {
        out[0] = f16_bits_to_f32(u16::from_le_bytes(bytes.try_into().unwrap()));
    }

    fn encode_blocks(&self, src: &[f32], _importance: Option<&[f32]>, out: &mut [u8]) {
        for (o, v) in out.chunks_exact_mut(2).zip(src) {
            o.copy_from_slice(&f32_to_f16_bits(*v).to_le_bytes());
        }
    }

    fn decode_blocks(&self, bytes: &[u8], out: &mut [f32]) {
        for (o, b) in out.iter_mut().zip(bytes.chunks_exact(2)) {
            *o = f16_bits_to_f32(u16::from_le_bytes(b.try_into().unwrap()));
        }
    }

    fn vec_dot(&self, bytes: &[u8], x: &[f32]) -> f32 {
        super::kernels::vec_dot_f16(bytes, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_block_roundtrip_exact() {
        let c = F32Codec;
        let src = [-1234.5678f32];
        let mut bytes = [0u8; 4];
        c.encode_block(&src, None, &mut bytes);
        let mut back = [0f32; 1];
        c.decode_block(&bytes, &mut back);
        assert_eq!(back, src);
    }

    #[test]
    fn f16_block_roundtrip_halves() {
        let c = F16Codec;
        // Exactly representable halves survive the trip bit-for-bit.
        for v in [0.0f32, 1.0, -2.5, 0.625, 65504.0] {
            let mut bytes = [0u8; 2];
            c.encode_block(&[v], None, &mut bytes);
            let mut back = [0f32; 1];
            c.decode_block(&bytes, &mut back);
            assert_eq!(back[0], v, "{v}");
        }
    }
}
