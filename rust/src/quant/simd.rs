//! Lane-chunked scale-search kernels.
//!
//! The inner loops of [`super::scalar::make_qx_quants`] /
//! [`super::scalar::make_qkx_quants`] dominate encode time (they run
//! once per candidate scale per 16/32-weight sub-block). This module
//! holds the **explicitly vectorizable** versions: the input is walked
//! in fixed chunks of [`LANES`] elements, partial sums live in
//! `[f32; LANES]` arrays, and the per-element math is branch-free
//! (`round` + float `max`/`min` clamps), which lets the autovectorizer
//! lower the chunk body to SIMD in release builds.
//!
//! ## The byte-identity contract
//!
//! The scalar reference in [`super::scalar`] computes the *same* sums in
//! the *same* order: element `i` accumulates into lane `i % LANES`, each
//! lane is a sequential f32 sum, and the horizontal reduction is the
//! shared `hsum` fold. Because f32 addition order is fixed and Rust
//! never contracts `a*b + c` into an FMA implicitly, the lane kernels
//! and the reference produce bit-identical sums — and therefore
//! bit-identical codec output. `tests` below and
//! `tests/golden_vectors.rs` assert this; CI additionally runs the
//! golden suite with `DSQ_SCALAR_SEARCH=1` to pin both dispatch arms to
//! the same fixtures.
//!
//! The decode side goes one arm further: [`super::kernels`] adds
//! hand-written AVX2/NEON intrinsic arms on top of the lane kernels
//! (selected by `DSQ_FORCE_ARM`), all bound to the same `LANES`-wide
//! reduction order — see the arm matrix in the [`super`] module docs.

use std::sync::OnceLock;

/// Accumulator width. Eight f32 lanes = one AVX register / two NEON
/// registers; wide enough to hide the add latency, small enough that
/// the five-accumulator `qkx` kernel still fits the register file.
/// The decode-side kernels ([`super::kernels`]) share this width and
/// the `hsum` fold, so encode search and fused `vec_dot` follow one
/// reduction-order contract.
pub const LANES: usize = 8;

/// Whether the lane kernels are active. Default on; set
/// `DSQ_SCALAR_SEARCH=1` to force the scalar reference (the two paths
/// are byte-identical — the switch exists for benchmarking and for
/// pinning CI drift tests to either arm). Read once per process.
pub fn lanes_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        !matches!(
            std::env::var("DSQ_SCALAR_SEARCH").as_deref(),
            Ok("1") | Ok("true") | Ok("yes")
        )
    })
}

/// Round to nearest (ties away from zero) and clamp to `[lo, hi]`, in
/// float domain. Shared by the lane kernels, the scalar reference and
/// the final code-emission passes so every path rounds identically.
#[inline(always)]
pub(crate) fn qround(v: f32, lo: f32, hi: f32) -> f32 {
    v.round().max(lo).min(hi)
}

/// Horizontal sum of a lane accumulator — a fixed sequential fold, so
/// every caller reduces in the same order.
#[inline(always)]
pub(crate) fn hsum(acc: &[f32; LANES]) -> f32 {
    let mut s = 0.0f32;
    for &v in acc.iter() {
        s += v;
    }
    s
}

/// Weighted sums for one symmetric candidate scale:
/// `(Σ w·x·q, Σ w·q²)` with `q = qround(iscale·x, lo, hi)` and
/// `w = x² + 1e-8` (no imatrix) or `w_i + 1e-10`.
#[inline]
pub(crate) fn qx_sums(
    x: &[f32],
    weights: Option<&[f32]>,
    iscale: f32,
    lo: f32,
    hi: f32,
) -> (f32, f32) {
    let mut sumlx = [0.0f32; LANES];
    let mut suml2 = [0.0f32; LANES];
    let head = x.len() / LANES * LANES;
    match weights {
        None => {
            for c in x[..head].chunks_exact(LANES) {
                for l in 0..LANES {
                    let xv = c[l];
                    let q = qround(iscale * xv, lo, hi);
                    let w = xv * xv + 1e-8;
                    sumlx[l] += w * xv * q;
                    suml2[l] += w * q * q;
                }
            }
            for (l, &xv) in x[head..].iter().enumerate() {
                let q = qround(iscale * xv, lo, hi);
                let w = xv * xv + 1e-8;
                sumlx[l] += w * xv * q;
                suml2[l] += w * q * q;
            }
        }
        Some(ws) => {
            for (c, wc) in x[..head]
                .chunks_exact(LANES)
                .zip(ws[..head].chunks_exact(LANES))
            {
                for l in 0..LANES {
                    let xv = c[l];
                    let q = qround(iscale * xv, lo, hi);
                    let w = wc[l] + 1e-10;
                    sumlx[l] += w * xv * q;
                    suml2[l] += w * q * q;
                }
            }
            for (l, (&xv, &wv)) in x[head..].iter().zip(ws[head..].iter()).enumerate() {
                let q = qround(iscale * xv, lo, hi);
                let w = wv + 1e-10;
                sumlx[l] += w * xv * q;
                suml2[l] += w * q * q;
            }
        }
    }
    (hsum(&sumlx), hsum(&suml2))
}

/// The five weighted sums the asymmetric (scale, min) least-squares fit
/// needs, gathered in one pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct QkxSums {
    pub w: f32,
    pub x: f32,
    pub l: f32,
    pub l2: f32,
    pub xl: f32,
}

/// One-pass weighted sums for one asymmetric candidate scale:
/// `q = qround(iscale·(x − vmin), 0, nmax)`.
#[inline]
pub(crate) fn qkx_sums(
    x: &[f32],
    weights: Option<&[f32]>,
    iscale: f32,
    vmin: f32,
    hi: f32,
) -> QkxSums {
    let mut sw = [0.0f32; LANES];
    let mut sx = [0.0f32; LANES];
    let mut sl = [0.0f32; LANES];
    let mut sl2 = [0.0f32; LANES];
    let mut sxl = [0.0f32; LANES];
    let head = x.len() / LANES * LANES;
    match weights {
        None => {
            for c in x[..head].chunks_exact(LANES) {
                for l in 0..LANES {
                    let xv = c[l];
                    let q = qround(iscale * (xv - vmin), 0.0, hi);
                    let w = xv * xv + 1e-8;
                    sw[l] += w;
                    sx[l] += w * xv;
                    sl[l] += w * q;
                    sl2[l] += w * q * q;
                    sxl[l] += w * xv * q;
                }
            }
            for (l, &xv) in x[head..].iter().enumerate() {
                let q = qround(iscale * (xv - vmin), 0.0, hi);
                let w = xv * xv + 1e-8;
                sw[l] += w;
                sx[l] += w * xv;
                sl[l] += w * q;
                sl2[l] += w * q * q;
                sxl[l] += w * xv * q;
            }
        }
        Some(ws) => {
            for (c, wc) in x[..head]
                .chunks_exact(LANES)
                .zip(ws[..head].chunks_exact(LANES))
            {
                for l in 0..LANES {
                    let xv = c[l];
                    let q = qround(iscale * (xv - vmin), 0.0, hi);
                    let w = wc[l] + 1e-10;
                    sw[l] += w;
                    sx[l] += w * xv;
                    sl[l] += w * q;
                    sl2[l] += w * q * q;
                    sxl[l] += w * xv * q;
                }
            }
            for (l, (&xv, &wv)) in x[head..].iter().zip(ws[head..].iter()).enumerate() {
                let q = qround(iscale * (xv - vmin), 0.0, hi);
                let w = wv + 1e-10;
                sw[l] += w;
                sx[l] += w * xv;
                sl[l] += w * q;
                sl2[l] += w * q * q;
                sxl[l] += w * xv * q;
            }
        }
    }
    QkxSums {
        w: hsum(&sw),
        x: hsum(&sx),
        l: hsum(&sl),
        l2: hsum(&sl2),
        xl: hsum(&sxl),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scalar::{qkx_sums_ref, qx_sums_ref};
    use crate::util::rng::Pcg;

    fn random_case(seed: u64, n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg::new(seed);
        let scale = 10f32.powi(rng.next_below(7) as i32 - 3);
        let mut x: Vec<f32> = (0..n).map(|_| rng.next_normal() * scale).collect();
        if n > 2 {
            x[0] = 0.0; // exact zero
            x[n / 2] = -x[n / 2].abs() * 3.0; // outlier
        }
        let w: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.05).collect();
        (x, w)
    }

    #[test]
    fn qx_sums_lanes_bit_identical_to_reference() {
        for seed in 0..200u64 {
            // Sizes straddle the lane width: remainders, exact
            // multiples, sub-lane inputs.
            for &n in &[1usize, 5, 8, 15, 16, 24, 32, 33] {
                let (x, w) = random_case(9100 + seed, n);
                for &nmax in &[4i32, 32] {
                    let (lo, hi) = (-(nmax as f32), (nmax - 1) as f32);
                    let iscale = -(nmax as f32 + 0.1 * (seed % 19) as f32 - 0.9)
                        / x.iter().fold(0.1f32, |a, &v| a.max(v.abs()));
                    for weights in [None, Some(w.as_slice())] {
                        let a = qx_sums(&x, weights, iscale, lo, hi);
                        let b = qx_sums_ref(&x, weights, iscale, lo, hi);
                        assert_eq!(
                            (a.0.to_bits(), a.1.to_bits()),
                            (b.0.to_bits(), b.1.to_bits()),
                            "seed {seed} n {n} nmax {nmax} im {}",
                            weights.is_some()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn qkx_sums_lanes_bit_identical_to_reference() {
        for seed in 0..200u64 {
            for &n in &[1usize, 5, 8, 15, 16, 24, 32, 33] {
                let (x, w) = random_case(9700 + seed, n);
                let vmin = x.iter().cloned().fold(f32::INFINITY, f32::min).min(0.0);
                let vmax = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                for &nmax in &[3i32, 15, 31] {
                    let iscale =
                        (0.1 * (seed % 14) as f32 - 0.5 + nmax as f32) / (vmax - vmin).max(1e-6);
                    for weights in [None, Some(w.as_slice())] {
                        let a = qkx_sums(&x, weights, iscale, vmin, nmax as f32);
                        let b = qkx_sums_ref(&x, weights, iscale, vmin, nmax as f32);
                        let bits = |s: &QkxSums| {
                            [
                                s.w.to_bits(),
                                s.x.to_bits(),
                                s.l.to_bits(),
                                s.l2.to_bits(),
                                s.xl.to_bits(),
                            ]
                        };
                        assert_eq!(
                            bits(&a),
                            bits(&b),
                            "seed {seed} n {n} nmax {nmax} im {}",
                            weights.is_some()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn qround_matches_int_rounding_path() {
        // The float clamp must agree with the historical
        // `nearest_int(v).clamp(lo, hi)` integer path on finite inputs
        // (up to the sign of zero: qround keeps -0.0, which the
        // accumulators and `as i32`/`as u8` casts treat as 0).
        let mut rng = Pcg::new(55);
        for _ in 0..10_000 {
            let v = (rng.next_f32() - 0.5) * 200.0;
            let got = qround(v, -32.0, 31.0);
            let want = (v.round() as i32).clamp(-32, 31) as f32;
            assert_eq!(got, want, "v={v}");
        }
        assert_eq!(qround(1e30, -4.0, 3.0), 3.0);
        assert_eq!(qround(-1e30, -4.0, 3.0), -4.0);
        assert_eq!(qround(-0.3, -4.0, 3.0) as i32, 0);
    }
}
