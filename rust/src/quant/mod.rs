//! k-quant codec family, implemented from scratch.
//!
//! These are the block quantization formats the paper evaluates (the
//! `llama.cpp` "k-quants"), re-implemented with identical *byte layouts*
//! (so that Table 1's size / average-bit arithmetic is exact) and a
//! documented, self-consistent packing order that is mirrored bit-for-bit
//! by the JAX/Pallas dequantization kernels in
//! `python/compile/kernels/` (cross-checked via shared test vectors).
//!
//! ## Architecture
//!
//! Every format implements the [`BlockCodec`] trait — a block-oriented
//! encode/decode interface (`encode_block` / `decode_block`, plus the
//! batch `encode_blocks` / `decode_blocks` that each format overrides
//! with its tight loop). [`codec`] is the per-format registry returning
//! the `&'static dyn BlockCodec` for a [`QuantFormat`].
//!
//! The crate-facing entry points are **zero-copy**:
//! [`quantize_into`] / [`dequantize_into`] encode into caller-provided
//! buffers (no allocation per call), and both automatically split large
//! tensors across threads at super-block granularity
//! ([`parallel`]). Because every block is encoded independently into a
//! disjoint output range, the parallel result is **byte-identical** to
//! the serial one (asserted by `tests/quant_properties.rs` and
//! `dsq selfcheck`). [`quantize`] / [`dequantize`] are thin allocating
//! wrappers kept for convenience.
//!
//! ## Format summary
//!
//! | format | block | bytes/block | bits/weight | structure |
//! |--------|------:|------------:|------------:|-----------|
//! | `F32`  |     1 |           4 |        32.0 | raw                         |
//! | `F16`  |     1 |           2 |        16.0 | raw IEEE half               |
//! | `Q8_0` |    32 |          34 |         8.5 | f16 d + 32×i8               |
//! | `Q6_K` |   256 |         210 |      6.5625 | ql128 + qh64 + 16×i8 sc + d |
//! | `Q5_K` |   256 |         176 |         5.5 | d,dmin + 8×(6b sc,6b m) + qh32 + qs128 |
//! | `Q4_K` |   256 |         144 |         4.5 | d,dmin + 8×(6b sc,6b m) + qs128 |
//! | `Q3_K` |   256 |         110 |      3.4375 | 16×6b sc + hmask32 + qs64 + d |
//! | `Q2_K` |   256 |          84 |       2.625 | 16×(4b sc,4b m) + qs64 + d,dmin |
//!
//! All "K" formats use a super-block of 256 weights subdivided into
//! sub-blocks (8×32 or 16×16); sub-block scales/mins are themselves
//! quantized against per-super-block f16 scales (`d`, `dmin`).
//!
//! ## Quantization quality
//!
//! Scale search follows the same strategy as `llama.cpp`:
//! symmetric formats (`Q3_K`, `Q6_K`) use a weighted grid search
//! around `max|x| / qmax` ([`scalar::make_qx_quants`]); asymmetric
//! formats (`Q2_K`, `Q4_K`, `Q5_K`) use iterative weighted min/max
//! refinement ([`scalar::make_qkx_quants`]); `Q8_0` uses plain absmax.
//! All entry points accept an optional importance vector (the "imatrix"
//! in llama.cpp terms) so that calibration data can steer the rounding.
//!
//! The search inner loops are single-pass and lane-chunked: per-format
//! specialization stays behind [`BlockCodec`], while the per-candidate
//! weighted sums run through the explicitly vectorizable kernels in
//! [`simd`] (scalar reference in [`scalar`], selected at runtime via
//! `DSQ_SCALAR_SEARCH`; both arms are byte-identical by construction —
//! see `tests/golden_vectors.rs`).
//!
//! ## The read side: decode kernels and fused `vec_dot`
//!
//! The serving path consumes encoded blocks far more often than it
//! produces them, so the decode direction mirrors the encode dual-arm
//! design. [`kernels`] holds lane-chunked, branch-free batch decoders
//! (sub-block scales hoisted out of the inner loop) and fused
//! [`BlockCodec::vec_dot`] kernels that compute dot products directly
//! on encoded bytes without materializing f32 weights; the format
//! modules' plain `dequantize` loops are the scalar reference arm,
//! selected at runtime with `DSQ_SCALAR_DECODE=1`.
//!
//! **The `vec_dot` contract:** `vec_dot(bytes, x)` is bit-identical to
//! [`kernels::dot_lanes`]`(decoded, x)` where `decoded` is the output
//! of `decode_blocks(bytes)` — element `i` accumulates into lane
//! `i % `[`simd::LANES`], each lane is a sequential f32 sum, the
//! horizontal reduction is the shared `hsum` fold, and no step may use
//! an FMA contraction (Rust never emits one implicitly). Because the
//! reduction order is fixed, the lane kernels, the scalar reference,
//! and every `vec_dot_rows` thread count agree bit-for-bit — asserted
//! by `tests/decode_kernels.rs`, the golden suite under both env arms
//! in CI, and `dsq selfcheck` on the deployment host.
//!
//! **GEMM accumulation order:** [`BlockCodec::vec_dot_mat`] extends
//! the same contract to a `T`-column activation panel. Each quantized
//! block is decoded **once** and then accumulated against every
//! column, but per output element the accumulation sequence is exactly
//! the single-column one — blocks in order, element `i` into lane
//! `i % LANES`, same `hsum` fold — so `out[c]` is bit-identical to
//! `vec_dot(bytes, column_c)` for every column, and
//! [`vec_dot_rows_mat`] is bit-identical to `T` independent
//! [`vec_dot_rows`] calls at any thread count. The panel kernel only
//! reorders *which column* is touched between block decodes, never the
//! float operations within one output element.
//!
//! **Dispatch arms:** the decode/`vec_dot`/`vec_dot_mat` kernels come
//! in up to three bit-identical arms, selected at process start by
//! [`kernels::active_arm`]:
//!
//! | arm      | inner loop                          | availability |
//! |----------|-------------------------------------|--------------|
//! | `scalar` | format modules' reference loops     | always       |
//! | `lanes`  | lane-chunked, branch-free kernels   | always       |
//! | `simd`   | hand-written AVX2 / NEON intrinsics | `x86_64` with AVX2, any `aarch64` |
//!
//! `DSQ_FORCE_ARM={scalar,lanes,simd}` pins the arm (an unavailable
//! `simd` request falls back to `lanes`); `DSQ_SCALAR_DECODE=1` is the
//! back-compat spelling of `scalar`. The `simd` arm carries intrinsic
//! decoders for `Q8_0` and `Q4_K` (the deployment-relevant formats)
//! plus a shared intrinsic accumulator for every format; the remaining
//! k-quants reuse the lane decoders inside the `simd` arm, and the raw
//! `F32`/`F16` paths are arm-independent. The intrinsics use only
//! separate multiply/add instructions (no FMA) in the canonical lane
//! order, which is what keeps all arms bit-identical — proven per arm
//! by `tests/decode_kernels.rs`, the `DSQ_FORCE_ARM` CI matrix over
//! the golden suites, and `dsq selfcheck`.

pub mod error;
pub mod kernels;
pub mod parallel;
pub mod q2k;
pub mod q3k;
pub mod q4k;
pub mod q5k;
pub mod q6k;
pub mod q8_0;
pub mod raw;
pub mod scalar;
pub mod simd;

use anyhow::{bail, Result};

/// Number of weights in a k-quant super-block.
pub const QK_K: usize = 256;
/// Number of weights in a `Q8_0` block.
pub const QK8_0: usize = 32;

/// The quantization formats the paper evaluates.
///
/// Serialized names match llama.cpp's lower-case spelling (`q4_k`, …)
/// because the scheme JSON files (Table 7) use those names.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]

pub enum QuantFormat {
    F32,
    F16,
    Q8_0,
    Q6K,
    Q5K,
    Q4K,
    Q3K,
    Q2K,
}

impl QuantFormat {
    /// All formats, most precise first.
    pub const ALL: [QuantFormat; 8] = [
        QuantFormat::F32,
        QuantFormat::F16,
        QuantFormat::Q8_0,
        QuantFormat::Q6K,
        QuantFormat::Q5K,
        QuantFormat::Q4K,
        QuantFormat::Q3K,
        QuantFormat::Q2K,
    ];

    /// Block size in weights.
    pub fn block_weights(self) -> usize {
        match self {
            QuantFormat::F32 | QuantFormat::F16 => 1,
            QuantFormat::Q8_0 => QK8_0,
            _ => QK_K,
        }
    }

    /// Block size in bytes.
    pub fn block_bytes(self) -> usize {
        match self {
            QuantFormat::F32 => 4,
            QuantFormat::F16 => 2,
            QuantFormat::Q8_0 => q8_0::BLOCK_BYTES,
            QuantFormat::Q6K => q6k::BLOCK_BYTES,
            QuantFormat::Q5K => q5k::BLOCK_BYTES,
            QuantFormat::Q4K => q4k::BLOCK_BYTES,
            QuantFormat::Q3K => q3k::BLOCK_BYTES,
            QuantFormat::Q2K => q2k::BLOCK_BYTES,
        }
    }

    /// Effective bits per weight.
    pub fn bits_per_weight(self) -> f64 {
        self.block_bytes() as f64 * 8.0 / self.block_weights() as f64
    }

    /// Bytes needed to store `n` weights (`n` must be a multiple of the
    /// block size).
    pub fn row_bytes(self, n: usize) -> Result<usize> {
        let bw = self.block_weights();
        if n % bw != 0 {
            bail!("{self:?}: element count {n} not a multiple of block size {bw}");
        }
        Ok(n / bw * self.block_bytes())
    }

    /// The canonical lower-case name (`"q4_k"`, `"f32"`, …).
    pub fn name(self) -> &'static str {
        match self {
            QuantFormat::F32 => "f32",
            QuantFormat::F16 => "f16",
            QuantFormat::Q8_0 => "q8_0",
            QuantFormat::Q6K => "q6_k",
            QuantFormat::Q5K => "q5_k",
            QuantFormat::Q4K => "q4_k",
            QuantFormat::Q3K => "q3_k",
            QuantFormat::Q2K => "q2_k",
        }
    }

    /// Parse a lower-case format name.
    ///
    /// `bf16` is rejected by name: bfloat16 has a different bit layout
    /// (8-bit exponent, 7-bit mantissa) from IEEE half, so treating a
    /// bf16 payload as [`QuantFormat::F16`] silently decodes every
    /// weight wrong instead of failing loudly.
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "f32" | "fp32" => QuantFormat::F32,
            "bf16" => bail!(
                "bf16 is not IEEE half: refusing to decode a bfloat16 payload as f16 \
                 (no bf16 codec is implemented)"
            ),
            "f16" | "fp16" => QuantFormat::F16,
            "q8_0" => QuantFormat::Q8_0,
            "q6_k" => QuantFormat::Q6K,
            "q5_k" => QuantFormat::Q5K,
            "q4_k" => QuantFormat::Q4K,
            "q3_k" => QuantFormat::Q3K,
            "q2_k" => QuantFormat::Q2K,
            other => bail!("unknown quant format {other:?}"),
        })
    }
}

impl std::fmt::Display for QuantFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for QuantFormat {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        QuantFormat::parse(s)
    }
}

/// KV-cache storage scheme: how the serving engine encodes appended
/// cache lines (one per-token row per layer plane).
///
/// `F32` is the default and keeps the historical raw `f32` planes
/// byte-for-byte (the golden-logits fixtures are pinned against it).
/// `Q8_0` stores every cache line as Q8_0 blocks, quantized **once on
/// append** (write-once, like the absorbed-MLA expanded plane) and read
/// through the fused [`kernels::vec_dot_arm`] / [`kernels::decode_blocks_arm`]
/// kernels so attention scores keep the canonical 8-lane reduction
/// order. Lines whose element count is not a multiple of the 32-weight
/// block are padded with zeros to the block grid ([`KvScheme::line_weights`]);
/// the padding participates in the (absmax) scale search only as zeros
/// and is never read back.
///
/// Lower-bit K-quants are admissible later: everything downstream
/// speaks [`KvScheme::line_bytes`], not `4 * width`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KvScheme {
    #[default]
    F32,
    Q8_0,
}

impl KvScheme {
    /// The canonical lower-case name (`"f32"` / `"q8_0"`).
    pub fn name(self) -> &'static str {
        match self {
            KvScheme::F32 => "f32",
            KvScheme::Q8_0 => "q8_0",
        }
    }

    /// Parse a `--kv-scheme` value.
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "f32" | "fp32" => KvScheme::F32,
            "q8_0" => KvScheme::Q8_0,
            other => bail!(
                "unknown KV scheme {other:?} (supported: f32, q8_0)"
            ),
        })
    }

    /// The underlying block format of encoded cache lines.
    pub fn format(self) -> QuantFormat {
        match self {
            KvScheme::F32 => QuantFormat::F32,
            KvScheme::Q8_0 => QuantFormat::Q8_0,
        }
    }

    /// Element count of an `n`-element cache line after padding up to
    /// the scheme's block grid (identity for `F32`).
    pub fn line_weights(self, n: usize) -> usize {
        let bw = self.format().block_weights();
        n.div_ceil(bw) * bw
    }

    /// Encoded bytes of an `n`-element cache line, padding included.
    /// This is the unit all KV reservation / planner arithmetic uses.
    pub fn line_bytes(self, n: usize) -> usize {
        let fmt = self.format();
        self.line_weights(n) / fmt.block_weights() * fmt.block_bytes()
    }
}

impl std::fmt::Display for KvScheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for KvScheme {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        KvScheme::parse(s)
    }
}

/// Encode one staged cache line into `scheme`-packed bytes.
///
/// `staged` is the exact f32 line **already padded** to
/// [`KvScheme::line_weights`] (callers keep a preallocated staging
/// buffer whose zero tail is written once — appends are write-once, so
/// the tail stays zero); `out` is exactly [`KvScheme::line_bytes`] for
/// the unpadded width. Serial and allocation-free: this is the
/// quantize-on-append hot path of the serving decode loop. The scale
/// search is plain absmax (`Q8_0`), so the encoding is a pure function
/// of the line — identical across threads, shards, and dispatch arms.
pub fn encode_kv_line(scheme: KvScheme, staged: &[f32], out: &mut [u8]) -> Result<()> {
    let fmt = scheme.format();
    let bw = fmt.block_weights();
    if staged.len() % bw != 0 {
        bail!(
            "kv line: staged length {} not padded to the {bw}-weight {fmt} block grid",
            staged.len()
        );
    }
    let nbytes = staged.len() / bw * fmt.block_bytes();
    if out.len() != nbytes {
        bail!(
            "kv line: output buffer {} bytes, expected {nbytes} for {} staged weights",
            out.len(),
            staged.len()
        );
    }
    codec(fmt).encode_blocks(staged, None, out);
    Ok(())
}

/// A block quantization codec.
///
/// One implementation per [`QuantFormat`], registered in [`codec`].
/// The contract every implementation upholds:
///
/// - `encode_block` consumes exactly `block_weights()` weights (and the
///   matching importance slice, when given) and writes exactly
///   `block_bytes()` bytes. It depends on nothing but its inputs —
///   no shared state — which is what makes block-parallel encoding
///   byte-identical to serial encoding.
/// - `decode_block` is the exact inverse byte layout.
/// - `encode_blocks` / `decode_blocks` process a whole run of blocks;
///   the default implementations loop over the single-block methods,
///   and every format overrides them with its fused loop (virtual
///   dispatch then happens once per *run*, not once per block).
pub trait BlockCodec: Sync {
    /// The format this codec implements.
    fn format(&self) -> QuantFormat;

    /// Weights per block.
    fn block_weights(&self) -> usize {
        self.format().block_weights()
    }

    /// Packed bytes per block.
    fn block_bytes(&self) -> usize {
        self.format().block_bytes()
    }

    /// Encode one block (`src.len() == block_weights()`,
    /// `out.len() == block_bytes()`).
    fn encode_block(&self, src: &[f32], importance: Option<&[f32]>, out: &mut [u8]);

    /// Decode one block (`bytes.len() == block_bytes()`,
    /// `out.len() == block_weights()`).
    fn decode_block(&self, bytes: &[u8], out: &mut [f32]);

    /// Encode a run of whole blocks.
    fn encode_blocks(&self, src: &[f32], importance: Option<&[f32]>, out: &mut [u8]) {
        let bw = self.block_weights();
        let bb = self.block_bytes();
        for (bi, (xb, ob)) in src.chunks_exact(bw).zip(out.chunks_exact_mut(bb)).enumerate() {
            let imp = importance.map(|w| &w[bi * bw..(bi + 1) * bw]);
            self.encode_block(xb, imp, ob);
        }
    }

    /// Decode a run of whole blocks.
    fn decode_blocks(&self, bytes: &[u8], out: &mut [f32]) {
        let bw = self.block_weights();
        let bb = self.block_bytes();
        for (ob, xb) in bytes.chunks_exact(bb).zip(out.chunks_exact_mut(bw)) {
            self.decode_block(ob, xb);
        }
    }

    /// Fused dot product of a run of encoded blocks with `x`
    /// (`bytes.len() == row_bytes(x.len())`), without materializing the
    /// decoded weights. Contract: bit-identical to
    /// [`kernels::dot_lanes`] over `decode_blocks(bytes)` — see the
    /// module docs for the fixed reduction order. The default decodes
    /// block-by-block into a stack buffer (the scalar reference);
    /// formats override with their fused lane kernel.
    fn vec_dot(&self, bytes: &[u8], x: &[f32]) -> f32 {
        kernels::vec_dot_ref(self, bytes, x)
    }

    /// Row-major quantized matrix × f32 vector:
    /// `out[r] = vec_dot(row_r, x)` for `out.len()` rows of `x.len()`
    /// weights each (`bytes.len() == out.len() * row_bytes(x.len())`;
    /// like the other batch methods, the caller guarantees whole
    /// blocks — the validated entry point is [`vec_dot_rows_with`]).
    /// Rows are independent, which is what makes the row-parallel entry
    /// point bit-identical at any thread count.
    fn vec_dot_rows(&self, bytes: &[u8], x: &[f32], out: &mut [f32]) {
        let rb = x.len() / self.block_weights() * self.block_bytes();
        if rb == 0 {
            out.fill(0.0);
            return;
        }
        for (o, row) in out.iter_mut().zip(bytes.chunks_exact(rb)) {
            *o = self.vec_dot(row, x);
        }
    }

    /// Fused dot products of one encoded row against a `T`-column
    /// activation panel `xs` (token-major: column `c` is
    /// `xs[c * n..(c + 1) * n]`, `out.len() == T`). Each block is
    /// decoded once and accumulated against every column, but
    /// `out[c]` is bit-identical to `vec_dot(bytes, column_c)` — see
    /// the GEMM accumulation order in the module docs. The default is
    /// the per-column reference loop; formats override with the
    /// decode-once panel kernel.
    fn vec_dot_mat(&self, bytes: &[u8], xs: &[f32], n: usize, out: &mut [f32]) {
        if n == 0 {
            out.fill(0.0);
            return;
        }
        for (o, col) in out.iter_mut().zip(xs.chunks_exact(n)) {
            *o = self.vec_dot(bytes, col);
        }
    }
}

/// Implement [`BlockCodec`] for a format module whose slice-level
/// `quantize(src, importance, out)` / `dequantize(bytes, out)` already
/// loop over whole blocks (the module invokes this once; single-block
/// calls just hit those loops with exactly one block).
macro_rules! impl_block_codec {
    ($fmt:expr) => {
        /// [`BlockCodec`](crate::quant::BlockCodec) registration for
        /// this module's format.
        pub struct Codec;

        impl crate::quant::BlockCodec for Codec {
            fn format(&self) -> crate::quant::QuantFormat {
                $fmt
            }

            fn encode_block(&self, src: &[f32], importance: Option<&[f32]>, out: &mut [u8]) {
                quantize(src, importance, out);
            }

            fn decode_block(&self, bytes: &[u8], out: &mut [f32]) {
                dequantize(bytes, out);
            }

            fn encode_blocks(&self, src: &[f32], importance: Option<&[f32]>, out: &mut [u8]) {
                quantize(src, importance, out);
            }

            fn decode_blocks(&self, bytes: &[u8], out: &mut [f32]) {
                // Runtime-dispatched: lane kernels by default, this
                // module's `dequantize` loop under `DSQ_SCALAR_DECODE=1`
                // (bit-identical either way).
                crate::quant::kernels::decode_blocks_auto($fmt, bytes, out);
            }

            fn vec_dot(&self, bytes: &[u8], x: &[f32]) -> f32 {
                crate::quant::kernels::vec_dot_auto($fmt, bytes, x)
            }

            fn vec_dot_mat(&self, bytes: &[u8], xs: &[f32], n: usize, out: &mut [f32]) {
                crate::quant::kernels::vec_dot_mat_auto($fmt, bytes, xs, n, out);
            }
        }
    };
}
pub(crate) use impl_block_codec;

/// The per-format codec registry.
pub fn codec(fmt: QuantFormat) -> &'static dyn BlockCodec {
    match fmt {
        QuantFormat::F32 => &raw::F32Codec,
        QuantFormat::F16 => &raw::F16Codec,
        QuantFormat::Q8_0 => &q8_0::Codec,
        QuantFormat::Q6K => &q6k::Codec,
        QuantFormat::Q5K => &q5k::Codec,
        QuantFormat::Q4K => &q4k::Codec,
        QuantFormat::Q3K => &q3k::Codec,
        QuantFormat::Q2K => &q2k::Codec,
    }
}

fn check_importance(src: &[f32], importance: Option<&[f32]>) -> Result<()> {
    if let Some(w) = importance {
        if w.len() != src.len() {
            bail!(
                "importance length {} does not match data length {}",
                w.len(),
                src.len()
            );
        }
    }
    Ok(())
}

/// Quantize `src` into `fmt`'s packed representation, writing into the
/// caller-provided `out` buffer (which must be exactly
/// `fmt.row_bytes(src.len())` long). Returns the bytes written.
///
/// Large tensors are split across threads at block granularity; the
/// output is byte-identical to the serial encoding.
pub fn quantize_into(
    fmt: QuantFormat,
    src: &[f32],
    importance: Option<&[f32]>,
    out: &mut [u8],
) -> Result<usize> {
    quantize_into_with(fmt, src, importance, out, parallel::auto_threads(src.len()))
}

/// [`quantize_into`] with an explicit worker-thread count (`1` forces
/// the serial path; used by the byte-identity tests and by the
/// container pipeline, which parallelizes across tensors instead).
pub fn quantize_into_with(
    fmt: QuantFormat,
    src: &[f32],
    importance: Option<&[f32]>,
    out: &mut [u8],
    threads: usize,
) -> Result<usize> {
    check_importance(src, importance)?;
    let nbytes = fmt.row_bytes(src.len())?;
    if out.len() != nbytes {
        bail!(
            "{fmt}: output buffer {} bytes, expected {nbytes} for {} weights",
            out.len(),
            src.len()
        );
    }
    parallel::encode_chunked(codec(fmt), src, importance, out, threads);
    Ok(nbytes)
}

/// Dequantize `fmt`-packed `bytes` into the caller-provided `out`
/// buffer (`bytes.len()` must equal `fmt.row_bytes(out.len())`).
pub fn dequantize_into(fmt: QuantFormat, bytes: &[u8], out: &mut [f32]) -> Result<()> {
    dequantize_into_with(fmt, bytes, out, parallel::auto_threads(out.len()))
}

/// [`dequantize_into`] with an explicit worker-thread count.
pub fn dequantize_into_with(
    fmt: QuantFormat,
    bytes: &[u8],
    out: &mut [f32],
    threads: usize,
) -> Result<()> {
    let expect = fmt.row_bytes(out.len())?;
    if bytes.len() != expect {
        bail!(
            "{fmt}: byte length {} does not match expected {expect} for {} weights",
            bytes.len(),
            out.len()
        );
    }
    parallel::decode_chunked(codec(fmt), bytes, out, threads);
    Ok(())
}

/// Fused dot product of a `fmt`-packed row with `x` (`bytes.len()` must
/// equal `fmt.row_bytes(x.len())`), computed directly on the encoded
/// blocks. Bit-identical to [`kernels::dot_lanes`] over the decoded
/// row — see the module docs for the reduction-order contract.
pub fn vec_dot(fmt: QuantFormat, bytes: &[u8], x: &[f32]) -> Result<f32> {
    let expect = fmt.row_bytes(x.len())?;
    if bytes.len() != expect {
        bail!(
            "{fmt}: byte length {} does not match expected {expect} for {} weights",
            bytes.len(),
            x.len()
        );
    }
    Ok(codec(fmt).vec_dot(bytes, x))
}

/// Quantized matrix × f32 vector: `out[r]` = fused dot of row `r` of
/// the row-major `fmt`-packed matrix `bytes` with `x`
/// (`bytes.len() == out.len() * fmt.row_bytes(x.len())`). Rows are
/// split across threads; the result is bit-identical at any count.
pub fn vec_dot_rows(fmt: QuantFormat, bytes: &[u8], x: &[f32], out: &mut [f32]) -> Result<()> {
    let threads = parallel::auto_threads(out.len().saturating_mul(x.len()));
    vec_dot_rows_with(fmt, bytes, x, out, threads)
}

/// [`vec_dot_rows`] with an explicit worker-thread count (`1` forces
/// the serial path; used by the identity tests and benches).
pub fn vec_dot_rows_with(
    fmt: QuantFormat,
    bytes: &[u8],
    x: &[f32],
    out: &mut [f32],
    threads: usize,
) -> Result<()> {
    let rb = fmt.row_bytes(x.len())?;
    if bytes.len() != rb * out.len() {
        bail!(
            "{fmt}: matrix byte length {} does not match {} rows × {rb} bytes",
            bytes.len(),
            out.len()
        );
    }
    if rb == 0 {
        out.fill(0.0);
        return Ok(());
    }
    parallel::vec_dot_rows_chunked(codec(fmt), bytes, x, out, rb, threads);
    Ok(())
}

/// Quantized matrix × f32 activation panel (the prefill GEMM):
/// `out[r * t + c]` = fused dot of row `r` of the row-major
/// `fmt`-packed matrix with column `c` of the token-major panel `xs`
/// (`xs.len() == t * n`, column `c` at `xs[c * n..(c + 1) * n]`;
/// `out.len() == rows * t`, row-major). Each quantized block of a row
/// is decoded once and accumulated against all `t` columns;
/// bit-identical to `t` independent [`vec_dot_rows`] calls — see the
/// GEMM accumulation order in the module docs.
pub fn vec_dot_rows_mat(
    fmt: QuantFormat,
    bytes: &[u8],
    xs: &[f32],
    n: usize,
    t: usize,
    out: &mut [f32],
) -> Result<()> {
    let threads = parallel::auto_threads(out.len().saturating_mul(n));
    vec_dot_rows_mat_with(fmt, bytes, xs, n, t, out, threads)
}

/// [`vec_dot_rows_mat`] with an explicit worker-thread count (`1`
/// forces the serial path; rows are split across threads, so the
/// result is bit-identical at any count).
pub fn vec_dot_rows_mat_with(
    fmt: QuantFormat,
    bytes: &[u8],
    xs: &[f32],
    n: usize,
    t: usize,
    out: &mut [f32],
    threads: usize,
) -> Result<()> {
    let rb = fmt.row_bytes(n)?;
    if xs.len() != t * n {
        bail!("{fmt}: panel length {} does not match {t} columns × {n} weights", xs.len());
    }
    if t == 0 || out.len() % t != 0 {
        bail!("{fmt}: output length {} is not a multiple of {t} columns", out.len());
    }
    let rows = out.len() / t;
    if bytes.len() != rb * rows {
        bail!(
            "{fmt}: matrix byte length {} does not match {rows} rows × {rb} bytes",
            bytes.len()
        );
    }
    if rb == 0 {
        out.fill(0.0);
        return Ok(());
    }
    parallel::vec_dot_rows_mat_chunked(codec(fmt), bytes, xs, out, rb, n, t, threads);
    Ok(())
}

/// Quantize `src` into `fmt`'s packed byte representation (allocating
/// wrapper around [`quantize_into`]).
///
/// `importance`, when given, must have the same length as `src` and holds
/// per-weight importance (e.g. mean squared activations from
/// calibration); the scale search minimizes importance-weighted squared
/// reconstruction error.
pub fn quantize(fmt: QuantFormat, src: &[f32], importance: Option<&[f32]>) -> Result<Vec<u8>> {
    let mut out = vec![0u8; fmt.row_bytes(src.len())?];
    quantize_into(fmt, src, importance, &mut out)?;
    Ok(out)
}

/// Dequantize `n` weights from `fmt`-packed `bytes` (allocating wrapper
/// around [`dequantize_into`]).
pub fn dequantize(fmt: QuantFormat, bytes: &[u8], n: usize) -> Result<Vec<f32>> {
    let mut out = vec![0f32; n];
    dequantize_into(fmt, bytes, &mut out)?;
    Ok(out)
}

/// Quantize → dequantize round trip (the "fake quantization" used by the
/// error sweep and by tests).
pub fn roundtrip(fmt: QuantFormat, src: &[f32], importance: Option<&[f32]>) -> Result<Vec<f32>> {
    let bytes = quantize(fmt, src, importance)?;
    dequantize(fmt, &bytes, src.len())
}

/// Round trip into caller-owned scratch: packs into `packed` (resized as
/// needed) and decodes into `out` (`out.len() == src.len()`). This is
/// the zero-allocation hot path of the bpw↔error sweep.
pub fn roundtrip_into(
    fmt: QuantFormat,
    src: &[f32],
    importance: Option<&[f32]>,
    packed: &mut Vec<u8>,
    out: &mut [f32],
) -> Result<()> {
    packed.resize(fmt.row_bytes(src.len())?, 0);
    quantize_into(fmt, src, importance, packed)?;
    dequantize_into(fmt, packed, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_per_weight_match_paper_formats() {
        // These are the bpw figures Table 1's "Avg Quants" row is built
        // from; they must match llama.cpp exactly.
        assert_eq!(QuantFormat::Q8_0.bits_per_weight(), 8.5);
        assert_eq!(QuantFormat::Q6K.bits_per_weight(), 6.5625);
        assert_eq!(QuantFormat::Q5K.bits_per_weight(), 5.5);
        assert_eq!(QuantFormat::Q4K.bits_per_weight(), 4.5);
        assert_eq!(QuantFormat::Q3K.bits_per_weight(), 3.4375);
        assert_eq!(QuantFormat::Q2K.bits_per_weight(), 2.625);
    }

    #[test]
    fn parse_roundtrip() {
        for fmt in QuantFormat::ALL {
            assert_eq!(QuantFormat::parse(fmt.name()).unwrap(), fmt);
        }
    }

    #[test]
    fn parse_rejects_bf16_by_name() {
        // Regression: "bf16" used to alias to F16 and silently misdecode
        // bfloat16 payloads as IEEE half. It must fail with a named error.
        let err = QuantFormat::parse("bf16").unwrap_err().to_string();
        assert!(err.contains("bf16"), "error must name bf16: {err}");
        assert!(
            "bf16".parse::<QuantFormat>().is_err(),
            "FromStr must reject bf16 too"
        );
        // The legitimate IEEE-half spellings still parse.
        assert_eq!(QuantFormat::parse("f16").unwrap(), QuantFormat::F16);
        assert_eq!(QuantFormat::parse("fp16").unwrap(), QuantFormat::F16);
    }

    #[test]
    fn registry_agrees_with_format() {
        for fmt in QuantFormat::ALL {
            let c = codec(fmt);
            assert_eq!(c.format(), fmt);
            assert_eq!(c.block_weights(), fmt.block_weights());
            assert_eq!(c.block_bytes(), fmt.block_bytes());
        }
    }

    #[test]
    fn row_bytes_rejects_ragged() {
        assert!(QuantFormat::Q4K.row_bytes(100).is_err());
        assert_eq!(QuantFormat::Q4K.row_bytes(512).unwrap(), 288);
        assert_eq!(QuantFormat::Q8_0.row_bytes(64).unwrap(), 68);
    }

    #[test]
    fn f32_f16_roundtrip() {
        let src = [1.0f32, -2.5, 0.0, 1000.0];
        let rt = roundtrip(QuantFormat::F32, &src, None).unwrap();
        assert_eq!(rt, src);
        let rt = roundtrip(QuantFormat::F16, &src, None).unwrap();
        for (a, b) in rt.iter().zip(src.iter()) {
            assert!((a - b).abs() <= b.abs() * 1e-3);
        }
    }

    #[test]
    fn importance_length_checked() {
        let src = vec![0.5f32; QK_K];
        let w = vec![1.0f32; QK_K - 1];
        assert!(quantize(QuantFormat::Q4K, &src, Some(&w)).is_err());
    }

    #[test]
    fn into_buffers_validated() {
        let src = vec![0.5f32; QK_K];
        let mut short = vec![0u8; 10];
        assert!(quantize_into(QuantFormat::Q4K, &src, None, &mut short).is_err());
        let packed = quantize(QuantFormat::Q4K, &src, None).unwrap();
        let mut out = vec![0f32; QK_K - 1]; // ragged target length
        assert!(dequantize_into(QuantFormat::Q4K, &packed, &mut out).is_err());
    }

    #[test]
    fn kv_scheme_line_arithmetic_and_parse() {
        assert_eq!(KvScheme::F32.line_weights(288), 288);
        assert_eq!(KvScheme::F32.line_bytes(288), 288 * 4);
        // 288 = 9 whole Q8_0 blocks → 9 × 34 bytes, no padding.
        assert_eq!(KvScheme::Q8_0.line_weights(288), 288);
        assert_eq!(KvScheme::Q8_0.line_bytes(288), 9 * 34);
        // Ragged widths pad up to the 32 grid.
        assert_eq!(KvScheme::Q8_0.line_weights(33), 64);
        assert_eq!(KvScheme::Q8_0.line_bytes(33), 2 * 34);
        assert_eq!(KvScheme::Q8_0.line_bytes(0), 0);
        for s in [KvScheme::F32, KvScheme::Q8_0] {
            assert_eq!(KvScheme::parse(s.name()).unwrap(), s);
        }
        assert!(KvScheme::parse("q4_k").is_err());
        assert_eq!(KvScheme::default(), KvScheme::F32);
    }

    #[test]
    fn encode_kv_line_matches_whole_row_encoding_and_validates() {
        let mut rng = crate::util::rng::Pcg::new(77);
        let line: Vec<f32> = (0..64).map(|_| rng.next_normal()).collect();
        let mut enc = vec![0u8; KvScheme::Q8_0.line_bytes(64)];
        encode_kv_line(KvScheme::Q8_0, &line, &mut enc).unwrap();
        // Identical to the general whole-row encoder on the same data.
        assert_eq!(enc, quantize(QuantFormat::Q8_0, &line, None).unwrap());
        // Zero-padded staging: the tail only feeds the last block.
        let mut padded = vec![0f32; 64];
        padded[..40].copy_from_slice(&line[..40]);
        let mut enc2 = vec![0u8; KvScheme::Q8_0.line_bytes(40)];
        encode_kv_line(KvScheme::Q8_0, &padded, &mut enc2).unwrap();
        assert_eq!(enc2, quantize(QuantFormat::Q8_0, &padded, None).unwrap());
        // Un-padded staging or wrong output size is an error.
        assert!(encode_kv_line(KvScheme::Q8_0, &line[..40], &mut enc2).is_err());
        let mut short = vec![0u8; 10];
        assert!(encode_kv_line(KvScheme::Q8_0, &line, &mut short).is_err());
    }

    #[test]
    fn single_block_codec_matches_slice_path() {
        let mut rng = crate::util::rng::Pcg::new(91);
        for fmt in QuantFormat::ALL {
            let n = fmt.block_weights();
            let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let c = codec(fmt);
            let mut via_block = vec![0u8; fmt.block_bytes()];
            c.encode_block(&data, None, &mut via_block);
            let via_slice = quantize(fmt, &data, None).unwrap();
            assert_eq!(via_block, via_slice, "{fmt}");
            let mut decoded = vec![0f32; n];
            c.decode_block(&via_block, &mut decoded);
            assert_eq!(decoded, dequantize(fmt, &via_slice, n).unwrap(), "{fmt}");
        }
    }
}
