//! k-quant codec family, implemented from scratch.
//!
//! These are the block quantization formats the paper evaluates (the
//! `llama.cpp` "k-quants"), re-implemented with identical *byte layouts*
//! (so that Table 1's size / average-bit arithmetic is exact) and a
//! documented, self-consistent packing order that is mirrored bit-for-bit
//! by the JAX/Pallas dequantization kernels in
//! `python/compile/kernels/` (cross-checked via shared test vectors).
//!
//! ## Format summary
//!
//! | format | block | bytes/block | bits/weight | structure |
//! |--------|------:|------------:|------------:|-----------|
//! | `F32`  |     1 |           4 |        32.0 | raw                         |
//! | `F16`  |     1 |           2 |        16.0 | raw IEEE half               |
//! | `Q8_0` |    32 |          34 |         8.5 | f16 d + 32×i8               |
//! | `Q6_K` |   256 |         210 |      6.5625 | ql128 + qh64 + 16×i8 sc + d |
//! | `Q5_K` |   256 |         176 |         5.5 | d,dmin + 8×(6b sc,6b m) + qh32 + qs128 |
//! | `Q4_K` |   256 |         144 |         4.5 | d,dmin + 8×(6b sc,6b m) + qs128 |
//! | `Q3_K` |   256 |         110 |      3.4375 | 16×6b sc + hmask32 + qs64 + d |
//! | `Q2_K` |   256 |          84 |       2.625 | 16×(4b sc,4b m) + qs64 + d,dmin |
//!
//! All "K" formats use a super-block of 256 weights subdivided into
//! sub-blocks (8×32 or 16×16); sub-block scales/mins are themselves
//! quantized against per-super-block f16 scales (`d`, `dmin`).
//!
//! ## Quantization quality
//!
//! Scale search follows the same strategy as `llama.cpp`:
//! symmetric formats (`Q3_K`, `Q6_K`, `Q8_0`) use a weighted grid search
//! around `max|x| / qmax` ([`scalar::make_qx_quants`]); asymmetric
//! formats (`Q2_K`, `Q4_K`, `Q5_K`) use iterative weighted min/max
//! refinement ([`scalar::make_qkx_quants`]). All entry points accept an
//! optional importance vector (the "imatrix" in llama.cpp terms) so that
//! calibration data can steer the rounding.

pub mod error;
pub mod q2k;
pub mod q3k;
pub mod q4k;
pub mod q5k;
pub mod q6k;
pub mod q8_0;
pub mod scalar;

use anyhow::{bail, Result};

/// Number of weights in a k-quant super-block.
pub const QK_K: usize = 256;
/// Number of weights in a `Q8_0` block.
pub const QK8_0: usize = 32;

/// The quantization formats the paper evaluates.
///
/// Serialized names match llama.cpp's lower-case spelling (`q4_k`, …)
/// because the scheme JSON files (Table 7) use those names.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]

pub enum QuantFormat {
    F32,
    F16,
    Q8_0,
    Q6K,
    Q5K,
    Q4K,
    Q3K,
    Q2K,
}

impl QuantFormat {
    /// All formats, most precise first.
    pub const ALL: [QuantFormat; 8] = [
        QuantFormat::F32,
        QuantFormat::F16,
        QuantFormat::Q8_0,
        QuantFormat::Q6K,
        QuantFormat::Q5K,
        QuantFormat::Q4K,
        QuantFormat::Q3K,
        QuantFormat::Q2K,
    ];

    /// Block size in weights.
    pub fn block_weights(self) -> usize {
        match self {
            QuantFormat::F32 | QuantFormat::F16 => 1,
            QuantFormat::Q8_0 => QK8_0,
            _ => QK_K,
        }
    }

    /// Block size in bytes.
    pub fn block_bytes(self) -> usize {
        match self {
            QuantFormat::F32 => 4,
            QuantFormat::F16 => 2,
            QuantFormat::Q8_0 => 34,
            QuantFormat::Q6K => 210,
            QuantFormat::Q5K => 176,
            QuantFormat::Q4K => 144,
            QuantFormat::Q3K => 110,
            QuantFormat::Q2K => 84,
        }
    }

    /// Effective bits per weight.
    pub fn bits_per_weight(self) -> f64 {
        self.block_bytes() as f64 * 8.0 / self.block_weights() as f64
    }

    /// Bytes needed to store `n` weights (`n` must be a multiple of the
    /// block size).
    pub fn row_bytes(self, n: usize) -> Result<usize> {
        let bw = self.block_weights();
        if n % bw != 0 {
            bail!("{self:?}: element count {n} not a multiple of block size {bw}");
        }
        Ok(n / bw * self.block_bytes())
    }

    /// The canonical lower-case name (`"q4_k"`, `"f32"`, …).
    pub fn name(self) -> &'static str {
        match self {
            QuantFormat::F32 => "f32",
            QuantFormat::F16 => "f16",
            QuantFormat::Q8_0 => "q8_0",
            QuantFormat::Q6K => "q6_k",
            QuantFormat::Q5K => "q5_k",
            QuantFormat::Q4K => "q4_k",
            QuantFormat::Q3K => "q3_k",
            QuantFormat::Q2K => "q2_k",
        }
    }

    /// Parse a lower-case format name.
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "f32" | "fp32" => QuantFormat::F32,
            "f16" | "fp16" | "bf16" => QuantFormat::F16,
            "q8_0" => QuantFormat::Q8_0,
            "q6_k" => QuantFormat::Q6K,
            "q5_k" => QuantFormat::Q5K,
            "q4_k" => QuantFormat::Q4K,
            "q3_k" => QuantFormat::Q3K,
            "q2_k" => QuantFormat::Q2K,
            other => bail!("unknown quant format {other:?}"),
        })
    }
}

impl std::fmt::Display for QuantFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for QuantFormat {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        QuantFormat::parse(s)
    }
}

/// Quantize `src` into `fmt`'s packed byte representation.
///
/// `importance`, when given, must have the same length as `src` and holds
/// per-weight importance (e.g. mean squared activations from
/// calibration); the scale search minimizes importance-weighted squared
/// reconstruction error.
pub fn quantize(fmt: QuantFormat, src: &[f32], importance: Option<&[f32]>) -> Result<Vec<u8>> {
    if let Some(w) = importance {
        if w.len() != src.len() {
            bail!(
                "importance length {} does not match data length {}",
                w.len(),
                src.len()
            );
        }
    }
    let nbytes = fmt.row_bytes(src.len())?;
    let mut out = vec![0u8; nbytes];
    match fmt {
        QuantFormat::F32 => {
            for (o, v) in out.chunks_exact_mut(4).zip(src) {
                o.copy_from_slice(&v.to_le_bytes());
            }
        }
        QuantFormat::F16 => {
            for (o, v) in out.chunks_exact_mut(2).zip(src) {
                o.copy_from_slice(&crate::util::f16::f32_to_f16_bits(*v).to_le_bytes());
            }
        }
        QuantFormat::Q8_0 => q8_0::quantize(src, importance, &mut out),
        QuantFormat::Q6K => q6k::quantize(src, importance, &mut out),
        QuantFormat::Q5K => q5k::quantize(src, importance, &mut out),
        QuantFormat::Q4K => q4k::quantize(src, importance, &mut out),
        QuantFormat::Q3K => q3k::quantize(src, importance, &mut out),
        QuantFormat::Q2K => q2k::quantize(src, importance, &mut out),
    }
    Ok(out)
}

/// Dequantize `n` weights from `fmt`-packed `bytes`.
pub fn dequantize(fmt: QuantFormat, bytes: &[u8], n: usize) -> Result<Vec<f32>> {
    let expect = fmt.row_bytes(n)?;
    if bytes.len() != expect {
        bail!(
            "{fmt}: byte length {} does not match expected {expect} for {n} weights",
            bytes.len()
        );
    }
    let mut out = vec![0f32; n];
    match fmt {
        QuantFormat::F32 => {
            for (o, b) in out.iter_mut().zip(bytes.chunks_exact(4)) {
                *o = f32::from_le_bytes(b.try_into().unwrap());
            }
        }
        QuantFormat::F16 => {
            for (o, b) in out.iter_mut().zip(bytes.chunks_exact(2)) {
                *o = crate::util::f16::f16_bits_to_f32(u16::from_le_bytes(b.try_into().unwrap()));
            }
        }
        QuantFormat::Q8_0 => q8_0::dequantize(bytes, &mut out),
        QuantFormat::Q6K => q6k::dequantize(bytes, &mut out),
        QuantFormat::Q5K => q5k::dequantize(bytes, &mut out),
        QuantFormat::Q4K => q4k::dequantize(bytes, &mut out),
        QuantFormat::Q3K => q3k::dequantize(bytes, &mut out),
        QuantFormat::Q2K => q2k::dequantize(bytes, &mut out),
    }
    Ok(out)
}

/// Quantize → dequantize round trip (the "fake quantization" used by the
/// error sweep and by tests).
pub fn roundtrip(fmt: QuantFormat, src: &[f32], importance: Option<&[f32]>) -> Result<Vec<f32>> {
    let bytes = quantize(fmt, src, importance)?;
    dequantize(fmt, &bytes, src.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_per_weight_match_paper_formats() {
        // These are the bpw figures Table 1's "Avg Quants" row is built
        // from; they must match llama.cpp exactly.
        assert_eq!(QuantFormat::Q8_0.bits_per_weight(), 8.5);
        assert_eq!(QuantFormat::Q6K.bits_per_weight(), 6.5625);
        assert_eq!(QuantFormat::Q5K.bits_per_weight(), 5.5);
        assert_eq!(QuantFormat::Q4K.bits_per_weight(), 4.5);
        assert_eq!(QuantFormat::Q3K.bits_per_weight(), 3.4375);
        assert_eq!(QuantFormat::Q2K.bits_per_weight(), 2.625);
    }

    #[test]
    fn parse_roundtrip() {
        for fmt in QuantFormat::ALL {
            assert_eq!(QuantFormat::parse(fmt.name()).unwrap(), fmt);
        }
    }

    #[test]
    fn row_bytes_rejects_ragged() {
        assert!(QuantFormat::Q4K.row_bytes(100).is_err());
        assert_eq!(QuantFormat::Q4K.row_bytes(512).unwrap(), 288);
        assert_eq!(QuantFormat::Q8_0.row_bytes(64).unwrap(), 68);
    }

    #[test]
    fn f32_f16_roundtrip() {
        let src = [1.0f32, -2.5, 0.0, 1000.0];
        let rt = roundtrip(QuantFormat::F32, &src, None).unwrap();
        assert_eq!(rt, src);
        let rt = roundtrip(QuantFormat::F16, &src, None).unwrap();
        for (a, b) in rt.iter().zip(src.iter()) {
            assert!((a - b).abs() <= b.abs() * 1e-3);
        }
    }

    #[test]
    fn importance_length_checked() {
        let src = vec![0.5f32; QK_K];
        let w = vec![1.0f32; QK_K - 1];
        assert!(quantize(QuantFormat::Q4K, &src, Some(&w)).is_err());
    }
}
