//! `Q5_K` — 5-bit k-quant, super-block of 256, 176 bytes (5.5 bpw).
//!
//! Identical structure to [`super::q4k`] (8 sub-blocks of 32, asymmetric,
//! 6-bit packed scales/mins) plus a fifth code bit stored in a 32-byte
//! high-bit plane:
//! ```text
//! [0..2)     f16 d
//! [2..4)     f16 dmin
//! [4..16)    packed 6-bit scales+mins
//! [16..48)   qh[32]    high bit of c_i: bit (i&7) of qh[i>>3]
//! [48..176)  qs[128]   low 4 bits of c_i: nibble (i&1) of qs[i>>1]
//! ```
//! Codes `c_i ∈ [0, 31]`, `x_i = d · sc[j] · c_i − dmin · m[j]`.
//!
//! Decode arms: scalar (this module) and lane-chunked; inside the
//! `simd` dispatch arm the lane decoder is reused with the intrinsic
//! accumulator (see the arm matrix in [`super`]).

use super::q4k::{dequantize_impl, quantize_impl};


pub const BLOCK_BYTES: usize = 176;

pub fn quantize(src: &[f32], importance: Option<&[f32]>, out: &mut [u8]) {
    quantize_impl(src, importance, out, 31, BLOCK_BYTES, 48, true);
}

pub fn dequantize(bytes: &[u8], out: &mut [f32]) {
    dequantize_impl(bytes, out, BLOCK_BYTES, 48, true);
}

crate::quant::impl_block_codec!(crate::quant::QuantFormat::Q5K);

#[cfg(test)]
mod tests {
    use crate::quant::error::rel_rmse;
    use crate::quant::{roundtrip, QuantFormat, QK_K};
    use crate::util::rng::Pcg;

    #[test]
    fn q5k_accuracy_on_gaussian() {
        let mut rng = Pcg::new(19);
        let src: Vec<f32> = (0..QK_K * 4).map(|_| rng.next_normal()).collect();
        let rt = roundtrip(QuantFormat::Q5K, &src, None).unwrap();
        let err = rel_rmse(&src, &rt);
        assert!(err < 0.05, "q5_k rel rmse too high: {err}");
    }

    #[test]
    fn q5k_better_than_q4k() {
        let mut rng = Pcg::new(23);
        let src: Vec<f32> = (0..QK_K * 8).map(|_| rng.next_normal()).collect();
        let e5 = rel_rmse(&src, &roundtrip(QuantFormat::Q5K, &src, None).unwrap());
        let e4 = rel_rmse(&src, &roundtrip(QuantFormat::Q4K, &src, None).unwrap());
        assert!(e5 < e4, "q5_k ({e5}) should beat q4_k ({e4})");
    }

    #[test]
    fn q5k_zero_block() {
        let src = vec![0f32; QK_K];
        let rt = roundtrip(QuantFormat::Q5K, &src, None).unwrap();
        assert_eq!(rt, src);
    }

    #[test]
    fn q5k_decode_kernel_and_vec_dot_bit_identical() {
        crate::quant::kernels::assert_decode_and_vec_dot_identity(
            crate::quant::QuantFormat::Q5K,
            0x5D,
        );
    }
}
