//! Decode-side lane kernels: batch block decoding and fused
//! quantized-dot (`vec_dot`) for every builtin format.
//!
//! PR 2 made the *write* side fast (SIMD-specialized scale search); this
//! module is the matching *read* side. Two things live here:
//!
//! - **Batch decode kernels** (`decode_blocks_*`): the per-format
//!   `dequantize` loops in the format modules recompute sub-block
//!   scales once per *element* (a division, an unpack and two f16
//!   loads per weight). The kernels here hoist all per-sub-block work
//!   out of the inner loop and walk the code planes byte-by-byte with
//!   branch-free nibble/bit extraction, which the autovectorizer can
//!   lower in release builds. The hoisting is algebraically a no-op:
//!   each element still evaluates the exact same f32 expression in the
//!   same order (e.g. `(d·sc)·c − (dmin·m)` for `Q4_K`), so the fast
//!   kernels are **bit-identical** to the module loops.
//! - **Fused `vec_dot` kernels**: dot products computed directly on
//!   encoded blocks — each super-block is decoded into a stack buffer
//!   (never touching main memory) and multiplied into eight persistent
//!   f32 accumulator lanes. `vec_dot(q, x)` is defined to equal
//!   [`dot_lanes`]`(decode_blocks(q), x)` **bit-for-bit**.
//! - **GEMM `vec_dot_mat` kernels** (PR 6): one encoded row against a
//!   `T`-column activation panel. Each quantized block is decoded
//!   **once per [`MAT_COLS`] columns** instead of once per column and
//!   accumulated against every column through the same lane
//!   accumulator, so `out[c]` is bit-identical to `vec_dot(q, col_c)`
//!   — the prefill path batches a whole prompt through this.
//!
//! ## The reduction-order contract
//!
//! [`dot_lanes`] is the one canonical dot product of the crate: element
//! `i` accumulates into lane `i % LANES` (the shared
//! [`super::simd::LANES`] = 8), each lane is a sequential f32 sum, and
//! the horizontal reduction is the shared `simd::hsum` fold.
//! No implicit FMA exists anywhere on the path (Rust never contracts
//! `a*b + c`). Every arm — the fused kernels, the scalar reference
//! ([`vec_dot_ref`]: scalar block decode + lane dot), and every
//! row-parallel thread count — therefore produces the same bits.
//!
//! ## Dispatch
//!
//! Three [`DispatchArm`]s share the seams, all bit-identical:
//!
//! | arm      | what runs                                   | exists on            |
//! |----------|---------------------------------------------|----------------------|
//! | `scalar` | format modules' reference loops             | every target         |
//! | `lanes`  | lane-chunked kernels (autovectorized)       | every target         |
//! | `simd`   | hand-written AVX2 / NEON intrinsic bodies   | `x86_64`+AVX2, `aarch64` |
//!
//! The `simd` arm carries intrinsic decoders for the hot formats
//! (`Q8_0`, `Q4_K`) plus the shared `vec_dot`/`vec_dot_mat` lane
//! accumulator; formats without an intrinsic body fall back to the
//! `lanes` decoder *within* the arm. Raw `F32`/`F16` rows use one code
//! path on every arm (their "decode" is a plain byte load).
//!
//! Selection ([`active_arm`], read once per process): `DSQ_FORCE_ARM=
//! {scalar,lanes,simd}` pins an arm (`simd` degrades to `lanes` where
//! unsupported); otherwise `DSQ_SCALAR_DECODE=1` keeps its PR-3
//! meaning (scalar reference), and the default is the fastest
//! available arm. Every arm is pinned to the same golden fixtures in
//! CI (the `DSQ_FORCE_ARM` matrix) and cross-checked by
//! `dsq selfcheck` and `tests/decode_kernels.rs`.

use super::simd::{hsum, LANES};
use super::{codec, q2k, q3k, q4k, q5k, q6k, q8_0, raw, BlockCodec, QuantFormat, QK8_0, QK_K};
use crate::quant::scalar::get_f16;
use std::sync::OnceLock;

/// Whether the decode-side lane kernels are active. Default on; set
/// `DSQ_SCALAR_DECODE=1` to force the scalar reference loops (both
/// arms are bit-identical — the switch exists for benchmarking and for
/// pinning CI drift tests to either arm). Read once per process.
pub fn decode_kernels_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        !matches!(
            std::env::var("DSQ_SCALAR_DECODE").as_deref(),
            Ok("1") | Ok("true") | Ok("yes")
        )
    })
}

/// One decode/`vec_dot` implementation family (see the module-level
/// dispatch table). All arms are **bit-identical** — element `i` still
/// lands in lane `i % LANES` and every f32 op happens in the same
/// order — so the choice is purely a throughput knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchArm {
    /// The format modules' plain reference loops.
    Scalar,
    /// Lane-chunked kernels the autovectorizer lowers.
    Lanes,
    /// Hand-written AVX2 (x86_64) / NEON (aarch64) intrinsic bodies.
    Simd,
}

impl DispatchArm {
    /// Every arm, reference-most first.
    pub const ALL: [DispatchArm; 3] =
        [DispatchArm::Scalar, DispatchArm::Lanes, DispatchArm::Simd];

    /// The `DSQ_FORCE_ARM` spelling of this arm.
    pub fn name(self) -> &'static str {
        match self {
            DispatchArm::Scalar => "scalar",
            DispatchArm::Lanes => "lanes",
            DispatchArm::Simd => "simd",
        }
    }

    /// Whether this arm can run on the current host (`simd` needs AVX2
    /// on x86_64; NEON is part of the aarch64 baseline). The identity
    /// sweeps iterate `ALL.filter(available)`.
    pub fn available(self) -> bool {
        match self {
            DispatchArm::Simd => simd_available(),
            _ => true,
        }
    }
}

/// Whether the hand-written intrinsic arm exists *and* the CPU supports
/// it. Selecting [`DispatchArm::Simd`] anywhere it does not degrades to
/// the `lanes` kernels, so every entry point stays total.
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(target_arch = "aarch64")]
    {
        true
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        false
    }
}

/// The runtime-selected dispatch arm, read once per process.
/// `DSQ_FORCE_ARM={scalar,lanes,simd}` pins it (any other value is
/// ignored; `simd` falls back to `lanes` where unavailable); otherwise
/// `DSQ_SCALAR_DECODE=1` keeps its historical meaning (scalar), and
/// the default is the fastest available arm.
pub fn active_arm() -> DispatchArm {
    static ARM: OnceLock<DispatchArm> = OnceLock::new();
    *ARM.get_or_init(|| match std::env::var("DSQ_FORCE_ARM").as_deref() {
        Ok("scalar") => DispatchArm::Scalar,
        Ok("lanes") => DispatchArm::Lanes,
        Ok("simd") if simd_available() => DispatchArm::Simd,
        Ok("simd") => DispatchArm::Lanes,
        _ if !decode_kernels_enabled() => DispatchArm::Scalar,
        _ if simd_available() => DispatchArm::Simd,
        _ => DispatchArm::Lanes,
    })
}

/// The canonical lane-ordered dot product: element `i` → lane
/// `i % LANES`, sequential sums per lane, `simd::hsum` fold. This is the
/// reduction order `vec_dot` is contractually bit-identical to.
pub fn dot_lanes(w: &[f32], x: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), x.len());
    let mut acc = [0f32; LANES];
    let head = w.len() / LANES * LANES;
    for (wc, xc) in w[..head].chunks_exact(LANES).zip(x[..head].chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += wc[l] * xc[l];
        }
    }
    for (l, (&wv, &xv)) in w[head..].iter().zip(x[head..].iter()).enumerate() {
        acc[l] += wv * xv;
    }
    hsum(&acc)
}

/// Multiply one decoded run (a multiple of `LANES` long) into the
/// persistent accumulator lanes, preserving the global lane order.
#[inline(always)]
fn accumulate(acc: &mut [f32; LANES], w: &[f32], x: &[f32]) {
    for (wc, xc) in w.chunks_exact(LANES).zip(x.chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += wc[l] * xc[l];
        }
    }
}

// --- per-block fast decoders (bit-identical to the module loops) ---

fn block_q8_0(ob: &[u8], xb: &mut [f32]) {
    let d = get_f16(ob, 0);
    for (x, &q) in xb.iter_mut().zip(&ob[2..2 + QK8_0]) {
        *x = d * (q as i8) as f32;
    }
}

fn block_q2k(ob: &[u8], xb: &mut [f32]) {
    let d = get_f16(ob, 80);
    let dmin = get_f16(ob, 82);
    for j in 0..16 {
        let sd = d * (ob[j] & 0x0F) as f32;
        let sm = dmin * (ob[j] >> 4) as f32;
        let qs = &ob[16 + 4 * j..16 + 4 * j + 4];
        let xs = &mut xb[16 * j..16 * j + 16];
        for (&b, xq) in qs.iter().zip(xs.chunks_exact_mut(4)) {
            xq[0] = sd * (b & 0x03) as f32 - sm;
            xq[1] = sd * ((b >> 2) & 0x03) as f32 - sm;
            xq[2] = sd * ((b >> 4) & 0x03) as f32 - sm;
            xq[3] = sd * (b >> 6) as f32 - sm;
        }
    }
}

fn block_q3k(ob: &[u8], xb: &mut [f32]) {
    let d = get_f16(ob, 108);
    for j in 0..16 {
        let sc = q3k::unpack_scales_6x16(&ob[0..12], j) as i32 - 32;
        let ds = d * sc as f32;
        let qs = &ob[44 + 4 * j..44 + 4 * j + 4];
        let hm = &ob[12 + 2 * j..12 + 2 * j + 2];
        let xs = &mut xb[16 * j..16 * j + 16];
        for (t, xq) in xs.chunks_exact_mut(4).enumerate() {
            let b = qs[t];
            let h = hm[t >> 1];
            for (u, x) in xq.iter_mut().enumerate() {
                let k = 4 * t + u;
                let lo = (b >> (2 * u)) & 0x03;
                let hi = (h >> (k & 7)) & 0x01;
                *x = ds * ((lo | (hi << 2)) as i32 - 4) as f32;
            }
        }
    }
}

/// Shared `Q4_K` / `Q5_K` fast decoder (`qs_off` = 4-bit plane offset,
/// `high_bit` = fifth code bit in the 32-byte plane at offset 16).
fn block_q45k(ob: &[u8], xb: &mut [f32], qs_off: usize, high_bit: bool) {
    let d = get_f16(ob, 0);
    let dmin = get_f16(ob, 2);
    for j in 0..8 {
        let (sc, mn) = q4k::unpack_scale_min_6(&ob[4..16], j);
        let sd = d * sc as f32;
        let sm = dmin * mn as f32;
        let qs = &ob[qs_off + 16 * j..qs_off + 16 * j + 16];
        let xs = &mut xb[32 * j..32 * j + 32];
        if high_bit {
            let qh = &ob[16 + 4 * j..16 + 4 * j + 4];
            for (k2, xq) in xs.chunks_exact_mut(2).enumerate() {
                let b = qs[k2];
                let h = qh[k2 >> 2];
                let k = 2 * k2;
                let h0 = ((h >> (k & 7)) & 1) << 4;
                let h1 = ((h >> ((k + 1) & 7)) & 1) << 4;
                xq[0] = sd * ((b & 0x0F) | h0) as f32 - sm;
                xq[1] = sd * ((b >> 4) | h1) as f32 - sm;
            }
        } else {
            for (&b, xq) in qs.iter().zip(xs.chunks_exact_mut(2)) {
                xq[0] = sd * (b & 0x0F) as f32 - sm;
                xq[1] = sd * (b >> 4) as f32 - sm;
            }
        }
    }
}

fn block_q6k(ob: &[u8], xb: &mut [f32]) {
    let d = get_f16(ob, 208);
    for j in 0..16 {
        let dsc = d * (ob[192 + j] as i8) as f32;
        let ql = &ob[8 * j..8 * j + 8];
        let qh = &ob[128 + 4 * j..128 + 4 * j + 4];
        let xs = &mut xb[16 * j..16 * j + 16];
        for (k2, xq) in xs.chunks_exact_mut(2).enumerate() {
            let b = ql[k2];
            let h = qh[k2 >> 1];
            let k = 2 * k2;
            let hi0 = (h >> (2 * (k & 3))) & 0x03;
            let hi1 = (h >> (2 * ((k + 1) & 3))) & 0x03;
            xq[0] = dsc * (((b & 0x0F) | (hi0 << 4)) as i32 - 32) as f32;
            xq[1] = dsc * (((b >> 4) | (hi1 << 4)) as i32 - 32) as f32;
        }
    }
}

fn block_q5k(ob: &[u8], xb: &mut [f32]) {
    block_q45k(ob, xb, 48, true)
}

fn block_q4k(ob: &[u8], xb: &mut [f32]) {
    block_q45k(ob, xb, 16, false)
}

/// The fast per-block decoder for one k-quant/`Q8_0` format — the one
/// seam both [`decode_blocks_fast`] and [`vec_dot_fast`] select
/// through, so a new block format needs exactly one registration here.
fn fast_block_decoder(fmt: QuantFormat) -> fn(&[u8], &mut [f32]) {
    match fmt {
        QuantFormat::Q8_0 => block_q8_0,
        QuantFormat::Q6K => block_q6k,
        QuantFormat::Q5K => block_q5k,
        QuantFormat::Q4K => block_q4k,
        QuantFormat::Q3K => block_q3k,
        QuantFormat::Q2K => block_q2k,
        QuantFormat::F32 | QuantFormat::F16 => unreachable!("raw formats handled in dispatch"),
    }
}

// --- the hand-written SIMD arm (AVX2 / NEON) ---
//
// Every body below computes, per element, the exact same f32 expression
// as its `lanes` counterpart — unpack/widen the integer codes, one
// multiply, one add or subtract — so the outputs are bit-identical
// (elementwise IEEE f32 ops have no ordering freedom). The shared
// accumulator uses separate mul + add intrinsics, never an FMA: the
// crate's reduction contract is FMA-free.

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{q4k, LANES, QK8_0};
    use crate::quant::scalar::get_f16;
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller verified AVX2 support; `w`/`x` have equal lengths that
    /// are a multiple of `LANES`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn accumulate(acc: &mut [f32; LANES], w: &[f32], x: &[f32]) {
        let mut a = _mm256_loadu_ps(acc.as_ptr());
        for (wc, xc) in w.chunks_exact(LANES).zip(x.chunks_exact(LANES)) {
            let wv = _mm256_loadu_ps(wc.as_ptr());
            let xv = _mm256_loadu_ps(xc.as_ptr());
            // Separate mul + add — never `_mm256_fmadd_ps` — so the
            // lane sums round exactly like the scalar loop.
            a = _mm256_add_ps(a, _mm256_mul_ps(wv, xv));
        }
        _mm256_storeu_ps(acc.as_mut_ptr(), a);
    }

    /// # Safety
    /// Caller verified AVX2 support; `ob` is one whole `Q8_0` block
    /// (34 bytes), `xb` exactly 32 elements.
    #[target_feature(enable = "avx2")]
    pub unsafe fn block_q8_0(ob: &[u8], xb: &mut [f32]) {
        let d = _mm256_set1_ps(get_f16(ob, 0));
        for k in (0..QK8_0).step_by(8) {
            let q = _mm_loadl_epi64(ob.as_ptr().add(2 + k) as *const __m128i);
            let w = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(q));
            _mm256_storeu_ps(xb.as_mut_ptr().add(k), _mm256_mul_ps(d, w));
        }
    }

    /// # Safety
    /// Caller verified AVX2 support; `ob` is one whole `Q4_K`
    /// super-block (144 bytes), `xb` exactly 256 elements.
    #[target_feature(enable = "avx2")]
    pub unsafe fn block_q4k(ob: &[u8], xb: &mut [f32]) {
        let d = get_f16(ob, 0);
        let dmin = get_f16(ob, 2);
        let mask = _mm_set1_epi8(0x0F);
        for j in 0..8 {
            let (sc, mn) = q4k::unpack_scale_min_6(&ob[4..16], j);
            let sd = _mm256_set1_ps(d * sc as f32);
            let sm = _mm256_set1_ps(dmin * mn as f32);
            let qs = _mm_loadu_si128(ob.as_ptr().add(16 + 16 * j) as *const __m128i);
            let lo = _mm_and_si128(qs, mask);
            let hi = _mm_and_si128(_mm_srli_epi16::<4>(qs), mask);
            // Interleaving restores the scalar output order
            // (lo0, hi0, lo1, hi1, …): codes 0..16, then 16..32.
            let parts = [_mm_unpacklo_epi8(lo, hi), _mm_unpackhi_epi8(lo, hi)];
            let out = xb.as_mut_ptr().add(32 * j);
            for (h, &v) in parts.iter().enumerate() {
                let f0 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(v));
                let f1 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128::<8>(v)));
                let o = out.add(16 * h);
                _mm256_storeu_ps(o, _mm256_sub_ps(_mm256_mul_ps(sd, f0), sm));
                _mm256_storeu_ps(o.add(8), _mm256_sub_ps(_mm256_mul_ps(sd, f1), sm));
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{q4k, LANES, QK8_0};
    use crate::quant::scalar::get_f16;
    use std::arch::aarch64::*;

    /// # Safety
    /// `w`/`x` have equal lengths that are a multiple of `LANES`
    /// (NEON itself is baseline on aarch64).
    pub unsafe fn accumulate(acc: &mut [f32; LANES], w: &[f32], x: &[f32]) {
        let mut a0 = vld1q_f32(acc.as_ptr());
        let mut a1 = vld1q_f32(acc.as_ptr().add(4));
        for (wc, xc) in w.chunks_exact(LANES).zip(x.chunks_exact(LANES)) {
            let w0 = vld1q_f32(wc.as_ptr());
            let w1 = vld1q_f32(wc.as_ptr().add(4));
            let x0 = vld1q_f32(xc.as_ptr());
            let x1 = vld1q_f32(xc.as_ptr().add(4));
            // Separate mul + add — never `vfmaq_f32` — so the lane
            // sums round exactly like the scalar loop.
            a0 = vaddq_f32(a0, vmulq_f32(w0, x0));
            a1 = vaddq_f32(a1, vmulq_f32(w1, x1));
        }
        vst1q_f32(acc.as_mut_ptr(), a0);
        vst1q_f32(acc.as_mut_ptr().add(4), a1);
    }

    /// # Safety
    /// `ob` is one whole `Q8_0` block (34 bytes), `xb` exactly 32
    /// elements.
    pub unsafe fn block_q8_0(ob: &[u8], xb: &mut [f32]) {
        let d = vdupq_n_f32(get_f16(ob, 0));
        for k in (0..QK8_0).step_by(8) {
            let q = vmovl_s8(vld1_s8(ob.as_ptr().add(2 + k) as *const i8));
            let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(q)));
            let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(q)));
            vst1q_f32(xb.as_mut_ptr().add(k), vmulq_f32(d, lo));
            vst1q_f32(xb.as_mut_ptr().add(k + 4), vmulq_f32(d, hi));
        }
    }

    /// # Safety
    /// `ob` is one whole `Q4_K` super-block (144 bytes), `xb` exactly
    /// 256 elements.
    pub unsafe fn block_q4k(ob: &[u8], xb: &mut [f32]) {
        let d = get_f16(ob, 0);
        let dmin = get_f16(ob, 2);
        let mask = vdupq_n_u8(0x0F);
        for j in 0..8 {
            let (sc, mn) = q4k::unpack_scale_min_6(&ob[4..16], j);
            let sd = vdupq_n_f32(d * sc as f32);
            let sm = vdupq_n_f32(dmin * mn as f32);
            let qs = vld1q_u8(ob.as_ptr().add(16 + 16 * j));
            let lo = vandq_u8(qs, mask);
            let hi = vshrq_n_u8::<4>(qs);
            // Interleaving restores the scalar output order
            // (lo0, hi0, lo1, hi1, …): codes 0..16, then 16..32.
            let parts = [vzip1q_u8(lo, hi), vzip2q_u8(lo, hi)];
            let out = xb.as_mut_ptr().add(32 * j);
            for (h, &v) in parts.iter().enumerate() {
                let halves = [vmovl_u8(vget_low_u8(v)), vmovl_u8(vget_high_u8(v))];
                for (g, &w16) in halves.iter().enumerate() {
                    let f0 = vcvtq_f32_u32(vmovl_u16(vget_low_u16(w16)));
                    let f1 = vcvtq_f32_u32(vmovl_u16(vget_high_u16(w16)));
                    let o = out.add(16 * h + 8 * g);
                    vst1q_f32(o, vsubq_f32(vmulq_f32(sd, f0), sm));
                    vst1q_f32(o.add(4), vsubq_f32(vmulq_f32(sd, f1), sm));
                }
            }
        }
    }
}

/// A per-block decoder and a lane accumulator — the two function
/// pointers one dispatch arm plugs into the shared kernels.
type BlockDecoder = fn(&[u8], &mut [f32]);
type Accumulator = fn(&mut [f32; LANES], &[f32], &[f32]);

// Safe wrappers over the intrinsic bodies: the `simd` arm is only ever
// selected after [`simd_available`] returned true (enforced in
// [`arm_kernels`] / [`decode_blocks_arm`]), which is exactly the
// intrinsics' safety requirement; slice-shape preconditions match the
// `lanes` kernels the callers already uphold.
#[cfg(target_arch = "x86_64")]
fn accumulate_simd(acc: &mut [f32; LANES], w: &[f32], x: &[f32]) {
    unsafe { avx2::accumulate(acc, w, x) }
}
#[cfg(target_arch = "x86_64")]
fn block_q8_0_simd(ob: &[u8], xb: &mut [f32]) {
    unsafe { avx2::block_q8_0(ob, xb) }
}
#[cfg(target_arch = "x86_64")]
fn block_q4k_simd(ob: &[u8], xb: &mut [f32]) {
    unsafe { avx2::block_q4k(ob, xb) }
}
#[cfg(target_arch = "aarch64")]
fn accumulate_simd(acc: &mut [f32; LANES], w: &[f32], x: &[f32]) {
    unsafe { neon::accumulate(acc, w, x) }
}
#[cfg(target_arch = "aarch64")]
fn block_q8_0_simd(ob: &[u8], xb: &mut [f32]) {
    unsafe { neon::block_q8_0(ob, xb) }
}
#[cfg(target_arch = "aarch64")]
fn block_q4k_simd(ob: &[u8], xb: &mut [f32]) {
    unsafe { neon::block_q4k(ob, xb) }
}

/// The intrinsic per-block decoder for `fmt` on this target, if one
/// exists. `None` falls back to the `lanes` decoder *inside* the
/// `simd` arm (still bit-identical, just not hand-scheduled) — the
/// per-arch coverage is documented in `quant/mod.rs`.
fn simd_block_decoder(fmt: QuantFormat) -> Option<BlockDecoder> {
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    {
        match fmt {
            QuantFormat::Q8_0 => Some(block_q8_0_simd),
            QuantFormat::Q4K => Some(block_q4k_simd),
            _ => None,
        }
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = fmt;
        None
    }
}

/// The (block decoder, lane accumulator) pair for a *non-raw* format
/// under one arm. `Scalar` never reaches here (callers route it to the
/// format modules / [`vec_dot_ref`]); a `simd` request on a host
/// without support degrades to the `lanes` pair, keeping every entry
/// point total.
fn arm_kernels(fmt: QuantFormat, arm: DispatchArm) -> (BlockDecoder, Accumulator) {
    if matches!(arm, DispatchArm::Simd) && simd_available() {
        #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
        {
            let decode = simd_block_decoder(fmt).unwrap_or_else(|| fast_block_decoder(fmt));
            return (decode, accumulate_simd);
        }
    }
    (fast_block_decoder(fmt), accumulate)
}

/// The scalar-arm batch decode: the format modules' reference loops.
fn decode_blocks_scalar(fmt: QuantFormat, bytes: &[u8], out: &mut [f32]) {
    match fmt {
        QuantFormat::Q8_0 => q8_0::dequantize(bytes, out),
        QuantFormat::Q6K => q6k::dequantize(bytes, out),
        QuantFormat::Q5K => q5k::dequantize(bytes, out),
        QuantFormat::Q4K => q4k::dequantize(bytes, out),
        QuantFormat::Q3K => q3k::dequantize(bytes, out),
        QuantFormat::Q2K => q2k::dequantize(bytes, out),
        QuantFormat::F32 | QuantFormat::F16 => unreachable!("raw formats handled in dispatch"),
    }
}

/// Batch decode with an explicitly pinned [`DispatchArm`] — the seam
/// the cross-arm identity tests, `dsq selfcheck` and the forward
/// pass's pinned mode use. All arms are bit-identical; caller
/// guarantees whole blocks and exactly-sized buffers.
pub fn decode_blocks_arm(fmt: QuantFormat, bytes: &[u8], out: &mut [f32], arm: DispatchArm) {
    match fmt {
        // Raw formats have a single (already optimal) decode loop.
        QuantFormat::F32 => raw::F32Codec.decode_blocks(bytes, out),
        QuantFormat::F16 => raw::F16Codec.decode_blocks(bytes, out),
        _ => match arm {
            DispatchArm::Scalar => decode_blocks_scalar(fmt, bytes, out),
            arm => {
                let (decode, _) = arm_kernels(fmt, arm);
                let bb = fmt.block_bytes();
                let bw = fmt.block_weights();
                for (ob, xb) in bytes.chunks_exact(bb).zip(out.chunks_exact_mut(bw)) {
                    decode(ob, xb);
                }
            }
        },
    }
}

/// Batch decode with the dispatch arm pinned two-ways (`fast == true`
/// selects the lane kernels, `false` the format modules' scalar
/// loops). Kept as the PR-3 seam; [`decode_blocks_arm`] is the
/// three-arm generalization.
pub fn decode_blocks_pinned(fmt: QuantFormat, bytes: &[u8], out: &mut [f32], fast: bool) {
    let arm = if fast { DispatchArm::Lanes } else { DispatchArm::Scalar };
    decode_blocks_arm(fmt, bytes, out, arm);
}

/// Runtime-dispatched batch decode (the `BlockCodec::decode_blocks`
/// body for every block format).
pub(crate) fn decode_blocks_auto(fmt: QuantFormat, bytes: &[u8], out: &mut [f32]) {
    decode_blocks_arm(fmt, bytes, out, active_arm());
}

// --- fused vec_dot / vec_dot_mat ---

/// Fused dot over one (decoder, accumulator) kernel pair: each block
/// is decoded into a stack buffer and multiplied straight into the
/// lanes.
fn vec_dot_kernel(
    fmt: QuantFormat,
    kern: (BlockDecoder, Accumulator),
    bytes: &[u8],
    x: &[f32],
) -> f32 {
    let bb = fmt.block_bytes();
    let bw = fmt.block_weights();
    let (decode, acc_fn) = kern;
    let mut acc = [0f32; LANES];
    let mut buf = [0f32; QK_K];
    for (ob, xs) in bytes.chunks_exact(bb).zip(x.chunks_exact(bw)) {
        let wb = &mut buf[..bw];
        decode(ob, wb);
        acc_fn(&mut acc, wb, xs);
    }
    hsum(&acc)
}

/// Column-panel width of the GEMM kernel: each decoded block is
/// amortized over up to this many activation columns while the
/// per-column accumulators (`MAT_COLS × LANES` f32, 512 bytes) stay on
/// the stack.
pub const MAT_COLS: usize = 16;

/// GEMM kernel for one encoded row against a `[t][n]` activation panel
/// (`t = out.len()` contiguous columns of `n` weights each): decode
/// each block **once per [`MAT_COLS`] columns** and run the canonical
/// lane accumulation per column, so `out[c]` is bit-identical to the
/// fused dot of `bytes` with column `c` alone — the accumulate calls
/// any single column sees happen in exactly the per-column order.
fn vec_dot_mat_kernel(
    fmt: QuantFormat,
    kern: (BlockDecoder, Accumulator),
    bytes: &[u8],
    xs: &[f32],
    n: usize,
    out: &mut [f32],
) {
    let bb = fmt.block_bytes();
    let bw = fmt.block_weights();
    let (decode, acc_fn) = kern;
    let t = out.len();
    let mut buf = [0f32; QK_K];
    let mut c0 = 0usize;
    while c0 < t {
        let tc = (t - c0).min(MAT_COLS);
        let mut acc = [[0f32; LANES]; MAT_COLS];
        for (bi, ob) in bytes.chunks_exact(bb).enumerate() {
            let wb = &mut buf[..bw];
            decode(ob, wb);
            let off = bi * bw;
            for (c, a) in acc[..tc].iter_mut().enumerate() {
                let col = &xs[(c0 + c) * n + off..(c0 + c) * n + off + bw];
                acc_fn(a, wb, col);
            }
        }
        for (c, a) in acc[..tc].iter().enumerate() {
            out[c0 + c] = hsum(a);
        }
        c0 += tc;
    }
}

/// Fused dot for raw little-endian f32 payloads.
pub(crate) fn vec_dot_f32(bytes: &[u8], x: &[f32]) -> f32 {
    let mut acc = [0f32; LANES];
    let head = x.len() / LANES * LANES;
    for (bc, xc) in bytes[..head * 4]
        .chunks_exact(4 * LANES)
        .zip(x[..head].chunks_exact(LANES))
    {
        for l in 0..LANES {
            let w = f32::from_le_bytes(bc[4 * l..4 * l + 4].try_into().unwrap());
            acc[l] += w * xc[l];
        }
    }
    for (l, (bc, &xv)) in bytes[head * 4..]
        .chunks_exact(4)
        .zip(x[head..].iter())
        .enumerate()
    {
        acc[l] += f32::from_le_bytes(bc.try_into().unwrap()) * xv;
    }
    hsum(&acc)
}

/// Fused dot for raw little-endian f16 payloads.
pub(crate) fn vec_dot_f16(bytes: &[u8], x: &[f32]) -> f32 {
    let mut acc = [0f32; LANES];
    let head = x.len() / LANES * LANES;
    for (bc, xc) in bytes[..head * 2]
        .chunks_exact(2 * LANES)
        .zip(x[..head].chunks_exact(LANES))
    {
        for l in 0..LANES {
            let bits = u16::from_le_bytes([bc[2 * l], bc[2 * l + 1]]);
            acc[l] += crate::util::f16::f16_bits_to_f32(bits) * xc[l];
        }
    }
    for (l, (bc, &xv)) in bytes[head * 2..]
        .chunks_exact(2)
        .zip(x[head..].iter())
        .enumerate()
    {
        let bits = u16::from_le_bytes([bc[0], bc[1]]);
        acc[l] += crate::util::f16::f16_bits_to_f32(bits) * xv;
    }
    hsum(&acc)
}

/// The scalar-reference fused dot: decode each block with the codec's
/// (scalar) `decode_block` into a stack buffer and accumulate in the
/// canonical lane order. This is both the `DSQ_SCALAR_DECODE=1` arm
/// and the default [`BlockCodec::vec_dot`] implementation; the lane
/// kernels are bit-identical to it by construction.
pub fn vec_dot_ref<C: BlockCodec + ?Sized>(c: &C, bytes: &[u8], x: &[f32]) -> f32 {
    let bw = c.block_weights();
    let bb = c.block_bytes();
    let mut acc = [0f32; LANES];
    let mut buf = [0f32; QK_K];
    if bw % LANES != 0 {
        // Raw formats (block of one weight): keep the global lane order.
        for (i, (ob, &xv)) in bytes.chunks_exact(bb).zip(x.iter()).enumerate() {
            c.decode_block(ob, &mut buf[..bw]);
            acc[i % LANES] += buf[0] * xv;
        }
        return hsum(&acc);
    }
    for (ob, xs) in bytes.chunks_exact(bb).zip(x.chunks_exact(bw)) {
        let wb = &mut buf[..bw];
        c.decode_block(ob, wb);
        accumulate(&mut acc, wb, xs);
    }
    hsum(&acc)
}

/// Fused dot with an explicitly pinned [`DispatchArm`]. Caller
/// guarantees `bytes.len() == fmt.row_bytes(x.len())`.
pub fn vec_dot_arm(fmt: QuantFormat, bytes: &[u8], x: &[f32], arm: DispatchArm) -> f32 {
    match fmt {
        // Raw formats: one code path for every arm (the "decode" is a
        // plain byte load either way).
        QuantFormat::F32 => vec_dot_f32(bytes, x),
        QuantFormat::F16 => vec_dot_f16(bytes, x),
        _ => match arm {
            DispatchArm::Scalar => vec_dot_ref(codec(fmt), bytes, x),
            arm => vec_dot_kernel(fmt, arm_kernels(fmt, arm), bytes, x),
        },
    }
}

/// Fused dot with the dispatch arm pinned two-ways (see
/// [`decode_blocks_pinned`]); [`vec_dot_arm`] is the three-arm
/// generalization.
pub fn vec_dot_pinned(fmt: QuantFormat, bytes: &[u8], x: &[f32], fast: bool) -> f32 {
    let arm = if fast { DispatchArm::Lanes } else { DispatchArm::Scalar };
    vec_dot_arm(fmt, bytes, x, arm)
}

/// Runtime-dispatched fused dot (the `BlockCodec::vec_dot` body for
/// every block format).
pub(crate) fn vec_dot_auto(fmt: QuantFormat, bytes: &[u8], x: &[f32]) -> f32 {
    vec_dot_arm(fmt, bytes, x, active_arm())
}

/// GEMM row-panel dot with an explicitly pinned arm:
/// `out[c] = vec_dot(bytes, xs[c·n .. (c+1)·n])` bit-for-bit for each
/// of the `out.len()` columns, with each quantized block decoded once
/// per [`MAT_COLS`] columns instead of once per column. The scalar arm
/// and the raw formats run the per-column fused dots directly (nothing
/// to amortize there). Caller guarantees
/// `bytes.len() == fmt.row_bytes(n)` and `xs.len() == n · out.len()`.
pub fn vec_dot_mat_arm(
    fmt: QuantFormat,
    bytes: &[u8],
    xs: &[f32],
    n: usize,
    out: &mut [f32],
    arm: DispatchArm,
) {
    debug_assert_eq!(xs.len(), n * out.len());
    if n == 0 {
        out.fill(0.0);
        return;
    }
    match fmt {
        QuantFormat::F32 => {
            for (o, col) in out.iter_mut().zip(xs.chunks_exact(n)) {
                *o = vec_dot_f32(bytes, col);
            }
        }
        QuantFormat::F16 => {
            for (o, col) in out.iter_mut().zip(xs.chunks_exact(n)) {
                *o = vec_dot_f16(bytes, col);
            }
        }
        _ => match arm {
            DispatchArm::Scalar => {
                let c = codec(fmt);
                for (o, col) in out.iter_mut().zip(xs.chunks_exact(n)) {
                    *o = vec_dot_ref(c, bytes, col);
                }
            }
            arm => vec_dot_mat_kernel(fmt, arm_kernels(fmt, arm), bytes, xs, n, out),
        },
    }
}

/// Runtime-dispatched GEMM row-panel dot (the `BlockCodec::vec_dot_mat`
/// body for every block format).
pub(crate) fn vec_dot_mat_auto(
    fmt: QuantFormat,
    bytes: &[u8],
    xs: &[f32],
    n: usize,
    out: &mut [f32],
) {
    vec_dot_mat_arm(fmt, bytes, xs, n, out, active_arm());
}

/// Shared body of the per-format in-module identity tests (q2k … q8_0
/// each pin their own seed): every available decode arm is
/// bit-identical to the scalar reference, every `vec_dot` arm equals
/// the canonical decode-then-lane-dot reduction, and every
/// `vec_dot_mat` arm equals the per-column `vec_dot` loop.
#[cfg(test)]
pub(crate) fn assert_decode_and_vec_dot_identity(fmt: QuantFormat, seed: u64) {
    let n = fmt.block_weights() * 3;
    let mut rng = crate::util::rng::Pcg::new(seed);
    let src: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
    let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
    let packed = super::quantize(fmt, &src, None).unwrap();
    let mut scalar = vec![0f32; n];
    decode_blocks_arm(fmt, &packed, &mut scalar, DispatchArm::Scalar);
    let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
    let want = dot_lanes(&scalar, &x);
    let t = 3usize;
    let xs: Vec<f32> = (0..t * n).map(|_| rng.next_normal()).collect();
    for arm in DispatchArm::ALL {
        if !arm.available() {
            continue;
        }
        let mut decoded = vec![0f32; n];
        decode_blocks_arm(fmt, &packed, &mut decoded, arm);
        assert_eq!(bits(&decoded), bits(&scalar), "{fmt} decode arm {}", arm.name());
        let got = vec_dot_arm(fmt, &packed, &x, arm);
        assert_eq!(got.to_bits(), want.to_bits(), "{fmt} vec_dot arm {}", arm.name());
        let mut mat = vec![0f32; t];
        vec_dot_mat_arm(fmt, &packed, &xs, n, &mut mat, arm);
        for (c, &got) in mat.iter().enumerate() {
            let want = vec_dot_arm(fmt, &packed, &xs[c * n..(c + 1) * n], DispatchArm::Scalar);
            assert_eq!(got.to_bits(), want.to_bits(), "{fmt} vec_dot_mat[{c}] arm {}", arm.name());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{self, quantize};
    use crate::util::rng::Pcg;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn decode_arms_bit_identical_every_format() {
        for fmt in QuantFormat::ALL {
            for nblocks in [1usize, 3, 9] {
                let n = fmt.block_weights() * nblocks;
                let mut rng = Pcg::new(0xDEC0 + fmt.block_bytes() as u64 + nblocks as u64);
                let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
                let packed = quantize(fmt, &data, None).unwrap();
                let mut fast = vec![0f32; n];
                let mut scalar = vec![0f32; n];
                decode_blocks_pinned(fmt, &packed, &mut fast, true);
                decode_blocks_pinned(fmt, &packed, &mut scalar, false);
                assert_eq!(bits(&fast), bits(&scalar), "{fmt} nblocks={nblocks}");
            }
        }
    }

    #[test]
    fn vec_dot_arms_match_decode_then_dot() {
        for fmt in QuantFormat::ALL {
            let n = fmt.block_weights() * 5;
            let mut rng = Pcg::new(0xD07 + fmt.block_bytes() as u64);
            let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let packed = quantize(fmt, &data, None).unwrap();
            let mut decoded = vec![0f32; n];
            decode_blocks_pinned(fmt, &packed, &mut decoded, false);
            let want = dot_lanes(&decoded, &x);
            for fast in [false, true] {
                let got = vec_dot_pinned(fmt, &packed, &x, fast);
                assert_eq!(got.to_bits(), want.to_bits(), "{fmt} fast={fast}");
            }
        }
    }

    #[test]
    fn vec_dot_raw_handles_ragged_lengths() {
        // f32/f16 rows need not be lane multiples; the remainder lanes
        // must still follow the global `i % LANES` order.
        for &n in &[1usize, 5, 8, 13, 16, 31] {
            let mut rng = Pcg::new(0xA6 + n as u64);
            let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            for fmt in [QuantFormat::F32, QuantFormat::F16] {
                let packed = quantize(fmt, &data, None).unwrap();
                let decoded = quant::dequantize(fmt, &packed, n).unwrap();
                let want = dot_lanes(&decoded, &x);
                for fast in [false, true] {
                    let got = vec_dot_pinned(fmt, &packed, &x, fast);
                    assert_eq!(got.to_bits(), want.to_bits(), "{fmt} n={n} fast={fast}");
                }
            }
        }
    }

    #[test]
    fn dot_lanes_matches_plain_lane_loop() {
        for &n in &[1usize, 7, 8, 9, 64, 100] {
            let mut rng = Pcg::new(0x1A + n as u64);
            let w: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let mut acc = [0f32; LANES];
            for (i, (&wv, &xv)) in w.iter().zip(&x).enumerate() {
                acc[i % LANES] += wv * xv;
            }
            assert_eq!(dot_lanes(&w, &x).to_bits(), hsum(&acc).to_bits(), "n={n}");
        }
    }

    #[test]
    fn fast_decode_total_on_random_bytes() {
        // Arbitrary byte patterns must decode without panicking through
        // the fast arm too (mirrors the scalar totality property test).
        let mut rng = Pcg::new(0xBAD);
        for fmt in QuantFormat::ALL {
            let n = fmt.block_weights() * 4;
            let nb = fmt.row_bytes(n).unwrap();
            let bytes: Vec<u8> = (0..nb).map(|_| rng.next_u64() as u8).collect();
            let mut out = vec![0f32; n];
            decode_blocks_pinned(fmt, &bytes, &mut out, true);
            let x = vec![1.0f32; n];
            let _ = vec_dot_pinned(fmt, &bytes, &x, true);
        }
    }
}
