//! Decode-side lane kernels: batch block decoding and fused
//! quantized-dot (`vec_dot`) for every builtin format.
//!
//! PR 2 made the *write* side fast (SIMD-specialized scale search); this
//! module is the matching *read* side. Two things live here:
//!
//! - **Batch decode kernels** (`decode_blocks_*`): the per-format
//!   `dequantize` loops in the format modules recompute sub-block
//!   scales once per *element* (a division, an unpack and two f16
//!   loads per weight). The kernels here hoist all per-sub-block work
//!   out of the inner loop and walk the code planes byte-by-byte with
//!   branch-free nibble/bit extraction, which the autovectorizer can
//!   lower in release builds. The hoisting is algebraically a no-op:
//!   each element still evaluates the exact same f32 expression in the
//!   same order (e.g. `(d·sc)·c − (dmin·m)` for `Q4_K`), so the fast
//!   kernels are **bit-identical** to the module loops.
//! - **Fused `vec_dot` kernels**: dot products computed directly on
//!   encoded blocks — each super-block is decoded into a stack buffer
//!   (never touching main memory) and multiplied into eight persistent
//!   f32 accumulator lanes. `vec_dot(q, x)` is defined to equal
//!   [`dot_lanes`]`(decode_blocks(q), x)` **bit-for-bit**.
//!
//! ## The reduction-order contract
//!
//! [`dot_lanes`] is the one canonical dot product of the crate: element
//! `i` accumulates into lane `i % LANES` (the shared
//! [`super::simd::LANES`] = 8), each lane is a sequential f32 sum, and
//! the horizontal reduction is the shared `simd::hsum` fold.
//! No implicit FMA exists anywhere on the path (Rust never contracts
//! `a*b + c`). Every arm — the fused kernels, the scalar reference
//! ([`vec_dot_ref`]: scalar block decode + lane dot), and every
//! row-parallel thread count — therefore produces the same bits.
//!
//! ## Dispatch
//!
//! Mirroring the encode side's `DSQ_SCALAR_SEARCH`, the env var
//! `DSQ_SCALAR_DECODE=1` pins the decode/vec_dot paths to the scalar
//! reference arm (the format modules' plain loops). Default is the lane
//! kernels. Both arms are pinned to the same golden fixtures in CI and
//! cross-checked by `dsq selfcheck` and `tests/decode_kernels.rs`.

use super::simd::{hsum, LANES};
use super::{codec, q2k, q3k, q4k, q5k, q6k, q8_0, raw, BlockCodec, QuantFormat, QK8_0, QK_K};
use crate::quant::scalar::get_f16;
use std::sync::OnceLock;

/// Whether the decode-side lane kernels are active. Default on; set
/// `DSQ_SCALAR_DECODE=1` to force the scalar reference loops (both
/// arms are bit-identical — the switch exists for benchmarking and for
/// pinning CI drift tests to either arm). Read once per process.
pub fn decode_kernels_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        !matches!(
            std::env::var("DSQ_SCALAR_DECODE").as_deref(),
            Ok("1") | Ok("true") | Ok("yes")
        )
    })
}

/// The canonical lane-ordered dot product: element `i` → lane
/// `i % LANES`, sequential sums per lane, `simd::hsum` fold. This is the
/// reduction order `vec_dot` is contractually bit-identical to.
pub fn dot_lanes(w: &[f32], x: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), x.len());
    let mut acc = [0f32; LANES];
    let head = w.len() / LANES * LANES;
    for (wc, xc) in w[..head].chunks_exact(LANES).zip(x[..head].chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += wc[l] * xc[l];
        }
    }
    for (l, (&wv, &xv)) in w[head..].iter().zip(x[head..].iter()).enumerate() {
        acc[l] += wv * xv;
    }
    hsum(&acc)
}

/// Multiply one decoded run (a multiple of `LANES` long) into the
/// persistent accumulator lanes, preserving the global lane order.
#[inline(always)]
fn accumulate(acc: &mut [f32; LANES], w: &[f32], x: &[f32]) {
    for (wc, xc) in w.chunks_exact(LANES).zip(x.chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += wc[l] * xc[l];
        }
    }
}

// --- per-block fast decoders (bit-identical to the module loops) ---

fn block_q8_0(ob: &[u8], xb: &mut [f32]) {
    let d = get_f16(ob, 0);
    for (x, &q) in xb.iter_mut().zip(&ob[2..2 + QK8_0]) {
        *x = d * (q as i8) as f32;
    }
}

fn block_q2k(ob: &[u8], xb: &mut [f32]) {
    let d = get_f16(ob, 80);
    let dmin = get_f16(ob, 82);
    for j in 0..16 {
        let sd = d * (ob[j] & 0x0F) as f32;
        let sm = dmin * (ob[j] >> 4) as f32;
        let qs = &ob[16 + 4 * j..16 + 4 * j + 4];
        let xs = &mut xb[16 * j..16 * j + 16];
        for (&b, xq) in qs.iter().zip(xs.chunks_exact_mut(4)) {
            xq[0] = sd * (b & 0x03) as f32 - sm;
            xq[1] = sd * ((b >> 2) & 0x03) as f32 - sm;
            xq[2] = sd * ((b >> 4) & 0x03) as f32 - sm;
            xq[3] = sd * (b >> 6) as f32 - sm;
        }
    }
}

fn block_q3k(ob: &[u8], xb: &mut [f32]) {
    let d = get_f16(ob, 108);
    for j in 0..16 {
        let sc = q3k::unpack_scales_6x16(&ob[0..12], j) as i32 - 32;
        let ds = d * sc as f32;
        let qs = &ob[44 + 4 * j..44 + 4 * j + 4];
        let hm = &ob[12 + 2 * j..12 + 2 * j + 2];
        let xs = &mut xb[16 * j..16 * j + 16];
        for (t, xq) in xs.chunks_exact_mut(4).enumerate() {
            let b = qs[t];
            let h = hm[t >> 1];
            for (u, x) in xq.iter_mut().enumerate() {
                let k = 4 * t + u;
                let lo = (b >> (2 * u)) & 0x03;
                let hi = (h >> (k & 7)) & 0x01;
                *x = ds * ((lo | (hi << 2)) as i32 - 4) as f32;
            }
        }
    }
}

/// Shared `Q4_K` / `Q5_K` fast decoder (`qs_off` = 4-bit plane offset,
/// `high_bit` = fifth code bit in the 32-byte plane at offset 16).
fn block_q45k(ob: &[u8], xb: &mut [f32], qs_off: usize, high_bit: bool) {
    let d = get_f16(ob, 0);
    let dmin = get_f16(ob, 2);
    for j in 0..8 {
        let (sc, mn) = q4k::unpack_scale_min_6(&ob[4..16], j);
        let sd = d * sc as f32;
        let sm = dmin * mn as f32;
        let qs = &ob[qs_off + 16 * j..qs_off + 16 * j + 16];
        let xs = &mut xb[32 * j..32 * j + 32];
        if high_bit {
            let qh = &ob[16 + 4 * j..16 + 4 * j + 4];
            for (k2, xq) in xs.chunks_exact_mut(2).enumerate() {
                let b = qs[k2];
                let h = qh[k2 >> 2];
                let k = 2 * k2;
                let h0 = ((h >> (k & 7)) & 1) << 4;
                let h1 = ((h >> ((k + 1) & 7)) & 1) << 4;
                xq[0] = sd * ((b & 0x0F) | h0) as f32 - sm;
                xq[1] = sd * ((b >> 4) | h1) as f32 - sm;
            }
        } else {
            for (&b, xq) in qs.iter().zip(xs.chunks_exact_mut(2)) {
                xq[0] = sd * (b & 0x0F) as f32 - sm;
                xq[1] = sd * (b >> 4) as f32 - sm;
            }
        }
    }
}

fn block_q6k(ob: &[u8], xb: &mut [f32]) {
    let d = get_f16(ob, 208);
    for j in 0..16 {
        let dsc = d * (ob[192 + j] as i8) as f32;
        let ql = &ob[8 * j..8 * j + 8];
        let qh = &ob[128 + 4 * j..128 + 4 * j + 4];
        let xs = &mut xb[16 * j..16 * j + 16];
        for (k2, xq) in xs.chunks_exact_mut(2).enumerate() {
            let b = ql[k2];
            let h = qh[k2 >> 1];
            let k = 2 * k2;
            let hi0 = (h >> (2 * (k & 3))) & 0x03;
            let hi1 = (h >> (2 * ((k + 1) & 3))) & 0x03;
            xq[0] = dsc * (((b & 0x0F) | (hi0 << 4)) as i32 - 32) as f32;
            xq[1] = dsc * (((b >> 4) | (hi1 << 4)) as i32 - 32) as f32;
        }
    }
}

fn block_q5k(ob: &[u8], xb: &mut [f32]) {
    block_q45k(ob, xb, 48, true)
}

fn block_q4k(ob: &[u8], xb: &mut [f32]) {
    block_q45k(ob, xb, 16, false)
}

/// The fast per-block decoder for one k-quant/`Q8_0` format — the one
/// seam both [`decode_blocks_fast`] and [`vec_dot_fast`] select
/// through, so a new block format needs exactly one registration here.
fn fast_block_decoder(fmt: QuantFormat) -> fn(&[u8], &mut [f32]) {
    match fmt {
        QuantFormat::Q8_0 => block_q8_0,
        QuantFormat::Q6K => block_q6k,
        QuantFormat::Q5K => block_q5k,
        QuantFormat::Q4K => block_q4k,
        QuantFormat::Q3K => block_q3k,
        QuantFormat::Q2K => block_q2k,
        QuantFormat::F32 | QuantFormat::F16 => unreachable!("raw formats handled in dispatch"),
    }
}

/// The fast batch decoder for one k-quant/`Q8_0` format. Caller
/// guarantees whole blocks and exactly-sized buffers.
fn decode_blocks_fast(fmt: QuantFormat, bytes: &[u8], out: &mut [f32]) {
    let bb = fmt.block_bytes();
    let bw = fmt.block_weights();
    let decode = fast_block_decoder(fmt);
    for (ob, xb) in bytes.chunks_exact(bb).zip(out.chunks_exact_mut(bw)) {
        decode(ob, xb);
    }
}

/// Batch decode with the dispatch arm pinned (`fast == true` selects
/// the lane kernels, `false` the format modules' scalar loops). The
/// seam the cross-arm identity tests and `dsq selfcheck` use; both
/// arms are bit-identical.
pub fn decode_blocks_pinned(fmt: QuantFormat, bytes: &[u8], out: &mut [f32], fast: bool) {
    match fmt {
        // Raw formats have a single (already optimal) decode loop.
        QuantFormat::F32 => raw::F32Codec.decode_blocks(bytes, out),
        QuantFormat::F16 => raw::F16Codec.decode_blocks(bytes, out),
        _ if fast => decode_blocks_fast(fmt, bytes, out),
        QuantFormat::Q8_0 => q8_0::dequantize(bytes, out),
        QuantFormat::Q6K => q6k::dequantize(bytes, out),
        QuantFormat::Q5K => q5k::dequantize(bytes, out),
        QuantFormat::Q4K => q4k::dequantize(bytes, out),
        QuantFormat::Q3K => q3k::dequantize(bytes, out),
        QuantFormat::Q2K => q2k::dequantize(bytes, out),
    }
}

/// Runtime-dispatched batch decode (the `BlockCodec::decode_blocks`
/// body for every block format).
pub(crate) fn decode_blocks_auto(fmt: QuantFormat, bytes: &[u8], out: &mut [f32]) {
    decode_blocks_pinned(fmt, bytes, out, decode_kernels_enabled());
}

// --- fused vec_dot ---

/// Fused dot over the fast per-block decoders: each block is decoded
/// into a stack buffer and multiplied straight into the lanes.
fn vec_dot_fast(fmt: QuantFormat, bytes: &[u8], x: &[f32]) -> f32 {
    let bb = fmt.block_bytes();
    let bw = fmt.block_weights();
    let decode = fast_block_decoder(fmt);
    let mut acc = [0f32; LANES];
    let mut buf = [0f32; QK_K];
    for (ob, xs) in bytes.chunks_exact(bb).zip(x.chunks_exact(bw)) {
        let wb = &mut buf[..bw];
        decode(ob, wb);
        accumulate(&mut acc, wb, xs);
    }
    hsum(&acc)
}

/// Fused dot for raw little-endian f32 payloads.
pub(crate) fn vec_dot_f32(bytes: &[u8], x: &[f32]) -> f32 {
    let mut acc = [0f32; LANES];
    let head = x.len() / LANES * LANES;
    for (bc, xc) in bytes[..head * 4]
        .chunks_exact(4 * LANES)
        .zip(x[..head].chunks_exact(LANES))
    {
        for l in 0..LANES {
            let w = f32::from_le_bytes(bc[4 * l..4 * l + 4].try_into().unwrap());
            acc[l] += w * xc[l];
        }
    }
    for (l, (bc, &xv)) in bytes[head * 4..]
        .chunks_exact(4)
        .zip(x[head..].iter())
        .enumerate()
    {
        acc[l] += f32::from_le_bytes(bc.try_into().unwrap()) * xv;
    }
    hsum(&acc)
}

/// Fused dot for raw little-endian f16 payloads.
pub(crate) fn vec_dot_f16(bytes: &[u8], x: &[f32]) -> f32 {
    let mut acc = [0f32; LANES];
    let head = x.len() / LANES * LANES;
    for (bc, xc) in bytes[..head * 2]
        .chunks_exact(2 * LANES)
        .zip(x[..head].chunks_exact(LANES))
    {
        for l in 0..LANES {
            let bits = u16::from_le_bytes([bc[2 * l], bc[2 * l + 1]]);
            acc[l] += crate::util::f16::f16_bits_to_f32(bits) * xc[l];
        }
    }
    for (l, (bc, &xv)) in bytes[head * 2..]
        .chunks_exact(2)
        .zip(x[head..].iter())
        .enumerate()
    {
        let bits = u16::from_le_bytes([bc[0], bc[1]]);
        acc[l] += crate::util::f16::f16_bits_to_f32(bits) * xv;
    }
    hsum(&acc)
}

/// The scalar-reference fused dot: decode each block with the codec's
/// (scalar) `decode_block` into a stack buffer and accumulate in the
/// canonical lane order. This is both the `DSQ_SCALAR_DECODE=1` arm
/// and the default [`BlockCodec::vec_dot`] implementation; the lane
/// kernels are bit-identical to it by construction.
pub fn vec_dot_ref<C: BlockCodec + ?Sized>(c: &C, bytes: &[u8], x: &[f32]) -> f32 {
    let bw = c.block_weights();
    let bb = c.block_bytes();
    let mut acc = [0f32; LANES];
    let mut buf = [0f32; QK_K];
    if bw % LANES != 0 {
        // Raw formats (block of one weight): keep the global lane order.
        for (i, (ob, &xv)) in bytes.chunks_exact(bb).zip(x.iter()).enumerate() {
            c.decode_block(ob, &mut buf[..bw]);
            acc[i % LANES] += buf[0] * xv;
        }
        return hsum(&acc);
    }
    for (ob, xs) in bytes.chunks_exact(bb).zip(x.chunks_exact(bw)) {
        let wb = &mut buf[..bw];
        c.decode_block(ob, wb);
        accumulate(&mut acc, wb, xs);
    }
    hsum(&acc)
}

/// Fused dot with the dispatch arm pinned (see
/// [`decode_blocks_pinned`]). Caller guarantees
/// `bytes.len() == fmt.row_bytes(x.len())`.
pub fn vec_dot_pinned(fmt: QuantFormat, bytes: &[u8], x: &[f32], fast: bool) -> f32 {
    match fmt {
        // Raw formats: one code path for both arms (the "decode" is a
        // plain byte load either way).
        QuantFormat::F32 => vec_dot_f32(bytes, x),
        QuantFormat::F16 => vec_dot_f16(bytes, x),
        _ if fast => vec_dot_fast(fmt, bytes, x),
        _ => vec_dot_ref(codec(fmt), bytes, x),
    }
}

/// Runtime-dispatched fused dot (the `BlockCodec::vec_dot` body for
/// every block format).
pub(crate) fn vec_dot_auto(fmt: QuantFormat, bytes: &[u8], x: &[f32]) -> f32 {
    vec_dot_pinned(fmt, bytes, x, decode_kernels_enabled())
}

/// Shared body of the per-format in-module identity tests (q2k … q8_0
/// each pin their own seed): the fast and scalar decode arms are
/// bit-identical, and both `vec_dot` arms equal the canonical
/// decode-then-lane-dot reduction.
#[cfg(test)]
pub(crate) fn assert_decode_and_vec_dot_identity(fmt: QuantFormat, seed: u64) {
    let n = fmt.block_weights() * 3;
    let mut rng = crate::util::rng::Pcg::new(seed);
    let src: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
    let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
    let packed = super::quantize(fmt, &src, None).unwrap();
    let mut fast = vec![0f32; n];
    let mut scalar = vec![0f32; n];
    decode_blocks_pinned(fmt, &packed, &mut fast, true);
    decode_blocks_pinned(fmt, &packed, &mut scalar, false);
    let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&fast), bits(&scalar), "{fmt} decode arms");
    let want = dot_lanes(&scalar, &x);
    for arm in [false, true] {
        let got = vec_dot_pinned(fmt, &packed, &x, arm);
        assert_eq!(got.to_bits(), want.to_bits(), "{fmt} vec_dot fast={arm}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{self, quantize};
    use crate::util::rng::Pcg;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn decode_arms_bit_identical_every_format() {
        for fmt in QuantFormat::ALL {
            for nblocks in [1usize, 3, 9] {
                let n = fmt.block_weights() * nblocks;
                let mut rng = Pcg::new(0xDEC0 + fmt.block_bytes() as u64 + nblocks as u64);
                let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
                let packed = quantize(fmt, &data, None).unwrap();
                let mut fast = vec![0f32; n];
                let mut scalar = vec![0f32; n];
                decode_blocks_pinned(fmt, &packed, &mut fast, true);
                decode_blocks_pinned(fmt, &packed, &mut scalar, false);
                assert_eq!(bits(&fast), bits(&scalar), "{fmt} nblocks={nblocks}");
            }
        }
    }

    #[test]
    fn vec_dot_arms_match_decode_then_dot() {
        for fmt in QuantFormat::ALL {
            let n = fmt.block_weights() * 5;
            let mut rng = Pcg::new(0xD07 + fmt.block_bytes() as u64);
            let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let packed = quantize(fmt, &data, None).unwrap();
            let mut decoded = vec![0f32; n];
            decode_blocks_pinned(fmt, &packed, &mut decoded, false);
            let want = dot_lanes(&decoded, &x);
            for fast in [false, true] {
                let got = vec_dot_pinned(fmt, &packed, &x, fast);
                assert_eq!(got.to_bits(), want.to_bits(), "{fmt} fast={fast}");
            }
        }
    }

    #[test]
    fn vec_dot_raw_handles_ragged_lengths() {
        // f32/f16 rows need not be lane multiples; the remainder lanes
        // must still follow the global `i % LANES` order.
        for &n in &[1usize, 5, 8, 13, 16, 31] {
            let mut rng = Pcg::new(0xA6 + n as u64);
            let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            for fmt in [QuantFormat::F32, QuantFormat::F16] {
                let packed = quantize(fmt, &data, None).unwrap();
                let decoded = quant::dequantize(fmt, &packed, n).unwrap();
                let want = dot_lanes(&decoded, &x);
                for fast in [false, true] {
                    let got = vec_dot_pinned(fmt, &packed, &x, fast);
                    assert_eq!(got.to_bits(), want.to_bits(), "{fmt} n={n} fast={fast}");
                }
            }
        }
    }

    #[test]
    fn dot_lanes_matches_plain_lane_loop() {
        for &n in &[1usize, 7, 8, 9, 64, 100] {
            let mut rng = Pcg::new(0x1A + n as u64);
            let w: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let mut acc = [0f32; LANES];
            for (i, (&wv, &xv)) in w.iter().zip(&x).enumerate() {
                acc[i % LANES] += wv * xv;
            }
            assert_eq!(dot_lanes(&w, &x).to_bits(), hsum(&acc).to_bits(), "n={n}");
        }
    }

    #[test]
    fn fast_decode_total_on_random_bytes() {
        // Arbitrary byte patterns must decode without panicking through
        // the fast arm too (mirrors the scalar totality property test).
        let mut rng = Pcg::new(0xBAD);
        for fmt in QuantFormat::ALL {
            let n = fmt.block_weights() * 4;
            let nb = fmt.row_bytes(n).unwrap();
            let bytes: Vec<u8> = (0..nb).map(|_| rng.next_u64() as u8).collect();
            let mut out = vec![0f32; n];
            decode_blocks_pinned(fmt, &bytes, &mut out, true);
            let x = vec![1.0f32; n];
            let _ = vec_dot_pinned(fmt, &bytes, &x, true);
        }
    }
}
