//! `Q4_K` — 4-bit k-quant, super-block of 256, 144 bytes (4.5 bpw).
//!
//! 8 sub-blocks of 32 weights. Asymmetric:
//! `x_i = d · sc[j] · c_i − dmin · m[j]` with codes `c_i ∈ [0, 15]` and
//! 6-bit sub-block scales `sc[j]` / mins `m[j]` quantized against the
//! per-super-block f16 `d` / `dmin`.
//!
//! Layout per super-block (flat element order, sub-block `j = i / 32`):
//! ```text
//! [0..2)     f16 d
//! [2..4)     f16 dmin
//! [4..16)    packed 6-bit scales+mins (see [`pack_scale_min_6`])
//! [16..144)  qs[128]   4-bit codes: nibble (i&1) of qs[i>>1]
//! ```
//!
//! ### 6-bit scale/min packing
//!
//! 8 scales + 8 mins, 6 bits each = 12 bytes:
//! - byte `j` (j<8) = `sc[j] & 0x3F | (m[j] & 0x03) << 6`
//! - byte `8+k` (k<4) = `(m[2k] >> 2) | (m[2k+1] >> 2) << 4`
//!
//! i.e. `sc[j] = b[j] & 63`,
//! `m[j] = (b[j] >> 6) | ((b[8 + j/2] >> (4·(j&1))) & 0x0F) << 2`.
//!
//! Decode arms: scalar (this module), lane-chunked, **and** a
//! hand-written AVX2/NEON intrinsic decoder in [`super::kernels`] —
//! `Q4_K` is the paper's single-machine serving format, so it gets a
//! dedicated `simd`-arm body (see the arm matrix in [`super`]).

use super::scalar::{get_f16, make_qkx_quants, nearest_int, put_f16};
use super::QK_K;

pub const BLOCK_BYTES: usize = 144;
const SUB: usize = 32;
const NSUB: usize = QK_K / SUB;

/// Pack 8 six-bit scales and 8 six-bit mins into 12 bytes.
pub fn pack_scale_min_6(sc: &[u8; NSUB], mn: &[u8; NSUB], out: &mut [u8]) {
    debug_assert!(out.len() >= 12);
    for j in 0..NSUB {
        out[j] = (sc[j] & 0x3F) | ((mn[j] & 0x03) << 6);
    }
    for k in 0..4 {
        out[8 + k] = (mn[2 * k] >> 2) | ((mn[2 * k + 1] >> 2) << 4);
    }
}

/// Inverse of [`pack_scale_min_6`].
pub fn unpack_scale_min_6(b: &[u8], j: usize) -> (u8, u8) {
    let sc = b[j] & 0x3F;
    let m = (b[j] >> 6) | (((b[8 + j / 2] >> (4 * (j & 1))) & 0x0F) << 2);
    (sc, m)
}

pub fn quantize(src: &[f32], importance: Option<&[f32]>, out: &mut [u8]) {
    quantize_impl(src, importance, out, 15, BLOCK_BYTES, 16, false);
}

pub fn dequantize(bytes: &[u8], out: &mut [f32]) {
    dequantize_impl(bytes, out, BLOCK_BYTES, 16, false);
}

/// Shared implementation for `Q4_K` (`nmax=15`, no high bits) and `Q5_K`
/// (`nmax=31`, one high bit per code stored in a 32-byte plane before
/// `qs`). `qs_off` is the byte offset of the 4-bit code plane.
pub(crate) fn quantize_impl(
    src: &[f32],
    importance: Option<&[f32]>,
    out: &mut [u8],
    nmax: i32,
    block_bytes: usize,
    qs_off: usize,
    high_bit: bool,
) {
    debug_assert_eq!(src.len() % QK_K, 0);
    for (bi, (xb, ob)) in src
        .chunks_exact(QK_K)
        .zip(out.chunks_exact_mut(block_bytes))
        .enumerate()
    {
        let wb = importance.map(|w| &w[bi * QK_K..(bi + 1) * QK_K]);
        let mut scales = [0f32; NSUB];
        let mut mins = [0f32; NSUB];
        let mut codes = [0u8; QK_K];
        let mut max_scale = 0f32;
        let mut max_min = 0f32;
        for j in 0..NSUB {
            let xs = &xb[j * SUB..(j + 1) * SUB];
            let ws = wb.map(|w| &w[j * SUB..(j + 1) * SUB]);
            let (s, m) = make_qkx_quants(xs, nmax, ws, &mut codes[j * SUB..(j + 1) * SUB]);
            scales[j] = s;
            mins[j] = m;
            max_scale = max_scale.max(s);
            max_min = max_min.max(m);
        }
        let d = if max_scale > 0.0 { max_scale / 63.0 } else { 0.0 };
        let dmin = if max_min > 0.0 { max_min / 63.0 } else { 0.0 };
        put_f16(ob, 0, d);
        put_f16(ob, 2, dmin);
        let d = get_f16(ob, 0);
        let dmin = get_f16(ob, 2);
        let mut sc6 = [0u8; NSUB];
        let mut mn6 = [0u8; NSUB];
        for j in 0..NSUB {
            sc6[j] = if d > 0.0 {
                nearest_int(scales[j] / d).clamp(0, 63) as u8
            } else {
                0
            };
            mn6[j] = if dmin > 0.0 {
                nearest_int(mins[j] / dmin).clamp(0, 63) as u8
            } else {
                0
            };
        }
        pack_scale_min_6(&sc6, &mn6, &mut ob[4..16]);
        // Re-round codes against the reconstructed (quantized) scales.
        for j in 0..NSUB {
            let sd = d * sc6[j] as f32;
            let sm = dmin * mn6[j] as f32;
            for k in 0..SUB {
                let i = j * SUB + k;
                codes[i] = if sd > 0.0 {
                    nearest_int((xb[i] + sm) / sd).clamp(0, nmax) as u8
                } else {
                    0
                };
            }
        }
        // Pack the 4-bit plane (and the high-bit plane for Q5_K).
        let (head, qs) = ob.split_at_mut(qs_off);
        qs.fill(0);
        if high_bit {
            let qh = &mut head[16..48];
            qh.fill(0);
            for (i, &c) in codes.iter().enumerate() {
                qs[i >> 1] |= (c & 0x0F) << (4 * (i & 1));
                qh[i >> 3] |= (c >> 4) << (i & 7);
            }
        } else {
            for (i, &c) in codes.iter().enumerate() {
                qs[i >> 1] |= (c & 0x0F) << (4 * (i & 1));
            }
        }
    }
}

pub(crate) fn dequantize_impl(
    bytes: &[u8],
    out: &mut [f32],
    block_bytes: usize,
    qs_off: usize,
    high_bit: bool,
) {
    for (ob, xb) in bytes.chunks_exact(block_bytes).zip(out.chunks_exact_mut(QK_K)) {
        let d = get_f16(ob, 0);
        let dmin = get_f16(ob, 2);
        let qs = &ob[qs_off..];
        for i in 0..QK_K {
            let j = i / SUB;
            let (sc, mn) = unpack_scale_min_6(&ob[4..16], j);
            let mut c = (qs[i >> 1] >> (4 * (i & 1))) & 0x0F;
            if high_bit {
                c |= ((ob[16 + (i >> 3)] >> (i & 7)) & 1) << 4;
            }
            xb[i] = d * sc as f32 * c as f32 - dmin * mn as f32;
        }
    }
}

crate::quant::impl_block_codec!(crate::quant::QuantFormat::Q4K);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::error::rel_rmse;
    use crate::quant::{roundtrip, QuantFormat};
    use crate::util::rng::Pcg;

    #[test]
    fn scale_min_packing_roundtrips() {
        let mut rng = Pcg::new(5);
        for _ in 0..100 {
            let mut sc = [0u8; NSUB];
            let mut mn = [0u8; NSUB];
            for j in 0..NSUB {
                sc[j] = (rng.next_u64() % 64) as u8;
                mn[j] = (rng.next_u64() % 64) as u8;
            }
            let mut buf = [0u8; 12];
            pack_scale_min_6(&sc, &mn, &mut buf);
            for j in 0..NSUB {
                let (s, m) = unpack_scale_min_6(&buf, j);
                assert_eq!((s, m), (sc[j], mn[j]), "sub-block {j}");
            }
        }
    }

    #[test]
    fn q4k_accuracy_on_gaussian() {
        let mut rng = Pcg::new(13);
        let src: Vec<f32> = (0..QK_K * 4).map(|_| rng.next_normal()).collect();
        let rt = roundtrip(QuantFormat::Q4K, &src, None).unwrap();
        let err = rel_rmse(&src, &rt);
        assert!(err < 0.09, "q4_k rel rmse too high: {err}");
    }

    #[test]
    fn q4k_zero_block() {
        let src = vec![0f32; QK_K];
        let rt = roundtrip(QuantFormat::Q4K, &src, None).unwrap();
        assert_eq!(rt, src);
    }

    #[test]
    fn q4k_decode_kernel_and_vec_dot_bit_identical() {
        crate::quant::kernels::assert_decode_and_vec_dot_identity(
            crate::quant::QuantFormat::Q4K,
            0x4D,
        );
    }

    #[test]
    fn q4k_positive_shift_handled() {
        // All-positive data exercises the min path.
        let mut rng = Pcg::new(17);
        let src: Vec<f32> = (0..QK_K).map(|_| rng.next_normal().abs() + 2.0).collect();
        let rt = roundtrip(QuantFormat::Q4K, &src, None).unwrap();
        let err = rel_rmse(&src, &rt);
        assert!(err < 0.04, "q4_k rel rmse on shifted data: {err}");
    }
}
