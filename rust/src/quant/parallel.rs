//! Block-parallel codec execution.
//!
//! Every block format encodes each block independently into a disjoint
//! output range, so a tensor can be split into runs of whole blocks and
//! encoded on separate threads with **byte-identical** results — the
//! same per-block arithmetic runs either way, only the loop ownership
//! changes. This module provides that splitting over `std::thread::scope`
//! (no external thread-pool dependency).
//!
//! Two knobs:
//! - [`auto_threads`] — the default policy: serial below
//!   [`PAR_MIN_WEIGHTS`] (thread spawn costs more than it saves on small
//!   tensors), one thread per core above it.
//! - the explicit `threads` parameter on the `*_with` entry points in
//!   [`super`] — used by tests (to pin serial vs parallel) and by
//!   [`crate::container::quantize_container_with`], which parallelizes
//!   across tensors and therefore forces `threads = 1` per tensor.

use super::BlockCodec;

/// Minimum tensor size (in weights) before block-level threading is
/// worth the spawn overhead. One 256-weight super-block costs ~1µs to
/// encode; a thread spawn costs ~10µs, so the break-even run is a few
/// hundred blocks per worker.
pub const PAR_MIN_WEIGHTS: usize = 64 * 1024;

/// Worker threads this machine supports (≥ 1).
pub fn max_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Default thread count for an `n`-weight tensor.
pub fn auto_threads(n: usize) -> usize {
    if n < PAR_MIN_WEIGHTS {
        1
    } else {
        max_threads()
    }
}

/// Encode `src` into `out`, splitting whole blocks across up to
/// `threads` scoped threads. Caller guarantees `src.len()` is a
/// multiple of the block size and `out` is sized exactly.
pub(crate) fn encode_chunked(
    codec: &dyn BlockCodec,
    src: &[f32],
    importance: Option<&[f32]>,
    out: &mut [u8],
    threads: usize,
) {
    let bw = codec.block_weights();
    let bb = codec.block_bytes();
    let nblocks = src.len() / bw;
    let threads = threads.clamp(1, nblocks.max(1));
    if threads == 1 {
        codec.encode_blocks(src, importance, out);
        return;
    }
    let per = nblocks.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut src = src;
        let mut imp = importance;
        let mut out: &mut [u8] = out;
        while !src.is_empty() {
            let nb = (src.len() / bw).min(per);
            let (src_head, src_tail) = src.split_at(nb * bw);
            let (imp_head, imp_tail) = match imp {
                Some(w) => {
                    let (a, b) = w.split_at(nb * bw);
                    (Some(a), Some(b))
                }
                None => (None, None),
            };
            let (out_head, out_tail) = std::mem::take(&mut out).split_at_mut(nb * bb);
            src = src_tail;
            imp = imp_tail;
            out = out_tail;
            scope.spawn(move || codec.encode_blocks(src_head, imp_head, out_head));
        }
    });
}

/// Decode `bytes` into `out`, splitting whole blocks across up to
/// `threads` scoped threads. Caller guarantees sizes match.
pub(crate) fn decode_chunked(
    codec: &dyn BlockCodec,
    bytes: &[u8],
    out: &mut [f32],
    threads: usize,
) {
    let bw = codec.block_weights();
    let bb = codec.block_bytes();
    let nblocks = out.len() / bw;
    let threads = threads.clamp(1, nblocks.max(1));
    if threads == 1 {
        codec.decode_blocks(bytes, out);
        return;
    }
    let per = nblocks.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut bytes = bytes;
        let mut out: &mut [f32] = out;
        while !out.is_empty() {
            let nb = (out.len() / bw).min(per);
            let (bytes_head, bytes_tail) = bytes.split_at(nb * bb);
            let (out_head, out_tail) = std::mem::take(&mut out).split_at_mut(nb * bw);
            bytes = bytes_tail;
            out = out_tail;
            scope.spawn(move || codec.decode_blocks(bytes_head, out_head));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{codec, QuantFormat};
    use crate::util::rng::Pcg;

    #[test]
    fn thread_policy_sane() {
        assert!(max_threads() >= 1);
        assert_eq!(auto_threads(16), 1);
        assert!(auto_threads(PAR_MIN_WEIGHTS) >= 1);
    }

    #[test]
    fn chunked_encode_decode_identical_to_serial() {
        // Covered exhaustively by tests/quant_properties.rs; this is the
        // fast in-module smoke check (q4_k, odd block count > threads).
        let fmt = QuantFormat::Q4K;
        let n = fmt.block_weights() * 7;
        let mut rng = Pcg::new(53);
        let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let c = codec(fmt);
        let mut serial = vec![0u8; fmt.row_bytes(n).unwrap()];
        let mut par = serial.clone();
        encode_chunked(c, &data, None, &mut serial, 1);
        encode_chunked(c, &data, None, &mut par, 3);
        assert_eq!(serial, par);
        let mut out_serial = vec![0f32; n];
        let mut out_par = vec![0f32; n];
        decode_chunked(c, &serial, &mut out_serial, 1);
        decode_chunked(c, &par, &mut out_par, 3);
        assert_eq!(out_serial, out_par);
    }
}
