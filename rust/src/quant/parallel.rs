//! Block-parallel codec execution.
//!
//! Every block format encodes each block independently into a disjoint
//! output range, so a tensor can be split into runs of whole blocks and
//! encoded on separate threads with **byte-identical** results — the
//! same per-block arithmetic runs either way, only the loop ownership
//! changes. This module provides that splitting over `std::thread::scope`
//! (no external thread-pool dependency).
//!
//! Two knobs:
//! - [`auto_threads`] — the default policy: serial below
//!   [`PAR_MIN_WEIGHTS`] (thread spawn costs more than it saves on small
//!   tensors), one thread per core above it.
//! - the explicit `threads` parameter on the `*_with` entry points in
//!   [`super`] — used by tests (to pin serial vs parallel) and by
//!   [`crate::container::quantize_container_with`], which parallelizes
//!   across tensors and therefore forces `threads = 1` per tensor.

use super::BlockCodec;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Minimum tensor size (in weights) before block-level threading is
/// worth the spawn overhead. One 256-weight super-block costs ~1µs to
/// encode; a thread spawn costs ~10µs, so the break-even run is a few
/// hundred blocks per worker.
pub const PAR_MIN_WEIGHTS: usize = 64 * 1024;

/// Worker threads this machine supports (≥ 1).
pub fn max_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Default thread count for an `n`-weight tensor.
pub fn auto_threads(n: usize) -> usize {
    if n < PAR_MIN_WEIGHTS {
        1
    } else {
        max_threads()
    }
}

/// Split a thread budget between `jobs` outer tasks and per-task block
/// threading: returns `(workers, inner)` with `workers ≤ jobs` and
/// `workers · inner ≤ threads`. Many small jobs get one thread each
/// (`inner == 1`); a single giant job gets the whole budget as block
/// parallelism — the policy the runtime weight loader uses so that both
/// a many-tensor checkpoint and one huge expert matrix split.
pub fn fan_out(threads: usize, jobs: usize) -> (usize, usize) {
    let threads = threads.max(1);
    if jobs == 0 {
        return (1, threads);
    }
    let workers = threads.min(jobs);
    (workers, (threads / workers).max(1))
}

/// Run `jobs` indexed tasks over up to `workers` scoped threads pulling
/// from a shared cursor (sizes vary wildly in practice, so a queue
/// load-balances better than static chunking), collecting results in
/// index order. `init` builds one per-worker scratch value reused
/// across that worker's jobs; `run` executes job `i` with it.
///
/// `workers <= 1` (or a single job) runs inline on the caller's thread
/// with the same per-job arithmetic, so results are identical either
/// way. Every slot is guaranteed filled on return: the cursor visits
/// each index exactly once and worker panics re-raise at scope exit.
/// This is the one ordered work-queue shared by
/// `container::quantize_container_with` and `runtime::loader`.
pub fn run_queue<R, S, I, F>(jobs: usize, workers: usize, init: I, run: F) -> Vec<R>
where
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> R + Sync,
{
    if workers <= 1 || jobs <= 1 {
        let mut scratch = init();
        return (0..jobs).map(|i| run(&mut scratch, i)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..jobs).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers.min(jobs) {
            scope.spawn(|| {
                let mut scratch = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs {
                        break;
                    }
                    *slots[i].lock().unwrap() = Some(run(&mut scratch, i));
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("work-queue slot unfilled"))
        .collect()
}

/// Encode `src` into `out`, splitting whole blocks across up to
/// `threads` scoped threads. Caller guarantees `src.len()` is a
/// multiple of the block size and `out` is sized exactly.
pub(crate) fn encode_chunked(
    codec: &dyn BlockCodec,
    src: &[f32],
    importance: Option<&[f32]>,
    out: &mut [u8],
    threads: usize,
) {
    let bw = codec.block_weights();
    let bb = codec.block_bytes();
    let nblocks = src.len() / bw;
    let threads = threads.clamp(1, nblocks.max(1));
    if threads == 1 {
        codec.encode_blocks(src, importance, out);
        return;
    }
    let per = nblocks.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut src = src;
        let mut imp = importance;
        let mut out: &mut [u8] = out;
        while !src.is_empty() {
            let nb = (src.len() / bw).min(per);
            let (src_head, src_tail) = src.split_at(nb * bw);
            let (imp_head, imp_tail) = match imp {
                Some(w) => {
                    let (a, b) = w.split_at(nb * bw);
                    (Some(a), Some(b))
                }
                None => (None, None),
            };
            let (out_head, out_tail) = std::mem::take(&mut out).split_at_mut(nb * bb);
            src = src_tail;
            imp = imp_tail;
            out = out_tail;
            scope.spawn(move || codec.encode_blocks(src_head, imp_head, out_head));
        }
    });
}

/// Decode `bytes` into `out`, splitting whole blocks across up to
/// `threads` scoped threads. Caller guarantees sizes match.
pub(crate) fn decode_chunked(
    codec: &dyn BlockCodec,
    bytes: &[u8],
    out: &mut [f32],
    threads: usize,
) {
    let bw = codec.block_weights();
    let bb = codec.block_bytes();
    let nblocks = out.len() / bw;
    let threads = threads.clamp(1, nblocks.max(1));
    if threads == 1 {
        codec.decode_blocks(bytes, out);
        return;
    }
    let per = nblocks.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut bytes = bytes;
        let mut out: &mut [f32] = out;
        while !out.is_empty() {
            let nb = (out.len() / bw).min(per);
            let (bytes_head, bytes_tail) = bytes.split_at(nb * bb);
            let (out_head, out_tail) = std::mem::take(&mut out).split_at_mut(nb * bw);
            bytes = bytes_tail;
            out = out_tail;
            scope.spawn(move || codec.decode_blocks(bytes_head, out_head));
        }
    });
}

/// Compute `out[r] = vec_dot(row_r, x)` for a row-major quantized
/// matrix, splitting rows across up to `threads` scoped threads. Rows
/// write disjoint output slots and share no state, so the result is
/// bit-identical to the serial loop. Caller passes the already
/// validated row stride `rb` (non-zero) with
/// `bytes.len() == out.len() * rb`.
pub(crate) fn vec_dot_rows_chunked(
    codec: &dyn BlockCodec,
    bytes: &[u8],
    x: &[f32],
    out: &mut [f32],
    rb: usize,
    threads: usize,
) {
    let rows = out.len();
    let threads = threads.clamp(1, rows.max(1));
    if threads == 1 {
        codec.vec_dot_rows(bytes, x, out);
        return;
    }
    let per = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut bytes = bytes;
        let mut out: &mut [f32] = out;
        while !out.is_empty() {
            let nr = out.len().min(per);
            let (bytes_head, bytes_tail) = bytes.split_at(nr * rb);
            let (out_head, out_tail) = std::mem::take(&mut out).split_at_mut(nr);
            bytes = bytes_tail;
            out = out_tail;
            scope.spawn(move || codec.vec_dot_rows(bytes_head, x, out_head));
        }
    });
}

/// Compute the prefill GEMM `out[r * t + c] = vec_dot(row_r, col_c)`
/// for a row-major quantized matrix against a `t`-column token-major
/// activation panel `xs` (`xs.len() == t * n`), splitting rows across
/// up to `threads` scoped threads. Each row's `t` outputs live in one
/// contiguous row-major slot, so the split is a plain `split_at_mut`
/// and the result is bit-identical to the serial loop. Caller passes
/// the already validated row stride `rb` (non-zero) with
/// `bytes.len() == (out.len() / t) * rb`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn vec_dot_rows_mat_chunked(
    codec: &dyn BlockCodec,
    bytes: &[u8],
    xs: &[f32],
    out: &mut [f32],
    rb: usize,
    n: usize,
    t: usize,
    threads: usize,
) {
    if t == 0 {
        return;
    }
    let rows = out.len() / t;
    let threads = threads.clamp(1, rows.max(1));
    if threads == 1 {
        for (row, o) in bytes.chunks_exact(rb).zip(out.chunks_exact_mut(t)) {
            codec.vec_dot_mat(row, xs, n, o);
        }
        return;
    }
    let per = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut bytes = bytes;
        let mut out: &mut [f32] = out;
        while !out.is_empty() {
            let nr = (out.len() / t).min(per);
            let (bytes_head, bytes_tail) = bytes.split_at(nr * rb);
            let (out_head, out_tail) = std::mem::take(&mut out).split_at_mut(nr * t);
            bytes = bytes_tail;
            out = out_tail;
            scope.spawn(move || {
                for (row, o) in bytes_head.chunks_exact(rb).zip(out_head.chunks_exact_mut(t)) {
                    codec.vec_dot_mat(row, xs, n, o);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{codec, QuantFormat};
    use crate::util::rng::Pcg;

    #[test]
    fn thread_policy_sane() {
        assert!(max_threads() >= 1);
        assert_eq!(auto_threads(16), 1);
        assert!(auto_threads(PAR_MIN_WEIGHTS) >= 1);
    }

    #[test]
    fn run_queue_ordered_and_complete() {
        for workers in [1usize, 3, 8] {
            let out = run_queue(17, workers, || 0u32, |scratch, i| {
                *scratch += 1; // per-worker scratch is writable
                i * i
            });
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>(), "workers={workers}");
        }
        assert!(run_queue(0, 4, || (), |_, _| ()).is_empty());
    }

    #[test]
    fn fan_out_policy() {
        assert_eq!(fan_out(8, 1), (1, 8)); // one giant tensor: all block-parallel
        assert_eq!(fan_out(8, 100), (8, 1)); // many tensors: one thread each
        assert_eq!(fan_out(8, 3), (3, 2)); // leftover budget nests
        assert_eq!(fan_out(1, 42), (1, 1));
        assert_eq!(fan_out(0, 0), (1, 1));
        assert_eq!(fan_out(4, 0), (1, 4));
    }

    #[test]
    fn chunked_encode_decode_identical_to_serial() {
        // Covered exhaustively by tests/quant_properties.rs; this is the
        // fast in-module smoke check (q4_k, odd block count > threads).
        let fmt = QuantFormat::Q4K;
        let n = fmt.block_weights() * 7;
        let mut rng = Pcg::new(53);
        let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let c = codec(fmt);
        let mut serial = vec![0u8; fmt.row_bytes(n).unwrap()];
        let mut par = serial.clone();
        encode_chunked(c, &data, None, &mut serial, 1);
        encode_chunked(c, &data, None, &mut par, 3);
        assert_eq!(serial, par);
        let mut out_serial = vec![0f32; n];
        let mut out_par = vec![0f32; n];
        decode_chunked(c, &serial, &mut out_serial, 1);
        decode_chunked(c, &par, &mut out_par, 3);
        assert_eq!(out_serial, out_par);
    }

    #[test]
    fn chunked_vec_dot_rows_identical_to_serial() {
        // Row-parallel quantized matvec: 7 rows over 3 threads (ragged
        // split) must match the serial loop bit-for-bit.
        let fmt = QuantFormat::Q4K;
        let n = fmt.block_weights() * 2;
        let rows = 7;
        let mut rng = Pcg::new(59);
        let data: Vec<f32> = (0..rows * n).map(|_| rng.next_normal()).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let c = codec(fmt);
        let mut packed = vec![0u8; fmt.row_bytes(rows * n).unwrap()];
        encode_chunked(c, &data, None, &mut packed, 1);
        let rb = fmt.row_bytes(n).unwrap();
        let mut serial = vec![0f32; rows];
        let mut par = vec![0f32; rows];
        vec_dot_rows_chunked(c, &packed, &x, &mut serial, rb, 1);
        vec_dot_rows_chunked(c, &packed, &x, &mut par, rb, 3);
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&serial), bits(&par));
    }

    #[test]
    fn chunked_vec_dot_rows_mat_identical_to_serial_and_per_column() {
        // Row-parallel prefill GEMM: 7 rows × 5 columns over 3 threads
        // (ragged split) must match the serial panel loop bit-for-bit,
        // and every column must equal the independent vec_dot_rows run.
        let fmt = QuantFormat::Q4K;
        let n = fmt.block_weights() * 2;
        let (rows, t) = (7usize, 5usize);
        let mut rng = Pcg::new(61);
        let data: Vec<f32> = (0..rows * n).map(|_| rng.next_normal()).collect();
        let xs: Vec<f32> = (0..t * n).map(|_| rng.next_normal()).collect();
        let c = codec(fmt);
        let mut packed = vec![0u8; fmt.row_bytes(rows * n).unwrap()];
        encode_chunked(c, &data, None, &mut packed, 1);
        let rb = fmt.row_bytes(n).unwrap();
        let mut serial = vec![0f32; rows * t];
        let mut par = vec![0f32; rows * t];
        vec_dot_rows_mat_chunked(c, &packed, &xs, &mut serial, rb, n, t, 1);
        vec_dot_rows_mat_chunked(c, &packed, &xs, &mut par, rb, n, t, 3);
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&serial), bits(&par));
        for col in 0..t {
            let mut want = vec![0f32; rows];
            vec_dot_rows_chunked(c, &packed, &xs[col * n..(col + 1) * n], &mut want, rb, 1);
            for r in 0..rows {
                assert_eq!(serial[r * t + col].to_bits(), want[r].to_bits(), "r={r} c={col}");
            }
        }
    }
}
