//! `Q8_0` — 8-bit blocks of 32, 34 bytes/block (8.5 bpw).
//!
//! Layout per block (little-endian):
//! ```text
//! [0..2)   f16 d        (scale)
//! [2..34)  i8  qs[32]   (codes; x_i = d · q_i)
//! ```
//!
//! Like llama.cpp, `Q8_0` uses plain absmax scaling (no search): the
//! format has enough resolution that the scale fit is not the
//! bottleneck.
//!
//! Decode arms: scalar (this module), lane-chunked, **and** a
//! hand-written AVX2/NEON intrinsic decoder in
//! [`super::kernels`] — `Q8_0` is one of the two formats with a
//! dedicated `simd`-arm body (see the arm matrix in [`super`]).

use super::scalar::{get_f16, nearest_int, put_f16};
use super::QK8_0;

pub const BLOCK_BYTES: usize = 34;

pub fn quantize(src: &[f32], _importance: Option<&[f32]>, out: &mut [u8]) {
    debug_assert_eq!(src.len() % QK8_0, 0);
    debug_assert_eq!(out.len(), src.len() / QK8_0 * BLOCK_BYTES);
    for (xb, ob) in src.chunks_exact(QK8_0).zip(out.chunks_exact_mut(BLOCK_BYTES)) {
        let amax = xb.iter().fold(0f32, |a, &v| a.max(v.abs()));
        let d = amax / 127.0;
        let inv = if d > 0.0 { 1.0 / d } else { 0.0 };
        // Store the f16-rounded scale and quantize against *that* value
        // so the dequantizer reconstructs exactly what we optimized.
        put_f16(ob, 0, d);
        let d_stored = get_f16(ob, 0);
        let inv = if d_stored > 0.0 { 1.0 / d_stored } else { inv };
        for (i, &v) in xb.iter().enumerate() {
            ob[2 + i] = nearest_int(v * inv).clamp(-127, 127) as i8 as u8;
        }
    }
}

pub fn dequantize(bytes: &[u8], out: &mut [f32]) {
    for (ob, xb) in bytes.chunks_exact(BLOCK_BYTES).zip(out.chunks_exact_mut(QK8_0)) {
        let d = get_f16(ob, 0);
        for (i, x) in xb.iter_mut().enumerate() {
            *x = d * (ob[2 + i] as i8) as f32;
        }
    }
}

crate::quant::impl_block_codec!(crate::quant::QuantFormat::Q8_0);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{roundtrip, QuantFormat};
    use crate::util::rng::Pcg;

    #[test]
    fn near_lossless_on_gaussianish() {
        let mut rng = Pcg::new(7);
        let src: Vec<f32> = (0..QK8_0 * 8).map(|_| rng.next_normal()).collect();
        let rt = roundtrip(QuantFormat::Q8_0, &src, None).unwrap();
        let amax = src.iter().fold(0f32, |a, &v| a.max(v.abs()));
        for (a, b) in src.iter().zip(&rt) {
            assert!((a - b).abs() <= amax / 127.0 * 0.51 + 1e-4);
        }
    }

    #[test]
    fn zero_block_is_exact() {
        let src = vec![0f32; QK8_0];
        let rt = roundtrip(QuantFormat::Q8_0, &src, None).unwrap();
        assert_eq!(rt, src);
    }

    #[test]
    fn q8_0_decode_kernel_and_vec_dot_bit_identical() {
        crate::quant::kernels::assert_decode_and_vec_dot_identity(
            crate::quant::QuantFormat::Q8_0,
            0x8D,
        );
    }
}
