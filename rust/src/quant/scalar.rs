//! Scale-search primitives shared by the k-quant codecs.
//!
//! These follow the strategy of llama.cpp's `make_qx_quants` /
//! `make_qkx2_quants`: start from the naive min-max scale and refine it
//! with a small deterministic search that minimizes (importance-)weighted
//! squared reconstruction error.

/// Round-to-nearest, ties away from zero (matches llama.cpp's
/// `nearest_int` behaviour for the value ranges we use).
#[inline]
pub fn nearest_int(x: f32) -> i32 {
    x.round() as i32
}

/// Default importance weight when no imatrix is supplied: `x²` biases the
/// search toward preserving large-magnitude weights, mirroring
/// llama.cpp's `quantize_row_*_impl` fallback (`weight = x*x`).
#[inline]
fn default_weight(x: f32) -> f32 {
    x * x + 1e-8
}

/// Symmetric scale search: find `scale` such that
/// `q_i = clamp(round(x_i / scale), -nmax, nmax-1)` minimizes
/// `Σ w_i (x_i - scale·q_i)²`, writing the chosen `q_i + nmax` (i.e. an
/// unsigned code in `[0, 2·nmax)`) into `out`.
///
/// Returns the scale. `nmax` is the magnitude bound: 4 for 3-bit
/// (`q ∈ [-4, 3]`), 32 for 6-bit (`q ∈ [-32, 31]`).
///
/// The search mirrors llama.cpp `make_qx_quants(..., rmse_type=1)`:
/// evaluate the least-squares-optimal scale for the roundings induced by
/// 19 candidate scales around `-nmax / max|x|` and keep the best.
pub fn make_qx_quants(x: &[f32], nmax: i32, weights: Option<&[f32]>, out: &mut [u8]) -> f32 {
    debug_assert_eq!(x.len(), out.len());
    let n = x.len();
    let mut amax = 0f32;
    let mut max = 0f32;
    for &v in x {
        if v.abs() > amax {
            amax = v.abs();
            max = v;
        }
    }
    if amax < 1e-30 {
        out.iter_mut().for_each(|o| *o = nmax as u8);
        return 0.0;
    }
    // llama.cpp anchors the initial inverse scale on the signed max so
    // that the extreme value maps exactly to ±nmax.
    let mut best_scale = 0f32;
    let mut best_err = f32::INFINITY;
    let w_at = |i: usize| weights.map_or(default_weight(x[i]), |w| w[i] + 1e-10);
    for is in -9i32..=9 {
        let iscale = -(nmax as f32 + 0.1f32 * is as f32) / max;
        // Least-squares re-fit of the scale for this rounding: given
        // q_i fixed, optimal scale = Σ w x q / Σ w q².
        let mut sumlx = 0f32;
        let mut suml2 = 0f32;
        for i in 0..n {
            let l = nearest_int(iscale * x[i]).clamp(-nmax, nmax - 1) as f32;
            let w = w_at(i);
            sumlx += w * x[i] * l;
            suml2 += w * l * l;
        }
        if suml2 <= 0.0 {
            continue;
        }
        let scale = sumlx / suml2;
        let mut err = 0f32;
        for i in 0..n {
            let l = nearest_int(iscale * x[i]).clamp(-nmax, nmax - 1) as f32;
            let d = x[i] - scale * l;
            err += w_at(i) * d * d;
        }
        if err < best_err {
            best_err = err;
            best_scale = scale;
        }
    }
    if best_scale == 0.0 {
        // Degenerate: fall back to naive.
        best_scale = max / -(nmax as f32);
    }
    let inv = if best_scale != 0.0 { 1.0 / best_scale } else { 0.0 };
    for i in 0..n {
        let l = nearest_int(inv * x[i]).clamp(-nmax, nmax - 1);
        out[i] = (l + nmax) as u8;
    }
    best_scale
}

/// Asymmetric (scale, min) search: find `(scale, min)` such that
/// `q_i = clamp(round((x_i + min) / scale), 0, nmax)` minimizes
/// `Σ w_i (x_i - (scale·q_i - min))²`. Writes codes into `out`, returns
/// `(scale, min)` with `min ≥ 0` (k-quants store the *negated* minimum,
/// i.e. reconstruction is `d·q - m`).
///
/// Mirrors llama.cpp `make_qkx2_quants`: candidate inverse scales around
/// `nmax / (max - min)` plus an exact least-squares (scale, min) re-fit
/// per candidate rounding.
pub fn make_qkx_quants(x: &[f32], nmax: i32, weights: Option<&[f32]>, out: &mut [u8]) -> (f32, f32) {
    debug_assert_eq!(x.len(), out.len());
    let n = x.len();
    let mut vmin = x[0];
    let mut vmax = x[0];
    for &v in x {
        vmin = vmin.min(v);
        vmax = vmax.max(v);
    }
    if vmax <= vmin + 1e-30 {
        // Constant block. The stored min is constrained to be ≥ 0
        // (reconstruction is d·q − m with m ≥ 0), so a positive constant
        // must go through the scale path (q = nmax), while a negative
        // constant goes through the min path (q = 0).
        if vmin >= 0.0 {
            out.iter_mut().for_each(|o| *o = nmax as u8);
            return (vmin / nmax as f32, 0.0);
        }
        out.iter_mut().for_each(|o| *o = 0);
        return (0.0, -vmin);
    }
    if vmin > 0.0 {
        vmin = 0.0; // k-quants constrain min ≥ 0 in stored (negated) form
    }
    let w_at = |i: usize| weights.map_or(default_weight(x[i]), |w| w[i] + 1e-10);

    let mut best = (vmax - vmin) / nmax as f32;
    let mut best_min = -vmin;
    let mut best_err = f32::INFINITY;
    for step in -5i32..=8 {
        let iscale = (0.1f32 * step as f32 + nmax as f32) / (vmax - vmin);
        // Round with the candidate scale, then solve the 2-parameter
        // weighted least squares for (scale, min) exactly.
        let mut sum_w = 0f32;
        let mut sum_x = 0f32;
        let mut sum_l = 0f32;
        let mut sum_l2 = 0f32;
        let mut sum_xl = 0f32;
        for i in 0..n {
            let l = nearest_int(iscale * (x[i] - vmin)).clamp(0, nmax) as f32;
            let w = w_at(i);
            sum_w += w;
            sum_x += w * x[i];
            sum_l += w * l;
            sum_l2 += w * l * l;
            sum_xl += w * x[i] * l;
        }
        let det = sum_w * sum_l2 - sum_l * sum_l;
        if det <= 0.0 {
            continue;
        }
        let mut scale = (sum_w * sum_xl - sum_x * sum_l) / det;
        let mut minv = (sum_l2 * sum_x - sum_l * sum_xl) / det;
        if minv > 0.0 {
            // Constrained fit: min must be ≤ 0 (stored negated ≥ 0).
            minv = 0.0;
            scale = if sum_l2 > 0.0 { sum_xl / sum_l2 } else { scale };
        }
        if scale <= 0.0 {
            continue;
        }
        let mut err = 0f32;
        for i in 0..n {
            let l = nearest_int(iscale * (x[i] - vmin)).clamp(0, nmax) as f32;
            let d = x[i] - (scale * l + minv);
            err += w_at(i) * d * d;
        }
        if err < best_err {
            best_err = err;
            best = scale;
            best_min = -minv;
        }
    }
    let inv = if best > 0.0 { 1.0 / best } else { 0.0 };
    for i in 0..n {
        out[i] = nearest_int(inv * (x[i] + best_min)).clamp(0, nmax) as u8;
    }
    (best, best_min)
}

/// Read a little-endian f16 at `bytes[off..off+2]`.
#[inline]
pub fn get_f16(bytes: &[u8], off: usize) -> f32 {
    let bits = u16::from_le_bytes([bytes[off], bytes[off + 1]]);
    crate::util::f16::f16_bits_to_f32(bits)
}

/// Write `v` as little-endian f16 at `bytes[off..off+2]`.
#[inline]
pub fn put_f16(bytes: &mut [u8], off: usize, v: f32) {
    let bits = crate::util::f16::f32_to_f16_bits(v);
    bytes[off..off + 2].copy_from_slice(&bits.to_le_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mse(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>() / a.len() as f32
    }

    #[test]
    fn qx_reconstructs_linear_ramp() {
        // A ramp exactly representable with 6-bit symmetric codes.
        let x: Vec<f32> = (-32..32).map(|i| i as f32 * 0.5).collect();
        let mut codes = vec![0u8; x.len()];
        let scale = make_qx_quants(&x, 32, None, &mut codes);
        let recon: Vec<f32> = codes.iter().map(|&c| scale * (c as f32 - 32.0)).collect();
        assert!(mse(&x, &recon) < 1e-8, "mse={}", mse(&x, &recon));
    }

    #[test]
    fn qx_zero_block() {
        let x = vec![0f32; 16];
        let mut codes = vec![0u8; 16];
        let scale = make_qx_quants(&x, 4, None, &mut codes);
        assert_eq!(scale, 0.0);
        let recon: Vec<f32> = codes.iter().map(|&c| scale * (c as f32 - 4.0)).collect();
        assert_eq!(recon, x);
    }

    #[test]
    fn qkx_reconstructs_shifted_ramp() {
        let x: Vec<f32> = (0..32).map(|i| 3.0 + i as f32 * 0.25).collect();
        let mut codes = vec![0u8; x.len()];
        let (scale, min) = make_qkx_quants(&x, 31, None, &mut codes);
        let recon: Vec<f32> = codes.iter().map(|&c| scale * c as f32 - min).collect();
        assert!(mse(&x, &recon) < 0.02, "mse={}", mse(&x, &recon));
    }

    #[test]
    fn qkx_constant_block() {
        let x = vec![-1.5f32; 32];
        let mut codes = vec![0u8; 32];
        let (scale, min) = make_qkx_quants(&x, 15, None, &mut codes);
        let recon: Vec<f32> = codes.iter().map(|&c| scale * c as f32 - min).collect();
        for v in recon {
            assert!((v - -1.5).abs() < 1e-6);
        }
    }

    #[test]
    fn importance_shifts_rounding() {
        // A block with one huge-importance element: its reconstruction
        // error must not exceed the unweighted case.
        let mut x = vec![0.1f32; 32];
        x[7] = 0.9;
        let mut w = vec![1.0f32; 32];
        w[7] = 1e6;
        let mut codes_u = vec![0u8; 32];
        let mut codes_w = vec![0u8; 32];
        let s_u = make_qx_quants(&x, 4, None, &mut codes_u);
        let s_w = make_qx_quants(&x, 4, Some(&w), &mut codes_w);
        let err_u = (x[7] - s_u * (codes_u[7] as f32 - 4.0)).abs();
        let err_w = (x[7] - s_w * (codes_w[7] as f32 - 4.0)).abs();
        assert!(err_w <= err_u + 1e-6, "err_w={err_w} err_u={err_u}");
    }

    #[test]
    fn f16_helpers_roundtrip() {
        let mut buf = [0u8; 4];
        put_f16(&mut buf, 1, 0.625);
        assert_eq!(get_f16(&buf, 1), 0.625);
    }
}
