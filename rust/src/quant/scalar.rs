//! Scale-search primitives shared by the k-quant codecs.
//!
//! These follow the strategy of llama.cpp's `make_qx_quants` /
//! `make_qkx2_quants`: start from the naive min-max scale and refine it
//! with a small deterministic search that minimizes (importance-)weighted
//! squared reconstruction error.
//!
//! ## Accumulation order and the SIMD dispatch
//!
//! Since PR 2 the canonical arithmetic is **lane-chunked**: per-candidate
//! weighted sums accumulate into [`simd::LANES`] parallel f32 lanes
//! (element `i` → lane `i % LANES`, sequential within a lane, reduced by
//! the shared [`simd::hsum`] fold), and each candidate is scored from its
//! sums in closed form — one pass per candidate instead of the historic
//! two. Two implementations of that one spec exist:
//!
//! - `qx_sums_ref` / `qkx_sums_ref` here — the plain-loop **scalar
//!   reference**;
//! - `simd::qx_sums` / `simd::qkx_sums` — the explicitly vectorizable
//!   chunked kernels.
//!
//! [`make_qx_quants`] / [`make_qkx_quants`] select the lane kernels at
//! runtime (scalar reference under `DSQ_SCALAR_SEARCH=1` or for
//! sub-lane inputs). Both arms are **byte-identical** — same lane
//! assignment, same per-lane order, same reduction, no implicit FMA —
//! which `tests/golden_vectors.rs` and the in-module tests pin.
//!
//! The *decode* direction has the same dual-arm shape: the format
//! modules' plain `dequantize` loops are the scalar reference, and the
//! lane-chunked batch decoders / fused `vec_dot` live in
//! [`super::kernels`] (dispatched via `DSQ_SCALAR_DECODE`).

use super::simd::{self, qround, QkxSums};

/// Round-to-nearest, ties away from zero (matches llama.cpp's
/// `nearest_int` behaviour for the value ranges we use).
#[inline]
pub fn nearest_int(x: f32) -> i32 {
    x.round() as i32
}

/// Scalar reference for [`simd::qx_sums`] — the same lane-ordered sums
/// written as one plain indexed loop.
pub(crate) fn qx_sums_ref(
    x: &[f32],
    weights: Option<&[f32]>,
    iscale: f32,
    lo: f32,
    hi: f32,
) -> (f32, f32) {
    let mut sumlx = [0.0f32; simd::LANES];
    let mut suml2 = [0.0f32; simd::LANES];
    for (i, &xv) in x.iter().enumerate() {
        let q = qround(iscale * xv, lo, hi);
        let w = match weights {
            Some(w) => w[i] + 1e-10,
            // Default importance: x² biases the fit toward preserving
            // large-magnitude weights (llama.cpp's `weight = x*x`).
            None => xv * xv + 1e-8,
        };
        let lane = i % simd::LANES;
        sumlx[lane] += w * xv * q;
        suml2[lane] += w * q * q;
    }
    (simd::hsum(&sumlx), simd::hsum(&suml2))
}

/// Scalar reference for [`simd::qkx_sums`].
pub(crate) fn qkx_sums_ref(
    x: &[f32],
    weights: Option<&[f32]>,
    iscale: f32,
    vmin: f32,
    hi: f32,
) -> QkxSums {
    let mut sw = [0.0f32; simd::LANES];
    let mut sx = [0.0f32; simd::LANES];
    let mut sl = [0.0f32; simd::LANES];
    let mut sl2 = [0.0f32; simd::LANES];
    let mut sxl = [0.0f32; simd::LANES];
    for (i, &xv) in x.iter().enumerate() {
        let q = qround(iscale * (xv - vmin), 0.0, hi);
        let w = match weights {
            Some(w) => w[i] + 1e-10,
            None => xv * xv + 1e-8,
        };
        let lane = i % simd::LANES;
        sw[lane] += w;
        sx[lane] += w * xv;
        sl[lane] += w * q;
        sl2[lane] += w * q * q;
        sxl[lane] += w * xv * q;
    }
    QkxSums {
        w: simd::hsum(&sw),
        x: simd::hsum(&sx),
        l: simd::hsum(&sl),
        l2: simd::hsum(&sl2),
        xl: simd::hsum(&sxl),
    }
}

/// Candidate error relative to the (constant) `Σ w·x²` term, evaluated
/// in closed form from the one-pass sums:
/// `err' = s²·Σwl² + 2sm·Σwl + m²·Σw − 2s·Σwxl − 2m·Σwx`
/// for reconstruction `x̂ = s·l + m`. Shared by both dispatch arms.
#[inline]
fn qkx_err(s: f32, m: f32, sums: &QkxSums) -> f32 {
    s * s * sums.l2 + 2.0 * s * m * sums.l + m * m * sums.w - 2.0 * s * sums.xl - 2.0 * m * sums.x
}

/// Symmetric scale search: find `scale` such that
/// `q_i = clamp(round(x_i / scale), -nmax, nmax-1)` minimizes
/// `Σ w_i (x_i - scale·q_i)²`, writing the chosen `q_i + nmax` (i.e. an
/// unsigned code in `[0, 2·nmax)`) into `out`.
///
/// Returns the scale. `nmax` is the magnitude bound: 4 for 3-bit
/// (`q ∈ [-4, 3]`), 32 for 6-bit (`q ∈ [-32, 31]`).
///
/// The search mirrors llama.cpp `make_qx_quants(..., rmse_type=1)`:
/// for each of 19 candidate inverse scales around `-nmax / max|x|`,
/// re-fit the least-squares-optimal scale for the induced rounding and
/// keep the candidate maximizing `Σwxq²/Σwq²` (equivalently minimizing
/// the weighted error — the constant `Σwx²` term cancels).
pub fn make_qx_quants(x: &[f32], nmax: i32, weights: Option<&[f32]>, out: &mut [u8]) -> f32 {
    let use_lanes = simd::lanes_enabled() && x.len() >= simd::LANES;
    make_qx_quants_impl(x, nmax, weights, out, use_lanes)
}

/// [`make_qx_quants`] with the dispatch arm pinned — the seam the
/// cross-arm identity tests use (runtime dispatch is process-global).
fn make_qx_quants_impl(
    x: &[f32],
    nmax: i32,
    weights: Option<&[f32]>,
    out: &mut [u8],
    use_lanes: bool,
) -> f32 {
    debug_assert_eq!(x.len(), out.len());
    let mut amax = 0f32;
    let mut max = 0f32;
    for &v in x {
        if v.abs() > amax {
            amax = v.abs();
            max = v;
        }
    }
    if amax < 1e-30 {
        out.iter_mut().for_each(|o| *o = nmax as u8);
        return 0.0;
    }
    let (lo, hi) = (-(nmax as f32), (nmax - 1) as f32);
    // llama.cpp anchors the initial inverse scale on the signed max so
    // that the extreme value maps exactly to ±nmax.
    let mut best_scale = 0f32;
    let mut best_metric = 0f32;
    for is in -9i32..=9 {
        let iscale = -(nmax as f32 + 0.1f32 * is as f32) / max;
        let (sumlx, suml2) = if use_lanes {
            simd::qx_sums(x, weights, iscale, lo, hi)
        } else {
            qx_sums_ref(x, weights, iscale, lo, hi)
        };
        if suml2 <= 0.0 {
            continue;
        }
        // Least-squares re-fit of the scale for this rounding: given
        // q_i fixed, optimal scale = Σwxq / Σwq², with weighted error
        // Σwx² − (Σwxq)²/Σwq² — so maximize scale·Σwxq.
        let scale = sumlx / suml2;
        let metric = scale * sumlx;
        if metric > best_metric {
            best_metric = metric;
            best_scale = scale;
        }
    }
    if best_scale == 0.0 {
        // Degenerate: fall back to naive.
        best_scale = max / -(nmax as f32);
    }
    let inv = if best_scale != 0.0 { 1.0 / best_scale } else { 0.0 };
    for (i, &xv) in x.iter().enumerate() {
        let q = qround(inv * xv, lo, hi);
        out[i] = (q as i32 + nmax) as u8;
    }
    best_scale
}

/// Asymmetric (scale, min) search: find `(scale, min)` such that
/// `q_i = clamp(round((x_i + min) / scale), 0, nmax)` minimizes
/// `Σ w_i (x_i - (scale·q_i - min))²`. Writes codes into `out`, returns
/// `(scale, min)` with `min ≥ 0` (k-quants store the *negated* minimum,
/// i.e. reconstruction is `d·q - m`).
///
/// Mirrors llama.cpp `make_qkx2_quants`: candidate inverse scales around
/// `nmax / (max - min)` plus an exact least-squares (scale, min) re-fit
/// per candidate rounding, scored in closed form from one-pass sums.
pub fn make_qkx_quants(x: &[f32], nmax: i32, weights: Option<&[f32]>, out: &mut [u8]) -> (f32, f32) {
    let use_lanes = simd::lanes_enabled() && x.len() >= simd::LANES;
    make_qkx_quants_impl(x, nmax, weights, out, use_lanes)
}

/// [`make_qkx_quants`] with the dispatch arm pinned (see
/// `make_qx_quants_impl`).
fn make_qkx_quants_impl(
    x: &[f32],
    nmax: i32,
    weights: Option<&[f32]>,
    out: &mut [u8],
    use_lanes: bool,
) -> (f32, f32) {
    debug_assert_eq!(x.len(), out.len());
    let mut vmin = x[0];
    let mut vmax = x[0];
    for &v in x {
        vmin = vmin.min(v);
        vmax = vmax.max(v);
    }
    if vmax <= vmin + 1e-30 {
        // Constant block. The stored min is constrained to be ≥ 0
        // (reconstruction is d·q − m with m ≥ 0), so a positive constant
        // must go through the scale path (q = nmax), while a negative
        // constant goes through the min path (q = 0).
        if vmin >= 0.0 {
            out.iter_mut().for_each(|o| *o = nmax as u8);
            return (vmin / nmax as f32, 0.0);
        }
        out.iter_mut().for_each(|o| *o = 0);
        return (0.0, -vmin);
    }
    if vmin > 0.0 {
        vmin = 0.0; // k-quants constrain min ≥ 0 in stored (negated) form
    }
    let hi = nmax as f32;

    let mut best = (vmax - vmin) / nmax as f32;
    let mut best_min = -vmin;
    let mut best_err = f32::INFINITY;
    for step in -5i32..=8 {
        let iscale = (0.1f32 * step as f32 + nmax as f32) / (vmax - vmin);
        // Round with the candidate scale, then solve the 2-parameter
        // weighted least squares for (scale, min) exactly.
        let s = if use_lanes {
            simd::qkx_sums(x, weights, iscale, vmin, hi)
        } else {
            qkx_sums_ref(x, weights, iscale, vmin, hi)
        };
        let det = s.w * s.l2 - s.l * s.l;
        if det <= 0.0 {
            continue;
        }
        let mut scale = (s.w * s.xl - s.x * s.l) / det;
        let mut minv = (s.l2 * s.x - s.l * s.xl) / det;
        if minv > 0.0 {
            // Constrained fit: min must be ≤ 0 (stored negated ≥ 0).
            minv = 0.0;
            scale = if s.l2 > 0.0 { s.xl / s.l2 } else { scale };
        }
        if scale <= 0.0 {
            continue;
        }
        let err = qkx_err(scale, minv, &s);
        if err < best_err {
            best_err = err;
            best = scale;
            best_min = -minv;
        }
    }
    let inv = if best > 0.0 { 1.0 / best } else { 0.0 };
    for (i, &xv) in x.iter().enumerate() {
        out[i] = qround(inv * (xv + best_min), 0.0, hi) as u8;
    }
    (best, best_min)
}

/// Read a little-endian f16 at `bytes[off..off+2]`.
#[inline]
pub fn get_f16(bytes: &[u8], off: usize) -> f32 {
    let bits = u16::from_le_bytes([bytes[off], bytes[off + 1]]);
    crate::util::f16::f16_bits_to_f32(bits)
}

/// Write `v` as little-endian f16 at `bytes[off..off+2]`.
#[inline]
pub fn put_f16(bytes: &mut [u8], off: usize, v: f32) {
    let bits = crate::util::f16::f32_to_f16_bits(v);
    bytes[off..off + 2].copy_from_slice(&bits.to_le_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn mse(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>() / a.len() as f32
    }

    #[test]
    fn qx_reconstructs_linear_ramp() {
        // A ramp exactly representable with 6-bit symmetric codes.
        let x: Vec<f32> = (-32..32).map(|i| i as f32 * 0.5).collect();
        let mut codes = vec![0u8; x.len()];
        let scale = make_qx_quants(&x, 32, None, &mut codes);
        let recon: Vec<f32> = codes.iter().map(|&c| scale * (c as f32 - 32.0)).collect();
        assert!(mse(&x, &recon) < 1e-8, "mse={}", mse(&x, &recon));
    }

    #[test]
    fn qx_zero_block() {
        let x = vec![0f32; 16];
        let mut codes = vec![0u8; 16];
        let scale = make_qx_quants(&x, 4, None, &mut codes);
        assert_eq!(scale, 0.0);
        let recon: Vec<f32> = codes.iter().map(|&c| scale * (c as f32 - 4.0)).collect();
        assert_eq!(recon, x);
    }

    #[test]
    fn qkx_reconstructs_shifted_ramp() {
        let x: Vec<f32> = (0..32).map(|i| 3.0 + i as f32 * 0.25).collect();
        let mut codes = vec![0u8; x.len()];
        let (scale, min) = make_qkx_quants(&x, 31, None, &mut codes);
        let recon: Vec<f32> = codes.iter().map(|&c| scale * c as f32 - min).collect();
        assert!(mse(&x, &recon) < 0.02, "mse={}", mse(&x, &recon));
    }

    #[test]
    fn qkx_constant_block() {
        let x = vec![-1.5f32; 32];
        let mut codes = vec![0u8; 32];
        let (scale, min) = make_qkx_quants(&x, 15, None, &mut codes);
        let recon: Vec<f32> = codes.iter().map(|&c| scale * c as f32 - min).collect();
        for v in recon {
            assert!((v - -1.5).abs() < 1e-6);
        }
    }

    #[test]
    fn importance_shifts_rounding() {
        // A block with one huge-importance element: its reconstruction
        // error must not exceed the unweighted case.
        let mut x = vec![0.1f32; 32];
        x[7] = 0.9;
        let mut w = vec![1.0f32; 32];
        w[7] = 1e6;
        let mut codes_u = vec![0u8; 32];
        let mut codes_w = vec![0u8; 32];
        let s_u = make_qx_quants(&x, 4, None, &mut codes_u);
        let s_w = make_qx_quants(&x, 4, Some(&w), &mut codes_w);
        let err_u = (x[7] - s_u * (codes_u[7] as f32 - 4.0)).abs();
        let err_w = (x[7] - s_w * (codes_w[7] as f32 - 4.0)).abs();
        assert!(err_w <= err_u + 1e-6, "err_w={err_w} err_u={err_u}");
    }

    #[test]
    fn f16_helpers_roundtrip() {
        let mut buf = [0u8; 4];
        put_f16(&mut buf, 1, 0.625);
        assert_eq!(get_f16(&buf, 1), 0.625);
    }

    #[test]
    fn qx_search_identical_across_dispatch_arms() {
        // The runtime dispatch is process-global (env var read once),
        // so the identity test pins the arm through the `_impl` seam.
        for seed in 0..300u64 {
            let mut rng = Pcg::new(6100 + seed);
            let n = [16usize, 32][seed as usize % 2];
            let scale = 10f32.powi(rng.next_below(7) as i32 - 3);
            let x: Vec<f32> = (0..n).map(|_| rng.next_normal() * scale).collect();
            let w: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.05).collect();
            for &nmax in &[4i32, 32] {
                for weights in [None, Some(w.as_slice())] {
                    let mut a = vec![0u8; n];
                    let mut b = vec![0u8; n];
                    let sa = make_qx_quants_impl(&x, nmax, weights, &mut a, true);
                    let sb = make_qx_quants_impl(&x, nmax, weights, &mut b, false);
                    assert_eq!(sa.to_bits(), sb.to_bits(), "seed {seed} nmax {nmax}");
                    assert_eq!(a, b, "seed {seed} nmax {nmax}");
                }
            }
        }
    }

    #[test]
    fn qkx_search_identical_across_dispatch_arms() {
        for seed in 0..300u64 {
            let mut rng = Pcg::new(6400 + seed);
            let n = [16usize, 32][seed as usize % 2];
            let scale = 10f32.powi(rng.next_below(7) as i32 - 3);
            let shift = if seed % 3 == 0 { scale * 0.7 } else { 0.0 };
            let x: Vec<f32> = (0..n).map(|_| rng.next_normal() * scale + shift).collect();
            let w: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.05).collect();
            for &nmax in &[3i32, 15, 31] {
                for weights in [None, Some(w.as_slice())] {
                    let mut a = vec![0u8; n];
                    let mut b = vec![0u8; n];
                    let (sa, ma) = make_qkx_quants_impl(&x, nmax, weights, &mut a, true);
                    let (sb, mb) = make_qkx_quants_impl(&x, nmax, weights, &mut b, false);
                    assert_eq!(
                        (sa.to_bits(), ma.to_bits()),
                        (sb.to_bits(), mb.to_bits()),
                        "seed {seed} nmax {nmax}"
                    );
                    assert_eq!(a, b, "seed {seed} nmax {nmax}");
                }
            }
        }
    }

    #[test]
    fn public_entry_matches_pinned_arm() {
        // Whatever arm the process-global dispatch selected, the public
        // functions must agree with the `_impl` seam pinned to it.
        let lanes = simd::lanes_enabled();
        let mut rng = Pcg::new(77);
        let x: Vec<f32> = (0..32).map(|_| rng.next_normal()).collect();
        let mut a = vec![0u8; 32];
        let mut b = vec![0u8; 32];
        let sa = make_qx_quants(&x, 32, None, &mut a);
        let sb = make_qx_quants_impl(&x, 32, None, &mut b, lanes);
        assert_eq!(sa.to_bits(), sb.to_bits());
        assert_eq!(a, b);
        let (ka, kma) = make_qkx_quants(&x, 15, None, &mut a);
        let (kb, kmb) = make_qkx_quants_impl(&x, 15, None, &mut b, lanes);
        assert_eq!((ka.to_bits(), kma.to_bits()), (kb.to_bits(), kmb.to_bits()));
        assert_eq!(a, b);
    }
}
