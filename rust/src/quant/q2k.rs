//! `Q2_K` — 2-bit k-quant, super-block of 256, 84 bytes (2.625 bpw).
//!
//! 16 sub-blocks of 16 weights. Asymmetric:
//! `x_i = d · sc[j] · c_i − dmin · m[j]` with codes `c_i ∈ [0, 3]` and
//! 4-bit sub-block scales/mins.
//!
//! Layout per super-block (flat element order, sub-block `j = i / 16`):
//! ```text
//! [0..16)   scales[16]  byte j = sc[j] | m[j] << 4
//! [16..80)  qs[64]      2-bit codes: bits 2·(i&3) of qs[i>>2]
//! [80..82)  f16 d
//! [82..84)  f16 dmin
//! ```
//!
//! Decode arms: scalar (this module) and lane-chunked; inside the
//! `simd` dispatch arm the lane decoder is reused with the intrinsic
//! accumulator (see the arm matrix in [`super`]).

use super::scalar::{get_f16, make_qkx_quants, nearest_int, put_f16};
use super::QK_K;

pub const BLOCK_BYTES: usize = 84;
const SUB: usize = 16;
const NSUB: usize = QK_K / SUB;

pub fn quantize(src: &[f32], importance: Option<&[f32]>, out: &mut [u8]) {
    debug_assert_eq!(src.len() % QK_K, 0);
    for (bi, (xb, ob)) in src
        .chunks_exact(QK_K)
        .zip(out.chunks_exact_mut(BLOCK_BYTES))
        .enumerate()
    {
        let wb = importance.map(|w| &w[bi * QK_K..(bi + 1) * QK_K]);
        let mut scales = [0f32; NSUB];
        let mut mins = [0f32; NSUB];
        let mut codes = [0u8; QK_K];
        let mut max_scale = 0f32;
        let mut max_min = 0f32;
        for j in 0..NSUB {
            let xs = &xb[j * SUB..(j + 1) * SUB];
            let ws = wb.map(|w| &w[j * SUB..(j + 1) * SUB]);
            let (s, m) = make_qkx_quants(xs, 3, ws, &mut codes[j * SUB..(j + 1) * SUB]);
            scales[j] = s;
            mins[j] = m;
            max_scale = max_scale.max(s);
            max_min = max_min.max(m);
        }
        let d = if max_scale > 0.0 { max_scale / 15.0 } else { 0.0 };
        let dmin = if max_min > 0.0 { max_min / 15.0 } else { 0.0 };
        put_f16(ob, 80, d);
        put_f16(ob, 82, dmin);
        let d = get_f16(ob, 80);
        let dmin = get_f16(ob, 82);
        for j in 0..NSUB {
            let sc = if d > 0.0 {
                nearest_int(scales[j] / d).clamp(0, 15) as u8
            } else {
                0
            };
            let mn = if dmin > 0.0 {
                nearest_int(mins[j] / dmin).clamp(0, 15) as u8
            } else {
                0
            };
            ob[j] = sc | (mn << 4);
            let sd = d * sc as f32;
            let sm = dmin * mn as f32;
            for k in 0..SUB {
                let i = j * SUB + k;
                codes[i] = if sd > 0.0 {
                    nearest_int((xb[i] + sm) / sd).clamp(0, 3) as u8
                } else {
                    0
                };
            }
        }
        let qs = &mut ob[16..80];
        qs.fill(0);
        for (i, &c) in codes.iter().enumerate() {
            qs[i >> 2] |= (c & 0x03) << (2 * (i & 3));
        }
    }
}

pub fn dequantize(bytes: &[u8], out: &mut [f32]) {
    for (ob, xb) in bytes.chunks_exact(BLOCK_BYTES).zip(out.chunks_exact_mut(QK_K)) {
        let d = get_f16(ob, 80);
        let dmin = get_f16(ob, 82);
        for i in 0..QK_K {
            let j = i / SUB;
            let sc = (ob[j] & 0x0F) as f32;
            let mn = (ob[j] >> 4) as f32;
            let c = ((ob[16 + (i >> 2)] >> (2 * (i & 3))) & 0x03) as f32;
            xb[i] = d * sc * c - dmin * mn;
        }
    }
}

crate::quant::impl_block_codec!(crate::quant::QuantFormat::Q2K);

#[cfg(test)]
mod tests {
    use crate::quant::error::rel_rmse;
    use crate::quant::{roundtrip, QuantFormat, QK_K};
    use crate::util::rng::Pcg;

    #[test]
    fn q2k_roundtrip_bounded_error() {
        let mut rng = Pcg::new(41);
        let src: Vec<f32> = (0..QK_K * 4).map(|_| rng.next_normal()).collect();
        let rt = roundtrip(QuantFormat::Q2K, &src, None).unwrap();
        let err = rel_rmse(&src, &rt);
        // 2-bit is lossy; just bound it and check the ordering vs q3_k.
        assert!(err < 0.35, "q2_k rel rmse unexpectedly high: {err}");
        let e3 = rel_rmse(&src, &roundtrip(QuantFormat::Q3K, &src, None).unwrap());
        assert!(err > e3, "q2_k ({err}) should be worse than q3_k ({e3})");
    }

    #[test]
    fn q2k_zero_block() {
        let src = vec![0f32; QK_K];
        let rt = roundtrip(QuantFormat::Q2K, &src, None).unwrap();
        assert_eq!(rt, src);
    }

    #[test]
    fn q2k_constant_positive_block() {
        let src = vec![0.75f32; QK_K];
        let rt = roundtrip(QuantFormat::Q2K, &src, None).unwrap();
        for v in &rt {
            assert!((v - 0.75).abs() < 0.01, "got {v}");
        }
    }

    #[test]
    fn q2k_decode_kernel_and_vec_dot_bit_identical() {
        crate::quant::kernels::assert_decode_and_vec_dot_identity(
            crate::quant::QuantFormat::Q2K,
            0x2D,
        );
    }
}
