//! `Q6_K` — 6-bit k-quant, super-block of 256, 210 bytes (6.5625 bpw).
//!
//! 16 sub-blocks of 16 weights. Symmetric: `x_i = d · sc[j] · (c_i − 32)`
//! with codes `c_i ∈ [0, 63]`, per-sub-block int8 scales `sc[j]`, and a
//! per-super-block f16 scale `d`.
//!
//! Layout per super-block (flat element order `i = 0..256`, sub-block
//! `j = i / 16`):
//! ```text
//! [0..128)    ql[128]    low 4 bits of c_i: nibble (i&1) of ql[i>>1]
//! [128..192)  qh[64]     high 2 bits of c_i: bits 2·(i&3) of qh[i>>2]
//! [192..208)  sc[16]     int8 sub-block scales
//! [208..210)  f16 d
//! ```
//!
//! Decode arms: scalar (this module) and lane-chunked; inside the
//! `simd` dispatch arm the lane decoder is reused with the intrinsic
//! accumulator (see the arm matrix in [`super`]).

use super::scalar::{get_f16, make_qx_quants, nearest_int, put_f16};
use super::QK_K;

pub const BLOCK_BYTES: usize = 210;
const SUB: usize = 16; // weights per sub-block
const NSUB: usize = QK_K / SUB;

pub fn quantize(src: &[f32], importance: Option<&[f32]>, out: &mut [u8]) {
    debug_assert_eq!(src.len() % QK_K, 0);
    for (bi, (xb, ob)) in src
        .chunks_exact(QK_K)
        .zip(out.chunks_exact_mut(BLOCK_BYTES))
        .enumerate()
    {
        let wb = importance.map(|w| &w[bi * QK_K..(bi + 1) * QK_K]);
        // Pass 1: per-sub-block symmetric scale search.
        let mut scales = [0f32; NSUB];
        let mut codes = [0u8; QK_K];
        let mut max_abs_scale = 0f32;
        for j in 0..NSUB {
            let xs = &xb[j * SUB..(j + 1) * SUB];
            let ws = wb.map(|w| &w[j * SUB..(j + 1) * SUB]);
            scales[j] = make_qx_quants(xs, 32, ws, &mut codes[j * SUB..(j + 1) * SUB]);
            max_abs_scale = max_abs_scale.max(scales[j].abs());
        }
        if max_abs_scale < 1e-30 {
            ob.fill(0);
            continue;
        }
        // Pass 2: quantize the sub-block scales to int8 against d.
        let d = max_abs_scale / 127.0;
        put_f16(ob, 208, d);
        let d = get_f16(ob, 208); // optimize against the stored value
        let invd = if d > 0.0 { 1.0 / d } else { 0.0 };
        for j in 0..NSUB {
            let isc = nearest_int(scales[j] * invd).clamp(-127, 127) as i8;
            ob[192 + j] = isc as u8;
            // Pass 3: re-round the codes against the reconstructed scale.
            let sd = d * isc as f32;
            let inv = if sd != 0.0 { 1.0 / sd } else { 0.0 };
            for k in 0..SUB {
                let i = j * SUB + k;
                let c = if sd != 0.0 {
                    (nearest_int(xb[i] * inv).clamp(-32, 31) + 32) as u8
                } else {
                    32
                };
                codes[i] = c;
            }
        }
        pack_codes(&codes, ob);
    }
}

fn pack_codes(codes: &[u8; QK_K], ob: &mut [u8]) {
    ob[..192].fill(0);
    for (i, &c) in codes.iter().enumerate() {
        let lo = c & 0x0F;
        let hi = c >> 4; // 2 bits
        ob[i >> 1] |= lo << (4 * (i & 1));
        ob[128 + (i >> 2)] |= hi << (2 * (i & 3));
    }
}

pub fn dequantize(bytes: &[u8], out: &mut [f32]) {
    for (ob, xb) in bytes.chunks_exact(BLOCK_BYTES).zip(out.chunks_exact_mut(QK_K)) {
        let d = get_f16(ob, 208);
        for i in 0..QK_K {
            let lo = (ob[i >> 1] >> (4 * (i & 1))) & 0x0F;
            let hi = (ob[128 + (i >> 2)] >> (2 * (i & 3))) & 0x03;
            let c = (lo | (hi << 4)) as i32;
            let sc = ob[192 + i / SUB] as i8 as f32;
            xb[i] = d * sc * (c - 32) as f32;
        }
    }
}

crate::quant::impl_block_codec!(crate::quant::QuantFormat::Q6K);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::error::rel_rmse;
    use crate::quant::{roundtrip, QuantFormat};
    use crate::util::rng::Pcg;

    #[test]
    fn q6k_accuracy_on_gaussian() {
        let mut rng = Pcg::new(11);
        let src: Vec<f32> = (0..QK_K * 4).map(|_| rng.next_normal()).collect();
        let rt = roundtrip(QuantFormat::Q6K, &src, None).unwrap();
        let err = rel_rmse(&src, &rt);
        assert!(err < 0.02, "q6_k rel rmse too high: {err}");
    }

    #[test]
    fn q6k_zero_block() {
        let src = vec![0f32; QK_K];
        let rt = roundtrip(QuantFormat::Q6K, &src, None).unwrap();
        assert_eq!(rt, src);
    }

    #[test]
    fn q6k_decode_kernel_and_vec_dot_bit_identical() {
        crate::quant::kernels::assert_decode_and_vec_dot_identity(
            crate::quant::QuantFormat::Q6K,
            0x6D,
        );
    }

    #[test]
    fn q6k_code_packing_roundtrips() {
        let mut codes = [0u8; QK_K];
        let mut rng = Pcg::new(3);
        for c in codes.iter_mut() {
            *c = (rng.next_u64() % 64) as u8;
        }
        let mut ob = vec![0u8; BLOCK_BYTES];
        pack_codes(&codes, &mut ob);
        for i in 0..QK_K {
            let lo = (ob[i >> 1] >> (4 * (i & 1))) & 0x0F;
            let hi = (ob[128 + (i >> 2)] >> (2 * (i & 3))) & 0x03;
            assert_eq!(lo | (hi << 4), codes[i], "element {i}");
        }
    }
}
