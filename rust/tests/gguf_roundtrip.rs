//! GGUF interop suite: the committed mirror-generated fixture
//! (`tests/golden/tiny_dense.q4_k_m.gguf`, written by
//! `python/tools/make_gguf_fixture.py`) must import to a DSQ1 container
//! **byte-identical** to the native `dsq quantize` output for the same
//! seed — pinned cross-language by `import.tiny_dense.q4_k_m.fnv64` —
//! at every thread count; export back must reproduce the fixture's
//! payload bytes exactly; the imported container must serve the
//! forward-logits golden; and imported K-quant rows must satisfy the
//! fused `vec_dot` ≡ dequantize+dot identity on every dispatch arm
//! (CI reruns this file under each `DSQ_FORCE_ARM`).
//!
//! The error-path half holds the importer to the same totality
//! discipline as `decode_kernels.rs`: truncated files, bad
//! magic/version, unknown tensor types, misaligned or overlapping
//! offsets, and census-name mismatches are all named errors — never
//! panics — including under a byte-flip/truncation sweep.

use dsq::container::gguf::{self, Gguf};
use dsq::container::{quantize_container_with, synthetic_f32_container, Container, Writer};
use dsq::coordinator::sampler::argmax;
use dsq::model::{ModelConfig, ModuleClass};
use dsq::quant::kernels::{self, DispatchArm};
use dsq::quant::{self, QuantFormat};
use dsq::runtime::forward::ForwardPass;
use dsq::runtime::native::NATIVE_MAX_CTX;
use dsq::util::fnv64;
use dsq::util::rng::Pcg;
use std::path::PathBuf;
use std::sync::OnceLock;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

fn fixture_bytes() -> &'static [u8] {
    static CELL: OnceLock<Vec<u8>> = OnceLock::new();
    CELL.get_or_init(|| {
        std::fs::read(golden_dir().join("tiny_dense.q4_k_m.gguf"))
            .expect("missing fixture — run python3 python/tools/make_gguf_fixture.py")
    })
}

/// Imported container bytes at a given thread count.
fn import_fixture(threads: usize) -> Vec<u8> {
    let g = Gguf::from_bytes(fixture_bytes()).unwrap();
    gguf::import_gguf(&g, threads).unwrap().to_bytes()
}

/// The same checkpoint produced natively: synthetic f32 weights
/// (seed 0x601D, identical to the mirror's) quantized under q4_k_m.
fn native_quantize_path() -> Vec<u8> {
    let src = synthetic_f32_container(&ModelConfig::tiny_dense(), 0x601D).unwrap();
    let scheme = dsq::scheme::builtin::scheme("q4_k_m").unwrap();
    quantize_container_with(&src, &scheme, None, 1).unwrap().to_bytes()
}

// ---------------------------------------------------------------------------
// Import: cross-language byte identity + determinism
// ---------------------------------------------------------------------------

#[test]
fn fixture_imports_byte_identical_to_native_quantize_path() {
    let imported = import_fixture(1);
    assert_eq!(
        imported,
        native_quantize_path(),
        "imported GGUF container != native dsq-quantize container"
    );
    let c = Container::from_bytes(imported).unwrap();
    assert_eq!(c.model.name, "tiny-dense");
    assert_eq!(c.model.rope_base, 1_000_000.0, "qwen2.rope.freq_base lost in import");
    assert_eq!(c.scheme_name, "q4_k_m", "scheme inference should match the builtin plan");
}

#[test]
fn fixture_import_matches_committed_mirror_golden() {
    let imported = import_fixture(1);
    let line = format!("{:016x} {}\n", fnv64(&imported), imported.len());
    let path = golden_dir().join("import.tiny_dense.q4_k_m.fnv64");
    let expect = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        expect.trim(),
        line.trim(),
        "imported container drifted from the Python mirror golden {}; if intentional, \
         regenerate via python/tools/make_gguf_fixture.py and call it out in the PR",
        path.display()
    );
}

#[test]
fn import_is_bit_identical_across_thread_counts() {
    let base = import_fixture(1);
    for threads in [2, 8] {
        assert_eq!(base, import_fixture(threads), "threads={threads}");
    }
}

// ---------------------------------------------------------------------------
// Export: payload-exact round-trip
// ---------------------------------------------------------------------------

#[test]
fn import_export_roundtrip_payloads_byte_identical() {
    let g = Gguf::from_bytes(fixture_bytes()).unwrap();
    let c = Container::from_bytes(import_fixture(1)).unwrap();
    let exported = gguf::export_bytes(&c).unwrap();
    let g2 = Gguf::from_bytes(&exported).unwrap();
    assert_eq!(g.tensors.len(), g2.tensors.len());
    for t in &g.tensors {
        let t2 = g2.tensors.iter().find(|x| x.name == t.name).unwrap_or_else(|| {
            panic!("tensor {:?} lost in export", t.name)
        });
        assert_eq!(t.shape, t2.shape, "{}", t.name);
        assert_eq!(t.format, t2.format, "{}", t.name);
        assert_eq!(g.payload(t), g2.payload(t2), "{}: payload bytes drifted", t.name);
    }
    // Re-importing the export lands on the identical container again
    // (the export carries dsq.model_config, so nothing is inferred).
    let reimported = gguf::import_gguf(&g2, 1).unwrap().to_bytes();
    assert_eq!(reimported, import_fixture(1));
}

// ---------------------------------------------------------------------------
// Serving + kernel identities on imported bytes
// ---------------------------------------------------------------------------

/// The golden forward script (same as tests/native_forward.rs) run off
/// the *imported* checkpoint must hash to the committed
/// forward.tiny_dense.q4_k_m.fnv64 golden — the fixture really serves.
#[test]
fn imported_fixture_serves_the_forward_logits_golden() {
    const PROMPT: [i32; 8] = [1, 17, 300, 42, 511, 7, 5, 260];
    const DECODE_STEPS: usize = 4;
    let ckpt = Container::from_bytes(import_fixture(1)).unwrap();
    let fwd = ForwardPass::new(ckpt, 1, NATIVE_MAX_CTX).unwrap();
    let mut cache = fwd.new_cache();
    let mut scratch = fwd.new_scratch();
    let mut logits = vec![0f32; fwd.vocab()];
    for (j, &t) in PROMPT.iter().enumerate() {
        let want = if j + 1 == PROMPT.len() { Some(&mut logits[..]) } else { None };
        fwd.forward_token(t, &mut cache, &mut scratch, want).unwrap();
    }
    let mut rows = vec![logits.clone()];
    for _ in 0..DECODE_STEPS {
        let tok = argmax(rows.last().unwrap());
        fwd.forward_token(tok, &mut cache, &mut scratch, Some(&mut logits)).unwrap();
        rows.push(logits.clone());
    }
    let mut blob = Vec::new();
    for r in &rows {
        for v in r {
            blob.extend_from_slice(&v.to_le_bytes());
        }
    }
    let line = format!("{:016x} {}", fnv64(&blob), blob.len());
    let expect = std::fs::read_to_string(golden_dir().join("forward.tiny_dense.q4_k_m.fnv64"))
        .unwrap();
    assert_eq!(expect.trim(), line.trim(), "imported checkpoint serves drifted logits");
}

/// Imported K-quant rows: fused `vec_dot` equals decode-then-`dot_lanes`
/// bit-for-bit on every available dispatch arm (per-arm CI pins each).
#[test]
fn imported_kquant_rows_satisfy_vec_dot_identity_on_every_arm() {
    let c = Container::from_bytes(import_fixture(1)).unwrap();
    let mut rng = Pcg::new(0x99F);
    let mut checked = 0;
    for t in &c.tensors {
        if !matches!(t.format, QuantFormat::Q4K | QuantFormat::Q6K) {
            continue;
        }
        let row_len = *t.shape.last().unwrap();
        let row_bytes = t.format.row_bytes(row_len).unwrap();
        let row = &c.bytes(t)[..row_bytes];
        let x: Vec<f32> = (0..row_len).map(|_| rng.next_normal()).collect();
        let decoded = quant::dequantize(t.format, row, row_len).unwrap();
        let want = kernels::dot_lanes(&decoded, &x);
        for arm in DispatchArm::ALL {
            if arm.available() {
                let got = kernels::vec_dot_arm(t.format, row, &x, arm);
                assert_eq!(
                    want.to_bits(),
                    got.to_bits(),
                    "{}: vec_dot ({}) != dequantize+dot",
                    t.name,
                    arm.name()
                );
            }
        }
        checked += 1;
    }
    assert!(checked > 10, "fixture unexpectedly holds only {checked} K-quant tensors");
}

#[test]
fn open_checkpoint_sniffs_both_magics() {
    let dir = std::env::temp_dir().join(format!("dsq-gguf-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let gguf_path = dir.join("ckpt.gguf");
    let dsq_path = dir.join("ckpt.dsq");
    std::fs::write(&gguf_path, fixture_bytes()).unwrap();
    std::fs::write(&dsq_path, import_fixture(1)).unwrap();
    let a = gguf::open_checkpoint(&gguf_path, 1).unwrap();
    let b = gguf::open_checkpoint(&dsq_path, 1).unwrap();
    assert_eq!(a.model.name, b.model.name);
    assert_eq!(a.tensors.len(), b.tensors.len());
    for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
        assert_eq!(a.bytes(ta), b.bytes(tb), "{}", ta.name);
    }
    std::fs::write(dir.join("junk"), b"XXXXnothing").unwrap();
    assert!(gguf::open_checkpoint(&dir.join("junk"), 1).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Adversarial error paths (named errors, no panics)
// ---------------------------------------------------------------------------

fn err_of(bytes: &[u8]) -> String {
    match Gguf::from_bytes(bytes) {
        Ok(g) => match gguf::import_gguf(&g, 1) {
            Ok(_) => panic!("adversarial input imported cleanly"),
            Err(e) => format!("{e:#}"),
        },
        Err(e) => format!("{e:#}"),
    }
}

/// Minimal hand-rolled GGUF builder for adversarial cases.
fn gstr(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u64).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// One-tensor GGUF: `name` with innermost-first `dims`, raw ggml type
/// id and offset, plus a data section of `data_len` zero bytes.
fn one_tensor_gguf(name: &str, dims: &[u64], ggml_type: u32, offset: u64, data_len: usize) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(b"GGUF");
    out.extend_from_slice(&3u32.to_le_bytes());
    out.extend_from_slice(&1u64.to_le_bytes()); // tensors
    out.extend_from_slice(&1u64.to_le_bytes()); // kvs
    gstr(&mut out, "general.architecture");
    out.extend_from_slice(&8u32.to_le_bytes());
    gstr(&mut out, "llama");
    gstr(&mut out, name);
    out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
    for d in dims {
        out.extend_from_slice(&d.to_le_bytes());
    }
    out.extend_from_slice(&ggml_type.to_le_bytes());
    out.extend_from_slice(&offset.to_le_bytes());
    let data_start = out.len().div_ceil(32) * 32;
    out.resize(data_start + data_len, 0);
    out
}

#[test]
fn bad_magic_is_a_named_error() {
    let mut b = fixture_bytes().to_vec();
    b[0] = b'X';
    assert!(err_of(&b).contains("not a GGUF"), "{}", err_of(&b));
    assert!(err_of(b"GG").contains("truncated"));
}

#[test]
fn bad_version_is_a_named_error() {
    let mut b = fixture_bytes().to_vec();
    b[4..8].copy_from_slice(&2u32.to_le_bytes());
    assert!(err_of(&b).contains("unsupported GGUF version 2"), "{}", err_of(&b));
}

#[test]
fn unknown_tensor_type_is_a_named_error() {
    // 2 = ggml q4_0: a real type we have no codec for; 99 = nonsense.
    for ty in [2u32, 99] {
        let b = one_tensor_gguf("t.weight", &[256], ty, 0, 1024);
        let e = err_of(&b);
        assert!(e.contains(&format!("unsupported ggml tensor type {ty}")), "{e}");
    }
}

#[test]
fn misaligned_offset_is_a_named_error() {
    let b = one_tensor_gguf("t.weight", &[8], 0 /* f32 */, 7, 1024);
    assert!(err_of(&b).contains("not aligned"), "{}", err_of(&b));
}

#[test]
fn out_of_bounds_payload_is_a_named_error() {
    let b = one_tensor_gguf("t.weight", &[256], 0, 0, 64); // needs 1024 bytes
    assert!(err_of(&b).contains("out of bounds"), "{}", err_of(&b));
}

#[test]
fn overlapping_payloads_are_a_named_error() {
    // Two f32 tensors of 64 elements (256 bytes each) at offsets 0 and 128.
    let mut out = Vec::new();
    out.extend_from_slice(b"GGUF");
    out.extend_from_slice(&3u32.to_le_bytes());
    out.extend_from_slice(&2u64.to_le_bytes());
    out.extend_from_slice(&0u64.to_le_bytes());
    for (name, off) in [("a.weight", 0u64), ("b.weight", 128)] {
        gstr(&mut out, name);
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&64u64.to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes());
        out.extend_from_slice(&off.to_le_bytes());
    }
    let data_start = out.len().div_ceil(32) * 32;
    out.resize(data_start + 512, 0);
    let e = err_of(&out);
    assert!(e.contains("overlapping"), "{e}");
}

#[test]
fn unsupported_architecture_is_a_named_error() {
    let b = one_tensor_gguf("t.weight", &[8], 0, 0, 32);
    assert!(err_of(&b).contains("unsupported GGUF architecture"), "{}", err_of(&b));
}

#[test]
fn census_name_mismatch_is_a_named_error() {
    // Same-length rename inside the fixture: the importer must notice
    // the census tensor has gone missing, by name.
    let from: &[u8] = b"blk.0.attn_q.weight";
    let to: &[u8] = b"blk.0.attn_x.weight";
    let mut b = fixture_bytes().to_vec();
    let pos = b.windows(from.len()).position(|w| w == from).unwrap();
    b[pos..pos + to.len()].copy_from_slice(to);
    let e = err_of(&b);
    assert!(e.contains("missing tensor") && e.contains("blk.0.attn_q.weight"), "{e}");
}

#[test]
fn unexpected_tensor_is_a_named_error() {
    // A container with one extra non-census tensor exports fine but
    // must be rejected on re-import (exact set equality both ways).
    let cfg = ModelConfig::tiny_dense();
    let mut w = Writer::new(cfg.clone(), "f32");
    for t in cfg.census() {
        let n: usize = t.shape.iter().product();
        let payload = quant::quantize(QuantFormat::F32, &vec![0.25f32; n], None).unwrap();
        w.add_tensor(&t.name, t.class, t.layer, &t.shape, QuantFormat::F32, &payload).unwrap();
    }
    let payload = quant::quantize(QuantFormat::F32, &vec![1.0f32; 256], None).unwrap();
    w.add_tensor("extra.weight", ModuleClass::Norm, None, &[256], QuantFormat::F32, &payload)
        .unwrap();
    let c = Container::from_bytes(w.to_bytes()).unwrap();
    let e = err_of(&gguf::export_bytes(&c).unwrap());
    assert!(e.contains("unexpected tensor") && e.contains("extra.weight"), "{e}");
}

#[test]
fn shape_mismatch_is_a_named_error() {
    // token_embd.weight transposed relative to the census.
    let cfg = ModelConfig::tiny_dense();
    let mut w = Writer::new(cfg.clone(), "f32");
    for t in cfg.census() {
        let n: usize = t.shape.iter().product();
        let payload = quant::quantize(QuantFormat::F32, &vec![0.25f32; n], None).unwrap();
        let shape: Vec<usize> = if t.name == "token_embd.weight" {
            t.shape.iter().rev().copied().collect()
        } else {
            t.shape.clone()
        };
        w.add_tensor(&t.name, t.class, t.layer, &shape, QuantFormat::F32, &payload).unwrap();
    }
    let c = Container::from_bytes(w.to_bytes()).unwrap();
    let e = err_of(&gguf::export_bytes(&c).unwrap());
    assert!(e.contains("does not match the census shape"), "{e}");
}

/// Totality sweep: every prefix of a small valid file, plus a
/// deterministic byte-flip fuzz over the fixture's header region, must
/// parse-or-error without panicking.
#[test]
fn truncation_and_byteflip_sweep_never_panics() {
    let small = one_tensor_gguf("t.weight", &[8], 0, 0, 32);
    assert!(Gguf::from_bytes(&small).is_ok());
    for len in 0..small.len() {
        assert!(Gguf::from_bytes(&small[..len]).is_err(), "prefix {len} parsed");
    }
    let fixture = fixture_bytes();
    for len in [0, 3, 4, 8, 24, 100, 1000, fixture.len() - 1] {
        let _ = Gguf::from_bytes(&fixture[..len]).map(|g| gguf::import_gguf(&g, 1));
    }
    let mut rng = Pcg::new(0xF522);
    for _ in 0..200 {
        let mut b = fixture.to_vec();
        let pos = (rng.next_u64() % 4096) as usize; // header + kv region
        let bit = 1u8 << (rng.next_u64() % 8);
        b[pos] ^= bit;
        // Must return, Ok or Err — never panic.
        let _ = Gguf::from_bytes(&b).map(|g| gguf::import_gguf(&g, 1));
    }
}
