//! Differential & scheduling suite for the continuous-batching serving
//! engine (`coordinator::scheduler` + `runtime::paged`).
//!
//! The central gate mirrors the kernel PRs' differential style one
//! level up: **every request's token stream under continuous batching
//! must be bit-identical to the same request run alone on a fresh
//! scheduler**, swept across thread counts {1, 2, 8}, every available
//! pinned dispatch arm, staggered admission orders, and mixed
//! prompt/generation lengths — on both model kinds × both paper
//! schemes. On top of that:
//!
//! * property tests for the paged KV allocator: random
//!   admit/grow/finish schedules never leak blocks, never alias one
//!   block into two caches, and keep the peak block count within the
//!   reservation bound;
//! * paged caches reconstruct the exact bits of a dense reference
//!   (same tokens forwarded into both, logits and cache planes
//!   compared);
//! * submit-time rejection (impossible block demand), bounded-queue
//!   backpressure, and cancel (queued + mid-generation) regressions;
//! * the zero-alloc gate extended to steady-state continuous decode:
//!   after warmup, decode steps with admissions disabled make zero
//!   heap allocations, and admissions draw only recycled pool blocks.

use dsq::container::{quantize_container_with, synthetic_f32_container, Container};
use dsq::coordinator::sampler::SamplingParams;
use dsq::coordinator::scheduler::{ContinuousScheduler, ServeConfig, SubmitOutcome};
use dsq::coordinator::{Coordinator, Request};
use dsq::model::ModelConfig;
use dsq::quant::kernels::DispatchArm;
use dsq::quant::KvScheme;
use dsq::runtime::forward::{KvCache, MatvecMode};
use dsq::runtime::native::NativeEngine;
use dsq::runtime::Engine;
use dsq::util::rng::Pcg;
use std::collections::{HashMap, HashSet};
use std::sync::OnceLock;

// --- counting allocator (zero-alloc gate) ---------------------------------
//
// Per-thread allocation-event counter; the measured scheduler runs with
// `threads = 1` so the measuring thread sees every allocation.

use std::alloc::{GlobalAlloc, Layout, System};

struct CountingAlloc;

thread_local! {
    static THREAD_ALLOCS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(p, l, n)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

// --- shared fixtures ------------------------------------------------------

/// Quantized container bytes, built once per (model, scheme).
fn qbytes(model: &str, scheme: &str) -> &'static [u8] {
    static MOE_DQ3: OnceLock<Vec<u8>> = OnceLock::new();
    static MOE_Q4: OnceLock<Vec<u8>> = OnceLock::new();
    static DENSE_DQ3: OnceLock<Vec<u8>> = OnceLock::new();
    static DENSE_Q4: OnceLock<Vec<u8>> = OnceLock::new();
    let cell = match (model, scheme) {
        ("tiny-moe", "dq3_k_m") => &MOE_DQ3,
        ("tiny-moe", "q4_k_m") => &MOE_Q4,
        ("tiny-dense", "dq3_k_m") => &DENSE_DQ3,
        ("tiny-dense", "q4_k_m") => &DENSE_Q4,
        other => panic!("unexpected config {other:?}"),
    };
    cell.get_or_init(|| {
        let src = synthetic_f32_container(&ModelConfig::by_name(model).unwrap(), 0xCB07).unwrap();
        let scheme = dsq::scheme::builtin::scheme(scheme).unwrap();
        quantize_container_with(&src, &scheme, None, 1).unwrap().to_bytes()
    })
}

/// A 4-slot engine with a 12-token context: 5 mixed requests overflow
/// the batch (slot recycling) and the default 4-token blocks split each
/// slot across 3 pages.
fn engine(model: &str, scheme: &str, threads: usize) -> NativeEngine {
    let q = Container::from_bytes(qbytes(model, scheme).to_vec()).unwrap();
    NativeEngine::with_limits(q, threads, 4, 6, 12).unwrap()
}

/// [`engine`] with the KV cache switched to Q8_0 (set before the
/// scheduler exists, so its pool inherits the quantized block layout).
fn q8_engine(model: &str, scheme: &str, threads: usize, max_ctx: usize) -> NativeEngine {
    let q = Container::from_bytes(qbytes(model, scheme).to_vec()).unwrap();
    let mut eng = NativeEngine::with_limits(q, threads, 4, 6, max_ctx).unwrap();
    eng.set_kv_scheme(KvScheme::Q8_0).unwrap();
    eng
}

fn mk_req(id: u64, plen: usize, max_new: usize, seed: u64) -> Request {
    Request {
        id,
        prompt: (0..plen as i32).map(|i| (7 + id as i32 * 31 + i * 13) % 500).collect(),
        params: SamplingParams { temperature: 0.6, top_p: 0.95, max_new_tokens: max_new },
        seed,
    }
}

/// Mixed prompt/generation lengths; 5 requests > 4 slots.
fn mixed_requests() -> Vec<Request> {
    vec![
        mk_req(0, 1, 8, 101),
        mk_req(1, 4, 8, 102),
        mk_req(2, 6, 2, 103),
        mk_req(3, 3, 8, 104),
        mk_req(4, 5, 1, 105),
    ]
}

fn submit_all(sched: &mut ContinuousScheduler, reqs: &[Request]) {
    for r in reqs {
        match sched.submit(r.clone()).unwrap() {
            SubmitOutcome::Queued => {}
            SubmitOutcome::Backpressure(_) => panic!("unbounded queue backpressured"),
        }
    }
}

/// id → tokens for a batch of requests run through one scheduler.
fn run_batch(
    eng: &NativeEngine,
    cfg: ServeConfig,
    reqs: &[Request],
) -> HashMap<u64, Vec<i32>> {
    let mut sched = ContinuousScheduler::new(eng, cfg).unwrap();
    submit_all(&mut sched, reqs);
    let responses = sched.run_to_completion().unwrap();
    assert_eq!(responses.len(), reqs.len());
    responses.into_iter().map(|r| (r.id, r.tokens)).collect()
}

/// The same request run alone on a fresh scheduler — the differential
/// reference every batched stream must match bit for bit.
fn solo_tokens(eng: &NativeEngine, req: &Request) -> Vec<i32> {
    let mut sched = ContinuousScheduler::new(eng, ServeConfig::default()).unwrap();
    submit_all(&mut sched, std::slice::from_ref(req));
    let mut responses = sched.run_to_completion().unwrap();
    assert_eq!(responses.len(), 1);
    responses.pop().unwrap().tokens
}

// --- the differential gate ------------------------------------------------

#[test]
fn continuous_streams_match_solo_across_threads_kinds_schemes() {
    let reqs = mixed_requests();
    for model in ["tiny-moe", "tiny-dense"] {
        for scheme in ["dq3_k_m", "q4_k_m"] {
            // Solo references once per config (threads = 1); each
            // batched sweep must reproduce them exactly, which also
            // pins thread-count independence.
            let ref_eng = engine(model, scheme, 1);
            let solo: HashMap<u64, Vec<i32>> =
                reqs.iter().map(|r| (r.id, solo_tokens(&ref_eng, r))).collect();
            assert!(
                solo.values().any(|t| !t.is_empty()),
                "degenerate fixture: no request generated anything"
            );
            for threads in [1usize, 2, 8] {
                let eng = engine(model, scheme, threads);
                let batched = run_batch(&eng, ServeConfig::default(), &reqs);
                for r in &reqs {
                    assert_eq!(
                        batched[&r.id], solo[&r.id],
                        "{model}/{scheme} threads={threads} request {}: continuous \
                         stream diverged from solo run",
                        r.id
                    );
                }
            }
        }
    }
}

#[test]
fn continuous_streams_match_solo_on_every_pinned_arm() {
    let reqs = mixed_requests();
    let mut per_arm: Vec<HashMap<u64, Vec<i32>>> = Vec::new();
    for arm in DispatchArm::ALL {
        if !arm.available() {
            continue;
        }
        let mut eng = engine("tiny-moe", "dq3_k_m", 1);
        eng.set_mode(MatvecMode::Pinned(arm));
        let solo: HashMap<u64, Vec<i32>> =
            reqs.iter().map(|r| (r.id, solo_tokens(&eng, r))).collect();
        let batched = run_batch(&eng, ServeConfig::default(), &reqs);
        for r in &reqs {
            assert_eq!(batched[&r.id], solo[&r.id], "arm {:?} request {}", arm, r.id);
        }
        per_arm.push(batched);
    }
    // The arms are bit-identical by the kernel contract, so the served
    // streams must agree across arms too.
    for w in per_arm.windows(2) {
        assert_eq!(w[0], w[1], "dispatch arms disagree on served token streams");
    }
}

#[test]
fn admission_order_and_staggering_cannot_change_any_stream() {
    let eng = engine("tiny-moe", "q4_k_m", 2);
    let reqs = mixed_requests();
    let upfront = run_batch(&eng, ServeConfig::default(), &reqs);

    // Staggered, order-scrambled admission: 2 requests, a few steps,
    // 2 more in swapped order mid-generation, steps, the last one late.
    let mut sched = ContinuousScheduler::new(&eng, ServeConfig::default()).unwrap();
    submit_all(&mut sched, &[reqs[0].clone(), reqs[1].clone()]);
    for _ in 0..2 {
        sched.step().unwrap();
    }
    submit_all(&mut sched, &[reqs[3].clone(), reqs[2].clone()]);
    sched.step().unwrap();
    submit_all(&mut sched, &[reqs[4].clone()]);
    let staggered: HashMap<u64, Vec<i32>> = sched
        .run_to_completion()
        .unwrap()
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect();
    assert_eq!(staggered.len(), reqs.len());
    for r in &reqs {
        assert_eq!(
            staggered[&r.id], upfront[&r.id],
            "request {} changed under staggered admission",
            r.id
        );
    }
}

#[test]
fn continuous_matches_the_legacy_wave_scheduler() {
    // Equal prompt lengths make the wave's shared budget equal every
    // request's continuous budget, so the two schedulers must emit the
    // same streams (the wave loop stays live for PJRT and `--wave`).
    let reqs: Vec<Request> =
        (0..3).map(|i| mk_req(i, 4, 8, 0xCAFE + i)).collect();
    let continuous = run_batch(&engine("tiny-moe", "dq3_k_m", 1), ServeConfig::default(), &reqs);

    let q = Container::from_bytes(qbytes("tiny-moe", "dq3_k_m").to_vec()).unwrap();
    let wave_engine =
        Engine::from_native(NativeEngine::with_limits(q, 1, 4, 6, 12).unwrap()).unwrap();
    let mut coord = Coordinator::new(wave_engine);
    for r in &reqs {
        coord.submit(r.clone()).unwrap();
    }
    let mut wave = Vec::new();
    while coord.pending() > 0 {
        wave.extend(coord.run_wave().unwrap());
    }
    assert_eq!(wave.len(), reqs.len());
    for r in wave {
        assert_eq!(
            continuous[&r.id], r.tokens,
            "request {} differs between wave and continuous scheduling",
            r.id
        );
    }
}

// --- paged KV allocator properties ----------------------------------------

/// Random admit/grow/finish schedules against a real pool + caches:
/// no leaks, no aliasing, peak within the reservation bound.
#[test]
fn paged_allocator_random_schedules_hold_invariants() {
    let eng = engine("tiny-moe", "q4_k_m", 1);
    let fwd = eng.forward();
    let max_ctx = eng.max_ctx();
    for (bt, capacity, seed) in [(1usize, 8usize, 11u64), (2, 6, 22), (4, 9, 33), (5, 7, 44)] {
        let mut pool = fwd.new_block_pool(capacity, bt).unwrap();
        let n_slots = 4;
        let mut caches: Vec<KvCache> =
            (0..n_slots).map(|_| fwd.new_paged_cache(&pool).unwrap()).collect();
        // Per-slot (target_len, reserved) of the simulated requests.
        let mut active: Vec<Option<(usize, usize)>> = vec![None; n_slots];
        let mut rng = Pcg::new(seed);
        for _ in 0..400 {
            let i = rng.next_below(n_slots as u64) as usize;
            match active[i] {
                None => {
                    let target = 1 + rng.next_below(max_ctx as u64) as usize;
                    let need = target.div_ceil(bt);
                    if pool.try_reserve(need) {
                        active[i] = Some((target, need));
                        let first = 1 + rng.next_below(target as u64) as usize;
                        caches[i].grow_to(first, &mut pool).unwrap();
                    }
                }
                Some((target, need)) => {
                    let grown = caches[i].capacity();
                    if grown < target && rng.next_below(3) > 0 {
                        caches[i].grow_to((grown + 1).min(target), &mut pool).unwrap();
                    } else {
                        let freed = caches[i].release(&mut pool);
                        assert!(freed <= need, "released {freed} > reserved {need}");
                        pool.unreserve(need);
                        active[i] = None;
                    }
                }
            }
            // Invariants after every operation:
            let held: usize = caches.iter().map(|c| c.block_addrs().len()).sum();
            assert_eq!(pool.outstanding(), held, "pool/caches disagree on outstanding");
            let addrs: Vec<usize> = caches.iter().flat_map(|c| c.block_addrs()).collect();
            let uniq: HashSet<usize> = addrs.iter().copied().collect();
            assert_eq!(uniq.len(), addrs.len(), "two caches alias one block");
            assert!(pool.outstanding() <= pool.reserved());
            assert!(pool.reserved() <= pool.capacity());
            assert!(pool.peak_outstanding() <= pool.capacity());
        }
        for (i, cache) in caches.iter_mut().enumerate() {
            if let Some((_, need)) = active[i].take() {
                cache.release(&mut pool);
                pool.unreserve(need);
            }
        }
        assert_eq!(pool.outstanding(), 0, "blocks leaked");
        assert_eq!(pool.reserved(), 0, "reservations leaked");
        assert_eq!(pool.free_blocks(), pool.created(), "free list lost recycled blocks");
        assert!(pool.created() <= pool.capacity());
    }
}

/// The allocator property test again under a **Q8_0** KV cache, with
/// every swept `block_tokens` deliberately *not* a multiple (or
/// divisor) of the codec's 32-weight block: lines are padded to the
/// block grid inside each token slot, so block-boundary arithmetic and
/// codec-padding arithmetic land on different offsets. Reservation is
/// blocks-of-bytes: each block's byte footprint must be exactly
/// `block_tokens × bytes_per_token` under the quantized layout, and no
/// schedule may leak or alias a block.
#[test]
fn paged_allocator_random_schedules_hold_invariants_under_q8_kv() {
    let eng = q8_engine("tiny-moe", "q4_k_m", 1, 12);
    let fwd = eng.forward();
    let max_ctx = eng.max_ctx();
    for (bt, capacity, seed) in [(1usize, 8usize, 55u64), (3, 6, 66), (5, 7, 77), (7, 9, 88)] {
        assert_ne!(bt % 32, 0);
        let mut pool = fwd.new_block_pool(capacity, bt).unwrap();
        assert_eq!(pool.scheme(), KvScheme::Q8_0);
        assert_eq!(pool.block_bytes(), bt * pool.bytes_per_token());
        let n_slots = 4;
        let mut caches: Vec<KvCache> =
            (0..n_slots).map(|_| fwd.new_paged_cache(&pool).unwrap()).collect();
        let mut active: Vec<Option<(usize, usize)>> = vec![None; n_slots];
        let mut rng = Pcg::new(seed);
        for _ in 0..400 {
            let i = rng.next_below(n_slots as u64) as usize;
            match active[i] {
                None => {
                    let target = 1 + rng.next_below(max_ctx as u64) as usize;
                    let need = target.div_ceil(bt);
                    if pool.try_reserve(need) {
                        active[i] = Some((target, need));
                        let first = 1 + rng.next_below(target as u64) as usize;
                        caches[i].grow_to(first, &mut pool).unwrap();
                    }
                }
                Some((target, need)) => {
                    let grown = caches[i].capacity();
                    if grown < target && rng.next_below(3) > 0 {
                        caches[i].grow_to((grown + 1).min(target), &mut pool).unwrap();
                    } else {
                        let freed = caches[i].release(&mut pool);
                        assert!(freed <= need, "released {freed} > reserved {need}");
                        pool.unreserve(need);
                        active[i] = None;
                    }
                }
            }
            let held: usize = caches.iter().map(|c| c.block_addrs().len()).sum();
            assert_eq!(pool.outstanding(), held, "pool/caches disagree on outstanding");
            let addrs: Vec<usize> = caches.iter().flat_map(|c| c.block_addrs()).collect();
            let uniq: HashSet<usize> = addrs.iter().copied().collect();
            assert_eq!(uniq.len(), addrs.len(), "two caches alias one block");
            assert!(pool.outstanding() <= pool.reserved());
            assert!(pool.reserved() <= pool.capacity());
            assert!(pool.peak_outstanding() <= pool.capacity());
        }
        for (i, cache) in caches.iter_mut().enumerate() {
            if let Some((_, need)) = active[i].take() {
                cache.release(&mut pool);
                pool.unreserve(need);
            }
        }
        assert_eq!(pool.outstanding(), 0, "blocks leaked");
        assert_eq!(pool.reserved(), 0, "reservations leaked");
        assert_eq!(pool.free_blocks(), pool.created(), "free list lost recycled blocks");
        assert!(pool.created() <= pool.capacity());
    }
}

/// Q8_0 padding must not leak across token slots or block boundaries:
/// with `block_tokens = 3` (crossing the codec grid at every boundary)
/// a recycled paged q8 cache reconstructs the same encoded bytes a
/// fresh dense q8 cache holds after identical forwards — stale block
/// contents from the previous tenant never show through.
#[test]
fn q8_paged_padding_does_not_alias_across_recycled_blocks() {
    for model in ["tiny-moe", "tiny-dense"] {
        let eng = q8_engine(model, "q4_k_m", 1, 12);
        let fwd = eng.forward();
        let v = eng.vocab();
        let mut scratch = fwd.new_scratch_cols(4);
        let mut logits = vec![0f32; v];
        let mut pool = fwd.new_block_pool(4, 3).unwrap();

        // First tenant dirties the pool's blocks with its own rows.
        assert!(pool.try_reserve(4));
        let mut first = fwd.new_paged_cache(&pool).unwrap();
        first.grow_to(10, &mut pool).unwrap();
        let warm: Vec<i32> = (0..10).map(|i| 11 + i * 29).collect();
        fwd.forward_tokens(&warm, &mut first, &mut scratch, None).unwrap();
        first.release(&mut pool);
        pool.unreserve(4);

        // Second tenant recycles those dirty blocks for a shorter run.
        let toks: Vec<i32> = (0..7).map(|i| 3 + i * 37).collect();
        let mut dense = fwd.new_cache();
        fwd.forward_tokens(&toks, &mut dense, &mut scratch, Some(&mut logits)).unwrap();
        let dense_logits = logits.clone();

        assert!(pool.try_reserve(3));
        let mut paged = fwd.new_paged_cache(&pool).unwrap();
        paged.grow_to(toks.len(), &mut pool).unwrap();
        fwd.forward_tokens(&toks, &mut paged, &mut scratch, Some(&mut logits)).unwrap();
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&dense_logits), bits(&logits), "{model}: recycled-block logits");
        assert_eq!(
            dense.copy_rows_enc(),
            paged.copy_rows_enc(),
            "{model}: stale recycled bytes leaked into the encoded row plane"
        );
        assert_eq!(
            dense.copy_expanded_enc(),
            paged.copy_expanded_enc(),
            "{model}: stale recycled bytes leaked into the encoded expanded plane"
        );
        paged.release(&mut pool);
        pool.unreserve(3);
    }
}

/// A paged cache must hold the exact bits a dense cache holds after the
/// same forwards — planes and logits both.
#[test]
fn paged_cache_reconstructs_dense_reference_bit_for_bit() {
    for model in ["tiny-moe", "tiny-dense"] {
        let eng = engine(model, "q4_k_m", 1);
        let fwd = eng.forward();
        let v = eng.vocab();
        let toks: Vec<i32> = (0..8).map(|i| 3 + i * 37).collect();

        let mut dense = fwd.new_cache();
        let mut scratch = fwd.new_scratch_cols(4);
        let mut dense_logits = vec![0f32; v];
        fwd.forward_tokens(&toks[..6], &mut dense, &mut scratch, Some(&mut dense_logits))
            .unwrap();

        let mut pool = fwd.new_block_pool(6, 3).unwrap();
        assert!(pool.try_reserve(6));
        let mut paged = fwd.new_paged_cache(&pool).unwrap();
        // Grow incrementally across block boundaries, then forward the
        // same prefix: 6 prompt tokens as a panel, 2 more one by one.
        paged.grow_to(4, &mut pool).unwrap();
        paged.grow_to(6, &mut pool).unwrap();
        let mut paged_logits = vec![0f32; v];
        fwd.forward_tokens(&toks[..6], &mut paged, &mut scratch, Some(&mut paged_logits))
            .unwrap();
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&dense_logits), bits(&paged_logits), "{model} prefill logits");

        for &tok in &toks[6..] {
            fwd.forward_token(tok, &mut dense, &mut scratch, Some(&mut dense_logits)).unwrap();
            let len = paged.len();
            paged.grow_to(len + 1, &mut pool).unwrap();
            fwd.forward_token(tok, &mut paged, &mut scratch, Some(&mut paged_logits)).unwrap();
            assert_eq!(bits(&dense_logits), bits(&paged_logits), "{model} decode logits");
        }

        assert_eq!(dense.len(), paged.len());
        assert_eq!(
            bits(&dense.copy_rows()),
            bits(&paged.copy_rows()),
            "{model}: paged main plane diverged from dense"
        );
        assert_eq!(
            bits(&dense.copy_expanded()),
            bits(&paged.copy_expanded()),
            "{model}: paged expanded plane diverged from dense"
        );
        paged.release(&mut pool);
        pool.unreserve(6);
    }
}

// --- rejection, backpressure, cancel --------------------------------------

#[test]
fn impossible_block_demand_rejected_at_submit() {
    let eng = engine("tiny-moe", "q4_k_m", 1);
    // A 1-block pool can never serve a 4-prompt/8-new request.
    let cfg = ServeConfig { kv_blocks: 1, block_tokens: 2, max_pending: 0 };
    let mut sched = ContinuousScheduler::new(&eng, cfg).unwrap();
    let err = sched.submit(mk_req(0, 4, 8, 1)).unwrap_err().to_string();
    assert!(err.contains("KV blocks"), "error must name the resource: {err}");
    assert!(err.contains("kv-blocks"), "error must point at the remedy: {err}");
    assert_eq!(sched.pending(), 0, "rejected request must not be queued");
    assert_eq!(sched.metrics.rejected, 1);

    // Structural prompt errors still reject too.
    assert!(sched.submit(mk_req(1, 0, 4, 1)).is_err(), "empty prompt");
    let err = sched.submit(mk_req(2, 7, 4, 1)).unwrap_err().to_string();
    assert!(err.contains("out of range"), "{err}");

    // A request that fits the pool is accepted and completes even on
    // the minimal pool (1 block × 2 tokens ⇒ 1-token prompt, 1 token
    // generated).
    let mut req = mk_req(3, 1, 1, 9);
    req.params.max_new_tokens = 1;
    assert!(matches!(sched.submit(req).unwrap(), SubmitOutcome::Queued));
    let responses = sched.run_to_completion().unwrap();
    assert_eq!(responses.len(), 1);
    assert_eq!(responses[0].n_generated, 1);
    assert_eq!(sched.pool().outstanding(), 0);
}

#[test]
fn bounded_queue_backpressures_and_drains() {
    let eng = engine("tiny-moe", "q4_k_m", 1);
    let cfg = ServeConfig { max_pending: 1, ..ServeConfig::default() };
    let mut sched = ContinuousScheduler::new(&eng, cfg).unwrap();
    assert!(matches!(sched.submit(mk_req(0, 3, 4, 1)).unwrap(), SubmitOutcome::Queued));
    // Queue full: the request is handed back intact, not dropped.
    let r1 = mk_req(1, 4, 4, 2);
    match sched.submit(r1.clone()).unwrap() {
        SubmitOutcome::Backpressure(back) => {
            assert_eq!(back.id, r1.id);
            assert_eq!(back.prompt, r1.prompt);
        }
        SubmitOutcome::Queued => panic!("queue of depth 1 must backpressure"),
    }
    // One step admits the queued request; the retry then lands.
    sched.step().unwrap();
    assert!(matches!(sched.submit(r1).unwrap(), SubmitOutcome::Queued));
    let responses = sched.run_to_completion().unwrap();
    assert_eq!(responses.len(), 2);
}

#[test]
fn cancel_queued_and_mid_generation_recycles_blocks() {
    let eng = engine("tiny-moe", "q4_k_m", 1);
    let mut sched = ContinuousScheduler::new(&eng, ServeConfig::default()).unwrap();
    let reqs: Vec<Request> = (0..5).map(|i| mk_req(i, 3, 8, 0xD00 + i)).collect();
    submit_all(&mut sched, &reqs);
    // Cancel one while still queued (batch = 4, request 4 queues).
    assert!(sched.cancel(4), "queued request must be cancellable");
    // Admit + a couple of decode steps, then cancel one mid-generation.
    // A slot is free to finish early on EOS, so pick a cancel target
    // that is verifiably still live.
    sched.step().unwrap();
    sched.step().unwrap();
    let mut responses = sched.take_responses();
    let finished_early: HashSet<u64> = responses.iter().map(|r| r.id).collect();
    let target = (0..4u64).find(|i| !finished_early.contains(i)).expect("a live request");
    let live_before = sched.live();
    let outstanding_before = sched.pool().outstanding();
    assert!(sched.cancel(target), "live request must be cancellable");
    assert_eq!(sched.live(), live_before - 1);
    assert!(
        sched.pool().outstanding() < outstanding_before,
        "cancelling a live request must return its blocks to the pool"
    );
    assert!(!sched.cancel(target), "double-cancel must report nothing to do");
    assert!(!sched.cancel(99), "unknown id must report nothing to do");

    // The survivors run to completion, bit-identical to solo runs.
    responses.extend(sched.run_to_completion().unwrap());
    let ids: HashSet<u64> = responses.iter().map(|r| r.id).collect();
    let expected: HashSet<u64> = (0..4).filter(|&i| i != target).collect();
    assert_eq!(ids, expected, "cancelled requests must not respond");
    assert_eq!(sched.metrics.cancelled, 2);
    assert_eq!(sched.pool().outstanding(), 0);
    for r in responses {
        assert_eq!(
            r.tokens,
            solo_tokens(&eng, &reqs[r.id as usize]),
            "survivor {} perturbed by cancellations",
            r.id
        );
    }
}

#[test]
fn tiny_pool_forces_serial_admission_but_streams_are_unchanged() {
    let eng = engine("tiny-moe", "q4_k_m", 1);
    // 3 blocks × 4 tokens = one worst-case request at a time: the four
    // requests must trickle through serially (peak ≤ 3 blocks) and
    // still match their solo streams exactly.
    let cfg = ServeConfig { kv_blocks: 3, block_tokens: 4, max_pending: 0 };
    let reqs: Vec<Request> = (0..4).map(|i| mk_req(i, 4, 8, 0xE00 + i)).collect();
    let batched = run_batch(&eng, cfg, &reqs);
    let mut sched = ContinuousScheduler::new(&eng, cfg).unwrap();
    submit_all(&mut sched, &reqs);
    sched.run_to_completion().unwrap();
    assert!(sched.pool().peak_outstanding() <= 3, "pool overcommitted beyond capacity");
    for r in &reqs {
        assert_eq!(batched[&r.id], solo_tokens(&eng, r), "request {} diverged", r.id);
    }
}

#[test]
fn zero_budget_request_completes_empty() {
    let eng = engine("tiny-moe", "q4_k_m", 1);
    let mut sched = ContinuousScheduler::new(&eng, ServeConfig::default()).unwrap();
    let mut req = mk_req(0, 3, 0, 7);
    req.params.max_new_tokens = 0;
    submit_all(&mut sched, std::slice::from_ref(&req));
    let responses = sched.run_to_completion().unwrap();
    assert_eq!(responses.len(), 1);
    assert!(responses[0].tokens.is_empty());
    assert_eq!(responses[0].n_generated, 0);
    assert_eq!(sched.pool().outstanding(), 0);
}

// --- the zero-alloc gate --------------------------------------------------

/// After a warmup workload has populated the pool's free list and grown
/// every reusable buffer, (a) admissions draw only recycled KV blocks
/// (`created()` stays flat, zero heap events), and (b) decode steps
/// with admissions disabled make zero heap allocations — including
/// steps that cross a block boundary.
#[test]
fn steady_state_continuous_decode_is_allocation_free() {
    // Taller context so the measured requests cross block boundaries
    // (prompt 4 + 8 new = 12 tokens over 4-token blocks) without
    // finishing during the measured steps.
    let q = Container::from_bytes(qbytes("tiny-moe", "q4_k_m").to_vec()).unwrap();
    let eng = NativeEngine::with_limits(q, 1, 4, 6, 16).unwrap();
    let mut sched = ContinuousScheduler::new(&eng, ServeConfig::default()).unwrap();

    // Warmup: a full 4-slot workload end to end.
    let warm: Vec<Request> = (0..4).map(|i| mk_req(i, 4, 8, 0xF0 + i)).collect();
    submit_all(&mut sched, &warm);
    sched.run_to_completion().unwrap();

    // Fresh submissions (queue pushes may allocate — not under test).
    let fresh: Vec<Request> = (10..14).map(|i| mk_req(i, 4, 8, 0xF0 + i)).collect();
    submit_all(&mut sched, &fresh);

    // (a) Admission: only recycled pool blocks, no heap traffic. (A
    // request that finishes *at* admission — instant EOS — allocates
    // its response, so the heap assertion only binds when none did.)
    let created_before = sched.pool().created();
    let a0 = thread_allocs();
    assert_eq!(sched.admit().unwrap(), 4);
    let admit_allocs = thread_allocs() - a0;
    assert_eq!(
        sched.pool().created(),
        created_before,
        "admission must be served from the recycled free list"
    );
    if sched.live() == 4 {
        assert_eq!(admit_allocs, 0, "admission after warmup must not touch the heap");
    }

    // (b) Decode with admissions disabled: zero allocations on every
    // step where no request finished (finishing legitimately allocates
    // the response). The budget keeps all four slots live well past
    // the measured window, so the clean-step floor is deterministic.
    let mut clean_steps = 0;
    for _ in 0..5 {
        let live_before = sched.live();
        if live_before == 0 {
            break;
        }
        let d0 = thread_allocs();
        let stepped = sched.decode_step().unwrap();
        assert_eq!(stepped, live_before);
        if sched.live() == live_before {
            assert_eq!(
                thread_allocs() - d0,
                0,
                "steady-state decode step touched the heap"
            );
            clean_steps += 1;
        }
    }
    assert!(clean_steps >= 2, "only {clean_steps} finish-free decode steps measured");
    sched.run_to_completion().unwrap();
    assert_eq!(sched.metrics.completed, 8);
}

/// The zero-alloc gate under a **Q8_0** KV cache: quantize-on-append
/// encodes into the block's preallocated byte plane and fused reads
/// decode into preallocated scratch, so post-warmup continuous decode
/// must stay exactly as allocation-free as the f32 path.
#[test]
fn steady_state_q8_continuous_decode_is_allocation_free() {
    let eng = q8_engine("tiny-moe", "q4_k_m", 1, 16);
    let mut sched = ContinuousScheduler::new(&eng, ServeConfig::default()).unwrap();
    assert_eq!(sched.pool().scheme(), KvScheme::Q8_0);

    // Warmup: a full 4-slot workload end to end.
    let warm: Vec<Request> = (0..4).map(|i| mk_req(i, 4, 8, 0xF0 + i)).collect();
    submit_all(&mut sched, &warm);
    sched.run_to_completion().unwrap();

    let fresh: Vec<Request> = (10..14).map(|i| mk_req(i, 4, 8, 0xF0 + i)).collect();
    submit_all(&mut sched, &fresh);

    let created_before = sched.pool().created();
    let a0 = thread_allocs();
    assert_eq!(sched.admit().unwrap(), 4);
    let admit_allocs = thread_allocs() - a0;
    assert_eq!(
        sched.pool().created(),
        created_before,
        "q8 admission must be served from the recycled free list"
    );
    if sched.live() == 4 {
        assert_eq!(admit_allocs, 0, "q8 admission after warmup must not touch the heap");
    }

    let mut clean_steps = 0;
    for _ in 0..5 {
        let live_before = sched.live();
        if live_before == 0 {
            break;
        }
        let d0 = thread_allocs();
        let stepped = sched.decode_step().unwrap();
        assert_eq!(stepped, live_before);
        if sched.live() == live_before {
            assert_eq!(
                thread_allocs() - d0,
                0,
                "steady-state q8 decode step touched the heap"
            );
            clean_steps += 1;
        }
    }
    assert!(clean_steps >= 2, "only {clean_steps} finish-free q8 decode steps measured");
    sched.run_to_completion().unwrap();
    assert_eq!(sched.metrics.completed, 8);
}

/// End-to-end continuous serving under q8_0 KV: every batched stream
/// matches its solo q8 run bit for bit (sampling included), and the
/// serving report names the quantized scheme with its measured
/// bytes-per-token.
#[test]
fn q8_continuous_streams_match_q8_solo() {
    let reqs = mixed_requests();
    for threads in [1usize, 2] {
        let eng = q8_engine("tiny-moe", "q4_k_m", threads, 12);
        let solo: HashMap<u64, Vec<i32>> =
            reqs.iter().map(|r| (r.id, solo_tokens(&eng, r))).collect();
        let mut sched = ContinuousScheduler::new(&eng, ServeConfig::default()).unwrap();
        submit_all(&mut sched, &reqs);
        let responses = sched.run_to_completion().unwrap();
        assert_eq!(responses.len(), reqs.len());
        for r in responses {
            assert_eq!(
                r.tokens, solo[&r.id],
                "threads={threads} request {}: q8 continuous stream diverged from q8 solo",
                r.id
            );
        }
        let report = sched.metrics.report();
        assert!(
            report.contains("kv: scheme q8_0"),
            "serving report must name the KV scheme:\n{report}"
        );
        let bpt = sched.pool().bytes_per_token();
        assert!(
            report.contains(&format!("{bpt} B/token")),
            "serving report must carry the measured bytes/token:\n{report}"
        );
    }
}
