//! Acceptance suite for the sharded native engine
//! (`runtime::sharded`): the Table-2 8-device deployment plan run as
//! real cooperating shard workers must be **bit-identical** to the
//! unsharded engine, and the analytic memory planner must predict the
//! engine's per-shard resident bytes exactly.
//!
//! Three locks:
//!
//! 1. **Shard-count identity** — logits from the per-token loop, the
//!    panel prefill (`forward_tokens`, including both KV-cache planes),
//!    and batched decode (`forward_step_batch`) are bit-identical to
//!    local execution for every shard count in {1, 2, 4, 8}, across
//!    matvec thread counts, every available pinned dispatch arm
//!    (CI reruns the suite under each `DSQ_FORCE_ARM`), absorbed and
//!    eager MLA, and both architecture families — plus the scaled
//!    671B deployment proxy at the full 8-shard Table-2 shape.
//! 2. **Planner-vs-engine weights** — [`dsq::memory::shard_weights`]
//!    must match [`ShardRuntime::shard_plan`] tensor for tensor and
//!    byte for byte; any drift fails with a named-tensor diff.
//! 3. **Planner-vs-engine KV** — `kv_bytes_per_token` must agree with
//!    the rows the dense cache and the paged block pool actually
//!    allocate, for both model kinds.
//!
//! [`ShardRuntime::shard_plan`]: dsq::runtime::sharded::ShardRuntime::shard_plan

use dsq::container::{quantize_container_with, synthetic_f32_container, Container};
use dsq::coordinator::sampler::argmax;
use dsq::memory::shard_weights;
use dsq::model::ModelConfig;
use dsq::quant::kernels::DispatchArm;
use dsq::runtime::forward::{ForwardPass, MatvecMode};
use dsq::runtime::native::NATIVE_MAX_CTX;
use dsq::runtime::sharded::ShardRuntime;
use dsq::scheme::builtin;
use std::sync::OnceLock;

/// Same golden script as `tests/native_forward.rs`.
const PROMPT: [i32; 8] = [1, 17, 300, 42, 511, 7, 5, 260];
const DECODE_STEPS: usize = 3;

const MODELS: [&str; 2] = ["tiny-moe", "tiny-dense"];
const SCHEMES: [&str; 2] = ["dq3_k_m", "q4_k_m"];
const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Quantized golden-container bytes, built once per (model, scheme).
fn qbytes(model: &str, scheme: &str) -> &'static [u8] {
    static MOE_DQ3: OnceLock<Vec<u8>> = OnceLock::new();
    static MOE_Q4: OnceLock<Vec<u8>> = OnceLock::new();
    static DENSE_DQ3: OnceLock<Vec<u8>> = OnceLock::new();
    static DENSE_Q4: OnceLock<Vec<u8>> = OnceLock::new();
    static SIM_Q4: OnceLock<Vec<u8>> = OnceLock::new();
    let cell = match (model, scheme) {
        ("tiny-moe", "dq3_k_m") => &MOE_DQ3,
        ("tiny-moe", "q4_k_m") => &MOE_Q4,
        ("tiny-dense", "dq3_k_m") => &DENSE_DQ3,
        ("tiny-dense", "q4_k_m") => &DENSE_Q4,
        ("deepseek-v3-671b-sim", "q4_k_m") => &SIM_Q4,
        other => panic!("unexpected combination {other:?}"),
    };
    cell.get_or_init(|| {
        let cfg = ModelConfig::by_name(model).unwrap();
        let src = synthetic_f32_container(&cfg, 0x601D).unwrap();
        let scheme = builtin::scheme(scheme).unwrap();
        quantize_container_with(&src, &scheme, None, 4).unwrap().to_bytes()
    })
}

fn forward(model: &str, scheme: &str, threads: usize, shards: usize) -> ForwardPass {
    let ckpt = Container::from_bytes(qbytes(model, scheme).to_vec()).unwrap();
    let mut fwd = ForwardPass::new(ckpt, threads, NATIVE_MAX_CTX).unwrap();
    fwd.set_sharding(shards).unwrap();
    fwd
}

/// Prefill `prompt` token by token (logits at the last), then greedy
/// decode; returns every emitted logits row.
fn run_script(fwd: &ForwardPass, prompt: &[i32], steps: usize) -> Vec<Vec<f32>> {
    let mut cache = fwd.new_cache();
    let mut scratch = fwd.new_scratch();
    let mut logits = vec![0f32; fwd.vocab()];
    for (j, &t) in prompt.iter().enumerate() {
        let want = if j + 1 == prompt.len() { Some(&mut logits[..]) } else { None };
        fwd.forward_token(t, &mut cache, &mut scratch, want).unwrap();
    }
    let mut rows = vec![logits.clone()];
    for _ in 0..steps {
        let tok = argmax(rows.last().unwrap());
        fwd.forward_token(tok, &mut cache, &mut scratch, Some(&mut logits)).unwrap();
        rows.push(logits.clone());
    }
    rows
}

fn bits(rows: &[Vec<f32>]) -> Vec<u32> {
    rows.iter().flatten().map(|v| v.to_bits()).collect()
}

fn slice_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

// --- lock 1: shard-count identity -----------------------------------------

#[test]
fn token_loop_is_bit_identical_across_shard_counts() {
    for model in MODELS {
        for scheme in SCHEMES {
            let base = bits(&run_script(&forward(model, scheme, 2, 0), &PROMPT, DECODE_STEPS));
            for shards in SHARD_COUNTS {
                for threads in [1usize, 2] {
                    let fwd = forward(model, scheme, threads, shards);
                    assert_eq!(fwd.shard_count(), shards);
                    assert_eq!(
                        base,
                        bits(&run_script(&fwd, &PROMPT, DECODE_STEPS)),
                        "{model}/{scheme}: shards={shards} threads={threads}"
                    );
                }
            }
        }
    }
}

/// Panel prefill under sharding: logits, both cache planes, and the
/// decode step that continues off the panel cache all match local bits.
#[test]
fn panel_prefill_is_bit_identical_under_sharding() {
    for model in MODELS {
        for scheme in SCHEMES {
            let local = forward(model, scheme, 2, 0);
            let mut c1 = local.new_cache();
            let mut s1 = local.new_scratch();
            let mut l1 = vec![0f32; local.vocab()];
            local.forward_tokens(&PROMPT, &mut c1, &mut s1, Some(&mut l1)).unwrap();

            let sharded = forward(model, scheme, 2, 4);
            let mut c2 = sharded.new_cache();
            let mut s2 = sharded.new_scratch();
            let mut l2 = vec![0f32; sharded.vocab()];
            sharded.forward_tokens(&PROMPT, &mut c2, &mut s2, Some(&mut l2)).unwrap();

            assert_eq!(slice_bits(&l1), slice_bits(&l2), "{model}/{scheme}: panel logits");
            assert_eq!(
                slice_bits(c1.raw_rows()),
                slice_bits(c2.raw_rows()),
                "{model}/{scheme}: latent/K-V cache plane"
            );
            assert_eq!(
                slice_bits(c1.raw_expanded()),
                slice_bits(c2.raw_expanded()),
                "{model}/{scheme}: expanded-KV plane"
            );
            let tok = argmax(&l1);
            local.forward_token(tok, &mut c1, &mut s1, Some(&mut l1)).unwrap();
            sharded.forward_token(tok, &mut c2, &mut s2, Some(&mut l2)).unwrap();
            assert_eq!(slice_bits(&l1), slice_bits(&l2), "{model}/{scheme}: decode after panel");
        }
    }
}

/// Batched decode (`forward_step_batch`, the continuous-serving step,
/// dead slot included) under sharding matches local bits per slot per
/// step.
#[test]
fn batched_decode_is_bit_identical_under_sharding() {
    for model in MODELS {
        let prompts: [&[i32]; 3] = [&[1, 17, 300], &[42, 511], &[7, 5, 260, 9]];
        let live = [true, false, true];
        let steps = 3;
        let mut per_engine: Vec<Vec<u32>> = Vec::new();
        for shards in [0usize, 2] {
            let fwd = forward(model, "q4_k_m", 2, shards);
            let mut caches: Vec<_> = (0..prompts.len()).map(|_| fwd.new_cache()).collect();
            let mut scratch = fwd.new_scratch_cols(prompts.len());
            let mut logits = vec![0f32; prompts.len() * fwd.vocab()];
            for (slot, p) in prompts.iter().enumerate() {
                for &t in *p {
                    fwd.forward_token(t, &mut caches[slot], &mut scratch, None).unwrap();
                }
            }
            let mut all = Vec::new();
            let mut toks = [2i32, 3, 4];
            for _ in 0..steps {
                fwd.forward_step_batch(&toks, &live, &mut caches, &mut scratch, &mut logits)
                    .unwrap();
                all.extend(slice_bits(&logits));
                for (slot, t) in toks.iter_mut().enumerate() {
                    if live[slot] {
                        let v = fwd.vocab();
                        *t = argmax(&logits[slot * v..(slot + 1) * v]);
                    }
                }
            }
            per_engine.push(all);
        }
        assert_eq!(per_engine[0], per_engine[1], "{model}: batched decode local vs 2 shards");
    }
}

#[test]
fn sharding_is_bit_identical_on_every_pinned_arm() {
    for model in MODELS {
        for arm in DispatchArm::ALL {
            if !arm.available() {
                continue;
            }
            let mut local = forward(model, "dq3_k_m", 1, 0);
            local.set_mode(MatvecMode::Pinned(arm));
            let base = bits(&run_script(&local, &PROMPT, DECODE_STEPS));
            let mut sharded = forward(model, "dq3_k_m", 1, 2);
            sharded.set_mode(MatvecMode::Pinned(arm));
            assert_eq!(
                base,
                bits(&run_script(&sharded, &PROMPT, DECODE_STEPS)),
                "{model}: pinned {} arm under sharding",
                arm.name()
            );
        }
    }
}

#[test]
fn eager_mla_is_bit_identical_under_sharding() {
    let mut local = forward("tiny-moe", "dq3_k_m", 1, 0);
    local.set_mla_absorption(false);
    let base = bits(&run_script(&local, &PROMPT, DECODE_STEPS));
    let mut sharded = forward("tiny-moe", "dq3_k_m", 1, 4);
    sharded.set_mla_absorption(false);
    assert_eq!(base, bits(&run_script(&sharded, &PROMPT, DECODE_STEPS)), "eager MLA sharded");
}

/// The scaled 671B deployment proxy at the full Table-2 shape: 64
/// routed experts over 8 shards (8 experts per shard, mirroring the
/// paper's 256/32 per device).
#[test]
fn sim_671b_is_bit_identical_at_8_shards() {
    let model = "deepseek-v3-671b-sim";
    let prompt = [1i32, 17, 1000, 42];
    let base = bits(&run_script(&forward(model, "q4_k_m", 2, 0), &prompt, 2));
    let fwd = forward(model, "q4_k_m", 2, 8);
    assert_eq!(base, bits(&run_script(&fwd, &prompt, 2)), "671b-sim at 8 shards");
}

// --- lock 2: planner-vs-engine weight bytes -------------------------------

/// The planner's per-shard per-tensor byte predictions must match what
/// the shard loader actually allocated — reported tensor by tensor.
#[test]
fn planner_predicts_engine_shard_bytes_exactly() {
    for model in ["tiny-moe", "tiny-dense", "deepseek-v3-671b-sim"] {
        let scheme_name = "q4_k_m";
        let ckpt = Container::from_bytes(qbytes(model, scheme_name).to_vec()).unwrap();
        let scheme = builtin::scheme(scheme_name).unwrap();
        for shards in SHARD_COUNTS {
            let engine = ShardRuntime::new(&ckpt, shards).unwrap();
            let predicted = shard_weights(&ckpt.model, &scheme, shards).unwrap();
            let measured = engine.shard_plan();
            assert_eq!(predicted.len(), measured.len(), "{model}: shard count");
            let mut diffs = Vec::new();
            for (s, (p, m)) in predicted.iter().zip(measured).enumerate() {
                let pn: Vec<&str> = p.iter().map(|(n, _)| n.as_str()).collect();
                let mn: Vec<&str> = m.iter().map(|(n, _)| n.as_str()).collect();
                assert_eq!(pn, mn, "{model} shard {s}: sliced-tensor sets differ");
                for ((name, pb), (_, mb)) in p.iter().zip(m) {
                    if pb != mb {
                        diffs.push(format!("shard {s} {name}: planner {pb} vs engine {mb}"));
                    }
                }
            }
            assert!(
                diffs.is_empty(),
                "{model} at {shards} shards: planner-vs-engine weight bytes drifted:\n{}",
                diffs.join("\n")
            );
            // Resident totals are the plan's row sums.
            for (s, shard) in measured.iter().enumerate() {
                let total: u64 = shard.iter().map(|(_, b)| b).sum();
                assert_eq!(engine.resident_bytes()[s], total, "{model} shard {s} resident");
            }
        }
    }
}

// --- lock 3: planner-vs-engine KV bytes -----------------------------------

/// `kv_bytes_per_token` (the f16 deployment arithmetic behind Table 1)
/// must agree element-for-element with the rows the engine's dense
/// cache and paged block pool allocate (f32 planes, hence the factor
/// of 2 between bytes-per-token and elements-per-token).
#[test]
fn planner_kv_bytes_match_engine_cache_allocation() {
    for model in MODELS {
        let fwd = forward(model, "q4_k_m", 1, 0);
        let cfg = fwd.config();
        let width = cfg.kv_cache_width();
        assert_eq!(
            cfg.kv_bytes_per_token(),
            cfg.n_layers * width * 2,
            "{model}: planner kv arithmetic"
        );
        // Dense: the lazily allocated plane holds exactly
        // n_layers × max_ctx × width f32 elements.
        let mut cache = fwd.new_cache();
        let mut scratch = fwd.new_scratch();
        fwd.forward_token(1, &mut cache, &mut scratch, None).unwrap();
        let per_pos = cache.raw_rows().len() / NATIVE_MAX_CTX;
        assert_eq!(
            per_pos * 2,
            cfg.kv_bytes_per_token(),
            "{model}: dense cache rows vs planner bytes per token"
        );
        // Paged: growing to k tokens takes exactly ceil(k / block_tokens)
        // blocks, each covering block_tokens positions of the same width.
        let block_tokens = 4usize;
        let mut pool = fwd.new_block_pool(4, block_tokens).unwrap();
        let mut paged = fwd.new_paged_cache(&pool).unwrap();
        assert!(pool.try_reserve(2));
        paged.grow_to(6, &mut pool).unwrap();
        assert_eq!(paged.block_addrs().len(), 2, "{model}: blocks for 6 tokens");
        assert_eq!(pool.outstanding(), 2);
        let covered = paged.block_addrs().len() * block_tokens;
        let pool_bytes_f16 = covered * cfg.n_layers * width * 2;
        assert_eq!(
            pool_bytes_f16,
            covered * cfg.kv_bytes_per_token(),
            "{model}: paged pool allocation vs planner bytes"
        );
        paged.release(&mut pool);
        pool.unreserve(2);
    }
}
