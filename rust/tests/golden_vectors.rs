//! Golden-vector regression suite: seeded input block → expected
//! encoded bytes, pinned in fixture files under `tests/golden/`.
//!
//! Any byte drift in a codec — from the SIMD scale-search kernels, the
//! block-parallel paths, or an (intended or not) algorithm change —
//! fails these tests with the offending format named. Fixtures are
//! *blessed on first run* (a missing `.hex`/`.fnv64` file is written
//! from the current encoder and the test passes with a notice); commit
//! the generated files to lock the codecs down. To intentionally
//! re-bless after an algorithm change, delete the fixture and rerun.
//!
//! CI runs this suite twice in release mode: once with the default
//! lane-kernel dispatch and once with `DSQ_SCALAR_SEARCH=1`, so both
//! dispatch arms are pinned to the *same* fixtures.

use dsq::container::{quantize_container_with, synthetic_f32_container};
use dsq::model::ModelConfig;
use dsq::quant::{self, parallel, QuantFormat};
use dsq::util::fnv64;
use dsq::util::rng::Pcg;
use std::path::PathBuf;

const NBLOCKS: usize = 3;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Deterministic input exercising edge features: exact zeros, a large
/// positive/negative outlier pair, and gaussian bulk at mixed scale.
fn golden_input(fmt: QuantFormat) -> (Vec<f32>, Vec<f32>) {
    let n = fmt.block_weights() * NBLOCKS;
    let mut rng = Pcg::new(0x601D ^ ((fmt.block_bytes() as u64) << 16));
    let mut data: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.1).collect();
    data[0] = 0.0;
    if n >= 8 {
        data[5] = 1.5;
        data[6] = -2.25;
        data[7] = 0.0;
    }
    let imp: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
    (data, imp)
}

fn hex(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() * 2 + bytes.len() / 16);
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && i % 32 == 0 {
            out.push('\n');
        }
        out.push_str(&format!("{b:02x}"));
    }
    out.push('\n');
    out
}

fn parse_hex(text: &str) -> Vec<u8> {
    let digits: Vec<u8> = text
        .chars()
        .filter(|c| c.is_ascii_hexdigit())
        .map(|c| c.to_digit(16).unwrap() as u8)
        .collect();
    assert_eq!(digits.len() % 2, 0, "odd hex digit count in fixture");
    digits.chunks_exact(2).map(|p| (p[0] << 4) | p[1]).collect()
}

/// Compare against the fixture, blessing it when absent.
fn check_fixture(label: &str, file: &str, bytes: &[u8]) {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(file);
    if !path.exists() {
        std::fs::write(&path, hex(bytes)).unwrap();
        eprintln!("[golden] blessed new fixture {} — commit it", path.display());
        return;
    }
    let expect = parse_hex(&std::fs::read_to_string(&path).unwrap());
    assert_eq!(
        expect,
        bytes,
        "{label}: encoded bytes drifted from {}; if the codec change is \
         intentional, delete the fixture and rerun to re-bless",
        path.display()
    );
}

#[test]
fn golden_vectors_every_builtin_format() {
    for fmt in QuantFormat::ALL {
        let (data, imp) = golden_input(fmt);
        for (variant, importance) in [("plain", None), ("imatrix", Some(imp.as_slice()))] {
            let mut packed = vec![0u8; fmt.row_bytes(data.len()).unwrap()];
            quant::quantize_into_with(fmt, &data, importance, &mut packed, 1).unwrap();
            check_fixture(
                &format!("{fmt} {variant}"),
                &format!("{}.{variant}.hex", fmt.name()),
                &packed,
            );
        }
    }
}

/// Scheme-level golden: the whole quantized container (header + every
/// tensor payload) for the paper's DQ3_K_M recipe — this pins the
/// dynamic sub-format assignment (q6_k early MoE layers, q4_k period
/// layers, q3_k bulk) together with every codec it uses, plus the plain
/// q4_k_m recipe. Checksummed (FNV-1a 64) rather than stored raw.
#[test]
fn golden_container_checksums() {
    let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 0x601D).unwrap();
    for scheme_name in ["dq3_k_m", "q4_k_m"] {
        let scheme = dsq::scheme::builtin::scheme(scheme_name).unwrap();
        let bytes = quantize_container_with(&src, &scheme, None, 1)
            .unwrap()
            .to_bytes();
        let line = format!("{:016x} {}\n", fnv64(&bytes), bytes.len());
        let dir = golden_dir();
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("container.{scheme_name}.fnv64"));
        if !path.exists() {
            std::fs::write(&path, &line).unwrap();
            eprintln!("[golden] blessed new fixture {} — commit it", path.display());
            continue;
        }
        let expect = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            expect.trim(),
            line.trim(),
            "container bytes for scheme {scheme_name} drifted from {}",
            path.display()
        );
    }
}

/// The drift half that needs no fixtures: parallel encode/decode at
/// thread counts {1, 2, 8} must be byte-identical for every format,
/// with and without an imatrix. (SIMD-vs-scalar identity is asserted
/// bitwise in the `quant::simd` / `quant::scalar` unit tests and by
/// running this whole suite under `DSQ_SCALAR_SEARCH=1` in CI.)
#[test]
fn no_byte_drift_across_thread_counts() {
    for fmt in QuantFormat::ALL {
        let (data, imp) = golden_input(fmt);
        for importance in [None, Some(imp.as_slice())] {
            let nbytes = fmt.row_bytes(data.len()).unwrap();
            let mut base = vec![0u8; nbytes];
            quant::quantize_into_with(fmt, &data, importance, &mut base, 1).unwrap();
            let mut dec_base = vec![0f32; data.len()];
            quant::dequantize_into_with(fmt, &base, &mut dec_base, 1).unwrap();
            for threads in [2usize, 8] {
                let mut packed = vec![0u8; nbytes];
                quant::quantize_into_with(fmt, &data, importance, &mut packed, threads).unwrap();
                assert_eq!(base, packed, "{fmt} encode threads={threads}");
                let mut dec = vec![0f32; data.len()];
                quant::dequantize_into_with(fmt, &packed, &mut dec, threads).unwrap();
                assert_eq!(dec_base, dec, "{fmt} decode threads={threads}");
            }
        }
    }
}

/// Release-mode heavyweight variant: tensors big enough that the
/// auto-threading path engages real block splits, swept over thread
/// counts {1, 2, 8}. Ignored by default (slow in debug); the CI release
/// job runs it via `--include-ignored` — where autovectorization is
/// actually active, so this is the SIMD-path byte-drift gate.
#[test]
#[ignore = "large-tensor thread sweep; run in release via --include-ignored"]
fn no_byte_drift_large_tensors_release() {
    for fmt in [
        QuantFormat::Q8_0,
        QuantFormat::Q6K,
        QuantFormat::Q5K,
        QuantFormat::Q4K,
        QuantFormat::Q3K,
        QuantFormat::Q2K,
    ] {
        let n = 2 * parallel::PAR_MIN_WEIGHTS; // multiple of every block size
        let mut rng = Pcg::new(0xB16 ^ fmt.block_bytes() as u64);
        let data: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.05).collect();
        let nbytes = fmt.row_bytes(n).unwrap();
        let mut base = vec![0u8; nbytes];
        quant::quantize_into_with(fmt, &data, None, &mut base, 1).unwrap();
        let mut dec_base = vec![0f32; n];
        quant::dequantize_into_with(fmt, &base, &mut dec_base, 1).unwrap();
        for threads in [2usize, 8] {
            let mut packed = vec![0u8; nbytes];
            quant::quantize_into_with(fmt, &data, None, &mut packed, threads).unwrap();
            assert_eq!(base, packed, "{fmt} encode threads={threads}");
            let mut dec = vec![0f32; n];
            quant::dequantize_into_with(fmt, &packed, &mut dec, threads).unwrap();
            assert_eq!(dec_base, dec, "{fmt} decode threads={threads}");
        }
    }
}
