//! Property-style randomized tests over the codec + scheme + container
//! stack (in-tree deterministic RNG substitutes for `proptest`, which is
//! unavailable offline — each test sweeps hundreds of random cases and
//! prints the failing seed on assertion).

use dsq::container::{
    load_imatrix, quantize_container, quantize_container_with, synthetic_f32_container, Container,
    Writer,
};
use dsq::model::{ModelConfig, ModuleClass, TensorInfo};
use dsq::quant::{self, error::rel_rmse, QuantFormat};
use dsq::scheme::builtin;
use dsq::util::rng::Pcg;
use std::collections::HashMap;

const KQ: [QuantFormat; 6] = [
    QuantFormat::Q8_0,
    QuantFormat::Q6K,
    QuantFormat::Q5K,
    QuantFormat::Q4K,
    QuantFormat::Q3K,
    QuantFormat::Q2K,
];

/// Error bounds (relative RMSE on unit gaussian) per format — generous
/// versions of the theoretical uniform-quantizer error.
fn bound(fmt: QuantFormat) -> f64 {
    match fmt {
        QuantFormat::Q8_0 => 0.01,
        QuantFormat::Q6K => 0.025,
        QuantFormat::Q5K => 0.055,
        QuantFormat::Q4K => 0.10,
        QuantFormat::Q3K => 0.19,
        QuantFormat::Q2K => 0.40,
        _ => 0.0,
    }
}

#[test]
fn prop_roundtrip_error_bounded_across_distributions() {
    for case in 0..60u64 {
        let mut rng = Pcg::new(1000 + case);
        let n = 256 * (1 + rng.next_below(6) as usize);
        let scale = 10f32.powi(rng.next_below(7) as i32 - 3); // 1e-3..1e3
        let shift = if case % 3 == 0 { scale * 0.5 } else { 0.0 };
        let data: Vec<f32> = (0..n)
            .map(|_| rng.next_normal() * scale + shift)
            .collect();
        for fmt in KQ {
            let rt = quant::roundtrip(fmt, &data, None).unwrap();
            let err = rel_rmse(&data, &rt);
            // Shifted data is harder for symmetric formats; relax 2×.
            let b = bound(fmt) * if shift != 0.0 { 2.0 } else { 1.0 };
            assert!(
                err < b,
                "case {case} fmt {fmt} scale {scale} shift {shift}: err {err} > {b}"
            );
        }
    }
}

#[test]
fn prop_error_monotone_in_bits() {
    // More bits must not give (meaningfully) worse reconstruction.
    for case in 0..30u64 {
        let mut rng = Pcg::new(2000 + case);
        let n = 512;
        let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let errs: Vec<f64> = KQ
            .iter()
            .map(|&f| rel_rmse(&data, &quant::roundtrip(f, &data, None).unwrap()))
            .collect();
        for w in errs.windows(2) {
            assert!(
                w[0] <= w[1] * 1.15 + 1e-6,
                "case {case}: error ordering violated: {errs:?}"
            );
        }
    }
}

#[test]
fn prop_quantize_deterministic() {
    let mut rng = Pcg::new(7);
    let data: Vec<f32> = (0..1024).map(|_| rng.next_normal()).collect();
    for fmt in KQ {
        let a = quant::quantize(fmt, &data, None).unwrap();
        let b = quant::quantize(fmt, &data, None).unwrap();
        assert_eq!(a, b, "{fmt} must be deterministic");
    }
}

#[test]
fn prop_dequantize_total_on_random_bytes() {
    // Any byte pattern must decode without panicking (formats are
    // total); NaN/Inf only from f16 scale fields.
    for case in 0..40u64 {
        let mut rng = Pcg::new(3000 + case);
        for fmt in KQ {
            let n = fmt.block_weights() * 4;
            let nb = fmt.row_bytes(n).unwrap();
            let bytes: Vec<u8> = (0..nb).map(|_| rng.next_u64() as u8).collect();
            let out = quant::dequantize(fmt, &bytes, n).unwrap();
            assert_eq!(out.len(), n);
        }
    }
}

#[test]
fn prop_parallel_quantize_bitwise_identical_all_formats() {
    // The BlockCodec contract: splitting a tensor across threads must
    // not change a single bit, for every format, with and without an
    // imatrix, at edge block counts (one block, fewer blocks than
    // threads, non-divisible multiples).
    for fmt in QuantFormat::ALL {
        for nblocks in [1usize, 2, 7, 33] {
            let n = fmt.block_weights() * nblocks;
            let mut rng = Pcg::new(7000 + n as u64 + fmt.block_bytes() as u64);
            let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let imp: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.1).collect();
            for importance in [None, Some(imp.as_slice())] {
                let nbytes = fmt.row_bytes(n).unwrap();
                let mut serial = vec![0u8; nbytes];
                let mut par = vec![0u8; nbytes];
                quant::quantize_into_with(fmt, &data, importance, &mut serial, 1).unwrap();
                quant::quantize_into_with(fmt, &data, importance, &mut par, 4).unwrap();
                assert_eq!(
                    serial, par,
                    "{fmt} nblocks={nblocks} imatrix={}",
                    importance.is_some()
                );
                let mut dec_serial = vec![0f32; n];
                let mut dec_par = vec![0f32; n];
                quant::dequantize_into_with(fmt, &serial, &mut dec_serial, 1).unwrap();
                quant::dequantize_into_with(fmt, &par, &mut dec_par, 4).unwrap();
                assert_eq!(dec_serial, dec_par, "{fmt} nblocks={nblocks} decode");
            }
        }
    }
}

#[test]
fn prop_quantize_into_matches_quantize() {
    // The zero-copy entry points must agree with the allocating wrappers
    // at every edge size: one block, a handful, and larger multiples.
    for fmt in QuantFormat::ALL {
        for nblocks in [1usize, 3, 16] {
            let n = fmt.block_weights() * nblocks;
            let mut rng = Pcg::new(8000 + n as u64 + fmt.block_bytes() as u64);
            let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
            let alloc = quant::quantize(fmt, &data, None).unwrap();
            let mut into = vec![0u8; fmt.row_bytes(n).unwrap()];
            let written = quant::quantize_into(fmt, &data, None, &mut into).unwrap();
            assert_eq!(written, alloc.len(), "{fmt} nblocks={nblocks}");
            assert_eq!(into, alloc, "{fmt} nblocks={nblocks}");
            let dec_alloc = quant::dequantize(fmt, &alloc, n).unwrap();
            let mut dec_into = vec![0f32; n];
            quant::dequantize_into(fmt, &into, &mut dec_into).unwrap();
            assert_eq!(dec_into, dec_alloc, "{fmt} nblocks={nblocks} decode");
            // And the scratch-reusing roundtrip helper.
            let mut packed = Vec::new();
            let mut rt = vec![0f32; n];
            quant::roundtrip_into(fmt, &data, None, &mut packed, &mut rt).unwrap();
            assert_eq!(rt, dec_alloc, "{fmt} nblocks={nblocks} roundtrip_into");
        }
    }
}

fn tiny_moe_f32_container(seed: u64) -> Container {
    synthetic_f32_container(&ModelConfig::tiny_moe(), seed).unwrap()
}

#[test]
fn prop_parallel_container_bitwise_identical_all_schemes() {
    // Acceptance gate: for every builtin scheme the tensor-parallel
    // container pipeline must reproduce the serial container exactly —
    // same header, same offsets, same payload bytes.
    let src = tiny_moe_f32_container(4242);
    for scheme in builtin::all() {
        let serial = quantize_container_with(&src, &scheme, None, 1).unwrap().to_bytes();
        let par = quantize_container_with(&src, &scheme, None, 4).unwrap().to_bytes();
        assert_eq!(serial, par, "scheme {}", scheme.name);
        // Default (auto-threaded) entry point too.
        let auto = quantize_container(&src, &scheme, None).unwrap().to_bytes();
        assert_eq!(serial, auto, "scheme {} (auto)", scheme.name);
    }
}

#[test]
fn prop_parallel_container_identical_with_imatrix() {
    // Importance maps flow through the parallel pipeline unchanged.
    let src = tiny_moe_f32_container(777);
    let mut rng = Pcg::new(778);
    let mut imatrix: HashMap<String, Vec<f32>> = HashMap::new();
    for t in &src.tensors {
        let n: usize = t.shape.iter().product();
        imatrix.insert(t.name.clone(), (0..n).map(|_| rng.next_f32() + 0.05).collect());
    }
    let scheme = builtin::scheme("q4_k_m").unwrap();
    let serial = quantize_container_with(&src, &scheme, Some(&imatrix), 1)
        .unwrap()
        .to_bytes();
    let par = quantize_container_with(&src, &scheme, Some(&imatrix), 4)
        .unwrap()
        .to_bytes();
    assert_eq!(serial, par);
}

#[test]
fn prop_container_roundtrip_random_models() {
    // Random tensor sets through write → read → quantize → read.
    for case in 0..10u64 {
        let mut rng = Pcg::new(4000 + case);
        let cfg = ModelConfig::tiny_dense();
        let mut w = Writer::new(cfg.clone(), "f32");
        let mut names = Vec::new();
        for i in 0..(3 + rng.next_below(5)) {
            let rows = 1 + rng.next_below(4) as usize;
            let cols = 256 * (1 + rng.next_below(3) as usize);
            let name = format!("blk.{i}.t{case}.weight");
            let vals: Vec<f32> = (0..rows * cols).map(|_| rng.next_normal()).collect();
            let payload = quant::quantize(QuantFormat::F32, &vals, None).unwrap();
            w.add_tensor(
                &name,
                ModuleClass::AttnOutput,
                Some(i as usize),
                &[rows, cols],
                QuantFormat::F32,
                &payload,
            )
            .unwrap();
            names.push((name, vals));
        }
        let c = Container::from_bytes(w.to_bytes()).unwrap();
        for (name, vals) in &names {
            let t = c.tensor(name).unwrap();
            assert_eq!(&c.dequantize(t).unwrap(), vals);
        }
        let q = quantize_container(&c, &builtin::scheme("q4_k_m").unwrap(), None).unwrap();
        let qc = Container::from_bytes(q.to_bytes()).unwrap();
        for (name, vals) in &names {
            let t = qc.tensor(name).unwrap();
            let rt = qc.dequantize(t).unwrap();
            let err = rel_rmse(vals, &rt);
            assert!(err < 0.12, "case {case} {name}: err {err}");
        }
    }
}

#[test]
fn prop_scheme_assignment_total_and_valid() {
    // Every scheme assigns a representable format to every tensor of
    // every model (fallback to f16 where blocks don't fit).
    let models = [
        ModelConfig::deepseek_v3_671b(),
        ModelConfig::distill_qwen_32b(),
        ModelConfig::tiny_moe(),
        ModelConfig::tiny_dense(),
    ];
    for cfg in &models {
        for scheme in builtin::all() {
            for t in cfg.census() {
                let fmt = scheme.assign(&t, cfg);
                let info = TensorInfo {
                    name: t.name.clone(),
                    class: t.class,
                    layer: t.layer,
                    shape: t.shape.clone(),
                };
                assert_eq!(
                    info.row_len() % fmt.block_weights(),
                    0,
                    "{}: {} assigned {} with row {}",
                    scheme.name,
                    t.name,
                    fmt,
                    info.row_len()
                );
            }
        }
    }
}

#[test]
fn prop_imatrix_never_hurts_weighted_error() {
    // With importance supplied, importance-weighted MSE must not exceed
    // the unweighted quantizer's importance-weighted MSE (averaged over
    // cases — per-block ties can flip individual cases).
    let mut worse = 0;
    let cases = 20;
    for case in 0..cases {
        let mut rng = Pcg::new(5000 + case);
        let n = 512;
        let data: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let imp: Vec<f32> = (0..n)
            .map(|_| if rng.next_f32() < 0.1 { 50.0 } else { 1.0 })
            .collect();
        let wmse = |recon: &[f32]| -> f64 {
            data.iter()
                .zip(recon)
                .zip(&imp)
                .map(|((a, b), w)| (*w as f64) * ((a - b) as f64).powi(2))
                .sum()
        };
        let plain = quant::roundtrip(QuantFormat::Q3K, &data, None).unwrap();
        let guided = quant::roundtrip(QuantFormat::Q3K, &data, Some(&imp)).unwrap();
        if wmse(&guided) > wmse(&plain) {
            worse += 1;
        }
    }
    assert!(
        worse <= cases / 4,
        "imatrix made weighted error worse in {worse}/{cases} cases"
    );
}

// --- imatrix at census scale + load_imatrix error paths -------------------

/// Satellite of the sharded-serving PR: the scaled 671B deployment
/// proxy's full DeepSeek census (attention low-rank stack, 64-expert
/// MoE tensors across the Table-2 layer plan), imatrix-weighted, must
/// quantize byte-identically serial vs parallel.
#[test]
fn prop_parallel_container_identical_with_imatrix_at_census_scale() {
    let src = synthetic_f32_container(&ModelConfig::deepseek_v3_671b_sim(), 31).unwrap();
    let mut rng = Pcg::new(32);
    let mut imatrix: HashMap<String, Vec<f32>> = HashMap::new();
    for t in &src.tensors {
        let n: usize = t.shape.iter().product();
        imatrix.insert(t.name.clone(), (0..n).map(|_| rng.next_f32() + 0.05).collect());
    }
    let scheme = builtin::scheme("dq3_k_m").unwrap();
    let serial = quantize_container_with(&src, &scheme, Some(&imatrix), 1).unwrap().to_bytes();
    let par = quantize_container_with(&src, &scheme, Some(&imatrix), 8).unwrap().to_bytes();
    assert_eq!(serial, par, "census-scale imatrix quantization must not depend on threading");
}

/// `container::load_imatrix` fails early, naming the offending tensor:
/// malformed files, unknown tensor names, and width mismatches are all
/// rejected before the quantizer ever sees the map.
#[test]
fn load_imatrix_rejects_malformed_and_mismatched_containers() {
    let dir = std::env::temp_dir().join("dsq-imatrix-test");
    std::fs::create_dir_all(&dir).unwrap();
    let src = synthetic_f32_container(&ModelConfig::tiny_dense(), 7).unwrap();
    let f32_payload = |vals: &[f32]| quant::quantize(QuantFormat::F32, vals, None).unwrap();

    // Malformed file: not a container at all.
    let garbage = dir.join("garbage.dsq");
    std::fs::write(&garbage, b"not a dsq container").unwrap();
    assert!(load_imatrix(&garbage, &src).is_err(), "garbage bytes must not parse");

    // Unknown tensor name.
    let mut w = Writer::new(ModelConfig::tiny_dense(), "f32");
    let payload = f32_payload(&[1.0f32; 256]);
    w.add_tensor("no.such.weight", ModuleClass::Norm, None, &[256], QuantFormat::F32, &payload)
        .unwrap();
    let unknown = dir.join("unknown.dsq");
    w.write(&unknown).unwrap();
    let err = load_imatrix(&unknown, &src).unwrap_err().to_string();
    assert!(err.contains("no.such.weight"), "error should name the tensor: {err}");

    // Mismatched width: right name, wrong element count.
    let mut w = Writer::new(ModelConfig::tiny_dense(), "f32");
    let payload = f32_payload(&[1.0f32; 256]);
    w.add_tensor(
        "token_embd.weight",
        ModuleClass::TokenEmbd,
        None,
        &[1, 256],
        QuantFormat::F32,
        &payload,
    )
    .unwrap();
    let mismatched = dir.join("mismatched.dsq");
    w.write(&mismatched).unwrap();
    let err = load_imatrix(&mismatched, &src).unwrap_err().to_string();
    assert!(
        err.contains("token_embd.weight") && err.contains("importance"),
        "error should name the mismatched tensor: {err}"
    );

    // Happy path: a well-formed partial imatrix loads with full widths.
    let t0 = src.tensors.iter().find(|t| t.shape.len() == 2).unwrap();
    let mut w = Writer::new(ModelConfig::tiny_dense(), "f32");
    let payload = f32_payload(&vec![0.5f32; t0.n_elems()]);
    w.add_tensor(&t0.name, t0.class, t0.layer, &t0.shape, QuantFormat::F32, &payload).unwrap();
    let good = dir.join("good.dsq");
    w.write(&good).unwrap();
    let map = load_imatrix(&good, &src).unwrap();
    assert_eq!(map.len(), 1);
    assert_eq!(map[&t0.name].len(), t0.n_elems());
}
