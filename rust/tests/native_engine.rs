//! Native CPU backend, end to end: `Engine::load_native`-style
//! construction (via `Engine::native_from_container` /
//! `Engine::from_native`), step determinism across thread counts, and a
//! full `Coordinator` wave over quantized weights — no HLO artifacts,
//! no PJRT.
//!
//! Since PR 4 every step is a complete forward pass fused on the
//! encoded container payloads (MLA attention + routed experts for the
//! MoE shapes; since PR 5, grouped-query attention + dense FFNs for
//! the Table-5 tiny-dense proxy too; since PR 6 prefill pushes each
//! slot's whole prompt through one quantized-GEMM panel pass); the
//! per-step numeric properties live in `tests/native_forward.rs`, this
//! file covers the serving plumbing: prefill/decode state threading,
//! inactive-slot skipping (including that skipped slots never allocate
//! KV backing memory), and the submit-time admission checks against
//! the engine's context bound.

use dsq::container::{quantize_container_with, synthetic_f32_container, Container};
use dsq::coordinator::{sampler::SamplingParams, Coordinator, Request};
use dsq::model::ModelConfig;
use dsq::runtime::native::{NativeEngine, NATIVE_BATCH, NATIVE_MAX_CTX, NATIVE_PROMPT_LEN};
use dsq::runtime::{Engine, StepState};
use std::sync::OnceLock;

/// Quantized tiny-moe container bytes, built once per scheme (serial
/// container quantization is the slow part of these tests in debug).
fn qbytes(scheme: &str) -> &'static [u8] {
    static DQ3: OnceLock<Vec<u8>> = OnceLock::new();
    static Q4: OnceLock<Vec<u8>> = OnceLock::new();
    let cell = match scheme {
        "dq3_k_m" => &DQ3,
        "q4_k_m" => &Q4,
        other => panic!("unexpected scheme {other}"),
    };
    cell.get_or_init(|| {
        let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 0x1A7E).unwrap();
        let scheme = dsq::scheme::builtin::scheme(scheme).unwrap();
        quantize_container_with(&src, &scheme, None, 1).unwrap().to_bytes()
    })
}

fn quantized_container(scheme: &str) -> Container {
    Container::from_bytes(qbytes(scheme).to_vec()).unwrap()
}

/// A small serving shape so debug-mode waves stay fast; the default
/// NATIVE_* shape is covered by `native_engine_reports_serving_shapes`.
fn small_engine(scheme: &str, threads: usize) -> Engine {
    Engine::from_native(
        NativeEngine::with_limits(quantized_container(scheme), threads, 3, 6, 10).unwrap(),
    )
    .unwrap()
}

#[test]
fn native_engine_reports_serving_shapes() {
    let engine = Engine::native_from_container(quantized_container("dq3_k_m"), 1).unwrap();
    assert_eq!(engine.model_name, "tiny-moe");
    assert_eq!(engine.scheme_name, "dq3_k_m");
    assert_eq!(engine.vocab(), 512);
    assert_eq!(engine.batch(), NATIVE_BATCH);
    assert_eq!(engine.prompt_len(), NATIVE_PROMPT_LEN);
    assert_eq!(engine.max_ctx(), NATIVE_MAX_CTX);
    assert!(engine.max_ctx() > engine.prompt_len());
}

#[test]
fn native_steps_bit_identical_across_thread_counts() {
    let a = small_engine("q4_k_m", 1);
    let b = small_engine("q4_k_m", 8);
    let (bt, t) = (a.batch(), a.prompt_len());
    let tokens: Vec<i32> = (0..(bt * t) as i32).map(|i| (i * 37) % 512).collect();
    let lengths: Vec<i32> = (0..bt as i32).map(|i| 1 + i % t as i32).collect();
    let pa = a.run_prefill(&tokens, &lengths).unwrap();
    let pb = b.run_prefill(&tokens, &lengths).unwrap();
    let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&pa.logits), bits(&pb.logits), "prefill");
    let step: Vec<i32> = (0..bt as i32).map(|i| (7 * i + 3) % 512).collect();
    let pos: Vec<i32> = lengths.clone();
    let da = a.run_decode(&step, &pos, pa.state).unwrap();
    let db = b.run_decode(&step, &pos, pb.state).unwrap();
    assert_eq!(bits(&da.logits), bits(&db.logits), "decode");
}

#[test]
fn native_logits_have_serving_shape_and_are_finite() {
    let engine = small_engine("dq3_k_m", 2);
    let (b, t, v) = (engine.batch(), engine.prompt_len(), engine.vocab());
    let tokens = vec![1i32; b * t];
    let lengths = vec![1i32; b];
    let out = engine.run_prefill(&tokens, &lengths).unwrap();
    assert_eq!(out.logits.len(), b * v);
    assert!(out.logits.iter().all(|x| x.is_finite()));
    // The native backend threads per-slot KV caches, not PJRT literals.
    match out.state {
        StepState::Native(kv) => {
            assert_eq!(kv.n_slots(), b);
            assert!((0..b).all(|i| kv.slot_len(i) == 1));
        }
        StepState::Pjrt(_) => panic!("native engine must carry native state"),
    }
    assert!(matches!(engine.initial_state().unwrap(), StepState::Native(_)));
}

#[test]
fn coordinator_serves_a_wave_on_quantized_weights() {
    let run = || {
        let mut coord = Coordinator::new(small_engine("dq3_k_m", 4));
        for i in 0..3u64 {
            coord
                .submit(Request {
                    id: i,
                    prompt: vec![(3 + i as i32) % 512; 3 + i as usize],
                    params: SamplingParams::paper(),
                    seed: 1000 + i,
                })
                .unwrap();
        }
        let responses = coord.run_to_completion().unwrap();
        assert_eq!(responses.len(), 3);
        for r in &responses {
            assert!(!r.tokens.is_empty(), "request {} generated nothing", r.id);
            assert_eq!(r.n_generated, r.tokens.len());
        }
        assert!(coord.metrics.decode_summary().median >= 0.0);
        responses.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
    };
    // The whole serve path is deterministic: same engine + seeds ⇒ the
    // same sampled tokens, independent of the matvec thread fan-out.
    assert_eq!(run(), run());
}

#[test]
fn coordinator_serves_a_dense_gqa_wave_on_quantized_weights() {
    // The Table-5 workload end to end: tiny-dense on a DQ3_K_M
    // container through the same coordinator loop, deterministic
    // across runs and thread counts.
    let dense_engine = |threads: usize| {
        let src = synthetic_f32_container(&ModelConfig::tiny_dense(), 0x1A7E).unwrap();
        let scheme = dsq::scheme::builtin::scheme("dq3_k_m").unwrap();
        let q = Container::from_bytes(
            quantize_container_with(&src, &scheme, None, 1).unwrap().to_bytes(),
        )
        .unwrap();
        Engine::from_native(NativeEngine::with_limits(q, threads, 3, 6, 10).unwrap()).unwrap()
    };
    let run = |threads: usize| {
        let mut coord = Coordinator::new(dense_engine(threads));
        assert_eq!(coord.engine().model_name, "tiny-dense");
        for i in 0..2u64 {
            coord
                .submit(Request {
                    id: i,
                    prompt: vec![(3 + i as i32) % 512; 3 + i as usize],
                    params: SamplingParams::paper(),
                    seed: 2000 + i,
                })
                .unwrap();
        }
        let responses = coord.run_to_completion().unwrap();
        assert_eq!(responses.len(), 2);
        for r in &responses {
            assert!(!r.tokens.is_empty(), "request {} generated nothing", r.id);
        }
        responses.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
    };
    assert_eq!(run(1), run(4), "dense wave must be thread-count independent");
}

#[test]
fn skipped_slots_never_allocate_kv_memory() {
    // The eager-allocation defect this PR fixes: a 3-slot engine
    // serving one request used to allocate all three full KV buffers.
    let engine = small_engine("q4_k_m", 1);
    let (b, t) = (engine.batch(), engine.prompt_len());
    let mut tokens = vec![0i32; b * t];
    tokens[..3].copy_from_slice(&[5, 6, 7]);
    let mut lengths = vec![0i32; b];
    lengths[0] = 3;
    let out = engine.run_prefill(&tokens, &lengths).unwrap();
    match out.state {
        StepState::Native(kv) => {
            assert!(kv.slot_allocated(0), "live slot allocates on first token");
            for i in 1..b {
                assert!(!kv.slot_allocated(i), "skipped slot {i} must stay unallocated");
            }
        }
        StepState::Pjrt(_) => panic!("native engine must carry native state"),
    }
}

#[test]
fn oversized_prompt_rejected_before_reaching_the_engine() {
    let mut coord = Coordinator::new(small_engine("q4_k_m", 1));
    let too_long = coord.engine().prompt_len() + 1;
    let err = coord.submit(Request {
        id: 0,
        prompt: vec![1; too_long],
        params: SamplingParams::greedy(),
        seed: 1,
    });
    assert!(err.is_err());
}

#[test]
fn prompt_overrunning_max_ctx_rejected_at_submit_not_mid_wave() {
    // An engine whose compiled prompt length exceeds its context bound:
    // an 7-token prompt packs fine (≤ prompt_len = 8) but could never
    // generate inside max_ctx = 6 — the old coordinator accepted it and
    // only failed once the per-slot KV cache overflowed mid-wave.
    let engine = Engine::from_native(
        NativeEngine::with_limits(quantized_container("q4_k_m"), 1, 2, 8, 6).unwrap(),
    )
    .unwrap();
    let mut coord = Coordinator::new(engine);
    let err = coord
        .submit(Request {
            id: 0,
            prompt: vec![1; 7],
            params: SamplingParams::greedy(),
            seed: 1,
        })
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("max context"), "error must name the bound: {msg}");
    assert!(msg.contains('7') && msg.contains('6'), "error must give the numbers: {msg}");
    assert_eq!(coord.pending(), 0, "rejected request must not be queued");

    // A prompt that leaves generation room is admitted and the wave
    // completes without ever hitting the KV bound.
    coord
        .submit(Request {
            id: 1,
            prompt: vec![1; 5],
            params: SamplingParams::greedy(),
            seed: 2,
        })
        .unwrap();
    let responses = coord.run_to_completion().unwrap();
    assert_eq!(responses.len(), 1);
    assert!(!responses[0].tokens.is_empty());
}
