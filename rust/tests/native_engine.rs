//! Native CPU matvec backend, end to end: `Engine::load_native`-style
//! construction (via `Engine::native_from_container`), step determinism
//! across thread counts, and a full `Coordinator` wave over quantized
//! weights — no HLO artifacts, no PJRT.
//!
//! This is the serving path the fused `quant::kernels::vec_dot` work
//! exists for: the unembedding matrix stays container-encoded and every
//! decode step's logits are computed directly on the packed bytes.

use dsq::container::{quantize_container_with, synthetic_f32_container, Container};
use dsq::coordinator::{sampler::SamplingParams, Coordinator, Request};
use dsq::model::ModelConfig;
use dsq::runtime::Engine;
use dsq::scheme::builtin;

fn quantized_container(scheme: &str) -> Container {
    let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 0x1A7E).unwrap();
    let writer =
        quantize_container_with(&src, &builtin::scheme(scheme).unwrap(), None, 1).unwrap();
    Container::from_bytes(writer.to_bytes()).unwrap()
}

fn native_engine(scheme: &str, threads: usize) -> Engine {
    Engine::native_from_container(quantized_container(scheme), threads).unwrap()
}

#[test]
fn native_engine_reports_serving_shapes() {
    let engine = native_engine("dq3_k_m", 1);
    assert_eq!(engine.model_name, "tiny-moe");
    assert_eq!(engine.scheme_name, "dq3_k_m");
    assert_eq!(engine.vocab(), 512);
    assert!(engine.batch() > 0 && engine.prompt_len() > 0);
    assert!(engine.max_ctx() > engine.prompt_len());
}

#[test]
fn native_steps_bit_identical_across_thread_counts() {
    let a = native_engine("q4_k_m", 1);
    let b = native_engine("q4_k_m", 8);
    let (bt, t) = (a.batch(), a.prompt_len());
    let tokens: Vec<i32> = (0..(bt * t) as i32).map(|i| i % 512).collect();
    let lengths: Vec<i32> = (0..bt as i32).map(|i| 1 + i % t as i32).collect();
    let pa = a.run_prefill(&tokens, &lengths).unwrap();
    let pb = b.run_prefill(&tokens, &lengths).unwrap();
    let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&pa.logits), bits(&pb.logits), "prefill");
    let step: Vec<i32> = (0..bt as i32).map(|i| (7 * i + 3) % 512).collect();
    let pos = vec![1i32; bt];
    let da = a.run_decode(&step, &pos, pa.cache).unwrap();
    let db = b.run_decode(&step, &pos, pb.cache).unwrap();
    assert_eq!(bits(&da.logits), bits(&db.logits), "decode");
}

#[test]
fn native_logits_have_serving_shape_and_are_finite() {
    let engine = native_engine("dq3_k_m", 2);
    let (b, t, v) = (engine.batch(), engine.prompt_len(), engine.vocab());
    let tokens = vec![1i32; b * t];
    let lengths = vec![t as i32; b];
    let out = engine.run_prefill(&tokens, &lengths).unwrap();
    assert_eq!(out.logits.len(), b * v);
    assert!(out.logits.iter().all(|x| x.is_finite()));
    // Native backend carries no PJRT cache literals.
    assert!(out.cache.is_empty());
    assert!(engine.empty_cache().unwrap().is_empty());
}

#[test]
fn coordinator_serves_a_wave_on_quantized_weights() {
    let run = || {
        let mut coord = Coordinator::new(native_engine("dq3_k_m", 4));
        for i in 0..5u64 {
            coord
                .submit(Request {
                    id: i,
                    prompt: vec![(3 + i as i32) % 512; 4 + i as usize],
                    params: SamplingParams::paper(),
                    seed: 1000 + i,
                })
                .unwrap();
        }
        let responses = coord.run_to_completion().unwrap();
        assert_eq!(responses.len(), 5);
        for r in &responses {
            assert!(!r.tokens.is_empty(), "request {} generated nothing", r.id);
            assert_eq!(r.n_generated, r.tokens.len());
        }
        assert!(coord.metrics.decode_summary().median >= 0.0);
        responses.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
    };
    // The whole serve path is deterministic: same engine + seeds ⇒ the
    // same sampled tokens, independent of the matvec thread fan-out.
    assert_eq!(run(), run());
}

#[test]
fn oversized_prompt_rejected_before_reaching_the_engine() {
    let mut coord = Coordinator::new(native_engine("q4_k_m", 1));
    let too_long = coord.engine().prompt_len() + 1;
    let err = coord.submit(Request {
        id: 0,
        prompt: vec![1; too_long],
        params: SamplingParams::greedy(),
        seed: 1,
    });
    assert!(err.is_err());
}
