//! Property tests for the serving weight-loader decode path
//! (`runtime::loader`) — the quantize → loader-dequantize round trip,
//! swept across thread counts {1, 2, 8}. These need no compiled HLO
//! artifacts: the loader is exercised directly through a synthetic
//! manifest over in-memory containers.

use dsq::container::{quantize_container_with, synthetic_f32_container, Container, Writer};
use dsq::model::{ModelConfig, ModuleClass};
use dsq::quant::{self, parallel, QuantFormat};
use dsq::runtime::loader::{self, WeightBytes};
use dsq::scheme::builtin;
use dsq::util::rng::Pcg;

fn le_f32(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
        .collect()
}

#[test]
fn loader_decode_identical_across_thread_counts() {
    let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 0xD0C).unwrap();
    for scheme_name in ["dq3_k_m", "q4_k_m", "q2_k_l"] {
        let scheme = builtin::scheme(scheme_name).unwrap();
        let q = Container::from_bytes(
            quantize_container_with(&src, &scheme, None, 1)
                .unwrap()
                .to_bytes(),
        )
        .unwrap();
        let manifest = loader::f32_weight_manifest(&q);
        let base = loader::prepare_weights(&manifest, &q, 1).unwrap();
        for threads in [2usize, 8] {
            let other = loader::prepare_weights(&manifest, &q, threads).unwrap();
            assert_eq!(base.len(), other.len());
            for ((t, a), b) in q.tensors.iter().zip(&base).zip(&other) {
                assert_eq!(
                    a.as_slice(),
                    b.as_slice(),
                    "scheme {scheme_name} tensor {} threads={threads}",
                    t.name
                );
            }
        }
        // Decoded literals must equal the container's own dequantize.
        for (t, p) in q.tensors.iter().zip(&base) {
            assert_eq!(
                le_f32(p.as_slice()),
                q.dequantize(t).unwrap(),
                "scheme {scheme_name} tensor {}",
                t.name
            );
        }
    }
}

#[test]
fn loader_splits_single_giant_tensor_across_blocks() {
    // One tensor bigger than the block-threading threshold: the fan-out
    // gives all threads to block-level dequantize inside the single
    // decode job, and the result must match the serial bytes exactly.
    let cfg = ModelConfig::tiny_dense();
    let n = 4 * parallel::PAR_MIN_WEIGHTS;
    let mut rng = Pcg::new(0xB1607);
    let vals: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.05).collect();
    let payload = quant::quantize(QuantFormat::Q4K, &vals, None).unwrap();
    let mut w = Writer::new(cfg, "q4_k");
    w.add_tensor(
        "blk.0.attn_output.weight",
        ModuleClass::AttnOutput,
        Some(0),
        &[256, n / 256],
        QuantFormat::Q4K,
        &payload,
    )
    .unwrap();
    let q = Container::from_bytes(w.to_bytes()).unwrap();
    let manifest = loader::f32_weight_manifest(&q);
    let base = loader::prepare_weights(&manifest, &q, 1).unwrap();
    assert!(matches!(base[0], WeightBytes::Decoded(_)));
    for threads in [2usize, 8] {
        let other = loader::prepare_weights(&manifest, &q, threads).unwrap();
        assert_eq!(base[0].as_slice(), other[0].as_slice(), "threads={threads}");
    }
    assert_eq!(le_f32(base[0].as_slice()), q.dequantize(&q.tensors[0]).unwrap());
}

#[test]
fn loader_passthrough_when_formats_match() {
    // A manifest that declares the container's own (quantized) formats
    // gets raw payload passthrough — no decode, bytes borrowed as-is.
    let src = synthetic_f32_container(&ModelConfig::tiny_moe(), 0xFACE).unwrap();
    let scheme = builtin::scheme("q4_k_m").unwrap();
    let q = Container::from_bytes(
        quantize_container_with(&src, &scheme, None, 1)
            .unwrap()
            .to_bytes(),
    )
    .unwrap();
    let mut manifest = loader::f32_weight_manifest(&q);
    for (spec, t) in manifest.inputs.iter_mut().zip(&q.tensors) {
        spec.format = Some(t.format.name().to_string());
        spec.dtype = dsq::runtime::manifest::Dtype::U8;
        spec.shape = vec![t.nbytes];
    }
    let payloads = loader::prepare_weights(&manifest, &q, 4).unwrap();
    for (t, p) in q.tensors.iter().zip(&payloads) {
        assert!(matches!(p, WeightBytes::Raw(_)), "tensor {}", t.name);
        assert_eq!(p.as_slice(), q.bytes(t), "tensor {}", t.name);
    }
}
