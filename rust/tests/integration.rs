//! Integration tests across runtime + coordinator + eval harness.
//!
//! Tests that need AOT artifacts (`make artifacts`) skip gracefully
//! when they are missing, so `cargo test` is green on a fresh clone;
//! the full pipeline runs them in CI/final validation.

use dsq::container::{quantize_container, Container, Writer};
use dsq::coordinator::{sampler::SamplingParams, Coordinator, Request};
use dsq::eval::{self, suites};
use dsq::model::ModelConfig;
use dsq::quant::QuantFormat;
use dsq::runtime::Engine;
use dsq::scheme::builtin;
use dsq::util::rng::Pcg;
use std::path::PathBuf;
use std::sync::OnceLock;

fn repo() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn artifacts_ready() -> bool {
    repo().join("artifacts/hlo/tiny-moe_f32_prefill.hlo.txt").exists()
}

/// Build (once) a deterministic random-weight checkpoint for engine
/// tests — independent of the trained checkpoints.
fn test_ckpt(scheme_name: &str) -> PathBuf {
    static F32: OnceLock<PathBuf> = OnceLock::new();
    let dir = std::env::temp_dir().join("dsq-itest");
    std::fs::create_dir_all(&dir).unwrap();
    let f32_path = F32
        .get_or_init(|| {
            let cfg = ModelConfig::tiny_moe();
            let mut w = Writer::new(cfg.clone(), "f32");
            let mut rng = Pcg::new(99);
            for t in cfg.census() {
                let n: usize = t.shape.iter().product();
                let vals: Vec<f32> = (0..n).map(|_| rng.next_normal() * 0.05).collect();
                let payload = dsq::quant::quantize(QuantFormat::F32, &vals, None).unwrap();
                w.add_tensor(&t.name, t.class, t.layer, &t.shape, QuantFormat::F32, &payload)
                    .unwrap();
            }
            let p = dir.join("itest.f32.dsq");
            w.write(&p).unwrap();
            p
        })
        .clone();
    if scheme_name == "f32" {
        return f32_path;
    }
    let qpath = dir.join(format!("itest.{scheme_name}.dsq"));
    if !qpath.exists() {
        let src = Container::open(&f32_path).unwrap();
        let scheme = builtin::scheme(scheme_name).unwrap();
        quantize_container(&src, &scheme, None)
            .unwrap()
            .write(&qpath)
            .unwrap();
    }
    qpath
}

fn load_engine(scheme: &str) -> Option<Engine> {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    // Engine derives the artifact stem from the container's model name
    // (tiny-moe) + scheme.
    Some(Engine::load(&repo().join("artifacts/hlo"), &test_ckpt(scheme)).unwrap())
}

#[test]
fn engine_prefill_decode_shapes() {
    let Some(engine) = load_engine("f32") else { return };
    let b = engine.batch();
    let t = engine.prompt_len();
    let tokens = vec![1i32; b * t];
    let lengths = vec![4i32; b];
    let out = engine.run_prefill(&tokens, &lengths).unwrap();
    assert_eq!(out.logits.len(), b * engine.vocab());
    assert!(out.logits.iter().all(|v| v.is_finite()));
    let next = vec![5i32; b];
    let pos = vec![4i32; b];
    let out2 = engine.run_decode(&next, &pos, out.state).unwrap();
    assert_eq!(out2.logits.len(), b * engine.vocab());
}

#[test]
fn coordinator_serves_mixed_queue() {
    let Some(engine) = load_engine("dq3_k_m") else { return };
    let mut coord = Coordinator::new(engine);
    // 20 requests > one wave of 16 → two waves.
    for i in 0..20u64 {
        let suite = &suites::SUITES[(i % 9) as usize];
        let q = eval::tasks::eval_question(suite, i);
        coord
            .submit(Request {
                id: i,
                prompt: q.prompt,
                params: SamplingParams::paper(),
                seed: i,
            })
            .unwrap();
    }
    let responses = coord.run_to_completion().unwrap();
    assert_eq!(responses.len(), 20);
    assert_eq!(coord.metrics.waves, 2);
    for r in &responses {
        assert!(!r.tokens.is_empty());
        assert!(r.tokens.len() <= 8);
    }
}

#[test]
fn coordinator_rejects_oversized_prompt() {
    let Some(engine) = load_engine("f32") else { return };
    let mut coord = Coordinator::new(engine);
    let long = vec![1i32; coord.engine().prompt_len() + 1];
    assert!(coord
        .submit(Request { id: 0, prompt: long, params: SamplingParams::greedy(), seed: 0 })
        .is_err());
    assert!(coord
        .submit(Request { id: 0, prompt: vec![], params: SamplingParams::greedy(), seed: 0 })
        .is_err());
}

#[test]
fn sampling_is_seed_deterministic_through_engine() {
    let Some(engine) = load_engine("f32") else { return };
    let mut coord = Coordinator::new(engine);
    let q = eval::tasks::eval_question(suites::by_name("MATH 500").unwrap(), 3);
    let mk = |id| Request {
        id,
        prompt: q.prompt.clone(),
        params: SamplingParams::paper(),
        seed: 1234,
    };
    coord.submit(mk(0)).unwrap();
    coord.submit(mk(1)).unwrap();
    let r = coord.run_to_completion().unwrap();
    assert_eq!(r[0].tokens, r[1].tokens, "same seed+prompt → same tokens");
}

#[test]
fn eval_suite_runs_end_to_end_small() {
    let Some(engine) = load_engine("f32") else { return };
    let mut coord = Coordinator::new(engine);
    let protocol = eval::Protocol {
        full_size: false,
        sample_divisor: 8, // 1 sample per question for speed
        temperature: 0.6,
        top_p: 0.95,
    };
    let suite = suites::by_name("GPQA").unwrap();
    let r = eval::run_suite(&mut coord, suite, &protocol, None).unwrap();
    assert_eq!(r.n_questions, suite.default_count);
    assert!(r.sample_scores.iter().all(|&s| (0.0..=100.0).contains(&s)));
}

#[test]
fn engine_rejects_mismatched_scheme_container() {
    if !artifacts_ready() {
        return;
    }
    // A q4_k_m container loaded against dq3_k_m artifacts must fail the
    // manifest validation — rename trickery should not crash the engine.
    let q4 = test_ckpt("q4_k_m");
    let renamed = std::env::temp_dir().join("dsq-itest/fake.dq3_k_m.dsq");
    // Rewrite the container with a lying scheme name.
    let src = Container::open(&q4).unwrap();
    let mut w = Writer::new(src.model.clone(), "dq3_k_m");
    for t in &src.tensors {
        w.add_tensor(&t.name, t.class, t.layer, &t.shape, t.format, src.bytes(t))
            .unwrap();
    }
    w.write(&renamed).unwrap();
    let err = Engine::load(&repo().join("artifacts/hlo"), &renamed);
    assert!(err.is_err(), "mismatched formats must be rejected");
}

#[test]
fn quantized_engine_logits_close_to_f32() {
    // q4_k_m: the highest-precision scheme with tiny-moe AOT artifacts.
    let Some(f32_engine) = load_engine("f32") else { return };
    let q_engine = load_engine("q4_k_m");
    let Some(q_engine) = q_engine else { return };
    let b = f32_engine.batch();
    let t = f32_engine.prompt_len();
    let q = eval::tasks::eval_question(suites::by_name("MMLU").unwrap(), 0);
    let mut tokens = vec![0i32; b * t];
    let mut lengths = vec![1i32; b];
    tokens[..q.prompt.len()].copy_from_slice(&q.prompt);
    lengths[0] = q.prompt.len() as i32;
    let a = f32_engine.run_prefill(&tokens, &lengths).unwrap();
    let bq = q_engine.run_prefill(&tokens, &lengths).unwrap();
    let v = f32_engine.vocab();
    let cos = dsq::quant::error::cosine(&a.logits[..v], &bq.logits[..v]);
    assert!(cos > 0.98, "q4_k_m logits should track f32 (cos={cos})");
}
