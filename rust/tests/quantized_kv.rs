//! Acceptance suite for the quantized (Q8_0) KV cache — the
//! scheme-parametric store behind `--kv-scheme q8_0`, quantizing each
//! appended cache line once (write-once) and reading attention through
//! the fused block-codec kernels.
//!
//! Five locks, mirroring `tests/native_forward.rs` one scheme down:
//!
//! 1. **Golden logits, mirror-blessed only** — the shared golden script
//!    under a Q8_0 KV cache must hash to the committed
//!    `tests/golden/forward.kv_q8_0.*.fnv64` fixtures. Unlike the f32
//!    goldens these are **never self-blessed by the Rust side**: a
//!    missing fixture fails the test, and the only way to produce one
//!    is the bit-exact Python mirror (`python/tools/bless_goldens.py`),
//!    so Rust and Python must agree on every quantized cache byte
//!    before a fixture can exist at all.
//! 2. **Bit identity** — q8-KV logits are identical across matvec
//!    thread counts {1, 2, 8}, every available pinned dispatch arm
//!    (CI re-runs the suite under each `DSQ_FORCE_ARM`), shard counts
//!    {1, 2, 4}, dense vs paged backing (logits *and* the encoded block
//!    planes), and batched panel decode vs solo per-slot decode.
//! 3. **Accuracy bound** — teacher-forcing the f32-KV greedy trajectory
//!    through a q8-KV cache perturbs logits measurably but stays within
//!    a small relative-L2 bound (KV quantization error is tiny next to
//!    the weight quantization the paper studies).
//! 4. **Planner-vs-engine bytes** — [`dsq::memory::kv_token_plan`]
//!    must match [`KvCache::measured_token_plan`] entry for entry and
//!    byte for byte (named diff on drift), the block pool must agree,
//!    and q8_0 must be a ≥3× reduction vs the f32 planes.
//! 5. **Clean rejection** — eager (non-absorbed) MLA refuses a
//!    quantized KV scheme, and a pool created under one scheme cannot
//!    back caches of another.
//!
//! [`KvCache::measured_token_plan`]: dsq::runtime::forward::KvCache::measured_token_plan

use dsq::container::{quantize_container_with, synthetic_f32_container, Container};
use dsq::coordinator::sampler::argmax;
use dsq::memory;
use dsq::model::ModelConfig;
use dsq::quant::kernels::DispatchArm;
use dsq::quant::KvScheme;
use dsq::runtime::forward::{ForwardPass, MatvecMode};
use dsq::runtime::native::NATIVE_MAX_CTX;
use dsq::util::fnv64;
use std::path::PathBuf;
use std::sync::OnceLock;

/// Same golden script as `tests/native_forward.rs` — the q8-KV fixtures
/// pin this exact prompt + greedy-decode sequence.
const PROMPT: [i32; 8] = [1, 17, 300, 42, 511, 7, 5, 260];
const DECODE_STEPS: usize = 4;

const MODELS: [&str; 2] = ["tiny-moe", "tiny-dense"];
const SCHEMES: [&str; 2] = ["dq3_k_m", "q4_k_m"];

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Quantized golden-container bytes (seed 0x601D, the shared golden
/// source), built once per (model, scheme).
fn qbytes(model: &str, scheme: &str) -> &'static [u8] {
    static MOE_DQ3: OnceLock<Vec<u8>> = OnceLock::new();
    static MOE_Q4: OnceLock<Vec<u8>> = OnceLock::new();
    static DENSE_DQ3: OnceLock<Vec<u8>> = OnceLock::new();
    static DENSE_Q4: OnceLock<Vec<u8>> = OnceLock::new();
    let cell = match (model, scheme) {
        ("tiny-moe", "dq3_k_m") => &MOE_DQ3,
        ("tiny-moe", "q4_k_m") => &MOE_Q4,
        ("tiny-dense", "dq3_k_m") => &DENSE_DQ3,
        ("tiny-dense", "q4_k_m") => &DENSE_Q4,
        other => panic!("unexpected combination {other:?}"),
    };
    cell.get_or_init(|| {
        let cfg = ModelConfig::by_name(model).unwrap();
        let src = synthetic_f32_container(&cfg, 0x601D).unwrap();
        let scheme = dsq::scheme::builtin::scheme(scheme).unwrap();
        quantize_container_with(&src, &scheme, None, 1).unwrap().to_bytes()
    })
}

fn forward(model: &str, scheme: &str, threads: usize, shards: usize, kv: KvScheme) -> ForwardPass {
    let ckpt = Container::from_bytes(qbytes(model, scheme).to_vec()).unwrap();
    let mut fwd = ForwardPass::new(ckpt, threads, NATIVE_MAX_CTX).unwrap();
    fwd.set_sharding(shards).unwrap();
    fwd.set_kv_scheme(kv).unwrap();
    fwd
}

/// Prefill [`PROMPT`] token by token (logits at the last), then
/// [`DECODE_STEPS`] greedy steps; returns every emitted logits row.
fn run_script(fwd: &ForwardPass) -> Vec<Vec<f32>> {
    let mut cache = fwd.new_cache();
    let mut scratch = fwd.new_scratch();
    let mut logits = vec![0f32; fwd.vocab()];
    for (j, &t) in PROMPT.iter().enumerate() {
        let want = if j + 1 == PROMPT.len() { Some(&mut logits[..]) } else { None };
        fwd.forward_token(t, &mut cache, &mut scratch, want).unwrap();
    }
    let mut rows = vec![logits.clone()];
    for _ in 0..DECODE_STEPS {
        let tok = argmax(rows.last().unwrap());
        fwd.forward_token(tok, &mut cache, &mut scratch, Some(&mut logits)).unwrap();
        rows.push(logits.clone());
    }
    rows
}

/// Teacher-force a fixed token stream, collecting logits at every
/// position from `want_from` on — the accuracy-bound comparison runs
/// the *same* tokens through both KV schemes.
fn run_forced(fwd: &ForwardPass, stream: &[i32], want_from: usize) -> Vec<Vec<f32>> {
    let mut cache = fwd.new_cache();
    let mut scratch = fwd.new_scratch();
    let mut logits = vec![0f32; fwd.vocab()];
    let mut rows = Vec::new();
    for (j, &t) in stream.iter().enumerate() {
        let want = if j >= want_from { Some(&mut logits[..]) } else { None };
        fwd.forward_token(t, &mut cache, &mut scratch, want).unwrap();
        if j >= want_from {
            rows.push(logits.clone());
        }
    }
    rows
}

fn bits(rows: &[Vec<f32>]) -> Vec<u32> {
    rows.iter().flatten().map(|v| v.to_bits()).collect()
}

fn slice_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 = a.iter().zip(b).map(|(&x, &y)| (x as f64 - y as f64).powi(2)).sum();
    let den: f64 = b.iter().map(|&y| (y as f64).powi(2)).sum();
    (num / den.max(1e-30)).sqrt()
}

// --- lock 1: mirror-blessed goldens ---------------------------------------

/// The q8-KV fixtures exist **only** via the Python mirror: a missing
/// file is a hard failure (never blessed from this side), a present
/// file is the Rust↔Python cross-language gate for the quantized cache.
#[test]
fn golden_q8_kv_logits_checksums_mirror_blessed_only() {
    for model in MODELS {
        let rows = run_script(&forward(model, "q4_k_m", 1, 0, KvScheme::Q8_0));
        let mut blob = Vec::with_capacity(rows.len() * rows[0].len() * 4);
        for r in &rows {
            for v in r {
                blob.extend_from_slice(&v.to_le_bytes());
            }
        }
        let line = format!("{:016x} {}\n", fnv64(&blob), blob.len());
        let name = match model {
            "tiny-moe" => "forward.kv_q8_0.q4_k_m.fnv64",
            "tiny-dense" => "forward.kv_q8_0.tiny_dense.q4_k_m.fnv64",
            other => panic!("unexpected model {other}"),
        };
        let path = golden_dir().join(name);
        assert!(
            path.exists(),
            "missing q8-KV golden {} — quantized-KV fixtures are blessed ONLY from the \
             bit-exact Python mirror: run `python3 python/tools/bless_goldens.py` and commit \
             the file (the Rust side never self-blesses these)",
            path.display()
        );
        let expect = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            expect.trim(),
            line.trim(),
            "q8-KV forward logits for {model}/q4_k_m drifted from {}; if intentional, \
             re-bless from python/tools/bless_goldens.py and call it out in the PR",
            path.display()
        );
    }
}

// --- lock 2: bit identity -------------------------------------------------

#[test]
fn q8_kv_bit_identical_across_threads_and_dispatch_arms() {
    for model in MODELS {
        for scheme in SCHEMES {
            let base = bits(&run_script(&forward(model, scheme, 1, 0, KvScheme::Q8_0)));
            let mut modes = vec![
                ("threads=2".to_string(), MatvecMode::Threads(2)),
                ("threads=8".to_string(), MatvecMode::Threads(8)),
            ];
            for arm in DispatchArm::ALL {
                if arm.available() {
                    modes.push((format!("pinned {} arm", arm.name()), MatvecMode::Pinned(arm)));
                }
            }
            for (label, mode) in modes {
                let mut fwd = forward(model, scheme, 1, 0, KvScheme::Q8_0);
                fwd.set_mode(mode);
                assert_eq!(base, bits(&run_script(&fwd)), "{model}/{scheme}: q8 KV, {label}");
            }
        }
    }
}

#[test]
fn q8_kv_bit_identical_across_shard_counts() {
    for model in MODELS {
        let base = bits(&run_script(&forward(model, "q4_k_m", 2, 0, KvScheme::Q8_0)));
        for shards in [1usize, 2, 4] {
            let fwd = forward(model, "q4_k_m", 2, shards, KvScheme::Q8_0);
            assert_eq!(fwd.shard_count(), shards);
            assert_eq!(
                base,
                bits(&run_script(&fwd)),
                "{model}: q8 KV at {shards} shards vs local"
            );
        }
    }
}

/// Dense vs paged backing under q8_0: logits, the decoded planes, and
/// the **encoded** block planes must all match bit for bit. The paged
/// run uses `block_tokens = 5` — deliberately not a multiple (or
/// divisor) of the 32-weight codec block, so per-line zero padding and
/// block-table indexing are exercised against each other.
#[test]
fn q8_dense_and_paged_caches_are_bit_identical() {
    for model in MODELS {
        let fwd = forward(model, "q4_k_m", 2, 0, KvScheme::Q8_0);
        let total = PROMPT.len() + DECODE_STEPS;
        let mut scratch = fwd.new_scratch();
        let mut logits = vec![0f32; fwd.vocab()];

        let mut dense = fwd.new_cache();
        let mut dense_rows: Vec<Vec<f32>> = Vec::new();
        for (j, &t) in PROMPT.iter().enumerate() {
            let want = if j + 1 == PROMPT.len() { Some(&mut logits[..]) } else { None };
            fwd.forward_token(t, &mut dense, &mut scratch, want).unwrap();
        }
        dense_rows.push(logits.clone());
        for _ in 0..DECODE_STEPS {
            let tok = argmax(dense_rows.last().unwrap());
            fwd.forward_token(tok, &mut dense, &mut scratch, Some(&mut logits)).unwrap();
            dense_rows.push(logits.clone());
        }

        let block_tokens = 5usize;
        let n_blocks = total.div_ceil(block_tokens);
        let mut pool = fwd.new_block_pool(n_blocks, block_tokens).unwrap();
        assert!(pool.try_reserve(n_blocks));
        let mut paged = fwd.new_paged_cache(&pool).unwrap();
        paged.grow_to(total, &mut pool).unwrap();
        let mut paged_rows: Vec<Vec<f32>> = Vec::new();
        for (j, &t) in PROMPT.iter().enumerate() {
            let want = if j + 1 == PROMPT.len() { Some(&mut logits[..]) } else { None };
            fwd.forward_token(t, &mut paged, &mut scratch, want).unwrap();
        }
        paged_rows.push(logits.clone());
        for _ in 0..DECODE_STEPS {
            let tok = argmax(paged_rows.last().unwrap());
            fwd.forward_token(tok, &mut paged, &mut scratch, Some(&mut logits)).unwrap();
            paged_rows.push(logits.clone());
        }

        assert_eq!(bits(&dense_rows), bits(&paged_rows), "{model}: q8 dense vs paged logits");
        assert_eq!(dense.len(), paged.len());
        assert_eq!(
            dense.copy_rows_enc(),
            paged.copy_rows_enc(),
            "{model}: encoded KV-row plane dense vs paged"
        );
        assert_eq!(
            dense.copy_expanded_enc(),
            paged.copy_expanded_enc(),
            "{model}: encoded expanded plane dense vs paged"
        );
        assert_eq!(
            slice_bits(&dense.copy_rows()),
            slice_bits(&paged.copy_rows()),
            "{model}: decoded KV-row plane dense vs paged"
        );
        paged.release(&mut pool);
        pool.unreserve(n_blocks);
    }
}

/// Batched panel decode (`forward_step_batch`, dead slot included) under
/// q8_0 matches solo per-slot decode bit for bit, step for step.
#[test]
fn q8_batched_decode_matches_solo() {
    for model in MODELS {
        let fwd = forward(model, "q4_k_m", 2, 0, KvScheme::Q8_0);
        let prompts: [&[i32]; 3] = [&[1, 17, 300], &[42, 511], &[7, 5, 260, 9]];
        let live = [true, false, true];
        let steps = 3usize;
        let v = fwd.vocab();

        // Batched run, recording each slot's fed token and logits row.
        let mut caches: Vec<_> = (0..prompts.len()).map(|_| fwd.new_cache()).collect();
        let mut scratch = fwd.new_scratch_cols(prompts.len());
        let mut logits = vec![0f32; prompts.len() * v];
        for (slot, p) in prompts.iter().enumerate() {
            for &t in *p {
                fwd.forward_token(t, &mut caches[slot], &mut scratch, None).unwrap();
            }
        }
        let mut fed: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        let mut rows: Vec<Vec<Vec<u32>>> = vec![Vec::new(); prompts.len()];
        let mut toks = [2i32, 3, 4];
        for _ in 0..steps {
            for (slot, &t) in toks.iter().enumerate() {
                fed[slot].push(t);
            }
            fwd.forward_step_batch(&toks, &live, &mut caches, &mut scratch, &mut logits).unwrap();
            for slot in 0..prompts.len() {
                rows[slot].push(slice_bits(&logits[slot * v..(slot + 1) * v]));
            }
            for (slot, t) in toks.iter_mut().enumerate() {
                if live[slot] {
                    *t = argmax(&logits[slot * v..(slot + 1) * v]);
                }
            }
        }

        // Solo replay of each live slot: same prompt, same fed tokens.
        for (slot, p) in prompts.iter().enumerate() {
            if !live[slot] {
                continue;
            }
            let mut cache = fwd.new_cache();
            let mut s = fwd.new_scratch();
            let mut l = vec![0f32; v];
            for &t in *p {
                fwd.forward_token(t, &mut cache, &mut s, None).unwrap();
            }
            for (step, &t) in fed[slot].iter().enumerate() {
                fwd.forward_token(t, &mut cache, &mut s, Some(&mut l)).unwrap();
                assert_eq!(
                    slice_bits(&l),
                    rows[slot][step],
                    "{model}: q8 batched vs solo, slot {slot} step {step}"
                );
            }
        }
    }
}

// --- lock 3: accuracy bound -----------------------------------------------

/// Teacher-forcing the f32-KV greedy trajectory through a q8_0 KV cache
/// must move logits measurably (quantization is real) but stay within a
/// small relative-L2 bound — KV-cache error is far below the
/// weight-quantization error budget the paper's schemes spend.
#[test]
fn q8_kv_tracks_f32_kv_within_bound() {
    for model in MODELS {
        for scheme in SCHEMES {
            let f32_fwd = forward(model, scheme, 1, 0, KvScheme::F32);
            let rows = run_script(&f32_fwd);
            let mut stream: Vec<i32> = PROMPT.to_vec();
            for r in &rows[..DECODE_STEPS] {
                stream.push(argmax(r));
            }
            let want_from = PROMPT.len() - 1;
            let base = run_forced(&f32_fwd, &stream, want_from);
            let q8 = run_forced(
                &forward(model, scheme, 1, 0, KvScheme::Q8_0),
                &stream,
                want_from,
            );
            assert_eq!(base.len(), q8.len());
            let worst = base
                .iter()
                .zip(&q8)
                .map(|(b, q)| rel_l2(q, b))
                .fold(0.0f64, f64::max);
            assert!(
                worst < 0.05,
                "{model}/{scheme}: q8-KV logits drift {worst:.3e} exceeds the 5e-2 bound"
            );
            assert_ne!(
                bits(&base),
                bits(&q8),
                "{model}/{scheme}: q8 KV should measurably perturb logits"
            );
        }
    }
}

// --- lock 4: planner-vs-engine bytes --------------------------------------

/// [`dsq::memory::kv_token_plan`] vs the engine's measured plan — entry
/// names and bytes must match exactly under both schemes, the block
/// pool must price tokens identically, and q8_0 must buy ≥3× vs f32.
#[test]
fn planner_kv_plan_matches_engine_measured_exactly() {
    for model in MODELS {
        let cfg = ModelConfig::by_name(model).unwrap();
        for kv in [KvScheme::F32, KvScheme::Q8_0] {
            let fwd = forward(model, "q4_k_m", 1, 0, kv);
            let cache = fwd.new_cache();
            let planned = memory::kv_token_plan(&cfg, kv, true);
            let measured = cache.measured_token_plan();
            assert_eq!(planned.len(), measured.len(), "{model}/{kv}: plan entry count");
            let mut diffs = Vec::new();
            for ((pn, pb), (mn, mb)) in planned.iter().zip(&measured) {
                if pn != mn || pb != mb {
                    diffs.push(format!("planner {pn}={pb} vs engine {mn}={mb}"));
                }
            }
            assert!(
                diffs.is_empty(),
                "{model}/{kv}: planner-vs-engine KV token plan drifted:\n{}",
                diffs.join("\n")
            );
            assert_eq!(
                memory::kv_bytes_per_token(&cfg, kv, true),
                cache.bytes_per_token() as u64,
                "{model}/{kv}: planner total vs engine cache"
            );
            let pool = fwd.new_block_pool(1, 4).unwrap();
            assert_eq!(
                pool.bytes_per_token(),
                cache.bytes_per_token(),
                "{model}/{kv}: block pool vs dense cache bytes per token"
            );
            assert_eq!(pool.block_bytes(), 4 * pool.bytes_per_token());
        }
        let f32b = memory::kv_bytes_per_token(&cfg, KvScheme::F32, true);
        let q8b = memory::kv_bytes_per_token(&cfg, KvScheme::Q8_0, true);
        assert!(
            q8b * 3 <= f32b,
            "{model}: q8_0 KV must be a ≥3× reduction (f32 {f32b} B/token, q8_0 {q8b})"
        );
    }
}

// --- lock 5: clean rejection ----------------------------------------------

#[test]
fn eager_mla_rejects_quantized_kv() {
    let ckpt = Container::from_bytes(qbytes("tiny-moe", "q4_k_m").to_vec()).unwrap();
    let mut fwd = ForwardPass::new(ckpt, 1, NATIVE_MAX_CTX).unwrap();
    fwd.set_mla_absorption(false);
    let err = fwd.set_kv_scheme(KvScheme::Q8_0).unwrap_err().to_string();
    assert!(err.contains("absorbed MLA"), "unexpected error: {err}");
    // f32 stays available to the eager path.
    fwd.set_kv_scheme(KvScheme::F32).unwrap();
}

#[test]
fn pool_created_under_another_scheme_is_rejected() {
    let ckpt = Container::from_bytes(qbytes("tiny-dense", "q4_k_m").to_vec()).unwrap();
    let mut fwd = ForwardPass::new(ckpt, 1, NATIVE_MAX_CTX).unwrap();
    let pool = fwd.new_block_pool(2, 4).unwrap();
    fwd.set_kv_scheme(KvScheme::Q8_0).unwrap();
    let err = fwd.new_paged_cache(&pool).unwrap_err().to_string();
    assert!(err.contains("does not match the block pool"), "unexpected error: {err}");
}
